# Convenience targets; `make verify` is the documented pre-merge check
# (tier-1 pytest + a 2-device sharded smoke test + the serve smoke test
# + the client smoke test + the cluster smoke test + the sweep/statistics
# smoke test).

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify serve-smoke client-smoke cluster-smoke sweep-smoke \
	test test-all bench

verify:
	$(PYTHON) -m repro.dev verify

serve-smoke:
	$(PYTHON) -m repro.dev serve-smoke

client-smoke:
	$(PYTHON) -m repro.dev client-smoke

cluster-smoke:
	$(PYTHON) -m repro.dev cluster-smoke

sweep-smoke:
	$(PYTHON) -m repro.dev sweep-smoke

test:
	$(PYTHON) -m pytest -x -q

test-all:
	$(PYTHON) -m pytest -q -m ""

bench:
	$(PYTHON) -m benchmarks.run
