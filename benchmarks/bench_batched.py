"""Beyond-paper: batched/fused device evaluation vs the per-query pattern.

pytrec_eval still walks queries in a Python loop (one C call per query dict).
The device-resident engine evaluates the whole [Q, D] tensor in one compiled
call, and the fused-measures kernel collapses all measure passes into one.
This benchmark quantifies that additional headroom on the paper's largest
grid (CPU here; the same program shards over a pod — see §Roofline).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import RelevanceEvaluator, measures as M
from repro.data.synthetic_ir import synthesize_run
from repro.kernels import ops

from benchmarks.common import time_call

MEASURES = ("map", "ndcg", "ndcg_cut", "P", "recall", "recip_rank")


def run(full: bool = False) -> List[Dict]:
    reps = 10 if full else 3
    nq, nd = (10_000, 1000) if full else (2000, 500)
    run_dict, qrel = synthesize_run(nq, nd)
    parsed = M.parse_measures(MEASURES)

    # 1. pytrec_eval pattern: dict API, one batch per call but per-query
    #    Python loop for densify + dict assembly.
    ev = RelevanceEvaluator(qrel, MEASURES)
    t_dict = time_call(lambda: ev.evaluate(run_dict), reps=reps)

    # 2. device-resident: dense tensors stay on device, one compiled call.
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.standard_normal((nq, nd)).astype(np.float32))
    rel = jnp.asarray((rng.random((nq, nd)) < 0.1).astype(np.float32))
    batch = M.batch_from_dense(scores, rel)
    compute = jax.jit(lambda b: M.compute_measures(b, parsed))
    t_dense = time_call(
        lambda: jax.block_until_ready(compute(batch)), reps=reps)

    # 3. fused single-pass kernel (interpret mode on CPU: structural check,
    #    the win is architectural on TPU).
    fused = jax.jit(lambda b: ops.evaluate_fused(b))
    t_fused = time_call(
        lambda: jax.block_until_ready(fused(batch)), reps=reps)

    rows = [{
        "n_queries": nq, "n_docs": nd,
        "dict_api_us": t_dict * 1e6,
        "dense_batched_us": t_dense * 1e6,
        "fused_kernel_us": t_fused * 1e6,
        "dense_speedup_vs_dict": t_dict / t_dense,
        "queries_per_s_dense": nq / t_dense,
    }]
    print(f"batched q={nq} d={nd}: dict={t_dict*1e3:.0f}ms "
          f"dense={t_dense*1e3:.0f}ms (x{t_dict/t_dense:.1f}) "
          f"fused(interp)={t_fused*1e3:.0f}ms")
    return rows
