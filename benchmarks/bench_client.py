"""Client segment: end-to-end serving throughput through ``repro.client``.

Where ``bench_serve`` drives the service in-process (no sockets — an upper
bound), this segment measures what a USER of the service actually sees:
JSON encoding, a real TCP connection, the server's reader loop, coalescing,
and response fan-in, end to end.

Protocol: one collection is registered and its run pinned (``register_run``)
on a live ``serve_tcp`` endpoint (:class:`repro.serve.testing.ServerThread`);
then

* **raw-socket baseline** — one connection, strict request→response
  lockstep (depth 1, no client library): the serialize-invoke-wait pattern
  the paper argues against, ported to the wire;
* **EvalClient pipelined** — one :class:`repro.client.AsyncEvalClient`
  connection with ``depth`` worker coroutines keeping ``depth`` requests in
  flight, so the server's micro-batcher actually coalesces.

Reported per row: sustained ``runs_per_s`` and client-observed p50/p99
latency.  Pipelining should raise throughput well past the lockstep
baseline (bigger coalesced batches amortize backend dispatch) at the cost
of per-request latency — exactly the window/batch trade documented in
``docs/SERVING.md``.
"""

from __future__ import annotations

import asyncio
import json
import time
from typing import Dict, List

import numpy as np

#: pipeline depths for the client rows (the acceptance bar is >= 2 depths)
DEPTHS = (1, 8)
DEPTHS_FULL = (1, 4, 16, 64)

MEASURES = ("map", "ndcg", "recip_rank")


def _percentiles(latencies: List[float]) -> Dict[str, float]:
    return {
        "p50_ms": 1e3 * float(np.quantile(latencies, 0.5)),
        "p99_ms": 1e3 * float(np.quantile(latencies, 0.99)),
    }


def _row(mode: str, depth: int, latencies: List[float],
         wall: float) -> Dict:
    row = {"mode": mode, "depth": depth, "requests": len(latencies),
           "runs_per_s": len(latencies) / wall}
    row.update(_percentiles(latencies))
    print(f"client {mode} depth={depth}: {row['runs_per_s']:.1f} runs/s, "
          f"p50 {row['p50_ms']:.1f}ms, p99 {row['p99_ms']:.1f}ms")
    return row


async def _raw_socket_loop(host: str, port: int, score_sets, requests: int,
                           warmup: int = 4) -> Dict:
    """Depth-1 lockstep over a bare socket — no client library at all."""
    reader, writer = await asyncio.open_connection(host, port)

    async def once(i: int) -> float:
        req = {"op": "evaluate", "id": i, "qrel_id": "bench",
               "run_ref": "r", "scores": score_sets[i % len(score_sets)]}
        t0 = time.perf_counter()
        writer.write(json.dumps(req).encode() + b"\n")
        await writer.drain()
        resp = json.loads(await reader.readline())
        assert resp["ok"], resp
        return time.perf_counter() - t0

    for i in range(warmup):
        await once(i)
    t0 = time.perf_counter()
    latencies = [await once(i) for i in range(requests)]
    wall = time.perf_counter() - t0
    writer.close()
    await writer.wait_closed()
    return _row("raw_socket", 1, latencies, wall)


async def _client_pipelined(host: str, port: int, score_sets,
                            requests: int, depth: int) -> Dict:
    """One AsyncEvalClient connection, ``depth`` requests kept in flight."""
    from repro.client import AsyncEvalClient

    client = await AsyncEvalClient.connect(host, port)
    # warm every coalesced-batch geometry this depth can produce
    wave = 1
    while True:
        await client.evaluate_many("bench", run_ref="r",
                                   scores_list=score_sets[:wave])
        if wave >= depth:
            break
        wave = min(wave * 2, depth)

    latencies: List[float] = []
    done = 0

    async def worker(w: int) -> None:
        nonlocal done
        k = w
        while done < requests:
            t0 = time.perf_counter()
            await client.evaluate("bench", run_ref="r",
                                  scores=score_sets[k % len(score_sets)])
            latencies.append(time.perf_counter() - t0)
            done += 1
            k += depth

    t0 = time.perf_counter()
    await asyncio.gather(*(worker(w) for w in range(depth)))
    wall = time.perf_counter() - t0
    await client.aclose()
    return _row("client", depth, latencies, wall)


def run(full: bool = False) -> List[Dict]:
    from repro.core import RelevanceEvaluator
    from repro.data.synthetic_ir import synthesize_run
    from repro.serve.testing import ServerThread

    n_queries, n_docs = (256, 128) if full else (64, 32)
    requests = 192 if full else 48
    depths = DEPTHS_FULL if full else DEPTHS

    run_dict, qrel = synthesize_run(n_queries, n_docs)
    n_scores = int(RelevanceEvaluator(qrel, ("map",))
                   .tokenize_run(run_dict).qidx.shape[0])
    rng = np.random.default_rng(0)
    # pre-generated, pre-listified score sets: the loop measures serving
    score_sets = [rng.normal(size=n_scores).astype(np.float32).tolist()
                  for _ in range(min(requests, 32))]

    rows: List[Dict] = []
    with ServerThread(service_kw=dict(window=0.002, max_batch=64,
                                      backend="single")) as srv:
        srv.register_qrel("bench", qrel, MEASURES)
        srv.register_run("bench", "r", run=run_dict)
        rows.append(asyncio.run(_raw_socket_loop(
            srv.host, srv.port, score_sets, requests)))
        for depth in depths:
            rows.append(asyncio.run(_client_pipelined(
                srv.host, srv.port, score_sets, requests, depth)))
        stats = srv.stats()
    for row in rows:
        row.update(n_queries=n_queries, n_docs=n_docs)
    print(f"client totals: {stats['requests']} evaluate requests -> "
          f"{stats['backend_calls']} backend calls "
          f"({stats['flushes']} flushes)")
    return rows
