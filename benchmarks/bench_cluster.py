"""Cluster segment: multi-worker scale-out vs the single-process plateau.

``bench_serve``/``bench_client`` top out around the single asyncio loop +
GIL of one ``repro.serve`` process (~415 runs/s on the reference host).
This segment measures what the consistent-hash router buys: the SAME
multi-collection workload driven through

* **single** — one in-process ``serve_tcp`` endpoint (the plateau), and
* **cluster** — ``repro.serve.cluster`` at 1, 2, and 4 workers (8 with
  ``--full``), collections spread across the ring so every worker's
  micro-batcher coalesces its own share of the traffic.

Rows report sustained ``runs_per_s``, client-observed p50/p99, and
``speedup_vs_single``.  Honesty matters here: worker processes only help
when there are cores to run them on, so every row also carries
``host_cpus`` (``os.cpu_count()``).  On a 1-core host the cluster rows
measure routing overhead, not scale-out — expect speedups < 1; on an
N-core host the 4-worker row is where the >= 2x aggregate-throughput
claim is checked.
"""

from __future__ import annotations

import asyncio
import os
import threading
import time
from typing import Dict, List

import numpy as np

#: cluster sizes measured (the paper-scale run adds 8)
WORKER_COUNTS = (1, 2, 4)
WORKER_COUNTS_FULL = (1, 2, 4, 8)

MEASURES = ("map", "ndcg", "recip_rank")
DEPTH = 16  # pipelined requests kept in flight by the driver


def _percentiles(latencies: List[float]) -> Dict[str, float]:
    return {
        "p50_ms": 1e3 * float(np.quantile(latencies, 0.5)),
        "p99_ms": 1e3 * float(np.quantile(latencies, 0.99)),
    }


def _make_workload(n_collections: int, n_queries: int, n_docs: int,
                   n_score_sets: int):
    """Per-collection qrel/run pairs + pre-listified score sets."""
    from repro.core import RelevanceEvaluator
    from repro.data.synthetic_ir import synthesize_run

    workload = {}
    rng = np.random.default_rng(0)
    for c in range(n_collections):
        cid = f"col{c}"
        run, qrel = synthesize_run(n_queries, n_docs, seed=c)
        n_scores = int(RelevanceEvaluator(qrel, ("map",))
                       .tokenize_run(run).qidx.shape[0])
        scores = [rng.normal(size=n_scores).astype(np.float32).tolist()
                  for _ in range(n_score_sets)]
        workload[cid] = {"qrel": qrel, "run": run, "scores": scores}
    return workload


def _register(host: str, port: int, workload) -> None:
    from repro.client import EvalClient

    with EvalClient(host, port) as client:
        for cid, spec in workload.items():
            client.register_qrel(cid, spec["qrel"], MEASURES)
            client.register_run(cid, "r", run=spec["run"])


async def _drive(host: str, port: int, workload, requests: int,
                 depth: int = DEPTH):
    """One pipelined client, round-robin over the collections."""
    from repro.client import AsyncEvalClient

    cids = list(workload)
    client = await AsyncEvalClient.connect(host, port)
    for cid in cids:  # warm every collection's compile/cache path
        await client.evaluate(cid, run_ref="r",
                              scores=workload[cid]["scores"][0])
    latencies: List[float] = []
    done = 0

    async def worker(w: int) -> None:
        nonlocal done
        k = w
        while done < requests:
            spec = workload[cids[k % len(cids)]]
            scores = spec["scores"][k % len(spec["scores"])]
            t0 = time.perf_counter()
            await client.evaluate(cids[k % len(cids)], run_ref="r",
                                  scores=scores)
            latencies.append(time.perf_counter() - t0)
            done += 1
            k += depth

    t0 = time.perf_counter()
    await asyncio.gather(*(worker(w) for w in range(depth)))
    wall = time.perf_counter() - t0
    await client.aclose()
    return latencies, wall


def _row(mode: str, workers: int, latencies: List[float],
         wall: float) -> Dict:
    row = {"mode": mode, "workers": workers, "depth": DEPTH,
           "requests": len(latencies), "runs_per_s": len(latencies) / wall,
           "host_cpus": os.cpu_count()}
    row.update(_percentiles(latencies))
    print(f"cluster {mode} workers={workers}: "
          f"{row['runs_per_s']:.1f} runs/s, p50 {row['p50_ms']:.1f}ms, "
          f"p99 {row['p99_ms']:.1f}ms")
    return row


def run(full: bool = False) -> List[Dict]:
    from repro.serve.cluster.testing import ClusterThread
    from repro.serve.testing import ServerThread

    n_collections = 8 if full else 6
    n_queries, n_docs = (128, 64) if full else (48, 24)
    requests = 480 if full else 160
    counts = WORKER_COUNTS_FULL if full else WORKER_COUNTS

    workload = _make_workload(n_collections, n_queries, n_docs,
                              n_score_sets=8)
    worker_args = ["--backend", "single", "--window-ms", "2",
                   "--max-batch", "64"]
    rows: List[Dict] = []

    # the single-process plateau, same workload, same pipelining
    with ServerThread(service_kw=dict(window=0.002, max_batch=64,
                                      backend="single",
                                      max_collections=n_collections)) as srv:
        _register(srv.host, srv.port, workload)
        latencies, wall = asyncio.run(_drive(srv.host, srv.port, workload,
                                             requests))
        rows.append(_row("single", 0, latencies, wall))
    baseline = rows[0]["runs_per_s"]

    for n in counts:
        with ClusterThread(n, worker_args=worker_args
                           + ["--max-collections", str(n_collections)],
                           router_kw=dict(health_interval=5.0)) as cluster:
            _register(cluster.host, cluster.port, workload)
            latencies, wall = asyncio.run(_drive(
                cluster.host, cluster.port, workload, requests))
            stats = cluster.stats()
        row = _row("cluster", n, latencies, wall)
        row["speedup_vs_single"] = row["runs_per_s"] / baseline
        row["forwarded"] = stats["router"]["forwarded"]
        rows.append(row)
        print(f"  speedup vs single-process: "
              f"{row['speedup_vs_single']:.2f}x "
              f"({row['host_cpus']} host cpu(s))")

    # replication (R=2 over 2 workers): what fan-out registration and
    # p2c reads cost when healthy, and — the headline robustness number —
    # the client-observed p50/p99 when one replica is SIGKILLed mid-run
    # and every request fails over to its sibling
    healthy_wall = 1.0
    for mode in ("replicated", "replicated-kill"):
        with ClusterThread(2, worker_args=worker_args
                           + ["--max-collections", str(n_collections)],
                           router_kw=dict(replication=2, retries=4,
                                          health_interval=5.0)) as cluster:
            _register(cluster.host, cluster.port, workload)
            timer = None
            if mode == "replicated-kill":
                victim = cluster.replicas_of(next(iter(workload)))[0]

                def _kill(name=victim, c=cluster):
                    try:
                        c.kill_worker(name)
                    except Exception:
                        pass  # the run already finished: nothing to kill

                timer = threading.Timer(max(0.2, 0.4 * healthy_wall),
                                        _kill)
                timer.start()
            try:
                latencies, wall = asyncio.run(_drive(
                    cluster.host, cluster.port, workload, requests))
            finally:
                if timer is not None:
                    timer.cancel()
            stats = cluster.stats()
        if mode == "replicated":
            healthy_wall = wall
        row = _row(mode, 2, latencies, wall)
        row["replication"] = 2
        row["speedup_vs_single"] = row["runs_per_s"] / baseline
        if mode == "replicated-kill":
            row["failovers"] = stats["router"]["failovers"]
            row["worker_retries"] = stats["router"]["worker_retries"]
        rows.append(row)
    return rows
