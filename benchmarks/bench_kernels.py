"""Kernel-layer segment: achieved vs roofline bandwidth + compile accounting.

Two questions, answered per shape:

1. **How close does the fused-measures kernel run to the memory roofline?**
   The kernel is bandwidth-bound — it reads the two ``[Q, D]`` tiles plus a
   ``[Q, 16]`` scalar block once and writes ``[Q, 64]`` — so achieved
   bytes/s against :data:`repro.analysis.roofline.HBM_BW` is the honest
   utilization number (``kernel_roofline``).  On this host the kernel runs
   in the backend-resolved execution mode (``ops.INTERPRET``: compiled on
   TPU, interpret elsewhere), and the mode is reported with every row.

2. **Is the compiled-signature set actually closed?**  A sweep over many
   distinct raw batch sizes is pushed through power-of-two bucketing
   (``repro.kernels.bucketing``) and the trace-time compile counters are
   read back: the retrace count must stay at the number of *buckets*, not
   the number of raw sizes.  This is the same accounting the serve layer's
   recompile-bound test asserts; here it is reported as data.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis import roofline
from repro.core import measures as M
from repro.kernels import autotune, bucketing, ops
from repro.kernels.fused_measures import OUT_WIDTH

from benchmarks.common import time_call

#: (Q, D) shapes for the roofline rows — small enough for interpret mode on
#: CPU hosts, large enough that the [Q, D] streams dominate the footprint.
SHAPES = ((256, 256), (512, 1024))
SHAPES_FULL = ((256, 256), (512, 1024), (1024, 1024), (1024, 4096))


def _fused_bytes(q: int, d: int) -> int:
    """HBM traffic of one fused_measures call (f32 in and out)."""
    return 4 * (2 * q * d + q * 16 + q * OUT_WIDTH)


def _roofline_rows(shapes, reps: int) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []
    for q, d in shapes:
        rel = jnp.asarray((rng.random((q, d)) < 0.1).astype(np.float32))
        judged = jnp.ones((q, d), jnp.float32)
        n_rel = jnp.sum(rel, axis=-1)
        scal = ops.make_scalars(n_rel, jnp.sum(judged, -1) - n_rel, rel)
        scal = jax.block_until_ready(scal)
        block_q = autotune.block_q_for(q, d)
        traces0 = bucketing.compile_count("fused_measures")
        t = time_call(
            lambda: jax.block_until_ready(
                ops.fused_measures_cols(rel, judged, scal)),
            reps=reps)
        rl = roofline.kernel_roofline(_fused_bytes(q, d), t)
        rows.append({
            "segment": "fused_roofline", "n_queries": q, "n_docs": d,
            "block_q": block_q, "interpret": ops.INTERPRET,
            "us_per_call": t * 1e6,
            "achieved_bytes_per_s": rl["achieved_bytes_per_s"],
            "peak_bytes_per_s": rl["peak_bytes_per_s"],
            "bw_fraction": rl["bw_fraction"],
            "new_compiles": bucketing.compile_count("fused_measures")
            - traces0,
        })
        mode = "interp" if ops.INTERPRET else "compiled"
        print(f"fused[{mode}] q={q} d={d} block_q={block_q}: "
              f"{t*1e3:.1f}ms  {rl['achieved_bytes_per_s']/1e9:.3f} GB/s "
              f"({100*rl['bw_fraction']:.4f}% of roofline)")
    return rows


def _bucketing_row(max_batch: int = 64) -> Dict:
    """Sweep distinct raw wave sizes; count retraces of the measure core.

    Uses a one-off measure tuple as the static jit key so the deltas are
    not absorbed by signatures other segments already compiled.
    """
    parsed = M.parse_measures(("recall_30", "success_5"))
    rng = np.random.default_rng(1)
    waves = sorted({max(1, (max_batch * k) // 9) for k in range(1, 10)}
                   | {1, max_batch})
    before = bucketing.compile_count("measure_core")
    t0 = time.perf_counter()
    for nq in waves:
        nq_pad = bucketing.bucket_queries(nq)
        scores = rng.standard_normal((nq, 32)).astype(np.float32)
        rel = (rng.random((nq, 32)) < 0.2).astype(np.float32)
        if nq_pad != nq:
            pad = ((0, nq_pad - nq), (0, 0))
            scores, rel = np.pad(scores, pad), np.pad(rel, pad)
        qmask = jnp.asarray(np.arange(nq_pad) < nq)
        batch = M.batch_from_dense(jnp.asarray(scores), jnp.asarray(rel),
                                   query_mask=qmask)
        jax.block_until_ready(M.compute_measures_jit(batch, parsed))
    elapsed = time.perf_counter() - t0
    compiles = bucketing.compile_count("measure_core") - before
    bound = bucketing.max_signatures(max_batch)
    print(f"bucketing: {len(waves)} distinct wave sizes (1..{max_batch}) -> "
          f"{compiles} compiles (closed-set bound {bound}) "
          f"in {elapsed*1e3:.0f}ms")
    return {
        "segment": "bucketing_sweep", "distinct_wave_sizes": len(waves),
        "max_batch": max_batch, "compiles": compiles,
        "signature_bound": bound, "elapsed_s": elapsed,
        "trace_counts": bucketing.trace_counts(),
    }


def run(full: bool = False) -> List[Dict]:
    reps = 10 if full else 3
    shapes = SHAPES_FULL if full else SHAPES
    rows = _roofline_rows(shapes, reps)
    rows.append(_bucketing_row(128 if full else 64))
    return rows
