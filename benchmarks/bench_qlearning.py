"""Paper Fig. 3: Q-learning query expansion — average reward (ΔNDCG) rises
over training, enabled by cheap in-process evaluation on every env step."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.data import synthetic_ir as sir
from repro.rl.environment import EnvConfig, QueryExpansionEnv
from repro.rl.qlearning import QLearningAgent, QLearningConfig


def run(full: bool = False) -> List[Dict]:
    cfg = sir.CollectionConfig(
        vocab_size=2000 if full else 200,
        n_docs=100 if full else 50,
        n_queries=100 if full else 8,  # few queries → many visits per state
        avg_doc_len=200 if full else 60, seed=0)
    coll = sir.build_collection(cfg)
    env = QueryExpansionEnv(coll, EnvConfig(depth=10,
                                            max_actions=5 if full else 3))
    agent = QLearningAgent(env, QLearningConfig(
        n_candidate_actions=128 if full else 48, seed=0))
    qids = list(coll.qrels)
    episodes = 2000 if full else 400
    t0 = time.perf_counter()
    rewards = agent.train(qids, episodes=episodes)
    dt = time.perf_counter() - t0
    w = max(episodes // 10, 1)
    head = float(np.mean(rewards[:w]))
    tail = float(np.mean(rewards[-w:]))
    print(f"qlearning: episodes={episodes} head_avg={head:+.4f} "
          f"tail_avg={tail:+.4f} eps/s={episodes/dt:.1f}")
    return [{"episodes": episodes, "head_avg_reward": head,
             "tail_avg_reward": tail, "episodes_per_s": episodes / dt,
             "learned": tail > head}]
