"""RQ1 (paper Fig. 1): speedup of in-process evaluation over the
serialize-invoke-parse workflow, across query/doc grid sizes and storages.

Also hosts the ``densify`` segment (:func:`densify`) — the run→``EvalBatch``
conversion cost in isolation, comparing the seed per-query loop, the
vectorized cold dict ingest, and the pre-tokenized session path.

The paper's protocol, reproduced: rankings synthesized with distinct integer
scores and relevance 1 (``synthesize_run``); the run is serialized unsorted;
the child's stdout is read into a string but not parsed; speedup =
t(serialize-invoke-parse) / t(in-process).
"""

from __future__ import annotations

from typing import Dict, List

from repro.baselines import workflow
from repro.core import RelevanceEvaluator
from repro.data.synthetic_ir import synthesize_run

from benchmarks.common import storage_dirs, time_call

MEASURES = ("map", "ndcg")


def run(full: bool = False) -> List[Dict]:
    reps = 20 if full else 3
    grid_q = (1, 10, 100, 1000, 10_000) if full else (1, 10, 100, 1000)
    grid_d = (1, 10, 100, 1000)
    rows = []
    for nq in grid_q:
        for nd in grid_d:
            run_dict, qrel = synthesize_run(nq, nd)

            def in_process():
                ev = RelevanceEvaluator(qrel, MEASURES)
                ev.evaluate(run_dict)  # vectorized densify path (default)

            t_in = time_call(in_process, reps=reps)
            row = {"n_queries": nq, "n_docs": nd,
                   "inprocess_us": t_in * 1e6}
            for storage, workdir in storage_dirs().items():
                t_sip = time_call(
                    lambda: workflow.serialize_invoke_parse(
                        run_dict, qrel, workdir, MEASURES),
                    reps=reps, warmup=0)
                row[f"sip_{storage}_us"] = t_sip * 1e6
                row[f"speedup_{storage}"] = t_sip / t_in
            rows.append(row)
            print(f"rq1 q={nq} d={nd}: " + " ".join(
                f"{k}={row[k]:.1f}" for k in row if k.startswith("speedup")))
    return rows


def densify(full: bool = False) -> List[Dict]:
    """Densify segment: run→``EvalBatch`` conversion cost in isolation.

    Three timings per grid point, all producing bit-identical batches
    (proved by ``tests/test_densify.py``):

    * ``reference`` — the seed per-query-loop densifier
      (``RelevanceEvaluator(..., densify="reference")``);
    * ``vectorized`` — the flat pipeline on dict-of-dicts input (cold: pays
      the Python→numpy docno/score extraction every call);
    * ``session`` — ``batch_from_buffer`` on a pre-tokenized ``RunBuffer``,
      the steady-state cost when the same collection is evaluated repeatedly
      (the paper's "conversion happens once" pitch; this is what
      ``evaluate_many`` / ``core.streaming`` pay per step after the first).

    ``speedup_densify`` (reference/session) is the headline; ``speedup_cold``
    (reference/vectorized) isolates the one-shot dict-ingest win.
    """
    reps = 20 if full else 5
    grid = ((100, 100), (100, 1000), (1000, 100), (1000, 1000))
    rows = []
    for nq, nd in grid:
        run_dict, qrel = synthesize_run(nq, nd)
        qids = list(run_dict)
        ev_vec = RelevanceEvaluator(qrel, MEASURES)
        ev_ref = RelevanceEvaluator(qrel, MEASURES, densify="reference")
        t_ref = time_call(lambda: ev_ref._densify(run_dict, qids), reps=reps)
        t_cold = time_call(lambda: ev_vec._densify(run_dict, qids), reps=reps)
        buf = ev_vec.tokenize_run(run_dict)
        t_sess = time_call(lambda: ev_vec.batch_from_buffer(buf), reps=reps)
        row = {
            "n_queries": nq, "n_docs": nd,
            "reference_us": t_ref * 1e6,
            "vectorized_us": t_cold * 1e6,
            "session_us": t_sess * 1e6,
            "speedup_cold": t_ref / t_cold,
            "speedup_densify": t_ref / t_sess,
        }
        rows.append(row)
        print(f"densify q={nq} d={nd}: ref={t_ref*1e6:.0f}us "
              f"cold={t_cold*1e6:.0f}us ({row['speedup_cold']:.2f}x) "
              f"session={t_sess*1e6:.0f}us "
              f"({row['speedup_densify']:.2f}x)")
    return rows
