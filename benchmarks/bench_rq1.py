"""RQ1 (paper Fig. 1): speedup of in-process evaluation over the
serialize-invoke-parse workflow, across query/doc grid sizes and storages.

The paper's protocol, reproduced: rankings synthesized with distinct integer
scores and relevance 1 (``synthesize_run``); the run is serialized unsorted;
the child's stdout is read into a string but not parsed; speedup =
t(serialize-invoke-parse) / t(in-process).
"""

from __future__ import annotations

from typing import Dict, List

from repro.baselines import workflow
from repro.core import RelevanceEvaluator
from repro.data.synthetic_ir import synthesize_run

from benchmarks.common import storage_dirs, time_call

MEASURES = ("map", "ndcg")


def run(full: bool = False) -> List[Dict]:
    reps = 20 if full else 3
    grid_q = (1, 10, 100, 1000, 10_000) if full else (1, 10, 100, 1000)
    grid_d = (1, 10, 100, 1000)
    rows = []
    for nq in grid_q:
        for nd in grid_d:
            run_dict, qrel = synthesize_run(nq, nd)

            def in_process():
                ev = RelevanceEvaluator(qrel, MEASURES)
                ev.evaluate(run_dict)

            t_in = time_call(in_process, reps=reps)
            row = {"n_queries": nq, "n_docs": nd,
                   "inprocess_us": t_in * 1e6}
            for storage, workdir in storage_dirs().items():
                t_sip = time_call(
                    lambda: workflow.serialize_invoke_parse(
                        run_dict, qrel, workdir, MEASURES),
                    reps=reps, warmup=0)
                row[f"sip_{storage}_us"] = t_sip * 1e6
                row[f"speedup_{storage}"] = t_sip / t_in
            rows.append(row)
            print(f"rq1 q={nq} d={nd}: " + " ".join(
                f"{k}={row[k]:.1f}" for k in row if k.startswith("speedup")))
    return rows
