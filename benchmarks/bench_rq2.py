"""RQ2 (paper Fig. 2): in-process engine vs native-Python NDCG, single query,
varying ranking depth.  The paper finds native Python wins below ~5 docs
(internal-format conversion overhead) and loses ~2× at 100–1000 docs.
"""

from __future__ import annotations

from typing import Dict, List

from repro.baselines import native_ndcg
from repro.core import RelevanceEvaluator
from repro.data.synthetic_ir import synthesize_run

from benchmarks.common import time_call


def run(full: bool = False) -> List[Dict]:
    reps = 20 if full else 5
    depths = (1, 2, 3, 5, 10, 31, 100, 316, 1000, 3162, 10_000)
    rows = []
    for nd in depths:
        run_dict, qrel = synthesize_run(1, nd)
        docs, rels = run_dict["q0"], qrel["q0"]

        # evaluator construction (the one-time qrel parse) is outside the
        # timed region, matching the paper's per-evaluation comparison
        ev = RelevanceEvaluator(qrel, ("ndcg",))
        ev_ref = RelevanceEvaluator(qrel, ("ndcg",), densify="reference")
        t_ours = time_call(lambda: ev.evaluate(run_dict), reps=reps)
        t_native = time_call(lambda: native_ndcg.ndcg(docs, rels), reps=reps)
        # densify segment: the conversion share of the RQ2 crossover —
        # vectorized vs the seed per-query loop, at a single tiny query
        t_dens = time_call(lambda: ev._densify(run_dict, ["q0"]), reps=reps)
        t_dens_ref = time_call(lambda: ev_ref._densify(run_dict, ["q0"]),
                               reps=reps)
        rows.append({"n_docs": nd, "ours_us": t_ours * 1e6,
                     "native_us": t_native * 1e6,
                     "densify_us": t_dens * 1e6,
                     "densify_ref_us": t_dens_ref * 1e6,
                     "speedup": t_native / t_ours})
        print(f"rq2 d={nd}: ours={t_ours*1e6:.0f}us native="
              f"{t_native*1e6:.0f}us speedup={t_native/t_ours:.2f} "
              f"densify={t_dens*1e6:.0f}us (ref {t_dens_ref*1e6:.0f}us)")
    return rows
