"""Serve segment: sustained throughput/latency of the evaluation service.

Protocol: one collection (paper §3 synthetic protocol) is registered and its
run pinned via ``register_run``; then, at each concurrency level C, C client
coroutines issue score-only re-scoring requests back to back for a fixed
request budget.  This measures the serving hot path end to end — request
validation → ``with_scores`` → micro-batch coalescing → ONE
``evaluate_buffers`` backend call per window → per-request result fan-out —
the same work a training loop or A/B harness generates against a resident
service.

Reported per level: sustained ``runs_per_s`` (completed requests / wall),
mean per-request latency, and the coalescing factor (requests per backend
call).  Higher concurrency should raise throughput (bigger coalesced
batches amortize dispatch) until the batch cost itself dominates.
"""

from __future__ import annotations

import asyncio
import time
from typing import Dict, List

import numpy as np

#: concurrency levels (the acceptance bar is >= 2 levels)
LEVELS = (1, 4, 16)
LEVELS_FULL = (1, 2, 4, 8, 16, 32, 64)


def _drive(n_queries: int, n_docs: int, requests: int,
           concurrency: int, window: float) -> Dict:
    from repro.core import RelevanceEvaluator
    from repro.data.synthetic_ir import synthesize_run
    from repro.serve import EvaluationService

    run, qrel = synthesize_run(n_queries, n_docs)
    ev = RelevanceEvaluator(qrel, ("map", "ndcg", "recip_rank"))
    n_scores = int(ev.tokenize_run(run).qidx.shape[0])
    rng = np.random.default_rng(0)
    # pre-generate score sets so the clients measure serving, not RNG
    score_sets = [rng.normal(size=n_scores).astype(np.float32)
                  for _ in range(min(requests, 32))]

    async def bench() -> Dict:
        svc = EvaluationService(window=window, max_batch=max(concurrency, 1),
                                backend="single")
        svc.register_qrel("bench", qrel, ("map", "ndcg", "recip_rank"))
        svc.register_run("bench", "r", run=run)
        # Warmup: pre-compile the closed set of padded geometries.  Shape
        # bucketing (repro.kernels.bucketing) guarantees any wave size maps
        # onto one of log2(concurrency)+O(1) signature classes, so sweeping
        # doubling wave sizes here is cheap and exhaustive — the timed
        # section measures serving with a fully warm jit cache.
        wave = 1
        while True:
            await asyncio.gather(*(
                svc.evaluate("bench", run_ref="r",
                             scores=score_sets[i % len(score_sets)])
                for i in range(wave)))
            if wave >= concurrency:
                break
            wave = min(wave * 2, concurrency)
        # snapshot AFTER warmup so the reported coalescing factor covers
        # only the timed section (warmup waves are small on purpose and
        # would otherwise understate requests-per-backend-call)
        warmup_calls = svc.stats()["backend_calls"]

        done = 0
        latencies: List[float] = []

        async def client(i: int) -> None:
            nonlocal done
            k = i
            while done < requests:
                t0 = time.perf_counter()
                await svc.evaluate("bench", run_ref="r",
                                   scores=score_sets[k % len(score_sets)])
                latencies.append(time.perf_counter() - t0)
                done += 1
                k += concurrency

        t0 = time.perf_counter()
        await asyncio.gather(*(client(i) for i in range(concurrency)))
        wall = time.perf_counter() - t0
        timed_calls = svc.stats()["backend_calls"] - warmup_calls
        return {
            "concurrency": concurrency,
            "requests": len(latencies),
            "runs_per_s": len(latencies) / wall,
            "mean_latency_ms": 1e3 * float(np.mean(latencies)),
            "p90_latency_ms": 1e3 * float(np.quantile(latencies, 0.9)),
            "backend_calls": timed_calls,
            "coalesce_factor": len(latencies) / max(timed_calls, 1),
        }

    return asyncio.run(bench())


def run(full: bool = False) -> List[Dict]:
    n_queries, n_docs = (512, 256) if full else (128, 64)
    requests = 256 if full else 48
    window = 0.002
    rows: List[Dict] = []
    for concurrency in (LEVELS_FULL if full else LEVELS):
        row = _drive(n_queries, n_docs, requests, concurrency, window)
        row.update(n_queries=n_queries, n_docs=n_docs, window_s=window)
        rows.append(row)
        print(f"serve c={row['concurrency']}: "
              f"{row['runs_per_s']:.1f} runs/s, "
              f"mean latency {row['mean_latency_ms']:.1f}ms, "
              f"{row['coalesce_factor']:.1f} req/backend-call")
    return rows
