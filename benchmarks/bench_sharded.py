"""Sharded segment: evaluation throughput scaling vs. device count.

Each device count runs in its own subprocess (the XLA host-platform device
count must be fixed before jax initializes, exactly like
``tests/test_distributed.py``) with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.  The child builds one
synthesized collection (paper §3 protocol), tokenizes the run once, and times
the steady-state sharded step — ``ShardedEvaluator.evaluate_buffer`` on the
cached ``RunBuffer``: numeric scatter → shard_map → fused kernel per shard →
one psum.  ``speedup_vs_1dev`` is the wall-clock ratio against the 1-device
subprocess.

Host-platform "devices" are CPU threads sharing one machine, so the scaling
curve here is a plumbing/overhead check, not a hardware claim: it verifies
the collective payload stays O(measures), and on a real TPU mesh the same
code path shards the sort + fused kernel across chips.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CHILD = """
import json, sys
import numpy as np
from repro.core import RelevanceEvaluator
from repro.data.synthetic_ir import synthesize_run
from repro.distributed import ShardedEvaluator
from benchmarks.common import time_call

n_queries, n_docs, reps = (int(x) for x in sys.argv[1:4])
run, qrel = synthesize_run(n_queries, n_docs)
ev = RelevanceEvaluator(qrel, ("map", "ndcg", "recip_rank", "P"))
buf = ev.tokenize_run(run)
sev = ShardedEvaluator(ev)
t = time_call(lambda: sev.evaluate_buffer(buf), reps=reps)
print(json.dumps({"devices": sev.n_shards, "sharded_us": t * 1e6}))
"""


def run(full: bool = False) -> List[Dict]:
    n_queries, n_docs = (2048, 1000) if full else (512, 256)
    reps = 10 if full else 3
    rows: List[Dict] = []
    base_us = None
    for devices in (1, 2, 4, 8):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [SRC, os.path.join(SRC, ".."), env.get("PYTHONPATH", "")])
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
        out = subprocess.run(
            [sys.executable, "-c", _CHILD,
             str(n_queries), str(n_docs), str(reps)],
            capture_output=True, text=True, env=env,
            cwd=os.path.join(os.path.dirname(__file__), ".."))
        if out.returncode != 0:
            print(f"sharded devices={devices}: FAILED\n{out.stderr[-800:]}")
            continue
        row = json.loads(out.stdout.strip().splitlines()[-1])
        row.update(n_queries=n_queries, n_docs=n_docs)
        if row["devices"] == 1:  # only the true 1-device run seeds the base
            base_us = row["sharded_us"]
        row["speedup_vs_1dev"] = (base_us / row["sharded_us"]
                                  if base_us is not None else None)
        rows.append(row)
        rel = (f"({row['speedup_vs_1dev']:.2f}x vs 1 device)"
               if base_us is not None else "(1-device baseline missing)")
        print(f"sharded devices={devices}: {row['sharded_us']:.0f}us {rel}")
    return rows
