"""Sweep segment: K-run batched evaluation + in-JAX significance testing.

Two claims measured, both at K in the tens-to-hundreds (the hyperparameter
sweeps the paper argues cheap evaluation enables):

1. **Sweep evaluation throughput** — ``evaluate_sweep`` (K runs stacked on
   the query axis, chunked measure-core dispatches) vs the loop of K
   independent ``evaluate_buffer`` calls it is bit-identical to.  Both
   paths are post-tokenization, so the delta is pure dispatch/padding
   amortization.
2. **Significance-testing speedup** — the vectorized all-pairs paired
   t-test + Holm correction (:mod:`repro.stats`, one ``[K, K, Q]``
   reduction) vs the scipy-per-pair baseline every IR toolkit ships: a
   Python loop of ``scipy.stats.ttest_rel`` over all K·(K-1)/2 pairs plus
   a numpy Holm pass.  The acceptance gate is >=5x at K>=64; the scipy
   baseline row is skipped (with a note) when scipy is not installed.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

import jax

from repro.core import RelevanceEvaluator, evaluate_sweep
from repro.data.synthetic_ir import synthesize_run

from benchmarks.common import time_call

#: (K, Q, D) grid: runs per sweep, queries, docs per query
GRID = ((16, 64, 32), (64, 64, 32))
GRID_FULL = ((16, 128, 64), (64, 128, 64), (128, 128, 64), (256, 128, 64))

MEASURES = ("map", "ndcg", "P_10")

#: ``--full`` showcase: one K=512 sweep over a deliberately mixed-dialect
#: measure request — both spellings resolve to the same registry
#: selectors, so the dialect front-end is cost-neutral on the hot path.
SHOWCASE_K = 512
SHOWCASE_MEASURES = ("AP", "nDCG@10", "P_10", "Judged@10",
                     "RBP(p=0.8)", "ERR@20")


def _scipy_pairs(x: np.ndarray):
    """The baseline: scipy per pair + numpy Holm over the p matrix."""
    from scipy import stats as sps

    k = x.shape[0]
    t = np.zeros((k, k))
    p = np.ones((k, k))
    for i in range(k):
        for j in range(i + 1, k):
            r = sps.ttest_rel(x[i], x[j])
            t[i, j], t[j, i] = r.statistic, -r.statistic
            p[i, j] = p[j, i] = r.pvalue
    iu = np.triu_indices(k, 1)
    flat = p[iu]
    order = np.argsort(flat)
    m = len(flat)
    adj = np.minimum(
        np.maximum.accumulate(flat[order] * (m - np.arange(m))), 1.0)
    holm = np.empty_like(flat)
    holm[order] = adj
    out = p.copy()
    out[iu] = holm
    out[iu[1], iu[0]] = holm
    return t, p, out


def run(full: bool = False) -> List[Dict]:
    from repro import stats

    reps = 10 if full else 3
    grid = GRID_FULL if full else GRID
    try:
        import scipy.stats  # noqa: F401
        have_scipy = True
    except ImportError:
        have_scipy = False
        print("scipy not installed: per-pair baseline rows skipped")

    rows: List[Dict] = []
    rng = np.random.default_rng(0)
    for k, q, d in grid:
        run0, qrel = synthesize_run(q, d, seed=7)
        ev = RelevanceEvaluator(qrel, MEASURES)
        runs = []
        for _ in range(k):
            scored = {qid: {doc: float(s) for doc, s in
                            zip(docs, rng.random(len(docs)))}
                      for qid, docs in run0.items()}
            runs.append(scored)
        bufs = [ev.tokenize_run(r) for r in runs]

        sweep_t = time_call(lambda: evaluate_sweep(ev, bufs), reps=reps)
        loop_t = time_call(
            lambda: [ev.evaluate_buffer(b) for b in bufs], reps=reps)

        x = np.ascontiguousarray(evaluate_sweep(ev, bufs).measure("map"))

        def jax_stats():
            _, p = stats.paired_t_matrix(x)
            return jax.block_until_ready(stats.holm_matrix(p))

        stats_t = time_call(jax_stats, reps=reps)
        row = {
            "segment": "sweep", "n_runs": k, "n_queries": q, "n_docs": d,
            "sweep_us": sweep_t * 1e6, "loop_us": loop_t * 1e6,
            "eval_speedup": loop_t / sweep_t,
            "stats_us": stats_t * 1e6,
        }
        if have_scipy:
            scipy_t = time_call(lambda: _scipy_pairs(x), reps=reps)
            row["scipy_us"] = scipy_t * 1e6
            row["stats_speedup"] = scipy_t / stats_t
            extra = f"  t+holm {stats_t*1e3:.2f}ms vs scipy " \
                    f"{scipy_t*1e3:.2f}ms ({scipy_t/stats_t:.1f}x)"
        else:
            extra = f"  t+holm {stats_t*1e3:.2f}ms (no scipy baseline)"
        print(f"sweep k={k} q={q} d={d}: eval {sweep_t*1e3:.1f}ms vs "
              f"loop {loop_t*1e3:.1f}ms ({loop_t/sweep_t:.2f}x){extra}")
        rows.append(row)
    if full:
        rows.append(_showcase_row())
    return rows


def _showcase_row() -> Dict:
    """K=512 mixed-dialect sweep; reports per-(run, query, measure) cost.

    Tagged ``"kind": "showcase"`` — the CI speedup gate skips it (there is
    no scipy baseline here; the row exists to pin the cost of the measure
    set a dialect-mixing caller actually requests).
    """
    from repro.core import registry

    q, d = 64, 32
    run0, qrel = synthesize_run(q, d, seed=11)
    ev = RelevanceEvaluator(qrel, SHOWCASE_MEASURES)
    base = ev.tokenize_run(run0)
    rng = np.random.default_rng(1)
    n = base.scores.shape[0]
    bufs = [base.with_scores(rng.random(n)) for _ in range(SHOWCASE_K)]
    sweep_t = time_call(lambda: evaluate_sweep(ev, bufs), reps=3)
    keys = list(ev.measure_keys)
    cell_ns = sweep_t * 1e9 / (SHOWCASE_K * q * len(keys))
    print(f"sweep showcase k={SHOWCASE_K} q={q} d={d} "
          f"measures={[registry.render_ir(k) for k in keys]}: "
          f"{sweep_t*1e3:.1f}ms total, {cell_ns:.0f}ns per "
          f"run x query x measure")
    return {
        "segment": "sweep", "kind": "showcase", "n_runs": SHOWCASE_K,
        "n_queries": q, "n_docs": d,
        "measures": [registry.render_ir(k) for k in keys],
        "measure_keys": keys,
        "sweep_us": sweep_t * 1e6,
        "ns_per_run_query_measure": cell_ns,
    }
