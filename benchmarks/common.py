"""Shared benchmark utilities (timing protocol follows the paper §3)."""

from __future__ import annotations

import os
import time
from typing import Callable


def time_call(fn: Callable, reps: int = 3, warmup: int = 1) -> float:
    """Mean wall seconds over ``reps`` (after ``warmup`` unmeasured calls).

    The paper repeats every configuration 20 times and reports the average;
    ``--full`` restores that (reps=20).  Warmup excludes one-time jit
    compilation, which has no analogue in the C tool being reproduced.
    """
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def storage_dirs() -> dict:
    """Available storage backends: disk (filesystem) and tmpfs (RAM)."""
    out = {"disk": "/tmp/repro_bench"}
    if os.path.isdir("/dev/shm"):
        out["tmpfs"] = "/dev/shm/repro_bench"
    for d in out.values():
        os.makedirs(d, exist_ok=True)
    return out
