"""Benchmark driver — one section per paper table/figure plus system segments.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--list]

Segments (repeat ``--only`` to pick several):

* ``rq1``       — paper Fig. 1: in-process vs serialize-invoke-parse grid.
* ``rq2``       — paper Fig. 2: tiny-ranking crossover vs trec_eval.
* ``densify``   — run→``EvalBatch`` conversion in isolation: seed per-query
  loop vs the vectorized flat pipeline (cold dict ingest) vs the
  pre-tokenized session path (``batch_from_buffer`` on a ``RunBuffer``).
* ``kernels``   — kernel-layer roofline: fused-measures achieved vs peak
  bytes/s, execution mode (``ops.INTERPRET``), autotuned ``block_q``, and
  the compile-count accounting behind shape bucketing; see
  ``bench_kernels``.
* ``sharded``   — multi-device scaling of the sharded evaluation pipeline
  (``repro.distributed.sharded_evaluator``) over 1/2/4/8 host-platform
  devices; subprocess-per-device-count, see ``bench_sharded``.
* ``serve``     — sustained throughput/latency of the async evaluation
  service (``repro.serve``) at several client-concurrency levels, including
  the request-coalescing factor; see ``bench_serve``.
* ``client``    — the same serving hot path measured END TO END through a
  real TCP socket and ``repro.client``: a raw-socket lockstep baseline vs
  ``AsyncEvalClient`` pipelining at several depths; see ``bench_client``.
* ``cluster``   — multi-worker scale-out (``repro.serve.cluster``): the
  same multi-collection workload through one in-process server vs the
  consistent-hash router at 1/2/4 workers (8 under ``--full``), with
  ``speedup_vs_single`` and the host core count; see ``bench_cluster``.
* ``qlearning`` — the paper's RL demo, episodes/s.
* ``batched``   — dense batched evaluation vs the dict API.
* ``sweep``     — K-run sweep evaluation (``evaluate_sweep``) vs K
  independent ``evaluate_buffer`` calls, and the vectorized all-pairs
  paired t-test + Holm (``repro.stats``) vs a scipy-per-pair baseline;
  see ``bench_sweep``.

Prints ``name,us_per_call,derived`` CSV rows (plus a JSON dump under
experiments/bench_results.json for EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import json
import os

#: Segment name -> "module.function" (resolved lazily in main(); keeping the
#: registry import-free lets ``--list`` answer without loading jax, and gives
#: the docs-drift test one authoritative name list to compare against).
SEGMENTS = {
    "rq1": "bench_rq1.run",
    "rq2": "bench_rq2.run",
    "densify": "bench_rq1.densify",
    "kernels": "bench_kernels.run",
    "sharded": "bench_sharded.run",
    "serve": "bench_serve.run",
    "client": "bench_client.run",
    "cluster": "bench_cluster.run",
    "qlearning": "bench_qlearning.run",
    "batched": "bench_batched.run",
    "sweep": "bench_sweep.run",
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (20 reps, 10k queries)")
    ap.add_argument("--only", action="append", default=None,
                    choices=tuple(SEGMENTS),
                    help="segment to run (repeatable; default: all): "
                         "rq1/rq2 = paper figures, densify = run->EvalBatch "
                         "conversion paths, kernels = roofline + compile "
                         "accounting, sharded = multi-device scaling, "
                         "serve = async service throughput/latency, "
                         "client = TCP client library end to end, "
                         "cluster = multi-worker router scale-out, "
                         "qlearning = RL demo, batched = dense batched "
                         "eval, sweep = K-run sweep + significance stats")
    ap.add_argument("--list", action="store_true",
                    help="print the segment names (one per line) and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in SEGMENTS:
            print(name)
        return

    from benchmarks import bench_batched, bench_client, bench_cluster, \
        bench_kernels, bench_qlearning, bench_rq1, bench_rq2, bench_serve, \
        bench_sharded, bench_sweep

    modules = {
        "bench_batched": bench_batched, "bench_client": bench_client,
        "bench_cluster": bench_cluster, "bench_kernels": bench_kernels,
        "bench_qlearning": bench_qlearning, "bench_rq1": bench_rq1,
        "bench_rq2": bench_rq2, "bench_serve": bench_serve,
        "bench_sharded": bench_sharded, "bench_sweep": bench_sweep,
    }
    suites = {}
    for name, ref in SEGMENTS.items():
        mod, fn = ref.split(".")
        suites[name] = getattr(modules[mod], fn)
    selected = args.only or list(suites)
    results = {}
    for name in selected:
        print(f"=== {name} ===", flush=True)
        results[name] = suites[name](full=args.full)

    os.makedirs("experiments", exist_ok=True)
    # Merge into the existing record: a partial run (--only X) must refresh
    # segment X without dropping every other segment's stored results.
    path = "experiments/bench_results.json"
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as fh:
                merged = json.load(fh)
        except (json.JSONDecodeError, OSError):
            merged = {}
    merged.update(results)
    with open(path, "w") as fh:
        json.dump(merged, fh, indent=1)

    print("\nname,us_per_call,derived")
    for row in results.get("rq1", []):
        for k in row:
            if k.startswith("speedup_"):
                print(f"rq1_q{row['n_queries']}_d{row['n_docs']}_{k[8:]},"
                      f"{row['inprocess_us']:.1f},speedup={row[k]:.2f}")
    for row in results.get("rq2", []):
        print(f"rq2_d{row['n_docs']},{row['ours_us']:.1f},"
              f"speedup={row['speedup']:.2f}")
    for row in results.get("densify", []):
        print(f"densify_q{row['n_queries']}_d{row['n_docs']},"
              f"{row['session_us']:.1f},"
              f"speedup={row['speedup_densify']:.2f}")
    for row in results.get("kernels", []):
        if row["segment"] == "fused_roofline":
            print(f"kernels_fused_q{row['n_queries']}_d{row['n_docs']},"
                  f"{row['us_per_call']:.1f},"
                  f"bw_fraction={row['bw_fraction']:.6f}")
        else:
            print(f"kernels_bucketing_w{row['distinct_wave_sizes']},"
                  f"{1e6 * row['elapsed_s'] / row['distinct_wave_sizes']:.1f},"
                  f"compiles={row['compiles']}/{row['signature_bound']}")
    for row in results.get("sharded", []):
        sp = row.get("speedup_vs_1dev")
        sp_str = f"{sp:.2f}" if sp is not None else "nan"
        print(f"sharded_dev{row['devices']},{row['sharded_us']:.1f},"
              f"speedup={sp_str}")
    for row in results.get("serve", []):
        print(f"serve_c{row['concurrency']},"
              f"{1e6 / row['runs_per_s']:.1f},"
              f"runs_per_s={row['runs_per_s']:.1f}")
    for row in results.get("client", []):
        print(f"client_{row['mode']}_d{row['depth']},"
              f"{1e6 / row['runs_per_s']:.1f},"
              f"p99_ms={row['p99_ms']:.1f}")
    for row in results.get("cluster", []):
        sp = row.get("speedup_vs_single")
        sp_str = f"{sp:.2f}" if sp is not None else "nan"
        print(f"cluster_{row['mode']}_w{row['workers']},"
              f"{1e6 / row['runs_per_s']:.1f},"
              f"speedup={sp_str}")
    for row in results.get("qlearning", []):
        print(f"qlearning,{1e6 / row['episodes_per_s']:.1f},"
              f"tail_reward={row['tail_avg_reward']:+.4f}")
    for row in results.get("batched", []):
        print(f"batched_dense,{row['dense_batched_us']:.1f},"
              f"speedup_vs_dict={row['dense_speedup_vs_dict']:.2f}")
    for row in results.get("sweep", []):
        sp = row.get("stats_speedup")
        sp_str = f"{sp:.2f}" if sp is not None else "nan"
        print(f"sweep_k{row['n_runs']},{row['sweep_us']:.1f},"
              f"stats_speedup={sp_str}")


if __name__ == "__main__":
    main()
