"""Paper §4 end-to-end: Q-learning query expansion on a synthetic collection.

Pipeline (all in-process, the point of the paper):
  synthetic Tague-style collection → Dirichlet-QL ranking (the Pyndri role)
  → ΔNDCG reward from the device-resident evaluator (the pytrec_eval role)
  → tabular Q-learning agent (α=0.1, γ=0.95, ε=0.05).

    PYTHONPATH=src python examples/qlearning_query_expansion.py \
        [--episodes 600] [--paper-scale]
"""

import argparse

import numpy as np

from repro.data import synthetic_ir as sir
from repro.rl.environment import EnvConfig, QueryExpansionEnv
from repro.rl.qlearning import QLearningAgent, QLearningConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=600)
    ap.add_argument("--paper-scale", action="store_true",
                    help="|V|=10k, |D|=100, μ_d=200, 100k queries (slow)")
    args = ap.parse_args()

    if args.paper_scale:
        cfg = sir.CollectionConfig(vocab_size=10_000, n_docs=100,
                                   n_queries=100_000, avg_doc_len=200)
    else:
        cfg = sir.CollectionConfig(vocab_size=500, n_docs=60, n_queries=16,
                                   avg_doc_len=80)
    print(f"building collection |V|={cfg.vocab_size} |D|={cfg.n_docs} "
          f"|Q|={cfg.n_queries} ...")
    coll = sir.build_collection(cfg)

    env = QueryExpansionEnv(coll, EnvConfig(depth=10, max_actions=5,
                                            mu=2500.0))
    agent = QLearningAgent(env, QLearningConfig(
        alpha=0.1, gamma=0.95, epsilon=0.05,
        n_candidate_actions=min(128, cfg.vocab_size)))

    qids = list(coll.qrels)[:64]
    rewards = agent.train(qids, episodes=args.episodes,
                          log_every=max(args.episodes // 10, 1))

    w = max(args.episodes // 10, 1)
    smoothed = np.convolve(rewards, np.ones(w) / w, mode="valid")
    print("\naverage reward (ΔNDCG) over training — paper Fig. 3:")
    cols = 60
    lo, hi = float(smoothed.min()), float(smoothed.max())
    span = max(hi - lo, 1e-9)
    for i in range(0, len(smoothed), max(len(smoothed) // 20, 1)):
        bar = "#" * int((smoothed[i] - lo) / span * cols)
        print(f"  ep {i + w:5d} {smoothed[i]:+.4f} |{bar}")
    print(f"\nfirst-{w} avg: {np.mean(rewards[:w]):+.4f}   "
          f"last-{w} avg: {np.mean(rewards[-w:]):+.4f}")


if __name__ == "__main__":
    main()
