"""Quickstart: the paper's Code snippet 1, on this framework.

    PYTHONPATH=src python examples/quickstart.py
"""

import repro.core as core  # noqa: E402  (pytrec_eval-compatible surface)


def main() -> None:
    # --- the paper's minimal example (Code snippet 1) -----------------------
    qrel = {
        "q1": {"d1": 0, "d2": 1},
        "q2": {"d1": 1},
    }
    evaluator = core.RelevanceEvaluator(qrel, {"map", "ndcg"})
    run = {
        "q1": {"d1": 1.0, "d2": 0.0},
        "q2": {"d1": 1.5, "d2": 0.2},
    }
    results = evaluator.evaluate(run)
    print("per-query:", results)
    print("aggregate:", core.aggregate_results(results))

    # --- all trec_eval measures (the '-m all_trec' pattern) ----------------
    full = core.RelevanceEvaluator(qrel, core.supported_measures)
    print("\nsupported measure families:", sorted(core.supported_measures))
    q1 = full.evaluate(run)["q1"]
    print(f"q1 has {len(q1)} measure values, e.g. "
          f"ndcg_cut_10={q1['ndcg_cut_10']:.4f} P_5={q1['P_5']:.4f}")

    # --- device-resident batched evaluation (the TPU-native path) ----------
    import numpy as np
    import jax.numpy as jnp
    from repro.core import batch_from_dense, compute_measures, parse_measures

    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.standard_normal((128, 100)).astype(np.float32))
    rel = jnp.asarray((rng.random((128, 100)) < 0.1).astype(np.float32))
    batch = batch_from_dense(scores, rel)
    per_query = compute_measures(batch, parse_measures(("ndcg", "map")))
    print(f"\nbatched on-device: 128 queries evaluated in one compiled call; "
          f"mean ndcg={float(per_query['ndcg'].mean()):.4f}")


if __name__ == "__main__":
    main()
