"""Batched recsys serving with in-loop device-resident evaluation.

A SASRec ranker answers batched slate-ranking requests; NDCG@10 / MRR of
every response batch is computed inside the same jitted call (the
pytrec_eval pattern: evaluation lives with the scores).  A second phase runs
1M-candidate retrieval through the blocked top-K Pallas kernel.

    PYTHONPATH=src python examples/serve_recsys.py [--requests 20]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import smoke_shape
from repro.kernels import ops
from repro.launch.api import get_arch
from repro.models.recsys import SASRecConfig, sasrec_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--slate", type=int, default=128)
    ap.add_argument("--n-items", type=int, default=50_000)
    ap.add_argument("--n-candidates", type=int, default=200_000)
    args = ap.parse_args()

    cfg = SASRecConfig(name="serve", n_items=args.n_items, embed_dim=50,
                       n_blocks=2, n_heads=1, seq_len=50)
    params = sasrec_init(jax.random.PRNGKey(0), cfg)
    arch = get_arch("sasrec")
    shape = smoke_shape(arch.shapes["serve_p99"], batch=args.batch,
                        slate=args.slate)
    bundle = arch.make_step(cfg, shape, None)
    serve = jax.jit(bundle.step_fn)

    rng = np.random.default_rng(0)
    lat = []
    print(f"serving {args.requests} request batches "
          f"(batch={args.batch}, slate={args.slate})...")
    for i in range(args.requests):
        batch = {
            "items": jnp.asarray(rng.integers(
                0, cfg.n_items, (args.batch, cfg.seq_len)), jnp.int32),
            "pos": jnp.asarray(rng.integers(
                0, cfg.n_items, (args.batch, cfg.seq_len)), jnp.int32),
            "neg": jnp.asarray(rng.integers(
                0, cfg.n_items, (args.batch, cfg.seq_len)), jnp.int32),
            "mask": jnp.ones((args.batch, cfg.seq_len), bool),
        }
        cand = jnp.asarray(rng.integers(
            0, cfg.n_items, (args.batch, args.slate)), jnp.int32)
        rel = jnp.zeros((args.batch, args.slate), jnp.int32
                        ).at[:, rng.integers(0, args.slate)].set(1)
        t0 = time.perf_counter()
        scores, metrics = serve(params, batch, cand, rel)
        jax.block_until_ready(scores)
        lat.append(time.perf_counter() - t0)
        if i % 5 == 0:
            print(f"  req {i}: ndcg@10={float(metrics['ndcg_cut_10']):.4f} "
                  f"mrr={float(metrics['recip_rank']):.4f} "
                  f"({lat[-1]*1e3:.1f} ms)")
    lat_ms = np.array(lat[1:]) * 1e3  # drop compile
    print(f"latency p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms")

    # --- retrieval: top-1000 of n_candidates via the Pallas top-K kernel ---
    print(f"\nretrieval: top-1000 of {args.n_candidates} candidates "
          "(blocked bitonic top-K kernel, interpret mode)...")
    user = jnp.asarray(rng.standard_normal((1, 50)).astype(np.float32))
    cand_emb = jnp.asarray(rng.standard_normal(
        (args.n_candidates, 50)).astype(np.float32))
    scores = (user @ cand_emb.T)
    t0 = time.perf_counter()
    v, i = ops.topk(scores, 1000)
    jax.block_until_ready(v)
    print(f"  kernel top-1000 done in {time.perf_counter()-t0:.2f}s; "
          f"best score {float(v[0, 0]):.3f} @ item {int(i[0, 0])}")
    rv, ri = jax.lax.top_k(scores, 1000)
    assert bool((i == ri).all()), "kernel disagrees with lax.top_k"
    print("  verified against lax.top_k ✓")


if __name__ == "__main__":
    main()
