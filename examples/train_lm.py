"""End-to-end LM training with the device-resident evaluator fused into the
step: loss + gold-token MRR/NDCG computed on device, async checkpoints,
auto-resume, straggler monitor.

    PYTHONPATH=src python examples/train_lm.py --steps 200         # ~20M
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300

(--size 100m is the deliverable-scale run; on this 1-core CPU container it
is slow — the default is a faithful scaled-down configuration.)
"""

import argparse

import jax
import jax.numpy as jnp

from repro.data import lm_data
from repro.launch.api import get_arch
from repro.launch.steps import lm_step_bundle
from repro.models.transformer import TransformerConfig, init_transformer
from repro.train import optimizer as opt_lib
from repro.train.trainer import TrainConfig, Trainer
from repro.configs.common import smoke_shape


def make_cfg(size: str) -> TransformerConfig:
    if size == "100m":
        # ~100M params: 12L d=768 12H (GPT-2-small-ish, SwiGLU)
        return TransformerConfig(name="lm-100m", n_layers=12, d_model=768,
                                 n_heads=12, n_kv_heads=12, d_ff=2048,
                                 vocab_size=32_000, tie_embeddings=True)
    return TransformerConfig(name="lm-20m", n_layers=6, d_model=384,
                             n_heads=6, n_kv_heads=6, d_ff=1024,
                             vocab_size=8_000, tie_embeddings=True,
                             remat=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=("20m", "100m"), default="20m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = make_cfg(args.size)
    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    arch = get_arch("olmo-1b")  # reuse the LM step builder
    shape = smoke_shape(arch.shapes["train_4k"], seq_len=args.seq,
                        global_batch=args.batch)
    bundle = lm_step_bundle(cfg, shape, None)
    step_fn = jax.jit(bundle.step_fn, donate_argnums=(0, 1))

    params = init_transformer(jax.random.PRNGKey(0), cfg)
    init_opt, _ = opt_lib.adamw(opt_lib.OptimizerConfig(
        lr=3e-4, warmup_steps=200, decay_steps=20_000))
    opt_state = init_opt(params)

    gen = lm_data.MarkovLM(lm_data.LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    def wrapped(params, opt_state, batch):
        return step_fn(params, opt_state, jnp.asarray(batch["tokens"]),
                       jnp.asarray(batch["labels"]))

    trainer = Trainer(
        TrainConfig(total_steps=args.steps, log_every=10, ckpt_every=50,
                    ckpt_dir=args.ckpt_dir),
        wrapped, params, opt_state, gen.iterator())
    trainer.install_preemption_handler()
    if trainer.maybe_resume():
        print(f"auto-resumed from step {trainer.step}")
        trainer.data_iter = gen.iterator(start_step=trainer.step)
    trainer.run()
    print(f"done at step {trainer.step}; straggler flags: "
          f"{trainer.monitor.flags}")


if __name__ == "__main__":
    main()
