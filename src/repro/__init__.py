"""repro — a device-resident IR-evaluation training/serving framework in JAX.

Reproduction + TPU-scale extension of *Pytrec_eval: An Extremely Fast Python
Interface to trec_eval* (Van Gysel & de Rijke, SIGIR 2018).
"""

__version__ = "0.1.0"
