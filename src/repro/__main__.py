"""``python -m repro`` — the trec_eval-compatible CLI (see repro.cli)."""

import sys

from repro.cli import main

sys.exit(main())
