"""Post-compile analysis: HLO collective accounting + roofline terms."""
