"""Collective-byte accounting from optimized HLO text.

``compiled.cost_analysis()`` has no collective entry, so we parse the
post-SPMD HLO: every ``all-reduce`` / ``all-gather`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` op contributes its *result* bytes
(the standard per-device traffic proxy; reduce-scatter is scaled by its group
size since its result is the already-scattered shard).  ``-start`` variants
are counted once (their ``-done`` twins are skipped).

The compiled module is the per-device SPMD program, so totals here are
**bytes per device**; multiply by chip count for fabric-global traffic.
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[total]
    m = _GROUPS_BRACES_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic by op kind (bytes)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        rhs = rhs.strip()
        for kind in _COLLECTIVES:
            # match ` = <type> <kind>(` and `<kind>-start(`
            if re.search(rf"\)?\s{kind}(-start)?\(", " " + rhs):
                if f"{kind}-done" in rhs:
                    break
                # result type is between '=' and the op name
                type_str = rhs.split(kind)[0]
                nbytes = _shape_bytes(type_str)
                if kind == "reduce-scatter":
                    nbytes *= _group_size(rhs)
                out[kind] += nbytes
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def flop_summary(cost: Dict[str, float]) -> Dict[str, float]:
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }
