"""Three-term roofline analysis from the dry-run's compiled artifacts.

    PYTHONPATH=src python -m repro.analysis.roofline \
        [--dryrun experiments/dryrun] [--out experiments/roofline.md]

Per (arch × shape × mesh):
  compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS      (197 TF/s bf16, v5e)
  memory_s     = HLO_bytes_per_device / HBM_BW          (819 GB/s)
  collective_s = collective_bytes_per_device / ICI_BW   (~50 GB/s/link)

``cost_analysis()`` / the parsed HLO describe the per-device SPMD program, so
the spec's global formulation (global / (chips × bw)) reduces to the
per-device quantities used here.  MODEL_FLOPS is the analytic useful compute
(6·N_active·D for training, 2·N for single-token decode, family-specific
estimates elsewhere); MODEL/HLO exposes remat and dispatch overcompute.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
VMEM_BYTES = 16 * 2**20  # on-chip vector memory per core (Pallas tile budget)


def kernel_roofline(bytes_moved: float, seconds: float,
                    flops: float = 0.0) -> Dict:
    """Achieved-vs-peak terms for one measured kernel invocation.

    The kernel-benchmark counterpart of :func:`analyze`: instead of HLO
    cost estimates it takes *measured* wall time plus the analytic bytes
    the kernel must move (its HBM traffic floor) and reports achieved
    bandwidth against :data:`HBM_BW` — the axis the fused measure kernel
    lives on (it is memory-bound by construction: one [Q, D] read, a
    [Q, 64] write, O(D log D) VPU work in between).  Consumed by
    ``benchmarks.bench_kernels`` and the ``--only kernels`` segment, and
    by ``kernels.autotune`` for its VMEM occupancy model.
    """
    achieved_bw = bytes_moved / seconds if seconds > 0 else 0.0
    achieved_flops = flops / seconds if seconds > 0 else 0.0
    return {
        "bytes_moved": bytes_moved,
        "seconds": seconds,
        "achieved_bytes_per_s": achieved_bw,
        "peak_bytes_per_s": HBM_BW,
        "bw_fraction": achieved_bw / HBM_BW,
        "achieved_flops_per_s": achieved_flops,
        "flops_fraction": achieved_flops / PEAK_FLOPS,
    }


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS per family (global, whole step)
# ---------------------------------------------------------------------------


def _lm_model_flops(arch_name: str, shape: Dict) -> Optional[float]:
    from repro.launch.api import get_arch

    cfg = get_arch(arch_name).make_config(False)
    n_mm = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
    if cfg.tie_embeddings:
        n_mm += cfg.vocab_size * cfg.d_model  # head matmul still happens
    b = shape.get("global_batch")
    s = shape.get("seq_len")
    l, h, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
    kind = shape["kind"]
    if kind == "train":
        tokens = b * s
        return 6.0 * n_mm * tokens + 12.0 * l * b * s * s * h * hd
    if kind == "prefill":
        tokens = b * s
        return 2.0 * n_mm * tokens + 4.0 * l * b * s * s * h * hd
    if kind == "decode":
        return 2.0 * n_mm * b + 4.0 * l * b * s * h * hd
    return None


def _gnn_model_flops(shape: Dict) -> float:
    from repro.launch.api import get_arch

    cfg = get_arch("gatedgcn").make_config(False)
    d = cfg.d_hidden
    n, e = shape["n_nodes"], shape["n_edges"]
    d_in = shape.get("d_feat", cfg.d_in)
    nc = shape.get("n_classes", cfg.n_classes)
    per_layer = 4.0 * n * d * d + 6.0 * e * d * d
    fwd = cfg.n_layers * per_layer + 2.0 * n * d_in * d + 2.0 * n * d * nc
    return 3.0 * fwd  # train step ≈ fwd + 2×fwd backward


def _recsys_model_flops(arch_name: str, shape: Dict) -> Optional[float]:
    from repro.launch.api import get_arch

    cfg = get_arch(arch_name).make_config(False)
    kind = shape["kind"]
    b = shape.get("batch", 1)

    def fwd_per_example() -> float:
        if arch_name == "xdeepfm":
            dmodel, f = cfg.table.dim, cfg.table.n_fields
            flops, h_prev = 0.0, f
            for h in cfg.cin_layers:
                flops += 2.0 * dmodel * h_prev * f * h
                h_prev = h
            dims = (f * dmodel,) + tuple(cfg.mlp_dims) + (1,)
            flops += sum(2.0 * a * bb for a, bb in zip(dims[:-1], dims[1:]))
            return flops
        if arch_name == "autoint":
            f = cfg.table.n_fields
            da, nh = cfg.d_attn, cfg.n_attn_heads
            d_in = cfg.table.dim
            flops = 0.0
            for _ in range(cfg.n_attn_layers):
                flops += 4.0 * 2.0 * f * d_in * da * nh  # q,k,v,res proj
                flops += 2.0 * 2.0 * f * f * da * nh  # scores + weighted sum
                d_in = da * nh
            return flops + 2.0 * f * d_in
        if arch_name == "sasrec":
            d, s = cfg.embed_dim, cfg.seq_len
            per_block = 2.0 * s * d * 3 * d + 2.0 * s * d * d * 2 + \
                4.0 * s * s * d
            return cfg.n_blocks * per_block + 4.0 * s * d  # + BCE dots
        if arch_name == "mind":
            d, t, k = cfg.table.dim, cfg.hist_len, cfg.n_interests
            route = cfg.capsule_iters * (2.0 * k * t * d * 2)
            return 2.0 * t * d * d + route + 2.0 * d * 4 * d * 2
        return 0.0

    per_ex = fwd_per_example()
    if kind == "train":
        return 3.0 * b * per_ex
    if kind == "serve":
        slate = shape.get("slate", 0)
        if slate and arch_name in ("sasrec", "mind"):
            d = cfg.embed_dim if arch_name == "sasrec" else cfg.table.dim
            return b * (per_ex + 2.0 * slate * d)
        return b * per_ex
    if kind == "retrieval":
        nc = shape["n_candidates"]
        if arch_name in ("sasrec", "mind"):
            d = cfg.embed_dim if arch_name == "sasrec" else cfg.table.dim
            k = getattr(cfg, "n_interests", 1) or 1
            return per_ex + 2.0 * nc * d * k
        return nc * per_ex  # CTR: full forward per candidate
    return None


def _eval_model_flops(shape: Dict) -> float:
    # sort (~D log2 D compares) + ~8 cumulative passes over [Q, D]
    import math

    q, d = shape["n_queries"], shape["n_docs"]
    return q * d * (math.log2(max(d, 2)) + 8.0)


def model_flops(rec: Dict) -> Optional[float]:
    from repro.launch.api import get_arch

    arch = rec["arch"]
    fam = rec["family"]
    spec = get_arch(arch).shapes[rec["shape"]]
    shape = dict(spec.meta)
    shape["kind"] = spec.kind
    if fam == "lm":
        return _lm_model_flops(arch, shape)
    if fam == "gnn":
        return _gnn_model_flops(shape)
    if fam == "recsys":
        return _recsys_model_flops(arch, shape)
    if fam == "eval":
        return _eval_model_flops(shape)
    return None


# ---------------------------------------------------------------------------
# Per-record analysis
# ---------------------------------------------------------------------------


def analyze(rec: Dict, probe: Optional[Dict] = None) -> Optional[Dict]:
    if rec["status"] != "ok":
        return None
    chips = rec["n_chips"]
    flops_dev = rec["cost"].get("flops", 0.0)
    bytes_dev = rec["cost"].get("bytes accessed", 0.0)
    coll_dev = rec["collectives"]["total"]
    scan_corrected = False
    if probe and probe.get("status") == "ok":
        # XLA counts the scan body once; correct the full compile's totals
        # with (L−1) extra copies of the true per-layer cost measured by the
        # unrolled L=1/L=2 probe (see launch/dryrun.py::run_scan_probe).
        t = probe["trips"]
        body = probe["body"]
        flops_dev += (t - 1) * max(body["flops"], 0.0)
        bytes_dev += (t - 1) * max(body["bytes"], 0.0)
        coll_dev += (t - 1) * max(body["collective"], 0.0)
        scan_corrected = True
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(rec)
    hlo_global = flops_dev * chips
    ratio = (mf / hlo_global) if (mf and hlo_global > 0) else None
    # roofline fraction: useful model FLOP/s at the bound vs peak
    frac = None
    if mf is not None and step_s > 0:
        frac = (mf / chips / step_s) / PEAK_FLOPS
    suggestion = {
        "compute": "compute-bound: raise MXU utilization (bf16 everywhere, "
                   "fuse small ops, cut remat recompute)",
        "memory": "memory-bound: raise arithmetic intensity (fuse passes, "
                  "larger per-device tiles, avoid fp32 spills)",
        "collective": "collective-bound: reshard to cut cross-device bytes "
                      "(overlap with compute, compress, change TP split)",
    }[dominant]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips, "scan_corrected": scan_corrected,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "model_over_hlo": ratio, "roofline_fraction": frac,
        "peak_bytes_per_dev": rec["memory"].get("peak_bytes") or
        (rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]),
        "suggestion": suggestion,
    }


def fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "–"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--mesh", default="single",
                    help="mesh for the main table (spec: single-pod)")
    args = ap.parse_args(argv)

    probes = {}
    for path in glob.glob(os.path.join(args.dryrun, "*__probe.json")):
        p = json.load(open(path))
        probes[(p["arch"], p["shape"], p["mesh"])] = p

    rows, skips = [], []
    for path in sorted(glob.glob(os.path.join(args.dryrun, "*.json"))):
        if path.endswith("__probe.json"):
            continue
        rec = json.load(open(path))
        if rec["status"] == "skipped":
            skips.append(rec)
            continue
        a = analyze(rec, probes.get((rec["arch"], rec["shape"],
                                     rec["mesh"])))
        if a:
            rows.append(a)

    lines = [
        "| arch | shape | mesh | compute | memory | collective | dominant |"
        " MODEL/HLO | roofline frac | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != args.mesh:
            continue
        ratio = f"{r['model_over_hlo']:.2f}" if r["model_over_hlo"] else "–"
        frac = (f"{100*r['roofline_fraction']:.1f}%"
                if r["roofline_fraction"] is not None else "–")
        hbm = f"{r['peak_bytes_per_dev']/2**30:.2f}GiB"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} |"
            f" {fmt_s(r['collective_s'])} | **{r['dominant']}** |"
            f" {ratio} | {frac} | {hbm} |")
    lines.append("")
    lines.append("Skipped cells: " + "; ".join(
        sorted({f"{s['arch']}×{s['shape']} ({s['skip_reason'][:40]}…)"
                for s in skips})) if skips else "No skips.")
    out = "\n".join(lines)
    print(out)
    with open(args.out, "w") as fh:
        fh.write(out + "\n")
    with open(args.out.replace(".md", ".json"), "w") as fh:
        json.dump(rows, fh, indent=1)


if __name__ == "__main__":
    main()
