"""The paper's comparison baselines, implemented in this repo.

* ``pure_eval``      — pure-Python trec_eval measure engine (no numpy/jax).
                       Plays the role of trec_eval's C core in the
                       serialize-invoke-parse baseline, and is the independent
                       oracle for property tests.
* ``trec_eval_cli``  — file-based CLI around ``pure_eval`` (the subprocess
                       target of RQ1's serialize-invoke-parse workflow).
* ``native_ndcg``    — the fastest-native-Python NDCG of RQ2.
* ``workflow``       — serialize → invoke → parse driver (the thing the paper
                       shows is ≥17× slower).
"""
