"""The paper's RQ2 baseline: fastest native-Python NDCG, no numpy.

The paper adapted "the fastest open-source implementation" of NDCG in plain
Python; this is our equivalent — hand-tuned dict/sort code with local-variable
caching, computing a single measure for a single query, matching trec_eval
semantics (linear gain, score-desc/docno-desc ordering, qrel-side ideal).
"""

from __future__ import annotations

from math import log2
from typing import Mapping


def ndcg(doc_scores: Mapping[str, float], qrel: Mapping[str, int]) -> float:
    """NDCG over the full ranking (trec_eval 'ndcg' measure)."""
    get = qrel.get
    items = sorted(doc_scores.items(), key=_key)
    _log2 = log2
    dcg = 0.0
    rank = 1
    for doc, _score in items:
        rel = get(doc)
        if rel is not None and rel > 0:
            dcg += rel / _log2(rank + 1)
        rank += 1
    idcg = 0.0
    rank = 1
    for rel in sorted(qrel.values(), reverse=True):
        if rel <= 0:
            break
        idcg += rel / _log2(rank + 1)
        rank += 1
    return dcg / idcg if idcg > 0.0 else 0.0


def _key(item):
    doc, score = item
    return (-score, _RevStr(doc))


class _RevStr(str):
    __slots__ = ()

    def __lt__(self, other):  # descending docno on score ties
        return str.__gt__(self, other)
