"""Pure-Python trec_eval measure engine (no numpy, no jax).

Semantics identical to ``repro.core.measures`` (trec_eval reference):
score-descending ranking, ties broken by docno descending; unjudged docs are
non-relevant; map/recall/Rprec normalized by R from the qrels; linear-gain
NDCG with the ideal drawn from the qrels.

This module intentionally avoids every scientific library so that:
  (1) the RQ1 subprocess baseline has trec_eval-like startup cost (a C binary
      starts in milliseconds; importing numpy/jax would not be comparable);
  (2) it is an *independent* oracle for cross-validating the JAX core.
"""

from __future__ import annotations

from math import log2
from typing import Dict, Iterable, Mapping

DEFAULT_CUTOFFS = (5, 10, 15, 20, 30, 100, 200, 500, 1000)
SUCCESS_CUTOFFS = (1, 5, 10)


def rank_documents(doc_scores: Mapping[str, float]) -> list:
    """trec_eval ordering: score desc, docno desc."""
    return sorted(doc_scores, key=lambda doc: (-doc_scores[doc], _neg_str(doc)))


class _neg_str(str):
    """Sort helper: reverses lexicographic comparison (descending docno)."""

    __slots__ = ()

    def __lt__(self, other):  # type: ignore[override]
        return str.__gt__(self, other)


def evaluate_query(
    doc_scores: Mapping[str, float],
    qrel: Mapping[str, int],
    measures: Iterable[str] = ("map", "ndcg"),
    relevance_level: int = 1,
) -> Dict[str, float]:
    """All requested measures for one query.  One pass over the ranking."""
    ranking = rank_documents(doc_scores)
    rels = [qrel.get(doc) for doc in ranking]

    n_rel = sum(1 for r in qrel.values() if r >= relevance_level)
    n_judged_nonrel = sum(
        1 for r in qrel.values() if r < relevance_level
    )

    # --- single pass, trec_eval style -------------------------------------
    cum_rel = 0
    nonrel_above = 0
    ap_sum = 0.0
    bpref_sum = 0.0
    dcg_val = 0.0
    first_rel_rank = 0
    rprec_num = 0
    cut_hits = {}  # cutoff -> relevant count at cutoff
    dcg_cuts = {}
    map_cut_sums = {}
    cutoffs = sorted(set(DEFAULT_CUTOFFS) | set(SUCCESS_CUTOFFS))
    ci = 0
    bpref_bound = min(n_rel, n_judged_nonrel)
    for rank0, rel in enumerate(rels):
        rank = rank0 + 1
        judged_rel = rel is not None and rel >= relevance_level
        judged_nonrel = rel is not None and rel < relevance_level
        if judged_rel:
            cum_rel += 1
            ap_sum += cum_rel / rank
            if first_rel_rank == 0:
                first_rel_rank = rank
            if nonrel_above > 0:
                bpref_sum += 1.0 - min(nonrel_above, n_rel) / bpref_bound
            else:
                bpref_sum += 1.0
        if judged_nonrel:
            nonrel_above += 1
        if rel is not None and rel > 0:
            dcg_val += rel / log2(rank + 1)
        if rank == n_rel:
            rprec_num = cum_rel
        while ci < len(cutoffs) and rank == cutoffs[ci]:
            cut_hits[cutoffs[ci]] = cum_rel
            dcg_cuts[cutoffs[ci]] = dcg_val
            map_cut_sums[cutoffs[ci]] = ap_sum
            ci += 1
    n_ret = len(rels)
    if n_ret < n_rel:
        rprec_num = cum_rel
    for c in cutoffs[ci:]:
        cut_hits[c] = cum_rel
        dcg_cuts[c] = dcg_val
        map_cut_sums[c] = ap_sum

    ideal = sorted((r for r in qrel.values() if r > 0), reverse=True)
    idcg = 0.0
    idcg_cuts = {}
    ci = 0
    for rank0, rel in enumerate(ideal):
        rank = rank0 + 1
        idcg += rel / log2(rank + 1)
        while ci < len(cutoffs) and rank == cutoffs[ci]:
            idcg_cuts[cutoffs[ci]] = idcg
            ci += 1
    for c in cutoffs[ci:]:
        idcg_cuts[c] = idcg

    out: Dict[str, float] = {}
    for m in measures:
        if m == "map":
            out["map"] = ap_sum / n_rel if n_rel else 0.0
        elif m == "ndcg":
            out["ndcg"] = dcg_val / idcg if idcg > 0 else 0.0
        elif m == "recip_rank":
            out["recip_rank"] = 1.0 / first_rel_rank if first_rel_rank else 0.0
        elif m == "Rprec":
            out["Rprec"] = rprec_num / n_rel if n_rel else 0.0
        elif m == "bpref":
            out["bpref"] = bpref_sum / n_rel if n_rel else 0.0
        elif m == "num_ret":
            out["num_ret"] = float(n_ret)
        elif m == "num_rel":
            out["num_rel"] = float(n_rel)
        elif m == "num_rel_ret":
            out["num_rel_ret"] = float(cum_rel)
        elif m == "P":
            for k in DEFAULT_CUTOFFS:
                out[f"P_{k}"] = cut_hits[k] / k
        elif m == "recall":
            for k in DEFAULT_CUTOFFS:
                out[f"recall_{k}"] = cut_hits[k] / n_rel if n_rel else 0.0
        elif m == "success":
            for k in SUCCESS_CUTOFFS:
                out[f"success_{k}"] = 1.0 if cut_hits[k] > 0 else 0.0
        elif m == "ndcg_cut":
            for k in DEFAULT_CUTOFFS:
                ic = idcg_cuts[k]
                out[f"ndcg_cut_{k}"] = dcg_cuts[k] / ic if ic > 0 else 0.0
        elif m == "map_cut":
            for k in DEFAULT_CUTOFFS:
                out[f"map_cut_{k}"] = map_cut_sums[k] / n_rel if n_rel else 0.0
        else:
            raise ValueError(f"unsupported measure: {m}")
    return out


def evaluate(
    run: Mapping[str, Mapping[str, float]],
    qrel: Mapping[str, Mapping[str, int]],
    measures: Iterable[str] = ("map", "ndcg"),
    relevance_level: int = 1,
) -> Dict[str, Dict[str, float]]:
    measures = tuple(measures)
    return {
        qid: evaluate_query(docs, qrel[qid], measures, relevance_level)
        for qid, docs in run.items()
        if qid in qrel
    }
