"""File-based evaluation CLI — the subprocess target of RQ1.

Mimics ``trec_eval``'s interface and output format::

    python -m repro.baselines.trec_eval_cli [-q] [-m MEASURE]... qrel_file run_file

Output lines: ``measure \t qid \t value`` (with qid ``all`` for the mean),
exactly the stream a serialize-invoke-parse workflow has to parse.

Keep imports minimal: this process's startup cost is part of what RQ1
measures, and the reference trec_eval is a small C binary.
"""

from __future__ import annotations

import argparse
import sys

from repro.baselines import pure_eval
from repro.core import trec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="trec_eval_cli")
    ap.add_argument("-q", action="store_true", help="per-query output")
    ap.add_argument("-m", action="append", default=None, metavar="MEASURE")
    ap.add_argument("-l", type=int, default=1, metavar="REL_LEVEL")
    ap.add_argument("qrel_file")
    ap.add_argument("run_file")
    args = ap.parse_args(argv)

    measures = tuple(args.m) if args.m else ("map", "ndcg")
    if "all_trec" in measures:
        measures = ("map", "ndcg", "ndcg_cut", "P", "recall", "recip_rank",
                    "Rprec", "bpref", "success", "map_cut", "num_ret",
                    "num_rel", "num_rel_ret")

    qrel = trec.load_qrel(args.qrel_file)
    run = trec.load_run(args.run_file)
    results = pure_eval.evaluate(run, qrel, measures, args.l)

    out = sys.stdout
    if not results:
        return 0
    keys = list(next(iter(results.values())).keys())
    if args.q:
        for qid, vals in results.items():
            for k in keys:
                out.write(f"{k}\t{qid}\t{vals[k]:.4f}\n")
    nq = len(results)
    for k in keys:
        mean = sum(results[q][k] for q in results) / nq
        out.write(f"{k}\tall\t{mean:.4f}\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
