"""The serialize-invoke-parse workflow the paper benchmarks against (RQ1).

Steps, exactly as §1 of the paper describes:
  (1) serialize the in-memory run + qrels to disk files (TREC formats);
  (2) invoke the evaluator through the operating system (subprocess);
  (3) read the evaluation output back from the child's stdout.

Per the paper's experimental setup, the run is written *without sorting* (the
evaluator sorts internally) and the stdout is read into a Python string but
not parsed further (parsing strategies add variance).
"""

from __future__ import annotations

import os
import subprocess
import sys
from typing import Mapping, Sequence

from repro.core import trec


def serialize_invoke_parse(
    run: Mapping[str, Mapping[str, float]],
    qrel: Mapping[str, Mapping[str, int]],
    workdir: str,
    measures: Sequence[str] = ("map", "ndcg"),
    python: str | None = None,
) -> str:
    """Run the full workflow once; returns the child's stdout as a string."""
    qrel_path = os.path.join(workdir, "eval.qrel")
    run_path = os.path.join(workdir, "eval.run")
    # (1) serialize
    trec.save_qrel(qrel_path, qrel)
    trec.save_run(run_path, run)
    # (2) invoke through the OS
    cmd = [python or sys.executable, "-m", "repro.baselines.trec_eval_cli", "-q"]
    for m in measures:
        cmd += ["-m", m]
    cmd += [qrel_path, run_path]
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          check=True)
    # (3) parse: read stdout into a Python string (paper stops here too)
    return proc.stdout
