"""trec_eval-compatible command line: ``python -m repro <qrel> <run>``.

Drop-in replacement for the subprocess invocation the paper benchmarks
against::

    python -m repro [-q] [-c] [-l N] [-m MEASURE ...] [--sharded] qrel run

Flags mirror trec_eval:

* ``-q`` — print per-query results (query-major blocks, run-file order)
  before the ``all`` summary.
* ``-c`` — average over every query in the qrels; queries with no results
  contribute 0 to every measure (and their R to ``num_rel``).
* ``-l N`` — relevance level: judgments >= N count as relevant (default 1).
* ``-J`` — judged-docs-only: unjudged retrieved documents are removed from
  every ranking before scoring (trec_eval's ``-J``).
* ``-m MEASURE`` — repeatable measure selector in either dialect: a
  trec_eval family (``map``, ``ndcg_cut``), a parameterized family
  (``P.5,10``), an output-style key (``ndcg_cut_10``), an ir-measures
  spelling (``nDCG@10``, ``AP(rel=2)``, ``RBP(p=0.8)``), or ``all`` (every
  supported measure, the default).  Aggregate-only measures (``gm_map``,
  the geometric-mean MAP) print a summary line only — never per-query
  lines — exactly like trec_eval.
* ``--sharded`` — run the multi-device pipeline
  (``repro.distributed.sharded_evaluator``) instead of the single-device
  evaluator; results are bit-identical, so output does not change.

Output format is trec_eval's: ``measure<tab>qid<tab>value`` with the measure
name left-justified to 22 columns, floats printed with 4 decimals and the
count measures (``num_q``, ``num_ret``, ``num_rel``, ``num_rel_ret``) as
integers.  In the summary, count measures are sums over queries; everything
else is the arithmetic mean.  ``runid`` is the tag column of the run file.

Print order, the integer/sum/aggregate-only measure sets, and the ``-c``
missing-query contributions are all derived from
:mod:`repro.core.registry` — the CLI holds no measure tables of its own.
"""

from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import (RelevanceEvaluator, measures as M, registry,
                        supported_measures, trec)

#: summary/per-query print order == registry declaration order (trec_eval
#: prints its registry order; so do we, stable under any -m combination)
FAMILY_ORDER = registry.family_order()

#: measures printed as integers (trec_eval uses %ld for these)
INT_MEASURES = frozenset({"num_q"}) | registry.integer_keys()

#: measures summarized by summation rather than the mean over queries
SUM_MEASURES = registry.sum_families()

#: aggregate-only measures: suppressed from per-query (-q) blocks, and their
#: summary is exp(mean(log contributions)) — trec_eval's geometric mean
AGGREGATE_ONLY = M.AGGREGATE_ONLY_MEASURES


def ordered_keys(measures: Sequence[str]) -> List[str]:
    """Output keys for a measure set (either dialect), in print order."""
    # canonicalize merges repeated same-family selectors (-m P_5 -m P@10)
    # into one entry with the union of params; this only reorders families.
    # The rel= level (if any) is resolved again by the evaluator.
    parsed: Dict[str, tuple] = dict(registry.canonicalize(measures)[0])
    keys: List[str] = []
    for fam in FAMILY_ORDER:
        if fam in parsed:
            keys.extend(M.family_keys(fam, parsed[fam]))
    return keys


def format_line(measure: str, qid: str, value) -> str:
    """One trec_eval output line: %-22s\\t%s\\t%value."""
    if measure == "runid":
        val = str(value)
    elif measure in INT_MEASURES:
        val = str(int(round(float(value))))
    else:
        val = f"{float(value):.4f}"
    return f"{measure:<22}\t{qid}\t{val}"


def _summarize(results: Dict[str, Dict[str, float]], keys: Sequence[str],
               qrel: Dict[str, Dict[str, int]], complete: bool,
               relevance_level: int) -> Dict[str, float]:
    """The 'all' row: sums for count measures, means for the rest.

    With ``complete`` (-c), queries judged in the qrels but absent from the
    run divide every mean and contribute their R to ``num_rel``.
    """
    n_q = len(qrel) if complete else len(results)
    summary: Dict[str, float] = {"num_q": float(n_q)}
    denom = float(max(n_q, 1))
    n_missing = n_q - len(results)
    for k in keys:
        total = sum(res[k] for res in results.values())
        contrib = registry.missing_contribution(k)
        if contrib == "n_rel" and complete:
            # a missing query still contributes its R to num_rel
            total += sum(
                float(sum(r >= relevance_level for r in docs.values()))
                for qid, docs in qrel.items() if qid not in results)
        elif contrib == "log_gm_min":
            # missing queries under -c have AP 0, clipped to GM_MIN
            total += np.log(M.GM_MIN) * n_missing
        summary[k] = total if k in SUM_MEASURES else total / denom
    out = M.finalize_aggregates(summary)
    if n_q == 0:  # no queries: report 0, not exp(empty mean) = 1
        for k in AGGREGATE_ONLY & set(out):
            out[k] = 0.0
    return out


def add_measure_args(ap: argparse.ArgumentParser) -> None:
    """The measure-selection flags shared by ``repro`` and ``repro.serve``.

    ``-l`` (relevance level) and repeatable ``-m`` (measure selector) mean
    the same thing to the one-shot CLI and to the evaluation service's
    default-collection registration.
    """
    ap.add_argument("-l", dest="level", type=int, default=1, metavar="N",
                    help="relevance level: judgment >= N is relevant "
                         "(default 1)")
    ap.add_argument("-m", dest="measures", action="append", metavar="MEASURE",
                    help="measure family/key in either dialect — trec_eval "
                         "(map, P.5,10, ndcg_cut_10) or ir-measures "
                         "(AP, P@5, nDCG@10, RBP(p=0.8)) — repeatable; "
                         "default: all supported measures")
    ap.add_argument("-J", dest="judged_docs_only", action="store_true",
                    help="judged docs only: remove unjudged retrieved "
                         "documents from every ranking before scoring")


def resolve_measures(selected: Optional[Sequence[str]]) -> List[str]:
    """Expand the ``-m`` selections (``None``/``all`` → every family)."""
    selected = list(selected or ["all"])
    if "all" in selected:
        return sorted(supported_measures)
    return selected


def main(argv: Optional[Sequence[str]] = None, out=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="trec_eval-compatible evaluation of a TREC run file "
                    "against a qrel file (in-process, device-accelerated).")
    ap.add_argument("qrel_path", metavar="qrel", help="TREC qrel file")
    ap.add_argument("run_path", metavar="run", help="TREC run file")
    ap.add_argument("-q", dest="per_query", action="store_true",
                    help="print per-query results before the summary")
    ap.add_argument("-c", dest="complete", action="store_true",
                    help="average over all qrel queries (missing queries "
                         "count as 0)")
    add_measure_args(ap)
    ap.add_argument("--sharded", action="store_true",
                    help="evaluate with the multi-device sharded pipeline")
    args = ap.parse_args(argv)
    out = out or sys.stdout

    selected = resolve_measures(args.measures)
    try:
        keys = ordered_keys(selected)
    except ValueError as e:
        ap.error(str(e))

    qrel = trec.load_qrel(args.qrel_path)
    runid = trec.run_id(args.run_path)
    try:
        ev = RelevanceEvaluator(qrel, selected, relevance_level=args.level,
                                judged_docs_only=args.judged_docs_only)
    except ValueError as e:
        ap.error(str(e))
    # Tokenized ingest: run file → flat arrays → RunBuffer (no dict-of-dicts).
    qids_arr, docnos, scores = trec.load_run_arrays(args.run_path)
    # trec_eval rejects duplicate (qid, docno) rows; the array fast path does
    # not re-check, so the CLI must (silently-wrong measures otherwise).
    pairs = np.char.add(np.char.add(qids_arr.astype(str), "\x1f"),
                        docnos.astype(str))
    if np.unique(pairs).size != pairs.size:
        ap.error(f"duplicate (qid, docno) rows in run file {args.run_path}")
    buf = ev.buffer_from_arrays(qids_arr, docnos, scores)
    if args.sharded:
        from repro.distributed.sharded_evaluator import ShardedEvaluator

        results = ShardedEvaluator(ev).evaluate_buffer(buf).per_query
    else:
        results = ev.evaluate_buffer(buf)

    lines: List[str] = []
    if args.per_query:
        # Query-major blocks, queries in run-file first-appearance order.
        # Aggregate-only measures (gm_map) have no per-query line, like
        # trec_eval.
        pq_keys = [k for k in keys if k not in AGGREGATE_ONLY]
        for qid in dict.fromkeys(qids_arr.tolist()):
            if qid not in results:
                continue
            lines.extend(
                format_line(k, qid, results[qid][k]) for k in pq_keys)
    # the evaluator resolved rel= annotations against -l; use its level so
    # num_rel's missing-query R matches what was actually scored
    summary = _summarize(results, keys, qrel, args.complete,
                         ev.relevance_level)
    lines.append(format_line("runid", "all", runid))
    lines.append(format_line("num_q", "all", summary["num_q"]))
    lines.extend(format_line(k, "all", summary[k]) for k in keys)
    out.write("\n".join(lines) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
