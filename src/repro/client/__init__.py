"""Client library for the ``repro.serve`` evaluation service.

The serving thesis (docs/SERVING.md) only pays off if clients keep ONE
connection open and keep it full: the server coalesces whatever is in
flight *together*, so a connect-per-request client never batches.  This
package is the supported way to talk to the service:

* :class:`~repro.client.aio.AsyncEvalClient` — asyncio-native, pipelined,
  request-id correlated, with automatic reconnect-and-retry for idempotent
  operations;
* :class:`~repro.client.sync.EvalClient` — the blocking facade (private
  loop thread) with :meth:`~repro.client.sync.EvalClient.evaluate_many`
  and :meth:`~repro.client.sync.EvalClient.submit` for pipelining;
* the error taxonomy (:mod:`repro.client.errors`): ``ServerError`` /
  ``AuthError`` (the server said no), ``ConnectionLostError`` (the wire
  died, retries exhausted), ``ProtocolError`` (unintelligible peer).

Transports: TCP (``connect(host, port)``) and a private stdio subprocess
(``spawn_stdio()``), both speaking the same JSON-lines protocol with the
same frame limit (``repro.serve.wire.DEFAULT_FRAME_LIMIT``, 64 MiB — large
qrel/run payloads are first-class, not a crash).

>>> from repro.serve.testing import ServerThread
>>> from repro.client import EvalClient
>>> with ServerThread() as srv:
...     _ = srv.register_qrel('web', {'q1': {'d1': 1}}, ('recip_rank',))
...     with EvalClient(srv.host, srv.port) as client:
...         client.ping()
...         res = client.evaluate('web', run={'q1': {'d1': 1.0}})
'pong'
>>> res.per_query['q1']['recip_rank']
1.0
"""

from repro.client.aio import AsyncEvalClient, EvalResult, IDEMPOTENT_OPS
from repro.client.errors import (AuthError, ClientError,
                                 ConnectionLostError, DeadlineExceededError,
                                 ProtocolError, ServerError,
                                 WorkerUnavailableError)
from repro.client.sync import EvalClient

__all__ = [
    "AsyncEvalClient",
    "EvalClient",
    "EvalResult",
    "IDEMPOTENT_OPS",
    "ClientError",
    "ServerError",
    "AuthError",
    "ConnectionLostError",
    "DeadlineExceededError",
    "ProtocolError",
    "WorkerUnavailableError",
]
