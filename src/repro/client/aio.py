"""``AsyncEvalClient`` — the asyncio persistent-connection client.

One client = one JSON-lines connection to a ``repro.serve`` front-end (TCP
or a spawned stdio subprocess) with:

* **request-id correlation** — every request carries a fresh ``id``; a
  background reader task resolves responses to their waiters, so responses
  may arrive in ANY order;
* **pipelining** — any number of requests may be in flight on the one
  connection (just ``asyncio.gather`` the calls, or use
  :meth:`evaluate_many`); that is what lets the server's micro-batcher
  coalesce them into fewer backend calls;
* **reconnect-with-retry** — if the TCP connection drops before a response
  arrives, idempotent requests (everything except ``drop_qrel``) are
  re-sent on a fresh connection with exponential backoff, re-authenticating
  first when a token is configured;
* **session-API helpers** — :meth:`register_qrel`, :meth:`register_run`,
  :meth:`evaluate` (``run=`` | ``tokens=`` | ``run_ref=`` + ``scores=``)
  mirror :class:`repro.serve.service.EvaluationService` one to one.

>>> import asyncio
>>> from repro.serve import EvaluationService, serve_tcp
>>> from repro.client import AsyncEvalClient
>>> async def demo():
...     svc = EvaluationService(window=0.005)
...     svc.register_qrel('web', {'q1': {'d1': 1, 'd2': 0}}, ('map',))
...     server = await serve_tcp(svc, '127.0.0.1', 0)
...     port = server.sockets[0].getsockname()[1]
...     async with await AsyncEvalClient.connect('127.0.0.1', port) as c:
...         a, b = await c.evaluate_many('web', runs=[
...             {'q1': {'d1': 9.0, 'd2': 1.0}},
...             {'q1': {'d1': 0.0, 'd2': 1.0}}])  # pipelined → coalesced
...     server.close(); await server.wait_closed()
...     return a.per_query['q1']['map'], b.per_query['q1']['map']
>>> asyncio.run(demo())
(1.0, 0.5)
"""

from __future__ import annotations

import asyncio
import contextlib
import json
import re
import sys
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.client.errors import (ClientError, ConnectionLostError,
                                 DeadlineExceededError, ProtocolError,
                                 error_from_response)
from repro.serve.wire import DEFAULT_FRAME_LIMIT

#: ops safe to re-send after a connection loss: they either replace state
#: (register_*) or read it.  ``drop_qrel`` is excluded — its *result* is
#: not idempotent (a retry of a delivered drop reports ``dropped: false``).
IDEMPOTENT_OPS = frozenset({
    "register_qrel", "register_run", "evaluate", "compare", "stats", "ping",
    "health", "auth",
})

#: ``repro.serve`` front-ends build responses as ``{"id": rid, ...}`` and
#: ``json.dumps`` preserves dict insertion order, so every correlatable
#: response line starts with its id.  Matching it here lets the read loop
#: resolve :meth:`AsyncEvalClient.forward` waiters without parsing the
#: (possibly multi-megabyte) body — the cluster router's fan-out path.
_ID_PREFIX = re.compile(rb'^\{"id":\s*(-?\d+)\s*,')


class EvalResult(NamedTuple):
    """One evaluation: pytrec_eval-style per-query values + aggregates."""

    per_query: Dict[str, Dict[str, float]]
    aggregates: Dict[str, float]


def _jsonable(obj):
    """Recursively convert numpy arrays/scalars for JSON encoding."""
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    return obj


class AsyncEvalClient:
    """A persistent JSON-lines connection to an evaluation server.

    Construct via :meth:`connect` (TCP) or :meth:`spawn_stdio` (a private
    ``python -m repro.serve`` subprocess).  All request methods may be
    called concurrently — that is the point: in-flight requests pipeline on
    the one connection and coalesce server-side.

    ``retries`` bounds automatic re-sends of idempotent requests after a
    connection loss (TCP only; a dead stdio subprocess is not revivable).
    ``frame_limit`` must match the server's ``--max-frame-mb`` — requests
    larger than it raise locally instead of poisoning the stream.
    """

    def __init__(self, host: Optional[str] = None,
                 port: Optional[int] = None, *,
                 token: Optional[str] = None, retries: int = 2,
                 backoff: float = 0.05,
                 frame_limit: int = DEFAULT_FRAME_LIMIT):
        self._host = host
        self._port = port
        self._token = token
        self._retries = int(retries)
        self._backoff = float(backoff)
        self._frame_limit = int(frame_limit)
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None
        self._reader_task: Optional[asyncio.Task] = None
        self._proc = None  # stdio transport: the server subprocess
        self._conn_lock = asyncio.Lock()
        # rid -> (future, raw): `raw` waiters (forward()) get the response
        # line as bytes, everyone else the parsed object
        self._pending: Dict[int, Tuple[asyncio.Future, bool]] = {}
        self._next_id = 0
        self._closed = False
        #: client-side counters: requests sent, retries, reconnects
        self.transport_stats = {"requests": 0, "retries": 0, "reconnects": 0}

    # -- construction --------------------------------------------------------

    @classmethod
    async def connect(cls, host: str, port: int, **kw) -> "AsyncEvalClient":
        """Open a TCP connection (and authenticate, if ``token=`` given)."""
        client = cls(host, port, **kw)
        try:
            await client._ensure_connected()
        except BaseException:
            await client.aclose()  # don't leak the half-open connection
            raise
        return client

    @classmethod
    async def spawn_stdio(cls, argv: Optional[Sequence[str]] = None,
                          **kw) -> "AsyncEvalClient":
        """Spawn ``python -m repro.serve`` and speak over its pipes.

        ``argv`` is the full command line (defaults to
        ``[sys.executable, "-m", "repro.serve"]``); extra server flags
        (``--qrel``, ``-m``, ...) go there.  The subprocess is private to
        this client and exits when the client closes (stdin EOF → the
        server drains and stops).
        """
        client = cls(**kw)
        argv = list(argv) if argv else [sys.executable, "-m", "repro.serve"]
        proc = await asyncio.create_subprocess_exec(
            *argv, stdin=asyncio.subprocess.PIPE,
            stdout=asyncio.subprocess.PIPE, limit=client._frame_limit)
        client._proc = proc
        client._reader, client._writer = proc.stdout, proc.stdin
        client._start_reader()
        if client._token is not None:
            try:
                await client._auth()
            except BaseException:
                await client.aclose()
                raise
        return client

    # -- connection management -----------------------------------------------

    @property
    def _connected(self) -> bool:
        return self._writer is not None and not self._writer.is_closing()

    async def _ensure_connected(self) -> None:
        if self._closed:
            raise ClientError("client is closed")
        if self._connected:
            return
        async with self._conn_lock:
            if self._connected or self._closed:
                return
            if self._host is None or self._port is None:
                raise ConnectionLostError(
                    "stdio transport lost (subprocess exited); "
                    "spawn_stdio again")
            old_task = self._reader_task
            reader, writer = await asyncio.open_connection(
                self._host, self._port, limit=self._frame_limit)
            if old_task is not None:
                # retire the previous generation: its read loop fails its
                # own pending futures (their requests then retry here)
                old_task.cancel()
                self.transport_stats["reconnects"] += 1
            self._reader, self._writer = reader, writer
            self._pending = {}  # futures are per connection generation
            self._start_reader()
            if self._token is not None:
                await self._auth()

    def _start_reader(self) -> None:
        self._reader_task = asyncio.get_running_loop().create_task(
            self._read_loop(self._reader, self._writer, self._pending))

    async def _read_loop(self, reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter,
                         pending: Dict[int, Tuple[asyncio.Future, bool]],
                         ) -> None:
        """Resolve responses to their waiting futures by request id.

        ``pending`` is THIS connection generation's future map — a dying
        loop must never touch futures registered on a successor connection.
        Lines whose id prefix matches a ``raw`` waiter are handed over as
        bytes without JSON-parsing the body (:meth:`forward`).
        """
        exc: ClientError = ConnectionLostError("connection closed by server")
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                if not line.endswith(b"\n"):
                    # a line missing its terminator at EOF is a TORN tail
                    # (the peer died / the stream was cut mid-response) —
                    # never hand it to a waiter as if it were a response
                    exc = ConnectionLostError(
                        "connection cut mid-response (torn frame)")
                    break
                m = _ID_PREFIX.match(line)
                if m is not None:
                    ent = pending.pop(int(m.group(1)), None)
                    if ent is not None and ent[1]:  # raw waiter: no parse
                        if not ent[0].done():
                            ent[0].set_result(line.rstrip(b"\r\n"))
                        continue
                else:
                    ent = None
                try:
                    msg = json.loads(line)
                    if not isinstance(msg, dict):
                        raise ValueError("response must be a JSON object")
                except ValueError as e:
                    raise ProtocolError(
                        f"bad response line from server: {e}: "
                        f"{line[:120]!r}") from e
                if ent is not None:
                    if not ent[0].done():
                        ent[0].set_result(msg)
                else:
                    self._dispatch(msg, pending)
        except (ConnectionError, OSError, asyncio.IncompleteReadError) as e:
            exc = ConnectionLostError(f"connection lost: {e}")
        except ValueError as e:  # response line over the reader's limit
            exc = ProtocolError(f"response exceeds frame limit: {e}")
        except ProtocolError as e:
            exc = e
        except asyncio.CancelledError:
            exc = ConnectionLostError("connection closed")
            raise
        finally:
            if self._writer is writer:  # nobody reconnected us yet
                self._writer = None
            with contextlib.suppress(ConnectionError, OSError,
                                     RuntimeError):
                writer.close()
            for fut, _raw in pending.values():
                if not fut.done():
                    fut.set_exception(exc)
            pending.clear()

    @staticmethod
    def _dispatch(msg: dict,
                  pending: Dict[int, Tuple[asyncio.Future, bool]]) -> None:
        rid = msg.get("id")
        ent = pending.pop(rid, None) if rid is not None else None
        if ent is None and rid is None and len(pending) == 1:
            # the server could not read an id (e.g. frame_too_large); with
            # exactly one request outstanding the correlation is unambiguous
            _, ent = pending.popitem()
        if ent is not None and not ent[0].done():
            # a raw waiter resolved here (null-id error path) gets the
            # parsed object; forward() re-encodes that rare case
            ent[0].set_result(msg)
        # anything else: an unsolicited/late line — drop it

    async def _auth(self) -> None:
        resp = await self._send_and_wait("auth", {"token": self._token})
        self._check(resp)

    # -- the request engine --------------------------------------------------

    async def _send_and_wait(self, op: str, payload: dict) -> dict:
        """One raw send on the current connection; no retry, no checks."""
        rid = self._next_id
        self._next_id += 1
        data = json.dumps({"op": op, "id": rid, **payload}).encode() + b"\n"
        if len(data) > self._frame_limit:
            raise ClientError(
                f"request is {len(data)} bytes but the frame limit is "
                f"{self._frame_limit}; raise frame_limit= here and "
                f"--max-frame-mb on the server")
        fut = asyncio.get_running_loop().create_future()
        pending = self._pending  # this connection generation's map
        pending[rid] = (fut, False)
        self.transport_stats["requests"] += 1
        try:
            self._writer.write(data)
            await self._writer.drain()
            return await fut
        finally:
            pending.pop(rid, None)

    @staticmethod
    def _check(resp: dict):
        if resp.get("ok"):
            return resp.get("result")
        raise error_from_response(resp)

    async def _request(self, op: str, _timeout: Optional[float] = None,
                       **fields):
        """Send ``op``; retry idempotent ops across reconnects.

        ``_timeout`` (seconds) is the per-call deadline: it is sent to the
        server as ``deadline_ms`` (routers/workers enforce it and answer
        ``deadline_exceeded``, mapped to :class:`DeadlineExceededError`)
        AND enforced locally with a small grace period as a backstop for a
        server too hung to even say so.
        """
        if _timeout is not None:
            if not _timeout > 0:
                raise ValueError(f"timeout must be > 0 s, got {_timeout}")
            fields["deadline_ms"] = float(_timeout) * 1e3
        payload = _jsonable({k: v for k, v in fields.items()
                             if v is not None})
        retryable = op in IDEMPOTENT_OPS and self._host is not None
        # local backstop: give the server the full budget plus slack to
        # answer deadline_exceeded itself (its error names the culprit)
        backstop = None if _timeout is None else \
            asyncio.get_running_loop().time() + _timeout + 1.0
        attempt = 0
        while True:
            try:
                # reconnecting is part of the attempt: a refused/dropped
                # reconnect consumes a retry and backs off like any other
                # transport failure (AuthError et al. are not caught here)
                await self._ensure_connected()
                if backstop is None:
                    resp = await self._send_and_wait(op, payload)
                else:
                    remaining = backstop \
                        - asyncio.get_running_loop().time()
                    if remaining <= 0:
                        raise asyncio.TimeoutError()
                    resp = await asyncio.wait_for(
                        self._send_and_wait(op, payload), remaining)
            except asyncio.TimeoutError:
                raise DeadlineExceededError(
                    f"op {op!r} got no answer within its {_timeout}s "
                    "timeout (local backstop); the server may still be "
                    "working on it", code="deadline_exceeded") from None
            except (ConnectionError, OSError) as exc:
                # covers ConnectionLostError from the reader loop and
                # raw socket errors from connect/write/drain
                if not retryable or attempt >= self._retries:
                    if isinstance(exc, ClientError):
                        raise
                    raise ConnectionLostError(
                        f"connection lost: {exc}") from exc
                attempt += 1
                self.transport_stats["retries"] += 1
                await asyncio.sleep(self._backoff * (2 ** (attempt - 1)))
                continue
            return self._check(resp)

    async def forward(self, frame: bytes) -> bytes:
        """Relay a pre-encoded request frame; return the raw response frame.

        This is the cluster router's hot path: the router has already
        parsed the client's request line (it needed ``op`` and ``qrel_id``
        to route it), so re-encoding the — possibly multi-megabyte — run
        payload just to send it on would double the serialization bill.
        Instead the original frame is forwarded verbatim with a fresh
        connection-local id *appended* before the closing brace; JSON
        object keys are last-one-wins on decode, so the spliced id shadows
        the client's without rewriting the body.  The response comes back
        as bytes, still carrying the spliced id (callers rewrite it; see
        ``repro.serve.cluster.router``), and is matched to its waiter by
        the id *prefix* of the line — no JSON parse on either direction.

        One attempt, no retry: the router owns retry policy (it knows
        which ops are idempotent).  Raises :class:`ConnectionLostError`
        (a ``ConnectionError``) if the transport dies first.
        """
        frame = frame.strip()
        if not frame.endswith(b"}"):
            raise ClientError(
                f"forward() needs one JSON object frame, got {frame[:80]!r}")
        await self._ensure_connected()
        rid = self._next_id
        self._next_id += 1
        data = b'%s,"id":%d}\n' % (frame[:-1], rid)
        if len(data) > self._frame_limit:
            raise ClientError(
                f"request is {len(data)} bytes but the frame limit is "
                f"{self._frame_limit}; raise frame_limit= here and "
                f"--max-frame-mb on the server")
        fut = asyncio.get_running_loop().create_future()
        pending = self._pending
        pending[rid] = (fut, True)
        self.transport_stats["requests"] += 1
        try:
            self._writer.write(data)
            await self._writer.drain()
            resp = await fut
        finally:
            pending.pop(rid, None)
        if isinstance(resp, dict):  # null-id error line, resolved parsed
            return json.dumps(resp).encode()
        return resp

    # -- session-API mirror --------------------------------------------------

    async def ping(self) -> str:
        return await self._request("ping")

    async def health(self) -> dict:
        """The server's cheap liveness probe (``status``, ``in_flight``)."""
        return await self._request("health")

    async def stats(self) -> dict:
        """Server-side counters (coalescing, cache, backpressure)."""
        return await self._request("stats")

    async def register_qrel(self, qrel_id: str, qrel, measures=None,
                            relevance_level=None, backend=None,
                            judged_docs_only=None,
                            timeout: Optional[float] = None) -> dict:
        """Intern a qrel server-side; returns the collection info dict.

        ``measures`` accepts either dialect (``"map"`` / ``"nDCG@10"``);
        ``judged_docs_only`` mirrors trec_eval's ``-J``.  ``timeout``
        (seconds) becomes the request's ``deadline_ms``; past it the call
        raises :class:`DeadlineExceededError`.
        """
        return await self._request(
            "register_qrel", _timeout=timeout, qrel_id=qrel_id, qrel=qrel,
            measures=measures, relevance_level=relevance_level,
            backend=backend, judged_docs_only=judged_docs_only)

    async def register_run(self, qrel_id: str, run_id: str, run=None,
                           tokens=None,
                           timeout: Optional[float] = None) -> dict:
        """Pin a tokenized run server-side for ``run_ref`` rescoring."""
        return await self._request("register_run", _timeout=timeout,
                                   qrel_id=qrel_id, run_id=run_id, run=run,
                                   tokens=tokens)

    async def evaluate(self, qrel_id: str, run=None, tokens=None,
                       run_ref: Optional[str] = None, scores=None,
                       timeout: Optional[float] = None) -> EvalResult:
        """Evaluate one run (``run=`` | ``tokens=`` | ``run_ref=+scores=``).

        Concurrent calls pipeline on the connection and coalesce
        server-side into fewer backend calls.  ``timeout`` (seconds) maps
        to the wire's ``deadline_ms``: the server answers (or this client
        raises) :class:`DeadlineExceededError` once the budget is gone.
        """
        result = await self._request("evaluate", _timeout=timeout,
                                     qrel_id=qrel_id, run=run,
                                     tokens=tokens, run_ref=run_ref,
                                     scores=scores)
        return EvalResult(result["per_query"], result["aggregates"])

    async def evaluate_many(self, qrel_id: str, runs=None, *,
                            run_ref: Optional[str] = None,
                            scores_list=None) -> List[EvalResult]:
        """Pipeline a batch of evaluations (all in flight at once).

        Either ``runs`` (a sequence of dict runs) or ``run_ref`` +
        ``scores_list`` (one pinned run, many score sets).
        """
        if (runs is None) == (scores_list is None):
            raise ValueError("need exactly one of runs/scores_list")
        if runs is not None:
            coros = [self.evaluate(qrel_id, run=r) for r in runs]
        else:
            coros = [self.evaluate(qrel_id, run_ref=run_ref, scores=s)
                     for s in scores_list]
        return list(await asyncio.gather(*coros))

    async def compare(self, qrel_id: str, runs=None,
                      run_refs: Optional[Sequence[str]] = None,
                      measure: str = "map", *, tests=None,
                      n_permutations: Optional[int] = None,
                      seed: Optional[int] = None,
                      alpha: Optional[float] = None,
                      run_names: Optional[Sequence[str]] = None,
                      timeout: Optional[float] = None) -> dict:
        """Paired significance tests across K >= 2 runs on one measure.

        Exactly one of ``runs`` (``{name: run}`` mapping or sequence of dict
        runs) or ``run_refs`` (server-side ``register_run`` names) selects
        the systems.  Returns the server's bundle: ``run_names``, ``qids``,
        per-run ``means``, the ``t``/``p``/``p_holm``/``p_bonferroni``
        matrices (plus ``p_permutation*`` with ``tests=["t",
        "permutation"]``), and the Holm-corrected ``significant`` mask at
        ``alpha``.  Omitted keyword arguments use the server defaults.
        """
        return await self._request(
            "compare", _timeout=timeout, qrel_id=qrel_id, runs=runs,
            run_refs=run_refs,
            measure=measure, tests=list(tests) if tests is not None else None,
            n_permutations=n_permutations, seed=seed, alpha=alpha,
            run_names=run_names)

    async def drop_qrel(self, qrel_id: str,
                        timeout: Optional[float] = None) -> bool:
        """Release a collection; NOT retried on connection loss."""
        result = await self._request("drop_qrel", _timeout=timeout,
                                     qrel_id=qrel_id)
        return bool(result["dropped"])

    # -- lifecycle -----------------------------------------------------------

    async def aclose(self) -> None:
        """Close the connection (stdio: EOF → the server drains and exits)."""
        self._closed = True
        writer, task, proc = self._writer, self._reader_task, self._proc
        self._writer = None
        if writer is not None:
            with contextlib.suppress(ConnectionError, OSError,
                                     RuntimeError):
                writer.close()
                await writer.wait_closed()
        if proc is not None:
            try:
                await asyncio.wait_for(proc.wait(), timeout=30)
            except asyncio.TimeoutError:  # pragma: no cover - safety net
                proc.kill()
                await proc.wait()
        if task is not None:
            task.cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await task

    async def __aenter__(self) -> "AsyncEvalClient":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()
