"""Exception taxonomy for the evaluation client.

Three failure families, matching what a caller can actually do about them:

* :class:`ServerError` — the server answered ``ok: false``.  The request
  was *delivered and rejected*; retrying the same bytes will fail the same
  way.  Carries the machine-readable ``code``
  (:data:`repro.serve.wire.ERROR_CODES`) and the echoed request id.
  :class:`AuthError` is the ``auth_required`` / ``bad_auth`` subset;
  :class:`WorkerUnavailableError` is the cluster router's
  ``worker_unavailable`` (the owning worker is down and the router will
  not retry on the caller's behalf).
* :class:`ConnectionLostError` — the transport died before a response
  arrived.  Idempotent requests are retried automatically
  (:class:`~repro.client.aio.AsyncEvalClient`); this surfaces only once
  retries are exhausted.  Subclasses :class:`ConnectionError` so generic
  network handling catches it too.
* :class:`ProtocolError` — the server sent bytes that do not parse as a
  protocol response; a version mismatch or a corrupted stream, not
  something to retry.

All of them subclass :class:`ClientError`.
"""

from __future__ import annotations

from typing import Optional


class ClientError(Exception):
    """Base class for every error raised by ``repro.client``."""


class ServerError(ClientError):
    """The server answered ``ok: false`` for this request."""

    def __init__(self, message: str, code: Optional[str] = None,
                 request_id=None):
        super().__init__(message)
        self.code = code or "internal"
        self.request_id = request_id

    def __str__(self) -> str:
        return f"[{self.code}] {self.args[0]}"


class AuthError(ServerError):
    """Authentication failed (``auth_required`` or ``bad_auth``)."""


class WorkerUnavailableError(ServerError):
    """A cluster router could not reach the worker owning this request.

    Raised only for requests the router will NOT transparently retry
    (``drop_qrel``, or idempotent ops once the router's retry budget is
    exhausted).  The request may or may not have executed — the caller
    decides whether re-sending is safe, which is exactly why the code is
    machine-readable instead of being folded into ``internal``.
    """


class DeadlineExceededError(ServerError):
    """The request's ``deadline_ms`` budget ran out before it completed.

    Raised either because the server (router or worker) answered with the
    ``deadline_exceeded`` code, or locally when a per-call ``timeout=``
    elapsed with no answer at all (hung server).  Either way the work may
    still complete server-side — a deadline bounds the *wait*, not the
    execution — so only idempotent requests are safe to re-send.
    """


class ConnectionLostError(ClientError, ConnectionError):
    """The connection dropped before this request's response arrived."""


class ProtocolError(ClientError):
    """The server sent a line that is not a valid protocol response."""


#: response codes that map to :class:`AuthError`
AUTH_CODES = frozenset({"auth_required", "bad_auth"})


def error_from_response(resp: dict) -> ServerError:
    """Build the right exception for an ``ok: false`` response object."""
    code = resp.get("code") or "internal"
    message = str(resp.get("error", "unknown server error"))
    if code in AUTH_CODES:
        cls = AuthError
    elif code == "worker_unavailable":
        cls = WorkerUnavailableError
    elif code == "deadline_exceeded":
        cls = DeadlineExceededError
    else:
        cls = ServerError
    return cls(message, code=code, request_id=resp.get("id"))
