"""``EvalClient`` — the blocking facade over :class:`AsyncEvalClient`.

For scripts, notebooks, and training loops that are not asyncio-native.
The client owns a private event loop on a daemon thread; every method is
the corresponding :class:`~repro.client.aio.AsyncEvalClient` coroutine run
to completion on that loop.  Pipelining still works two ways:

* :meth:`evaluate_many` — submit a whole batch, block for all results
  (in flight together → coalesced server-side);
* :meth:`submit` — enqueue ONE evaluation and immediately get a
  ``concurrent.futures.Future``, for callers managing their own depth.

>>> from repro.serve.testing import ServerThread
>>> from repro.client import EvalClient
>>> with ServerThread() as srv:
...     _ = srv.register_qrel('web', {'q1': {'d1': 1, 'd2': 0}}, ('map',))
...     with EvalClient(srv.host, srv.port) as client:
...         res = client.evaluate('web', run={'q1': {'d1': 2.0, 'd2': 1.0}})
>>> res.per_query['q1']['map']
1.0
"""

from __future__ import annotations

import asyncio
import concurrent.futures
import threading
from typing import List, Optional, Sequence

from repro.client.aio import AsyncEvalClient, EvalResult
from repro.serve.wire import DEFAULT_FRAME_LIMIT


class EvalClient:
    """Synchronous persistent-connection client (thread-confined loop).

    ``EvalClient(host, port)`` connects over TCP;
    :meth:`EvalClient.spawn_stdio` runs a private ``python -m repro.serve``
    subprocess instead.  Constructor keywords (``token``, ``retries``,
    ``frame_limit``) are forwarded to :class:`AsyncEvalClient`; ``timeout``
    bounds every blocking call.
    """

    def __init__(self, host: Optional[str] = None,
                 port: Optional[int] = None, *, timeout: float = 120.0,
                 _defer: bool = False, **kw):
        if not _defer and (host is None or port is None):
            raise ValueError("EvalClient(host, port) both required "
                             "(or use EvalClient.spawn_stdio)")
        self._timeout = float(timeout)
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-client-loop")
        self._thread.start()
        self._async: Optional[AsyncEvalClient] = None
        if not _defer:
            try:
                self._async = self._call(AsyncEvalClient.connect(host, port,
                                                                 **kw))
            except BaseException:
                self.close()  # reap the loop thread; nothing connected
                raise

    @classmethod
    def spawn_stdio(cls, argv: Optional[Sequence[str]] = None, *,
                    timeout: float = 120.0, **kw) -> "EvalClient":
        """Spawn a stdio server subprocess and connect to its pipes."""
        client = cls(timeout=timeout, _defer=True)
        try:
            client._async = client._call(AsyncEvalClient.spawn_stdio(argv,
                                                                     **kw))
        except BaseException:
            client.close()
            raise
        return client

    def _run(self) -> None:
        asyncio.set_event_loop(self._loop)
        self._loop.run_forever()

    def _call(self, coro):
        return asyncio.run_coroutine_threadsafe(coro, self._loop).result(
            self._timeout)

    # -- session-API mirror (blocking) ----------------------------------------

    def ping(self) -> str:
        return self._call(self._async.ping())

    def health(self) -> dict:
        return self._call(self._async.health())

    def stats(self) -> dict:
        return self._call(self._async.stats())

    def register_qrel(self, qrel_id: str, qrel, measures=None,
                      relevance_level=None, backend=None,
                      judged_docs_only=None,
                      timeout: Optional[float] = None) -> dict:
        return self._call(self._async.register_qrel(
            qrel_id, qrel, measures=measures,
            relevance_level=relevance_level, backend=backend,
            judged_docs_only=judged_docs_only, timeout=timeout))

    def register_run(self, qrel_id: str, run_id: str, run=None,
                     tokens=None, timeout: Optional[float] = None) -> dict:
        return self._call(self._async.register_run(qrel_id, run_id, run=run,
                                                   tokens=tokens,
                                                   timeout=timeout))

    def evaluate(self, qrel_id: str, run=None, tokens=None,
                 run_ref: Optional[str] = None, scores=None,
                 timeout: Optional[float] = None) -> EvalResult:
        """Evaluate one run.  ``timeout`` (seconds) maps to the request's
        ``deadline_ms``; past it the call raises
        :class:`repro.client.DeadlineExceededError`."""
        return self._call(self._async.evaluate(
            qrel_id, run=run, tokens=tokens, run_ref=run_ref, scores=scores,
            timeout=timeout))

    def evaluate_many(self, qrel_id: str, runs=None, *,
                      run_ref: Optional[str] = None,
                      scores_list=None) -> List[EvalResult]:
        """Pipeline a batch on the one connection; block for all results."""
        return self._call(self._async.evaluate_many(
            qrel_id, runs, run_ref=run_ref, scores_list=scores_list))

    def submit(self, qrel_id: str, run=None, tokens=None,
               run_ref: Optional[str] = None,
               scores=None) -> "concurrent.futures.Future[EvalResult]":
        """Enqueue one evaluation without blocking (manual pipelining)."""
        return asyncio.run_coroutine_threadsafe(
            self._async.evaluate(qrel_id, run=run, tokens=tokens,
                                 run_ref=run_ref, scores=scores),
            self._loop)

    def compare(self, qrel_id: str, runs=None,
                run_refs: Optional[Sequence[str]] = None,
                measure: str = "map", *, tests=None,
                n_permutations: Optional[int] = None,
                seed: Optional[int] = None, alpha: Optional[float] = None,
                run_names: Optional[Sequence[str]] = None,
                timeout: Optional[float] = None) -> dict:
        """Paired significance tests across K runs (see the async client)."""
        return self._call(self._async.compare(
            qrel_id, runs=runs, run_refs=run_refs, measure=measure,
            tests=tests, n_permutations=n_permutations, seed=seed,
            alpha=alpha, run_names=run_names, timeout=timeout))

    def drop_qrel(self, qrel_id: str,
                  timeout: Optional[float] = None) -> bool:
        return self._call(self._async.drop_qrel(qrel_id, timeout=timeout))

    @property
    def transport_stats(self) -> dict:
        """Client-side counters: requests sent, retries, reconnects."""
        return dict(self._async.transport_stats)

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        if self._thread.is_alive():
            if self._async is not None:
                self._call(self._async.aclose())
                self._async = None
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(10)
        if not self._loop.is_running():
            self._loop.close()

    def __enter__(self) -> "EvalClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
