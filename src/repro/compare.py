"""Significance-table CLI: ``python -m repro.compare <qrel> <run> <run> ...``.

The command-line face of the sweep workload: K TREC run files are scored
against one qrel in a single batched sweep
(:func:`repro.core.sweep.evaluate_sweep`) and every system pair is tested
with the in-JAX paired statistics of :mod:`repro.stats`::

    python -m repro.compare tests/fixtures/conformance.qrel \\
        run_a.run run_b.run run_c.run -m map -m ndcg

Flags:

* ``-m MEASURE`` — repeatable, exactly like the main CLI (``repro.cli``),
  in either dialect (``map`` or ``AP``, ``ndcg_cut_10`` or ``nDCG@10``):
  one comparison block per resulting output key, default ``map``
  (``all`` expands to every supported measure).
* ``-l N`` — relevance level, as everywhere else; ``-J`` removes unjudged
  retrieved documents before scoring, as in the main CLI.
* ``--test {t,permutation,both}`` — which paired test(s) to run
  (default ``t``; the permutation test Monte-Carlo samples
  ``--permutations`` sign flips with ``--seed``).
* ``--alpha A`` — significance threshold for the trailing ``*`` marker,
  applied to the Holm-corrected t-test p-value (default 0.05).
* ``--sharded`` — evaluate the sweep on the multi-device backend.

Output is deterministic, tab-separated, and golden-byte-tested
(``tests/fixtures/compare.golden``)::

    runid   <run-name>      <tag from the run file>          (one per run)
    num_q   all     <number of common judged queries>
    measure all     <key>                                    (block start)
    mean    <run-name>      <summary value, 4 decimals>
    pair    <a>:<b> diff=+0.1234  t=+2.0000  p=0.2952  p_holm=0.2952  p_bonf=0.2952 [*]

Runs are named by file basename (minus a trailing ``.run``/``.txt``);
pairs are listed in run order, upper triangle only (the matrices are
symmetric).  Queries compared are the intersection of the runs' query sets
with the judged set — paired statistics need every system scored on every
query.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence

import numpy as np

from repro import cli
from repro.core import trec
from repro.core.sweep import evaluate_sweep


def _run_name(path: str, taken: List[str]) -> str:
    """File basename (extension-stripped), de-duplicated by suffixing."""
    base = os.path.basename(path)
    for ext in (".run", ".txt", ".gz"):
        if base.endswith(ext):
            base = base[: -len(ext)]
            break
    name = base or "run"
    i = 2
    while name in taken:
        name = f"{base}.{i}"
        i += 1
    return name


def _fmt(value: float, signed: bool = False) -> str:
    return f"{value:+.4f}" if signed else f"{value:.4f}"


def main(argv: Optional[Sequence[str]] = None, out=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.compare",
        description="Evaluate K TREC run files against one qrel in a single "
                    "batched sweep and print paired-significance tables "
                    "for every system pair.")
    ap.add_argument("qrel_path", metavar="qrel", help="TREC qrel file")
    ap.add_argument("run_paths", metavar="run", nargs="+",
                    help="two or more TREC run files to compare")
    cli.add_measure_args(ap)
    ap.add_argument("--test", choices=("t", "permutation", "both"),
                    default="t",
                    help="paired test(s) to report (default: t)")
    ap.add_argument("--permutations", type=int, default=2000, metavar="N",
                    help="Monte-Carlo sign flips for the permutation test "
                         "(default 2000)")
    ap.add_argument("--seed", type=int, default=0, metavar="S",
                    help="PRNG seed for the permutation test (default 0)")
    ap.add_argument("--alpha", type=float, default=0.05, metavar="A",
                    help="Holm-corrected significance threshold for the "
                         "'*' marker (default 0.05)")
    ap.add_argument("--sharded", action="store_true",
                    help="evaluate the sweep with the multi-device backend")
    args = ap.parse_args(argv)
    out = out or sys.stdout
    if len(args.run_paths) < 2:
        ap.error("compare needs at least two run files")

    selected = cli.resolve_measures(args.measures if args.measures
                                    else ["map"])
    try:
        keys = cli.ordered_keys(selected)
    except ValueError as e:
        ap.error(str(e))
    tests = {"t": ("t",), "permutation": ("t", "permutation"),
             "both": ("t", "permutation")}[args.test]
    show_perm = "permutation" in tests

    qrel = trec.load_qrel(args.qrel_path)
    names: List[str] = []
    tags: List[str] = []
    runs = []
    for path in args.run_paths:
        names.append(_run_name(path, names))
        tags.append(trec.run_id(path))
        runs.append(trec.load_run(path))

    try:
        result = evaluate_sweep(
            qrel, runs, measures=selected, relevance_level=args.level,
            backend="sharded" if args.sharded else "single",
            run_names=names, judged_docs_only=args.judged_docs_only)
    except ValueError as e:
        ap.error(str(e))

    lines: List[str] = []
    for name, tag in zip(names, tags):
        lines.append(f"runid\t{name}\t{tag}")
    lines.append(f"num_q\tall\t{len(result.qids)}")
    aggs = result.aggregates()
    k = len(names)
    for key in keys:
        report = result.compare(key, tests=tests,
                                n_permutations=args.permutations,
                                seed=args.seed)
        lines.append(f"measure\tall\t{key}")
        for name in names:
            lines.append(f"mean\t{name}\t{_fmt(aggs[name][key])}")
        diff = np.asarray(report["diff"])
        t = np.asarray(report["t"])
        p = np.asarray(report["p"])
        holm = np.asarray(report["p_holm"])
        bonf = np.asarray(report["p_bonferroni"])
        perm = (np.asarray(report["p_permutation"]) if show_perm else None)
        perm_holm = (np.asarray(report["p_permutation_holm"])
                     if show_perm else None)
        for i in range(k):
            for j in range(i + 1, k):
                cells = [
                    f"pair\t{names[i]}:{names[j]}",
                    f"diff={_fmt(float(diff[i, j]), signed=True)}",
                    f"t={_fmt(float(t[i, j]), signed=True)}",
                    f"p={_fmt(float(p[i, j]))}",
                    f"p_holm={_fmt(float(holm[i, j]))}",
                    f"p_bonf={_fmt(float(bonf[i, j]))}",
                ]
                if show_perm:
                    cells.append(f"p_perm={_fmt(float(perm[i, j]))}")
                    cells.append(
                        f"p_perm_holm={_fmt(float(perm_holm[i, j]))}")
                if float(holm[i, j]) < args.alpha:
                    cells.append("*")
                lines.append("\t".join(cells))
    out.write("\n".join(lines) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
