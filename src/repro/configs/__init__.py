"""One module per assigned architecture (+ the paper's own eval workload).

Each module registers an :class:`repro.launch.api.ArchDef`; use
``repro.launch.api.get_arch(name)`` / ``list_archs()``.
"""
