"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf]

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + parallel dense residual MLP.
"""

from __future__ import annotations

from repro.configs.common import lm_shapes
from repro.launch.api import ArchDef, register
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name="arctic-smoke", n_layers=2, d_model=64, n_heads=8,
            n_kv_heads=2, d_ff=96, vocab_size=512, ffn="swiglu",
            moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48,
                          dense_residual=True, capacity_factor=2.0),
            dtype="float32", remat=False)
    return TransformerConfig(
        name="arctic-480b", n_layers=35, d_model=7168, n_heads=56,
        n_kv_heads=8, d_ff=4864, vocab_size=32_000, ffn="swiglu",
        moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                      dense_residual=True, capacity_factor=1.25),
        dtype="bfloat16", remat=True)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import lm_step_bundle

    return lm_step_bundle(cfg, shape, mesh, fsdp=True,
                          opt_memory_efficient=True)


ARCH = register(ArchDef(
    name="arctic-480b",
    family="lm",
    shapes=lm_shapes(),
    make_config=make_config,
    make_step=_make_step,
    notes="Dense-residual MoE (arctic): MoE out + parallel dense MLP.",
))
