"""autoint [arXiv:1810.11921; paper]

39 sparse fields, embed_dim=16, 3 self-attention layers, 2 heads, d_attn=32.
"""

from __future__ import annotations

from repro.configs.common import recsys_shapes
from repro.launch.api import ArchDef, register
from repro.models.embedding import TableConfig
from repro.models.recsys import CTRConfig


def make_config(smoke: bool = False) -> CTRConfig:
    if smoke:
        return CTRConfig(
            name="autoint-smoke",
            table=TableConfig(n_fields=8, vocab_per_field=500, dim=8),
            n_attn_layers=2, n_attn_heads=2, d_attn=4)
    return CTRConfig(
        name="autoint",
        table=TableConfig(n_fields=39, vocab_per_field=1_000_000, dim=16),
        n_attn_layers=3, n_attn_heads=2, d_attn=32)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import recsys_step_bundle

    return recsys_step_bundle("autoint", cfg, shape, mesh)


ARCH = register(ArchDef(
    name="autoint",
    family="recsys",
    shapes=recsys_shapes(),
    make_config=make_config,
    make_step=_make_step,
    notes="Multi-head self-attention over field embeddings.",
))
