"""Shared shape tables and helpers for the config modules."""

from __future__ import annotations

from repro.launch.api import ShapeSpec

FULL_ATTN_SKIP = ("sub-quadratic attention required; this arch is pure "
                  "full attention (see DESIGN.md §Arch-applicability)")


def lm_shapes(decode_ok: bool = True):
    return {
        "train_4k": ShapeSpec("train_4k", "train",
                              (("seq_len", 4096), ("global_batch", 256))),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                 (("seq_len", 32768), ("global_batch", 32))),
        "decode_32k": ShapeSpec("decode_32k", "decode",
                                (("seq_len", 32768), ("global_batch", 128))),
        "long_500k": ShapeSpec("long_500k", "decode",
                               (("seq_len", 524288), ("global_batch", 1)),
                               skip_reason=FULL_ATTN_SKIP),
    }


def recsys_shapes(slate: int = 1024):
    return {
        "train_batch": ShapeSpec("train_batch", "train",
                                 (("batch", 65_536),)),
        "serve_p99": ShapeSpec("serve_p99", "serve",
                               (("batch", 512), ("slate", slate))),
        "serve_bulk": ShapeSpec("serve_bulk", "serve",
                                (("batch", 262_144),)),
        "retrieval_cand": ShapeSpec(
            "retrieval_cand", "retrieval",
            (("batch", 1), ("n_candidates", 1_000_000), ("topk", 1000))),
    }


def smoke_shape(spec: ShapeSpec, **overrides) -> ShapeSpec:
    meta = dict(spec.meta)
    meta.update(overrides)
    return ShapeSpec(spec.name, spec.kind, tuple(meta.items()))
