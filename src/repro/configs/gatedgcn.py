"""gatedgcn [arXiv:2003.00982 benchmark config; paper]

16 layers, d_hidden=70, gated aggregator.  Input feature width varies per
shape (cora 1433, reddit 602, ogbn-products 100, molecules 16), so the model
config is specialized per shape inside make_step.
"""

from __future__ import annotations

import dataclasses

from repro.launch.api import ArchDef, ShapeSpec, register
from repro.models.gnn import GatedGCNConfig

SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        (("n_nodes", 2708), ("n_edges", 10_556), ("d_feat", 1433),
         ("n_classes", 7))),
    "minibatch_lg": ShapeSpec(
        # reddit-scale source graph (232,965 nodes / 114.6M edges); the step
        # consumes the padded fanout-(15,10) subgraph of 1024 seed nodes.
        "minibatch_lg", "train",
        (("n_nodes", 169_984), ("n_edges", 168_960), ("d_feat", 602),
         ("n_classes", 41), ("batch_nodes", 1024), ("fanout", (15, 10)),
         ("src_nodes", 232_965), ("src_edges", 114_615_892))),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        (("n_nodes", 2_449_029), ("n_edges", 61_859_140), ("d_feat", 100),
         ("n_classes", 47))),
    "molecule": ShapeSpec(
        "molecule", "train",
        (("n_nodes", 3840), ("n_edges", 8192), ("d_feat", 16),
         ("n_classes", 2), ("graph_task", True), ("n_graphs", 128),
         ("nodes_per_graph", 30), ("edges_per_graph", 64))),
}


def make_config(smoke: bool = False) -> GatedGCNConfig:
    if smoke:
        return GatedGCNConfig(name="gatedgcn-smoke", n_layers=3, d_hidden=16,
                              d_in=8, d_edge_in=4, n_classes=5)
    return GatedGCNConfig(name="gatedgcn", n_layers=16, d_hidden=70,
                          d_in=100, d_edge_in=8, n_classes=47)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import gnn_step_bundle

    cfg = dataclasses.replace(
        cfg, d_in=shape.get("d_feat", cfg.d_in),
        n_classes=shape.get("n_classes", cfg.n_classes))
    return gnn_step_bundle(cfg, shape, mesh)


ARCH = register(ArchDef(
    name="gatedgcn",
    family="gnn",
    shapes=SHAPES,
    make_config=make_config,
    make_step=_make_step,
    notes="Message passing via segment_sum over edge lists (no sparse lib); "
          "minibatch_lg uses the real fanout NeighborSampler.",
))
