"""mind [arXiv:1904.08030; unverified]

embed_dim=64, 4 interest capsules, 3 routing iterations, multi-interest
retrieval.  Item vocabulary 1M; behavior history length 50.
"""

from __future__ import annotations

from repro.configs.common import recsys_shapes
from repro.launch.api import ArchDef, register
from repro.models.embedding import TableConfig
from repro.models.recsys import CTRConfig


def make_config(smoke: bool = False) -> CTRConfig:
    if smoke:
        return CTRConfig(
            name="mind-smoke",
            table=TableConfig(n_fields=1, vocab_per_field=1000, dim=16),
            n_interests=4, capsule_iters=3, hist_len=12)
    return CTRConfig(
        name="mind",
        table=TableConfig(n_fields=1, vocab_per_field=1_000_000, dim=64),
        n_interests=4, capsule_iters=3, hist_len=50)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import recsys_step_bundle

    return recsys_step_bundle("mind", cfg, shape, mesh)


ARCH = register(ArchDef(
    name="mind",
    family="recsys",
    shapes=recsys_shapes(slate=1024),
    make_config=make_config,
    make_step=_make_step,
    notes="B2I dynamic routing (squash + logit updates, 3 iterations); "
          "retrieval scores = max over interests.",
))
