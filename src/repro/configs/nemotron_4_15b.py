"""nemotron-4-15b [arXiv:2402.16819; unverified]

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000, squared-ReLU FFN.
"""

from __future__ import annotations

from repro.configs.common import lm_shapes
from repro.launch.api import ArchDef, register
from repro.models.transformer import TransformerConfig


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name="nemotron-smoke", n_layers=2, d_model=64, n_heads=8,
            n_kv_heads=2, d_ff=256, vocab_size=512, ffn="sq_relu",
            dtype="float32", remat=False)
    return TransformerConfig(
        name="nemotron-4-15b", n_layers=32, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=24_576, vocab_size=256_000, ffn="sq_relu",
        dtype="bfloat16", remat=True)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import lm_step_bundle

    return lm_step_bundle(cfg, shape, mesh, fsdp=False)


ARCH = register(ArchDef(
    name="nemotron-4-15b",
    family="lm",
    shapes=lm_shapes(),
    make_config=make_config,
    make_step=_make_step,
    notes="Squared-ReLU FFN; 256k vocab stresses the vocab-parallel head.",
))
