"""olmo-1b [arXiv:2402.00838; hf]

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304, non-parametric LN.
"""

from __future__ import annotations

from repro.configs.common import lm_shapes
from repro.launch.api import ArchDef, register
from repro.models.transformer import TransformerConfig


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name="olmo-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=4, d_ff=128, vocab_size=512, ffn="swiglu",
            norm="nonparam", tie_embeddings=True, dtype="float32",
            remat=False)
    return TransformerConfig(
        name="olmo-1b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=8192, vocab_size=50_304, ffn="swiglu",
        norm="nonparam", tie_embeddings=True, dtype="bfloat16", remat=True)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import lm_step_bundle

    return lm_step_bundle(cfg, shape, mesh, fsdp=False)


ARCH = register(ArchDef(
    name="olmo-1b",
    family="lm",
    shapes=lm_shapes(),
    make_config=make_config,
    make_step=_make_step,
    notes="Non-parametric LayerNorm; tied embeddings.",
))
