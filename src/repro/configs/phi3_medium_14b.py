"""phi3-medium-14b [arXiv:2404.14219; unverified]

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352, RoPE SwiGLU GQA.
"""

from __future__ import annotations

from repro.configs.common import lm_shapes
from repro.launch.api import ArchDef, register
from repro.models.transformer import TransformerConfig


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name="phi3-smoke", n_layers=2, d_model=64, n_heads=8,
            n_kv_heads=2, d_ff=192, vocab_size=512, ffn="swiglu",
            dtype="float32", remat=False)
    return TransformerConfig(
        name="phi3-medium-14b", n_layers=40, d_model=5120, n_heads=40,
        n_kv_heads=10, d_ff=17_920, vocab_size=100_352, ffn="swiglu",
        dtype="bfloat16", remat=True)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import lm_step_bundle

    return lm_step_bundle(cfg, shape, mesh, fsdp=False)


ARCH = register(ArchDef(
    name="phi3-medium-14b",
    family="lm",
    shapes=lm_shapes(),
    make_config=make_config,
    make_step=_make_step,
    notes="kv=10 does not divide model=16: KV heads replicated within TP "
          "groups (GSPMD handles the uneven head sharding).",
))
