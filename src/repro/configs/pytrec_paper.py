"""The paper's own workload as a first-class arch: the batched evaluator.

Shapes mirror the paper's benchmark grid corners (Fig. 1): the largest
configuration (10,000 queries × 1,000 docs) plus a deep-ranking cell
(1,024 queries × 65,536 candidate docs).  The "model" is the measure core
itself: queries shard over every mesh axis (they are independent), docs stay
local, and a single psum of sufficient statistics produces corpus means —
pytrec_eval's in-process evaluation at pod scale.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import measures as M
from repro.launch.api import ArchDef, ShapeSpec, StepBundle, register

MEASURES = ("map", "ndcg", "ndcg_cut", "P", "recall", "recip_rank",
            "Rprec", "bpref", "success", "map_cut")
_PARSED = M.parse_measures(MEASURES)


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    name: str
    relevance_level: float = 1.0
    # "sorted": batched sort engine (packed payload — §Perf iteration C2)
    # "ranked": rank-reduction engine (core/ranked.py) — exact, collective-
    #   minimal, but XLA:CPU materializes its compare-reduce; it is the
    #   natural Pallas-kernel formulation (§Perf iteration C1 discussion)
    engine: str = "sorted"


SHAPES = {
    "eval_10k_1k": ShapeSpec("eval_10k_1k", "serve",
                             (("n_queries", 10_000), ("n_docs", 1000),
                              ("n_judged", 128))),
    "eval_1k_64k": ShapeSpec("eval_1k_64k", "serve",
                             (("n_queries", 1024), ("n_docs", 65_536),
                              ("n_judged", 128))),
}


def make_config(smoke: bool = False) -> EvalConfig:
    return EvalConfig(name="pytrec-eval-smoke" if smoke else "pytrec-eval")


def _make_step(cfg: EvalConfig, shape: ShapeSpec, mesh) -> StepBundle:
    from repro.core import ranked as RK

    q = shape.get("n_queries")
    d = shape.get("n_docs")
    j = shape.get("n_judged")
    if mesh is not None:
        # pad the query axis to a mesh multiple (query_mask covers the rest)
        m = int(mesh.devices.size)
        q = ((q + m - 1) // m) * m

    f32, i32, b_ = jnp.float32, jnp.int32, jnp.bool_
    sds = jax.ShapeDtypeStruct

    if cfg.engine == "ranked":
        def eval_step(batch: RK.RankedBatch):
            per_q = RK.compute_measures_ranked(batch, _PARSED,
                                               cfg.relevance_level)
            return M.aggregate(per_q, batch.query_mask)

        batch_abs = RK.RankedBatch(
            scores=sds((q, d), f32), tiebreak=sds((q, d), i32),
            mask=sds((q, d), b_),
            judged_scores=sds((q, j), f32), judged_tiebreak=sds((q, j), i32),
            judged_rel=sds((q, j), f32), judged_retrieved=sds((q, j), b_),
            judged_mask=sds((q, j), b_), ideal_rel=sds((q, j), f32),
            n_rel=sds((q,), f32), n_judged_nonrel=sds((q,), f32),
            query_mask=sds((q,), b_))
    else:
        def eval_step(batch: M.EvalBatch):
            per_q = M.compute_measures(batch, _PARSED, cfg.relevance_level)
            return M.aggregate(per_q, batch.query_mask)

        batch_abs = M.EvalBatch(
            scores=sds((q, d), f32), tiebreak=sds((q, d), i32),
            rel=sds((q, d), f32), judged=sds((q, d), b_),
            mask=sds((q, d), b_),
            ideal_rel=sds((q, j), f32), n_rel=sds((q,), f32),
            n_judged_nonrel=sds((q,), f32), query_mask=sds((q,), b_))
    if mesh is not None:
        qaxes = tuple(mesh.axis_names)  # queries shard over EVERY axis
        in_specs = jax.tree.map(
            lambda s: P(qaxes, *([None] * (len(s.shape) - 1))), batch_abs)
        out_abs = jax.eval_shape(eval_step, batch_abs)
        in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), in_specs,
                             is_leaf=lambda x: isinstance(x, P))
        out_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), out_abs)
    else:
        in_sh = out_sh = None
    return StepBundle(eval_step, (batch_abs,), (in_sh,), out_sh)


ARCH = register(ArchDef(
    name="pytrec-eval",
    family="eval",
    shapes=SHAPES,
    make_config=make_config,
    make_step=_make_step,
    notes="The paper's contribution itself as a dry-run workload.",
))
