"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family; hf]

94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128 experts top-8.
"""

from __future__ import annotations

import functools

from repro.configs.common import lm_shapes
from repro.launch.api import ArchDef, register
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def make_config(smoke: bool = False) -> TransformerConfig:
    if smoke:
        return TransformerConfig(
            name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=8,
            n_kv_heads=2, d_ff=96, vocab_size=512, ffn="swiglu",
            moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48,
                          capacity_factor=2.0),
            dtype="float32", remat=False)
    return TransformerConfig(
        name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
        n_kv_heads=4, d_ff=1536, vocab_size=151_936, ffn="swiglu",
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536,
                      capacity_factor=1.25),
        dtype="bfloat16", remat=True)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import lm_step_bundle

    return lm_step_bundle(cfg, shape, mesh, fsdp=True,
                          opt_memory_efficient=True)


ARCH = register(ArchDef(
    name="qwen3-moe-235b-a22b",
    family="lm",
    shapes=lm_shapes(),
    make_config=make_config,
    make_step=_make_step,
    notes="MoE: EP over `model` + expert FSDP over `data` (ZeRO-3 gather).",
))
