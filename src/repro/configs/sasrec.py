"""sasrec [arXiv:1808.09781; paper]

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50, self-attentive sequential rec.
Item vocabulary 1M (spec: tables 10^6–10^9 rows).
"""

from __future__ import annotations

from repro.configs.common import recsys_shapes
from repro.launch.api import ArchDef, register
from repro.models.recsys import SASRecConfig


def make_config(smoke: bool = False) -> SASRecConfig:
    if smoke:
        return SASRecConfig(name="sasrec-smoke", n_items=1000, embed_dim=16,
                            n_blocks=2, n_heads=1, seq_len=10)
    return SASRecConfig(name="sasrec", n_items=1_000_000, embed_dim=50,
                        n_blocks=2, n_heads=1, seq_len=50)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import recsys_step_bundle

    return recsys_step_bundle("sasrec", cfg, shape, mesh)


ARCH = register(ArchDef(
    name="sasrec",
    family="recsys",
    shapes=recsys_shapes(slate=1024),
    make_config=make_config,
    make_step=_make_step,
    notes="retrieval_cand scores the user state against 1M item embeddings "
          "(batched dot + top-K); in-loop NDCG via the measure core.",
))
