"""xdeepfm [arXiv:1803.05170; paper]

39 sparse fields, embed_dim=10, CIN layers 200-200-200, MLP 400-400.
1M rows per field → 39M-row concatenated table, row-sharded over `model`.
First 3 fields carry multi-hot bags (EmbeddingBag path).
"""

from __future__ import annotations

from repro.configs.common import recsys_shapes
from repro.launch.api import ArchDef, register
from repro.models.embedding import TableConfig
from repro.models.recsys import CTRConfig


def make_config(smoke: bool = False) -> CTRConfig:
    if smoke:
        return CTRConfig(
            name="xdeepfm-smoke",
            table=TableConfig(n_fields=8, vocab_per_field=500, dim=8),
            cin_layers=(16, 16), mlp_dims=(32, 32), n_multi_hot=2,
            multi_hot_len=4)
    return CTRConfig(
        name="xdeepfm",
        table=TableConfig(n_fields=39, vocab_per_field=1_000_000, dim=10),
        cin_layers=(200, 200, 200), mlp_dims=(400, 400), n_multi_hot=3,
        multi_hot_len=8)


def _make_step(cfg, shape, mesh):
    from repro.launch.steps import recsys_step_bundle

    return recsys_step_bundle("xdeepfm", cfg, shape, mesh)


ARCH = register(ArchDef(
    name="xdeepfm",
    family="recsys",
    shapes=recsys_shapes(),
    make_config=make_config,
    make_step=_make_step,
    notes="CIN = outer-product + tensordot compression; EmbeddingBag via "
          "take+segment_sum (Pallas kernel path available).",
))
