"""Device-resident IR evaluation (the paper's contribution, on TPU).

Public API mirrors pytrec_eval:

* :class:`RelevanceEvaluator` — dict-in / dict-out evaluation.
* :data:`supported_measures` — measure families available.
* ``measures`` / ``streaming`` — batched + in-loop device entry points.
"""

from repro.core.evaluator import RelevanceEvaluator, RunBuffer, aggregate_results
from repro.core.measures import (
    DEFAULT_CUTOFFS,
    SUPPORTED_MEASURES as supported_measures,
    EvalBatch,
    batch_from_dense,
    batch_from_flat,
    compute_measures,
    compute_measures_jit,
    measure_keys,
    parse_measures,
)
from repro.core import streaming, trec, sorting

__all__ = [
    "RelevanceEvaluator",
    "RunBuffer",
    "aggregate_results",
    "batch_from_flat",
    "supported_measures",
    "DEFAULT_CUTOFFS",
    "EvalBatch",
    "batch_from_dense",
    "compute_measures",
    "compute_measures_jit",
    "measure_keys",
    "parse_measures",
    "streaming",
    "trec",
    "sorting",
]
