"""Device-resident IR evaluation (the paper's contribution, on TPU).

Public API mirrors pytrec_eval:

* :class:`RelevanceEvaluator` — dict-in / dict-out evaluation.
* :data:`supported_measures` — measure families available.
* ``registry`` — the declarative measure table (both dialects) everything
  else derives from.
* ``measures`` / ``streaming`` — batched + in-loop device entry points.
"""

from repro.core.evaluator import (RelevanceEvaluator, RunBuffer,
                                  aggregate_results, concat_run_buffers)
from repro.core.measures import (
    AGGREGATE_ONLY_MEASURES,
    DEFAULT_CUTOFFS,
    GM_MIN,
    SUPPORTED_MEASURES as supported_measures,
    EvalBatch,
    batch_from_dense,
    batch_from_flat,
    compute_measures,
    compute_measures_jit,
    compute_measures_topk,
    compute_measures_topk_jit,
    finalize_aggregates,
    measure_keys,
    parse_measures,
)
from repro.core.registry import MeasureError, MeasureSpec, REGISTRY
from repro.core.sweep import SweepResult, evaluate_sweep
from repro.core import registry, streaming, trec, sorting

__all__ = [
    "RelevanceEvaluator",
    "RunBuffer",
    "SweepResult",
    "aggregate_results",
    "concat_run_buffers",
    "evaluate_sweep",
    "batch_from_flat",
    "supported_measures",
    "AGGREGATE_ONLY_MEASURES",
    "DEFAULT_CUTOFFS",
    "GM_MIN",
    "EvalBatch",
    "batch_from_dense",
    "compute_measures",
    "compute_measures_jit",
    "compute_measures_topk",
    "compute_measures_topk_jit",
    "finalize_aggregates",
    "measure_keys",
    "parse_measures",
    "MeasureError",
    "MeasureSpec",
    "REGISTRY",
    "registry",
    "streaming",
    "trec",
    "sorting",
]
