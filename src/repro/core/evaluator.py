"""pytrec_eval-compatible evaluator front-end with a vectorized fast path.

:class:`RelevanceEvaluator` reproduces the pytrec_eval API:

    >>> qrel = {'q1': {'d1': 0, 'd2': 1}, 'q2': {'d1': 1}}
    >>> evaluator = RelevanceEvaluator(qrel, {'map', 'ndcg'})
    >>> run = {'q1': {'d1': 1.0, 'd2': 0.0}, 'q2': {'d1': 1.5, 'd2': 0.2}}
    >>> results = evaluator.evaluate(run)
    >>> sorted(results['q1'])
    ['map', 'ndcg']

Internally the dict-of-dicts run is densified into a padded ``EvalBatch`` and
dispatched to the jitted batched measure core (``core.measures``).  Padding is
bucketed to powers of two so repeated calls with similar shapes reuse the same
compiled executable.

Densification is the analogue of pytrec_eval's "conversion to trec_eval's
internal format", and — like the paper's — it dominates for tiny rankings
(RQ2 crossover).  It is therefore built as a *flat* pipeline with all string
work hoisted to construction time:

* at construction, every qrel docno is interned into one sorted global
  vocabulary (``np.unique``), and the qrel side is laid out as contiguous
  slabs: a sorted ``(query, token)`` key array with judgment values for the
  run→qrel join, per-query ideal-gain rows, and R / judged-non-relevant
  count vectors;
* at ``evaluate`` time the whole run chunk is flattened into single
  ``(qid_idx, docno, score)`` arrays; ONE lexicographic argsort produces the
  trec_eval tie-break ranks, ONE ``searchsorted`` against the interned
  vocabulary plus ONE ``searchsorted`` against the key slab performs the
  run→qrel join, and the results are scattered into the padded ``[Q, D]``
  tensors with fancy indexing.  No Python loop touches individual documents;
  per-query work is limited to O(Q) dict lookups on the mapping input.

The seed per-query densifier is retained verbatim as the ``reference``
path (``RelevanceEvaluator(..., densify="reference")``) for benchmarking and
for bit-identity tests (``tests/test_densify.py``).

Session API (persistent, string-free re-evaluation):

* :meth:`RelevanceEvaluator.evaluate_many` evaluates a sequence (or mapping)
  of runs against the cached qrel state;
* :meth:`RelevanceEvaluator.tokenize_run` /
  :meth:`RelevanceEvaluator.buffer_from_arrays` /
  :meth:`RelevanceEvaluator.buffer_from_tokens` build a :class:`RunBuffer` —
  a pre-tokenized run whose docnos have been resolved against the interned
  vocabulary once.  :meth:`RelevanceEvaluator.evaluate_buffer` (optionally
  with fresh scores) then skips all string work, and
  :meth:`RelevanceEvaluator.batch_from_buffer` yields an ``EvalBatch`` for
  ``core.streaming``'s in-training-loop accumulators;
* :meth:`RelevanceEvaluator.evaluate_buffers` evaluates SEVERAL buffers
  with one coalesced backend call (:func:`concat_run_buffers` stacks them
  on the query axis) — the serving primitive behind :mod:`repro.serve`.
"""

from __future__ import annotations

import threading
from itertools import chain, repeat
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core import measures as M
from repro.core import registry
from repro.kernels import bucketing

RunType = Mapping[str, Mapping[str, float]]
QrelType = Mapping[str, Mapping[str, int]]

# Padding classes come from the shared bucketing module so every engine —
# this evaluator, the sharded dispatch, the serve layer's coalesced waves —
# agrees on ONE closed set of jit signatures (log2(max extent) + O(1)
# classes per axis; see kernels/bucketing.py).
_bucket = bucketing.bucket_docs


class RunBuffer:
    """A run pre-tokenized against an evaluator's interned docno vocabulary.

    Holds the flat, string-free representation of one run chunk: query
    indices, padded-column positions, qrel join results (judgment values and
    judged flags), trec_eval tie-break ranks, and (optionally) scores.  The
    expensive docno work — string materialization, the lexicographic
    tie-break sort, and the vocabulary join — happened exactly once at
    construction; re-evaluating the same collection with new scores is pure
    numeric scatter + the jitted measure core.

    Construct via :meth:`RelevanceEvaluator.tokenize_run`,
    :meth:`RelevanceEvaluator.buffer_from_arrays`, or
    :meth:`RelevanceEvaluator.buffer_from_tokens`.
    """

    __slots__ = ("qids", "gidx", "qidx", "col", "counts", "rel", "judged",
                 "tiebreak", "scores")

    def __init__(self, qids, gidx, qidx, col, counts, rel, judged, tiebreak,
                 scores):
        self.qids: List[str] = qids  # chunk qids, evaluation order
        self.gidx = gidx  # [nq] i64 — evaluator-global query indices
        self.qidx = qidx  # [n] i64 — flat doc → chunk-local query index
        self.col = col  # [n] i64 — flat doc → column in the padded tensor
        self.counts = counts  # [nq] i64 — retrieved docs per query
        self.rel = rel  # [n] f32 — joined judgment (0 for unjudged)
        self.judged = judged  # [n] bool — doc appears in the qrels
        self.tiebreak = tiebreak  # [n] i32 — docno desc-lex rank in query
        self.scores = scores  # [n] f32 or None — default scores

    def __len__(self) -> int:
        return len(self.qids)

    def with_scores(self, scores) -> "RunBuffer":
        """Same collection, new flat scores (concatenated in query order)."""
        scores = np.ascontiguousarray(scores, dtype=np.float32).reshape(-1)
        if scores.shape[0] != self.qidx.shape[0]:
            raise ValueError(
                f"expected {self.qidx.shape[0]} scores, got {scores.shape[0]}")
        return RunBuffer(self.qids, self.gidx, self.qidx, self.col,
                         self.counts, self.rel, self.judged, self.tiebreak,
                         scores)


def concat_run_buffers(bufs: Sequence[RunBuffer]) -> RunBuffer:
    """Stack several :class:`RunBuffer`\\ s (same evaluator) into one.

    The micro-batching primitive of the serve layer: N pending requests for
    the same collection become ONE buffer whose query axis is the requests
    laid end to end, so a single ``batch_from_buffer`` + measure-core call
    evaluates them all.  Queries are kept per-request (the same qid may
    appear in several buffers without collision); split results back by the
    per-buffer query counts (``len(b)``).

    Every buffer must carry scores (re-score first via
    :meth:`RunBuffer.with_scores` if needed).  Buffers must come from the
    same evaluator — ``gidx``/``rel``/``judged`` refer to its interned qrel
    state, and nothing here can re-check that.
    """
    if not bufs:
        raise ValueError("no buffers to concatenate")
    if any(b.scores is None for b in bufs):
        raise ValueError("every buffer needs scores; use with_scores()")
    if len(bufs) == 1:
        return bufs[0]
    qids: List[str] = []
    for b in bufs:
        qids.extend(b.qids)
    q_off = np.cumsum([0] + [len(b) for b in bufs[:-1]])
    return RunBuffer(
        qids,
        np.concatenate([b.gidx for b in bufs]),
        np.concatenate([b.qidx + off for b, off in zip(bufs, q_off)]),
        np.concatenate([b.col for b in bufs]),
        np.concatenate([b.counts for b in bufs]),
        np.concatenate([b.rel for b in bufs]),
        np.concatenate([b.judged for b in bufs]),
        np.concatenate([b.tiebreak for b in bufs]),
        np.concatenate([b.scores for b in bufs]),
    )


class RelevanceEvaluator:
    """Evaluate rankings against relevance judgments, trec_eval semantics.

    Thread-safety: after construction the evaluator's interned qrel state is
    immutable, so any number of threads may call ``evaluate`` /
    ``evaluate_buffer`` / ``evaluate_buffers`` concurrently (the serve layer
    relies on this to run backend calls on executor threads).  The one lazy
    mutation — the seed reference-densifier state — is built under a lock.
    """

    def __init__(
        self,
        query_relevance: QrelType,
        measures: Iterable[str],
        relevance_level: int = 1,
        densify: str = "vectorized",
        judged_docs_only: bool = False,
        judged_docs_only_flag: Optional[bool] = None,
    ):
        if not isinstance(query_relevance, Mapping):
            raise TypeError("query_relevance must be a mapping qid -> {doc: rel}")
        if densify not in ("vectorized", "reference"):
            raise ValueError(f"unknown densify path {densify!r}")
        self.densify_path = densify
        # upstream pytrec_eval spells the constructor flag judged_docs_only
        # (trec_eval -J); accept the _flag alias some callers use.
        if judged_docs_only_flag is not None:
            judged_docs_only = bool(judged_docs_only_flag)
        self.judged_docs_only = bool(judged_docs_only)
        # Measures may arrive in either dialect; rel= annotations (AP(rel=2))
        # resolve the relevance level together with the explicit argument.
        self.measures, self.relevance_level = registry.canonicalize(
            tuple(measures), relevance_level)
        self.measure_keys = registry.keys_for(self.measures)
        #: max ranking depth the measure set reads (None = full sort needed);
        #: drives the top-k kernel routing in :meth:`batch_from_buffer` users
        self._topk_depth = registry.topk_depth(self.measures)
        # Normalize keys only when needed (the copy is O(total judgments);
        # pytrec_eval's C conversion pays the same cost, ~10× cheaper).
        needs_norm = any(
            not isinstance(q, str)
            or any(not isinstance(d, str) for d in docs)
            for q, docs in list(query_relevance.items())[:1])
        if needs_norm:
            self._qrel: Dict[str, Dict[str, int]] = {
                str(q): {str(d): int(r) for d, r in docs.items()}
                for q, docs in query_relevance.items()
            }
        else:
            self._qrel = dict(query_relevance)
        self._build_interned()
        self._reference_state_built = False
        self._reference_lock = threading.Lock()

    #: queries per device batch: bounds padding waste and lets consecutive
    #: chunks reuse one compiled executable (pytrec_eval's C loop analogue)
    chunk_queries: int = 2048

    #: max entries for the dense (query, token) join tables (f32 + bool)
    _DENSE_JOIN_CAP: int = 1 << 24

    #: max bincount size for the counting-sort tie-break rank
    _COUNTING_RANK_CAP: int = 1 << 24

    # -- construction-time qrel interning ------------------------------------

    def _build_interned(self) -> None:
        """One-time qrel parse into flat slabs (pytrec_eval's C conversion).

        Builds: the sorted docno vocabulary; a sorted ``(query, token)`` key
        array + value array for the vectorized run→qrel join; per-query
        ideal-gain rows ``[Q, Jmax]``; and the R / judged-non-relevant
        vectors.  Everything downstream indexes these slabs with fancy
        indexing — no per-query recomputation at evaluate time.
        """
        self._qids: List[str] = list(self._qrel)
        self._qid_index: Dict[str, int] = {
            q: i for i, q in enumerate(self._qids)}
        nq = len(self._qids)
        counts = np.fromiter((len(self._qrel[q]) for q in self._qids),
                             dtype=np.int64, count=nq)
        total = int(counts.sum())
        self._judged_counts = counts
        if total == 0:
            self._vocab = np.empty(0, dtype="U1")
            self._tok = {}
            self._qrel_key = np.empty(0, dtype=np.int64)
            self._qrel_val = np.empty(0, dtype=np.float32)
            self._rel_table = None
            self._judged_table = None
            self._ideal = np.zeros((nq, 0), dtype=np.float32)
            self._n_rel = np.zeros(nq, dtype=np.float32)
            self._n_nonrel = np.zeros(nq, dtype=np.float32)
            return
        docnos = np.array(list(chain.from_iterable(
            self._qrel[q] for q in self._qids)))
        vals = np.fromiter(
            chain.from_iterable(self._qrel[q].values() for q in self._qids),
            dtype=np.float32, count=total)
        qidx = np.repeat(np.arange(nq, dtype=np.int64), counts)
        qptr = np.zeros(nq + 1, dtype=np.int64)
        np.cumsum(counts, out=qptr[1:])

        # Interned vocabulary: one sorted array of all distinct qrel docnos,
        # plus the docno→token hash map for O(1) per-doc interning of runs.
        self._vocab = np.unique(docnos)
        self._tok: Dict[str, int] = {
            d: i for i, d in enumerate(self._vocab.tolist())}
        tok = np.searchsorted(self._vocab, docnos)  # exact by construction
        key = qidx * np.int64(len(self._vocab)) + tok
        order = np.argsort(key)  # (query, token) keys are unique
        self._qrel_key = key[order]
        self._qrel_val = vals[order]
        # Dense join tables (rel value + judged flag indexed by the same
        # (query, token) key) when the qrel is small enough; searchsorted
        # over the sorted key slab otherwise.
        if nq * len(self._vocab) <= self._DENSE_JOIN_CAP:
            self._rel_table = np.zeros(nq * len(self._vocab), dtype=np.float32)
            self._judged_table = np.zeros(nq * len(self._vocab), dtype=bool)
            self._rel_table[self._qrel_key] = self._qrel_val
            self._judged_table[self._qrel_key] = True
        else:
            self._rel_table = None
            self._judged_table = None

        # Per-query statistics, vectorized over the whole qrel at once.
        binrel = (vals >= self.relevance_level).astype(np.float64)
        n_rel = np.bincount(qidx, weights=binrel, minlength=nq)
        self._n_rel = n_rel.astype(np.float32)
        self._n_nonrel = (counts - n_rel).astype(np.float32)

        # Ideal-gain rows: judgments sorted descending per query, scattered
        # into one contiguous [Q, Jmax] slab.
        jmax = int(counts.max())
        ideal = np.zeros((nq, jmax), dtype=np.float32)
        iorder = np.lexsort((-vals, qidx))
        icol = np.arange(total, dtype=np.int64) - qptr[qidx]
        ideal[qidx[iorder], icol] = vals[iorder]
        self._ideal = ideal

    @property
    def vocab(self) -> np.ndarray:
        """The interned docno vocabulary (sorted; token id = position)."""
        return self._vocab

    # -- pytrec_eval API -----------------------------------------------------

    def evaluate(self, run: RunType) -> Dict[str, Dict[str, float]]:
        """Evaluate a run: ``{qid: {docno: score}}`` → ``{qid: {measure: value}}``.

        The pytrec_eval-compatible entry point.  Only queries present in both
        the run and the qrels are evaluated (intersection semantics); docnos
        absent from the qrels count as unjudged/non-relevant.  Scores may be
        any floats — ranking is by descending score with trec_eval's
        descending-docno tie-break.  Values are plain Python floats.

        >>> ev = RelevanceEvaluator({'q1': {'d1': 1, 'd2': 0}}, {'map'})
        >>> ev.evaluate({'q1': {'d1': 0.2, 'd2': 0.9}})['q1']['map']
        0.5
        """
        qids = [q for q in run if q in self._qrel]
        if not qids:
            return {}
        out: Dict[str, Dict[str, float]] = {}
        for lo in range(0, len(qids), self.chunk_queries):
            chunk = qids[lo:lo + self.chunk_queries]
            if self.densify_path == "reference":
                batch, _ = self._densify(run, chunk)
                self._emit(out, chunk, batch)
            else:
                buf = self._tokenize_chunk(run, chunk)
                topk = self._route_topk(buf)
                batch = self.batch_from_buffer(buf, topk_layout=topk)
                self._emit(out, chunk, batch, topk=topk)
        return out

    def evaluate_many(
        self,
        runs: Union[Mapping[str, RunType], Sequence[RunType]],
    ) -> Union[Dict[str, Dict], List[Dict]]:
        """Evaluate several runs against the same cached qrel state.

        The persistent-session entry point: qrel interning, measure parsing,
        and the jit cache are shared across all runs.  Accepts either a
        mapping ``{run_name: run}`` (returns a mapping of results) or a
        sequence of runs (returns a list of results).

        >>> ev = RelevanceEvaluator({'q1': {'d1': 1, 'd2': 0}}, {'recip_rank'})
        >>> res = ev.evaluate_many({'a': {'q1': {'d1': 1.0, 'd2': 0.5}},
        ...                         'b': {'q1': {'d1': 0.5, 'd2': 1.0}}})
        >>> res['a']['q1']['recip_rank'], res['b']['q1']['recip_rank']
        (1.0, 0.5)
        """
        if isinstance(runs, Mapping):
            return {name: self.evaluate(r) for name, r in runs.items()}
        return [self.evaluate(r) for r in runs]

    # -- session API: pre-tokenized runs -------------------------------------

    def tokenize_run(self, run: RunType) -> RunBuffer:
        """Do the string work for a run once, yielding a reusable buffer.

        ``run`` is a ``{qid: {docno: score}}`` mapping; queries absent from
        the qrels are dropped (same intersection semantics as
        :meth:`evaluate`).  The returned :class:`RunBuffer` keeps documents in
        query-major dict-iteration order — that is the flat order fresh
        ``scores`` passed to :meth:`evaluate_buffer` /
        :meth:`batch_from_buffer` must follow.

        >>> ev = RelevanceEvaluator({'q1': {'d1': 1, 'd2': 0}}, {'map'})
        >>> buf = ev.tokenize_run({'q1': {'d1': 1.0, 'd2': 0.5}})
        >>> len(buf), buf.counts.tolist()
        (1, [2])
        """
        return self._tokenize_chunk(run, [q for q in run if q in self._qrel])

    def buffer_from_arrays(self, qids, docnos, scores) -> RunBuffer:
        """Tokenize a flat ``(qid, docno, score)`` triple-array run.

        The array analogue of :meth:`tokenize_run` — pairs with
        ``core.trec.parse_run_arrays`` so a TREC run file goes straight into
        the tokenized form without ever building a dict-of-dicts.  Rows may
        arrive in any order; queries are grouped with a stable sort, and rows
        for queries absent from the qrels are dropped (pytrec_eval
        intersection semantics).

        Shapes/dtypes: all three arguments are flat, equal-length 1-D arrays
        — ``qids`` and ``docnos`` string-convertible, ``scores`` cast to
        float32.  ``(qid, docno)`` pairs must be unique (trec_eval rejects
        duplicates; this fast path does not re-check).

        >>> import numpy as np
        >>> ev = RelevanceEvaluator({'q1': {'d1': 1, 'd2': 0}}, {'recip_rank'})
        >>> buf = ev.buffer_from_arrays(np.array(['q1', 'q1']),
        ...                             np.array(['d2', 'd1']),
        ...                             np.array([0.2, 0.9], dtype=np.float32))
        >>> ev.evaluate_buffer(buf)['q1']['recip_rank']
        1.0
        """
        qids = np.asarray(qids)
        docnos = np.asarray(docnos)
        scores = np.asarray(scores, dtype=np.float32)
        uniq, inv = np.unique(qids, return_inverse=True)
        known = np.fromiter((q in self._qid_index for q in uniq.tolist()),
                            dtype=bool, count=len(uniq))
        keep = known[inv]
        inv = inv[keep]
        order = np.argsort(inv, kind="stable")
        grouped_counts = np.bincount(inv, minlength=len(uniq))
        kept_uniq = [q for q, k in zip(uniq.tolist(), known.tolist()) if k]
        counts = grouped_counts[known].astype(np.int64)
        return self._make_buffer(kept_uniq, counts, docnos[keep][order],
                                 scores[keep][order])

    def buffer_from_tokens(self, qids: Sequence[str], counts, tokens,
                           scores=None) -> RunBuffer:
        """Build a buffer from *pre-tokenized* integer docnos — no strings.

        ``tokens`` is the flat concatenation (query order given by ``qids`` /
        ``counts``) of indices into :attr:`vocab`; out-of-vocabulary documents
        are ``-1``.  Tokens must be unique within a query.  Tie-break ranks
        are derived from token order — exact for in-vocabulary docnos (the
        vocabulary is lex-sorted), while OOV documents rank after all
        in-vocabulary docs at equal score.  OOV docs are unjudged, so this
        only reorders unjudged-vs-unjudged pairs relative to trec_eval, which
        no measure observes; score ties between an OOV and a judged doc are
        the one divergence, documented here.

        Shapes/dtypes: ``qids`` is a length-``nq`` sequence of qrel query
        ids; ``counts`` (``[nq]``, int) gives retrieved docs per query;
        ``tokens`` (``[sum(counts)]``, int) and optional ``scores`` (same
        length, cast to float32) are flat in that query order.

        >>> ev = RelevanceEvaluator({'q1': {'d1': 1, 'd2': 0}}, {'recip_rank'})
        >>> ev.vocab.tolist()  # token id = position; -1 = out-of-vocabulary
        ['d1', 'd2']
        >>> buf = ev.buffer_from_tokens(['q1'], counts=[2], tokens=[0, -1],
        ...                             scores=[0.9, 0.2])
        >>> ev.evaluate_buffer(buf)['q1']['recip_rank']
        1.0
        """
        qids = [str(q) for q in qids]
        missing = [q for q in qids if q not in self._qid_index]
        if missing:
            raise KeyError(f"qids not in qrels: {missing[:3]}")
        counts = np.asarray(counts, dtype=np.int64)
        tokens = np.asarray(tokens, dtype=np.int64)
        total = int(counts.sum())
        if tokens.shape[0] != total:
            raise ValueError(
                f"token count {tokens.shape[0]} != sum(counts) {total}")
        nq = len(qids)
        gidx = np.fromiter((self._qid_index[q] for q in qids),
                           dtype=np.int64, count=nq)
        qidx = np.repeat(np.arange(nq, dtype=np.int64), counts)
        qptr = np.zeros(nq + 1, dtype=np.int64)
        np.cumsum(counts, out=qptr[1:])
        col = np.arange(total, dtype=np.int64) - qptr[qidx]
        in_vocab = tokens >= 0
        rel, judged = self._join_tokens(gidx, qidx,
                                        np.maximum(tokens, 0), in_vocab)
        # Desc-token rank == desc-lex rank for in-vocab docs; OOV (-1) sorts
        # first ascending → last descending.
        tiebreak = self._desc_ranks(np.lexsort((tokens, qidx)), qidx, qptr,
                                    counts)
        if scores is not None:
            scores = np.ascontiguousarray(scores,
                                          dtype=np.float32).reshape(-1)
            if scores.shape[0] != total:
                raise ValueError(
                    f"score count {scores.shape[0]} != sum(counts) {total}")
        return RunBuffer(qids, gidx, qidx, col, counts, rel, judged, tiebreak,
                         scores)

    def batch_from_buffer(self, buf: RunBuffer, scores=None,
                          q_multiple: int = 1,
                          topk_layout: bool = False) -> M.EvalBatch:
        """Padded ``EvalBatch`` from a buffer (numeric work only).

        Feed the result to ``core.measures.compute_measures_jit`` or to
        ``core.streaming.metric_update`` inside a training loop.

        ``q_multiple`` is the shard-aware padding knob: the query axis is
        padded to a multiple of it (on top of the usual power-of-two
        bucketing), so the batch divides evenly over the query axis of a
        device mesh.  ``repro.distributed.sharded_evaluator`` passes the mesh
        size here; padded queries carry ``query_mask == False`` and are
        ignored by every measure and aggregate.

        ``topk_layout`` scatters each document at column == its tiebreak
        rank (a permutation of ``[0, count)``, so the counts-derived mask
        stays valid).  Under that layout the top-k kernel's
        smaller-index-wins tie rule IS trec_eval's tie rule, which is what
        ``core.measures.compute_measures_topk`` requires; the layout is
        measure-invariant for the full-sort path (``tiebreak`` still rides
        along as its own field).
        """
        if scores is not None:
            buf = buf.with_scores(scores)
        if buf.scores is None:
            raise ValueError("buffer has no scores; pass scores=")
        nq = len(buf.qids)
        max_d = int(buf.counts.max()) if nq else 0
        jcounts = self._judged_counts[buf.gidx]
        max_j = int(jcounts.max()) if nq else 0
        q_pad = bucketing.bucket_queries(nq, multiple=q_multiple)
        return M.batch_from_flat(
            qidx=buf.qidx,
            col=buf.tiebreak if topk_layout else buf.col,
            scores=buf.scores,
            tiebreak=buf.tiebreak, rel=buf.rel, judged=buf.judged,
            ideal_rows=self._ideal[buf.gidx],
            n_rel=self._n_rel[buf.gidx],
            n_judged_nonrel=self._n_nonrel[buf.gidx],
            n_queries=nq, q_pad=q_pad, d_pad=_bucket(max_d),
            j_pad=_bucket(max(max_j, 1)), counts=buf.counts)

    def _route_topk(self, buf: RunBuffer) -> bool:
        """Should this buffer take the top-k kernel path?

        Yes iff every requested measure is depth-bounded (ROADMAP item 2:
        ``*_cut`` / ``@k`` measures stop sorting the full document axis) and
        the padded document axis is wide enough that ranking only the top-k
        prefix beats the full multi-key sort.  Results are bit-identical
        either way (parity-tested in tests/test_measures.py).
        """
        if self._topk_depth is None or not len(buf):
            return False
        from repro.kernels import topk as _tk

        d_pad = _bucket(int(buf.counts.max()))
        k2 = _tk._next_pow2(self._topk_depth, 128)
        return d_pad > max(2 * k2, 512)

    def evaluate_buffer(self, buf: RunBuffer,
                        scores=None) -> Dict[str, Dict[str, float]]:
        """Evaluate a pre-tokenized buffer; optional fresh flat scores.

        The zero-string-work half of the session API: all docno
        interning/tie-breaking happened when ``buf`` was built, so this call
        is a numeric scatter plus the jitted measure core.  ``scores``, when
        given, replaces the buffer's scores — a flat float array in the
        buffer's query-major document order (``buf.counts[i]`` docs for
        ``buf.qids[i]``, concatenated).

        >>> ev = RelevanceEvaluator({'q1': {'d1': 1, 'd2': 0}}, {'recip_rank'})
        >>> buf = ev.tokenize_run({'q1': {'d1': 1.0, 'd2': 0.5}})
        >>> ev.evaluate_buffer(buf)['q1']['recip_rank']
        1.0
        >>> ev.evaluate_buffer(buf, scores=[0.1, 0.9])['q1']['recip_rank']
        0.5
        """
        if not len(buf):
            return {}
        topk = self._route_topk(buf)
        batch = self.batch_from_buffer(buf, scores, topk_layout=topk)
        out: Dict[str, Dict[str, float]] = {}
        self._emit(out, buf.qids, batch, topk=topk)
        return out

    def evaluate_buffers(
        self,
        bufs: Sequence[RunBuffer],
        scores_list: Optional[Sequence] = None,
    ) -> List[Dict[str, Dict[str, float]]]:
        """Evaluate several buffers with ONE densify + measure-core call.

        The coalescing hook for the serve layer
        (:mod:`repro.serve`): the buffers are stacked end to end on the query
        axis (:func:`concat_run_buffers`), scattered into one padded
        ``EvalBatch``, and dispatched to the jitted measure core once; the
        per-query columns are then split back by each buffer's query count.
        Results are bit-identical to calling :meth:`evaluate_buffer` once per
        buffer — measures are computed row-independently, so stacking the
        query axis (like sharding it) cannot change any value.

        ``scores_list``, when given, pairs each buffer with fresh flat scores
        (``None`` entries keep the buffer's own scores).

        >>> ev = RelevanceEvaluator({'q1': {'d1': 1, 'd2': 0}}, {'map'})
        >>> a = ev.tokenize_run({'q1': {'d1': 1.0, 'd2': 0.5}})
        >>> b = ev.tokenize_run({'q1': {'d1': 0.1, 'd2': 0.9}})
        >>> [r['q1']['map'] for r in ev.evaluate_buffers([a, b])]
        [1.0, 0.5]
        """
        bufs = list(bufs)
        if scores_list is not None:
            if len(scores_list) != len(bufs):
                raise ValueError(
                    f"{len(scores_list)} score sets for {len(bufs)} buffers")
            bufs = [b if s is None else b.with_scores(s)
                    for b, s in zip(bufs, scores_list)]
        if not bufs:
            return []
        nonempty = [b for b in bufs if len(b)]
        if not nonempty:
            return [{} for _ in bufs]
        big = concat_run_buffers(nonempty)
        topk = self._route_topk(big)
        batch = self.batch_from_buffer(big, topk_layout=topk)
        compute = (M.compute_measures_topk_jit if topk
                   else M.compute_measures_jit)
        per_query = compute(batch, self.measures, self.relevance_level,
                            self.judged_docs_only)
        cols = {k: np.asarray(per_query[k])[:len(big.qids)].tolist()
                for k in self.measure_keys}
        results: List[Dict[str, Dict[str, float]]] = []
        lo = 0
        for buf in bufs:
            out: Dict[str, Dict[str, float]] = {}
            for i, qid in enumerate(buf.qids):
                out[qid] = {k: cols[k][lo + i] for k in self.measure_keys}
            lo += len(buf.qids)
            results.append(out)
        return results

    def evaluate_sharded(self, run_or_buffer, mesh=None):
        """Evaluate across every visible device (convenience wrapper).

        Builds a :class:`repro.distributed.sharded_evaluator.ShardedEvaluator`
        over ``mesh`` (default: one 1-D mesh spanning ``jax.devices()``) and
        evaluates ``run_or_buffer`` (a run mapping or a :class:`RunBuffer`).
        Returns a ``ShardedResult`` with per-query results bit-identical to
        :meth:`evaluate` plus corpus-mean aggregates.
        """
        from repro.distributed.sharded_evaluator import ShardedEvaluator

        return ShardedEvaluator(self, mesh=mesh).evaluate(run_or_buffer)

    # -- densification --------------------------------------------------------

    def _densify(self, run: RunType, qids: Sequence[str]):
        if self.densify_path == "reference":
            return self._densify_reference(run, qids)
        return self._densify_vectorized(run, qids)

    def _densify_vectorized(self, run: RunType, qids: Sequence[str]):
        """Flat pipeline: one tie-break lexsort, one vocab join, one scatter."""
        batch = self.batch_from_buffer(self._tokenize_chunk(run, qids))
        return batch, np.asarray(batch.query_mask)

    def _tokenize_chunk(self, run: RunType, qids: Sequence[str]) -> RunBuffer:
        """Dict-of-dicts chunk → RunBuffer via the interned token map.

        The hot path does NOT materialize a docno string array: every docno
        is interned through the construction-time hash map in one C-level
        ``map`` pass, after which tie-break ranks and the qrel join are pure
        integer work.  Only runs containing out-of-vocabulary docnos (absent
        from the qrels) fall back to the exact string pipeline, because OOV
        tie-breaks need real lexicographic comparisons.
        """
        doc_maps = [run[q] for q in qids]
        nq = len(qids)
        counts = np.fromiter(map(len, doc_maps), dtype=np.int64, count=nq)
        total = int(counts.sum())
        if not total:
            return self._make_buffer(list(qids), counts,
                                     np.empty(0, dtype="U1"),
                                     np.empty(0, dtype=np.float32))
        tokens = np.fromiter(
            map(self._tok.get, chain.from_iterable(doc_maps), repeat(-1)),
            dtype=np.int64, count=total)
        scores = np.fromiter(
            chain.from_iterable(m.values() for m in doc_maps),
            dtype=np.float32, count=total)
        if int(tokens.min()) < 0:  # OOV docs → exact string pipeline
            docnos = np.array(list(chain.from_iterable(doc_maps)))
            return self._make_buffer(list(qids), counts, docnos, scores)
        return self._buffer_from_exact_tokens(list(qids), counts, tokens,
                                              scores)

    def _make_buffer(self, qids: List[str], counts: np.ndarray,
                     docnos: np.ndarray, scores: np.ndarray) -> RunBuffer:
        """Exact string tokenization core: grouped flat arrays → RunBuffer."""
        nq = len(qids)
        total = int(counts.sum())
        gidx = np.fromiter((self._qid_index[q] for q in qids),
                           dtype=np.int64, count=nq)
        qidx = np.repeat(np.arange(nq, dtype=np.int64), counts)
        qptr = np.zeros(nq + 1, dtype=np.int64)
        np.cumsum(counts, out=qptr[1:])
        col = np.arange(total, dtype=np.int64) - qptr[qidx]

        # ONE searchsorted against the interned vocabulary.
        v = len(self._vocab)
        if v and total:
            tok = np.searchsorted(self._vocab, docnos)
            tok_c = np.minimum(tok, v - 1)
            in_vocab = self._vocab[tok_c] == docnos
            rel, judged = self._join_tokens(gidx, qidx, tok_c, in_vocab)
        else:
            rel = np.zeros(total, dtype=np.float32)
            judged = np.zeros(total, dtype=bool)

        # ONE lexicographic argsort for the trec_eval tie-break ranks
        # (score ties broken by docno descending → smaller rank wins).
        tiebreak = self._desc_ranks(np.lexsort((docnos, qidx)), qidx, qptr,
                                    counts)
        return RunBuffer(qids, gidx, qidx, col, counts, rel, judged, tiebreak,
                         scores)

    def _buffer_from_exact_tokens(self, qids: List[str], counts: np.ndarray,
                                  tokens: np.ndarray,
                                  scores: np.ndarray) -> RunBuffer:
        """Integer-only tokenization core: every docno is in the vocabulary.

        Token order equals lexicographic docno order (the vocabulary is
        sorted), so tie-break ranks come from a counting sort over the unique
        ``(query, token)`` keys — O(n + Q·V), no comparison sort at all —
        and the qrel join is a table gather (or one integer searchsorted).
        """
        nq = len(qids)
        total = int(counts.sum())
        v = len(self._vocab)
        gidx = np.fromiter((self._qid_index[q] for q in qids),
                           dtype=np.int64, count=nq)
        qidx = np.repeat(np.arange(nq, dtype=np.int64), counts)
        qptr = np.zeros(nq + 1, dtype=np.int64)
        np.cumsum(counts, out=qptr[1:])
        col = np.arange(total, dtype=np.int64) - qptr[qidx]

        rel, judged = self._join_tokens(
            gidx, qidx, tokens, np.ones(total, dtype=bool))

        key = qidx * np.int64(v) + tokens  # unique: docnos unique per query
        if nq * v <= self._COUNTING_RANK_CAP:
            # counting-sort rank: position of each key in sorted order
            asc = np.cumsum(np.bincount(key, minlength=nq * v))[key] - 1
            asc -= qptr[qidx]
        else:
            order = np.argsort(key)
            asc = np.empty(total, dtype=np.int64)
            asc[order] = np.arange(total, dtype=np.int64)
            asc -= qptr[qidx]
        tiebreak = (counts[qidx] - 1 - asc).astype(np.int32)
        return RunBuffer(qids, gidx, qidx, col, counts, rel, judged, tiebreak,
                         scores)

    def _join_tokens(self, gidx, qidx, tok_c, in_vocab):
        """Vectorized run→qrel join on (query, token) keys: one table gather
        when the dense tables fit, one integer searchsorted otherwise."""
        total = qidx.shape[0]
        rel = np.zeros(total, dtype=np.float32)
        judged = np.zeros(total, dtype=bool)
        if not len(self._qrel_key) or not total:
            return rel, judged
        key = gidx[qidx] * np.int64(len(self._vocab)) + tok_c
        if self._rel_table is not None:
            rel = np.where(in_vocab, self._rel_table[key], 0.0)
            judged = in_vocab & self._judged_table[key]
            return rel, judged
        pos = np.searchsorted(self._qrel_key, key)
        pos_c = np.minimum(pos, len(self._qrel_key) - 1)
        hit = in_vocab & (self._qrel_key[pos_c] == key)
        rel[hit] = self._qrel_val[pos_c[hit]]
        judged = hit
        return rel, judged

    @staticmethod
    def _desc_ranks(order, qidx, qptr, counts) -> np.ndarray:
        """Per-query descending ranks from an ascending within-query sort."""
        total = qidx.shape[0]
        asc = np.arange(total, dtype=np.int64) - qptr[qidx[order]]
        tiebreak = np.empty(total, dtype=np.int32)
        tiebreak[order] = (counts[qidx[order]] - 1 - asc).astype(np.int32)
        return tiebreak

    # -- reference (seed) densifier, kept for benchmarks + bit-identity ------

    def _ensure_reference_state(self) -> None:
        if self._reference_state_built:
            return
        with self._reference_lock:
            if self._reference_state_built:
                return
            qstats = {}
            qrel_sorted = {}
            for qid, docs in self._qrel.items():
                rels = np.array(sorted(docs.values(), reverse=True),
                                dtype=np.float32)
                n_rel = float((rels >= self.relevance_level).sum())
                n_nonrel = float(len(rels)) - n_rel
                qstats[qid] = (rels, n_rel, n_nonrel)
                docnos = np.array(list(docs.keys()))
                vals = np.fromiter(docs.values(), dtype=np.float32,
                                   count=len(docs))
                order = np.argsort(docnos)
                qrel_sorted[qid] = (docnos[order], vals[order])
            self._qstats = qstats
            self._qrel_sorted = qrel_sorted
            self._reference_state_built = True

    def _densify_reference(self, run: RunType, qids: Sequence[str]):
        """The seed per-query-loop densifier (unchanged semantics)."""
        self._ensure_reference_state()
        nq = len(qids)
        max_d = max(len(run[q]) for q in qids)
        max_j = max(len(self._qstats[q][0]) for q in qids)
        qb, db, jb = _bucket(nq, 1), _bucket(max_d), _bucket(max(max_j, 1))

        scores = np.zeros((qb, db), dtype=np.float32)
        tiebreak = np.zeros((qb, db), dtype=np.int32)
        rel = np.zeros((qb, db), dtype=np.float32)
        judged = np.zeros((qb, db), dtype=bool)
        mask = np.zeros((qb, db), dtype=bool)
        ideal = np.zeros((qb, jb), dtype=np.float32)
        n_rel = np.zeros((qb,), dtype=np.float32)
        n_nonrel = np.zeros((qb,), dtype=np.float32)
        qmask = np.zeros((qb,), dtype=bool)

        for i, qid in enumerate(qids):
            docs = run[qid]
            d = len(docs)
            docnos = np.array(list(docs.keys()))
            # trec_eval tie-break: larger docno (desc lex) wins → order rank.
            order = np.empty(d, dtype=np.int32)
            order[np.argsort(docnos)[::-1]] = np.arange(d, dtype=np.int32)
            scores[i, :d] = np.fromiter(docs.values(), dtype=np.float32,
                                        count=d)
            tiebreak[i, :d] = order
            # vectorized run→qrel join (sorted-array searchsorted, C speed)
            qrel_docnos, qrel_vals = self._qrel_sorted[qid]
            if len(qrel_docnos):
                pos = np.searchsorted(qrel_docnos, docnos)
                pos_c = np.minimum(pos, len(qrel_docnos) - 1)
                hit = qrel_docnos[pos_c] == docnos
                rel[i, :d] = np.where(hit, qrel_vals[pos_c], 0.0)
                judged[i, :d] = hit
            mask[i, :d] = True
            rels, r, n = self._qstats[qid]
            ideal[i, : len(rels)] = rels
            n_rel[i], n_nonrel[i] = r, n
            qmask[i] = True

        batch = M.EvalBatch(
            scores=scores, tiebreak=tiebreak, rel=rel, judged=judged,
            mask=mask, ideal_rel=ideal, n_rel=n_rel,
            n_judged_nonrel=n_nonrel, query_mask=qmask,
        )
        return batch, qmask

    # -- output ---------------------------------------------------------------

    def _emit(self, out: Dict[str, Dict[str, float]], qids: Sequence[str],
              batch: M.EvalBatch, topk: bool = False) -> None:
        compute = (M.compute_measures_topk_jit if topk
                   else M.compute_measures_jit)
        per_query = compute(batch, self.measures, self.relevance_level,
                            self.judged_docs_only)
        nq = len(qids)
        cols = {k: np.asarray(per_query[k])[:nq].tolist()
                for k in self.measure_keys}
        for i, qid in enumerate(qids):
            out[qid] = {k: cols[k][i] for k in self.measure_keys}


def aggregate_results(results: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """Mean of every measure over queries (trec_eval's 'all' summary row).

    Geometric-mean measures (``gm_map``) carry per-query *log* contributions
    and are exponentiated after averaging (``measures.finalize_aggregates``),
    matching trec_eval's summary semantics.

    >>> res = {'q1': {'map': 1.0, 'gm_map': 0.0},
    ...        'q2': {'map': 0.25, 'gm_map': float(np.log(0.25))}}
    >>> agg = aggregate_results(res)
    >>> agg['map'], round(agg['gm_map'], 6)  # arithmetic vs geometric mean
    (0.625, 0.5)
    """
    if not results:
        return {}
    keys = next(iter(results.values())).keys()
    return M.finalize_aggregates({
        k: float(np.mean([results[q][k] for q in results])) for k in keys
    })
