"""pytrec_eval-compatible evaluator front-end.

:class:`RelevanceEvaluator` reproduces the pytrec_eval API:

    >>> qrel = {'q1': {'d1': 0, 'd2': 1}, 'q2': {'d1': 1}}
    >>> evaluator = RelevanceEvaluator(qrel, {'map', 'ndcg'})
    >>> run = {'q1': {'d1': 1.0, 'd2': 0.0}, 'q2': {'d1': 1.5, 'd2': 0.2}}
    >>> results = evaluator.evaluate(run)
    >>> sorted(results['q1'])
    ['map', 'ndcg']

Internally the dict-of-dicts run is densified into a padded ``EvalBatch`` and
dispatched to the jitted batched measure core (``core.measures``).  Padding is
bucketed to powers of two so repeated calls with similar shapes reuse the same
compiled executable — the analogue of pytrec_eval's "conversion to trec_eval's
internal format", and like the paper's, it is the dominant cost for tiny
rankings (RQ2 crossover).

The qrel-side statistics (R, judged-non-relevant count, ideal gain vector) are
precomputed once at construction, mirroring pytrec_eval's one-time qrel parse.
"""

from __future__ import annotations

from typing import Dict, Iterable, Mapping, Sequence

import numpy as np

import jax.numpy as jnp

from repro.core import measures as M

RunType = Mapping[str, Mapping[str, float]]
QrelType = Mapping[str, Mapping[str, int]]


def _bucket(n: int, minimum: int = 8) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


class RelevanceEvaluator:
    """Evaluate rankings against relevance judgments, trec_eval semantics."""

    def __init__(
        self,
        query_relevance: QrelType,
        measures: Iterable[str],
        relevance_level: int = 1,
    ):
        if not isinstance(query_relevance, Mapping):
            raise TypeError("query_relevance must be a mapping qid -> {doc: rel}")
        self.relevance_level = float(relevance_level)
        self.measures = M.parse_measures(tuple(measures))
        self.measure_keys = M.measure_keys(tuple(measures))
        # Normalize keys only when needed (the copy is O(total judgments);
        # pytrec_eval's C conversion pays the same cost, ~10× cheaper).
        needs_norm = any(
            not isinstance(q, str)
            or any(not isinstance(d, str) for d in docs)
            for q, docs in list(query_relevance.items())[:1])
        if needs_norm:
            self._qrel: Dict[str, Dict[str, int]] = {
                str(q): {str(d): int(r) for d, r in docs.items()}
                for q, docs in query_relevance.items()
            }
        else:
            self._qrel = dict(query_relevance)
        # Per-query qrel statistics (computed once; pytrec_eval's qrel parse).
        # Docnos are kept as a *sorted numpy string array* so the run→rel join
        # in _densify is a vectorized searchsorted, not a Python dict loop.
        self._qstats = {}
        self._qrel_sorted = {}
        for qid, docs in self._qrel.items():
            rels = np.array(sorted(docs.values(), reverse=True), dtype=np.float32)
            n_rel = float((rels >= self.relevance_level).sum())
            n_nonrel = float(len(rels)) - n_rel
            self._qstats[qid] = (rels, n_rel, n_nonrel)
            docnos = np.array(list(docs.keys()))
            vals = np.fromiter(docs.values(), dtype=np.float32,
                               count=len(docs))
            order = np.argsort(docnos)
            self._qrel_sorted[qid] = (docnos[order], vals[order])

    #: queries per device batch: bounds padding waste and lets consecutive
    #: chunks reuse one compiled executable (pytrec_eval's C loop analogue)
    chunk_queries: int = 2048

    # -- pytrec_eval API -----------------------------------------------------

    def evaluate(self, run: RunType) -> Dict[str, Dict[str, float]]:
        """Evaluate a run: {qid: {docno: score}} -> {qid: {measure: value}}."""
        qids = [q for q in run if q in self._qrel]
        if not qids:
            return {}
        out: Dict[str, Dict[str, float]] = {}
        for lo in range(0, len(qids), self.chunk_queries):
            chunk = qids[lo:lo + self.chunk_queries]
            batch, _ = self._densify(run, chunk)
            per_query = M.compute_measures_jit(batch, self.measures,
                                               self.relevance_level)
            per_query = {k: np.asarray(v) for k, v in per_query.items()}
            for i, qid in enumerate(chunk):
                out[qid] = {k: float(per_query[k][i])
                            for k in self.measure_keys}
        return out

    # -- densification --------------------------------------------------------

    def _densify(self, run: RunType, qids: Sequence[str]):
        nq = len(qids)
        max_d = max(len(run[q]) for q in qids)
        max_j = max(len(self._qstats[q][0]) for q in qids)
        qb, db, jb = _bucket(nq, 1), _bucket(max_d), _bucket(max(max_j, 1))

        scores = np.zeros((qb, db), dtype=np.float32)
        tiebreak = np.zeros((qb, db), dtype=np.int32)
        rel = np.zeros((qb, db), dtype=np.float32)
        judged = np.zeros((qb, db), dtype=bool)
        mask = np.zeros((qb, db), dtype=bool)
        ideal = np.zeros((qb, jb), dtype=np.float32)
        n_rel = np.zeros((qb,), dtype=np.float32)
        n_nonrel = np.zeros((qb,), dtype=np.float32)
        qmask = np.zeros((qb,), dtype=bool)

        for i, qid in enumerate(qids):
            docs = run[qid]
            d = len(docs)
            docnos = np.array(list(docs.keys()))
            # trec_eval tie-break: larger docno (desc lex) wins → order rank.
            order = np.empty(d, dtype=np.int32)
            order[np.argsort(docnos)[::-1]] = np.arange(d, dtype=np.int32)
            scores[i, :d] = np.fromiter(docs.values(), dtype=np.float32,
                                        count=d)
            tiebreak[i, :d] = order
            # vectorized run→qrel join (sorted-array searchsorted, C speed)
            qrel_docnos, qrel_vals = self._qrel_sorted[qid]
            if len(qrel_docnos):
                pos = np.searchsorted(qrel_docnos, docnos)
                pos_c = np.minimum(pos, len(qrel_docnos) - 1)
                hit = qrel_docnos[pos_c] == docnos
                rel[i, :d] = np.where(hit, qrel_vals[pos_c], 0.0)
                judged[i, :d] = hit
            mask[i, :d] = True
            rels, r, n = self._qstats[qid]
            ideal[i, : len(rels)] = rels
            n_rel[i], n_nonrel[i] = r, n
            qmask[i] = True

        # numpy arrays go straight into the jitted call (single transfer);
        # no intermediate per-array device_put.
        batch = M.EvalBatch(
            scores=scores, tiebreak=tiebreak, rel=rel, judged=judged,
            mask=mask, ideal_rel=ideal, n_rel=n_rel,
            n_judged_nonrel=n_nonrel, query_mask=qmask,
        )
        return batch, qmask


def aggregate_results(results: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """Mean of every measure over queries (trec_eval's 'all' summary row)."""
    if not results:
        return {}
    keys = next(iter(results.values())).keys()
    return {
        k: float(np.mean([results[q][k] for q in results])) for k in keys
    }
