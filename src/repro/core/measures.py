"""Batched trec_eval evaluation measures on dense ``[Q, D]`` tensors.

This is the device-resident core of the framework: the reference measure
definitions of trec_eval, re-expressed as vectorized JAX computations over a
whole batch of queries at once.  Where trec_eval walks each ranking once in C,
we compute cumulative statistics over the sorted relevance tensor with a single
pass of vector ops — the same one-pass structure, MXU/VPU-friendly.

Semantics follow trec_eval (and therefore pytrec_eval):

* documents are ranked by decreasing score, ties broken by docno (descending
  lex — encoded in the ``tiebreak`` field, see ``core.sorting``);
* unjudged documents count as non-relevant;
* a document is *relevant* iff its judgment >= ``relevance_level`` (default 1);
* ``map`` / ``recall`` / ``Rprec`` normalize by R = number of relevant docs in
  the **qrels** (including unretrieved ones);
* ``ndcg`` uses trec_eval's linear gain (rel / log2(rank+1)) with the ideal
  ranking drawn from the full qrels;
* cutoffs match trec_eval: 5,10,15,20,30,100,200,500,1000 (success: 1,5,10).

All measure functions operate on an :class:`EvalBatch` and return per-query
float32 vectors ``[Q]``; padded queries (``query_mask == False``) return 0 and
are excluded by the aggregation helpers.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import registry, sorting

# Shared measure constants live in the declarative registry; re-exported
# here because every engine historically imports them from this module.
DEFAULT_CUTOFFS: Tuple[int, ...] = registry.DEFAULT_CUTOFFS
SUCCESS_CUTOFFS: Tuple[int, ...] = registry.SUCCESS_CUTOFFS
IPREC_LEVELS: Tuple[float, ...] = registry.IPREC_LEVELS

#: trec_eval's MIN_GEO_MEAN: per-query AP is clipped to this before the log
#: so queries with AP == 0 do not collapse the geometric mean to 0.
GM_MIN: float = registry.GM_MIN

#: Measure families understood by this module (pytrec_eval-compatible ids),
#: derived from the declarative registry (``repro.core.registry``).
SUPPORTED_MEASURES = registry.supported_families()

#: Aggregate-only measures: the per-query column is a *log contribution*
#: (``log(max(AP, GM_MIN))`` for ``gm_map``, exactly what trec_eval
#: accumulates per query); the user-facing value is the geometric mean
#: ``exp(mean(column))`` produced by :func:`finalize_aggregates`.  The CLI
#: suppresses these keys from per-query (-q) output, like trec_eval does.
AGGREGATE_ONLY_MEASURES = registry.aggregate_only_families()


class EvalBatch(NamedTuple):
    """Dense, padded representation of a batch of rankings + ground truth.

    Axes: Q = queries (padded), D = retrieved docs per query (padded),
    J = judged docs per query (padded; used only for the ideal DCG).
    """

    scores: jax.Array  # [Q, D] f32 — retrieval scores (order irrelevant)
    tiebreak: jax.Array  # [Q, D] i32 — smaller wins ties (docno desc-lex rank)
    rel: jax.Array  # [Q, D] f32 — judgment of each retrieved doc (0 unjudged)
    judged: jax.Array  # [Q, D] bool — retrieved doc appears in the qrels
    mask: jax.Array  # [Q, D] bool — retrieved doc is real (not padding)
    ideal_rel: jax.Array  # [Q, J] f32 — qrel judgments, sorted descending
    n_rel: jax.Array  # [Q] f32 — R: relevant docs in qrels (rel >= level)
    n_judged_nonrel: jax.Array  # [Q] f32 — judged non-relevant docs in qrels
    query_mask: jax.Array  # [Q] bool — query is real (not padding)


class SortedBatch(NamedTuple):
    """EvalBatch after ranking: everything ordered by trec_eval rank."""

    rel: jax.Array  # [Q, D] f32, rank order
    binrel: jax.Array  # [Q, D] f32 (0/1), rank order
    judged: jax.Array  # [Q, D] f32 (0/1), rank order
    mask: jax.Array  # [Q, D] f32 (0/1), rank order
    cum_rel: jax.Array  # [Q, D] f32 — inclusive cumulative count of relevant
    ideal_rel: jax.Array  # [Q, J] f32
    n_rel: jax.Array  # [Q] f32
    n_judged_nonrel: jax.Array  # [Q] f32
    n_ret: jax.Array  # [Q] f32
    query_mask: jax.Array  # [Q] bool


_PACK_OFFSET = 4.0  # rel values ≥ -4 supported (trec_eval uses ≥ -2)


def sort_batch(batch: EvalBatch, relevance_level: float = 1.0,
               judged_only: bool = False) -> SortedBatch:
    """Rank every query's documents under trec_eval ordering.

    ``judged_only`` implements trec_eval's ``-J`` (pytrec_eval's
    ``judged_docs_only`` constructor flag): unjudged retrieved documents are
    removed from the ranking before any measure sees it.  Dropped documents
    sort to the tail with rel=0/judged=0 — indistinguishable from padding,
    hence inert for every measure — and ``n_ret`` counts only the kept docs.

    Perf note (§Perf iteration C2): (rel, judged) ride the sort as ONE packed
    f32 payload — ``(rel+4)·2 + judged`` — and the mask is not sorted at all
    (padding sorts last with rel=0/judged=0, which is inert for every
    measure; n_ret is an order-invariant pre-sort sum).  This halves the
    multi-operand sort's traffic vs the naive 5-payload formulation.
    """
    assert relevance_level >= 1.0 or relevance_level > 0, \
        "packed-payload sort assumes relevance_level > 0"
    mask = batch.mask & batch.judged if judged_only else batch.mask
    packed = (batch.rel * jnp.asarray(mask, jnp.float32)
              + _PACK_OFFSET) * 2.0 + jnp.asarray(
        batch.judged & mask, jnp.float32)
    packed = jnp.where(mask, packed, _PACK_OFFSET * 2.0)
    (packed_s,) = sorting.rank_sort(
        batch.scores, batch.tiebreak, mask, packed)[1:]
    judged_s = packed_s - 2.0 * jnp.floor(packed_s / 2.0)
    rel_s = jnp.floor(packed_s / 2.0) - _PACK_OFFSET
    binrel = jnp.where(rel_s >= relevance_level, 1.0, 0.0)
    cum_rel = jnp.cumsum(binrel, axis=-1)
    return SortedBatch(
        rel=rel_s,
        binrel=binrel,
        judged=judged_s,
        mask=jnp.ones_like(rel_s),
        cum_rel=cum_rel,
        ideal_rel=batch.ideal_rel,
        n_rel=batch.n_rel,
        n_judged_nonrel=batch.n_judged_nonrel,
        n_ret=jnp.sum(mask.astype(jnp.float32), axis=-1),
        query_mask=batch.query_mask,
    )


# ---------------------------------------------------------------------------
# Individual measures (each: SortedBatch -> [Q] f32).
# ---------------------------------------------------------------------------


def _ranks(d: int) -> jax.Array:
    return jnp.arange(1, d + 1, dtype=jnp.float32)


def _safe_div(num, den):
    return jnp.where(den > 0, num / jnp.maximum(den, 1e-30), 0.0)


def _at_rank(cum: jax.Array, k: int) -> jax.Array:
    """cum value at 1-based rank k (clipped to the retrieved-depth D)."""
    d = cum.shape[-1]
    return cum[..., min(k, d) - 1]


def average_precision(s: SortedBatch) -> jax.Array:
    d = s.binrel.shape[-1]
    prec = s.cum_rel / _ranks(d)
    ap = jnp.sum(s.binrel * prec, axis=-1)
    return _safe_div(ap, s.n_rel)


def gm_map_contrib(s: SortedBatch) -> jax.Array:
    """Per-query geometric-MAP contribution: ``log(max(AP, GM_MIN))``.

    trec_eval's ``gm_map`` accumulates exactly this per query and prints only
    the summary ``exp(sum / num_q)``; the clip keeps zero-AP queries from
    sending the geometric mean to 0.
    """
    return jnp.log(jnp.maximum(average_precision(s), GM_MIN))


def map_cut(s: SortedBatch, k: int) -> jax.Array:
    d = s.binrel.shape[-1]
    within = (_ranks(d) <= k).astype(jnp.float32)
    prec = s.cum_rel / _ranks(d)
    ap = jnp.sum(s.binrel * prec * within, axis=-1)
    return _safe_div(ap, s.n_rel)


def precision_at(s: SortedBatch, k: int) -> jax.Array:
    # trec_eval always divides by k, even when fewer than k docs were retrieved.
    return _at_rank(s.cum_rel, k) / float(k)


def recall_at(s: SortedBatch, k: int) -> jax.Array:
    return _safe_div(_at_rank(s.cum_rel, k), s.n_rel)


def success_at(s: SortedBatch, k: int) -> jax.Array:
    return (_at_rank(s.cum_rel, k) > 0).astype(jnp.float32)


def reciprocal_rank(s: SortedBatch) -> jax.Array:
    d = s.binrel.shape[-1]
    any_rel = jnp.sum(s.binrel, axis=-1) > 0
    first = jnp.argmax(s.binrel, axis=-1).astype(jnp.float32) + 1.0
    return jnp.where(any_rel, 1.0 / first, 0.0)


def r_precision(s: SortedBatch) -> jax.Array:
    d = s.cum_rel.shape[-1]
    idx = jnp.clip(s.n_rel.astype(jnp.int32), 1, d) - 1
    at_r = jnp.take_along_axis(s.cum_rel, idx[:, None], axis=-1)[:, 0]
    return _safe_div(at_r, s.n_rel)


def bpref(s: SortedBatch) -> jax.Array:
    """trec_eval bpref: judged-only preference measure."""
    judged_nonrel = s.judged * (1.0 - s.binrel)
    # judged non-relevant docs ranked strictly above each position (exclusive).
    nr_above = jnp.cumsum(judged_nonrel, axis=-1) - judged_nonrel
    r = s.n_rel[:, None]
    n = s.n_judged_nonrel[:, None]
    denom = jnp.minimum(r, n)
    bounded = jnp.minimum(nr_above, r)
    term = jnp.where(nr_above > 0, 1.0 - _safe_div(bounded, denom), 1.0)
    total = jnp.sum(term * s.binrel, axis=-1)
    return _safe_div(total, s.n_rel)


def _discounts(d: int) -> jax.Array:
    return 1.0 / jnp.log2(_ranks(d) + 1.0)


def dcg(s: SortedBatch, k: int | None = None) -> jax.Array:
    """trec_eval DCG: linear gain rel / log2(rank + 1)."""
    d = s.rel.shape[-1]
    disc = _discounts(d)
    gains = jnp.maximum(s.rel, 0.0) * disc  # trec_eval: negative rels gain 0
    if k is not None:
        gains = gains * (_ranks(d) <= k).astype(jnp.float32)
    return jnp.sum(gains, axis=-1)


def ideal_dcg(s: SortedBatch, k: int | None = None) -> jax.Array:
    j = s.ideal_rel.shape[-1]
    disc = _discounts(j)
    gains = jnp.maximum(s.ideal_rel, 0.0) * disc
    if k is not None:
        gains = gains * (_ranks(j) <= k).astype(jnp.float32)
    return jnp.sum(gains, axis=-1)


def ndcg(s: SortedBatch) -> jax.Array:
    return _safe_div(dcg(s), ideal_dcg(s))


def ndcg_cut(s: SortedBatch, k: int) -> jax.Array:
    return _safe_div(dcg(s, k), ideal_dcg(s, k))


def iprec_at_recall(s: SortedBatch, level: float) -> jax.Array:
    """Interpolated precision at a recall level (11-pt PR curve point)."""
    d = s.cum_rel.shape[-1]
    prec = s.cum_rel / _ranks(d)
    # Reverse running max: best precision achievable at this rank or deeper.
    rev_max = jnp.flip(
        jax.lax.cummax(jnp.flip(prec, axis=-1), axis=prec.ndim - 1), axis=-1)
    target = jnp.ceil(level * s.n_rel)[:, None]
    # First rank whose relevant-count reaches the target.
    reached = s.cum_rel >= jnp.maximum(target, 0.0)
    any_reach = jnp.any(reached, axis=-1)
    first_idx = jnp.argmax(reached, axis=-1)
    val = jnp.take_along_axis(rev_max, first_idx[:, None], axis=-1)[:, 0]
    val = jnp.where(any_reach, val, 0.0)
    return jnp.where(s.n_rel > 0, val, 0.0)


def num_ret(s: SortedBatch) -> jax.Array:
    return s.n_ret


def num_rel(s: SortedBatch) -> jax.Array:
    return s.n_rel


def num_rel_ret(s: SortedBatch) -> jax.Array:
    return s.cum_rel[:, -1]


def judged_at(s: SortedBatch, k: int) -> jax.Array:
    """Judged@k: fraction of the top k that appears in the qrels.

    Like trec_eval's P@k, the denominator is always k — queries retrieving
    fewer than k documents are penalized, not renormalized.
    """
    cum_judged = jnp.cumsum(s.judged, axis=-1)
    return _at_rank(cum_judged, k) / float(k)


def rbp(s: SortedBatch, p: float) -> jax.Array:
    """Rank-biased precision (Moffat & Zobel): ``(1-p)·Σ rel_i·p^(i-1)``.

    Binary relevance (>= the relevance level), geometric rank discount with
    persistence ``p``.  Documents beyond the retrieved depth contribute 0,
    i.e. this is the base RBP score without the residual.
    """
    d = s.binrel.shape[-1]
    weights = (1.0 - p) * jnp.power(p, _ranks(d) - 1.0)
    return jnp.sum(s.binrel * weights, axis=-1)


def err_at(s: SortedBatch, k: int) -> jax.Array:
    """Expected reciprocal rank at k (Chapelle et al.'s cascade model).

    ``ERR@k = Σ_{i<=k} (1/i) · R_i · Π_{j<i} (1 − R_j)`` with stop
    probability ``R_i = (2^max(rel_i, 0) − 1) / 2^G``.  ``G`` is the
    per-query maximum qrel grade (min 1) — each query's own grade scale
    normalizes its gains, the convention documented in docs/MEASURES.md.
    Unjudged documents have rel 0, hence stop probability 0.
    """
    d = s.rel.shape[-1]
    kk = min(int(k), d)
    g = jnp.maximum(s.ideal_rel[:, 0], 1.0)[:, None]
    # Static slice to the cutoff BEFORE reducing: the reduction width is
    # then k regardless of document padding, so the top-k path (d == k) and
    # the full-sort path produce bit-identical sums (no reassociation).
    rel_k = s.rel[:, :kk]
    stop = (jnp.power(2.0, jnp.maximum(rel_k, 0.0)) - 1.0) / jnp.power(2.0, g)
    no_stop = jnp.cumprod(1.0 - stop, axis=-1)
    prior = jnp.concatenate(
        [jnp.ones_like(no_stop[:, :1]), no_stop[:, :-1]], axis=-1)
    return jnp.sum(stop * prior / _ranks(kk), axis=-1)


# ---------------------------------------------------------------------------
# Measure-set plumbing (delegated to the declarative registry).
# ---------------------------------------------------------------------------


def parse_measures(measures: Sequence[str]) -> Tuple[Tuple[str, Tuple[float, ...]], ...]:
    """Normalize measure strings (either dialect) into (family, params).

    Accepts trec_eval-dialect family names (``"ndcg_cut"`` → all default
    cutoffs), explicit params (``"P.5,10"``), pytrec_eval output-style ids
    (``"P_5"``, ``"ndcg_cut_10"``), and ir-measures-dialect strings
    (``"nDCG@10"``, ``"P@5"``, ``"RBP(p=0.8)"``).  Selectors naming the
    same family merge into one entry with the union of their params
    (sorted), so a repeated measure list like ``("P_5", "P.5,10", "P@20")``
    yields each output key exactly once — the contract the sweep/compare
    CLI's repeatable ``-m`` flag relies on.  Delegates to
    :mod:`repro.core.registry`; ``rel=`` annotations require the
    level-aware :func:`registry.canonicalize`.
    """
    return registry.parse_measures(measures)


def family_keys(fam: str, params: Tuple[float, ...]) -> Tuple[str, ...]:
    """Output keys for one parsed (family, params) entry (registry rules)."""
    return registry.family_keys(fam, params)


def measure_keys(measures: Sequence[str]) -> Tuple[str, ...]:
    """The pytrec_eval-style output keys produced for a measure set."""
    return registry.measure_keys(measures)


def _mask_queries(out: Dict[str, jax.Array], s: SortedBatch) -> Dict[str, jax.Array]:
    zero = jnp.zeros_like(s.n_rel)
    qm = s.query_mask
    return {k: jnp.where(qm, v, zero) for k, v in out.items()}


def compute_measures(
    batch: EvalBatch,
    measures: Tuple[Tuple[str, Tuple[float, ...]], ...],
    relevance_level: float = 1.0,
    judged_only: bool = False,
) -> Dict[str, jax.Array]:
    """Compute every requested measure for every query in the batch.

    ``measures`` must be the output of :func:`parse_measures` (hashable, so
    this function can be jitted with ``static_argnums``).  Column dispatch
    is table-driven by :mod:`repro.core.registry`.  Returns a dict of
    pytrec_eval-style keys to ``[Q]`` float32 vectors.
    """
    s = sort_batch(batch, relevance_level, judged_only)
    return _mask_queries(registry.apply_columns(s, measures), s)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def compute_measures_jit(batch, measures, relevance_level=1.0,
                         judged_only=False):
    # Lazy import: repro.kernels pulls in this module at its own import time.
    # bucketing itself is dependency-free, so the in-body import is cheap and
    # cycle-safe; the call runs at trace time only (once per signature).
    from repro.kernels import bucketing
    bucketing.record_trace("measure_core")
    return compute_measures(batch, measures, relevance_level, judged_only)


def compute_measures_topk(
    batch: EvalBatch,
    measures: Tuple[Tuple[str, Tuple[float, ...]], ...],
    relevance_level: float = 1.0,
    judged_only: bool = False,
) -> Dict[str, jax.Array]:
    """Depth-bounded measure computation via the top-k kernel.

    Requires every family in ``measures`` to be depth-bounded
    (``registry.topk_depth(measures) is not None``) AND the batch to use the
    **tiebreak-column layout**: each document scattered at column ==
    tiebreak rank (``RelevanceEvaluator.batch_from_buffer(...,
    topk_layout=True)``).  Under that layout the top-k kernel's
    smaller-index-wins tie rule IS trec_eval's smaller-tiebreak-wins rule,
    so the selected prefix equals the full sort's first k rows exactly, and
    every bounded column is bit-identical to :func:`compute_measures` —
    without ever sorting the full document axis.
    """
    from repro.kernels import ops

    depth = registry.topk_depth(measures)
    assert depth is not None, "top-k path needs depth-bounded measures"
    q, d = batch.scores.shape
    k = min(depth, d)
    eff = batch.mask & batch.judged if judged_only else batch.mask
    scores_m = jnp.where(eff, batch.scores, -jnp.inf)
    _, idx = ops.topk(scores_m, k)
    in_range = (idx >= 0) & (idx < d)
    idx_c = jnp.clip(idx, 0, d - 1)
    valid = in_range & jnp.take_along_axis(eff, idx_c, axis=-1)
    rel_s = jnp.where(valid, jnp.take_along_axis(batch.rel, idx_c, axis=-1),
                      0.0)
    judged_s = jnp.where(
        valid, jnp.take_along_axis(batch.judged, idx_c, axis=-1),
        False).astype(jnp.float32)
    binrel = jnp.where(rel_s >= relevance_level, 1.0, 0.0) * valid
    s = SortedBatch(
        rel=rel_s,
        binrel=binrel,
        judged=judged_s,
        mask=jnp.ones_like(rel_s),
        cum_rel=jnp.cumsum(binrel, axis=-1),
        ideal_rel=batch.ideal_rel,
        n_rel=batch.n_rel,
        n_judged_nonrel=batch.n_judged_nonrel,
        n_ret=jnp.sum(eff.astype(jnp.float32), axis=-1),
        query_mask=batch.query_mask,
    )
    return _mask_queries(registry.apply_columns(s, measures), s)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def compute_measures_topk_jit(batch, measures, relevance_level=1.0,
                              judged_only=False):
    from repro.kernels import bucketing
    bucketing.record_trace("measure_core_topk")
    return compute_measures_topk(batch, measures, relevance_level,
                                 judged_only)


def aggregate(per_query: Dict[str, jax.Array], query_mask: jax.Array) -> Dict[str, jax.Array]:
    """Mean over real queries (trec_eval 'all' row)."""
    n = jnp.maximum(jnp.sum(query_mask.astype(jnp.float32)), 1.0)
    return {k: jnp.sum(v * query_mask, axis=-1) / n for k, v in per_query.items()}


def finalize_aggregates(aggs: Dict[str, float]) -> Dict[str, float]:
    """Turn averaged per-query columns into user-facing summary values.

    Arithmetic-mean measures pass through unchanged; aggregate-only
    geometric measures (``gm_map``) arrive as the mean of per-query log
    contributions and leave as ``exp(mean)`` — trec_eval's geometric mean.
    """
    return {k: float(np.exp(v)) if k in AGGREGATE_ONLY_MEASURES else v
            for k, v in aggs.items()}


# ---------------------------------------------------------------------------
# Batch construction helpers.
# ---------------------------------------------------------------------------


def batch_from_flat(
    *,
    qidx: np.ndarray,
    col: np.ndarray,
    scores: np.ndarray,
    tiebreak: np.ndarray,
    rel: np.ndarray,
    judged: np.ndarray,
    ideal_rows: np.ndarray,
    n_rel: np.ndarray,
    n_judged_nonrel: np.ndarray,
    n_queries: int,
    q_pad: int,
    d_pad: int,
    j_pad: int,
    counts: np.ndarray | None = None,
) -> EvalBatch:
    """Scatter flat per-document arrays into a padded ``EvalBatch``.

    The host-side counterpart of :func:`batch_from_dense`: all per-document
    vectors are flat (concatenated in query order), with ``(qidx, col)``
    giving each document's position in the padded ``[q_pad, d_pad]`` tensors.
    One fancy-indexed scatter per field — no Python loop over queries or
    documents.  When ``counts`` shows every query retrieved the same depth
    (the fixed-depth case that dominates real runs and the RQ1 grid), the
    scatter degenerates to a reshape+copy, and the validity mask is a
    broadcast compare either way.  Numpy in, so the jitted measure core sees
    a single host→device transfer.
    """
    scores2 = np.zeros((q_pad, d_pad), dtype=np.float32)
    tiebreak2 = np.zeros((q_pad, d_pad), dtype=np.int32)
    rel2 = np.zeros((q_pad, d_pad), dtype=np.float32)
    judged2 = np.zeros((q_pad, d_pad), dtype=bool)
    mask2 = np.zeros((q_pad, d_pad), dtype=bool)
    total = qidx.shape[0]
    uniform = (counts is not None and n_queries
               and int(counts.min()) == int(counts.max()))
    if uniform:
        # the reshape shortcut assumes query-major flat order; verify that
        # (qidx, col) really is the implied layout rather than trusting it
        d = int(counts[0])
        seq = np.arange(total, dtype=np.int64)
        uniform = (np.array_equal(qidx, seq // d)
                   and np.array_equal(col, seq % d))
    if uniform:
        d = int(counts[0])
        scores2[:n_queries, :d] = scores.reshape(n_queries, d)
        tiebreak2[:n_queries, :d] = tiebreak.reshape(n_queries, d)
        rel2[:n_queries, :d] = rel.reshape(n_queries, d)
        judged2[:n_queries, :d] = judged.reshape(n_queries, d)
        mask2[:n_queries, :d] = True
    else:
        scores2[qidx, col] = scores
        tiebreak2[qidx, col] = tiebreak
        rel2[qidx, col] = rel
        judged2[qidx, col] = judged
        if counts is not None:
            mask2[:n_queries] = (np.arange(d_pad, dtype=np.int64)[None, :]
                                 < counts[:, None])
        else:
            mask2[qidx, col] = True

    ideal = np.zeros((q_pad, j_pad), dtype=np.float32)
    w = min(j_pad, ideal_rows.shape[1])
    ideal[:n_queries, :w] = ideal_rows[:, :w]
    n_rel2 = np.zeros((q_pad,), dtype=np.float32)
    n_rel2[:n_queries] = n_rel
    n_nonrel2 = np.zeros((q_pad,), dtype=np.float32)
    n_nonrel2[:n_queries] = n_judged_nonrel
    qmask = np.zeros((q_pad,), dtype=bool)
    qmask[:n_queries] = True
    return EvalBatch(
        scores=scores2, tiebreak=tiebreak2, rel=rel2, judged=judged2,
        mask=mask2, ideal_rel=ideal, n_rel=n_rel2,
        n_judged_nonrel=n_nonrel2, query_mask=qmask,
    )


# ---------------------------------------------------------------------------
# Dense entry point for in-loop evaluation (no dicts, pure device).
# ---------------------------------------------------------------------------


def batch_from_dense(
    scores: jax.Array,
    rel: jax.Array,
    mask: jax.Array | None = None,
    judged: jax.Array | None = None,
    query_mask: jax.Array | None = None,
    tiebreak: jax.Array | None = None,
    relevance_level: float = 1.0,
) -> EvalBatch:
    """Build an EvalBatch from dense score/relevance tensors.

    Assumes the candidate set *is* the judged set (standard for in-loop model
    evaluation where every candidate has a known label).  The ideal ranking is
    derived by sorting ``rel`` — correct because all judged docs are present.
    """
    q, d = scores.shape
    if mask is None:
        mask = jnp.ones((q, d), dtype=bool)
    if judged is None:
        judged = mask
    if query_mask is None:
        query_mask = jnp.ones((q,), dtype=bool)
    if tiebreak is None:
        tiebreak = jnp.broadcast_to(jnp.arange(d, dtype=jnp.int32), (q, d))
    # unjudged docs are non-relevant by definition (trec_eval): zero their
    # rel so every engine sees consistent inputs
    rel = rel.astype(jnp.float32) * mask * judged
    ideal = -jnp.sort(-rel, axis=-1)
    binrel = (rel >= relevance_level) & mask & (judged > 0)
    n_rel = jnp.sum(binrel.astype(jnp.float32), axis=-1)
    n_nonrel = jnp.sum((judged & mask).astype(jnp.float32), axis=-1) - n_rel
    return EvalBatch(
        scores=scores.astype(jnp.float32),
        tiebreak=tiebreak,
        rel=rel,
        judged=judged,
        mask=mask,
        ideal_rel=ideal,
        n_rel=n_rel,
        n_judged_nonrel=n_nonrel,
        query_mask=query_mask,
    )
