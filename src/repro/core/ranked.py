"""Rank-reduction evaluation: measures from judged-document ranks only.

Beyond-paper optimization (EXPERIMENTS.md §Perf iteration C).  Every
trec_eval measure is a function of (a) the *ranks of the judged documents*
(≤ J per query, typically ≪ D) and (b) per-query scalars (R, N, n_ret).
Unjudged documents only matter through how many of them outrank each judged
one.  So instead of sorting the D-deep ranking and running full-width
cumulative passes (O(D log D) compute, many HBM passes — what both trec_eval
and the batched `core.measures` engine do), compute

    rank_j = 1 + Σ_d  mask_d · [ s_d > s_j  or  (s_d = s_j and tb_d < tb_j) ]

— one fused compare-reduce over the scores (a single HBM read of [Q, D],
VPU-only, trec_eval tie semantics exact) — and reconstruct every measure
from the [Q, J] rank matrix with O(J²) pairwise work.

Exactness: verified against `core.measures` in tests/test_ranked.py for the
full measure set, including ties, unretrieved judged docs, and padding.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import measures as M

INF_RANK = 2.0**30  # rank assigned to judged docs not retrieved


class RankedBatch(NamedTuple):
    """Inputs for rank-reduction evaluation (axes: Q queries, D docs in the
    run, J judged docs per query — all padded)."""

    scores: jax.Array  # [Q, D] f32 — retrieval scores of the run
    tiebreak: jax.Array  # [Q, D] i32 — trec_eval tie order (smaller wins)
    mask: jax.Array  # [Q, D] bool — real run entries
    judged_scores: jax.Array  # [Q, J] f32 — scores of judged docs in the run
    judged_tiebreak: jax.Array  # [Q, J] i32
    judged_rel: jax.Array  # [Q, J] f32 — relevance judgments
    judged_retrieved: jax.Array  # [Q, J] bool — judged doc appears in run
    judged_mask: jax.Array  # [Q, J] bool — real judged entries
    ideal_rel: jax.Array  # [Q, J'] f32 — qrel judgments sorted desc (IDCG)
    n_rel: jax.Array  # [Q] f32
    n_judged_nonrel: jax.Array  # [Q] f32
    query_mask: jax.Array  # [Q] bool


def from_eval_batch(batch: M.EvalBatch, j: int | None = None) -> RankedBatch:
    """Build a RankedBatch from a dense EvalBatch (judged docs extracted by
    relevance-descending top-J; used by tests and the evaluator fast path)."""
    q, d = batch.scores.shape
    j = j or batch.ideal_rel.shape[-1]
    judged_key = jnp.where(batch.judged & batch.mask, 1.0, 0.0)
    # order judged docs first (stable by index for determinism)
    _, idx = jax.lax.top_k(judged_key + jnp.linspace(1e-3, 0.0, d)[None, :],
                           j)
    take = lambda a: jnp.take_along_axis(a, idx, axis=-1)
    judged_mask = take(batch.judged & batch.mask)
    return RankedBatch(
        scores=batch.scores, tiebreak=batch.tiebreak, mask=batch.mask,
        judged_scores=take(batch.scores),
        judged_tiebreak=take(batch.tiebreak),
        judged_rel=take(batch.rel) * judged_mask,
        judged_retrieved=judged_mask,
        judged_mask=judged_mask,
        ideal_rel=batch.ideal_rel,
        n_rel=batch.n_rel, n_judged_nonrel=batch.n_judged_nonrel,
        query_mask=batch.query_mask)


def judged_ranks(rb: RankedBatch) -> jax.Array:
    """[Q, J] 1-based ranks of judged docs in the run (INF if unretrieved).

    The [Q, J, D] comparison never materializes: XLA fuses the selects into
    the reduction, so the scores tensor is read once.
    """
    s = rb.scores[:, None, :]
    tb = rb.tiebreak[:, None, :]
    js = rb.judged_scores[:, :, None]
    jtb = rb.judged_tiebreak[:, :, None]
    above = (s > js) | ((s == js) & (tb < jtb))
    above = above & rb.mask[:, None, :]
    ranks = 1.0 + jnp.sum(above, axis=-1, dtype=jnp.float32)
    return jnp.where(rb.judged_retrieved, ranks, INF_RANK)


def compute_measures_ranked(
    rb: RankedBatch,
    measures: Tuple[Tuple[str, Tuple[float, ...]], ...],
    relevance_level: float = 1.0,
) -> Dict[str, jax.Array]:
    """Same contract as measures.compute_measures, via rank reduction."""
    ranks = judged_ranks(rb)  # [Q, J]
    jm = rb.judged_mask.astype(jnp.float32)
    retrieved = rb.judged_retrieved.astype(jnp.float32) * jm
    rel = (rb.judged_rel >= relevance_level).astype(jnp.float32) * jm
    rel_ret = rel * retrieved
    nonrel_ret = (1.0 - rel) * retrieved  # judged non-relevant, retrieved
    gains = jnp.maximum(rb.judged_rel, 0.0) * jm

    n_ret = jnp.sum(rb.mask.astype(jnp.float32), axis=-1)
    r = rb.n_rel
    inv_r = jnp.where(r > 0, 1.0 / jnp.maximum(r, 1e-30), 0.0)

    # pairwise [Q, J, J]: how many judged-X docs rank at-or-above each doc
    le = (ranks[:, :, None] <= ranks[:, None, :]).astype(jnp.float32)
    lt = (ranks[:, :, None] < ranks[:, None, :]).astype(jnp.float32)
    # cnt_i = #rel-retrieved docs with rank ≤ rank_i (includes self if rel)
    cnt = jnp.einsum("qj,qji->qi", rel_ret, le)
    nonrel_above = jnp.einsum("qj,qji->qi", nonrel_ret, lt)

    finite = (ranks < INF_RANK).astype(jnp.float32)
    prec_at_i = jnp.where(finite > 0, cnt / jnp.maximum(ranks, 1.0), 0.0)

    out: Dict[str, jax.Array] = {}

    def rel_in_top(k):
        return jnp.sum(rel_ret * (ranks <= k), axis=-1)

    for fam, params in measures:
        if fam == "map":
            ap = jnp.sum(rel_ret * prec_at_i, axis=-1)
            out["map"] = ap * inv_r
        elif fam == "map_cut":
            for k in params:
                apk = jnp.sum(rel_ret * prec_at_i * (ranks <= k), axis=-1)
                out[f"map_cut_{int(k)}"] = apk * inv_r
        elif fam == "ndcg":
            dcg = jnp.sum(gains * retrieved
                          / jnp.log2(jnp.minimum(ranks, INF_RANK) + 1.0),
                          axis=-1)
            idcg = _ideal_dcg(rb, None)
            out["ndcg"] = jnp.where(idcg > 0, dcg / jnp.maximum(idcg, 1e-30),
                                    0.0)
        elif fam == "ndcg_cut":
            for k in params:
                dcg = jnp.sum(gains * retrieved * (ranks <= k)
                              / jnp.log2(jnp.minimum(ranks, INF_RANK) + 1.0),
                              axis=-1)
                idcg = _ideal_dcg(rb, int(k))
                out[f"ndcg_cut_{int(k)}"] = jnp.where(
                    idcg > 0, dcg / jnp.maximum(idcg, 1e-30), 0.0)
        elif fam == "P":
            for k in params:
                out[f"P_{int(k)}"] = rel_in_top(k) / float(k)
        elif fam == "recall":
            for k in params:
                out[f"recall_{int(k)}"] = rel_in_top(k) * inv_r
        elif fam == "success":
            for k in params:
                out[f"success_{int(k)}"] = (rel_in_top(k) > 0).astype(
                    jnp.float32)
        elif fam == "recip_rank":
            first = jnp.min(jnp.where(rel_ret > 0, ranks, INF_RANK), axis=-1)
            out["recip_rank"] = jnp.where(first < INF_RANK, 1.0 / first, 0.0)
        elif fam == "Rprec":
            out["Rprec"] = jnp.sum(rel_ret * (ranks <= r[:, None]), axis=-1
                                   ) * inv_r
        elif fam == "bpref":
            denom = jnp.maximum(jnp.minimum(r, rb.n_judged_nonrel), 1e-30)
            term = jnp.where(
                nonrel_above > 0,
                1.0 - jnp.minimum(nonrel_above, r[:, None]) / denom[:, None],
                1.0)
            out["bpref"] = jnp.sum(term * rel_ret, axis=-1) * inv_r
        elif fam == "iprec_at_recall":
            for lv in params:
                target = jnp.ceil(lv * r)[:, None]
                ok = (cnt >= jnp.maximum(target, 0.0)) & (rel_ret > 0)
                val = jnp.max(jnp.where(ok, prec_at_i, 0.0), axis=-1)
                out[f"iprec_at_recall_{lv:.2f}"] = jnp.where(r > 0, val, 0.0)
        elif fam == "num_ret":
            out["num_ret"] = n_ret
        elif fam == "num_rel":
            out["num_rel"] = r
        elif fam == "num_rel_ret":
            out["num_rel_ret"] = jnp.sum(rel_ret, axis=-1)
        elif fam == "judged":
            # every doc in the top k that is judged IS a row of this matrix
            for k in params:
                out[f"judged_{int(k)}"] = jnp.sum(
                    retrieved * (ranks <= k), axis=-1) / float(k)
        elif fam == "rbp":
            for p in params:
                w = jnp.power(p, jnp.minimum(ranks, INF_RANK) - 1.0)
                out[f"rbp_{p:.2f}"] = (1.0 - p) * jnp.sum(rel_ret * w,
                                                          axis=-1)
        elif fam == "err":
            # cascade model: unjudged docs have stop probability 0, so the
            # prior over each judged doc is the product over the *judged*
            # docs ranked above it — a [Q, J, J] pairwise log-sum
            g = jnp.maximum(rb.ideal_rel[:, 0], 1.0)[:, None]
            stop = (jnp.power(2.0, jnp.maximum(rb.judged_rel, 0.0)) - 1.0) \
                / jnp.power(2.0, g) * retrieved
            log_keep = jnp.log1p(-stop)
            prior = jnp.exp(jnp.einsum("qj,qji->qi", log_keep, lt))
            term = stop * prior / jnp.maximum(ranks, 1.0)
            for k in params:
                out[f"err_{int(k)}"] = jnp.sum(term * (ranks <= k), axis=-1)
        else:  # pragma: no cover
            raise ValueError(fam)
    zero = jnp.zeros_like(r)
    return {k: jnp.where(rb.query_mask, v, zero) for k, v in out.items()}


def _ideal_dcg(rb: RankedBatch, k: int | None) -> jax.Array:
    """Ideal DCG from the full qrel judgments (already sorted descending)."""
    ideal = jnp.maximum(rb.ideal_rel, 0.0)
    j = ideal.shape[-1]
    ranks = jnp.arange(1, j + 1, dtype=jnp.float32)
    disc = 1.0 / jnp.log2(ranks + 1.0)
    if k is not None:
        disc = disc * (ranks <= k)
    return jnp.sum(ideal * disc, axis=-1)
