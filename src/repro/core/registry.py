"""The declarative measure registry: one table drives every consumer.

Every measure the framework understands is a :class:`MeasureSpec` row in
:data:`REGISTRY`, declaring

* its **trec_eval spelling** (the ``family`` id and output-key format:
  ``map``, ``ndcg_cut_10``, ``iprec_at_recall_0.10``, ``rbp_0.80``),
* its **ir-measures spelling(s)** (``AP``, ``nDCG@10``, ``IPrec@0.10``,
  ``RBP(p=0.8)``) including accepted aliases,
* its **parameterization** (integer cutoffs, recall levels, the RBP
  persistence ``p``, and the global ``rel=`` relevance level),
* its **per-query column function** over ``measures.SortedBatch``
  (resolved lazily by attribute name, so this module stays import-clean),
* its **aggregation kind** (arithmetic mean, sum, or geometric
  aggregate-only), integer formatting, the contribution a query missing
  from the run makes under trec_eval ``-c``, and
* its **ranking-depth bound** — whether the column only reads a bounded
  prefix of the ranking (``P@k`` et al.), which lets the evaluator route
  the batch through the top-k kernel instead of a full document sort.

Everything else derives from this table: ``parse_measures`` /
``measure_keys`` in :mod:`repro.core.measures`, the CLI's print order and
int/sum/aggregate-only sets, the serve layer's measure validation, the
sweep/compare key handling, and the auto-generated ``docs/MEASURES.md``
table (``python -m repro.core.registry --check docs/MEASURES.md`` is the
CI drift gate).  Adding a measure is one row here, one column function in
``measures.py``, and one conformance fixture.

Both dialects parse to the same canonical keys:

>>> canonicalize(("nDCG@10", "map"))[0]
(('map', ()), ('ndcg_cut', (10.0,)))
>>> canonicalize(("AP(rel=2)",))
((('map', ()),), 2.0)
>>> render_ir("ndcg_cut_10"), render_ir("rbp_0.80"), render_ir("map")
('nDCG@10', 'RBP(p=0.8)', 'AP')
>>> render_trec("nDCG@10")
'ndcg_cut_10'
"""

from __future__ import annotations

import re
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

# -- shared measure constants (single source of truth; ``measures`` re-exports)

DEFAULT_CUTOFFS: Tuple[int, ...] = (5, 10, 15, 20, 30, 100, 200, 500, 1000)
SUCCESS_CUTOFFS: Tuple[int, ...] = (1, 5, 10)
IPREC_LEVELS: Tuple[float, ...] = tuple(round(0.1 * i, 1) for i in range(11))

#: trec_eval's MIN_GEO_MEAN: per-query AP is clipped to this before the log
#: so queries with AP == 0 do not collapse the geometric mean to 0.
GM_MIN: float = 1e-5

#: default RBP persistence (Moffat & Zobel's common choice)
DEFAULT_RBP_P: float = 0.8


class MeasureError(ValueError):
    """A measure string failed to parse/resolve (maps to wire code 'invalid')."""


class MeasureSpec(NamedTuple):
    """One measure family: both spellings, parameterization, and behavior."""

    family: str                 # canonical trec_eval family id / key stem
    ir_name: str                # canonical ir-measures spelling
    column: str                 # column fn attribute on repro.core.measures
    description: str            # one-liner for docs/MEASURES.md
    ir_aliases: Tuple[str, ...] = ()   # extra accepted ir spellings
    param_kind: str = ""        # "" | "cutoff" | "level" | "p"
    default_params: Tuple[float, ...] = ()
    agg: str = "mean"           # "mean" | "sum" | "geometric"
    integer: bool = False       # CLI prints as integer (trec_eval %ld)
    aggregate_only: bool = False  # summary-only (no per-query lines)
    missing: str = "zero"       # -c contribution: "zero"|"n_rel"|"log_gm_min"
    depth: str = "full"         # "full" | "param" | "none" (ranking prefix)
    cut_family: Optional[str] = None   # ir "@k" redirects to this family


#: Declaration order IS the trec_eval print order (``cli.FAMILY_ORDER``).
REGISTRY: Tuple[MeasureSpec, ...] = (
    MeasureSpec("num_ret", "NumRet", "num_ret",
                "retrieved documents", agg="sum", integer=True, depth="none"),
    MeasureSpec("num_rel", "NumRel", "num_rel",
                "relevant documents in the qrels (R)", agg="sum",
                integer=True, missing="n_rel", depth="none"),
    MeasureSpec("num_rel_ret", "NumRelRet", "num_rel_ret",
                "relevant retrieved documents", agg="sum", integer=True),
    MeasureSpec("map", "AP", "average_precision",
                "mean average precision", ir_aliases=("MAP",),
                cut_family="map_cut"),
    MeasureSpec("gm_map", "GMAP", "gm_map_contrib",
                "geometric-mean MAP (AP clipped at GM_MIN)",
                agg="geometric", aggregate_only=True, missing="log_gm_min"),
    MeasureSpec("Rprec", "Rprec", "r_precision",
                "precision at rank R"),
    MeasureSpec("bpref", "Bpref", "bpref",
                "judged-only preference measure"),
    MeasureSpec("recip_rank", "RR", "reciprocal_rank",
                "reciprocal rank of the first relevant document",
                ir_aliases=("MRR",)),
    MeasureSpec("iprec_at_recall", "IPrec", "iprec_at_recall",
                "interpolated precision at a recall level (11-pt PR curve)",
                param_kind="level", default_params=IPREC_LEVELS),
    MeasureSpec("P", "P", "precision_at",
                "precision at rank k (always divided by k)",
                param_kind="cutoff",
                default_params=tuple(map(float, DEFAULT_CUTOFFS)),
                depth="param"),
    MeasureSpec("recall", "R", "recall_at",
                "recall at rank k", ir_aliases=("Recall",),
                param_kind="cutoff",
                default_params=tuple(map(float, DEFAULT_CUTOFFS)),
                depth="param"),
    MeasureSpec("ndcg", "nDCG", "ndcg",
                "normalized DCG over the full ranking (linear gain)",
                cut_family="ndcg_cut"),
    MeasureSpec("ndcg_cut", "nDCG", "ndcg_cut",
                "normalized DCG at rank k", param_kind="cutoff",
                default_params=tuple(map(float, DEFAULT_CUTOFFS)),
                depth="param"),
    MeasureSpec("map_cut", "AP", "map_cut",
                "average precision at rank k", param_kind="cutoff",
                default_params=tuple(map(float, DEFAULT_CUTOFFS)),
                depth="param"),
    MeasureSpec("success", "Success", "success_at",
                "1 iff a relevant document appears in the top k",
                param_kind="cutoff",
                default_params=tuple(map(float, SUCCESS_CUTOFFS)),
                depth="param"),
    MeasureSpec("judged", "Judged", "judged_at",
                "fraction of the top k that is judged", param_kind="cutoff",
                default_params=tuple(map(float, DEFAULT_CUTOFFS)),
                depth="param"),
    MeasureSpec("rbp", "RBP", "rbp",
                "rank-biased precision with persistence p",
                param_kind="p", default_params=(DEFAULT_RBP_P,)),
    MeasureSpec("err", "ERR", "err_at",
                "expected reciprocal rank at k (cascade model, per-query "
                "max grade)", param_kind="cutoff",
                default_params=tuple(map(float, DEFAULT_CUTOFFS)),
                depth="param"),
)

SPECS: Dict[str, MeasureSpec] = {spec.family: spec for spec in REGISTRY}

#: case-insensitive ir-measures name lookup; declaration order wins, so
#: ``AP``/``nDCG`` resolve to the full-depth family (whose ``cut_family``
#: redirects ``AP@k``/``nDCG@k`` to the corresponding ``*_cut`` family).
_IR_LOOKUP: Dict[str, MeasureSpec] = {}
for _spec in REGISTRY:
    for _nm in (_spec.ir_name,) + _spec.ir_aliases:
        _IR_LOOKUP.setdefault(_nm.lower(), _spec)
del _spec, _nm

Parsed = Tuple[Tuple[str, Tuple[float, ...]], ...]

_IR_RE = re.compile(
    r"^\s*([A-Za-z][A-Za-z_]*)\s*(?:\((.*)\))?\s*(?:@(\d+(?:\.\d+)?))?\s*$")


# -- derivations -------------------------------------------------------------


def supported_families() -> frozenset:
    """Every family id (the old ``SUPPORTED_MEASURES`` frozenset, derived)."""
    return frozenset(SPECS)


def aggregate_only_families() -> frozenset:
    return frozenset(s.family for s in REGISTRY if s.aggregate_only)


def family_order() -> Tuple[str, ...]:
    """trec_eval print order == registry declaration order."""
    return tuple(s.family for s in REGISTRY)


def integer_keys() -> frozenset:
    """Keys the CLI prints as integers (all are paramless families)."""
    return frozenset(s.family for s in REGISTRY if s.integer)


def sum_families() -> frozenset:
    """Families summarized by summation rather than the mean over queries."""
    return frozenset(s.family for s in REGISTRY if s.agg == "sum")


# -- parameter / key plumbing ------------------------------------------------


def _check_param(fam: str, kind: str, value: float, origin: str) -> float:
    if kind == "cutoff":
        if value < 1 or value != int(value):
            raise MeasureError(
                f"measure {origin!r}: cutoff must be a positive integer, "
                f"got {value:g}")
    elif kind == "level":
        if not 0.0 <= value <= 1.0:
            raise MeasureError(
                f"measure {origin!r}: recall level must be in [0, 1], "
                f"got {value:g}")
    elif kind == "p":
        if not 0.0 < value < 1.0 or round(value, 2) != value:
            raise MeasureError(
                f"measure {origin!r}: persistence p must be in (0, 1) with "
                f"at most two decimals, got {value:g}")
    return float(value)


def family_keys(fam: str, params: Tuple[float, ...]) -> Tuple[str, ...]:
    """Output keys for one parsed (family, params) entry.

    Owns the pytrec_eval key-format rules: float-parameterized families
    (``iprec_at_recall``, ``rbp``) print the parameter with two decimals,
    cutoffs as integers, paramless families are their own key.
    """
    if not params:
        return (fam,)
    if SPECS[fam].param_kind in ("level", "p"):
        return tuple(f"{fam}_{p:.2f}" for p in params)
    return tuple(f"{fam}_{int(p)}" for p in params)


def split_key(key: str) -> Tuple[str, Optional[float]]:
    """Canonical output key → (family, parameter).

    >>> split_key("ndcg_cut_10"), split_key("map"), split_key("rbp_0.80")
    (('ndcg_cut', 10.0), ('map', None), ('rbp', 0.8))
    """
    spec = SPECS.get(key)
    if spec is not None and not spec.param_kind:
        return key, None
    for fam, s in SPECS.items():
        if s.param_kind and key.startswith(fam + "_"):
            try:
                value = float(key[len(fam) + 1:])
            except ValueError:
                continue
            return fam, _check_param(fam, s.param_kind, value, key)
    raise MeasureError(f"unsupported measure: {key!r}")


def _parse_trec(m: str):
    """trec_eval-dialect parse: (family, params|None) or None if not trec."""
    spec = SPECS.get(m)
    if spec is not None:
        return m, None
    for fam, s in SPECS.items():
        if s.param_kind and m.startswith(fam + "_"):
            try:
                value = float(m[len(fam) + 1:])
            except ValueError:
                return None
            return fam, (_check_param(fam, s.param_kind, value, m),)
    if "." in m:
        fam, _, arg = m.partition(".")
        s = SPECS.get(fam)
        if s is None or not s.param_kind:
            return None
        try:
            values = tuple(float(x) for x in arg.split(","))
        except ValueError:
            return None
        return fam, tuple(_check_param(fam, s.param_kind, v, m)
                          for v in values)
    return None


def _parse_ir(m: str):
    """ir-measures-dialect parse: (family, params|None, rel|None) or None."""
    mt = _IR_RE.match(m)
    if mt is None:
        return None
    name, argstr, at = mt.groups()
    spec = _IR_LOOKUP.get(name.lower())
    if spec is None:
        return None
    rel = None
    p = None
    if argstr is not None and argstr.strip():
        for part in argstr.split(","):
            key, eq, value = part.partition("=")
            key = key.strip()
            try:
                fv = float(value.strip()) if eq else None
            except ValueError:
                fv = None
            if fv is None:
                raise MeasureError(
                    f"measure {m!r}: malformed argument {part.strip()!r} "
                    f"(expected name=number)")
            if key == "rel":
                rel = fv
            elif key == "p" and spec.param_kind == "p":
                p = _check_param(spec.family, "p", fv, m)
            else:
                raise MeasureError(
                    f"measure {m!r}: unknown argument {key!r} for "
                    f"{spec.ir_name}")
    if at is not None:
        if spec.cut_family:
            spec = SPECS[spec.cut_family]
        if spec.param_kind not in ("cutoff", "level"):
            raise MeasureError(
                f"measure {m!r}: {spec.ir_name} does not take an @cutoff")
        params = (_check_param(spec.family, spec.param_kind, float(at), m),)
    elif p is not None:
        params = (p,)
    else:
        params = None
    return spec.family, params, rel


def parse_single(m: str):
    """One measure string (either dialect) → (family, params|None, rel|None).

    The trec_eval dialect is tried first (it is the canonical key space),
    the ir-measures dialect second; anything else raises
    :class:`MeasureError` naming the offending string.
    """
    trec = _parse_trec(m)
    if trec is not None:
        return trec[0], trec[1], None
    ir = _parse_ir(m)
    if ir is not None:
        return ir
    raise MeasureError(f"unsupported measure: {m!r}")


def canonicalize(measures: Sequence[str],
                 relevance_level: Optional[float] = None,
                 ) -> Tuple[Parsed, float]:
    """Measure strings in either dialect → (parsed selectors, level).

    The parsed form is the hashable ``((family, params), ...)`` tuple the
    jitted measure core takes as a static argument: families sorted by
    name, repeated same-family selectors merged with the union of their
    params (the repeatable ``-m`` contract).

    ``rel=`` annotations resolve the relevance level: all occurrences must
    agree, and an explicit non-default ``relevance_level`` (or ``-l``) must
    not contradict them.

    >>> canonicalize(("P@5", "P_10", "AP"))
    ((('P', (5.0, 10.0)), ('map', ())), 1.0)
    >>> canonicalize(("P(rel=2)@5",), relevance_level=3)
    Traceback (most recent call last):
        ...
    repro.core.registry.MeasureError: rel=2 conflicts with relevance_level=3
    """
    rels = {}
    merged: Dict[str, Tuple[float, ...]] = {}
    for m in sorted(set(str(x) for x in measures)):
        fam, params, rel = parse_single(m)
        if rel is not None:
            rels[m] = rel
        if params is None:
            params = SPECS[fam].default_params
        merged[fam] = tuple(sorted(set(merged.get(fam, ()) + params)))
    levels = sorted(set(rels.values()))
    if len(levels) > 1:
        raise MeasureError(
            "conflicting rel= levels across measures: "
            + ", ".join(f"{m} (rel={r:g})" for m, r in sorted(rels.items())))
    if levels:
        level = levels[0]
        if relevance_level is not None and float(relevance_level) != level \
                and float(relevance_level) != 1.0:
            raise MeasureError(
                f"rel={level:g} conflicts with "
                f"relevance_level={float(relevance_level):g}")
    else:
        level = float(relevance_level) if relevance_level is not None else 1.0
    return tuple(sorted(merged.items())), level


def parse_measures(measures: Sequence[str]) -> Parsed:
    """Level-agnostic canonicalization (the classic ``parse_measures``).

    Raises if a ``rel=`` annotation asks for a non-default relevance level —
    callers that support it (the evaluator, the CLI, serve registration)
    use :func:`canonicalize` and thread the level explicitly.
    """
    parsed, level = canonicalize(measures)
    if level != 1.0:
        raise MeasureError(
            f"rel={level:g} requires a relevance_level-aware caller "
            f"(pass relevance_level / -l instead)")
    return parsed


def measure_keys(measures: Sequence[str]) -> Tuple[str, ...]:
    """The pytrec_eval-style output keys produced for a measure set."""
    keys = []
    for fam, params in parse_measures(measures):
        keys.extend(family_keys(fam, params))
    return tuple(keys)


def keys_for(parsed: Parsed) -> Tuple[str, ...]:
    """Output keys for an already-parsed selector tuple."""
    keys = []
    for fam, params in parsed:
        keys.extend(family_keys(fam, params))
    return tuple(keys)


def canonical_key(measure: str) -> Tuple[str, Optional[float]]:
    """One measure string (either dialect) → exactly one canonical key.

    For single-measure call sites (the serve ``compare`` op): the string
    must resolve to a single output key, not a whole family's default grid.

    >>> canonical_key("nDCG@10")
    ('ndcg_cut_10', None)
    >>> canonical_key("AP(rel=2)")
    ('map', 2.0)
    """
    fam, params, rel = parse_single(measure)
    if params is None:
        if SPECS[fam].param_kind:
            params = SPECS[fam].default_params
            if len(params) != 1:
                raise MeasureError(
                    f"measure {measure!r} names a whole family; pick one key "
                    f"(e.g. {family_keys(fam, params[:1])[0]!r})")
        else:
            params = ()
    return family_keys(fam, params)[0], rel


# -- rendering ---------------------------------------------------------------


def render_trec(measure: str) -> str:
    """Either dialect → the canonical trec_eval output key."""
    return canonical_key(measure)[0]


def render_ir(key: str) -> str:
    """Canonical trec_eval key → the ir-measures spelling.

    >>> [render_ir(k) for k in ("recip_rank", "P_5", "iprec_at_recall_0.10")]
    ['RR', 'P@5', 'IPrec@0.10']
    """
    fam, param = split_key(key)
    spec = SPECS[fam]
    if param is None:
        return spec.ir_name
    if spec.param_kind == "p":
        return f"{spec.ir_name}(p={param:g})"
    if spec.param_kind == "level":
        return f"{spec.ir_name}@{param:.2f}"
    return f"{spec.ir_name}@{int(param)}"


def both_dialects(measure: str) -> str:
    """``'ndcg_cut_10' (ir-measures 'nDCG@10')`` — for error messages."""
    try:
        key = render_trec(measure)
        return f"{key!r} (ir-measures {render_ir(key)!r})"
    except MeasureError:
        return repr(measure)


# -- per-query column application (shared by full-sort and top-k paths) ------


def apply_columns(s, parsed: Parsed) -> Dict[str, object]:
    """Compute every requested per-query column over a ``SortedBatch``.

    The registry replacement for the old measure if-chain: each family's
    column function is resolved by name from :mod:`repro.core.measures`
    and called once per parameter (or once, paramless).
    """
    from repro.core import measures as M

    out = {}
    for fam, params in parsed:
        spec = SPECS[fam]
        fn = getattr(M, spec.column)
        if not spec.param_kind:
            out[fam] = fn(s)
        else:
            for key, p in zip(family_keys(fam, params), params):
                out[key] = fn(s, int(p) if spec.param_kind == "cutoff" else p)
    return out


# -- depth bounds (top-k routing) --------------------------------------------


def topk_depth(parsed: Parsed) -> Optional[int]:
    """Max ranking depth the measure set reads, or None if unbounded.

    ``None`` means some family needs the full ranking (full-sort path);
    an integer k means every requested column is determined by the top-k
    prefix (plus order-invariant scalars), so the evaluator may rank with
    the top-k kernel instead of sorting the whole document axis.
    """
    depth = 0
    for fam, params in parsed:
        spec = SPECS[fam]
        if spec.depth == "full":
            return None
        if spec.depth == "param":
            depth = max(depth, int(max(params)) if params else 0)
    return depth if depth > 0 else None


# -- -c missing-query contributions ------------------------------------------


def missing_contribution(key: str) -> str:
    """What a query judged in the qrels but absent from the run contributes
    under trec_eval ``-c``: ``"zero"``, ``"n_rel"`` (its R), or
    ``"log_gm_min"`` (a GM_MIN-clipped log term)."""
    return SPECS[split_key(key)[0]].missing


# -- documentation table + drift gate ----------------------------------------


def markdown_table() -> str:
    """The auto-derived registry table embedded in ``docs/MEASURES.md``."""
    rows = [
        "| trec_eval family | keys | ir-measures dialect | aggregation "
        "| description |",
        "|---|---|---|---|---|",
    ]
    for spec in REGISTRY:
        keys = family_keys(spec.family, spec.default_params)
        if len(keys) == 1:
            key_text = f"`{keys[0]}`"
        else:
            key_text = f"`{keys[0]}` … `{keys[-1]}`"
        ir = " / ".join(f"`{render_ir(k)}`" for k in (keys[0],)
                        ) + (f" … `{render_ir(keys[-1])}`"
                             if len(keys) > 1 else "")
        agg = {"mean": "mean", "sum": "sum",
               "geometric": "geometric (aggregate-only)"}[spec.agg]
        rows.append(f"| `{spec.family}` | {key_text} | {ir} | {agg} "
                    f"| {spec.description} |")
    return "\n".join(rows)


def check_docs(path: str) -> None:
    """Raise if ``path`` does not embed the current registry table verbatim."""
    with open(path) as fh:
        doc = fh.read()
    if markdown_table() not in doc:
        raise SystemExit(
            f"{path} is out of date with repro.core.registry — regenerate "
            f"its table with: PYTHONPATH=src python -m repro.core.registry "
            f"--print")


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.registry",
        description="Print or drift-check the measure registry table.")
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--print", action="store_true", dest="do_print",
                   help="print the markdown registry table")
    g.add_argument("--check", metavar="PATH",
                   help="fail unless PATH embeds the current table verbatim")
    args = ap.parse_args(argv)
    if args.do_print:
        print(markdown_table())
    else:
        check_docs(args.check)
        print(f"{args.check}: registry table up to date "
              f"({len(REGISTRY)} families)")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
