"""trec_eval ranking semantics on device.

trec_eval ignores the order of documents in the run file: documents are ranked
by decreasing retrieval score, and ties are broken by the document identifier
(descending lexicographic order — the document with the *larger* docno wins the
tie).  pytrec_eval mimics this exactly; so do we.

On device we cannot compare strings, so the evaluator precomputes, per query, a
``tiebreak`` integer for every retrieved document: the rank of its docno in
*descending* lexicographic order (0 = lexicographically largest = wins ties).
Purely-device pipelines (in-loop evaluation of model scores) use the candidate
index as the tiebreak, which is deterministic and documented.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# Large sentinel that pushes padded entries to the end of the sort.
_PAD_TIEBREAK = jnp.iinfo(jnp.int32).max


def rank_sort(scores, tiebreak, mask, *payload):
    """Sort along the last axis by (-score, tiebreak asc); padding goes last.

    Args:
      scores:   [..., D] float array of retrieval scores.
      tiebreak: [..., D] int32 array; smaller value wins ties (see module doc).
      mask:     [..., D] bool; False entries are padding and sort to the end.
      *payload: arrays of the same shape to carry through the sort.

    Returns:
      Tuple of (sorted_mask, *sorted_payload).
    """
    neg = jnp.where(mask, -scores.astype(jnp.float32), jnp.inf)
    tb = jnp.where(mask, tiebreak.astype(jnp.int32), _PAD_TIEBREAK)
    operands = (neg, tb, mask) + tuple(payload)
    out = lax.sort(operands, dimension=-1, num_keys=2, is_stable=False)
    return out[2:]


def ranks_of(scores, tiebreak, mask):
    """1-based rank of every entry under trec_eval ordering (padding gets D)."""
    d = scores.shape[-1]
    idx = jnp.broadcast_to(jnp.arange(d, dtype=jnp.int32), scores.shape)
    (_, sorted_idx) = rank_sort(scores, tiebreak, mask, idx)
    # Scatter: position p in sorted order means rank p+1 for doc sorted_idx[p].
    pos = jnp.broadcast_to(jnp.arange(d, dtype=jnp.int32), scores.shape)
    ranks = jnp.zeros(scores.shape, dtype=jnp.int32)
    ranks = jnp.put_along_axis(ranks, sorted_idx, pos + 1, axis=-1, inplace=False)
    return ranks


def gold_rank(scores, gold_index, tiebreak=None):
    """Rank (1-based) of ``gold_index`` in a score vector, trec_eval tie rules.

    Used by in-loop LM/recsys evaluation: the rank of the gold token/item in the
    model's score distribution, without sorting the whole vocabulary.

    A document ranks above gold if its score is strictly greater, or equal with
    a smaller tiebreak value.  Default tiebreak is the index itself.
    """
    d = scores.shape[-1]
    idx = jnp.arange(d, dtype=jnp.int32)
    if tiebreak is None:
        tiebreak = idx
    gold_score = jnp.take_along_axis(scores, gold_index[..., None], axis=-1)
    gold_tb = jnp.take_along_axis(
        jnp.broadcast_to(tiebreak, scores.shape), gold_index[..., None], axis=-1
    )
    above = (scores > gold_score) | (
        (scores == gold_score) & (tiebreak < gold_tb)
    )
    return jnp.sum(above, axis=-1).astype(jnp.int32) + 1
