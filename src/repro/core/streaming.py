"""Streaming (in-loop) evaluation: metrics as scan-carry sufficient statistics.

The paper's Q-learning demo computes NDCG on every RL step; at pod scale the
equivalent is computing ranking metrics inside a jitted training/serving loop
over many microbatches without a host round-trip.  Every trec_eval measure in
``core.measures`` is a per-query scalar, so the sufficient statistic for the
corpus mean is just (sum, count) — perfectly shardable: each device accumulates
its local queries, one ``psum`` at the end.

Usage inside a scan/loop::

    state = metric_init(("ndcg", "recip_rank"))
    ...
    state = metric_update(state, batch)          # batch: measures.EvalBatch
    ...
    means = metric_finalize(state)               # dict of scalars

All three are pure and jit/scan/shard_map friendly.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import measures as M

MetricState = Dict[str, jax.Array]  # keys + "__count"


def metric_init(measure_names: Tuple[str, ...]) -> MetricState:
    keys = M.measure_keys(measure_names)
    state = {k: jnp.zeros((), dtype=jnp.float32) for k in keys}
    state["__count"] = jnp.zeros((), dtype=jnp.float32)
    return state


def metric_update(
    state: MetricState,
    batch: M.EvalBatch,
    measure_names: Tuple[str, ...],
    relevance_level: float = 1.0,
) -> MetricState:
    parsed = M.parse_measures(measure_names)
    per_query = M.compute_measures(batch, parsed, relevance_level)
    qm = batch.query_mask.astype(jnp.float32)
    new = dict(state)
    for k, v in per_query.items():
        new[k] = state[k] + jnp.sum(v * qm)
    new["__count"] = state["__count"] + jnp.sum(qm)
    return new


def metric_update_run(
    state: MetricState,
    evaluator,
    buf,
    scores,
    measure_names: Tuple[str, ...],
    relevance_level: float | None = None,
) -> MetricState:
    """In-loop update from a pre-tokenized ``RunBuffer`` + fresh scores.

    The session fast path for evaluating the *same* collection every step:
    ``evaluator.tokenize_run`` (or ``buffer_from_tokens``) paid the string
    cost once; each step here is a numeric scatter
    (``evaluator.batch_from_buffer``) plus the jitted measure core.
    ``scores`` is the flat per-document score array in the buffer's query
    order.  ``relevance_level`` defaults to the evaluator's own level — the
    buffer's qrel-side statistics (R, judged-non-relevant) were counted at
    that level, so overriding it only makes sense for matching evaluators.
    """
    if relevance_level is None:
        relevance_level = evaluator.relevance_level
    batch = evaluator.batch_from_buffer(buf, scores)
    return metric_update(state, batch, measure_names, relevance_level)


def metric_update_cols(
    state: MetricState,
    per_query: Dict[str, jax.Array],
    query_mask: jax.Array,
) -> MetricState:
    """Accumulate precomputed per-query measure vectors into a MetricState.

    The fused-kernel/sharded counterpart of :func:`metric_update`: the caller
    already holds per-query ``[Q]`` vectors (e.g. columns of
    ``kernels.fused_measures``) and only needs the (sum, count) sufficient
    statistics.  Every key in ``state`` except ``"__count"`` must be present
    in ``per_query``; padded queries are excluded via ``query_mask``.  Pure
    and shard_map-friendly — pair with
    ``metric_finalize(state, axis_name=...)`` for the cross-device mean.
    """
    qm = query_mask.astype(jnp.float32)
    new = dict(state)
    for k in state:
        if k == "__count":
            continue
        new[k] = state[k] + jnp.sum(per_query[k] * qm)
    new["__count"] = state["__count"] + jnp.sum(qm)
    return new


def metric_finalize(state: MetricState, axis_name: str | None = None) -> Dict[str, jax.Array]:
    """Means over all queries; cross-device reduce if ``axis_name`` given."""
    count = state["__count"]
    sums = {k: v for k, v in state.items() if k != "__count"}
    if axis_name is not None:
        count = jax.lax.psum(count, axis_name)
        sums = {k: jax.lax.psum(v, axis_name) for k, v in sums.items()}
    denom = jnp.maximum(count, 1.0)
    return {k: v / denom for k, v in sums.items()}


# ---------------------------------------------------------------------------
# Cheap in-loop metrics from gold ranks (LM / sequential recsys path).
# ---------------------------------------------------------------------------


def rank_metrics(gold_ranks: jax.Array, mask: jax.Array | None = None,
                 ks: Tuple[int, ...] = (1, 5, 10)) -> Dict[str, jax.Array]:
    """MRR + success@k from 1-based gold-item ranks (no sort needed).

    This is the single-relevant-document special case of trec_eval measures:
    recip_rank == 1/rank, success_k == rank <= k, ndcg == 1/log2(rank+1).
    Used for next-token / next-item evaluation fused into the train step.
    """
    r = gold_ranks.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones_like(r, dtype=bool)
    m = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(m), 1.0)
    out = {
        "recip_rank": jnp.sum(m / r) / n,
        "ndcg": jnp.sum(m / jnp.log2(r + 1.0)) / n,
    }
    for k in ks:
        out[f"success_{k}"] = jnp.sum(m * (r <= k)) / n
    return out
