"""K-run sweep evaluation: one batched call → a ``[K, Q, M]`` score tensor.

The experiment-suite workload (ROADMAP item 3): a hyperparameter sweep
produces K system variants that must all be scored against ONE qrel and then
compared statistically.  Scoring them with K separate ``evaluate`` calls
pays K measure-core dispatches and K rounds of padding; this module instead
extends the serve layer's :func:`repro.core.evaluator.concat_run_buffers`
coalescing to whole runs — the K runs are tokenized once each, stacked end
to end on the query axis, and pushed through the jitted measure core in
large fused batches.  Because every measure is computed row-independently,
the resulting tensor is **bit-identical** to K independent
:meth:`~repro.core.evaluator.RelevanceEvaluator.evaluate_buffer` calls
(``tests/test_sweep.py`` asserts exact equality, including ragged
per-query document counts padded by the bucketing layer).

The output :class:`SweepResult` holds the dense ``[K, Q, M]`` per-query
tensor plus the aligned run/query/measure names, and hands ``[K, Q]``
slices straight to :mod:`repro.stats` for paired significance testing —
``result.compare("map")`` is the one-call sweep-and-test entry point that
``python -m repro.compare`` and the serve layer's ``compare`` op wrap.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core import measures as M, registry
from repro.core.evaluator import (RelevanceEvaluator, RunBuffer,
                                  concat_run_buffers)


class SweepResult(NamedTuple):
    """Aligned per-query scores for K runs: names + a ``[K, Q, M]`` tensor.

    ``table[k, q, m]`` is measure ``measure_keys[m]`` for run
    ``run_names[k]`` on query ``qids[q]`` — float32, exactly the values the
    single-run evaluator would report.  Aggregate-only measures (``gm_map``)
    store their per-query *log contributions*; :meth:`aggregates` applies
    the geometric-mean finalization.
    """

    run_names: Tuple[str, ...]
    qids: Tuple[str, ...]
    measure_keys: Tuple[str, ...]
    table: np.ndarray

    def measure(self, key: str) -> np.ndarray:
        """The ``[K, Q]`` per-query slice for one measure key.

        Accepts either dialect: ``"ndcg_cut_10"`` and ``"nDCG@10"`` name
        the same column.
        """
        lookup = key
        if lookup not in self.measure_keys:
            try:
                lookup = registry.canonical_key(key)[0]
            except registry.MeasureError:
                pass
        try:
            m = self.measure_keys.index(lookup)
        except ValueError:
            raise KeyError(
                f"measure {key!r} not in sweep (have {self.measure_keys})"
            ) from None
        return self.table[:, :, m]

    def per_query(self) -> Dict[str, Dict[str, Dict[str, float]]]:
        """pytrec_eval layout per run: ``{run: {qid: {measure: value}}}``."""
        return {
            name: {
                qid: {k: float(self.table[i, j, m])
                      for m, k in enumerate(self.measure_keys)}
                for j, qid in enumerate(self.qids)
            }
            for i, name in enumerate(self.run_names)
        }

    def aggregates(self) -> Dict[str, Dict[str, float]]:
        """Mean-over-queries summary per run (geometric for ``gm_map``)."""
        mean = self.table.mean(axis=1, dtype=np.float64)  # [K, M]
        return {
            name: M.finalize_aggregates(
                {k: float(mean[i, m])
                 for m, k in enumerate(self.measure_keys)})
            for i, name in enumerate(self.run_names)
        }

    def compare(self, measure: str = "map", *,
                tests: Sequence[str] = ("t",), n_permutations: int = 2000,
                seed: int = 0) -> Dict[str, np.ndarray]:
        """Paired significance tests between all run pairs on one measure.

        Returns :func:`repro.stats.significance.significance_report` for the
        ``[K, Q]`` slice of ``measure``, with the aligned ``run_names`` /
        ``measure`` / ``qids`` added so the bundle is self-describing.
        """
        from repro import stats

        report = stats.significance_report(
            self.measure(measure), tests=tests,
            n_permutations=n_permutations, seed=seed)
        report["run_names"] = self.run_names
        report["measure"] = measure
        report["qids"] = self.qids
        return report


def common_qids(qrel_qids: Mapping[str, int],
                runs: Sequence[Mapping]) -> List[str]:
    """Queries every run retrieved and the qrels judged, first-run order.

    The alignment rule shared by :func:`evaluate_sweep` and the serve
    layer's ``compare`` op: paired statistics only make sense on queries
    every system answered, so the sweep's query axis is the intersection of
    the runs' query sets with the judged set, ordered by the first run.
    """
    qids = [q for q in runs[0] if q in qrel_qids]
    for other in runs[1:]:
        qids = [q for q in qids if q in other]
    return qids


def evaluate_sweep(
    qrel_or_evaluator,
    runs,
    measures: Optional[Sequence[str]] = None,
    relevance_level: int = 1,
    backend: str = "single",
    run_names: Optional[Sequence[str]] = None,
    judged_docs_only: bool = False,
) -> SweepResult:
    """Evaluate K runs against one qrel as a single batched sweep.

    ``qrel_or_evaluator`` is a qrel mapping (a
    :class:`~repro.core.evaluator.RelevanceEvaluator` is built from it with
    ``measures``/``relevance_level``/``judged_docs_only``) or an existing
    evaluator whose interned state is reused (then those arguments must be
    left at their defaults — the evaluator already owns them).  ``measures``
    accepts either dialect (``"map"`` or ``"AP"``, ``"ndcg_cut_10"`` or
    ``"nDCG@10"``); output keys are always canonical trec_eval keys.

    ``runs`` is a sequence or ``{name: run}`` mapping of K >= 1 runs, all
    dict runs (``{qid: {docno: score}}``) or all pre-tokenized
    :class:`~repro.core.evaluator.RunBuffer`\\ s.  Dict runs are aligned to
    their **common** judged query set (first run's order — every run must
    retrieve every compared query, otherwise the pairing axis of the
    significance tests would be meaningless); buffers must already share one
    qid list and carry scores.  ``run_names`` (or the mapping keys) label
    the rows; default ``run_0 .. run_{K-1}``.

    ``backend`` is ``"single"``, ``"sharded"``, or ``"auto"`` (sharded iff
    more than one device is visible) — values are identical either way.
    Work is dispatched in groups of whole runs bounded by the evaluator's
    ``chunk_queries``, so K can reach the hundreds without unbounded
    padding.

    >>> qrel = {'q1': {'d1': 1, 'd2': 0}, 'q2': {'d1': 0, 'd2': 1}}
    >>> runs = {'good': {'q1': {'d1': 2.0, 'd2': 1.0},
    ...                  'q2': {'d1': 1.0, 'd2': 2.0}},
    ...         'bad':  {'q1': {'d1': 1.0, 'd2': 2.0},
    ...                  'q2': {'d1': 2.0, 'd2': 1.0}}}
    >>> res = evaluate_sweep(qrel, runs, measures={'map'})
    >>> res.run_names, res.qids, res.table.shape
    (('good', 'bad'), ('q1', 'q2'), (2, 2, 1))
    >>> res.aggregates()['good']['map'], res.aggregates()['bad']['map']
    (1.0, 0.5)
    """
    if isinstance(qrel_or_evaluator, RelevanceEvaluator):
        if measures is not None or relevance_level != 1 or judged_docs_only:
            raise ValueError(
                "pass measures/relevance_level/judged_docs_only only with a "
                "qrel mapping; an evaluator already owns them")
        ev = qrel_or_evaluator
    else:
        ev = RelevanceEvaluator(
            qrel_or_evaluator,
            measures if measures is not None else sorted(M.SUPPORTED_MEASURES),
            relevance_level=relevance_level,
            judged_docs_only=judged_docs_only)

    if isinstance(runs, Mapping):
        if run_names is not None:
            raise ValueError("run_names conflicts with a {name: run} mapping")
        run_names = list(runs)
        runs = list(runs.values())
    else:
        runs = list(runs)
    if not runs:
        raise ValueError("no runs to sweep")
    if run_names is None:
        run_names = [f"run_{i}" for i in range(len(runs))]
    run_names = [str(n) for n in run_names]
    if len(run_names) != len(runs):
        raise ValueError(f"{len(run_names)} names for {len(runs)} runs")

    if isinstance(runs[0], RunBuffer):
        bufs: List[RunBuffer] = []
        for name, buf in zip(run_names, runs):
            if not isinstance(buf, RunBuffer):
                raise TypeError("cannot mix dict runs and RunBuffers")
            if buf.scores is None:
                raise ValueError(f"run {name!r}: buffer has no scores; "
                                 "use with_scores()")
            if list(buf.qids) != list(runs[0].qids):
                raise ValueError(
                    f"run {name!r} covers different queries than "
                    f"{run_names[0]!r}; sweep rows must share one qid list")
            bufs.append(buf)
        qids = list(runs[0].qids)
    else:
        if any(isinstance(r, RunBuffer) for r in runs):
            raise TypeError("cannot mix dict runs and RunBuffers")
        qids = common_qids(ev._qid_index, runs)
        if not qids:
            raise ValueError("no common judged queries across the runs")
        bufs = [ev.tokenize_run({q: run[q] for q in qids}) for run in runs]

    k, nq = len(bufs), len(qids)
    keys = ev.measure_keys
    table = np.empty((k, nq, len(keys)), dtype=np.float32)

    resolved = _resolve_backend(backend)
    sev = None
    if resolved == "sharded":
        from repro.distributed.sharded_evaluator import ShardedEvaluator

        sev = ShardedEvaluator(ev)

    # Whole-run groups bounded by chunk_queries: consecutive sweep points
    # share one padded dispatch, and a run larger than the chunk budget
    # still goes through in one piece (same as evaluate_buffer).
    group = max(1, ev.chunk_queries // max(nq, 1))
    for lo in range(0, k, group):
        chunk = bufs[lo:lo + group]
        big = concat_run_buffers(chunk) if len(chunk) > 1 else chunk[0]
        if sev is not None:
            rows = sev.evaluate_table([big])
        else:
            batch = ev.batch_from_buffer(big)
            per_query = M.compute_measures_jit(batch, ev.measures,
                                               ev.relevance_level,
                                               ev.judged_docs_only)
            rows = np.stack(
                [np.asarray(per_query[key])[:len(big.qids)] for key in keys],
                axis=-1)
        table[lo:lo + len(chunk)] = rows.reshape(len(chunk), nq, len(keys))

    return SweepResult(tuple(run_names), tuple(qids), tuple(keys), table)


def _resolve_backend(backend: str) -> str:
    if backend == "single":  # the common case must not import jax's mesh API
        return backend
    from repro.distributed.sharded_evaluator import select_backend

    return select_backend(backend)
