"""TREC run / qrel file formats.

These exist for interoperability *and* as the serialization layer of the
serialize-invoke-parse baseline (the workflow the paper measures against).

Formats (whitespace separated):
  qrel:  ``qid  iter  docno  rel``
  run:   ``qid  Q0    docno  rank  score  tag``
"""

from __future__ import annotations

from typing import Dict, Mapping, TextIO, Tuple

import numpy as np


def parse_qrel(fh: TextIO) -> Dict[str, Dict[str, int]]:
    qrel: Dict[str, Dict[str, int]] = {}
    for line in fh:
        parts = line.split()
        if not parts:
            continue
        if len(parts) != 4:
            raise ValueError(f"malformed qrel line: {line!r}")
        qid, _, docno, rel = parts
        qrel.setdefault(qid, {})[docno] = int(rel)
    return qrel


def parse_run(fh: TextIO) -> Dict[str, Dict[str, float]]:
    run: Dict[str, Dict[str, float]] = {}
    for line in fh:
        parts = line.split()
        if not parts:
            continue
        if len(parts) != 6:
            raise ValueError(f"malformed run line: {line!r}")
        qid, _, docno, _rank, score, _tag = parts
        run.setdefault(qid, {})[docno] = float(score)
    return run


def parse_run_arrays(fh: TextIO) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Parse a TREC run straight into flat ``(qids, docnos, scores)`` arrays.

    The tokenized-ingest fast path: the arrays feed
    ``RelevanceEvaluator.buffer_from_arrays`` directly, so a run file becomes
    a pre-tokenized :class:`~repro.core.evaluator.RunBuffer` without ever
    materializing a dict-of-dicts.  Rows are returned as-is; duplicate
    ``(qid, docno)`` pairs are the caller's responsibility (trec_eval rejects
    them, dict parsing keeps the last).

    Returns three flat, equal-length 1-D arrays: ``qids`` and ``docnos`` as
    numpy unicode arrays (file row order preserved), ``scores`` as float32.

    >>> import io
    >>> fh = io.StringIO("q1 Q0 d2 0 0.9 tag\\nq1 Q0 d1 1 0.2 tag\\n")
    >>> qids, docnos, scores = parse_run_arrays(fh)
    >>> qids.tolist(), docnos.tolist(), scores.astype('f8').round(2).tolist()
    (['q1', 'q1'], ['d2', 'd1'], [0.9, 0.2])
    """
    qids, docnos, scores = [], [], []
    for line in fh:
        parts = line.split()
        if not parts:
            continue
        if len(parts) != 6:
            raise ValueError(f"malformed run line: {line!r}")
        qids.append(parts[0])
        docnos.append(parts[2])
        scores.append(parts[4])
    return (np.array(qids), np.array(docnos),
            np.array(scores, dtype=np.float32))


def parse_qrel_arrays(fh: TextIO) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Parse a TREC qrel into flat ``(qids, docnos, rels)`` arrays."""
    qids, docnos, rels = [], [], []
    for line in fh:
        parts = line.split()
        if not parts:
            continue
        if len(parts) != 4:
            raise ValueError(f"malformed qrel line: {line!r}")
        qids.append(parts[0])
        docnos.append(parts[2])
        rels.append(int(parts[3]))
    return (np.array(qids), np.array(docnos),
            np.array(rels, dtype=np.int32))


def write_qrel(fh: TextIO, qrel: Mapping[str, Mapping[str, int]]) -> None:
    for qid, docs in qrel.items():
        for docno, rel in docs.items():
            fh.write(f"{qid} 0 {docno} {int(rel)}\n")


def write_run(fh: TextIO, run: Mapping[str, Mapping[str, float]],
              tag: str = "repro") -> None:
    # Like the paper's benchmark setup: written WITHOUT sorting — the
    # evaluator sorts internally, so rank fields are positional placeholders.
    for qid, docs in run.items():
        for rank, (docno, score) in enumerate(docs.items()):
            fh.write(f"{qid} Q0 {docno} {rank} {score:.6f} {tag}\n")


def load_qrel(path: str) -> Dict[str, Dict[str, int]]:
    with open(path) as fh:
        return parse_qrel(fh)


def load_run(path: str) -> Dict[str, Dict[str, float]]:
    with open(path) as fh:
        return parse_run(fh)


def load_run_arrays(path: str) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """File-path convenience wrapper around :func:`parse_run_arrays`."""
    with open(path) as fh:
        return parse_run_arrays(fh)


def run_id(path: str) -> str:
    """The run tag (6th column) of the first data line — trec_eval's runid."""
    with open(path) as fh:
        for line in fh:
            parts = line.split()
            if not parts:
                continue
            if len(parts) != 6:
                raise ValueError(f"malformed run line: {line!r}")
            return parts[5]
    return ""


def save_qrel(path: str, qrel) -> None:
    with open(path, "w") as fh:
        write_qrel(fh, qrel)


def save_run(path: str, run, tag: str = "repro") -> None:
    with open(path, "w") as fh:
        write_run(fh, run, tag)
