"""Synthetic data pipelines (all substrates built, nothing stubbed)."""
