"""Synthetic graph pipelines + a real neighbor sampler (minibatch_lg shape).

Graphs are padded to static (n_nodes, n_edges) with masks so every batch
compiles once.  The neighbor sampler implements the GraphSAGE fanout
protocol: seed nodes → sample `fanout[0]` in-neighbors → their
`fanout[1]` in-neighbors → induced subgraph, CSR-backed and O(E) to build.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int = 40
    d_edge_feat: int = 8
    seed: int = 0


def random_graph(cfg: GraphConfig) -> dict:
    """Degree-skewed random graph (preferential-attachment-ish)."""
    rng = np.random.default_rng(cfg.seed)
    # power-law-ish destination preference
    pref = rng.exponential(1.0, cfg.n_nodes)
    pref /= pref.sum()
    src = rng.integers(0, cfg.n_nodes, cfg.n_edges).astype(np.int32)
    dst = rng.choice(cfg.n_nodes, cfg.n_edges, p=pref).astype(np.int32)
    return {
        "src": src,
        "dst": dst,
        "node_feat": rng.standard_normal(
            (cfg.n_nodes, cfg.d_feat)).astype(np.float32),
        "edge_feat": rng.standard_normal(
            (cfg.n_edges, cfg.d_edge_feat)).astype(np.float32),
        "labels": rng.integers(0, cfg.n_classes,
                               cfg.n_nodes).astype(np.int32),
        "node_mask": np.ones(cfg.n_nodes, bool),
        "edge_mask": np.ones(cfg.n_edges, bool),
    }


class NeighborSampler:
    """Fanout neighbor sampling over a CSR representation (in-edges)."""

    def __init__(self, graph: dict, fanout: Sequence[int],
                 batch_nodes: int, seed: int = 0):
        self.graph = graph
        self.fanout = tuple(fanout)
        self.batch_nodes = batch_nodes
        self.rng = np.random.default_rng(seed)
        n = graph["node_feat"].shape[0]
        # CSR over in-edges: for each dst, the list of (src, edge_id).
        order = np.argsort(graph["dst"], kind="stable")
        self.sorted_src = graph["src"][order]
        self.sorted_eid = order.astype(np.int32)
        counts = np.bincount(graph["dst"], minlength=n)
        self.indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self.n_nodes = n
        # static padded sizes
        max_new = batch_nodes
        self.max_nodes = batch_nodes
        self.max_edges = 0
        for f in self.fanout:
            e = max_new * f
            self.max_edges += e
            max_new = e
            self.max_nodes += e

    def sample(self) -> dict:
        g = self.graph
        seeds = self.rng.integers(0, self.n_nodes, self.batch_nodes)
        nodes = list(seeds)
        node_pos = {int(v): i for i, v in enumerate(seeds)}
        edges_src, edges_dst, edge_ids = [], [], []
        frontier = seeds
        for f in self.fanout:
            next_frontier = []
            for v in frontier:
                lo, hi = self.indptr[v], self.indptr[v + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(f, int(deg))
                sel = lo + self.rng.choice(deg, size=take, replace=False)
                for s in sel:
                    u = int(self.sorted_src[s])
                    if u not in node_pos:
                        node_pos[u] = len(nodes)
                        nodes.append(u)
                        next_frontier.append(u)
                    edges_src.append(node_pos[u])
                    edges_dst.append(node_pos[int(v)])
                    edge_ids.append(int(self.sorted_eid[s]))
            frontier = np.array(next_frontier, dtype=np.int64) \
                if next_frontier else np.array([], dtype=np.int64)

        n, e = len(nodes), len(edges_src)
        nodes_arr = np.array(nodes, dtype=np.int64)
        out = {
            "node_feat": np.zeros((self.max_nodes, g["node_feat"].shape[1]),
                                  np.float32),
            "edge_feat": np.zeros((self.max_edges, g["edge_feat"].shape[1]),
                                  np.float32),
            "src": np.zeros(self.max_edges, np.int32),
            "dst": np.zeros(self.max_edges, np.int32),
            "labels": np.zeros(self.max_nodes, np.int32),
            "node_mask": np.zeros(self.max_nodes, bool),
            "edge_mask": np.zeros(self.max_edges, bool),
            "train_mask": np.zeros(self.max_nodes, bool),
        }
        out["node_feat"][:n] = g["node_feat"][nodes_arr]
        out["labels"][:n] = g["labels"][nodes_arr]
        out["node_mask"][:n] = True
        out["train_mask"][: self.batch_nodes] = True  # loss on seeds only
        if e:
            out["src"][:e] = edges_src
            out["dst"][:e] = edges_dst
            out["edge_feat"][:e] = g["edge_feat"][np.array(edge_ids)]
            out["edge_mask"][:e] = True
        return out

    def iterator(self) -> Iterator[dict]:
        while True:
            yield self.sample()
