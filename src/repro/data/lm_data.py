"""Synthetic LM token pipeline: deterministic, shardable, restart-safe.

Markov-chain token stream (power-law unigram marginals, per-state successor
tables) — enough structure that a small model's loss visibly falls, cheap
enough to generate on the fly.  The iterator is keyed by (seed, step) so a
restarted job regenerates the exact batch sequence (checkpoint/restart
determinism: data state needs no checkpointing).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_successors: int = 64
    seed: int = 0


class MarkovLM:
    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipfian start distribution.
        ranks = np.arange(1, v + 1)
        self.start_p = (1.0 / ranks) / (1.0 / ranks).sum()
        self.successors = rng.integers(0, v, (v, cfg.n_successors))
        w = rng.exponential(1.0, (v, cfg.n_successors))
        self.succ_p = w / w.sum(axis=1, keepdims=True)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=b, p=self.start_p)
        sel = rng.integers(0, cfg.n_successors, (b, s))
        for t in range(s):
            # cheap successor draw: pick column then lookup (not exact
            # categorical per-row, but preserves the chain structure)
            toks[:, t + 1] = self.successors[toks[:, t], sel[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterator(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
