"""Synthetic recsys pipelines: CTR batches, behavior sequences, candidates.

Deterministic per (seed, step) like lm_data — restart-safe without data-state
checkpoints.  Labels follow a planted logistic model over field embeddings so
AUC/NDCG visibly improve during smoke training.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class CTRDataConfig:
    n_fields: int
    vocab_per_field: int
    batch: int
    n_multi_hot: int = 0
    multi_hot_len: int = 8
    seed: int = 0


def ctr_batch(cfg: CTRDataConfig, step: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    # Zipf-ish id popularity (hot rows — the embedding-bag stress pattern).
    ids = (rng.pareto(1.2, (cfg.batch, cfg.n_fields)) * 17
           ).astype(np.int64) % cfg.vocab_per_field
    # planted label: parity-ish interaction of two fields + noise
    h = ((ids[:, 0] % 7) + (ids[:, 1] % 5) + (ids[:, 0] % 3) * (ids[:, 1] % 2))
    p = 1.0 / (1.0 + np.exp(-(h.astype(np.float64) - 6.0) / 2.0))
    out = {
        "ids": ids.astype(np.int32),
        "labels": (rng.random(cfg.batch) < p).astype(np.int32),
    }
    if cfg.n_multi_hot:
        out["mh_ids"] = (rng.integers(
            0, cfg.vocab_per_field,
            (cfg.batch, cfg.n_multi_hot, cfg.multi_hot_len))).astype(np.int32)
        out["mh_mask"] = rng.random(
            (cfg.batch, cfg.n_multi_hot, cfg.multi_hot_len)) < 0.6
    return out


@dataclasses.dataclass(frozen=True)
class SeqDataConfig:
    n_items: int
    seq_len: int
    batch: int
    n_negs: int = 20
    seed: int = 0


def seq_batch(cfg: SeqDataConfig, step: int) -> dict:
    """SASRec-style: history items + per-position positives/negatives."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    b, s = cfg.batch, cfg.seq_len
    # sessions drift through item space — next item correlates with current
    base = rng.integers(0, cfg.n_items, (b, 1))
    walk = rng.integers(-50, 51, (b, s + 1)).cumsum(axis=1)
    items = (base + walk) % cfg.n_items
    return {
        "items": items[:, :-1].astype(np.int32),
        "pos": items[:, 1:].astype(np.int32),
        "neg": rng.integers(0, cfg.n_items, (b, s)).astype(np.int32),
        "mask": np.ones((b, s), bool),
    }


def mind_batch(cfg: SeqDataConfig, step: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, 7]))
    b, s = cfg.batch, cfg.seq_len
    base = rng.integers(0, cfg.n_items, (b, 1))
    walk = rng.integers(-50, 51, (b, s + 1)).cumsum(axis=1)
    items = (base + walk) % cfg.n_items
    return {
        "hist": items[:, :-1].astype(np.int32),
        "hist_mask": np.ones((b, s), bool),
        "pos": items[:, -1].astype(np.int32),
        "negs": rng.integers(0, cfg.n_items, (b, cfg.n_negs)).astype(np.int32),
    }


def iterator(batch_fn, cfg, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_fn(cfg, step)
        step += 1
