"""Synthetic IR test collection — the paper's §4 protocol (Tague et al. 1980).

Collection construction (paper defaults in brackets):
  * vocabulary V of symbolic tokens [|V| = 10,000];
  * collection-wide unigram and bigram pseudo-counts ~ Exp(λ=1) — term
    specificity: few frequent, most infrequent;
  * per document: |d| ~ Poisson(μ_d=200); unigram + bigram doc LMs ~
    Dirichlet(collection pseudo-counts); tokens drawn with P(n=1)=0.9,
    P(n=2)=0.1;
  * queries: r=5 uniformly-random relevant docs R_q; |q| ~ Poisson(μ_q=3);
    terms ~ P(w|R_q)·(1 − P(w|D)) (specific to R_q, uncommon in D).

Memory adaptation (documented in DESIGN.md): the paper's dense |V|² bigram
pseudo-count table is infeasible at |V|=10k × float; we keep a *sparse*
successor table (``n_successors`` per token, default 32) — the same
specificity skew with O(|V|·k) memory.  Dense mode is used automatically for
small vocabularies.

Ranking model for the demo environment: Dirichlet-smoothed query likelihood
(Indri's default, μ=2500) over the term-document count matrix — the Pyndri
stand-in, device-resident in JAX.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class CollectionConfig:
    vocab_size: int = 10_000
    n_docs: int = 100
    avg_doc_len: float = 200.0
    avg_query_len: float = 3.0
    n_queries: int = 1000
    n_relevant: int = 5
    p_bigram: float = 0.1
    n_successors: int = 32
    dense_bigram_threshold: int = 512  # |V| below this → dense bigram table
    seed: int = 0


@dataclasses.dataclass
class Collection:
    cfg: CollectionConfig
    doc_term: np.ndarray  # [n_docs, V] term counts
    doc_len: np.ndarray  # [n_docs]
    coll_freq: np.ndarray  # [V] collection term counts
    qrels: Dict[str, Dict[str, int]]
    query_terms: Dict[str, np.ndarray]

    @property
    def n_docs(self) -> int:
        return self.doc_term.shape[0]

    def doc_id(self, i: int) -> str:
        return f"d{i:06d}"


def build_collection(cfg: Optional[CollectionConfig] = None) -> Collection:
    cfg = cfg or CollectionConfig()
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab_size

    # Collection-wide pseudo counts (term specificity).
    uni_pseudo = rng.exponential(1.0, v)
    dense_bigram = v <= cfg.dense_bigram_threshold
    if dense_bigram:
        bi_pseudo = rng.exponential(1.0, (v, v))
        successors = None
    else:
        successors = rng.integers(0, v, (v, cfg.n_successors))
        bi_pseudo = rng.exponential(1.0, (v, cfg.n_successors))

    doc_term = np.zeros((cfg.n_docs, v), dtype=np.int32)
    doc_len = np.zeros(cfg.n_docs, dtype=np.int32)
    for d in range(cfg.n_docs):
        target = max(1, rng.poisson(cfg.avg_doc_len))
        # document language models ~ Dirichlet(collection pseudo counts)
        uni_lm = rng.dirichlet(uni_pseudo)
        tokens = []
        while len(tokens) < target:
            if rng.random() < cfg.p_bigram:
                x = rng.choice(v, p=uni_lm)
                if dense_bigram:
                    p = bi_pseudo[x] / bi_pseudo[x].sum()
                    y = rng.choice(v, p=p)
                else:
                    p = bi_pseudo[x] / bi_pseudo[x].sum()
                    y = successors[x][rng.choice(cfg.n_successors, p=p)]
                tokens.extend((int(x), int(y)))
            else:
                tokens.append(int(rng.choice(v, p=uni_lm)))
        tokens = tokens[:target]
        np.add.at(doc_term[d], tokens, 1)
        doc_len[d] = len(tokens)

    coll_freq = doc_term.sum(axis=0)
    coll_total = max(coll_freq.sum(), 1)
    p_w_coll = coll_freq / coll_total

    qrels: Dict[str, Dict[str, int]] = {}
    query_terms: Dict[str, np.ndarray] = {}
    for qi in range(cfg.n_queries):
        qid = f"q{qi:06d}"
        rel_docs = rng.choice(cfg.n_docs, size=cfg.n_relevant, replace=False)
        qrels[qid] = {f"d{d:06d}": 1 for d in rel_docs}
        rq_counts = doc_term[rel_docs].sum(axis=0)
        p_w_rq = rq_counts / max(rq_counts.sum(), 1)
        weights = p_w_rq * (1.0 - p_w_coll)
        total = weights.sum()
        qlen = max(1, rng.poisson(cfg.avg_query_len))
        if total <= 0:
            terms = rng.integers(0, v, qlen)
        else:
            terms = rng.choice(v, size=qlen, replace=True, p=weights / total)
        query_terms[qid] = terms.astype(np.int32)

    return Collection(cfg=cfg, doc_term=doc_term, doc_len=doc_len,
                      coll_freq=coll_freq, qrels=qrels,
                      query_terms=query_terms)


def ql_scores(coll: Collection, terms: np.ndarray, mu: float = 2500.0
              ) -> np.ndarray:
    """Dirichlet-smoothed query-likelihood scores for all docs (Indri-style).

    score(q, d) = Σ_w log( (tf_{w,d} + μ·P(w|C)) / (|d| + μ) )
    """
    if len(terms) == 0:
        return np.zeros(coll.n_docs, dtype=np.float32)
    p_c = coll.coll_freq / max(coll.coll_freq.sum(), 1)
    tf = coll.doc_term[:, terms].astype(np.float64)  # [D, |q|]
    smooth = mu * p_c[terms][None, :]
    denom = (coll.doc_len + mu)[:, None]
    return np.log((tf + smooth) / denom).sum(axis=1).astype(np.float32)


def run_from_scores(coll: Collection, qid_scores: Dict[str, np.ndarray],
                    depth: int = 10) -> Dict[str, Dict[str, float]]:
    """Top-``depth`` run dict from per-query score vectors."""
    run: Dict[str, Dict[str, float]] = {}
    for qid, scores in qid_scores.items():
        top = np.argsort(-scores)[:depth]
        run[qid] = {f"d{d:06d}": float(scores[d]) for d in top}
    return run


def synthesize_run(n_queries: int, n_docs: int, seed: int = 0):
    """The paper's *benchmark* synthesis (§3): every document gets a distinct
    integer score and relevance level 1.  Used by RQ1/RQ2 benchmarks."""
    rng = np.random.default_rng(seed)
    run, qrel = {}, {}
    for qi in range(n_queries):
        qid = f"q{qi}"
        scores = rng.permutation(n_docs)
        run[qid] = {f"d{j}": float(scores[j]) for j in range(n_docs)}
        qrel[qid] = {f"d{j}": 1 for j in range(n_docs)}
    return run, qrel
