"""Developer workflow helpers: ``python -m repro.dev verify``.

The ``verify`` target is the one-command pre-merge check documented in
README.md:

1. the tier-1 pytest suite (fast correctness, ``-m 'not slow'`` default),
2. a 2-device sharded smoke test under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` — the sharded
   pipeline must stay bit-identical to the single-device evaluator on the
   conformance fixtures, and
3. the serve smoke test (also ``python -m repro.dev serve-smoke`` /
   ``make serve-smoke``): boot a TCP evaluation service, fire concurrent
   requests from several connections, and assert they were coalesced into
   fewer backend calls with per-query results bit-identical to direct
   evaluation, and
4. the client smoke test (``python -m repro.dev client-smoke`` /
   ``make client-smoke``): drive a TCP server AND a stdio subprocess
   server through ``repro.client.EvalClient`` — pipelined requests that
   must coalesce, plus one >64 KiB ``register_qrel`` payload on each
   transport (the frame size that crashed the seed serve layer) —
   asserting bit-identical results throughout, and
5. the cluster smoke test (``python -m repro.dev cluster-smoke`` /
   ``make cluster-smoke``): boot a 2-worker ``repro.serve.cluster`` over
   TCP, round-trip a >64 KiB payload through the consistent-hash router
   bit-identically, then SIGKILL the owning worker while a request is in
   its coalescing window and assert the router restarts it, replays the
   registration journal, and retries transparently, and
6. the sweep smoke test (``python -m repro.dev sweep-smoke`` /
   ``make sweep-smoke``): evaluate a small K-run sweep
   (:func:`repro.core.evaluate_sweep`) and assert it is bit-identical to
   the K independent ``evaluate_buffer`` calls it replaces, then run the
   all-pairs paired t-test + Holm correction (:mod:`repro.stats`) and
   check the statistics invariants (symmetric unit-diagonal p matrices,
   Holm <= Bonferroni) plus the conformance fixture's known p-value, and
7. the sweep benchmark smoke: ``python -m benchmarks.run --only sweep``
   must complete and record its rows (CI asserts the >=5x
   significance-stack speedup from the recorded results).

Exit status is non-zero if any step fails.  ``make verify`` wraps this.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", ".."))
SRC = os.path.join(ROOT, "src")

_SMOKE = """
    import jax
    from repro.core import RelevanceEvaluator, supported_measures, trec
    from repro.distributed import ShardedEvaluator

    assert len(jax.devices()) == 2, jax.devices()
    qrel = trec.load_qrel({qrel!r})
    run = trec.load_run({run!r})
    ev = RelevanceEvaluator(qrel, supported_measures)
    want = ev.evaluate(run)
    res = ShardedEvaluator(ev).evaluate(run)
    for qid in want:
        for key, val in want[qid].items():
            assert res.per_query[qid][key] == val, (qid, key)
    print("sharded 2-device smoke: OK "
          f"({{len(want)}} queries x {{len(ev.measure_keys)}} measures)")
"""


_SERVE_SMOKE = """
    import asyncio, json
    from repro.core import RelevanceEvaluator, trec
    from repro.serve import EvaluationService, serve_tcp

    qrel = trec.load_qrel({qrel!r})
    run = trec.load_run({run!r})
    measures = ("map", "ndcg", "recip_rank")
    n = 6
    runs = [{{q: {{d: s + 0.25 * i for d, s in docs.items()}}
             for q, docs in run.items()}} for i in range(n)]
    want = [RelevanceEvaluator(qrel, measures).evaluate(r) for r in runs]

    async def client(port, i):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        req = {{"op": "evaluate", "id": i, "qrel_id": "smoke",
                "run": runs[i]}}
        writer.write((json.dumps(req) + "\\n").encode())
        await writer.drain()
        reply = json.loads(await reader.readline())
        writer.close(); await writer.wait_closed()
        assert reply["ok"], reply
        return reply["result"]["per_query"]

    async def main():
        svc = EvaluationService(window=0.05)
        svc.register_qrel("smoke", qrel, measures)
        server = await serve_tcp(svc, "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        got = await asyncio.gather(*(client(port, i) for i in range(n)))
        server.close(); await server.wait_closed()
        stats = svc.stats()
        assert stats["backend_calls"] < n, stats  # coalesced
        for g, w in zip(got, want):
            for qid in w:
                for key, val in w[qid].items():
                    assert g[qid][key] == val, (qid, key)  # bit-identical
        print(f"serve smoke: OK ({{n}} concurrent requests -> "
              f"{{stats['backend_calls']}} backend call(s), bit-identical)")

    asyncio.run(main())
"""


_CLIENT_SMOKE = """
    import json, sys
    from repro.client import EvalClient
    from repro.core import RelevanceEvaluator, trec
    from repro.serve.testing import ServerThread

    qrel_path = sys.argv[1]

    # a register_qrel payload comfortably past the seed's 64 KiB limit
    big_qrel = {"Q%04d-%s" % (i, "x" * 80):
                {"D%04d-%s" % (d, "y" * 80): int((i + d) % 3)
                 for d in range(24)} for i in range(36)}
    big_run = {q: {d: float((i * 31 + j * 7) % 97) / 97.0
                   for j, d in enumerate(docs)}
               for i, (q, docs) in enumerate(big_qrel.items())}
    payload = json.dumps({"op": "register_qrel", "qrel_id": "big",
                          "qrel": big_qrel})
    assert len(payload) > (1 << 16), len(payload)
    want = RelevanceEvaluator(big_qrel, ("map", "ndcg")).evaluate(big_run)

    # TCP: persistent connection, pipelining, >64 KiB payload
    with ServerThread(service_kw=dict(window=0.02)) as srv:
        with EvalClient(srv.host, srv.port) as client:
            assert client.ping() == "pong"
            client.register_qrel("big", big_qrel, ("map", "ndcg"))
            res = client.evaluate("big", run=big_run)
            assert res.per_query == want  # bit-identical through TCP
            many = client.evaluate_many("big", runs=[big_run] * 4)
            assert all(m.per_query == want for m in many)
        stats = srv.stats()
        assert stats["backend_calls"] < stats["requests"], stats

    # stdio: a private subprocess server, same >64 KiB payload
    with EvalClient.spawn_stdio(
            [sys.executable, "-m", "repro.serve", "--qrel", qrel_path,
             "-m", "map", "--window-ms", "1"]) as client:
        assert client.ping() == "pong"
        r = client.evaluate("default",
                            run={"q1": {"APPLE": 2.0, "BANANA": 1.0}})
        assert r.per_query["q1"]["map"] > 0
        client.register_qrel("big", big_qrel, ("map", "ndcg"))
        res = client.evaluate("big", run=big_run)
        assert res.per_query == want  # and through stdio pipes

    print("client smoke: OK (TCP pipelined + stdio, >64 KiB payloads, "
          f"{stats['requests']} reqs -> {stats['backend_calls']} backend "
          "calls, bit-identical)")
"""


_CLUSTER_SMOKE = """
    import asyncio, json
    from repro.client import EvalClient
    from repro.core import RelevanceEvaluator
    from repro.serve.cluster.testing import ClusterThread

    # a payload comfortably past 64 KiB, through the router's raw path
    big_qrel = {"Q%04d-%s" % (i, "x" * 80):
                {"D%04d-%s" % (d, "y" * 80): int((i + d) % 3)
                 for d in range(24)} for i in range(36)}
    big_run = {q: {d: float((i * 31 + j * 7) % 97) / 97.0
                   for j, d in enumerate(docs)}
               for i, (q, docs) in enumerate(big_qrel.items())}
    payload = json.dumps({"op": "evaluate", "qrel_id": "big",
                          "run": big_run})
    assert len(payload) > (1 << 16), len(payload)
    want = RelevanceEvaluator(big_qrel, ("map", "ndcg")).evaluate(big_run)

    # a wide coalescing window so the kill lands mid-request
    with ClusterThread(2, worker_args=["--backend", "single",
                                       "--window-ms", "250"],
                       router_kw=dict(retries=4,
                                      health_interval=30.0)) as cluster:
        with EvalClient(cluster.host, cluster.port, timeout=180) as client:
            assert client.ping() == "pong"
            health = client.health()
            assert health["status"] == "ok" and health["ready"] == 2, health
            client.register_qrel("big", big_qrel, ("map", "ndcg"))
            res = client.evaluate("big", run=big_run)
            assert res.per_query == want  # >64 KiB round trip, bit-identical

            owner = cluster.owner_of("big")
            future = client.submit("big", run=big_run)

            async def wait_inflight():
                slot = cluster.router._slots[owner]
                while True:
                    h = await slot.proc.client.health()
                    if h["in_flight"]:
                        return
                    await asyncio.sleep(0.002)

            cluster.call(wait_inflight(), timeout=60)
            cluster.kill_worker(owner)  # SIGKILL mid-request
            assert future.result(180).per_query == want  # transparent retry
        counters = dict(cluster.router.counters)
    assert counters["restarts"] >= 1 and counters["worker_retries"] >= 1, \\
        counters
    print("cluster smoke: OK (2 workers, >64 KiB through the router, "
          "worker killed mid-request -> restart + journal replay + "
          "transparent retry, bit-identical)")
"""


_SWEEP_SMOKE = """
    import numpy as np
    from repro import stats
    from repro.core import RelevanceEvaluator, evaluate_sweep, trec

    qrel = trec.load_qrel({qrel!r})
    base = trec.load_run({run!r})
    measures = ("map", "ndcg", "P_5")
    k = 6
    runs = [{{q: {{d: s + 0.25 * i * (1 if hash(d) % 2 else -1)
                 for d, s in docs.items()}}
             for q, docs in base.items()}} for i in range(k)]
    ev = RelevanceEvaluator(qrel, measures)
    result = evaluate_sweep(ev, [ev.tokenize_run(r) for r in runs])
    # bit-identity: the sweep table IS the K independent evaluations
    for ki, r in enumerate(runs):
        want = ev.evaluate(r)
        for qi, qid in enumerate(result.qids):
            for mi, key in enumerate(result.measure_keys):
                assert result.table[ki, qi, mi] == want[qid][key], \\
                    (ki, qid, key)

    x = np.asarray(result.measure("map"))
    t, p = stats.paired_t_matrix(x)
    holm = stats.holm_matrix(p)
    bonf = stats.bonferroni_matrix(p)
    t, p, holm, bonf = (np.asarray(a) for a in (t, p, holm, bonf))
    assert np.array_equal(p, p.T) and np.array_equal(np.diag(p),
                                                     np.ones(k))
    assert np.array_equal(t, -t.T)
    assert (holm <= bonf + 1e-7).all() and (holm <= 1.0).all()
    # closed form at df=1: d=[0.1, 0.3] -> t=2, p = 1 - (2/pi)atan(2)
    _, p2 = stats.paired_t_matrix(
        np.array([[0.4, 0.6], [0.3, 0.3]], np.float32))
    assert abs(float(p2[0, 1]) - 0.29516723) < 1e-6, float(p2[0, 1])
    print(f"sweep smoke: OK ({{k}} runs x {{len(result.qids)}} queries x "
          f"{{len(result.measure_keys)}} measures, bit-identical; "
          "stats invariants + df=1 closed form hold)")
"""


def _env(extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if extra:
        env.update(extra)
    return env


def _fixture(name: str) -> str:
    return os.path.join(ROOT, "tests", "fixtures", name)


def serve_smoke() -> int:
    """Boot a TCP service, assert coalescing + bit-identity (step 3)."""
    print("== serve smoke (TCP, concurrent clients) ==", flush=True)
    code = textwrap.dedent(_SERVE_SMOKE.format(
        qrel=_fixture("conformance.qrel"), run=_fixture("conformance.run")))
    return subprocess.run([sys.executable, "-c", code], cwd=ROOT,
                          env=_env()).returncode


def client_smoke() -> int:
    """EvalClient over TCP + stdio with >64 KiB payloads (step 4)."""
    print("== client smoke (EvalClient: TCP + stdio, large frames) ==",
          flush=True)
    code = textwrap.dedent(_CLIENT_SMOKE)
    return subprocess.run(
        [sys.executable, "-c", code, _fixture("conformance.qrel")],
        cwd=ROOT, env=_env()).returncode


def cluster_smoke() -> int:
    """2-worker cluster: big frames + kill-retry fault path (step 5)."""
    print("== cluster smoke (2 workers, router, worker-kill retry) ==",
          flush=True)
    code = textwrap.dedent(_CLUSTER_SMOKE)
    return subprocess.run([sys.executable, "-c", code], cwd=ROOT,
                          env=_env()).returncode


def sweep_smoke() -> int:
    """K-run sweep bit-identity + statistics invariants (step 6)."""
    print("== sweep smoke (evaluate_sweep + repro.stats) ==", flush=True)
    code = textwrap.dedent(_SWEEP_SMOKE.format(
        qrel=_fixture("conformance.qrel"), run=_fixture("conformance.run")))
    return subprocess.run([sys.executable, "-c", code], cwd=ROOT,
                          env=_env()).returncode


def verify() -> int:
    print("== tier-1 pytest ==", flush=True)
    rc = subprocess.run([sys.executable, "-m", "pytest", "-x", "-q"],
                        cwd=ROOT, env=_env()).returncode
    if rc != 0:
        return rc
    print("== sharded smoke (2 host-platform devices) ==", flush=True)
    code = textwrap.dedent(_SMOKE.format(
        qrel=_fixture("conformance.qrel"), run=_fixture("conformance.run")))
    rc = subprocess.run(
        [sys.executable, "-c", code], cwd=ROOT,
        env=_env({"XLA_FLAGS":
                  "--xla_force_host_platform_device_count=2"})).returncode
    if rc != 0:
        return rc
    rc = serve_smoke()
    if rc != 0:
        return rc
    rc = client_smoke()
    if rc != 0:
        return rc
    rc = cluster_smoke()
    if rc != 0:
        return rc
    rc = sweep_smoke()
    if rc != 0:
        return rc
    print("== sweep bench smoke (--only sweep) ==", flush=True)
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "sweep"],
        cwd=ROOT, env=_env()).returncode


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv == ["verify"]:
        return verify()
    if argv == ["serve-smoke"]:
        return serve_smoke()
    if argv == ["client-smoke"]:
        return client_smoke()
    if argv == ["cluster-smoke"]:
        return cluster_smoke()
    if argv == ["sweep-smoke"]:
        return sweep_smoke()
    print("usage: python -m repro.dev "
          "{verify|serve-smoke|client-smoke|cluster-smoke|sweep-smoke}",
          file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
