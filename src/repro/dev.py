"""Developer workflow helpers: ``python -m repro.dev verify``.

The ``verify`` target is the one-command pre-merge check documented in
README.md:

1. the tier-1 pytest suite (fast correctness, ``-m 'not slow'`` default), and
2. a 2-device sharded smoke test under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` — the sharded
   pipeline must stay bit-identical to the single-device evaluator on the
   conformance fixtures.

Exit status is non-zero if either step fails.  ``make verify`` wraps this.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", ".."))
SRC = os.path.join(ROOT, "src")

_SMOKE = """
    import jax
    from repro.core import RelevanceEvaluator, supported_measures, trec
    from repro.distributed import ShardedEvaluator

    assert len(jax.devices()) == 2, jax.devices()
    qrel = trec.load_qrel({qrel!r})
    run = trec.load_run({run!r})
    ev = RelevanceEvaluator(qrel, supported_measures)
    want = ev.evaluate(run)
    res = ShardedEvaluator(ev).evaluate(run)
    for qid in want:
        for key, val in want[qid].items():
            assert res.per_query[qid][key] == val, (qid, key)
    print("sharded 2-device smoke: OK "
          f"({{len(want)}} queries x {{len(ev.measure_keys)}} measures)")
"""


def _env(extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if extra:
        env.update(extra)
    return env


def verify() -> int:
    print("== tier-1 pytest ==", flush=True)
    rc = subprocess.run([sys.executable, "-m", "pytest", "-x", "-q"],
                        cwd=ROOT, env=_env()).returncode
    if rc != 0:
        return rc
    print("== sharded smoke (2 host-platform devices) ==", flush=True)
    code = textwrap.dedent(_SMOKE.format(
        qrel=os.path.join(ROOT, "tests", "fixtures", "conformance.qrel"),
        run=os.path.join(ROOT, "tests", "fixtures", "conformance.run")))
    return subprocess.run(
        [sys.executable, "-c", code], cwd=ROOT,
        env=_env({"XLA_FLAGS":
                  "--xla_force_host_platform_device_count=2"})).returncode


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv == ["verify"]:
        return verify()
    print("usage: python -m repro.dev verify", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
