"""Distribution layer: sharding rules, collective helpers, compression.

Exports :func:`shard_map`, a version-compat shim over the moving JAX API:
newer releases expose ``jax.shard_map`` (with ``check_vma``), older ones only
``jax.experimental.shard_map.shard_map`` (with ``check_rep``).  All repro code
must import shard_map from here rather than from jax directly.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pre-0.6 JAX: experimental API, `check_rep` instead of `check_vma`
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        if check_vma is not None:
            kwargs.setdefault("check_rep", check_vma)
        return _experimental_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


__all__ = ["shard_map", "ShardedEvaluator", "ShardedResult",
           "default_mesh", "select_backend"]


def __getattr__(name):  # lazy: sharded_evaluator imports kernels/measures
    if name in ("ShardedEvaluator", "ShardedResult", "default_mesh",
                "select_backend"):
        from repro.distributed import sharded_evaluator as _se

        return getattr(_se, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
