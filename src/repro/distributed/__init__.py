"""Distribution layer: sharding rules, collective helpers, compression."""
