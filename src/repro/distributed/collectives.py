"""Collective helpers: compressed DP all-reduce, sharded evaluation wrapper."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import shard_map
from repro.train import compression


def compressed_psum(grads, axis_name: str, method: str = "none",
                    error_state=None):
    """All-reduce a gradient pytree over ``axis_name`` with compression.

    * none — plain fp32 psum.
    * bf16 — cast → psum → cast (halves collective bytes).
    * int8 — error-feedback quantization; scales are psum-maxed so every
      member dequantizes identically.  Returns (mean_grads, new_error_state).
    """
    n = jax.lax.psum(1, axis_name)
    if method == "none":
        out = jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, grads)
        return out, error_state
    if method == "bf16":
        c = compression.compress_bf16(grads)
        out = jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.float32), axis_name) / n, c)
        return out, error_state
    if method == "int8":
        # agree on a shared scale FIRST (tiny pmax), then quantize with it —
        # quantizing locally and dequantizing globally would be biased.
        shared_scale = jax.tree.map(
            lambda g, e: jax.lax.pmax(
                compression.local_absmax(g, e), axis_name) / 127.0,
            grads, error_state)
        out, new_err = {}, {}
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(error_state)
        flat_s = jax.tree.leaves(shared_scale)
        outs, errs = [], []
        for g, e, s in zip(flat_g, flat_e, flat_s):
            q, _, ne = compression.quantize_int8(g, e, s)
            # psum over int8 payload (collective bytes = 1/4 of fp32)
            total = jax.lax.psum(q.astype(jnp.int32), axis_name)
            outs.append(total.astype(jnp.float32) * s / n)
            errs.append(ne)
        return (jax.tree.unflatten(treedef, outs),
                jax.tree.unflatten(treedef, errs))
    raise ValueError(f"unknown compression method {method!r}")


def sharded_evaluate(batch, measures: Tuple[str, ...], mesh,
                     query_axes=("data",), relevance_level: float = 1.0):
    """Shard an EvalBatch over the query axis and evaluate in parallel.

    The pytrec_eval pattern at pod scale: each device evaluates its local
    slice of queries with the batched measure core; one psum of sufficient
    statistics yields corpus means.  Returns dict of scalars.
    """
    from repro.core import measures as M
    from repro.core import streaming

    parsed = M.parse_measures(measures)
    axes = query_axes if len(query_axes) > 1 else query_axes[0]

    def local_eval(b):
        state = streaming.metric_init(measures)
        state = streaming.metric_update(state, b, measures, relevance_level)
        count = jax.lax.psum(state["__count"], query_axes)
        out = {}
        for k, v in state.items():
            if k == "__count":
                continue
            out[k] = jax.lax.psum(v, query_axes) / jnp.maximum(count, 1.0)
        return out

    qspec = P(axes)
    dspec = P(axes, None)
    in_specs = M.EvalBatch(
        scores=dspec, tiebreak=dspec, rel=dspec, judged=dspec, mask=dspec,
        ideal_rel=dspec, n_rel=qspec, n_judged_nonrel=qspec, query_mask=qspec)
    return shard_map(
        local_eval, mesh=mesh, in_specs=(in_specs,),
        out_specs=P(), check_vma=False)(batch)
