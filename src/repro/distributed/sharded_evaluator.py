"""End-to-end multi-device evaluation on the tokenized (``RunBuffer``) path.

This is the ROADMAP's "sharded evaluation builds on the tokenized ingest
path" milestone: one call that scales from a single CPU to a full TPU mesh
with no per-query Python.  The pipeline is

    qrel/run files ──parse_run_arrays──► RunBuffer      (strings paid once)
    RunBuffer ──batch_from_buffer(q_multiple=mesh)──► EvalBatch  (padded)
    EvalBatch ──shard_map over the query axis──► per-device shard
    shard: sort_batch → make_scalars → fused Pallas kernel (all measures)
    aggregates: metric_update_cols → metric_finalize(axis_name)  (one psum)

Per-query results come back as one ``[Q, K]`` gather (out_spec sharded over
the query axis); aggregates are psum-reduced sufficient statistics, so the
collective payload is K+1 scalars per device regardless of corpus size.

Bit-identity: every per-query measure is computed row-independently (each
query's documents live in one row), so sharding the query axis cannot change
any value — mesh sizes 1, 2, 4, ... produce byte-identical outputs for the
same input (``tests/test_sharded.py`` asserts this on synthetic data).
Against :meth:`RelevanceEvaluator.evaluate` the contract is: the fused
kernel divides exactly where ``core.measures`` divides (see
``kernels.fused_measures._sdiv``), so results are bit-identical whenever the
per-rank cumulative sums are exactly representable (integer judgments at
fixture scale — the conformance acceptance tests); on arbitrary float gains
the kernel's log-step VMEM scan may associate a long sum differently from
``jnp.cumsum`` and drift by ~1 ulp (observed: ``ndcg_cut_k`` at 1.2e-7).
Measures without a fused-kernel column (``num_ret``, ``num_rel``,
``iprec_at_recall_*``, non-standard cutoffs) fall back to the reference
measure core inside the same shard and match it exactly.

Usage::

    from repro.core import RelevanceEvaluator
    from repro.distributed.sharded_evaluator import ShardedEvaluator

    ev = RelevanceEvaluator(qrel, {"map", "ndcg"})
    sev = ShardedEvaluator(ev)            # 1-D mesh over jax.devices()
    result = sev.evaluate(run)            # or .evaluate_buffer(buf, scores)
    result.per_query["q1"]["map"], result.aggregates["map"]
"""

from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import measures as M
from repro.core import streaming
from repro.core.evaluator import concat_run_buffers
from repro.distributed import shard_map
from repro.kernels import bucketing, ops


class ShardedResult(NamedTuple):
    """Per-query results (pytrec_eval layout) + corpus-mean aggregates."""

    per_query: Dict[str, Dict[str, float]]
    aggregates: Dict[str, float]


@functools.lru_cache(maxsize=None)
def default_mesh(axis_name: str = "data"):
    """One shared 1-D mesh spanning every visible device.

    Memoized so every :class:`ShardedEvaluator` built without an explicit
    mesh (each serve-layer collection, every CLI ``--sharded`` call in a
    process) reuses ONE mesh object — and therefore one jit cache entry per
    batch geometry — instead of re-creating meshes per collection.
    """
    return jax.make_mesh((len(jax.devices()),), (axis_name,))


def select_backend(backend: str = "auto") -> str:
    """Resolve an evaluation-backend name to ``"single"`` or ``"sharded"``.

    ``"auto"`` picks the sharded pipeline exactly when more than one device
    is visible — on a 1-device host the single-device evaluator computes the
    same values without the shard_map dispatch overhead.  The serve layer
    calls this once per collection registration.
    """
    if backend in ("single", "sharded"):
        return backend
    if backend != "auto":
        raise ValueError(f"unknown backend {backend!r} "
                         "(expected auto|single|sharded)")
    return "sharded" if len(jax.devices()) > 1 else "single"


class ShardedEvaluator:
    """Shard a :class:`RelevanceEvaluator`'s batches across a device mesh.

    ``mesh`` must be 1-D (the query axis); it defaults to all visible
    devices.  The wrapped evaluator supplies the interned qrel state, the
    measure set, and the relevance level, so sharded results are directly
    comparable to its single-device ``evaluate``.

    ``interpret`` forwards to the Pallas kernel.  The default SNAPSHOTS the
    module-wide ``kernels.ops.INTERPRET`` (backend-resolved: compiled on
    TPU, interpret elsewhere) at *construction* time — the value is baked
    into the compiled dispatch closure, so flipping ``ops.INTERPRET``
    afterwards does not affect an existing instance.  Build a new
    ``ShardedEvaluator`` (or pass ``interpret=`` explicitly) to change
    mode; see the ``kernels.ops`` docstring for the full precedence rules.
    """

    def __init__(self, evaluator, mesh=None, interpret: Optional[bool] = None):
        self.evaluator = evaluator
        self.mesh = mesh if mesh is not None else default_mesh()
        if len(self.mesh.axis_names) != 1:
            raise ValueError(
                f"need a 1-D query mesh, got axes {self.mesh.axis_names}")
        self.axis_name = self.mesh.axis_names[0]
        self.n_shards = int(np.prod(self.mesh.devices.shape))
        self.interpret = ops.INTERPRET if interpret is None else interpret
        self.keys: Tuple[str, ...] = tuple(evaluator.measure_keys)
        # Measures the fused kernel does not emit ride the reference core.
        self._rest = tuple(k for k in self.keys if k not in ops.FUSED_COLUMNS)
        self._dispatch = self._build_dispatch()

    @classmethod
    def from_files(cls, qrel_path: str, run_path: str, measures=None,
                   relevance_level: int = 1, mesh=None,
                   interpret: Optional[bool] = None):
        """Build (ShardedEvaluator, RunBuffer) straight from TREC files.

        The run file is parsed with ``trec.parse_run_arrays`` into flat
        arrays and tokenized once via ``buffer_from_arrays`` — the
        dict-of-dicts representation is never materialized.
        """
        from repro.core import RelevanceEvaluator, supported_measures, trec

        qrel = trec.load_qrel(qrel_path)
        ev = RelevanceEvaluator(qrel, measures or supported_measures,
                                relevance_level=relevance_level)
        buf = ev.buffer_from_arrays(*trec.load_run_arrays(run_path))
        return cls(ev, mesh=mesh, interpret=interpret), buf

    # -- the sharded computation ---------------------------------------------

    def _build_dispatch(self):
        level = self.evaluator.relevance_level
        judged_only = self.evaluator.judged_docs_only
        keys = self.keys
        rest = self._rest
        rest_parsed = M.parse_measures(rest) if rest else ()
        interpret = self.interpret
        axis = self.axis_name

        def local_eval(batch: M.EvalBatch):
            # One shard: rank locally, one fused VMEM pass for all standard
            # measures, reference core for the remainder.  Under
            # judged_docs_only the sort drops unjudged docs to the tail as
            # inert padding, so the fused columns stay correct unchanged.
            bucketing.record_trace("sharded_dispatch")  # once per signature
            s = M.sort_batch(batch, level, judged_only)
            scal = ops.make_scalars(batch.n_rel, batch.n_judged_nonrel,
                                    batch.ideal_rel)
            cols = ops.fused_measures_cols(s.rel, s.judged, scal,
                                           relevance_level=level,
                                           interpret=interpret)
            qm = batch.query_mask
            zero = jnp.zeros_like(batch.n_rel)
            per_query = {
                name: jnp.where(qm, cols[:, i], zero)
                for i, name in enumerate(ops.FUSED_COLUMNS) if name in keys
            }
            if rest_parsed:
                per_query.update(M.compute_measures(batch, rest_parsed, level,
                                                    judged_only))
            stacked = jnp.stack([per_query[k] for k in keys], axis=-1)
            # Aggregates: (sum, count) sufficient statistics, one psum.
            state = {k: jnp.zeros((), jnp.float32) for k in keys}
            state["__count"] = jnp.zeros((), jnp.float32)
            state = streaming.metric_update_cols(state, per_query, qm)
            aggs = streaming.metric_finalize(state, axis_name=axis)
            return stacked, aggs

        qspec = P(axis)
        dspec = P(axis, None)
        in_specs = M.EvalBatch(
            scores=dspec, tiebreak=dspec, rel=dspec, judged=dspec, mask=dspec,
            ideal_rel=dspec, n_rel=qspec, n_judged_nonrel=qspec,
            query_mask=qspec)
        return jax.jit(shard_map(
            local_eval, mesh=self.mesh, in_specs=(in_specs,),
            out_specs=(dspec, P()), check_vma=False))

    # -- entry points ---------------------------------------------------------

    def evaluate(self, run_or_buffer) -> ShardedResult:
        """Evaluate a ``{qid: {docno: score}}`` run or a ``RunBuffer``."""
        from repro.core.evaluator import RunBuffer

        if isinstance(run_or_buffer, RunBuffer):
            return self.evaluate_buffer(run_or_buffer)
        return self.evaluate_buffer(
            self.evaluator.tokenize_run(run_or_buffer))

    def evaluate_buffer(self, buf, scores=None) -> ShardedResult:
        """Evaluate a pre-tokenized buffer (optionally with fresh scores)."""
        if not len(buf):
            return ShardedResult({}, {})
        batch = self.evaluator.batch_from_buffer(
            buf, scores, q_multiple=self.n_shards)
        stacked, aggs = self._dispatch(batch)
        nq = len(buf.qids)
        table = np.asarray(stacked)[:nq]
        per_query = self._rows_to_dicts(buf.qids, table)
        return ShardedResult(per_query, M.finalize_aggregates(
            {k: float(v) for k, v in aggs.items()}))

    def evaluate_buffers(self, bufs: Sequence) -> List[ShardedResult]:
        """Evaluate several buffers in ONE sharded dispatch (serve layer).

        The multi-device counterpart of
        :meth:`repro.core.RelevanceEvaluator.evaluate_buffers`: the buffers
        are stacked on the query axis, padded to the mesh, and shard_mapped
        once; per-query rows split back by each buffer's query count.  The
        device-side psum aggregates cover the whole coalesced batch, so
        per-request aggregates are recomputed on host from each request's
        rows with the same (sum / count) formula.
        """
        bufs = list(bufs)
        if not bufs:
            return []
        nonempty = [b for b in bufs if len(b)]
        if not nonempty:
            return [ShardedResult({}, {}) for _ in bufs]
        big = concat_run_buffers(nonempty)
        batch = self.evaluator.batch_from_buffer(
            big, q_multiple=self.n_shards)
        stacked, _ = self._dispatch(batch)
        table = np.asarray(stacked)[:len(big.qids)]
        results: List[ShardedResult] = []
        lo = 0
        for buf in bufs:
            nq = len(buf.qids)
            rows = table[lo:lo + nq]
            lo += nq
            if not nq:
                results.append(ShardedResult({}, {}))
                continue
            aggs = {k: float(rows[:, j].sum(dtype=np.float32) / np.float32(nq))
                    for j, k in enumerate(self.keys)}
            results.append(ShardedResult(
                self._rows_to_dicts(buf.qids, rows),
                M.finalize_aggregates(aggs)))
        return results

    def evaluate_table(self, bufs: Sequence) -> np.ndarray:
        """Raw per-query measure rows for several buffers in ONE dispatch.

        The sweep-tensor primitive behind
        :func:`repro.core.sweep.evaluate_sweep`'s ``backend="sharded"``
        path: buffers are stacked on the query axis, padded to the mesh,
        shard_mapped once, and the unpadded ``[sum(len(b)), len(self.keys)]``
        float32 row block comes back with no per-query dict materialization
        — the caller reshapes it into the ``[K, Q, M]`` sweep tensor.
        """
        bufs = [b for b in bufs if len(b)]
        if not bufs:
            return np.empty((0, len(self.keys)), dtype=np.float32)
        big = concat_run_buffers(bufs) if len(bufs) > 1 else bufs[0]
        batch = self.evaluator.batch_from_buffer(
            big, q_multiple=self.n_shards)
        stacked, _ = self._dispatch(batch)
        return np.asarray(stacked)[:len(big.qids)]

    def _rows_to_dicts(self, qids, table) -> Dict[str, Dict[str, float]]:
        return {
            qid: {k: float(table[i, j]) for j, k in enumerate(self.keys)}
            for i, qid in enumerate(qids)
        }
