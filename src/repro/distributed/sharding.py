"""Per-family sharding rules (PartitionSpecs) for the production mesh.

Axis convention (see launch/mesh.py):
  * ``data`` (+ ``pod`` when multi-pod) — batch / query axes (DP).
  * ``model`` — tensor-parallel axis: attention heads, FFN hidden, vocab,
    experts (EP), embedding-table rows, candidate sets, KV-cache sequence.

Models never hardcode specs; they receive a ``Sharding`` object and call
:func:`constrain`, which is a no-op when running unsharded (smoke tests).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def constrain(x, spec: Optional[P]):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


@dataclasses.dataclass(frozen=True)
class LMSharding:
    """Megatron-style TP + DP (+ optional FSDP for expert weights)."""

    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp_experts: bool = False
    # FSDP materialization: "gather" weights (train) or "activation"
    # (decode: gather the few tokens instead — see models/moe.py).
    moe_fsdp_mode: str = "gather"
    # decode: shard the KV-cache sequence axis over `model` (flash-decoding).
    shard_cache_seq: bool = True

    @property
    def batch(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    # --- activations ---
    def act(self):  # [B, S, D]
        return P(self.batch, None, None)

    def act_heads(self):  # [B, S, H, hd] — heads TP-sharded
        return P(self.batch, None, self.model_axis, None)

    def logits(self):  # [B, S, V] — vocab TP-sharded
        return P(self.batch, None, self.model_axis)

    def cache(self):  # [B, KV, S, hd]
        seq = self.model_axis if self.shard_cache_seq else None
        return P(self.batch, None, seq, None)

    # --- parameters ---
    def p_embed(self):  # [V, D]
        return P(self.model_axis, None)

    def p_attn_in(self):  # [D, H*hd] — column parallel
        return P(None, self.model_axis)

    def p_attn_out(self):  # [H*hd, D] — row parallel
        return P(self.model_axis, None)

    def p_ffn_in(self):  # [D, F]
        return P(None, self.model_axis)

    def p_ffn_out(self):  # [F, D]
        return P(self.model_axis, None)

    def p_norm(self):
        return P(None)

    def p_router(self):  # [D, E]
        return P()

    def p_expert_in(self):  # [E, D, F] — EP over model (+ FSDP over data)
        fsdp = self.data_axes[-1] if self.fsdp_experts else None
        return P(self.model_axis, None, fsdp)

    def p_expert_out(self):  # [E, F, D]
        fsdp = self.data_axes[-1] if self.fsdp_experts else None
        return P(self.model_axis, fsdp, None)

    def fsdp_axis(self) -> Optional[str]:
        return self.data_axes[-1] if self.fsdp_experts else None


@dataclasses.dataclass(frozen=True)
class GNNSharding:
    """Edges sharded over the full mesh; small feature dim replicated."""

    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    def edges(self):  # [E] / [E, F]
        return P((*self.data_axes, self.model_axis))

    def nodes(self):  # [N, F] — nodes over data
        return P(self.batch, None)

    @property
    def batch(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    def p_weight(self):
        return P(None, None)


@dataclasses.dataclass(frozen=True)
class RecSysSharding:
    """Embedding tables row-sharded over `model` (vocab-parallel); DP batch."""

    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def batch(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    def p_table(self):  # [V_total, E]
        return P(self.model_axis, None)

    def p_dense(self):
        return P(None, None)

    def act(self):  # [B, ...]
        return P(self.batch)

    def candidates(self):  # [N_cand, E] — candidate set over model
        return P(self.model_axis, None)
