"""Pallas TPU kernels for the framework's compute hot spots.

* ``topk``            — blocked top-K over the document axis (ranking sort).
* ``fused_measures``  — every trec_eval measure in one VMEM pass.
* ``embedding_bag``   — scalar-prefetch gather + segment-sum (recsys tables).

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in
``ops.py``.  On this CPU container they run in interpret mode; on TPU set
``ops.INTERPRET = False``.
"""

from repro.kernels import ops, ref  # noqa: F401
