"""Pallas TPU kernels for the framework's compute hot spots.

* ``topk``            — blocked top-K over the document axis (ranking sort).
* ``fused_measures``  — every trec_eval measure in one VMEM pass.
* ``embedding_bag``   — scalar-prefetch gather + segment-sum (recsys tables).
* ``bucketing``       — power-of-two shape classes + retrace accounting.
* ``autotune``        — roofline-driven ``block_q`` selection.

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd wrapper
in ``ops.py``.  Execution mode is backend-resolved at import
(``ops.INTERPRET``: compiled on TPU, interpret elsewhere; override with
the ``REPRO_INTERPRET`` env var or per call) — see the ``ops`` module
docstring for the full precedence rules.
"""

from repro.kernels import bucketing  # noqa: F401  (dependency-free; first)
from repro.kernels import autotune, ops, ref  # noqa: F401
