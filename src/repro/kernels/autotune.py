"""Roofline-driven ``block_q`` selection for the fused measure kernel.

The fused kernel (``kernels/fused_measures.py``) tiles the query axis:
each grid step holds a ``[block_q, D]`` relevance/judged tile plus its
cumulative-sum temporaries in VMEM.  The right ``block_q`` is a pure
occupancy question — the largest tile whose working set still fits the
on-chip budget — so it is derived from the same device model the roofline
analysis uses (``repro.analysis.roofline``: :data:`~repro.analysis.roofline.VMEM_BYTES`,
peak HBM bandwidth) rather than hand-tuned per call site:

* bigger ``block_q`` → fewer grid steps, better amortization of the
  per-step DMA latency, larger sequential HBM reads (the kernel is
  memory-bound — see ``kernels_roofline`` in ``--only kernels``);
* too big → the live tiles (two inputs, the scalar block, the output
  block, and ~2 cumsum temporaries at scan peak) spill out of VMEM and
  the compiler serializes.

``fused_measures(block_q=None)`` and ``ShardedEvaluator`` consult
:func:`block_q_for`; passing an explicit ``block_q`` still overrides it
everywhere.  The choice is a deterministic function of shape, so it never
adds compiled signatures beyond the bucketed shape classes.
"""

from __future__ import annotations

import functools
from typing import Optional

from repro.analysis import roofline

#: f32 [block_q, D] tiles live simultaneously at the scan's peak:
#: rel + judged inputs, ~2 shifted-add cumsum temporaries, and the
#: (lane-padded) output block counted as one D-wide tile equivalent.
LIVE_TILES = 5

#: block_q search range: powers of two; 8 sublanes is the floor one VPU
#: tile occupies, 128 bounds padding waste for small query counts.
MIN_BLOCK_Q = 8
MAX_BLOCK_Q = 128

#: leave half of VMEM to the compiler (double-buffered DMA, spills).
VMEM_HEADROOM = 0.5


@functools.lru_cache(maxsize=None)
def block_q_for(q: int, d: int, vmem_bytes: Optional[int] = None) -> int:
    """The query-tile height for a ``[q, d]`` fused-measures problem.

    Largest power of two in ``[MIN_BLOCK_Q, MAX_BLOCK_Q]`` whose
    ``LIVE_TILES`` resident ``[block_q, d]`` f32 tiles fit the VMEM
    budget, clamped down so one block never exceeds the (bucketed) query
    extent by more than the mandatory padding block.  Deterministic and
    memoized — the same shape always tunes to the same kernel.

    >>> block_q_for(1024, 64)
    128
    >>> block_q_for(1024, 1 << 16) < block_q_for(1024, 1 << 10)
    True
    >>> block_q_for(4, 64)
    8
    """
    budget = (roofline.VMEM_BYTES if vmem_bytes is None else vmem_bytes)
    budget *= VMEM_HEADROOM
    bq = MAX_BLOCK_Q
    while bq > MIN_BLOCK_Q and LIVE_TILES * bq * max(d, 1) * 4 > budget:
        bq //= 2
    # Don't tile wider than the problem: a [128, D] block for an 8-query
    # batch is pure padding traffic.
    while bq > MIN_BLOCK_Q and bq > max(q, 1):
        bq //= 2
    return bq
