"""Gather-free bitonic sorting network for Pallas TPU kernels.

trec_eval's hot loop is a qsort over (score, docno); on TPU the equivalent is
a vectorized sorting network.  Every compare-exchange stage is expressed as a
reshape + min/max/select over contiguous sub-blocks — no gathers — so it maps
onto the VPU's 8×128 lanes.

Total order ("precedes"): x before y  iff  x.value > y.value, ties broken by
smaller index first — exactly trec_eval's score-desc / tiebreak-asc ranking
(see ``core.sorting``).

All lengths must be powers of two (callers pad with -inf / INT32_MAX).
"""

from __future__ import annotations

import jax.numpy as jnp


def _compare_exchange(lo_v, lo_i, hi_v, hi_i, desc):
    """One compare-exchange; ``desc`` True reverses the segment direction."""
    lo_first = (lo_v > hi_v) | ((lo_v == hi_v) & (lo_i < hi_i))
    hi_first = (hi_v > lo_v) | ((hi_v == lo_v) & (hi_i < lo_i))
    swap = jnp.where(desc, lo_first, hi_first)
    new_lo_v = jnp.where(swap, hi_v, lo_v)
    new_hi_v = jnp.where(swap, lo_v, hi_v)
    new_lo_i = jnp.where(swap, hi_i, lo_i)
    new_hi_i = jnp.where(swap, lo_i, hi_i)
    return new_lo_v, new_lo_i, new_hi_v, new_hi_i


def _stage(v, i, j, k):
    """Compare-exchange at pair-distance ``j`` within segments of size ``k``."""
    n = v.shape[-1]
    g = n // (2 * j)
    vr = v.reshape(g, 2, j)
    ir = i.reshape(g, 2, j)
    # Each group of 2j consecutive elements pairs element b with element b+j;
    # the segment direction flips with bit log2(k) of the element index.
    grp = (jnp.arange(g, dtype=jnp.int32) * (2 * j)) // k
    desc = (grp % 2 == 1)[:, None]
    lo_v, lo_i, hi_v, hi_i = _compare_exchange(
        vr[:, 0, :], ir[:, 0, :], vr[:, 1, :], ir[:, 1, :], desc
    )
    v_out = jnp.stack([lo_v, hi_v], axis=1).reshape(n)
    i_out = jnp.stack([lo_i, hi_i], axis=1).reshape(n)
    return v_out, i_out


def sort_desc(v, i):
    """Full bitonic sort of (values, indices) into precedes order."""
    n = v.shape[-1]
    assert n & (n - 1) == 0, "bitonic sort needs a power-of-two length"
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            v, i = _stage(v, i, j, k)
            j //= 2
        k *= 2
    return v, i


def merge_desc(v, i):
    """Bitonic merge: input must be bitonic wrt the precedes order
    (e.g. the concatenation of a precedes-sorted and a reversed
    precedes-sorted array); output is fully precedes-sorted."""
    n = v.shape[-1]
    assert n & (n - 1) == 0
    j = n // 2
    while j >= 1:
        v, i = _stage(v, i, j, 2 * n)  # k=2n → every direction ascending
        j //= 2
    return v, i
