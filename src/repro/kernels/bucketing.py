"""Power-of-two shape bucketing + trace-time recompile accounting.

Every jit'd engine in this codebase (the measure core, the fused Pallas
kernel, the sharded dispatch) compiles once per *shape signature*.  Left
unbucketed, the serve layer's variable wave sizes — a coalesced batch of k
requests has a query axis proportional to k — would trigger one XLA
compile per distinct wave, re-introducing exactly the fixed per-call
overhead the paper set out to kill.  This module centralizes the fix:

* **padding classes** — batch extents are padded UP to the next power of
  two (``bucket_queries`` / ``bucket_docs``), so every possible extent in
  ``[1, max]`` maps onto one of ``log2(max) + O(1)`` classes.  A
  concurrency sweep over any number of distinct wave sizes therefore
  compiles at most ``log2(max_batch) + O(1)`` signatures, not one per
  wave.  Padded rows/columns carry ``mask == False`` and are inert for
  every measure, so bucketing never changes a value;
* **recompile accounting** — :func:`record_trace` is called from INSIDE
  the jit'd function bodies.  Python side effects in a traced function run
  exactly once per trace (i.e. once per compiled signature), so the
  counters are a true retrace count: tests assert the closed-set property
  directly (``tests/test_bucketing.py``) and ``benchmarks.run --only
  kernels`` reports it next to achieved bandwidth.

The module is dependency-free (no jax, no numpy) so any layer — the
evaluator's host-side padding, the kernels, the benchmarks — can import it
without cycles.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterable, Optional, Tuple

__all__ = [
    "next_pow2", "bucket_queries", "bucket_docs", "padding_classes",
    "max_signatures", "record_trace", "compile_count", "trace_counts",
    "reset_trace_counts",
]

#: default minimum document-axis bucket (matches the evaluator's historical
#: padding floor; one VPU lane group is never worth splitting below)
MIN_DOC_BUCKET = 8


def next_pow2(n: int, minimum: int = 1) -> int:
    """Smallest power of two (times ``minimum``) that is >= ``n``.

    ``minimum`` must itself be the smallest admissible bucket; the result
    is ``minimum * 2**j`` for the smallest ``j`` with that product >= n.

    >>> [next_pow2(n) for n in (1, 2, 3, 9, 1000)]
    [1, 2, 4, 16, 1024]
    """
    b = minimum
    while b < n:
        b *= 2
    return b


def bucket_queries(nq: int, minimum: int = 1, multiple: int = 1) -> int:
    """Padding class for a query-axis extent.

    Power-of-two bucketing, then rounded up to ``multiple`` so the batch
    divides evenly over a device mesh (``ShardedEvaluator`` passes its
    shard count).  For a fixed ``multiple`` the image of ``[1, max]`` is
    still a closed set of ``log2(max) + O(1)`` classes.

    >>> bucket_queries(37)
    64
    >>> bucket_queries(5, multiple=3)
    9
    """
    b = next_pow2(max(nq, 1), minimum)
    if multiple > 1:
        b = ((b + multiple - 1) // multiple) * multiple
    return b


def bucket_docs(nd: int, minimum: int = MIN_DOC_BUCKET) -> int:
    """Padding class for a document- (or judged-) axis extent.

    >>> bucket_docs(100), bucket_docs(3), bucket_docs(1000)
    (128, 8, 1024)
    """
    return next_pow2(max(nd, 1), minimum)


def padding_classes(max_n: int, minimum: int = 1,
                    multiple: int = 1) -> Tuple[int, ...]:
    """The closed set of classes extents in ``[1, max_n]`` can map to.

    This is what "recompile-proof" means operationally: however many
    distinct raw extents a workload produces, the compiled-signature count
    is bounded by ``len(padding_classes(max_n))``.

    >>> padding_classes(16)
    (1, 2, 4, 8, 16)
    """
    out = []
    b = minimum
    while True:
        c = bucket_queries(b, minimum, multiple)
        if not out or c != out[-1]:
            out.append(c)
        if c >= max_n and b >= max_n:
            break
        b *= 2
    return tuple(out)


def max_signatures(max_n: int, minimum: int = 1, multiple: int = 1) -> int:
    """Upper bound on compiled signatures for extents in ``[1, max_n]``."""
    return len(padding_classes(max_n, minimum, multiple))


# ---------------------------------------------------------------------------
# Trace-time compile counters.
# ---------------------------------------------------------------------------

_lock = threading.Lock()
_counts: Dict[str, int] = {}


def record_trace(name: str) -> None:
    """Count one retrace of the named engine.

    Call from INSIDE a jit'd function body: the call executes at trace
    time only, so each increment corresponds to one new compiled
    signature entering that engine's jit cache.  Thread-safe (traces can
    run on executor threads).
    """
    with _lock:
        _counts[name] = _counts.get(name, 0) + 1


def compile_count(name: Optional[str] = None) -> int:
    """Retraces recorded for ``name`` (or the total across all engines)."""
    with _lock:
        if name is not None:
            return _counts.get(name, 0)
        return sum(_counts.values())


def trace_counts() -> Dict[str, int]:
    """Snapshot of every engine's retrace count (for ``--only kernels``)."""
    with _lock:
        return dict(_counts)


def reset_trace_counts(names: Optional[Iterable[str]] = None) -> None:
    """Zero the counters (all of them, or just ``names``).

    Note this resets the *accounting*, not the process-global jit caches:
    a shape compiled before the reset will not retrace afterwards.  Tests
    should assert on deltas with fresh static signatures instead.
    """
    with _lock:
        if names is None:
            _counts.clear()
        else:
            for n in names:
                _counts.pop(n, None)
