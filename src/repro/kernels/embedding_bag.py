"""EmbeddingBag Pallas TPU kernel: scalar-prefetch gather + segment-sum.

JAX has no native EmbeddingBag; the recsys substrate builds one from
``jnp.take`` + ``segment_sum`` (see ``models/embedding.py``).  That reference
path materializes the full [L, E] gathered matrix in HBM before reducing.
This kernel instead streams table rows through VMEM and accumulates directly
into the output bag, the classic TPU sparse pattern:

* lookup indices and bag (segment) ids ride in scalar-prefetch memory (SMEM),
  available *before* the grid step runs, so the BlockSpec ``index_map`` can
  select which table row block to DMA next — data-dependent addressing without
  a gather op;
* lookups are pre-sorted by bag id; consecutive grid steps that land in the
  same output bag revisit the same output block, so the accumulation is a
  VMEM add (first visit initializes, others accumulate);
* every bag is seeded with one zero-weight dummy lookup so empty bags are
  still written (Pallas outputs are undefined unless written).

Weights make this a weighted bag (mean combining divides by count outside).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import bucketing


def _kernel(idx_ref, seg_ref, w_ref, table_ref, out_ref):
    i = pl.program_id(0)
    row = table_ref[0, :] * w_ref[i]
    is_first = jnp.logical_or(i == 0, seg_ref[i] != seg_ref[jnp.maximum(i - 1, 0)])

    @pl.when(is_first)
    def _init():
        out_ref[0, :] = row

    @pl.when(jnp.logical_not(is_first))
    def _acc():
        out_ref[0, :] = out_ref[0, :] + row


@functools.partial(jax.jit, static_argnames=("n_bags", "interpret"))
def embedding_bag(table: jax.Array, indices: jax.Array, segment_ids: jax.Array,
                  n_bags: int, weights: jax.Array | None = None,
                  interpret: bool = True) -> jax.Array:
    """Weighted sum of table rows per bag.

    Args:
      table:       [V, E] embedding table (HBM-resident; rows DMA'd on demand).
      indices:     [L] int32 row ids, **sorted by segment_ids**.
      segment_ids: [L] int32 bag ids, sorted ascending, each < n_bags.
      n_bags:      number of output bags B.
      weights:     optional [L] f32 per-lookup weights (default 1.0).

    Returns: [B, E] f32.
    """
    bucketing.record_trace("embedding_bag")  # trace-time: one per signature
    v, e = table.shape
    l = indices.shape[0]
    if weights is None:
        weights = jnp.ones((l,), dtype=table.dtype)
    # Seed every bag with a zero-weight row-0 lookup so empty bags are zeroed.
    seed_idx = jnp.zeros((n_bags,), jnp.int32)
    seed_seg = jnp.arange(n_bags, dtype=jnp.int32)
    seed_w = jnp.zeros((n_bags,), weights.dtype)
    all_idx = jnp.concatenate([seed_idx, indices.astype(jnp.int32)])
    all_seg = jnp.concatenate([seed_seg, segment_ids.astype(jnp.int32)])
    all_w = jnp.concatenate([seed_w, weights])
    order = jnp.argsort(all_seg, stable=True)
    all_idx, all_seg, all_w = all_idx[order], all_seg[order], all_w[order]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(l + n_bags,),
        in_specs=[
            pl.BlockSpec((1, e), lambda i, idx, seg, w: (idx[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, e), lambda i, idx, seg, w: (seg[i], 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, e), table.dtype),
        interpret=interpret,
    )(all_idx, all_seg, all_w, table)
