"""Fused one-pass measure kernel (pytrec_eval's C loop, TPU-native).

trec_eval computes every requested measure in a single walk over the sorted
ranking.  A naive JAX translation materializes a separate [Q, D] intermediate
per measure family (cumsum for AP, another for DCG, another for bpref, ...) —
each one an HBM round trip.  This kernel keeps a [block_q, D] tile of the
rank-sorted relevance in VMEM and computes *all* measures in one visit:
cumulative sums are log2(D) shifted adds in VMEM, cutoff reads are static
slices, and only a [block_q, 64] measure block leaves the core.

Inputs (already rank-sorted by score desc / tiebreak asc — see core.sorting
or the top-K kernel):
  rel      [Q, D] f32 — judgment of doc at each rank (0 unjudged/padding)
  judged   [Q, D] f32 — 1.0 where the doc is judged
  scalars  [Q, 16] f32 — col 0: R (n_rel), 1: judged-nonrel count,
           2: full-ranking ideal DCG, 3..11: ideal DCG at the 9 cutoffs.

Output: [Q, 64] f32, columns per :data:`COLUMNS`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.registry import DEFAULT_RBP_P
from repro.kernels import autotune, bucketing

CUTOFFS = (5, 10, 15, 20, 30, 100, 200, 500, 1000)
SUCCESS_CUTOFFS = (1, 5, 10)

COLUMNS = (
    ["map", "recip_rank", "ndcg", "bpref", "num_rel_ret", "Rprec"]
    + [f"P_{k}" for k in CUTOFFS]
    + [f"recall_{k}" for k in CUTOFFS]
    + [f"ndcg_cut_{k}" for k in CUTOFFS]
    + [f"map_cut_{k}" for k in CUTOFFS]
    + [f"success_{k}" for k in SUCCESS_CUTOFFS]
    + [f"judged_{k}" for k in CUTOFFS]
    + [f"rbp_{DEFAULT_RBP_P:.2f}"]
)
OUT_WIDTH = 64  # lane-padded; len(COLUMNS) == 55


def _sdiv(num, den):
    """Guarded division, bit-identical to ``core.measures._safe_div``.

    The kernel used to multiply by a precomputed reciprocal (``* inv_r``),
    which is one multiply cheaper but rounds differently from the reference
    engine's division (e.g. ``1.5 / 3 == 0.5`` exactly, while
    ``1.5 * float32(1/3)`` is ``0.50000001``).  The sharded evaluation path
    promises results bit-identical to ``RelevanceEvaluator.evaluate``, so the
    kernel divides exactly as ``core.measures`` does.
    """
    return jnp.where(den > 0, num / jnp.maximum(den, 1e-30), 0.0)


def _cumsum_lanes(x):
    """Inclusive cumsum along the last axis via log2(D) shifted adds.

    Shift-by-pad-and-slice only (static shapes, no gather) — each step is a
    full-tile VPU add, so the whole scan stays in VMEM.
    """
    n = x.shape[-1]
    sh = 1
    while sh < n:
        shifted = jnp.pad(x, ((0, 0), (sh, 0)))[:, :n]
        x = x + shifted
        sh *= 2
    return x


def _at(cum, k):
    d = cum.shape[-1]
    return cum[:, min(k, d) - 1]


def _kernel(rel_ref, judged_ref, scal_ref, out_ref, *, relevance_level):
    rel = rel_ref[...]
    judged = judged_ref[...]
    bq, d = rel.shape
    scal = scal_ref[...]
    n_rel = scal[:, 0]
    n_nonrel = scal[:, 1]
    idcg_full = scal[:, 2]

    ranks = jax.lax.broadcasted_iota(jnp.float32, (bq, d), 1) + 1.0
    binrel = jnp.where(rel >= relevance_level, 1.0, 0.0)
    cum = _cumsum_lanes(binrel)
    prec = cum / ranks

    # -- AP (+ cutoffs) ------------------------------------------------------
    ap_cum = _cumsum_lanes(binrel * prec)
    # -- DCG (+ cutoffs), linear trec_eval gain ------------------------------
    gains = jnp.maximum(rel, 0.0) / (jnp.log2(ranks + 1.0))
    dcg_cum = _cumsum_lanes(gains)
    # -- bpref ---------------------------------------------------------------
    jn = judged * (1.0 - binrel)
    nr_above = _cumsum_lanes(jn) - jn
    bpref_den = jnp.minimum(n_rel, n_nonrel)[:, None]
    bterm = jnp.where(
        nr_above > 0,
        1.0 - _sdiv(jnp.minimum(nr_above, n_rel[:, None]), bpref_den),
        1.0,
    )
    bpref_v = _sdiv(jnp.sum(bterm * binrel, axis=-1), n_rel)
    # -- reciprocal rank -----------------------------------------------------
    num_rel_ret = cum[:, -1]
    any_rel = num_rel_ret > 0
    first_rank = 1.0 + jnp.sum(jnp.where(cum == 0, 1.0, 0.0), axis=-1)
    rr = jnp.where(any_rel, 1.0 / first_rank, 0.0)
    # -- R-precision (dynamic per-row rank R) --------------------------------
    within_r = jnp.where(ranks <= n_rel[:, None], 1.0, 0.0)
    rel_at_r = jnp.sum(binrel * within_r, axis=-1)
    rprec = _sdiv(rel_at_r, n_rel)

    cols = [
        _sdiv(ap_cum[:, -1], n_rel),
        rr,
        _sdiv(dcg_cum[:, -1], idcg_full),
        bpref_v,
        num_rel_ret,
        rprec,
    ]
    for k in CUTOFFS:
        cols.append(_at(cum, k) / float(k))
    for k in CUTOFFS:
        cols.append(_sdiv(_at(cum, k), n_rel))
    for j, k in enumerate(CUTOFFS):
        idcg_k = scal[:, 3 + j]
        cols.append(_sdiv(_at(dcg_cum, k), idcg_k))
    for k in CUTOFFS:
        cols.append(_sdiv(_at(ap_cum, k), n_rel))
    for k in SUCCESS_CUTOFFS:
        cols.append(jnp.where(_at(cum, k) > 0, 1.0, 0.0))
    # -- judged@k (exact: 0/1 counts, the shifted-add cumsum is integral) ----
    cum_judged = _cumsum_lanes(judged)
    for k in CUTOFFS:
        cols.append(_at(cum_judged, k) / float(k))
    # -- RBP, default persistence (same expression as core.measures.rbp) -----
    rbp_w = (1.0 - DEFAULT_RBP_P) * jnp.power(DEFAULT_RBP_P, ranks - 1.0)
    cols.append(jnp.sum(binrel * rbp_w, axis=-1))

    out = jnp.stack(cols, axis=-1)  # [bq, 55]
    out = jnp.pad(out, ((0, 0), (0, OUT_WIDTH - out.shape[-1])))
    out_ref[...] = out


@functools.lru_cache(maxsize=None)
def _measure_call(q_pad: int, d: int, block_q: int, relevance_level: float,
                  interpret: bool):
    """Build the ``pallas_call`` for one shard geometry, memoized.

    The sharded evaluation path (``repro.distributed.sharded_evaluator``)
    invokes the kernel once per device shard; every shard has the identical
    local ``[q_pad/n_shards, d]`` geometry, so the grid/block specs (and the
    closure holding them) are constructed exactly once and reused across
    shards, re-traces, and steps.  Keys are the full static signature —
    anything that changes the lowered kernel.
    """
    kern = functools.partial(_kernel, relevance_level=relevance_level)
    return pl.pallas_call(
        kern,
        grid=(q_pad // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((block_q, 16), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, OUT_WIDTH), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q_pad, OUT_WIDTH), jnp.float32),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("block_q", "relevance_level",
                                             "interpret"))
def fused_measures(rel_sorted, judged_sorted, scalars,
                   block_q: int | None = None,
                   relevance_level: float = 1.0, interpret: bool = True):
    """All 55 standard measure columns in one VMEM pass.  Returns [Q, 64] f32.

    ``block_q=None`` (the default) consults the roofline-driven autotuner
    (``kernels.autotune.block_q_for``) — a deterministic function of the
    ``[Q, D]`` shape, resolved at trace time, so it adds no compiled
    signatures beyond the shape classes themselves.
    """
    bucketing.record_trace("fused_measures")  # trace-time: one per signature
    q, d = rel_sorted.shape
    if block_q is None:
        block_q = autotune.block_q_for(q, d)
    q_pad = ((q + block_q - 1) // block_q) * block_q
    if q_pad != q:
        pad = ((0, q_pad - q), (0, 0))
        rel_sorted = jnp.pad(rel_sorted, pad)
        judged_sorted = jnp.pad(judged_sorted, pad)
        scalars = jnp.pad(scalars, pad)
    out = _measure_call(q_pad, d, block_q, relevance_level, interpret)(
        rel_sorted, judged_sorted, scalars)
    return out[:q]
