"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True everywhere (this container is CPU-only); on a
real TPU deployment set ``repro.kernels.ops.INTERPRET = False`` or pass
``interpret=False``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import measures as M
from repro.kernels import fused_measures as _fm
from repro.kernels import topk as _topk
from repro.kernels import embedding_bag as _eb

INTERPRET = True

FUSED_COLUMNS: Tuple[str, ...] = tuple(_fm.COLUMNS)


def topk(scores, k, block_d=None, interpret=None):
    return _topk.topk(scores, k, block_d=block_d,
                      interpret=INTERPRET if interpret is None else interpret)


def embedding_bag(table, indices, segment_ids, n_bags, weights=None,
                  interpret=None):
    return _eb.embedding_bag(
        table, indices, segment_ids, n_bags, weights=weights,
        interpret=INTERPRET if interpret is None else interpret)


def fused_measures_cols(rel_sorted, judged_sorted, scalars,
                        relevance_level=1.0, interpret=None):
    return _fm.fused_measures(
        rel_sorted, judged_sorted, scalars,
        relevance_level=relevance_level,
        interpret=INTERPRET if interpret is None else interpret)


def make_scalars(n_rel, n_judged_nonrel, ideal_rel):
    """Pack the per-query scalar block consumed by the fused kernel."""
    q = n_rel.shape[0]
    j = ideal_rel.shape[-1]
    ranks = jnp.arange(1, j + 1, dtype=jnp.float32)
    disc = 1.0 / jnp.log2(ranks + 1.0)
    gains = jnp.maximum(ideal_rel, 0.0) * disc
    idcg_full = jnp.sum(gains, axis=-1)
    scal = [n_rel, n_judged_nonrel, idcg_full]
    for k in _fm.CUTOFFS:
        within = (ranks <= k).astype(jnp.float32)
        scal.append(jnp.sum(gains * within, axis=-1))
    out = jnp.stack(scal, axis=-1)  # [Q, 12]
    return jnp.pad(out, ((0, 0), (0, 16 - out.shape[-1])))


def evaluate_fused(batch: M.EvalBatch, relevance_level: float = 1.0,
                   interpret=None):
    """EvalBatch → dict of per-query measures via the fused kernel path.

    Sort with the XLA multi-key sort (exact trec_eval order), then one fused
    VMEM pass for all measures.  This is the optimized beyond-paper engine;
    `core.measures.compute_measures` is the paper-faithful reference engine.
    """
    s = M.sort_batch(batch, relevance_level)
    scal = make_scalars(batch.n_rel, batch.n_judged_nonrel, batch.ideal_rel)
    cols = fused_measures_cols(s.rel, s.judged, scal,
                               relevance_level=relevance_level,
                               interpret=interpret)
    qm = batch.query_mask
    zero = jnp.zeros_like(cols[:, 0])
    return {
        name: jnp.where(qm, cols[:, i], zero)
        for i, name in enumerate(FUSED_COLUMNS)
    }
