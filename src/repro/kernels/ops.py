"""jit'd public wrappers for the Pallas kernels.

Execution-mode (``interpret``) resolution, in priority order:

1. an explicit ``interpret=`` argument on any wrapper call;
2. the module global :data:`INTERPRET`, read at **call time**.  Every
   kernel jit treats ``interpret`` as a *static* argument, so flipping the
   global never mutates a warm executable — it selects a different jit
   cache entry on the next call (both modes can live in the cache side by
   side, and flipping back reuses the earlier entries).  The one caveat:
   objects that snapshot the global at construction —
   :class:`repro.distributed.ShardedEvaluator` captures it into its
   compiled dispatch closure — keep their captured mode for their
   lifetime; rebuild them after flipping (``tests/test_kernels.py``
   pins both behaviours);
3. :data:`INTERPRET` itself is resolved once at import by
   :func:`resolve_interpret`: the ``REPRO_INTERPRET`` environment variable
   wins when set (``1/true/yes/on/interpret`` → interpret,
   ``0/false/no/off/compiled`` → compiled), otherwise the JAX backend
   decides — **compiled (``False``) on TPU**, interpret everywhere else
   (the kernels are Mosaic-TPU programs; CPU/GPU hosts can only interpret
   them).  ``interpret=False`` on a non-TPU backend fails loudly at
   lowering time rather than silently falling back.

The compiled path is the default wherever it is valid; the
compiled-vs-interpret conformance gate in ``tests/test_kernels.py`` keeps
the two modes interchangeable (bit-identical when the resolved default
*is* the interpreter, within the documented ~1-ulp float tolerance when a
real TPU compiles them).
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import measures as M
from repro.kernels import fused_measures as _fm
from repro.kernels import topk as _topk
from repro.kernels import embedding_bag as _eb

_TRUTHY = ("1", "true", "yes", "on", "interpret")
_FALSY = ("0", "false", "no", "off", "compiled")


def resolve_interpret(env: Optional[str] = None,
                      backend: Optional[str] = None) -> bool:
    """Resolve the default Pallas execution mode.

    ``env`` defaults to ``os.environ["REPRO_INTERPRET"]`` and overrides
    everything when non-empty; ``backend`` defaults to
    ``jax.default_backend()``.  Returns True (interpret) unless the
    backend can actually compile the Mosaic-TPU kernels.

    >>> resolve_interpret(env="0")
    False
    >>> resolve_interpret(env="true")
    True
    >>> resolve_interpret(env="", backend="tpu")
    False
    >>> resolve_interpret(env="", backend="cpu")
    True
    """
    if env is None:
        env = os.environ.get("REPRO_INTERPRET")
    if env is not None and env.strip():
        flag = env.strip().lower()
        if flag in _TRUTHY:
            return True
        if flag in _FALSY:
            return False
        raise ValueError(
            f"REPRO_INTERPRET={env!r} not understood "
            f"(truthy: {_TRUTHY}, falsy: {_FALSY})")
    if backend is None:
        backend = jax.default_backend()
    return backend != "tpu"


#: process-wide default execution mode, backend-resolved at import (see
#: the module docstring for the full precedence rules)
INTERPRET = resolve_interpret()

FUSED_COLUMNS: Tuple[str, ...] = tuple(_fm.COLUMNS)


def topk(scores, k, block_d=None, interpret=None):
    return _topk.topk(scores, k, block_d=block_d,
                      interpret=INTERPRET if interpret is None else interpret)


def embedding_bag(table, indices, segment_ids, n_bags, weights=None,
                  interpret=None):
    return _eb.embedding_bag(
        table, indices, segment_ids, n_bags, weights=weights,
        interpret=INTERPRET if interpret is None else interpret)


def fused_measures_cols(rel_sorted, judged_sorted, scalars,
                        relevance_level=1.0, block_q=None, interpret=None):
    """All fused measure columns; ``block_q=None`` → roofline-autotuned."""
    return _fm.fused_measures(
        rel_sorted, judged_sorted, scalars, block_q=block_q,
        relevance_level=relevance_level,
        interpret=INTERPRET if interpret is None else interpret)


def make_scalars(n_rel, n_judged_nonrel, ideal_rel):
    """Pack the per-query scalar block consumed by the fused kernel."""
    q = n_rel.shape[0]
    j = ideal_rel.shape[-1]
    ranks = jnp.arange(1, j + 1, dtype=jnp.float32)
    disc = 1.0 / jnp.log2(ranks + 1.0)
    gains = jnp.maximum(ideal_rel, 0.0) * disc
    idcg_full = jnp.sum(gains, axis=-1)
    scal = [n_rel, n_judged_nonrel, idcg_full]
    for k in _fm.CUTOFFS:
        within = (ranks <= k).astype(jnp.float32)
        scal.append(jnp.sum(gains * within, axis=-1))
    out = jnp.stack(scal, axis=-1)  # [Q, 12]
    return jnp.pad(out, ((0, 0), (0, 16 - out.shape[-1])))


def evaluate_fused(batch: M.EvalBatch, relevance_level: float = 1.0,
                   block_q=None, interpret=None, judged_only: bool = False):
    """EvalBatch → dict of per-query measures via the fused kernel path.

    Sort with the XLA multi-key sort (exact trec_eval order), then one fused
    VMEM pass for all measures.  This is the optimized beyond-paper engine;
    `core.measures.compute_measures` is the paper-faithful reference engine.
    ``judged_only`` drops unjudged retrieved docs before ranking
    (trec_eval ``-J``) — they sort to the tail as inert padding, so the
    fused columns need no changes.
    """
    s = M.sort_batch(batch, relevance_level, judged_only)
    scal = make_scalars(batch.n_rel, batch.n_judged_nonrel, batch.ideal_rel)
    cols = fused_measures_cols(s.rel, s.judged, scal,
                               relevance_level=relevance_level,
                               block_q=block_q, interpret=interpret)
    qm = batch.query_mask
    zero = jnp.zeros_like(cols[:, 0])
    return {
        name: jnp.where(qm, cols[:, i], zero)
        for i, name in enumerate(FUSED_COLUMNS)
    }
