"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import measures as M


def topk_ref(scores: jax.Array, k: int):
    """lax.top_k: same tie semantics (equal values → lower index first)."""
    d = scores.shape[-1]
    if k <= d:
        return jax.lax.top_k(scores, k)
    v, i = jax.lax.top_k(scores, d)
    pad_v = jnp.full(scores.shape[:-1] + (k - d,), -jnp.inf, scores.dtype)
    pad_i = jnp.zeros(scores.shape[:-1] + (k - d,), jnp.int32)
    return jnp.concatenate([v, pad_v], -1), jnp.concatenate([i, pad_i], -1)


def fused_measures_ref(rel_sorted, judged_sorted, scalars,
                       relevance_level: float = 1.0):
    """Column-for-column oracle of kernels.fused_measures via core.measures."""
    from repro.kernels import fused_measures as FM

    q, d = rel_sorted.shape
    # Build a SortedBatch directly (input is already rank-ordered).
    binrel = jnp.where(rel_sorted >= relevance_level, 1.0, 0.0)
    s = M.SortedBatch(
        rel=rel_sorted,
        binrel=binrel,
        judged=judged_sorted,
        mask=jnp.ones_like(rel_sorted),
        cum_rel=jnp.cumsum(binrel, axis=-1),
        ideal_rel=jnp.zeros((q, 1), jnp.float32),  # idcg supplied via scalars
        n_rel=scalars[:, 0],
        n_judged_nonrel=scalars[:, 1],
        n_ret=jnp.full((q,), float(d)),
        query_mask=jnp.ones((q,), bool),
    )
    def safe_div(a, b):
        return jnp.where(b > 0, a / jnp.maximum(b, 1e-30), 0.0)

    cols = {
        "map": M.average_precision(s),
        "recip_rank": M.reciprocal_rank(s),
        "ndcg": safe_div(M.dcg(s), scalars[:, 2]),
        "bpref": M.bpref(s),
        "num_rel_ret": s.cum_rel[:, -1],
        "Rprec": M.r_precision(s),
    }
    for k in FM.CUTOFFS:
        cols[f"P_{k}"] = M.precision_at(s, k)
        cols[f"recall_{k}"] = M.recall_at(s, k)
        cols[f"map_cut_{k}"] = M.map_cut(s, k)
    for j, k in enumerate(FM.CUTOFFS):
        cols[f"ndcg_cut_{k}"] = safe_div(M.dcg(s, k), scalars[:, 3 + j])
    for k in FM.SUCCESS_CUTOFFS:
        cols[f"success_{k}"] = M.success_at(s, k)
    for k in FM.CUTOFFS:
        cols[f"judged_{k}"] = M.judged_at(s, k)
    cols[f"rbp_{FM.DEFAULT_RBP_P:.2f}"] = M.rbp(s, FM.DEFAULT_RBP_P)
    out = jnp.stack([cols[name] for name in FM.COLUMNS], axis=-1)
    return jnp.pad(out, ((0, 0), (0, FM.OUT_WIDTH - out.shape[-1])))


def embedding_bag_ref(table, indices, segment_ids, n_bags, weights=None):
    """jnp.take + segment_sum (the models/embedding.py reference path)."""
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    return jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
