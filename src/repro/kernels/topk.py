"""Blocked top-K Pallas TPU kernel (trec_eval's ranking sort, TPU-native).

The paper's measured hot spot is trec_eval's per-query qsort of the ranking.
On TPU, cutoff measures (P@k / ndcg_cut@k / ... with k ≤ 1000) never need the
full sort: this kernel streams the document axis through VMEM in blocks,
keeping a running top-K candidate buffer, so a 1M-candidate ranking
(``retrieval_cand``) costs one HBM read of the scores and O(D·log²B) VPU work
instead of an O(D log D) global sort with multiple HBM round trips.

Per (query, doc-block) grid step:
  1. bitonic-sort the VMEM block (carrying global doc indices for trec_eval
     tie-breaking: equal scores → smaller index wins);
  2. merge its top-K with the running top-K scratch buffer (a single bitonic
     merge stage — the concatenation of two sorted runs is bitonic);
  3. on the last block, write the scratch buffer out.

Layout notes (TPU target): the block width is a multiple of 128 lanes; the
compare-exchange stages are reshape+select only (no gathers).  Correctness is
validated in interpret mode against ``jax.lax.top_k`` (same tie semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import bitonic, bucketing

NEG_INF = float("-inf")


def _topk_kernel(scores_ref, out_v_ref, out_i_ref, v_scr, i_scr, *, k, block_d,
                 n_dblocks):
    db = pl.program_id(1)
    v = scores_ref[0, :]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, block_d), 1).reshape(block_d)
    idx = idx + db * block_d
    sv, si = bitonic.sort_desc(v, idx)
    bv, bi = sv[:k], si[:k]

    @pl.when(db == 0)
    def _init():
        v_scr[:] = bv
        i_scr[:] = bi

    @pl.when(db > 0)
    def _merge():
        # sorted ++ reversed(sorted) is bitonic → one merge pass suffices.
        mv = jnp.concatenate([v_scr[:], jnp.flip(bv)])
        mi = jnp.concatenate([i_scr[:], jnp.flip(bi)])
        fv, fi = bitonic.merge_desc(mv, mi)
        v_scr[:] = fv[:k]
        i_scr[:] = fi[:k]

    @pl.when(db == n_dblocks - 1)
    def _emit():
        out_v_ref[0, :] = v_scr[:]
        out_i_ref[0, :] = i_scr[:]


def _next_pow2(n: int, minimum: int = 1) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnames=("k", "block_d", "interpret"))
def topk(scores: jax.Array, k: int, block_d: int | None = None,
         interpret: bool = True):
    """Top-k (values, indices) per row of ``scores`` [Q, D], precedes order.

    Ties: smaller index first (trec_eval with index tiebreak).  Rows shorter
    than k are padded with -inf values / out-of-range indices.
    """
    bucketing.record_trace("topk")  # trace-time: one per compiled signature
    q, d = scores.shape
    k2 = _next_pow2(k, 128)  # lane-aligned candidate buffer
    if block_d is None:
        block_d = max(2 * k2, 512)
    block_d = _next_pow2(block_d)
    if block_d < k2:
        raise ValueError("block_d must be >= padded k")
    d_pad = ((d + block_d - 1) // block_d) * block_d
    if d_pad != d:
        scores = jnp.pad(scores, ((0, 0), (0, d_pad - d)),
                         constant_values=NEG_INF)
    n_dblocks = d_pad // block_d

    kern = functools.partial(_topk_kernel, k=k2, block_d=block_d,
                             n_dblocks=n_dblocks)
    out_v, out_i = pl.pallas_call(
        kern,
        grid=(q, n_dblocks),
        in_specs=[pl.BlockSpec((1, block_d), lambda qi, di: (qi, di))],
        out_specs=[
            pl.BlockSpec((1, k2), lambda qi, di: (qi, 0)),
            pl.BlockSpec((1, k2), lambda qi, di: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k2), scores.dtype),
            jax.ShapeDtypeStruct((q, k2), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k2,), scores.dtype),
            pltpu.VMEM((k2,), jnp.int32),
        ],
        interpret=interpret,
    )(scores)
    return out_v[:, :k], out_i[:, :k]
