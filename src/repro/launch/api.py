"""Architecture registry: every assigned arch is a selectable config.

Each ``configs/<id>.py`` exposes ``ARCH: ArchDef``.  An ArchDef knows how to:
  * build its full (paper-exact) and smoke (reduced) model configs;
  * produce ShapeDtypeStruct input specs for each of its shapes;
  * produce abstract parameters (``jax.eval_shape`` of init — no allocation);
  * produce partition specs for params/inputs/outputs on a mesh;
  * build the jittable step function (train or serve) for a shape.

The dry-run driver (launch/dryrun.py) consumes exactly this interface.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval
    meta: Tuple[Tuple[str, Any], ...] = ()
    skip_reason: Optional[str] = None  # e.g. long_500k on full attention

    def get(self, key, default=None):
        return dict(self.meta).get(key, default)


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower one (arch × shape × mesh) cell."""

    step_fn: Callable  # positional args matching arg_specs
    arg_specs: Tuple  # ShapeDtypeStructs (abstract params first)
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


@dataclasses.dataclass
class ArchDef:
    name: str
    family: str  # lm | gnn | recsys
    shapes: Dict[str, ShapeSpec]
    make_config: Callable[[bool], Any]  # (smoke: bool) -> model config
    # (cfg, shape, mesh|None) -> StepBundle   [mesh None → local smoke step]
    make_step: Callable[[Any, ShapeSpec, Any], StepBundle]
    notes: str = ""


_REGISTRY: Dict[str, ArchDef] = {}


def register(arch: ArchDef) -> ArchDef:
    _REGISTRY[arch.name] = arch
    return arch


def get_arch(name: str) -> ArchDef:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # Import for side effect: each module registers its ARCH.
    from repro.configs import (  # noqa: F401
        arctic_480b,
        autoint,
        gatedgcn,
        mind,
        nemotron_4_15b,
        olmo_1b,
        phi3_medium_14b,
        pytrec_paper,
        qwen3_moe_235b,
        sasrec,
        xdeepfm,
    )
