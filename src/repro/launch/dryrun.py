import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes and record memory / cost / collective analysis for §Roofline.

MUST be run as its own process (the two lines above lock the fake device
count before jax initializes):

    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch A]... [--shape S]... [--mesh single|multi|both] \
        [--out experiments/dryrun] [--devices 512]

Success criterion (deliverable e): ``.lower().compile()`` succeeds for every
cell on BOTH the (16,16) single-pod and (2,16,16) multi-pod mesh; the printed
``memory_analysis()`` proves per-device fit, ``cost_analysis()`` feeds the
roofline.  Skipped cells (long_500k × full-attention archs) are recorded with
their reason.
"""

import argparse
import json
import time
import traceback


def _cost_analysis(compiled) -> dict:
    """Version-compat: ``Compiled.cost_analysis()`` returns a dict on newer
    JAX but a one-element list of dicts on older releases."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _mesh_for(name: str, devices_per_pod: int = 256):
    import jax
    import numpy as np

    if name == "multi":
        n = devices_per_pod * 2
        devs = jax.devices()[:n]
        shape = (2, devices_per_pod // 16, 16)
        return jax.make_mesh(shape, ("pod", "data", "model"),
                             devices=devs)
    devs = jax.devices()[:devices_per_pod]
    return jax.make_mesh((devices_per_pod // 16, 16), ("data", "model"),
                         devices=devs)


def run_cell(arch_name: str, shape_name: str, mesh_name: str,
             devices_per_pod: int = 256, smoke: bool = False) -> dict:
    import jax

    from repro.analysis import hlo as hlo_lib
    from repro.launch.api import get_arch

    arch = get_arch(arch_name)
    shape = arch.shapes[shape_name]
    rec = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "family": arch.family, "status": "ok",
    }
    if shape.skip_reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = shape.skip_reason
        return rec
    mesh = _mesh_for(mesh_name, devices_per_pod)
    rec["mesh_shape"] = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_chips = int(mesh.devices.size)
    rec["n_chips"] = n_chips
    cfg = arch.make_config(smoke)
    t0 = time.time()
    try:
        with mesh:
            bundle = arch.make_step(cfg, shape, mesh)
            jitted = jax.jit(
                bundle.step_fn,
                in_shardings=bundle.in_shardings,
                out_shardings=bundle.out_shardings,
                donate_argnums=bundle.donate_argnums,
            )
            lowered = jitted.lower(*bundle.arg_specs)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
            mem = compiled.memory_analysis()
            print(f"[{arch_name} × {shape_name} × {mesh_name}] "
                  f"memory_analysis: {mem}")
            cost = _cost_analysis(compiled)
            print(f"[{arch_name} × {shape_name} × {mesh_name}] "
                  f"cost_analysis: flops={cost.get('flops', 0):.3e} "
                  f"bytes={cost.get('bytes accessed', 0):.3e}")
            rec["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
            }
            rec["cost"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float))}
            text = compiled.as_text()
            rec["collectives"] = hlo_lib.collective_bytes(text)
            rec["hlo_chars"] = len(text)
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def run_scan_probe(arch_name: str, shape_name: str, mesh_name: str,
                   devices_per_pod: int = 256) -> dict:
    """Separate scan-body cost from prologue/epilogue cost.

    ``cost_analysis()`` counts a while-loop body ONCE regardless of trip
    count (verified empirically: C(L=1) == C(L=2) == C(L=full) for scanned
    models), so per-layer cost must be measured from an *unrolled* module:
    compile n_layers=1 and 2 with ``unroll_layers=True`` — then
      body = C_u(2) − C_u(1)
    is one layer's true cost and the corrected full-model total is
      C_full_reported + (L − 1)·body      (see analysis/roofline.py).
    """
    import dataclasses as _dc

    import jax

    from repro.analysis import hlo as hlo_lib
    from repro.launch.api import get_arch

    arch = get_arch(arch_name)
    shape = arch.shapes[shape_name]
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "probe": True, "status": "ok"}
    if shape.skip_reason:
        rec["status"] = "skipped"
        return rec
    mesh = _mesh_for(mesh_name, devices_per_pod)
    base_cfg = arch.make_config(False)
    layer_field = ("n_layers" if hasattr(base_cfg, "n_layers") else
                   "n_blocks" if hasattr(base_cfg, "n_blocks") else None)
    if layer_field is None:
        rec["status"] = "no_scan"
        return rec
    rec["trips"] = getattr(base_cfg, layer_field)
    try:
        costs = {}
        for nl in (1, 2):
            cfg = _dc.replace(base_cfg, **{layer_field: nl,
                                           "unroll_layers": True})
            with mesh:
                bundle = arch.make_step(cfg, shape, mesh)
                compiled = jax.jit(
                    bundle.step_fn, in_shardings=bundle.in_shardings,
                    out_shardings=bundle.out_shardings,
                    donate_argnums=bundle.donate_argnums,
                ).lower(*bundle.arg_specs).compile()
                cost = _cost_analysis(compiled)
                coll = hlo_lib.collective_bytes(compiled.as_text())
                costs[nl] = {
                    "flops": float(cost.get("flops", 0.0)),
                    "bytes": float(cost.get("bytes accessed", 0.0)),
                    "collective": float(coll["total"]),
                }
        rec["body"] = {k: costs[2][k] - costs[1][k] for k in costs[1]}
        rec["rest"] = {k: costs[1][k] - rec["body"][k] for k in costs[1]}
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
    return rec


def main(argv=None) -> int:
    from repro.launch.api import get_arch, list_archs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--devices-per-pod", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI mini dry-run)")
    ap.add_argument("--probe-scan", action="store_true",
                    help="L=1/L=2 scan-body cost probe (see roofline.py)")
    args = ap.parse_args(argv)

    archs = args.arch or list_archs()
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch_name in archs:
        arch = get_arch(arch_name)
        shapes = args.shape or list(arch.shapes)
        for shape_name in shapes:
            if shape_name not in arch.shapes:
                continue
            for mesh_name in meshes:
                tag = f"{arch_name}__{shape_name}__{mesh_name}"
                if args.probe_scan:
                    tag += "__probe"
                path = os.path.join(args.out, tag + ".json")
                if args.probe_scan:
                    rec = run_scan_probe(arch_name, shape_name, mesh_name,
                                         args.devices_per_pod)
                else:
                    rec = run_cell(arch_name, shape_name, mesh_name,
                                   args.devices_per_pod, args.smoke)
                with open(path, "w") as fh:
                    json.dump(rec, fh, indent=1)
                status = rec["status"]
                extra = (f" ({rec.get('total_s', 0):.0f}s)"
                         if status == "ok" else
                         f" — {rec.get('skip_reason', rec.get('error', ''))}")
                print(f"{tag}: {status}{extra}", flush=True)
                failures += status == "error"
    print(f"dry-run complete; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
