"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any device query).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis carries
pure data parallelism (gradient all-reduce crosses DCI, everything else stays
inside a pod).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over the locally available devices (tests/examples)."""
    n = jax.device_count()
    dp = max(n // model_parallel, 1)
    return jax.make_mesh((dp, model_parallel), ("data", "model"))


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
