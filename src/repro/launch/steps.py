"""Family step builders: jittable train/serve steps with sharding trees.

The paper's technique is woven into every step: ranking metrics are computed
*inside* the jitted step from the scores that are already device-resident
(``core.measures`` / ``core.streaming``), so evaluation never crosses the
host boundary — only scalars do.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import measures as M
from repro.core import sorting, streaming
from repro.distributed.sharding import GNNSharding, LMSharding, RecSysSharding
from repro.launch.api import ShapeSpec, StepBundle
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.train import optimizer as opt_lib

I32 = jnp.int32
F32 = jnp.float32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _named(mesh, spec_tree):
    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _replicated_specs(tree):
    return jax.tree.map(lambda _: P(), tree)


SERVE_MEASURES = ("ndcg_cut", "recip_rank", "success")
_PARSED_SERVE = M.parse_measures(SERVE_MEASURES)


def _slate_metrics(scores, rel):
    """In-loop evaluation of a slate [B, D] against binary labels."""
    batch = M.batch_from_dense(scores.astype(F32), rel.astype(F32))
    per_q = M.compute_measures(batch, _PARSED_SERVE)
    agg = M.aggregate(per_q, batch.query_mask)
    return {k: agg[k] for k in ("ndcg_cut_10", "recip_rank", "success_10")}


# ===========================================================================
# LM family
# ===========================================================================


def _lm_sharding(mesh, fsdp: bool,
                 moe_fsdp_mode: str = "gather") -> Optional[LMSharding]:
    if mesh is None:
        return None
    from repro.launch.mesh import data_axes_of

    return LMSharding(data_axes=data_axes_of(mesh), fsdp_experts=fsdp,
                      moe_fsdp_mode=moe_fsdp_mode)


def _lm_opt_cfg():
    return opt_lib.OptimizerConfig(lr=3e-4, warmup_steps=50,
                                   decay_steps=20_000)


def lm_step_bundle(cfg: tfm.TransformerConfig, shape: ShapeSpec, mesh,
                   fsdp: bool = False,
                   opt_memory_efficient: bool = False,
                   opt_cfg: Optional[opt_lib.OptimizerConfig] = None
                   ) -> StepBundle:
    # decode gathers activations, not weight shards (§Perf iteration B)
    shd = _lm_sharding(mesh, fsdp,
                       "activation" if shape.kind == "decode" else "gather")
    rng = jax.random.PRNGKey(0)
    params_abs = _abstract(lambda: tfm.init_transformer(rng, cfg))
    pspecs = (tfm.param_partition_specs(cfg, shd) if shd else
              _replicated_specs(params_abs))
    batch_axes = shd.batch if shd else None

    if shape.kind == "train":
        b, s = shape.get("global_batch"), shape.get("seq_len")
        ocfg = opt_cfg or _lm_opt_cfg()
        if opt_memory_efficient:
            # §Perf iteration A: bf16 momentum + factored second moment
            ocfg = opt_lib.OptimizerConfig(
                lr=ocfg.lr, warmup_steps=ocfg.warmup_steps,
                decay_steps=ocfg.decay_steps,
                momentum_dtype="bfloat16", factored_v=True)
        init_opt, update = opt_lib.adamw(ocfg)
        opt_abs = _abstract(init_opt, params_abs)
        ospecs = opt_lib.opt_state_partition_specs(pspecs, ocfg, params_abs)

        def train_step(params, opt_state, tokens, labels):
            def loss_fn(p):
                logits = tfm.logits_train(p, tokens, cfg, mesh, shd)
                loss = tfm.L.cross_entropy(logits, labels)
                ranks = sorting.gold_rank(logits, labels)
                return loss, ranks

            (loss, ranks), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt_state, info = update(grads, opt_state, params)
            metrics = {"loss": loss, **info,
                       **streaming.rank_metrics(ranks.reshape(-1))}
            return params, opt_state, metrics

        arg_specs = (params_abs, opt_abs,
                     _sds((b, s), I32), _sds((b, s), I32))
        in_sp = (pspecs, ospecs, P(batch_axes, None), P(batch_axes, None))
        out_sp = (pspecs, ospecs, _replicated_specs(
            _abstract_metrics(train_step, arg_specs)[2]))
        return StepBundle(train_step, arg_specs, _named(mesh, in_sp),
                          _named(mesh, out_sp), donate_argnums=(0, 1))

    if shape.kind == "prefill":
        b, s = shape.get("global_batch"), shape.get("seq_len")

        def prefill_step(params, tokens):
            return tfm.prefill(params, tokens, cfg, mesh, shd)

        arg_specs = (params_abs, _sds((b, s), I32))
        cache_spec = (tfm.cache_partition_specs(cfg, shd) if shd
                      else {"k": P(), "v": P()})
        in_sp = (pspecs, P(batch_axes, None))
        out_sp = (P(batch_axes, shd.model_axis) if shd else P(), cache_spec)
        return StepBundle(prefill_step, arg_specs, _named(mesh, in_sp),
                          _named(mesh, out_sp))

    if shape.kind == "decode":
        b, s = shape.get("global_batch"), shape.get("seq_len")
        cache_abs = _abstract(
            lambda: tfm.init_cache(cfg, b, s, cfg.np_dtype))
        cache_spec = (tfm.cache_partition_specs(cfg, shd) if shd
                      else {"k": P(), "v": P()})

        def decode(params, cache, token, pos, gold):
            logits, cache = tfm.decode_step(params, cache, token, pos, cfg,
                                            mesh, shd)
            ranks = sorting.gold_rank(logits, gold)
            metrics = streaming.rank_metrics(ranks)
            return logits, cache, metrics

        arg_specs = (params_abs, cache_abs, _sds((b,), I32), _sds((), I32),
                     _sds((b,), I32))
        in_sp = (pspecs, cache_spec, P(batch_axes), P(), P(batch_axes))
        logits_sp = P(batch_axes, shd.model_axis) if shd else P()
        out_sp = (logits_sp, cache_spec, _replicated_specs(
            _abstract_metrics(decode, arg_specs)[2]))
        return StepBundle(decode, arg_specs, _named(mesh, in_sp),
                          _named(mesh, out_sp), donate_argnums=(1,))

    raise ValueError(f"unsupported LM shape kind {shape.kind}")


def _abstract_metrics(fn, arg_specs):
    return jax.eval_shape(fn, *arg_specs)


# ===========================================================================
# GNN family
# ===========================================================================


def _pad_to(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def gnn_step_bundle(cfg: gnn_lib.GatedGCNConfig, shape: ShapeSpec, mesh
                    ) -> StepBundle:
    from repro.launch.mesh import data_axes_of

    shd = GNNSharding(data_axes=data_axes_of(mesh)) if mesh else None
    n = shape.get("n_nodes")
    e = shape.get("n_edges")
    if mesh is not None:
        # pad to mesh multiples (masks make padding semantically inert):
        # nodes shard over the data axes, edges over the whole mesh.
        import numpy as _np

        n_data = int(_np.prod([mesh.shape[a] for a in data_axes_of(mesh)]))
        n = _pad_to(n, n_data)
        e = _pad_to(e, int(mesh.devices.size))
    graph_task = shape.get("graph_task", False)
    rng = jax.random.PRNGKey(0)
    params_abs = _abstract(lambda: gnn_lib.init_gatedgcn(rng, cfg))
    pspecs = _replicated_specs(params_abs)  # d_hidden=70: replicate weights
    init_opt, update = opt_lib.adamw(opt_lib.OptimizerConfig(lr=1e-3))
    opt_abs = _abstract(init_opt, params_abs)
    ospecs = opt_lib.opt_state_partition_specs(pspecs)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits = gnn_lib.gatedgcn_forward(p, batch, cfg)
            if graph_task:
                # disjoint-union batch: mean-pool nodes per graph
                n_graphs = shape.get("n_graphs")
                pooled = jax.ops.segment_sum(
                    logits * batch["node_mask"][:, None],
                    batch["graph_ids"], num_segments=n_graphs)
                cnt = jax.ops.segment_sum(
                    batch["node_mask"].astype(F32), batch["graph_ids"],
                    num_segments=n_graphs)
                pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]
                loss = tfm.L.cross_entropy(pooled, batch["graph_labels"])
                ranks = sorting.gold_rank(pooled, batch["graph_labels"])
                mask = jnp.ones_like(batch["graph_labels"], bool)
            else:
                mask = batch["node_mask"] & batch.get(
                    "train_mask", batch["node_mask"])
                loss = tfm.L.cross_entropy(logits, batch["labels"], mask)
                ranks = sorting.gold_rank(logits, batch["labels"])
            return loss, (ranks, mask)

        (loss, (ranks, mask)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, info = update(grads, opt_state, params)
        metrics = {"loss": loss, **info,
                   **streaming.rank_metrics(ranks.reshape(-1),
                                            mask.reshape(-1))}
        return params, opt_state, metrics

    batch_abs = {
        "node_feat": _sds((n, cfg.d_in), F32),
        "edge_feat": _sds((e, cfg.d_edge_in), F32),
        "src": _sds((e,), I32),
        "dst": _sds((e,), I32),
        "labels": _sds((n,), I32),
        "node_mask": _sds((n,), jnp.bool_),
        "edge_mask": _sds((e,), jnp.bool_),
    }
    if graph_task:
        ng = shape.get("n_graphs")
        batch_abs["graph_ids"] = _sds((n,), I32)
        batch_abs["graph_labels"] = _sds((ng,), I32)
    if shd:
        espec, nspec = shd.edges(), P(shd.batch)
        bspecs = {
            "node_feat": P(shd.batch, None), "edge_feat": P(espec[0], None),
            "src": espec, "dst": espec, "labels": nspec,
            "node_mask": nspec, "edge_mask": espec,
        }
        if graph_task:
            bspecs["graph_ids"] = nspec
            bspecs["graph_labels"] = P(shd.batch)
    else:
        bspecs = _replicated_specs(batch_abs)
    arg_specs = (params_abs, opt_abs, batch_abs)
    in_sp = (pspecs, ospecs, bspecs)
    out_sp = (pspecs, ospecs,
              _replicated_specs(_abstract_metrics(train_step, arg_specs)[2]))
    return StepBundle(train_step, arg_specs, _named(mesh, in_sp),
                      _named(mesh, out_sp), donate_argnums=(0, 1))


# ===========================================================================
# RecSys family
# ===========================================================================


def recsys_step_bundle(kind: str, cfg, shape: ShapeSpec, mesh) -> StepBundle:
    from repro.launch.mesh import data_axes_of

    shd = RecSysSharding(data_axes=data_axes_of(mesh)) if mesh else None
    rng = jax.random.PRNGKey(0)
    batch_axes = shd.batch if shd else None

    if kind == "sasrec":
        params_abs = _abstract(lambda: rec_lib.sasrec_init(rng, cfg))
        pspecs = jax.tree.map(lambda _: P(), params_abs)
        if shd:
            pspecs["item_emb"] = shd.p_table()
        seq = cfg.seq_len

        def make_inputs(b):
            return {
                "items": _sds((b, seq), I32), "pos": _sds((b, seq), I32),
                "neg": _sds((b, seq), I32), "mask": _sds((b, seq), jnp.bool_)}

        def in_specs(b):
            s = P(batch_axes, None)
            return {"items": s, "pos": s, "neg": s, "mask": s}

        loss_fn = lambda p, b: rec_lib.sasrec_loss(p, b, cfg)
        score_slate = None
        retrieval = lambda p, b: rec_lib.sasrec_retrieval_scores(p, b, cfg)
    elif kind == "mind":
        params_abs = _abstract(lambda: rec_lib.mind_init(rng, cfg))
        pspecs = jax.tree.map(lambda _: P(), params_abs)
        if shd:
            pspecs["item_emb"] = shd.p_table()
        hl = cfg.hist_len

        def make_inputs(b):
            return {"hist": _sds((b, hl), I32),
                    "hist_mask": _sds((b, hl), jnp.bool_),
                    "pos": _sds((b,), I32), "negs": _sds((b, 20), I32)}

        def in_specs(b):
            return {"hist": P(batch_axes, None),
                    "hist_mask": P(batch_axes, None),
                    "pos": P(batch_axes), "negs": P(batch_axes, None)}

        loss_fn = lambda p, b: rec_lib.mind_loss(p, b, cfg)
        retrieval = lambda p, b: rec_lib.mind_retrieval_scores(p, b, cfg)
    else:  # CTR models: xdeepfm | autoint
        score = (rec_lib.xdeepfm_score if kind == "xdeepfm"
                 else rec_lib.autoint_score)
        init = (rec_lib.xdeepfm_init if kind == "xdeepfm"
                else rec_lib.autoint_init)
        params_abs = _abstract(lambda: init(rng, cfg))
        pspecs = jax.tree.map(lambda _: P(), params_abs)
        if shd:
            pspecs["table"] = shd.p_table()
            if kind == "xdeepfm":
                pspecs["linear"] = P(shd.model_axis)
        nf = cfg.table.n_fields

        def make_inputs(b):
            out = {"ids": _sds((b, nf), I32), "labels": _sds((b,), I32)}
            if cfg.n_multi_hot:
                out["mh_ids"] = _sds((b, cfg.n_multi_hot, cfg.multi_hot_len),
                                     I32)
                out["mh_mask"] = _sds(
                    (b, cfg.n_multi_hot, cfg.multi_hot_len), jnp.bool_)
            return out

        def in_specs(b):
            out = {"ids": P(batch_axes, None), "labels": P(batch_axes)}
            if cfg.n_multi_hot:
                out["mh_ids"] = P(batch_axes, None, None)
                out["mh_mask"] = P(batch_axes, None, None)
            return out

        loss_fn = lambda p, b: rec_lib.ctr_loss(score, p, b, cfg)[0]
        retrieval = None

    # ----- shapes ----------------------------------------------------------
    if shape.kind == "train":
        b = shape.get("batch")
        init_opt, update = opt_lib.adamw(opt_lib.OptimizerConfig(lr=1e-3))
        opt_abs = _abstract(init_opt, params_abs)
        ospecs = opt_lib.opt_state_partition_specs(pspecs)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state, info = update(grads, opt_state, params)
            return params, opt_state, {"loss": loss, **info}

        arg_specs = (params_abs, opt_abs, make_inputs(b))
        in_sp = (pspecs, ospecs, in_specs(b))
        out_sp = (pspecs, ospecs, _replicated_specs(
            _abstract_metrics(train_step, arg_specs)[2]))
        return StepBundle(train_step, arg_specs, _named(mesh, in_sp),
                          _named(mesh, out_sp), donate_argnums=(0, 1))

    if shape.kind == "serve":
        b = shape.get("batch")
        slate = shape.get("slate", 0)
        if kind in ("sasrec", "mind") and slate:
            # online ranking: score a per-user candidate slate + in-loop eval
            def serve(params, batch, cand, rel):
                scores = _slate_scores(kind, cfg, params, batch, cand)
                return scores, _slate_metrics(scores, rel)

            arg_specs = (params_abs, make_inputs(b),
                         _sds((b, slate), I32), _sds((b, slate), I32))
            in_sp = (pspecs, in_specs(b), P(batch_axes, None),
                     P(batch_axes, None))
            out_sp = ((P(batch_axes, None), _replicated_specs(
                _abstract_metrics(serve, arg_specs)[1])))
            return StepBundle(serve, arg_specs, _named(mesh, in_sp),
                              _named(mesh, out_sp))

        def serve(params, batch):
            if kind in ("sasrec",):
                h = rec_lib.sasrec_encode(params, batch["items"], cfg)[:, -1]
                cand = jnp.take(params["item_emb"], batch["pos"][:, -1], 0)
                return jnp.sum(h * cand, -1)
            if kind == "mind":
                caps = rec_lib.mind_interests(params, batch, cfg)
                cand = jnp.take(params["item_emb"], batch["pos"], 0)
                return jnp.max(jnp.einsum("bkd,bd->bk", caps, cand), -1)
            return (rec_lib.xdeepfm_score if kind == "xdeepfm"
                    else rec_lib.autoint_score)(params, batch, cfg)

        arg_specs = (params_abs, make_inputs(b))
        in_sp = (pspecs, in_specs(b))
        out_sp = P(batch_axes)
        return StepBundle(serve, arg_specs, _named(mesh, in_sp),
                          _named(mesh, out_sp))

    if shape.kind == "retrieval":
        b = shape.get("batch")
        nc = shape.get("n_candidates")
        topk = shape.get("topk", 1000)
        # batch=1 per spec: user-side inputs stay replicated; all parallelism
        # lives on the candidate axis (sharded over `model`).
        cand_axis = shd.model_axis if shd else None

        if retrieval is not None:
            def serve(params, batch, cand_ids, rel):
                bb = dict(batch)
                bb["candidates"] = cand_ids
                scores = retrieval(params, bb)
                v, i = jax.lax.top_k(scores, topk)
                return v, i, _slate_metrics(scores, rel)

            arg_specs = (params_abs, make_inputs(b), _sds((nc,), I32),
                         _sds((b, nc), I32))
            cand_spec = P(cand_axis) if shd else P()
            in_sp = (pspecs, _replicated_specs(in_specs(b)), cand_spec,
                     P(None, cand_axis))
        else:
            # CTR: broadcast user fields over the candidate set (field 0 is
            # the item id field)
            def serve(params, batch, cand_ids, rel):
                nfields = cfg.table.n_fields
                ids = jnp.broadcast_to(batch["ids"], (nc, nfields))
                ids = ids.at[:, 0].set(cand_ids)
                scores = (rec_lib.xdeepfm_score if kind == "xdeepfm" else
                          rec_lib.autoint_score)(
                    params, {"ids": ids}, cfg)[None, :]
                v, i = jax.lax.top_k(scores, topk)
                return v, i, _slate_metrics(scores, rel)

            arg_specs = (params_abs,
                         {"ids": _sds((1, cfg.table.n_fields), I32)},
                         _sds((nc,), I32), _sds((1, nc), I32))
            in_sp = (pspecs, {"ids": P()}, P(cand_axis), P(None, cand_axis))
        out_abs = _abstract_metrics(serve, arg_specs)
        out_sp = ((P(), P(), _replicated_specs(out_abs[2])))
        return StepBundle(serve, arg_specs, _named(mesh, in_sp),
                          _named(mesh, out_sp))

    raise ValueError(f"unsupported recsys shape kind {shape.kind}")


def _slate_scores(kind, cfg, params, batch, cand):
    """Scores of per-user candidate slates [B, S_cand]."""
    if kind == "sasrec":
        h = rec_lib.sasrec_encode(params, batch["items"], cfg)[:, -1]
        ce = jnp.take(params["item_emb"], cand, axis=0)  # [B, S, D]
        return jnp.einsum("bd,bsd->bs", h, ce)
    caps = rec_lib.mind_interests(params, batch, cfg)  # [B, K, D]
    ce = jnp.take(params["item_emb"], cand, axis=0)
    return jnp.max(jnp.einsum("bkd,bsd->bks", caps, ce), axis=1)
