"""Model zoo: the assigned architectures, as pure-function JAX models.

Params are nested dicts of arrays; configs are frozen dataclasses (hashable,
so step functions can close over them under jit).  Layer stacks are scanned
(`lax.scan` over stacked params) to keep HLO size O(1) in depth.
"""
