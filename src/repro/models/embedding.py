"""EmbeddingBag for JAX — the recsys hot path, built not stubbed.

JAX has no native EmbeddingBag and no CSR sparse; the bag is constructed from
``jnp.take`` + ``jax.ops.segment_sum`` (reference path) with an optional
Pallas scalar-prefetch kernel path (``kernels.embedding_bag``) for the
single-device hot loop.

Tables for the CTR models are one concatenated [Σ vocab_f, dim] array,
row-sharded over `model` on the production mesh (vocab-parallel); field
offsets turn per-field ids into global rows.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TableConfig:
    n_fields: int
    vocab_per_field: int
    dim: int

    @property
    def total_rows(self) -> int:
        return self.n_fields * self.vocab_per_field


def init_table(rng, cfg: TableConfig, dtype=jnp.float32, scale=0.01):
    return (jax.random.normal(rng, (cfg.total_rows, cfg.dim)) * scale).astype(
        dtype)


def field_lookup(table, ids, cfg: TableConfig):
    """Single-hot lookup: ids [B, n_fields] per-field → [B, n_fields, dim].

    Per-field ids are offset into the concatenated table.  On the mesh the
    table is row-sharded over `model`; GSPMD lowers the gather to the
    vocab-parallel pattern (local gather + masked psum).
    """
    offsets = (jnp.arange(cfg.n_fields, dtype=ids.dtype) * cfg.vocab_per_field)
    rows = ids + offsets[None, :]
    return jnp.take(table, rows, axis=0)


def embedding_bag(table, indices, segment_ids, n_bags,
                  weights: Optional[jax.Array] = None,
                  combiner: str = "sum", use_kernel: bool = False):
    """Bag-combine table rows: [L] indices into [B] bags → [B, dim].

    ``use_kernel`` routes through the Pallas scalar-prefetch kernel (indices
    must then be pre-sorted by segment id).
    """
    if use_kernel:
        from repro.kernels import ops

        out = ops.embedding_bag(table, indices, segment_ids, n_bags, weights)
    else:
        rows = jnp.take(table, indices, axis=0)
        if weights is not None:
            rows = rows * weights[:, None]
        out = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if combiner == "mean":
        ones = jnp.ones_like(segment_ids, dtype=out.dtype)
        if weights is not None:
            ones = weights
        counts = jax.ops.segment_sum(ones, segment_ids, num_segments=n_bags)
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out


def multi_hot_lookup(table, ids, mask, cfg: TableConfig, field: int,
                     combiner: str = "sum"):
    """Multi-hot field: ids [B, M] (padded, mask [B, M]) → [B, dim]."""
    b, m = ids.shape
    rows = ids + field * cfg.vocab_per_field
    segs = jnp.broadcast_to(jnp.arange(b)[:, None], (b, m)).reshape(-1)
    w = mask.reshape(-1).astype(table.dtype)
    return embedding_bag(table, rows.reshape(-1), segs, b, weights=w,
                         combiner=combiner)
