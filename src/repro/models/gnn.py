"""GatedGCN (Bresson & Laurent, arXiv:1711.07553) via segment ops.

Message passing is built on ``jax.ops.segment_sum`` over an edge-index list —
JAX has no sparse SpMM beyond BCOO, so gather→compute→scatter IS the system
(see kernel_taxonomy §GNN).  Layer update (residual, edge-featured):

    e'_ij = E1·e_ij + E2·h_i + E3·h_j                     (edge gate logits)
    η_ij  = σ(e'_ij) / (Σ_{j'∈N(i)} σ(e'_ij') + ε)        (normalized gates)
    h'_i  = h_i + ReLU(LN(A·h_i + Σ_j η_ij ⊙ (B·h_j)))
    e''_ij = e_ij + ReLU(LN(e'_ij))

LayerNorm replaces the paper's BatchNorm (running stats don't compose with
pjit across graph shards; noted in DESIGN.md).  Graphs are padded to static
(n_nodes, n_edges) with masks; padded edges point at node 0 with zero gates.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_in: int
    d_edge_in: int
    n_classes: int
    dtype: str = "float32"
    remat: bool = False
    unroll_layers: bool = False  # cost-probe only

    @property
    def np_dtype(self):
        return jnp.dtype(self.dtype)


def init_gatedgcn(rng, cfg: GatedGCNConfig):
    d = cfg.d_hidden
    keys = jax.random.split(rng, 4)

    def stack(key, shape, fan_in):
        ks = jax.random.split(key, cfg.n_layers)
        return jax.vmap(
            lambda k: jax.random.normal(k, shape) * (1.0 / fan_in) ** 0.5
        )(ks).astype(cfg.np_dtype)

    # A,B (node) + E1,E2,E3 (edge) packed: [L, D, 5D]
    lp = {
        "w_node": stack(keys[0], (d, 2 * d), d),
        "w_edge": stack(keys[1], (d, 3 * d), d),
    }
    params = {
        "proj_node": L.dense_init(jax.random.fold_in(rng, 1), cfg.d_in, d,
                                  cfg.np_dtype),
        "proj_edge": L.dense_init(jax.random.fold_in(rng, 2), cfg.d_edge_in, d,
                                  cfg.np_dtype),
        "layers": lp,
        "head": L.dense_init(jax.random.fold_in(rng, 3), d, cfg.n_classes,
                             cfg.np_dtype),
    }
    return params


def _ln(x):
    return L.nonparam_layernorm(x)


def gatedgcn_forward(params, batch, cfg: GatedGCNConfig):
    """batch: dict with
      node_feat [N, d_in], edge_feat [E, d_edge_in],
      src [E] i32, dst [E] i32, node_mask [N] bool, edge_mask [E] bool.
    Returns per-node class logits [N, n_classes].
    """
    h = batch["node_feat"].astype(cfg.np_dtype) @ params["proj_node"]
    e = batch["edge_feat"].astype(cfg.np_dtype) @ params["proj_edge"]
    src, dst = batch["src"], batch["dst"]
    emask = batch["edge_mask"][:, None].astype(h.dtype)
    n = h.shape[0]

    def body(carry, lp):
        h, e = carry
        ah_bh = h @ lp["w_node"]  # [N, 2D]
        a_h, b_h = jnp.split(ah_bh, 2, axis=-1)
        # Edge gate logits: E1·e + E2·h_src + E3·h_dst (packed weights).
        e1, e2, e3 = jnp.split(lp["w_edge"], 3, axis=-1)
        eg = e @ e1 + jnp.take(h, src, axis=0) @ e2 + jnp.take(h, dst, axis=0) @ e3
        sig = jax.nn.sigmoid(eg) * emask
        denom = jax.ops.segment_sum(sig, dst, num_segments=n) + 1e-6
        msg = sig * jnp.take(b_h, src, axis=0)
        agg = jax.ops.segment_sum(msg, dst, num_segments=n) / denom
        h_new = h + jax.nn.relu(_ln(a_h + agg))
        e_new = e + jax.nn.relu(_ln(eg))
        return (h_new, e_new), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    from repro.models.scan_utils import scan_layers
    (h, e), _ = scan_layers(body, (h, e), params["layers"],
                            cfg.unroll_layers)
    return h @ params["head"]


def gatedgcn_loss(params, batch, cfg: GatedGCNConfig):
    """Node-classification CE + in-loop ranking metrics of the gold class."""
    logits = gatedgcn_forward(params, batch, cfg)
    labels = batch["labels"]
    mask = batch["node_mask"] & batch.get("train_mask", batch["node_mask"])
    loss = L.cross_entropy(logits, labels, mask)
    return loss, logits
