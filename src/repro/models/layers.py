"""Shared neural-net building blocks (pure functions, no framework)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(rng, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else (1.0 / d_in) ** 0.5
    return (jax.random.normal(rng, (d_in, d_out)) * scale).astype(dtype)


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def nonparam_layernorm(x, eps=1e-5):
    """OLMo-style non-parametric LayerNorm (no scale/bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def norm(x, scale):
    """Dispatch: scale is None → non-parametric LN, else RMSNorm."""
    if scale is None:
        return nonparam_layernorm(x)
    return rmsnorm(x, scale)


def rope(x, positions, theta: float = 10000.0):
    """Rotary position embedding.  x: [..., S, n, head_dim], positions [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def squared_relu_ffn(x, w_up, w_down):
    h = jnp.square(jax.nn.relu(x @ w_up))
    return h @ w_down


def gelu_ffn(x, w_up, w_down):
    return jax.nn.gelu(x @ w_up) @ w_down


def softmax_fp32(logits, axis=-1):
    return jax.nn.softmax(logits.astype(jnp.float32), axis=axis)


def cross_entropy(logits, labels, label_mask=None):
    """Mean CE over valid positions; logits [..., V] (softmax in fp32)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if label_mask is None:
        return jnp.mean(nll)
    m = label_mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
