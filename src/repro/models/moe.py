"""Mixture-of-Experts block: top-k routing, capacity dispatch, expert GEMMs.

Design (TPU-native, see DESIGN.md §5):

* Activations entering the block are **replicated over the `model` axis** and
  sharded over the data axes; experts are sharded over `model` (EP).  Each
  model shard therefore already holds every token it could need — dispatch is
  a *local gather*, combine is a *local scatter-add* followed by one
  ``psum`` over `model` (the same collective a Megatron row-parallel FFN
  pays).  No all-to-all, no GShard one-hot dispatch einsum: compiled FLOPs
  stay ≈ the true expert FLOPs.

* Capacity: each local expert takes its top ``C = cf · T · k / E`` tokens by
  router weight (drop-lowest-probability policy); dropped tokens pass through
  the residual stream only.

* Optional FSDP: expert weights additionally sharded over `data` on the FFN
  dim and all-gathered just-in-time (ZeRO-3) — needed for the 235B/480B
  configs to fit HBM.

The same function also runs without a mesh (smoke tests): all experts local,
no collectives.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: parallel dense MLP branch
    router_dtype: str = "float32"


def init_moe(rng, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    e, f = cfg.n_experts, cfg.d_ff_expert
    s_in = (1.0 / d_model) ** 0.5
    s_out = (1.0 / f) ** 0.5
    return {
        "router": (jax.random.normal(ks[0], (d_model, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d_model, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d_model, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d_model)) * s_out).astype(dtype),
    }


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(c, 1)


def moe_apply_local(x2d, params, cfg: MoEConfig, *, model_axis: Optional[str],
                    fsdp_axis: Optional[str] = None,
                    fsdp_mode: str = "gather"):
    """Apply MoE to flat tokens ``x2d [T, D]`` (local shard when mapped).

    ``params['w_*']`` hold the *local* expert slices when running under
    shard_map (leading dim E_local); the router is replicated.

    FSDP modes when expert FFN dims are additionally sharded over `data`:

    * ``gather`` — ZeRO-3: all-gather the weight shards just-in-time.
      Right for training, where tokens/device ≫ weight bytes.
    * ``activation`` — gather the *tokens* over `data` instead, compute
      partial FFN contributions with the local F-shard (SwiGLU is
      elementwise in F, so F-sharded partials are exact), and
      reduce-scatter the outputs back.  Right for decode, where a few
      tokens/device would otherwise pay a full weight gather per layer
      (arctic decode: 1.6 GB/layer weights vs ~4 MB/layer activations —
      see EXPERIMENTS.md §Perf iteration B).
    """
    t, d = x2d.shape
    w_gate, w_up, w_down = params["w_gate"], params["w_up"], params["w_down"]
    activation_mode = fsdp_axis is not None and fsdp_mode == "activation"
    if fsdp_axis is not None and fsdp_mode == "gather":
        # ZeRO-3: FFN dim sharded over data; materialize just-in-time.
        w_gate = lax.all_gather(w_gate, fsdp_axis, axis=2, tiled=True)
        w_up = lax.all_gather(w_up, fsdp_axis, axis=2, tiled=True)
        w_down = lax.all_gather(w_down, fsdp_axis, axis=1, tiled=True)
    if activation_mode:
        t_local = t
        x2d = lax.all_gather(x2d, fsdp_axis, axis=0, tiled=True)
        t, _ = x2d.shape
    e_loc = w_gate.shape[0]

    logits = x2d.astype(jnp.float32) @ params["router"]  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = lax.top_k(probs, cfg.top_k)  # [T, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    first = 0
    if model_axis is not None:
        first = lax.axis_index(model_axis) * e_loc
    local_ids = first + jnp.arange(e_loc, dtype=top_ids.dtype)
    # Router weight of each token for each *local* expert: [E_loc, T].
    hit = (top_ids[:, None, :] == local_ids[None, :, None]).astype(jnp.float32)
    w_local = jnp.sum(hit * top_p[:, None, :], axis=-1).T

    c = capacity(t, cfg)
    c = min(c, t)
    gate_vals, tok_idx = lax.top_k(w_local, c)  # [E_loc, C]
    xg = jnp.take(x2d, tok_idx.reshape(-1), axis=0).reshape(e_loc, c, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xg, w_up)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)
    y = y * gate_vals[..., None].astype(y.dtype)

    out = jnp.zeros_like(x2d)
    out = out.at[tok_idx.reshape(-1)].add(y.reshape(-1, d))
    if activation_mode:
        # partial over the F-shards: sum + re-shard tokens in one collective
        out = lax.psum_scatter(out, fsdp_axis, scatter_dimension=0,
                               tiled=True)
    if model_axis is not None:
        out = lax.psum(out, model_axis)
    return out


def moe_apply(x, params, cfg: MoEConfig, *, mesh=None,
              data_axes=("data",), model_axis="model",
              fsdp_axis: Optional[str] = None, fsdp_mode: str = "gather"):
    """MoE over ``x [..., D]``; uses shard_map when a mesh is provided."""
    from jax.sharding import PartitionSpec as P

    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    if mesh is None:
        out = moe_apply_local(x2d, params, cfg, model_axis=None)
        return out.reshape(shape)

    def fn(xl, router, w_gate, w_up, w_down):
        p = {"router": router, "w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        return moe_apply_local(xl, p, cfg, model_axis=model_axis,
                               fsdp_axis=fsdp_axis, fsdp_mode=fsdp_mode)

    wspec_gate = P(model_axis, None, fsdp_axis)
    wspec_down = P(model_axis, fsdp_axis, None)
    from repro.distributed import shard_map

    out = shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(data_axes, None), P(), wspec_gate, wspec_gate, wspec_down),
        out_specs=P(data_axes, None),
        check_vma=False,
    )(x2d, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return out.reshape(shape)
