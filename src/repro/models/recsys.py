"""RecSys architectures: SASRec, xDeepFM (CIN), MIND (capsules), AutoInt.

Shared anatomy: sparse embedding tables (the hot path, see ``embedding.py``)
→ feature-interaction op → small MLP → logit(s).  Every model exposes:

  * ``init(rng, cfg)``                → params
  * ``score(params, batch, cfg)``     → pCTR logits / ranking scores
  * ``loss(params, batch, cfg)``      → scalar training loss (+ aux)
  * ``retrieval_scores(params, batch, cfg)`` → [B, n_candidates] for the
    ``retrieval_cand`` shape (one query vs 10⁶ candidates — batched matmul
    into the top-K kernel, never a loop).

In-loop evaluation: serving paths return score tensors that feed directly
into ``core.measures`` / ``kernels.fused_measures`` without leaving the
device — the paper's in-process evaluation at pod scale.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import embedding as E
from repro.models import layers as L


# ===========================================================================
# SASRec — self-attentive sequential recommendation (arXiv:1808.09781)
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str
    n_items: int
    embed_dim: int
    n_blocks: int
    n_heads: int
    seq_len: int
    dtype: str = "float32"
    unroll_layers: bool = False  # cost-probe only

    @property
    def np_dtype(self):
        return jnp.dtype(self.dtype)


def sasrec_init(rng, cfg: SASRecConfig):
    d = cfg.embed_dim
    keys = jax.random.split(rng, 3)

    def stack(key, shape, fan_in):
        ks = jax.random.split(key, cfg.n_blocks)
        return jax.vmap(
            lambda k: jax.random.normal(k, shape) * (1.0 / fan_in) ** 0.5
        )(ks).astype(cfg.np_dtype)

    return {
        "item_emb": (jax.random.normal(keys[0], (cfg.n_items, d)) * 0.02
                     ).astype(cfg.np_dtype),
        "pos_emb": (jax.random.normal(keys[1], (cfg.seq_len, d)) * 0.02
                    ).astype(cfg.np_dtype),
        "blocks": {
            "wqkv": stack(keys[2], (d, 3 * d), d),
            "wo": stack(jax.random.fold_in(rng, 7), (d, d), d),
            "w1": stack(jax.random.fold_in(rng, 8), (d, d), d),
            "w2": stack(jax.random.fold_in(rng, 9), (d, d), d),
        },
    }


def sasrec_encode(params, item_ids, cfg: SASRecConfig):
    """item_ids [B, S] → sequence representations [B, S, D] (causal)."""
    b, s = item_ids.shape
    d = cfg.embed_dim
    x = jnp.take(params["item_emb"], item_ids, axis=0)
    x = x + params["pos_emb"][None, :s]
    causal = jnp.tril(jnp.ones((s, s), bool))

    def body(x, bp):
        qkv = L.nonparam_layernorm(x) @ bp["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        hd = d // cfg.n_heads
        q = q.reshape(b, s, cfg.n_heads, hd)
        k = k.reshape(b, s, cfg.n_heads, hd)
        v = v.reshape(b, s, cfg.n_heads, hd)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / hd**0.5
        sc = jnp.where(causal[None, None], sc, -1e30)
        p = jax.nn.softmax(sc, -1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, d)
        x = x + o @ bp["wo"]
        h = jax.nn.relu(L.nonparam_layernorm(x) @ bp["w1"]) @ bp["w2"]
        return x + h, None

    from repro.models.scan_utils import scan_layers
    x, _ = scan_layers(body, x, params["blocks"], cfg.unroll_layers)
    return L.nonparam_layernorm(x)


def sasrec_loss(params, batch, cfg: SASRecConfig):
    """BCE over (positive next item, sampled negative) — the paper's loss."""
    h = sasrec_encode(params, batch["items"], cfg)  # [B, S, D]
    pos = jnp.take(params["item_emb"], batch["pos"], axis=0)  # [B, S, D]
    neg = jnp.take(params["item_emb"], batch["neg"], axis=0)
    pos_logit = jnp.sum(h * pos, -1)
    neg_logit = jnp.sum(h * neg, -1)
    m = batch["mask"].astype(jnp.float32)
    loss = -(jax.nn.log_sigmoid(pos_logit) + jax.nn.log_sigmoid(-neg_logit))
    return jnp.sum(loss * m) / jnp.maximum(jnp.sum(m), 1.0)


def sasrec_retrieval_scores(params, batch, cfg: SASRecConfig):
    """Last-position user state vs candidate item set → [B, n_cand]."""
    h = sasrec_encode(params, batch["items"], cfg)[:, -1]  # [B, D]
    cand = params["item_emb"]
    if "candidates" in batch:
        cand = jnp.take(params["item_emb"], batch["candidates"], axis=0)
    return h @ cand.T


# ===========================================================================
# CTR models: shared sparse-feature front-end
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class CTRConfig:
    name: str
    table: E.TableConfig
    # xDeepFM
    cin_layers: tuple = ()
    mlp_dims: tuple = ()
    # AutoInt
    n_attn_layers: int = 0
    n_attn_heads: int = 0
    d_attn: int = 0
    # MIND
    n_interests: int = 0
    capsule_iters: int = 0
    hist_len: int = 0
    n_multi_hot: int = 0  # leading fields that are multi-hot (bags)
    multi_hot_len: int = 8
    dtype: str = "float32"

    @property
    def np_dtype(self):
        return jnp.dtype(self.dtype)


def _sparse_features(params, batch, cfg: CTRConfig):
    """ids [B, F] (+ optional multi-hot bags) → field embeddings [B, F, D]."""
    tab = params["table"]
    emb = E.field_lookup(tab, batch["ids"], cfg.table)  # [B, F, D]
    if cfg.n_multi_hot and "mh_ids" in batch:
        # First n_multi_hot fields also receive a bag of extra values.
        bags = []
        for f in range(cfg.n_multi_hot):
            bag = E.multi_hot_lookup(tab, batch["mh_ids"][:, f],
                                     batch["mh_mask"][:, f], cfg.table, f)
            bags.append(bag)
        mh = jnp.stack(bags, axis=1)  # [B, n_mh, D]
        emb = emb.at[:, : cfg.n_multi_hot].add(mh)
    return emb


def _mlp(x, ws, bs):
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = x @ w + b
        if i < len(ws) - 1:
            x = jax.nn.relu(x)
    return x


def _mlp_init(rng, dims, dtype):
    ws, bs = [], []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        ws.append(L.dense_init(jax.random.fold_in(rng, i), a, b, dtype))
        bs.append(jnp.zeros((b,), dtype))
    return ws, bs


# ===========================================================================
# xDeepFM — Compressed Interaction Network (arXiv:1803.05170)
# ===========================================================================


def xdeepfm_init(rng, cfg: CTRConfig):
    d = cfg.table.dim
    f = cfg.table.n_fields
    params = {
        "table": E.init_table(jax.random.fold_in(rng, 0), cfg.table,
                              cfg.np_dtype),
        "linear": (jax.random.normal(jax.random.fold_in(rng, 1),
                                     (cfg.table.total_rows,)) * 0.01
                   ).astype(cfg.np_dtype),
        "cin": [],
        "bias": jnp.zeros((), cfg.np_dtype),
    }
    h_prev = f
    for i, h in enumerate(cfg.cin_layers):
        params["cin"].append(
            (jax.random.normal(jax.random.fold_in(rng, 10 + i),
                               (h, h_prev, f)) * (1.0 / (h_prev * f)) ** 0.5
             ).astype(cfg.np_dtype))
        h_prev = h
    mlp_dims = (f * d,) + tuple(cfg.mlp_dims) + (1,)
    params["mlp_w"], params["mlp_b"] = _mlp_init(jax.random.fold_in(rng, 50),
                                                 mlp_dims, cfg.np_dtype)
    params["cin_out"] = L.dense_init(jax.random.fold_in(rng, 51),
                                     sum(cfg.cin_layers), 1, cfg.np_dtype)
    return params


def xdeepfm_score(params, batch, cfg: CTRConfig):
    emb = _sparse_features(params, batch, cfg)  # [B, F, D]
    b, f, d = emb.shape
    x0 = emb
    xk = emb
    pooled = []
    for w in params["cin"]:
        # CIN: x^{k+1}_h = Σ_{i,j} W_h[i,j] (x^k_i ∘ x^0_j)
        xk = jnp.einsum("bhd,bmd,phm->bpd", xk, x0, w)
        pooled.append(jnp.sum(xk, axis=-1))  # sum-pool over D → [B, H_k]
    cin_logit = jnp.concatenate(pooled, -1) @ params["cin_out"]
    deep_logit = _mlp(emb.reshape(b, f * d), params["mlp_w"], params["mlp_b"])
    offsets = jnp.arange(f, dtype=batch["ids"].dtype) * cfg.table.vocab_per_field
    lin_logit = jnp.sum(
        jnp.take(params["linear"], batch["ids"] + offsets[None], axis=0), -1)
    return (cin_logit[:, 0] + deep_logit[:, 0] + lin_logit + params["bias"])


# ===========================================================================
# AutoInt — self-attentive feature interaction (arXiv:1810.11921)
# ===========================================================================


def autoint_init(rng, cfg: CTRConfig):
    d = cfg.table.dim
    da, nh = cfg.d_attn, cfg.n_attn_heads
    params = {
        "table": E.init_table(jax.random.fold_in(rng, 0), cfg.table,
                              cfg.np_dtype),
        "attn": [],
    }
    d_in = d
    for i in range(cfg.n_attn_layers):
        key = jax.random.fold_in(rng, 10 + i)
        params["attn"].append({
            "wq": L.dense_init(jax.random.fold_in(key, 0), d_in, da * nh,
                               cfg.np_dtype),
            "wk": L.dense_init(jax.random.fold_in(key, 1), d_in, da * nh,
                               cfg.np_dtype),
            "wv": L.dense_init(jax.random.fold_in(key, 2), d_in, da * nh,
                               cfg.np_dtype),
            "wres": L.dense_init(jax.random.fold_in(key, 3), d_in, da * nh,
                                 cfg.np_dtype),
        })
        d_in = da * nh
    params["head"] = L.dense_init(jax.random.fold_in(rng, 99),
                                  cfg.table.n_fields * d_in, 1, cfg.np_dtype)
    return params


def autoint_score(params, batch, cfg: CTRConfig):
    x = _sparse_features(params, batch, cfg)  # [B, F, D]
    b, f, _ = x.shape
    nh, da = cfg.n_attn_heads, cfg.d_attn
    for lp in params["attn"]:
        q = (x @ lp["wq"]).reshape(b, f, nh, da)
        k = (x @ lp["wk"]).reshape(b, f, nh, da)
        v = (x @ lp["wv"]).reshape(b, f, nh, da)
        sc = jnp.einsum("bfhd,bghd->bhfg", q, k).astype(jnp.float32) / da**0.5
        p = jax.nn.softmax(sc, -1).astype(x.dtype)
        o = jnp.einsum("bhfg,bghd->bfhd", p, v).reshape(b, f, nh * da)
        x = jax.nn.relu(o + x @ lp["wres"])
    return (x.reshape(b, -1) @ params["head"])[:, 0]


# ===========================================================================
# MIND — multi-interest capsule routing (arXiv:1904.08030)
# ===========================================================================


def mind_init(rng, cfg: CTRConfig):
    d = cfg.table.dim
    return {
        "item_emb": (jax.random.normal(jax.random.fold_in(rng, 0),
                                       (cfg.table.vocab_per_field, d)) * 0.02
                     ).astype(cfg.np_dtype),
        "bilinear": L.dense_init(jax.random.fold_in(rng, 1), d, d,
                                 cfg.np_dtype),
        "proj1": L.dense_init(jax.random.fold_in(rng, 2), d, 4 * d,
                              cfg.np_dtype),
        "proj2": L.dense_init(jax.random.fold_in(rng, 3), 4 * d, d,
                              cfg.np_dtype),
    }


def mind_interests(params, batch, cfg: CTRConfig):
    """Behavior sequence → K interest capsules via B2I dynamic routing."""
    hist = jnp.take(params["item_emb"], batch["hist"], axis=0)  # [B, T, D]
    mask = batch["hist_mask"].astype(jnp.float32)  # [B, T]
    b, t, d = hist.shape
    k = cfg.n_interests
    u = hist @ params["bilinear"]  # shared bilinear map S·e_i

    logits = jnp.zeros((b, k, t), jnp.float32)  # routing logits b_ij
    caps = jnp.zeros((b, k, d), hist.dtype)
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(logits, axis=1) * mask[:, None, :]
        s = jnp.einsum("bkt,btd->bkd", w.astype(hist.dtype), u)
        # squash
        nrm2 = jnp.sum(jnp.square(s.astype(jnp.float32)), -1, keepdims=True)
        caps = (s * (nrm2 / (1 + nrm2) / jnp.sqrt(nrm2 + 1e-9)).astype(s.dtype))
        logits = logits + jnp.einsum("bkd,btd->bkt", caps, u).astype(jnp.float32)
    # per-interest MLP (H-layer)
    caps = jax.nn.relu(caps @ params["proj1"]) @ params["proj2"]
    return caps  # [B, K, D]


def mind_loss(params, batch, cfg: CTRConfig):
    """Sampled-softmax with label-aware attention (hard max at train)."""
    caps = mind_interests(params, batch, cfg)  # [B, K, D]
    pos = jnp.take(params["item_emb"], batch["pos"], axis=0)  # [B, D]
    negs = jnp.take(params["item_emb"], batch["negs"], axis=0)  # [B, Nneg, D]
    # label-aware attention: pick the interest most aligned with the label
    att = jnp.einsum("bkd,bd->bk", caps, pos)
    best = jnp.take_along_axis(caps, jnp.argmax(att, -1)[:, None, None], 1)[:, 0]
    pos_logit = jnp.sum(best * pos, -1, keepdims=True)
    neg_logit = jnp.einsum("bd,bnd->bn", best, negs)
    logits = jnp.concatenate([pos_logit, neg_logit], -1)
    labels = jnp.zeros((caps.shape[0],), jnp.int32)
    return L.cross_entropy(logits, labels)


def mind_retrieval_scores(params, batch, cfg: CTRConfig):
    """max over interests of ⟨candidate, interest⟩ → [B, n_cand]."""
    caps = mind_interests(params, batch, cfg)
    cand = params["item_emb"]
    if "candidates" in batch:
        cand = jnp.take(params["item_emb"], batch["candidates"], axis=0)
    scores = jnp.einsum("bkd,nd->bkn", caps, cand)
    return jnp.max(scores, axis=1)


# ===========================================================================
# Shared CTR loss
# ===========================================================================


def ctr_loss(score_fn, params, batch, cfg: CTRConfig):
    logits = score_fn(params, batch, cfg)
    y = batch["labels"].astype(jnp.float32)
    lf = logits.astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(lf, 0) - lf * y + jnp.log1p(jnp.exp(-jnp.abs(lf))))
    return loss, logits
