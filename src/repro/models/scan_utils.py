"""Layer-stack scan with an unrolled variant.

Production always scans (HLO stays O(1) in depth).  ``unroll=True`` exists
for the dry-run's cost probe: XLA's HloCostAnalysis counts a while-loop body
ONCE regardless of trip count, so per-layer costs can only be measured from
an unrolled module (compile L=1 and L=2 unrolled; the difference is one
layer's true cost — see launch/dryrun.py::run_scan_probe).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def scan_layers(body, carry, xs, unroll: bool = False, length=None):
    """lax.scan(body, carry, xs) with an optional Python-loop unroll."""
    if not unroll:
        return lax.scan(body, carry, xs)
    if length is None:
        length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked
