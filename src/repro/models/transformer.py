"""Decoder-only transformer LM: dense and MoE variants, train + serve paths.

Covers the five assigned LM architectures:

* qwen3-moe-235b  — 94L GQA(64/4) MoE 128e top-8, SwiGLU experts, RMSNorm
* arctic-480b     — 35L GQA(56/8) MoE 128e top-2 + parallel dense residual
* olmo-1b         — 16L MHA(16/16) GELU? → spec: non-parametric LN, SwiGLU
* nemotron-4-15b  — 32L GQA(48/8) squared-ReLU FFN
* phi3-medium-14b — 40L GQA(40/10) RoPE SwiGLU

Implementation notes:
* layer stack is a `lax.scan` over stacked params (HLO is O(1) in depth);
  each layer body is `jax.checkpoint`-ed (full remat) when cfg.remat;
* GQA attention, RoPE, fp32 softmax;
* MoE via `models.moe` (shard_map EP; see that module);
* the serve path is prefill(tokens) → cache, then decode_step(cache, token);
  KV cache layout [L, B, KV, S, hd] with the sequence axis sharded over
  `model` for the 32k/500k decode shapes (flash-decoding style partials — the
  partial-softmax collectives are inserted by GSPMD from the sharding
  constraints).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import LMSharding, constrain
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.scan_utils import scan_layers


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    ffn: str = "swiglu"  # swiglu | sq_relu | gelu
    norm: str = "rmsnorm"  # rmsnorm | nonparam
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[moe_lib.MoEConfig] = None
    dtype: str = "float32"
    remat: bool = True
    unroll_layers: bool = False  # cost-probe only; see models/scan_utils.py

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def np_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.moe is not None:
            fe = self.moe.d_ff_expert
            ffn = self.moe.n_experts * 3 * d * fe + d * self.moe.n_experts
            if self.moe.dense_residual:
                ffn += (3 if self.ffn == "swiglu" else 2) * d * f
        else:
            ffn = (3 if self.ffn == "swiglu" else 2) * d * f
        per_layer = attn + ffn
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        fe = self.moe.d_ff_expert
        ffn = self.moe.top_k * 3 * d * fe + d * self.moe.n_experts
        if self.moe.dense_residual:
            ffn += (3 if self.ffn == "swiglu" else 2) * d * f
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn) + emb


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_transformer(rng, cfg: TransformerConfig):
    dt = cfg.np_dtype
    d, hd = cfg.d_model, cfg.head_dim
    n_q, n_kv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    keys = jax.random.split(rng, 12)

    def stack_init(key, shape, fan_in):
        ks = jax.random.split(key, cfg.n_layers)
        scale = (1.0 / fan_in) ** 0.5
        return (
            jax.vmap(lambda k: jax.random.normal(k, shape) * scale)(ks)
        ).astype(dt)

    lp = {
        "wq": stack_init(keys[0], (d, n_q), d),
        "wk": stack_init(keys[1], (d, n_kv), d),
        "wv": stack_init(keys[2], (d, n_kv), d),
        "wo": stack_init(keys[3], (n_q, d), n_q),
    }
    if cfg.norm == "rmsnorm":
        lp["attn_norm"] = jnp.ones((cfg.n_layers, d), dt)
        lp["ffn_norm"] = jnp.ones((cfg.n_layers, d), dt)
    dense_ffn = cfg.moe is None or cfg.moe.dense_residual
    if dense_ffn:
        if cfg.ffn == "swiglu":
            lp["w_gate"] = stack_init(keys[4], (d, cfg.d_ff), d)
        lp["w_up"] = stack_init(keys[5], (d, cfg.d_ff), d)
        lp["w_down"] = stack_init(keys[6], (cfg.d_ff, d), cfg.d_ff)
    if cfg.moe is not None:
        e, fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
        lp["router"] = stack_init(keys[7], (d, e), d).astype(jnp.float32)
        lp["moe_gate"] = stack_init(keys[8], (e, d, fe), d)
        lp["moe_up"] = stack_init(keys[9], (e, d, fe), d)
        lp["moe_down"] = stack_init(keys[10], (e, fe, d), fe)

    params = {
        "embed": (jax.random.normal(keys[11], (cfg.vocab_size, d)) * 0.02).astype(dt),
        "layers": lp,
    }
    if cfg.norm == "rmsnorm":
        params["final_norm"] = jnp.ones((d,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(jax.random.fold_in(rng, 99), (d, cfg.vocab_size))
            * (1.0 / d) ** 0.5
        ).astype(dt)
    return params


def param_partition_specs(cfg: TransformerConfig, shd: LMSharding):
    """PartitionSpec pytree matching init_transformer's structure."""
    from jax.sharding import PartitionSpec as P

    def batched(spec):  # layer-stacked params get a leading None axis
        return P(None, *spec)

    lp = {
        "wq": batched(shd.p_attn_in()),
        "wk": batched(shd.p_attn_in()),
        "wv": batched(shd.p_attn_in()),
        "wo": batched(shd.p_attn_out()),
    }
    if cfg.norm == "rmsnorm":
        lp["attn_norm"] = P(None, None)
        lp["ffn_norm"] = P(None, None)
    if cfg.moe is None or cfg.moe.dense_residual:
        if cfg.ffn == "swiglu":
            lp["w_gate"] = batched(shd.p_ffn_in())
        lp["w_up"] = batched(shd.p_ffn_in())
        lp["w_down"] = batched(shd.p_ffn_out())
    if cfg.moe is not None:
        lp["router"] = P(None, None, None)
        lp["moe_gate"] = batched(shd.p_expert_in())
        lp["moe_up"] = batched(shd.p_expert_in())
        lp["moe_down"] = batched(shd.p_expert_out())
    specs = {"embed": shd.p_embed(), "layers": lp}
    if cfg.norm == "rmsnorm":
        specs["final_norm"] = P(None)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, shd.model_axis)
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _dense_ffn(x, lp, cfg):
    if cfg.ffn == "swiglu":
        return L.swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"])
    if cfg.ffn == "sq_relu":
        return L.squared_relu_ffn(x, lp["w_up"], lp["w_down"])
    return L.gelu_ffn(x, lp["w_up"], lp["w_down"])


def _ffn_block(x, lp, cfg, mesh, shd):
    """FFN or MoE (+ optional arctic dense residual branch)."""
    if cfg.moe is None:
        return _dense_ffn(x, lp, cfg)
    moe_params = {
        "router": lp["router"],
        "w_gate": lp["moe_gate"],
        "w_up": lp["moe_up"],
        "w_down": lp["moe_down"],
    }
    if mesh is not None and shd is not None:
        x = constrain(x, shd.act())
        out = moe_lib.moe_apply(
            x, moe_params, cfg.moe, mesh=mesh,
            data_axes=shd.data_axes, model_axis=shd.model_axis,
            fsdp_axis=shd.fsdp_axis(), fsdp_mode=shd.moe_fsdp_mode)
    else:
        out = moe_lib.moe_apply(x, moe_params, cfg.moe, mesh=None)
    if cfg.moe.dense_residual:
        out = out + _dense_ffn(x, lp, cfg)
    return out


def _norm(x, scale_or_none):
    return L.norm(x, scale_or_none)


def _attention_train(x, lp, cfg, positions, shd):
    b, s, d = x.shape
    hd, h, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ lp["wq"]).reshape(b, s, h, hd)
    k = (x @ lp["wk"]).reshape(b, s, kv, hd)
    v = (x @ lp["wv"]).reshape(b, s, kv, hd)
    if shd is not None:
        q = constrain(q, shd.act_heads())
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    rep = h // kv
    # Group query heads by their KV head: [b, s, kv, rep, hd].
    qg = q.reshape(b, s, kv, rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qg, k).astype(jnp.float32)
    scores = scores / (hd**0.5)
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, v).reshape(b, s, h * hd)
    return out @ lp["wo"]


def _layer_train(x, lp, cfg, mesh, positions, shd):
    a_scale = lp.get("attn_norm")
    f_scale = lp.get("ffn_norm")
    h = _attention_train(_norm(x, a_scale), lp, cfg, positions, shd)
    x = x + h
    h = _ffn_block(_norm(x, f_scale), lp, cfg, mesh, shd)
    return x + h


def logits_train(params, tokens, cfg: TransformerConfig, mesh=None,
                 shd: Optional[LMSharding] = None):
    """Full forward for training: tokens [B, S] → logits [B, S, V]."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if shd is not None:
        x = constrain(x, shd.act())
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, lp):
        y = _layer_train(carry, lp, cfg, mesh, positions, shd)
        if shd is not None:
            y = constrain(y, shd.act())
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = scan_layers(body, x, params["layers"], cfg.unroll_layers)
    x = _norm(x, params.get("final_norm"))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if shd is not None:
        logits = constrain(logits, shd.logits())
    return logits


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=None):
    dt = dtype or cfg.np_dtype
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def cache_partition_specs(cfg: TransformerConfig, shd: LMSharding):
    from jax.sharding import PartitionSpec as P

    spec = P(None, *shd.cache())
    return {"k": spec, "v": spec}


def _attention_decode(x, lp, cfg, k_cache, v_cache, pos, shd):
    """x [B, D] one new token; cache [B, KV, S, hd]; pos scalar int."""
    b, d = x.shape
    hd, h, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    rep = h // kv
    q = (x @ lp["wq"]).reshape(b, kv, rep, hd)
    k_new = (x @ lp["wk"]).reshape(b, kv, 1, hd)
    v_new = (x @ lp["wv"]).reshape(b, kv, 1, hd)
    posb = jnp.full((b, 1), pos)
    q = L.rope(q.reshape(b, 1, kv * rep, hd), posb, cfg.rope_theta).reshape(
        b, kv, rep, hd)
    k_new = L.rope(k_new.transpose(0, 2, 1, 3), posb, cfg.rope_theta
                   ).transpose(0, 2, 1, 3)
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k_new, pos, axis=2)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v_new, pos, axis=2)
    if shd is not None:
        k_cache = constrain(k_cache, shd.cache())
        v_cache = constrain(v_cache, shd.cache())
    s = k_cache.shape[2]
    scores = jnp.einsum("bkrh,bksh->bkrs", q, k_cache).astype(jnp.float32)
    scores = scores / (hd**0.5)
    valid = (jnp.arange(s) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrs,bksh->bkrh", probs, v_cache).reshape(b, h * hd)
    return out @ lp["wo"], k_cache, v_cache


def decode_step(params, cache, token, pos, cfg: TransformerConfig, mesh=None,
                shd: Optional[LMSharding] = None):
    """One decode step: token [B] → (logits [B, V], updated cache)."""
    x = jnp.take(params["embed"], token, axis=0)  # [B, D]

    def body(carry, scanned):
        xc = carry
        lp, k_c, v_c = scanned
        a_scale = lp.get("attn_norm")
        f_scale = lp.get("ffn_norm")
        h, k_c, v_c = _attention_decode(
            _norm(xc, a_scale), lp, cfg, k_c, v_c, pos, shd)
        xc = xc + h
        h = _ffn_block(_norm(xc, f_scale)[:, None, :], lp, cfg, mesh, shd)
        xc = xc + h[:, 0, :]
        return xc, (k_c, v_c)

    x, (k_new, v_new) = scan_layers(
        body, x, (params["layers"], cache["k"], cache["v"]),
        cfg.unroll_layers)
    x = _norm(x, params.get("final_norm"))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if shd is not None:
        logits = constrain(logits, jax.sharding.PartitionSpec(
            shd.batch, shd.model_axis))
    return logits, {"k": k_new, "v": v_new}


def prefill(params, tokens, cfg: TransformerConfig, mesh=None,
            shd: Optional[LMSharding] = None, max_seq: Optional[int] = None):
    """Prefill: tokens [B, S] → (last-position logits, KV cache)."""
    b, s = tokens.shape
    max_seq = max_seq or s
    x = jnp.take(params["embed"], tokens, axis=0)
    if shd is not None:
        x = constrain(x, shd.act())
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, lp):
        xc = carry
        a_scale = lp.get("attn_norm")
        f_scale = lp.get("ffn_norm")
        xn = _norm(xc, a_scale)
        hd, h_, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
        k = (xn @ lp["wk"]).reshape(b, s, kv, hd)
        v = (xn @ lp["wv"]).reshape(b, s, kv, hd)
        k = L.rope(k, positions, cfg.rope_theta)
        h = _attention_train(xn, lp, cfg, positions, shd)
        xc = xc + h
        h = _ffn_block(_norm(xc, f_scale), lp, cfg, mesh, shd)
        xc = xc + h
        k = k.transpose(0, 2, 1, 3)  # [B, KV, S, hd]
        v = v.transpose(0, 2, 1, 3)
        if max_seq > s:
            pad = ((0, 0), (0, 0), (0, max_seq - s), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        if shd is not None:
            k = constrain(k, shd.cache())
            v = constrain(v, shd.cache())
        return xc, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (k_all, v_all) = scan_layers(body, x, params["layers"],
                                    cfg.unroll_layers)
    x = _norm(x[:, -1], params.get("final_norm"))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, {"k": k_all, "v": v_all}
