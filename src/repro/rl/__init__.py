"""Paper §4: query-expansion RL on a synthetic collection.

Pyndri → ``data.synthetic_ir.ql_scores`` (Dirichlet QL ranking, in-process);
pytrec_eval → ``core`` evaluation (device-resident); OpenAI Gym → a
dependency-free environment with the same reset/step contract.
"""
