"""Query-expansion environment (paper §4, gym-style contract).

State: the set of terms in the expanded query (observation = binary vocab
vector).  Action: add one vocabulary term (or no-op).  Reward: ΔNDCG of the
re-ranked top-10 — computed by the in-process evaluator on every step, which
is exactly the workload pytrec_eval makes cheap (the serialize-invoke-parse
equivalent would fork a process per env step).

Episodes terminate after ``max_actions`` expansions or a perfect NDCG.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import RelevanceEvaluator
from repro.data import synthetic_ir as sir


@dataclasses.dataclass
class EnvConfig:
    depth: int = 10
    max_actions: int = 5
    mu: float = 2500.0
    measure: str = "ndcg"


class QueryExpansionEnv:
    def __init__(self, collection: sir.Collection,
                 cfg: Optional[EnvConfig] = None):
        self.coll = collection
        self.cfg = cfg or EnvConfig()
        self.evaluator = RelevanceEvaluator(collection.qrels,
                                            {self.cfg.measure})
        self._qid: Optional[str] = None
        self._terms: Optional[np.ndarray] = None
        self._ndcg: float = 0.0
        self._steps = 0

    @property
    def n_actions(self) -> int:
        return self.coll.cfg.vocab_size + 1  # + no-op

    def _evaluate(self) -> float:
        scores = sir.ql_scores(self.coll, self._terms, self.cfg.mu)
        run = sir.run_from_scores(self.coll, {self._qid: scores},
                                  self.cfg.depth)
        res = self.evaluator.evaluate(run)
        return float(res[self._qid][self.cfg.measure])

    def reset(self, qid: str) -> np.ndarray:
        self._qid = qid
        self._terms = np.array(self.coll.query_terms[qid], dtype=np.int64)
        self._steps = 0
        self._ndcg = self._evaluate()
        return self.observation()

    def observation(self) -> np.ndarray:
        obs = np.zeros(self.coll.cfg.vocab_size, dtype=bool)
        obs[self._terms] = True
        return obs

    def step(self, action: int) -> Tuple[np.ndarray, float, bool, Dict]:
        assert self._qid is not None, "call reset() first"
        self._steps += 1
        if action < self.coll.cfg.vocab_size:  # expansion (else: no-op)
            self._terms = np.append(self._terms, action)
        new_ndcg = self._evaluate()
        reward = new_ndcg - self._ndcg
        self._ndcg = new_ndcg
        done = (self._steps >= self.cfg.max_actions) or new_ndcg >= 1.0
        return self.observation(), reward, done, {self.cfg.measure: new_ndcg}
