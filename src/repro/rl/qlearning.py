"""Tabular Q-learning agent (paper §4: α=0.1, γ=0.95, ε=0.05)."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.rl.environment import QueryExpansionEnv


@dataclasses.dataclass
class QLearningConfig:
    alpha: float = 0.1
    gamma: float = 0.95
    epsilon: float = 0.05
    # action sub-sampling keeps the tabular policy tractable on big vocabs
    n_candidate_actions: int = 64
    seed: int = 0


class QLearningAgent:
    def __init__(self, env: QueryExpansionEnv, cfg: QLearningConfig):
        self.env = env
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.q: Dict[Tuple, np.ndarray] = {}
        # fixed candidate action set (uniform vocab sample + no-op)
        v = env.coll.cfg.vocab_size
        n = min(cfg.n_candidate_actions, v)
        self.actions = np.concatenate(
            [self.rng.choice(v, size=n, replace=False), [v]])

    def _state_key(self, obs: np.ndarray) -> Tuple:
        return tuple(np.flatnonzero(obs).tolist())

    def _qvals(self, key: Tuple) -> np.ndarray:
        if key not in self.q:
            self.q[key] = np.zeros(len(self.actions), dtype=np.float64)
        return self.q[key]

    def act(self, obs: np.ndarray) -> int:
        if self.rng.random() < self.cfg.epsilon:
            return int(self.rng.integers(len(self.actions)))
        return int(np.argmax(self._qvals(self._state_key(obs))))

    def episode(self, qid: str) -> float:
        """One training episode; returns total reward (ΔNDCG)."""
        obs = self.env.reset(qid)
        total = 0.0
        done = False
        while not done:
            a_idx = self.act(obs)
            new_obs, reward, done, _ = self.env.step(int(self.actions[a_idx]))
            total += reward
            key, new_key = self._state_key(obs), self._state_key(new_obs)
            qv = self._qvals(key)
            target = reward + (0.0 if done else
                               self.cfg.gamma * self._qvals(new_key).max())
            qv[a_idx] += self.cfg.alpha * (target - qv[a_idx])
            obs = new_obs
        return total

    def train(self, qids: List[str], episodes: int,
              log_every: int = 0) -> List[float]:
        rewards = []
        for ep in range(episodes):
            qid = qids[int(self.rng.integers(len(qids)))]
            rewards.append(self.episode(qid))
            if log_every and (ep + 1) % log_every == 0:
                avg = float(np.mean(rewards[-log_every:]))
                print(f"episode {ep + 1}: avg reward {avg:+.4f}")
        return rewards
