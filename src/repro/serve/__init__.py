"""Async evaluation serving on the tokenized (``RunBuffer``) path.

The paper makes single evaluations cheap; this package makes MANY concurrent
evaluations cheap: an asyncio service that interns each qrel once (bounded
LRU of evaluators), coalesces concurrent requests for the same collection
into one batched backend call, and answers over stdio or TCP JSON-lines.

    >>> import asyncio
    >>> from repro.serve import EvaluationService
    >>> async def demo():
    ...     svc = EvaluationService()
    ...     svc.register_qrel('t', {'q1': {'d1': 1}}, ('recip_rank',))
    ...     res = await svc.evaluate('t', run={'q1': {'d1': 1.0}})
    ...     return res.per_query['q1']['recip_rank']
    >>> asyncio.run(demo())
    1.0

See ``docs/SERVING.md`` for the request lifecycle, coalescing windows,
cache-eviction and backpressure semantics, and the wire protocol (frame
limits, error codes, auth, rate limiting — :mod:`repro.serve.wire`);
``python -m repro.serve --help`` for the front-end flags.  The client side
of the protocol lives in :mod:`repro.client` (persistent connections,
pipelining, reconnect-with-retry).
"""

from repro.serve.batcher import MicroBatcher
from repro.serve.cache import LRUCache
from repro.serve.frontend import (handle_line, handle_request, main,
                                  serve_protocol, serve_stdio, serve_tcp)
from repro.serve.service import EvaluationService, ServeResult
from repro.serve.wire import (DEFAULT_FRAME_LIMIT, ERROR_CODES,
                              OversizedFrame, ProtocolError, TokenBucket,
                              iter_frames)

__all__ = [
    "EvaluationService",
    "ServeResult",
    "MicroBatcher",
    "LRUCache",
    "handle_request",
    "handle_line",
    "serve_tcp",
    "serve_protocol",
    "serve_stdio",
    "main",
    "DEFAULT_FRAME_LIMIT",
    "ERROR_CODES",
    "OversizedFrame",
    "ProtocolError",
    "TokenBucket",
    "iter_frames",
]
