"""``python -m repro.serve`` — run the evaluation service front-end."""

import sys

from repro.serve.frontend import main

if __name__ == "__main__":
    sys.exit(main())
