"""Time/size-windowed coalescing of concurrent evaluation requests.

The paper's core argument is that evaluation overhead is dominated by fixed
per-call costs; the serving corollary is that N concurrent requests for the
same collection should pay those costs ONCE.  :class:`MicroBatcher` is the
piece that makes this happen: requests submitted for the same key within a
short window (or until a size cap fills) are flushed together as one list,
and the caller's flush function turns the whole list into one backend
``evaluate_buffers`` call.

Semantics:

* the FIRST item arriving for an idle key opens that key's window; a flush
  fires ``window`` seconds later with everything that accumulated;
* reaching ``max_batch`` pending items flushes immediately (the timer for
  that generation is cancelled) — latency is thus bounded by ``window`` and
  batch size by ``max_batch``;
* each flush calls ``flush_fn(key, items)`` — an async callable returning
  one result per item, in order.  Results (or the raised exception) are
  fanned back out to every waiter;
* cancellation fans out too: ``asyncio.CancelledError`` is a
  ``BaseException``, so it is handled on its own path — a cancelled flush
  (or a timer cancelled mid-window at teardown) cancels every coalesced
  waiter's future and re-raises, instead of leaving them pending forever;
* ``window=0`` still coalesces: the flush is scheduled as a task, so every
  request already sitting in the event-loop's ready queue joins the batch.

The batcher is asyncio-native and single-loop; it holds no threads of its
own.  Backend work belonging in a thread (jit dispatch, numpy scatter) is
the flush function's business (`asyncio.to_thread`), not the batcher's.
"""

from __future__ import annotations

import asyncio
from typing import Any, Awaitable, Callable, Dict, List, Tuple

FlushFn = Callable[[str, List[Any]], Awaitable[List[Any]]]


class MicroBatcher:
    """Coalesce per-key submissions into windowed flush calls.

    >>> import asyncio
    >>> async def demo():
    ...     async def flush(key, items):  # one "backend call" per flush
    ...         return [f"{key}:{x}" for x in items]
    ...     mb = MicroBatcher(flush, window=0.005, max_batch=8)
    ...     out = await asyncio.gather(*(mb.submit('k', i) for i in range(3)))
    ...     return out, mb.flushes
    >>> asyncio.run(demo())
    (['k:0', 'k:1', 'k:2'], 1)
    """

    def __init__(self, flush_fn: FlushFn, window: float = 0.002,
                 max_batch: int = 64):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self._flush_fn = flush_fn
        self.window = float(window)
        self.max_batch = int(max_batch)
        #: pending per key: list of (item, future) awaiting the next flush
        self._pending: Dict[str, List[Tuple[Any, asyncio.Future]]] = {}
        self._timers: Dict[str, asyncio.Task] = {}
        self._inflight = 0  # claimed batches whose flush has not finished
        self.flushes = 0  # completed flush calls (the backend-call count)
        self.submitted = 0

    async def submit(self, key: str, item: Any) -> Any:
        """Queue ``item`` under ``key``; resolves with its flush result."""
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        slot = self._pending.setdefault(key, [])
        slot.append((item, fut))
        self.submitted += 1
        if len(slot) >= self.max_batch:
            self._flush_now(key)
        elif key not in self._timers:
            self._timers[key] = loop.create_task(self._timed_flush(key))
        return await fut

    async def _timed_flush(self, key: str) -> None:
        # Leave the timer registry BEFORE flushing: once a flush is in
        # progress it must not be cancellable by a size-cap flush of the
        # next generation, or its waiters would never resolve.
        try:
            if self.window > 0:
                await asyncio.sleep(self.window)
        except asyncio.CancelledError:
            # Cancelled while waiting out the window.  Two callers do this:
            # ``_flush_now`` (which has ALREADY claimed the batch — our key
            # may even belong to a newer generation by now) and external
            # teardown (which has not).  Only if we are still the registered
            # timer is the pending batch ours to clean up; claim it and
            # cancel its waiters so no submit() awaits a flush that will
            # never come.  Either way the cancellation keeps propagating.
            if self._timers.get(key) is asyncio.current_task():
                del self._timers[key]
                for _, fut in self._pending.pop(key, []):
                    if not fut.done():
                        fut.cancel()
            raise
        self._timers.pop(key, None)
        # Claim the batch and mark it in flight in the same loop step the
        # timer leaves the registry, so idle() never sees a gap between
        # "timer gone" and "flush running" (drain relies on this).
        batch = self._pending.pop(key, [])
        self._inflight += 1
        try:
            await self._do_flush(key, batch)
        finally:
            self._inflight -= 1

    def _flush_now(self, key: str) -> None:
        """Size cap reached: cancel the window timer, flush immediately.

        The batch is claimed synchronously HERE — if it were left for the
        flush task to pop, requests arriving before that task runs would
        pile into the same batch and ``max_batch`` would not actually bound
        the coalesced size.
        """
        timer = self._timers.pop(key, None)
        if timer is not None:
            timer.cancel()
        batch = self._pending.pop(key, [])
        self._inflight += 1  # claimed here, released when the task finishes
        asyncio.get_running_loop().create_task(self._guarded_flush(key, batch))

    async def _guarded_flush(self, key: str, batch) -> None:
        try:
            await self._do_flush(key, batch)
        finally:
            self._inflight -= 1

    async def _do_flush(self, key: str, batch) -> None:
        if not batch:
            return
        items = [item for item, _ in batch]
        try:
            results = await self._flush_fn(key, items)
            if len(results) != len(items):
                raise RuntimeError(
                    f"flush returned {len(results)} results for "
                    f"{len(items)} items")
        except asyncio.CancelledError:
            # CancelledError is a BaseException (py3.8+), so the Exception
            # clause below never sees it.  A cancelled flush — the flush_fn
            # was cancelled, or the flush task itself was — must still fan
            # out to its waiters, or every submit() coalesced into this
            # batch awaits a future nobody will ever resolve.  Then
            # re-raise: cancellation must keep propagating to the task.
            for _, fut in batch:
                if not fut.done():
                    fut.cancel()
            raise
        except Exception as exc:  # noqa: BLE001 — fan the error out to waiters
            for _, fut in batch:
                if not fut.done():
                    fut.set_exception(exc)
            return
        finally:
            self.flushes += 1
        for (_, fut), res in zip(batch, results):
            if not fut.done():
                fut.set_result(res)

    def pending_count(self, key: str) -> int:
        return len(self._pending.get(key, ()))

    def idle(self) -> bool:
        """True when no batch is accumulating, timed, or mid-flush."""
        return (not self._pending and not self._timers
                and self._inflight == 0)
