"""Bounded LRU cache for interned evaluation collections.

A :class:`repro.core.RelevanceEvaluator` pays its string costs (docno
vocabulary interning, qrel slab layout) at construction; the serve layer
therefore builds each collection's evaluator ONCE and reuses it across every
request that names the same ``qrel_id``.  This module provides the bounded
container for those entries: least-recently-used eviction keeps the resident
set under a fixed cap no matter how many collections clients register over a
service's lifetime.

The cache is deliberately generic (string key → arbitrary entry) so tests
can exercise the eviction policy without building evaluators, and
thread-safe — service handlers touch it from the event loop while backend
flushes run on executor threads.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Dict, Iterator, Optional, TypeVar

T = TypeVar("T")

_MISSING = object()  # sentinel: distinguishes "absent" from "stored None"


class LRUCache:
    """A thread-safe, bounded, least-recently-used mapping.

    ``get`` and ``put`` both count as a "use".  When an insert pushes the
    size past ``capacity``, the least-recently-used entry is dropped and the
    optional ``on_evict(key, value)`` hook fires (the service uses it to
    count evictions and release per-collection state).  Replacing an
    existing key's entry with a DIFFERENT value fires the hook too — the
    displaced value leaves the cache just as surely as an evicted one, and
    whoever owns its resources must hear about it.  Re-putting the same
    object is a no-op refresh and fires nothing.

    >>> c = LRUCache(capacity=2)
    >>> c.put('a', 1); c.put('b', 2)
    >>> _ = c.get('a')          # 'a' is now most recently used
    >>> c.put('c', 3)           # evicts 'b', the LRU entry
    >>> sorted(c.keys()), c.get('b') is None
    (['a', 'c'], True)
    """

    def __init__(self, capacity: int,
                 on_evict: Optional[Callable[[str, T], None]] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._on_evict = on_evict
        self._entries: "OrderedDict[str, T]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.replacements = 0

    def get(self, key: str) -> Optional[T]:
        """The entry for ``key`` (refreshing its recency), or ``None``."""
        with self._lock:
            if key not in self._entries:
                self.misses += 1
                return None
            self.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]

    def put(self, key: str, value: T) -> None:
        """Insert/replace ``key``, evicting the LRU entry past capacity.

        A replacement (same key, different value object) fires ``on_evict``
        for the displaced value; identity, not equality, decides — putting
        the same object back is a recency refresh only.
        """
        displaced = []  # (key, value) pairs leaving the cache; hook per pair
        with self._lock:
            old = self._entries.get(key, _MISSING)
            self._entries[key] = value
            self._entries.move_to_end(key)
            if old is not _MISSING and old is not value:
                self.replacements += 1
                displaced.append((key, old))
            if len(self._entries) > self.capacity:
                displaced.append(self._entries.popitem(last=False))
                self.evictions += 1
        if self._on_evict is not None:
            for pair in displaced:
                self._on_evict(*pair)

    def pop(self, key: str) -> Optional[T]:
        """Remove and return ``key``'s entry (no evict hook), or ``None``."""
        with self._lock:
            return self._entries.pop(key, None)

    def keys(self) -> Iterator[str]:
        with self._lock:
            return iter(list(self._entries))

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> Dict[str, int]:
        """Counters for the service's ``stats`` op."""
        with self._lock:
            return {"size": len(self._entries), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "replacements": self.replacements}
