"""Multi-worker serve cluster: a consistent-hash router over processes.

One ``repro.serve`` process coalesces beautifully but is one asyncio loop
behind one GIL.  This package is the scale-out story: a router front-end
(:class:`~repro.serve.cluster.router.Router`) speaking the *same*
JSON-lines protocol on its public port, consistent-hashing ``qrel_id``s
(:class:`~repro.serve.cluster.ring.HashRing`) onto a supervised pool of
``python -m repro.serve`` worker subprocesses
(:class:`~repro.serve.cluster.worker.WorkerProcess`) and fanning requests
out/in over :class:`repro.client.AsyncEvalClient` connections — each
collection interned by exactly one worker, each worker's micro-batcher
still coalescing the traffic aimed at it.

Workers are restarted with backoff on crash or failed health probe, and
the router replays its registration journal onto the fresh process, so
idempotent requests (``evaluate``, ``compare``, ``register_*``) retry
transparently across a worker death; non-idempotent ``drop_qrel`` answers
a machine-readable ``worker_unavailable`` error instead.  See
``docs/SERVING.md`` (cluster section) for topology, failure semantics,
and the ``python -m repro.serve.cluster`` flags; tests in
``tests/test_cluster.py`` pin bit-identity against single-process serving
and exercise the fault paths deterministically.
"""

from repro.serve.cluster.ring import HashRing
from repro.serve.cluster.router import Router
from repro.serve.cluster.worker import WorkerProcess, WorkerStartupError

__all__ = [
    "HashRing",
    "Router",
    "WorkerProcess",
    "WorkerStartupError",
]
