"""Multi-worker serve cluster: a consistent-hash router over processes.

One ``repro.serve`` process coalesces beautifully but is one asyncio loop
behind one GIL.  This package is the scale-out story: a router front-end
(:class:`~repro.serve.cluster.router.Router`) speaking the *same*
JSON-lines protocol on its public port, consistent-hashing ``qrel_id``s
(:class:`~repro.serve.cluster.ring.HashRing`) onto a supervised pool of
``python -m repro.serve`` worker subprocesses
(:class:`~repro.serve.cluster.worker.WorkerProcess`) and fanning requests
out/in over :class:`repro.client.AsyncEvalClient` connections — each
collection interned by exactly one worker, each worker's micro-batcher
still coalescing the traffic aimed at it.

With ``replication >= 2`` each collection is owned by a *replica set*
(ring successor walk): registrations fan out to every live replica before
acking, reads balance across replicas with power-of-two-choices filtered
through per-worker circuit breakers
(:class:`~repro.serve.cluster.breaker.CircuitBreaker`), and a replica
dying mid-request fails over to its sibling instantly.  Workers are
restarted with backoff on crash or failed health probe, and the router
replays its registration journal
(:class:`~repro.serve.cluster.journal.RegistrationJournal` — durable on
disk with ``--state-dir``) onto the fresh process, so idempotent requests
(``evaluate``, ``compare``, ``register_*``) retry transparently across a
worker death; non-idempotent ``drop_qrel`` answers a machine-readable
``worker_unavailable`` error only when EVERY replica is unreachable.
Requests may carry ``deadline_ms`` — enforced end-to-end at the router
(``deadline_exceeded``), with hedged second requests for idempotent ops
near the deadline.

The chaos harness (:mod:`repro.serve.cluster.chaos`) replays seeded
declarative fault schedules — kill, SIGSTOP-hang, response delay, byte
truncation — against a live cluster; ``tests/test_chaos.py`` asserts
results stay bit-identical to in-process evaluation and no acknowledged
registration is ever lost.  See ``docs/SERVING.md`` (cluster section) for
topology, the failure-semantics matrix, and the ``python -m
repro.serve.cluster`` flags; tests in ``tests/test_cluster.py`` pin
bit-identity against single-process serving and exercise the fault paths
deterministically.
"""

from repro.serve.cluster.breaker import CircuitBreaker
from repro.serve.cluster.chaos import (ChaosEvent, ChaosInjector,
                                       ChaosSchedule, FaultProxy,
                                       ProxyManager, inject)
from repro.serve.cluster.journal import RegistrationJournal
from repro.serve.cluster.ring import HashRing
from repro.serve.cluster.router import Router
from repro.serve.cluster.worker import WorkerProcess, WorkerStartupError

__all__ = [
    "ChaosEvent",
    "ChaosInjector",
    "ChaosSchedule",
    "CircuitBreaker",
    "FaultProxy",
    "HashRing",
    "ProxyManager",
    "RegistrationJournal",
    "Router",
    "WorkerProcess",
    "WorkerStartupError",
    "inject",
]
