"""``python -m repro.serve.cluster`` — boot a router + worker pool.

The router listens on ``--host``/``--port`` speaking the exact same
JSON-lines protocol as a single ``python -m repro.serve`` server (clients
and the ``repro.client`` library work unchanged), and fans requests out to
``--workers`` supervised ``repro.serve`` subprocesses by consistent-hashed
``qrel_id``.

Router-level knobs (``--auth-token``, ``--rate-limit``, ``--burst``,
``--max-frame-mb``) guard the public listener; the worker knobs
(``--backend``, ``--window-ms``, ``--max-batch``, ``--max-collections``,
``--max-pending``) pass through to every worker's command line.

Robustness knobs: ``--replication R`` gives every collection a replica
set of R workers (fan-out registrations, balanced reads, instant
failover); ``--state-dir DIR`` makes the registration journal durable so
a full cluster restart against the same DIR recovers every acknowledged
collection; ``--breaker-failures`` / ``--breaker-cooldown`` tune the
per-worker circuit breaker and ``--hedge-fraction`` when deadline-carrying
requests hedge to a sibling.

SIGINT/SIGTERM drain gracefully: stop accepting, answer in-flight
requests, then SIGTERM each worker so it runs its own drain.
"""

from __future__ import annotations

import argparse
import asyncio
import signal
import sys
from typing import Optional, Sequence

from repro.serve.cluster.router import Router
from repro.serve.frontend import serve_protocol
from repro.serve.wire import DEFAULT_FRAME_LIMIT


def build_router(args, *, frame_limit: int) -> Router:
    """A :class:`Router` from parsed CLI args (worker flags passed through)."""
    worker_args = [
        "--backend", args.backend,
        "--window-ms", str(args.window_ms),
        "--max-batch", str(args.max_batch),
        "--max-collections", str(args.max_collections),
        "--max-pending", str(args.max_pending),
    ]
    return Router(args.workers, worker_args=worker_args,
                  replicas=args.replicas, replication=args.replication,
                  retries=args.retries,
                  health_interval=args.health_interval,
                  frame_limit=frame_limit, state_dir=args.state_dir,
                  breaker_failures=args.breaker_failures,
                  breaker_cooldown=args.breaker_cooldown,
                  hedge_fraction=args.hedge_fraction)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.cluster",
        description="Consistent-hash router over a pool of repro.serve "
                    "worker processes (same JSON-lines protocol).")
    ap.add_argument("--workers", type=int, default=2, metavar="N",
                    help="worker processes in the pool (default 2)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="router listen address (default 127.0.0.1)")
    ap.add_argument("--port", type=int, default=0,
                    help="router listen port (default 0 = ephemeral)")
    ap.add_argument("--replicas", type=int, default=64, metavar="N",
                    help="virtual nodes per worker on the hash ring")
    ap.add_argument("--replication", type=int, default=1, metavar="R",
                    help="replica set size per collection: registrations "
                         "fan out to R workers, reads balance across "
                         "them and fail over instantly (default 1)")
    ap.add_argument("--state-dir", default=None, metavar="DIR",
                    help="durable registration journal directory; a "
                         "restarted cluster pointed at the same DIR "
                         "recovers every acknowledged collection")
    ap.add_argument("--retries", type=int, default=3, metavar="N",
                    help="transparent retries of idempotent ops across "
                         "worker restarts")
    ap.add_argument("--health-interval", type=float, default=1.0,
                    metavar="S", help="seconds between worker health "
                    "probes (default 1)")
    ap.add_argument("--breaker-failures", type=int, default=3, metavar="N",
                    help="consecutive transport failures that open a "
                         "worker's circuit breaker (default 3)")
    ap.add_argument("--breaker-cooldown", type=float, default=1.0,
                    metavar="S", help="seconds an open breaker waits "
                    "before its half-open probe (default 1)")
    ap.add_argument("--hedge-fraction", type=float, default=0.5,
                    metavar="F", help="share of a request's deadline_ms "
                    "budget that elapses before an idempotent request is "
                    "hedged to a sibling replica (default 0.5)")
    # router-level hardening (same semantics as python -m repro.serve)
    ap.add_argument("--max-frame-mb", type=float,
                    default=DEFAULT_FRAME_LIMIT / 2**20, metavar="MB",
                    help="request line length limit in MiB (default 64)")
    ap.add_argument("--auth-token", default=None, metavar="TOKEN",
                    help="require connections to authenticate first")
    ap.add_argument("--rate-limit", type=float, default=None, metavar="N",
                    help="per-connection token-bucket budget in requests/s")
    ap.add_argument("--burst", type=float, default=None, metavar="N",
                    help="token-bucket burst capacity (default max(1, rate))")
    # worker pass-through knobs
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "single", "sharded"),
                    help="worker evaluation backend")
    ap.add_argument("--window-ms", type=float, default=2.0, metavar="MS",
                    help="worker coalescing window in milliseconds")
    ap.add_argument("--max-batch", type=int, default=64, metavar="N",
                    help="worker early-flush batch size")
    ap.add_argument("--max-collections", type=int, default=8, metavar="N",
                    help="worker LRU capacity for resident collections")
    ap.add_argument("--max-pending", type=int, default=256, metavar="N",
                    help="worker in-flight request cap")
    args = ap.parse_args(argv)
    limit = max(1, int(args.max_frame_mb * 2**20))

    async def run() -> None:
        router = build_router(args, frame_limit=limit)
        await router.start()
        server = await serve_protocol(
            router.handle, args.host, args.port, limit=limit,
            auth_token=args.auth_token, rate_limit=args.rate_limit,
            burst=args.burst)
        addr = server.sockets[0].getsockname()
        print(f"serving on {addr[0]}:{addr[1]}", file=sys.stderr,
              flush=True)
        print(f"cluster: {args.workers} worker(s) "
              f"{', '.join(router.worker_names)}", file=sys.stderr,
              flush=True)
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                loop.add_signal_handler(sig, stop.set)
            except (NotImplementedError, RuntimeError):
                pass  # platform without signal handlers (Windows loop)
        try:
            await stop.wait()
        finally:
            # stop accepting, give already-read lines a beat to enter
            # handle(), answer in-flight, then cascade to the workers
            server.close()
            await server.wait_closed()
            await asyncio.sleep(0.05)
            await router.drain()
            others = [t for t in asyncio.all_tasks()
                      if t is not asyncio.current_task()]
            if others:
                await asyncio.wait(others, timeout=1.0)
            print("drained; exiting", file=sys.stderr, flush=True)

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
