"""Per-worker circuit breaker: stop aiming traffic at a failing replica.

The router's power-of-two-choices balancer needs a candidate set that is
not just "process alive" (``ready``) but "recently answering": a worker
that is up-but-failing (proxy truncating its responses, connection flaps,
replies timing out against deadlines) would otherwise keep absorbing half
the traffic and converting it into retries.  The classic three-state
breaker fixes that:

* **closed** — healthy; every request is allowed.  ``failures``
  *consecutive* failures trip it open (any success resets the count).
* **open** — the worker is cut out of the candidate set for ``cooldown``
  seconds; requests route to its siblings instead.
* **half-open** — after the cooldown, exactly ONE probe request is let
  through.  Success closes the breaker; failure re-opens it for another
  cooldown.

The clock is injectable so tests (and the doctest below) are exact:

>>> now = [0.0]
>>> b = CircuitBreaker(failures=2, cooldown=1.0, clock=lambda: now[0])
>>> b.state, b.would_allow()
('closed', True)
>>> b.record_failure(); b.record_failure()      # trip: 2 consecutive
>>> b.state, b.would_allow()
('open', False)
>>> now[0] = 1.5                                # cooldown elapsed
>>> b.would_allow(), b.allow()                  # one half-open probe
(True, True)
>>> b.state, b.allow()                          # ...and only one
('half_open', False)
>>> b.record_success(); b.state                 # probe succeeded
'closed'
"""

from __future__ import annotations

import time
from typing import Callable

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """Consecutive-failure breaker with a single half-open probe.

    ``would_allow`` is the pure check the balancer uses to *filter*
    candidates (it never consumes the probe); ``allow`` is called for the
    one replica actually chosen and consumes the half-open probe slot.
    """

    __slots__ = ("threshold", "cooldown", "_clock", "_state", "_failures",
                 "_opened_at", "trips")

    def __init__(self, *, failures: int = 3, cooldown: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        if failures < 1:
            raise ValueError(f"failures must be >= 1, got {failures}")
        self.threshold = int(failures)
        self.cooldown = float(cooldown)
        self._clock = clock
        self._state = CLOSED
        self._failures = 0
        self._opened_at = 0.0
        #: total closed->open transitions (stats)
        self.trips = 0

    @property
    def state(self) -> str:
        return self._state

    def _cooled(self) -> bool:
        return self._clock() - self._opened_at >= self.cooldown

    def would_allow(self) -> bool:
        """Pure candidate check: may a request be routed here right now?"""
        if self._state == CLOSED:
            return True
        if self._state == OPEN:
            return self._cooled()
        return False  # half-open: the single probe is already in flight

    def allow(self) -> bool:
        """Consuming check for the chosen replica (takes the probe slot)."""
        if self._state == CLOSED:
            return True
        if self._state == OPEN and self._cooled():
            self._state = HALF_OPEN
            return True
        return False

    def record_success(self) -> None:
        self._state = CLOSED
        self._failures = 0

    def record_failure(self) -> None:
        if self._state == HALF_OPEN:  # the probe failed: back to open
            self._open()
            return
        self._failures += 1
        if self._state == CLOSED and self._failures >= self.threshold:
            self._open()

    def _open(self) -> None:
        if self._state != OPEN:
            self.trips += 1
        self._state = OPEN
        self._failures = 0
        self._opened_at = self._clock()

    def stats(self) -> dict:
        return {"state": self._state, "trips": self.trips,
                "consecutive_failures": self._failures}
