"""Chaos harness: seeded, declarative fault schedules for the cluster.

The robustness claims in ``repro.serve.cluster`` — replica failover,
journal replay, circuit breaking, deadline enforcement — are only worth
anything if they hold under *combinations* of faults arriving at awkward
times.  This module makes those combinations reproducible: a
:class:`ChaosSchedule` is a plain list of :class:`ChaosEvent` (fault
``kind`` on worker ``w`` at relative time ``t``), either written by hand
or generated from a seed (:meth:`ChaosSchedule.random`), and a
:class:`ChaosInjector` replays it against a live router on its own event
loop.  ``tests/test_chaos.py`` drives randomized schedules and asserts
the two invariants the cluster promises:

* every response that IS delivered is bit-identical to an in-process
  evaluation (garbage is never relayed — errors are typed protocol
  errors);
* no acknowledged registration is ever lost, whatever the schedule did.

Fault kinds:

* ``kill`` — SIGKILL the worker process (crash; supervisor restarts it);
* ``hang`` — SIGSTOP for ``duration`` seconds, then SIGCONT (alive but
  unresponsive; the router's *health probe*, not the supervisor, must
  notice — and SIGKILL it onto the restart path if the hang outlives the
  probe timeout);
* ``delay`` — add ``duration`` seconds of latency to every response chunk
  flowing through the worker's :class:`FaultProxy` (slow worker: feeds
  deadlines, hedging, and the circuit breaker), for ``duration`` seconds;
* ``truncate`` — cut the worker's next response off mid-frame and sever
  the connection (torn bytes on the wire: the router's client must treat
  the partial line as a connection loss, never as a response).

``delay`` / ``truncate`` need the wire interposed: create a
:class:`ProxyManager` and pass its :meth:`~ProxyManager.wrap` as the
router's ``wrap_endpoint`` so every worker generation is reached through
a fresh-targeted :class:`FaultProxy`::

    proxies = ProxyManager()
    cluster = ClusterThread(2, router_kw=dict(
        replication=2, wrap_endpoint=proxies.wrap))
    schedule = ChaosSchedule.random(seed=7, workers=cluster.worker_names)
    injector, fut = inject(cluster, schedule, proxies)
    ...drive traffic...
    fut.result()          # schedule fully applied
"""

from __future__ import annotations

import asyncio
import contextlib
import random
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

#: the fault vocabulary, in the order `ChaosSchedule.random` samples it
FAULT_KINDS = ("kill", "hang", "delay", "truncate")

_CHUNK = 1 << 16


class ChaosEvent(NamedTuple):
    """One scheduled fault: ``kind`` hits ``worker`` at ``t`` seconds.

    ``t`` is relative to :meth:`ChaosInjector.run` starting; ``duration``
    only applies to ``hang`` (how long the process stays stopped) and
    ``delay`` (added per-chunk latency AND how long it stays in effect).
    """

    t: float
    kind: str
    worker: str
    duration: float = 0.25


class ChaosSchedule:
    """An ordered, declarative fault schedule (what hits whom, when)."""

    def __init__(self, events: Sequence[ChaosEvent]):
        for ev in events:
            if ev.kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {ev.kind!r} "
                                 f"(expected one of {FAULT_KINDS})")
        self.events: List[ChaosEvent] = sorted(events, key=lambda e: e.t)

    @classmethod
    def random(cls, seed: int, workers: Sequence[str], *,
               n_events: int = 6, horizon: float = 3.0,
               kinds: Sequence[str] = FAULT_KINDS,
               max_duration: float = 0.4) -> "ChaosSchedule":
        """A seeded schedule: same seed + workers → same faults, always.

        >>> s = ChaosSchedule.random(7, ["w0", "w1"], n_events=3)
        >>> s.events == ChaosSchedule.random(7, ["w0", "w1"],
        ...                                  n_events=3).events
        True
        >>> all(e.kind in FAULT_KINDS and e.worker in ("w0", "w1")
        ...     for e in s)
        True
        """
        rng = random.Random(seed)
        events = [ChaosEvent(t=round(rng.uniform(0.05, horizon), 3),
                             kind=rng.choice(list(kinds)),
                             worker=rng.choice(list(workers)),
                             duration=round(rng.uniform(0.05, max_duration),
                                            3))
                  for _ in range(n_events)]
        return cls(events)

    def __iter__(self):
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        return f"ChaosSchedule({self.events!r})"


# -- wire interposition ------------------------------------------------------


class FaultProxy:
    """A TCP interposer between the router and ONE worker's endpoint.

    Relays bytes both ways untouched until told otherwise:

    * ``delay`` (seconds) — sleep before relaying each worker→router
      chunk (a slow worker without touching the worker);
    * ``truncate_next`` — relay only HALF of the next worker→router chunk
      and then sever both sides of the connection: the router's client
      sees a torn frame followed by EOF.  One-shot.

    The flags are plain attributes read in the data path, so tests may
    set them from any thread; the proxy itself lives on the router's
    event loop (created by :meth:`ProxyManager.wrap`).
    """

    def __init__(self, name: str):
        self.name = name
        self.target: Optional[Tuple[str, int]] = None
        self.host: Optional[str] = None
        self.port: Optional[int] = None
        self.delay = 0.0
        self.truncate_next = False
        self.counters = {"connections": 0, "truncated": 0,
                         "delayed_chunks": 0}
        self._server: Optional[asyncio.AbstractServer] = None
        self._tasks: set = set()

    async def start(self) -> "FaultProxy":
        assert self._server is None, "proxy already started"
        self._server = await asyncio.start_server(self._handle,
                                                  "127.0.0.1", 0)
        sock = self._server.sockets[0].getsockname()
        self.host, self.port = sock[0], sock[1]
        return self

    def set_target(self, host: str, port: int) -> None:
        """Point at the current worker generation's real endpoint."""
        self.target = (host, port)

    async def _handle(self, creader: asyncio.StreamReader,
                      cwriter: asyncio.StreamWriter) -> None:
        if self.target is None:
            cwriter.close()
            return
        try:
            ureader, uwriter = await asyncio.open_connection(*self.target)
        except OSError:
            cwriter.close()  # worker (re)starting: refuse like it would
            return
        self.counters["connections"] += 1
        loop = asyncio.get_running_loop()
        up = loop.create_task(self._pump_up(creader, uwriter))
        down = loop.create_task(self._pump_down(ureader, cwriter))
        self._tasks.update((up, down))
        try:
            done, pending = await asyncio.wait(
                (up, down), return_when=asyncio.FIRST_COMPLETED)
            for t in pending:
                t.cancel()
            await asyncio.gather(up, down, return_exceptions=True)
        finally:
            self._tasks.difference_update((up, down))
            for w in (cwriter, uwriter):
                with contextlib.suppress(ConnectionError, OSError,
                                         RuntimeError):
                    w.close()

    async def _pump_up(self, reader: asyncio.StreamReader,
                       writer: asyncio.StreamWriter) -> None:
        """router → worker: always relayed untouched."""
        with contextlib.suppress(ConnectionError, OSError):
            while True:
                chunk = await reader.read(_CHUNK)
                if not chunk:
                    return
                writer.write(chunk)
                await writer.drain()

    async def _pump_down(self, reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter) -> None:
        """worker → router: where delay and truncation strike."""
        with contextlib.suppress(ConnectionError, OSError):
            while True:
                chunk = await reader.read(_CHUNK)
                if not chunk:
                    return
                if self.delay > 0:
                    self.counters["delayed_chunks"] += 1
                    await asyncio.sleep(self.delay)
                if self.truncate_next:
                    self.truncate_next = False
                    self.counters["truncated"] += 1
                    writer.write(chunk[:max(1, len(chunk) // 2)])
                    with contextlib.suppress(ConnectionError, OSError):
                        await writer.drain()
                    return  # sever the connection mid-frame
                writer.write(chunk)
                await writer.drain()

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        for t in list(self._tasks):
            t.cancel()
        if self._tasks:
            await asyncio.gather(*self._tasks, return_exceptions=True)
        self._tasks.clear()


class ProxyManager:
    """One :class:`FaultProxy` per worker name, wired in as the router's
    ``wrap_endpoint`` hook.

    The proxy for a name persists across worker generations — each
    restart re-targets it — so its listen port is stable and fault flags
    survive the restart they usually caused.
    """

    def __init__(self):
        self.proxies: Dict[str, FaultProxy] = {}

    async def wrap(self, name: str, host: str, port: int,
                   ) -> Tuple[str, int]:
        proxy = self.proxies.get(name)
        if proxy is None:
            proxy = await FaultProxy(name).start()
            self.proxies[name] = proxy
        proxy.set_target(host, port)
        return proxy.host, proxy.port

    def __getitem__(self, name: str) -> FaultProxy:
        return self.proxies[name]

    def __contains__(self, name: str) -> bool:
        return name in self.proxies

    async def aclose(self) -> None:
        for proxy in self.proxies.values():
            await proxy.aclose()
        self.proxies.clear()


# -- applying a schedule -----------------------------------------------------


class ChaosInjector:
    """Replays a :class:`ChaosSchedule` against a live router.

    Runs on the router's event loop (see :func:`inject` for driving it
    from a synchronous test through :class:`ClusterThread`).  ``applied``
    records what actually fired; ``skipped`` what could not (unknown
    worker, or a wire fault with no proxy for it).
    """

    def __init__(self, router, proxies: Optional[ProxyManager] = None):
        self.router = router
        self.proxies = proxies
        self.applied: List[ChaosEvent] = []
        self.skipped: List[ChaosEvent] = []
        self._cleanups: List[asyncio.Task] = []

    async def run(self, schedule: ChaosSchedule) -> List[ChaosEvent]:
        """Apply every event at its scheduled offset; returns ``applied``.

        Resolves only after trailing effects (hang resumes, delay
        windows) have been undone, so a completed run leaves no fault
        standing.
        """
        loop = asyncio.get_running_loop()
        t0 = loop.time()
        for ev in schedule:
            await asyncio.sleep(max(0.0, t0 + ev.t - loop.time()))
            self._apply(ev)
        if self._cleanups:
            await asyncio.gather(*self._cleanups, return_exceptions=True)
        return self.applied

    def _apply(self, ev: ChaosEvent) -> None:
        loop = asyncio.get_running_loop()
        slot = self.router._slots.get(ev.worker)
        if ev.kind in ("kill", "hang") and slot is None:
            self.skipped.append(ev)
            return
        if ev.kind in ("delay", "truncate") and (
                self.proxies is None or ev.worker not in self.proxies):
            self.skipped.append(ev)
            return
        if ev.kind == "kill":
            slot.proc.kill()
        elif ev.kind == "hang":
            slot.proc.pause()
            self._cleanups.append(loop.create_task(
                self._resume_later(slot, ev.duration)))
        elif ev.kind == "delay":
            proxy = self.proxies[ev.worker]
            proxy.delay = max(proxy.delay, ev.duration)
            self._cleanups.append(loop.create_task(
                self._clear_delay_later(proxy, ev.duration)))
        else:  # truncate
            self.proxies[ev.worker].truncate_next = True
        self.applied.append(ev)

    @staticmethod
    async def _resume_later(slot, duration: float) -> None:
        await asyncio.sleep(duration)
        # if the health probe already SIGKILLed the hung generation this
        # is a no-op on a dead pid — both outcomes are valid recoveries
        slot.proc.resume()

    @staticmethod
    async def _clear_delay_later(proxy: FaultProxy,
                                 duration: float) -> None:
        await asyncio.sleep(duration)
        proxy.delay = 0.0


def inject(cluster, schedule: ChaosSchedule,
           proxies: Optional[ProxyManager] = None):
    """Start a schedule against a :class:`ClusterThread` from sync code.

    Returns ``(injector, future)``: the concurrent future resolves (with
    ``injector.applied``) once every event has fired and its trailing
    effects are undone.
    """
    injector = ChaosInjector(cluster.router, proxies)
    fut = asyncio.run_coroutine_threadsafe(injector.run(schedule),
                                           cluster._loop)
    return injector, fut
