"""The registration journal: what the router promised to remember.

Every acknowledged ``register_qrel`` / ``register_run`` lives here twice:

* **in memory** (:attr:`RegistrationJournal.entries`) — the source for
  replaying registrations onto restarted workers and onto new owners at
  rebalance (the router's restart-transparency contract from PR 8);
* **on disk** (``--state-dir``) — an append-only JSONL log, one wire-style
  frame per record (the same framing contract as the protocol itself:
  :func:`repro.serve.wire.split_frames` reads it back, enforcing the same
  frame limit and dropping a torn trailing line from a crash mid-append).
  A router restarted against the same ``--state-dir`` recovers every
  acknowledged collection before accepting traffic, so a *whole-cluster*
  restart loses nothing.

Record kinds (one JSON object per line)::

    {"kind": "qrel", "qrel_id": ..., "payload": {...}}   # register_qrel
    {"kind": "run",  "qrel_id": ..., "run_id": ..., "payload": {...}}
    {"kind": "drop", "qrel_id": ...}                      # drop_qrel

``drop`` records and superseded registrations make the log grow without
bound if left alone; once ``compact_min_dead`` dead records accumulate the
log is rewritten as a snapshot of the live entries (atomic
write-new-then-rename, fsync'd), dropping everything superseded or
dropped.  Appends fsync by default: an acknowledged registration must
survive the router dying the very next instant.

``state_dir=None`` degrades to the in-memory journal alone (PR 8
behavior): same API, no files.

>>> import tempfile
>>> d = tempfile.mkdtemp()
>>> j = RegistrationJournal(d)
>>> j.record_qrel("web", {"qrel_id": "web", "qrel": {"q1": {"d1": 1}}})
>>> j.record_run("web", "bm25", {"qrel_id": "web", "run_id": "bm25"})
>>> j2 = RegistrationJournal(d)                   # a restarted router
>>> sorted(j2.entries) == ["web"] and list(j2.entries["web"]["runs"])
['bm25']
>>> j2.record_drop("web")                         # dropped = pruned
True
>>> RegistrationJournal(d).entries                # ...durably
{}
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.serve.wire import (DEFAULT_FRAME_LIMIT, OversizedFrame,
                              split_frames)

#: journal file name inside ``--state-dir``
JOURNAL_FILE = "registrations.jsonl"


class RegistrationJournal:
    """In-memory registration map with an optional durable JSONL log.

    ``entries`` maps ``qrel_id -> {"qrel": <register_qrel payload>,
    "runs": {run_id: <register_run payload>}}`` — exactly the shape the
    router replays onto workers.  All mutations go through
    :meth:`record_qrel` / :meth:`record_run` / :meth:`record_drop` so the
    disk log can never disagree with memory.
    """

    def __init__(self, state_dir: Optional[str] = None, *,
                 frame_limit: int = DEFAULT_FRAME_LIMIT,
                 compact_min_dead: int = 32, fsync: bool = True):
        self._frame_limit = int(frame_limit)
        self._compact_min_dead = int(compact_min_dead)
        self._fsync = bool(fsync)
        self._path: Optional[str] = None
        self._dead = 0          # drop/superseded records since last compact
        self._skipped = 0       # unreadable records dropped at load
        self.counters = {"appended": 0, "compactions": 0,
                         "recovered_collections": 0}
        self.entries: Dict[str, dict] = {}
        if state_dir is not None:
            os.makedirs(state_dir, exist_ok=True)
            self._path = os.path.join(state_dir, JOURNAL_FILE)
            self._load()

    # -- recovery ------------------------------------------------------------

    def _load(self) -> None:
        try:
            with open(self._path, "rb") as fh:
                data = fh.read()
        except FileNotFoundError:
            return
        for frame in split_frames(data, self._frame_limit):
            if isinstance(frame, OversizedFrame):
                self._skipped += 1
                continue
            try:
                rec = json.loads(frame)
                kind, qrel_id = rec["kind"], rec["qrel_id"]
            except (ValueError, KeyError, TypeError):
                self._skipped += 1  # a corrupt line: skip, keep replaying
                continue
            if kind == "qrel":
                if qrel_id in self.entries:
                    self._dead += 1 + len(self.entries[qrel_id]["runs"])
                self.entries[qrel_id] = {"qrel": rec["payload"], "runs": {}}
            elif kind == "run" and qrel_id in self.entries:
                runs = self.entries[qrel_id]["runs"]
                if rec["run_id"] in runs:
                    self._dead += 1
                runs[str(rec["run_id"])] = rec["payload"]
            elif kind == "drop":
                entry = self.entries.pop(qrel_id, None)
                self._dead += 2 + (len(entry["runs"]) if entry else 0)
            else:
                self._skipped += 1
        self.counters["recovered_collections"] = len(self.entries)
        if self._dead >= self._compact_min_dead:
            self._compact()

    # -- mutation ------------------------------------------------------------

    def record_qrel(self, qrel_id: str, payload: dict) -> None:
        old = self.entries.get(qrel_id)
        if old is not None:  # superseded registration (and its runs)
            self._dead += 1 + len(old["runs"])
        self.entries[qrel_id] = {"qrel": payload, "runs": {}}
        self._append({"kind": "qrel", "qrel_id": qrel_id,
                      "payload": payload})

    def record_run(self, qrel_id: str, run_id: str, payload: dict) -> None:
        entry = self.entries.get(qrel_id)
        if entry is None:
            return  # register_run raced a drop: nothing durable to extend
        if run_id in entry["runs"]:
            self._dead += 1
        entry["runs"][str(run_id)] = payload
        self._append({"kind": "run", "qrel_id": qrel_id,
                      "run_id": str(run_id), "payload": payload})

    def record_drop(self, qrel_id: str) -> bool:
        """Prune a collection everywhere; True if it was journaled.

        This is the fix for the compaction bug-in-waiting: dropped
        collections must leave BOTH the in-memory journal (or replay onto
        a restarted worker resurrects them) and the durable log (or a
        whole-cluster restart does), and the drop record itself is what
        compaction later folds away.
        """
        entry = self.entries.pop(qrel_id, None)
        if entry is None:
            return False
        self._dead += 2 + len(entry["runs"])  # their records + this one
        self._append({"kind": "drop", "qrel_id": qrel_id})
        return True

    # -- the durable log -----------------------------------------------------

    def _append(self, record: dict) -> None:
        if self._path is None:
            if self._dead >= self._compact_min_dead:
                self._dead = 0  # memory-only: nothing on disk to rewrite
            return
        frame = json.dumps(record).encode() + b"\n"
        with open(self._path, "ab") as fh:
            fh.write(frame)
            fh.flush()
            if self._fsync:
                os.fsync(fh.fileno())
        self.counters["appended"] += 1
        if self._dead >= self._compact_min_dead:
            self._compact()

    def _compact(self) -> None:
        """Rewrite the log as a snapshot of the live entries, atomically."""
        if self._path is None:
            return
        tmp = self._path + ".compact"
        with open(tmp, "wb") as fh:
            for qrel_id, entry in self.entries.items():
                fh.write(json.dumps({"kind": "qrel", "qrel_id": qrel_id,
                                     "payload": entry["qrel"]}).encode()
                         + b"\n")
                for run_id, payload in entry["runs"].items():
                    fh.write(json.dumps(
                        {"kind": "run", "qrel_id": qrel_id,
                         "run_id": run_id, "payload": payload}).encode()
                        + b"\n")
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self._path)
        self._dead = 0
        self.counters["compactions"] += 1

    # -- mapping facade (what the router iterates) ---------------------------

    def get(self, qrel_id: str) -> Optional[dict]:
        return self.entries.get(qrel_id)

    def __contains__(self, qrel_id: str) -> bool:
        return qrel_id in self.entries

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self):
        return iter(self.entries)

    def stats(self) -> dict:
        out = {**self.counters, "collections": len(self.entries),
               "dead_records": self._dead, "skipped_records": self._skipped,
               "durable": self._path is not None}
        if self._path is not None:
            out["path"] = self._path
            try:
                out["bytes"] = os.path.getsize(self._path)
            except OSError:
                out["bytes"] = 0
        return out
