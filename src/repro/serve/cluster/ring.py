"""Consistent-hash ring: which worker owns which collection id.

The cluster shards *collections* (qrel ids), not requests: every request
naming a ``qrel_id`` goes to the one worker whose LRU interned that qrel,
so a hot collection's evaluator lives exactly once per worker process and
the worker's micro-batcher still coalesces everything aimed at it.

Plain modulo hashing would reshuffle almost every collection when the pool
grows or shrinks; the classic consistent-hash construction keeps the
disruption to ~1/N of the keyspace.  Each node is hashed onto the ring at
``replicas`` pseudo-random points (virtual nodes — 64 by default, enough
to keep the per-node share within a few percent of uniform for small
pools) and a key belongs to the first node point at or after its own hash,
wrapping at the top.

Hashing is SHA-1 (stable across processes and Python versions — never
``hash()``, which is salted per process), truncated to 64 bits.

>>> ring = HashRing(["w0", "w1", "w2"])
>>> ring.owner("robust04") == ring.owner("robust04")   # deterministic
True
>>> before = {k: ring.owner(k) for k in map(str, range(200))}
>>> ring.add("w3")                                     # grow the pool
>>> moved = [k for k, o in before.items() if ring.owner(k) != o]
>>> 0 < len(moved) < 110                 # ~1/4 of keys move, not all
True
>>> all(ring.owner(k) == "w3" for k in moved)  # ...and only TO the newcomer
True
>>> ring.remove("w3")                    # shrink: movers return home
>>> all(ring.owner(k) == before[k] for k in before)
True

Replication reads the same ring: a key's **replica set** is the first R
*distinct* nodes met walking clockwise from its hash
(:meth:`HashRing.owners`), so ``owners(k, 1)[0] == owner(k)`` always, the
sets are deterministic across processes, and a membership change disturbs
each replica set by at most the one node that joined or left it.

>>> sets = {k: ring.owners(k, 2) for k in map(str, range(100))}
>>> all(len(set(s)) == 2 for s in sets.values())       # R distinct workers
True
>>> ring.add("w3")
>>> changed = [k for k, s in sets.items() if ring.owners(k, 2) != s]
>>> all(set(ring.owners(k, 2)) - set(sets[k]) <= {"w3"} for k in changed)
True
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Iterable, List, Tuple


def _hash(key: str) -> int:
    """Stable 64-bit point on the ring for ``key``."""
    return int.from_bytes(hashlib.sha1(key.encode("utf-8")).digest()[:8],
                          "big")


class HashRing:
    """Consistent-hash ring over named nodes with virtual replicas."""

    def __init__(self, nodes: Iterable[str] = (), *, replicas: int = 64):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.replicas = int(replicas)
        self._nodes: set = set()
        self._ring: List[Tuple[int, str]] = []   # sorted (point, node)
        self._points: List[int] = []             # parallel sorted points
        for node in nodes:
            self.add(node)

    @property
    def nodes(self) -> Tuple[str, ...]:
        return tuple(sorted(self._nodes))

    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, node: str) -> bool:
        return node in self._nodes

    def add(self, node: str) -> None:
        """Place ``node`` on the ring at ``replicas`` virtual points."""
        if node in self._nodes:
            raise ValueError(f"node {node!r} is already on the ring")
        self._nodes.add(node)
        self._ring.extend((_hash(f"{node}#{i}"), node)
                          for i in range(self.replicas))
        self._ring.sort()
        self._points = [p for p, _ in self._ring]

    def remove(self, node: str) -> None:
        if node not in self._nodes:
            raise KeyError(f"node {node!r} is not on the ring")
        self._nodes.discard(node)
        self._ring = [(p, n) for p, n in self._ring if n != node]
        self._points = [p for p, _ in self._ring]

    def owner(self, key: str) -> str:
        """The node owning ``key``: first node point at/after its hash."""
        if not self._ring:
            raise KeyError("ring is empty: no workers")
        i = bisect.bisect_left(self._points, _hash(key))
        if i == len(self._points):
            i = 0  # wrap past the top of the ring
        return self._ring[i][1]

    def owners(self, key: str, n: int = 1) -> List[str]:
        """The replica set for ``key``: the first ``n`` DISTINCT nodes met
        walking clockwise from its hash (capped at the pool size).

        ``owners(key, 1) == [owner(key)]`` by construction, and appending a
        node to the walk order is how replication degrades gracefully: with
        fewer nodes than ``n`` every node is a replica.
        """
        if n < 1:
            raise ValueError(f"need n >= 1 replicas, got {n}")
        if not self._ring:
            raise KeyError("ring is empty: no workers")
        n = min(n, len(self._nodes))
        start = bisect.bisect_left(self._points, _hash(key))
        out: List[str] = []
        for step in range(len(self._ring)):
            node = self._ring[(start + step) % len(self._ring)][1]
            if node not in out:
                out.append(node)
                if len(out) == n:
                    break
        return out

    def copy(self) -> "HashRing":
        """An independent ring with the same membership (for what-if
        ownership computations during rebalancing)."""
        clone = HashRing(replicas=self.replicas)
        clone._nodes = set(self._nodes)
        clone._ring = list(self._ring)
        clone._points = list(self._points)
        return clone
