"""The cluster router: replicated consistent-hash fan-out over workers.

Topology (one router process, N worker processes)::

    clients ──TCP──▶ Router ──┬──▶ worker w0  (repro.serve, own port)
      JSON-lines    (ring)    ├──▶ worker w1
                              └──▶ worker w…

Every collection (``qrel_id``) is owned by a **replica set** of
``replication`` distinct workers — the first R nodes met walking the
:class:`~repro.serve.cluster.ring.HashRing` clockwise from the key's
hash.  ``register_qrel`` / ``register_run`` fan out to every *ready*
replica before acking (replicas that are down catch up from the journal
when they restart); read ops (``evaluate`` / ``compare``) are balanced
across live replicas with **power-of-two-choices** on in-flight counts,
filtered through a per-worker circuit breaker
(:class:`~repro.serve.cluster.breaker.CircuitBreaker`).  ``evaluate`` /
``compare`` ride the raw fan-out path (:meth:`AsyncEvalClient.forward`):
the router parses each request line once for routing, then relays the
original bytes with a spliced internal id and relays the response bytes
back with the client's id restored — no second serialization of
multi-megabyte payloads.

Durability: with ``state_dir`` set, every acknowledged registration is
appended to an on-disk JSONL journal
(:class:`~repro.serve.cluster.journal.RegistrationJournal`) *before* the
client sees the ack, so a whole-cluster restart against the same
``--state-dir`` recovers every acknowledged collection.  ``drop_qrel``
prunes the journal — in memory AND on disk — the moment any replica
acknowledges it, so neither a restarted sibling's replay nor a cluster
restart can resurrect a dropped collection.

Deadlines: a request may carry ``deadline_ms``; the router enforces it
end-to-end (a late answer becomes a ``deadline_exceeded`` error response)
and, for idempotent ops with a live sibling, fires a **hedged** second
request once ``hedge_fraction`` of the budget has elapsed without an
answer — first response wins.

Fault model:

* a worker crash fails that worker's in-flight futures immediately; the
  router **fails over to a sibling replica at once** (no waiting for the
  restart) while the supervisor restarts the process with exponential
  backoff and replays the journal before marking it ready again;
* **idempotent** ops (``evaluate``, ``compare``, ``register_*``, reads)
  retry transparently; a replica answering ``not_found`` for a journaled
  collection (it missed a registration while restarting) is *healed* —
  re-registered from the journal — and the request retried;
* **non-idempotent** ``drop_qrel`` fans out to every ready replica: it
  succeeds if ANY replica acknowledges (so with R >= 2 a single dead
  replica no longer forces ``worker_unavailable``), and only when every
  replica is down/unreachable does the caller get the machine-readable
  ``worker_unavailable`` error;
* repeated transport failures trip a worker's circuit breaker (closed →
  open → half-open probe), which removes it from the balancing candidate
  set until a probe succeeds;
* a periodic ``health`` probe per worker catches hung-but-alive processes
  and kills them onto the same restart path.

Membership changes (:meth:`Router.add_worker` / :meth:`Router.remove_worker`)
rebalance replica sets with journal replay: collections gaining a replica
are registered on it *before* the ring swaps (requests never see a gap)
and replicas leaving a set are best-effort dropped after.

:meth:`Router.drain` cascades: wait for router-level in-flight requests,
then stop every worker via SIGTERM → the worker's own
``EvaluationService.drain`` machinery.
"""

from __future__ import annotations

import asyncio
import contextlib
import json
import random
import re
import sys
import time
from typing import Dict, List, Optional, Sequence, Set

from repro.client.errors import ServerError
from repro.serve.cluster.breaker import CircuitBreaker
from repro.serve.cluster.journal import RegistrationJournal
from repro.serve.cluster.ring import HashRing
from repro.serve.cluster.worker import WorkerProcess
from repro.serve.frontend import _check_request, _error
from repro.serve.wire import DEFAULT_FRAME_LIMIT, ProtocolError

#: responses from our own front-ends lead with their id (dict insertion
#: order survives json.dumps), so the id can be rewritten by prefix splice
_RESPONSE_ID = re.compile(rb'^\{"id":\s*(?:-?\d+|null)\s*,')

#: ops fanned out as raw bytes (hot path) and retried across restarts
_RAW_OPS = frozenset({"evaluate", "compare"})

#: ops handled with a parsed round trip, journaled, and retried
_CONTROL_OPS = frozenset({"register_qrel", "register_run"})

#: marker for a forwarded worker response that reports a missing
#: collection — for journaled collections this means the replica missed a
#: registration (e.g. it restarted before the journal had it) and should
#: be healed rather than believed
_NOT_FOUND_MARK = b'"code": "not_found"'


def _rewrite_id(resp: bytes, rid) -> bytes:
    """Restore the client's request id on a forwarded response frame."""
    rid_b = json.dumps(rid).encode()
    m = _RESPONSE_ID.match(resp)
    if m is not None:
        return b'{"id": ' + rid_b + b"," + resp[m.end():]
    try:  # rare: a response shape we don't recognise — parse and patch
        msg = json.loads(resp)
        msg["id"] = rid
        return json.dumps(msg).encode()
    except ValueError:  # pragma: no cover - garbage from a worker
        return resp


class _Slot:
    """One worker position on the ring (stable name, restartable process)."""

    __slots__ = ("name", "proc", "ready", "restarts", "supervisor",
                 "health_task", "breaker", "inflight")

    def __init__(self, name: str, proc: WorkerProcess,
                 breaker: CircuitBreaker):
        self.name = name
        self.proc = proc
        self.ready = asyncio.Event()
        self.restarts = 0
        self.supervisor: Optional[asyncio.Task] = None
        self.health_task: Optional[asyncio.Task] = None
        self.breaker = breaker
        self.inflight = 0  # requests this slot is currently answering


class Router:
    """Replicated consistent-hash router over supervised serve workers.

    ``worker_args`` is appended to every worker's command line (measure
    flags, ``--window-ms``, ``--backend``, ...).  ``replication`` sizes
    each collection's replica set (capped at the pool size); ``retries``
    bounds transparent re-sends of idempotent requests across worker
    failures; ``ready_timeout`` bounds how long a request waits for ANY
    replica to come (back) up before giving up with
    ``worker_unavailable``.  ``state_dir`` makes the registration journal
    durable; ``breaker_failures`` / ``breaker_cooldown`` parameterize each
    worker's circuit breaker; ``hedge_fraction`` is the share of a
    ``deadline_ms`` budget that elapses before an idempotent request is
    hedged to a sibling replica.  ``rng_seed`` pins the power-of-two-
    choices sampling (tests); ``wrap_endpoint`` is an async hook
    ``(name, host, port) -> (host, port)`` interposed between the router
    and each worker generation (the chaos harness's proxy injection
    point).
    """

    def __init__(self, n_workers: int = 2, *,
                 worker_args: Sequence[str] = (), replicas: int = 64,
                 replication: int = 1, retries: int = 3,
                 ready_timeout: float = 15.0, start_timeout: float = 60.0,
                 health_interval: float = 1.0, health_timeout: float = 5.0,
                 backoff: float = 0.25, max_backoff: float = 4.0,
                 frame_limit: int = DEFAULT_FRAME_LIMIT,
                 state_dir: Optional[str] = None,
                 breaker_failures: int = 3, breaker_cooldown: float = 1.0,
                 hedge_fraction: float = 0.5,
                 rng_seed: Optional[int] = None, wrap_endpoint=None):
        if n_workers < 1:
            raise ValueError(f"need at least one worker, got {n_workers}")
        if not 0.0 < hedge_fraction <= 1.0:
            raise ValueError(
                f"hedge_fraction must be in (0, 1], got {hedge_fraction}")
        self._n_initial = int(n_workers)
        self._worker_args = [str(a) for a in worker_args]
        self._replication = max(1, int(replication))
        self._retries = int(retries)
        self._ready_timeout = float(ready_timeout)
        self._start_timeout = float(start_timeout)
        self._health_interval = float(health_interval)
        self._health_timeout = float(health_timeout)
        self._backoff = float(backoff)
        self._max_backoff = float(max_backoff)
        self._frame_limit = int(frame_limit)
        self._breaker_failures = int(breaker_failures)
        self._breaker_cooldown = float(breaker_cooldown)
        self._hedge_fraction = float(hedge_fraction)
        self._rng = random.Random(rng_seed)
        self._wrap_endpoint = wrap_endpoint
        self._ring = HashRing(replicas=replicas)
        self._slots: Dict[str, _Slot] = {}
        self._next_slot = 0
        #: the registration journal: replayed onto restarted workers and
        #: onto new replica-set members at rebalance; durable on disk when
        #: ``state_dir`` is set (recovered in this constructor).
        self._journal = RegistrationJournal(state_dir,
                                            frame_limit=frame_limit)
        self._inflight = 0
        self._closing = False
        self.counters = {
            "requests": 0, "forwarded": 0, "worker_retries": 0,
            "worker_unavailable": 0, "restarts": 0, "health_failures": 0,
            "replayed_collections": 0, "rebalanced_collections": 0,
            "failovers": 0, "hedges": 0, "hedge_wins": 0,
            "deadline_exceeded": 0, "healed_replicas": 0,
        }

    # -- pool lifecycle ------------------------------------------------------

    def _new_slot(self, name: Optional[str] = None) -> _Slot:
        if name is None:
            name = f"w{self._next_slot}"
        self._next_slot += 1
        if name in self._slots:
            raise ValueError(f"worker {name!r} already exists")
        slot = _Slot(
            name,
            WorkerProcess(name, extra_args=self._worker_args,
                          frame_limit=self._frame_limit,
                          wrap_endpoint=self._wrap_endpoint),
            CircuitBreaker(failures=self._breaker_failures,
                           cooldown=self._breaker_cooldown))
        self._slots[name] = slot
        loop = asyncio.get_running_loop()
        slot.supervisor = loop.create_task(self._supervise(slot))
        slot.health_task = loop.create_task(self._health_loop(slot))
        return slot

    async def start(self) -> None:
        """Spawn the initial pool and wait until every worker is ready.

        With a durable ``state_dir``, the journal was already recovered in
        the constructor — each worker's first :meth:`_replay` (before it
        is marked ready) re-registers every acknowledged collection, so
        the cluster accepts traffic only once recovery is complete.
        """
        slots = [self._new_slot() for _ in range(self._n_initial)]
        for slot in slots:
            self._ring.add(slot.name)
        try:
            await asyncio.wait_for(
                asyncio.gather(*(s.ready.wait() for s in slots)),
                self._start_timeout)
        except asyncio.TimeoutError:
            stderr = {s.name: list(s.proc.last_stderr)[-3:]
                      for s in slots if not s.ready.is_set()}
            await self.drain()
            raise RuntimeError(
                f"cluster failed to start within {self._start_timeout}s; "
                f"unready workers: {stderr}") from None

    async def _supervise(self, slot: _Slot) -> None:
        """Keep one slot populated: start → ready → wait for death → redo."""
        backoff = self._backoff
        while not self._closing:
            try:
                await slot.proc.start(ready_timeout=self._ready_timeout)
                await self._replay(slot)
            except asyncio.CancelledError:
                raise
            except Exception as exc:  # startup/replay failed: back off
                if self._closing:
                    return
                # a failed REPLAY leaves a live half-started generation
                # behind — put it down, or the next start() refuses to
                # spawn over it and this loop wedges forever
                slot.proc.kill()
                with contextlib.suppress(Exception):
                    await slot.proc.wait()
                if slot.proc.client is not None:
                    with contextlib.suppress(Exception):
                        await slot.proc.client.aclose()
                print(f"[cluster] worker {slot.name} start failed: {exc}; "
                      f"retrying in {backoff:.2f}s", file=sys.stderr,
                      flush=True)
                await asyncio.sleep(backoff)
                backoff = min(backoff * 2, self._max_backoff)
                continue
            backoff = self._backoff
            slot.breaker.record_success()  # fresh generation: close it
            slot.ready.set()
            await slot.proc.wait()  # blocks for this generation's lifetime
            slot.ready.clear()
            if slot.proc.client is not None:
                # fail the dead generation's pending futures NOW so raw
                # forwards waiting on them retry instead of hanging
                with contextlib.suppress(Exception):
                    await slot.proc.client.aclose()
            if self._closing:
                return
            slot.restarts += 1
            self.counters["restarts"] += 1
            print(f"[cluster] worker {slot.name} exited "
                  f"(rc={slot.proc.proc.returncode}); restarting in "
                  f"{backoff:.2f}s", file=sys.stderr, flush=True)
            await asyncio.sleep(backoff)

    async def _health_loop(self, slot: _Slot) -> None:
        """Probe a ready worker with the cheap ``health`` op on a timer.

        ``proc.wait`` in the supervisor catches crashes instantly; this
        loop catches the *hung-but-alive* worker (e.g. SIGSTOP), which
        gets SIGKILLed onto the same restart-and-replay path.
        """
        while not self._closing:
            await asyncio.sleep(self._health_interval)
            if self._closing or not slot.ready.is_set():
                continue
            client = slot.proc.client
            try:
                await asyncio.wait_for(client.health(),
                                       self._health_timeout)
            except asyncio.CancelledError:
                raise
            except Exception:
                if self._closing or not slot.ready.is_set():
                    continue
                self.counters["health_failures"] += 1
                slot.breaker.record_failure()
                print(f"[cluster] worker {slot.name} failed its health "
                      "check; killing for restart", file=sys.stderr,
                      flush=True)
                slot.ready.clear()
                slot.proc.kill()

    async def _replay(self, slot: _Slot, ring: Optional[HashRing] = None,
                      only: Optional[Sequence[str]] = None) -> int:
        """Re-register journaled collections replicated on ``slot``.

        ``ring`` defaults to the live ring; rebalancing passes the *next*
        ring so moved collections land on their future replicas before the
        swap.  ``only`` restricts to the listed qrel ids.
        """
        ring = ring if ring is not None else self._ring
        client = slot.proc.client
        n = 0
        for qrel_id in (list(self._journal) if only is None else only):
            entry = self._journal.get(qrel_id)
            if entry is None or slot.name not in ring.owners(
                    qrel_id, self._replication):
                continue
            await client._request("register_qrel", **entry["qrel"])
            for run_payload in entry["runs"].values():
                await client._request("register_run", **run_payload)
            n += 1
        if n:
            self.counters["replayed_collections"] += n
        return n

    # -- membership changes --------------------------------------------------

    async def add_worker(self, name: Optional[str] = None) -> str:
        """Grow the pool by one worker; rebalance moved replica sets.

        The new worker is started and loaded with every collection whose
        grown replica set includes it *before* the ring is swapped, so
        routing never sees a replica without its data; replicas leaving a
        set drop their copies afterwards (best effort — a failed drop only
        wastes cache).
        """
        slot = self._new_slot(name)
        try:
            await asyncio.wait_for(slot.ready.wait(), self._start_timeout)
        except asyncio.TimeoutError:
            await self._retire_slot(slot)
            self._slots.pop(slot.name, None)
            raise RuntimeError(
                f"new worker {slot.name} failed to become ready; "
                f"stderr: {list(slot.proc.last_stderr)[-3:]}") from None
        new_ring = self._ring.copy()
        new_ring.add(slot.name)
        R = self._replication
        old_sets = {q: self._ring.owners(q, R) for q in self._journal}
        moved = [q for q in self._journal
                 if slot.name in new_ring.owners(q, R)]
        await self._replay(slot, ring=new_ring, only=moved)
        self._ring = new_ring
        self.counters["rebalanced_collections"] += len(moved)
        for q in moved:
            new_set = set(new_ring.owners(q, R))
            for old_name in old_sets[q]:
                if old_name in new_set:
                    continue
                old = self._slots.get(old_name)
                if old is not None and old.ready.is_set():
                    with contextlib.suppress(Exception):
                        await old.proc.client._request("drop_qrel",
                                                       qrel_id=q)
        return slot.name

    async def remove_worker(self, name: str) -> None:
        """Shrink the pool; its replica memberships move to their heirs."""
        if name not in self._slots:
            raise KeyError(f"no worker named {name!r}")
        if len(self._slots) == 1:
            raise ValueError("cannot remove the last worker")
        slot = self._slots[name]
        new_ring = self._ring.copy()
        new_ring.remove(name)
        R = self._replication
        moved = []
        for q in self._journal:
            old_set = self._ring.owners(q, R)
            if name not in old_set:
                continue
            moved.append(q)
            for heir_name in new_ring.owners(q, R):
                if heir_name in old_set:
                    continue  # already a replica
                heir = self._slots[heir_name]
                if not await self._wait_ready(heir):
                    raise RuntimeError(
                        f"cannot rebalance {q!r}: worker {heir.name} is "
                        "down")
                await self._replay(heir, ring=new_ring, only=[q])
        self._ring = new_ring
        self.counters["rebalanced_collections"] += len(moved)
        del self._slots[name]
        await self._retire_slot(slot)

    async def _retire_slot(self, slot: _Slot) -> None:
        for task in (slot.health_task, slot.supervisor):
            if task is not None:
                task.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await task
        await slot.proc.stop()

    # -- request handling ----------------------------------------------------

    async def handle(self, req: dict, raw: bytes):
        """The :func:`repro.serve.frontend.serve_protocol` handler.

        Returns a response dict, or raw response bytes for the fan-out
        path.  Never raises.
        """
        self.counters["requests"] += 1
        self._inflight += 1
        try:
            return await self._handle(req, raw)
        except Exception as exc:  # noqa: BLE001 — router bug: tell the client
            return _error(req.get("id"),
                          f"router error: {type(exc).__name__}: {exc}",
                          "internal")
        finally:
            self._inflight -= 1

    async def _handle(self, req: dict, raw: bytes):
        rid = req.get("id")
        try:
            op = _check_request(req)
        except ProtocolError as exc:
            return _error(rid, str(exc), exc.code)
        if op == "ping":
            return {"id": rid, "ok": True, "result": "pong"}
        if op == "health":
            return {"id": rid, "ok": True, "result": self.health()}
        if op == "auth":
            # serve_protocol intercepts auth when the router has a token;
            # with no token configured, accept any (same as the worker
            # front-end) so token-configured clients work unchanged
            return {"id": rid, "ok": True,
                    "result": {"authenticated": True}}
        if op == "stats":
            return {"id": rid, "ok": True, "result": await self.stats()}
        deadline, err = self._parse_deadline(req)
        if err is not None:
            return err
        qrel_id = str(req["qrel_id"])
        if op == "drop_qrel":
            return await self._drop(qrel_id, req, deadline)
        if op in _CONTROL_OPS:
            return await self._control(op, qrel_id, req, deadline)
        assert op in _RAW_OPS, op
        return await self._forward(qrel_id, raw, rid, deadline)

    # -- deadlines -----------------------------------------------------------

    def _parse_deadline(self, req: dict):
        """``deadline_ms`` → absolute loop deadline (or an error response)."""
        ms = req.get("deadline_ms")
        if ms is None:
            return None, None
        if isinstance(ms, bool) or not isinstance(ms, (int, float)) \
                or ms <= 0:
            return None, _error(
                req.get("id"), "field 'deadline_ms' must be a positive "
                f"number of milliseconds, got {ms!r}", "invalid")
        loop = asyncio.get_running_loop()
        return loop.time() + float(ms) / 1e3, None

    def _deadline_error(self, rid, op: str):
        self.counters["deadline_exceeded"] += 1
        return _error(
            rid, f"op {op!r} missed its 'deadline_ms' budget at the "
            "router; the work may still complete on a worker",
            "deadline_exceeded")

    @staticmethod
    def _expired(deadline: Optional[float]) -> bool:
        return (deadline is not None
                and asyncio.get_running_loop().time() >= deadline)

    async def _bounded(self, coro, deadline: Optional[float]):
        """Await ``coro`` within the deadline budget (TimeoutError past it)."""
        if deadline is None:
            return await coro
        remaining = deadline - asyncio.get_running_loop().time()
        if remaining <= 0:
            coro.close()
            raise asyncio.TimeoutError()
        return await asyncio.wait_for(coro, remaining)

    # -- replica selection ---------------------------------------------------

    def _replica_names(self, qrel_id: str,
                       ring: Optional[HashRing] = None) -> List[str]:
        ring = ring if ring is not None else self._ring
        return ring.owners(qrel_id, self._replication)

    def _replica_slots(self, qrel_id: str) -> List[_Slot]:
        return [self._slots[n] for n in self._replica_names(qrel_id)
                if n in self._slots]

    def _pick_slot(self, slots: Sequence[_Slot],
                   exclude: Set[str] = frozenset()) -> Optional[_Slot]:
        """Power-of-two-choices over live replicas, breaker-filtered.

        Candidates are the ready replicas not in ``exclude`` whose breaker
        admits traffic; if the breakers exclude everyone, availability
        wins over precision and all ready replicas are candidates again.
        Two candidates are sampled and the one with fewer in-flight
        requests is chosen (one candidate short-circuits).
        """
        ready = [s for s in slots
                 if s.ready.is_set() and s.name not in exclude]
        if not ready:
            return None
        allowed = [s for s in ready if s.breaker.would_allow()]
        pool = allowed or ready
        if len(pool) == 1:
            choice = pool[0]
        else:
            a, b = self._rng.sample(pool, 2)
            choice = a if a.inflight <= b.inflight else b
        choice.breaker.allow()  # consume the half-open probe slot, if any
        return choice

    async def _wait_any_ready(self, slots: Sequence[_Slot],
                              deadline: Optional[float]) -> bool:
        """Block until ANY of ``slots`` is ready (bounded)."""
        if not slots:
            return False
        if any(s.ready.is_set() for s in slots):
            return True
        timeout = self._ready_timeout
        if deadline is not None:
            timeout = min(
                timeout,
                max(0.0, deadline - asyncio.get_running_loop().time()))
        waiters = [asyncio.get_running_loop().create_task(s.ready.wait())
                   for s in slots]
        try:
            done, _pending = await asyncio.wait(
                waiters, timeout=timeout,
                return_when=asyncio.FIRST_COMPLETED)
            return bool(done)
        finally:
            for t in waiters:
                t.cancel()
            await asyncio.gather(*waiters, return_exceptions=True)

    def _unavailable(self, rid, qrel_id: str, op: str, attempts: int):
        self.counters["worker_unavailable"] += 1
        names = self._replica_names(qrel_id)
        return _error(
            rid, f"worker(s) {names!r} (replica set of qrel_id "
            f"{qrel_id!r}) unavailable; op {op!r} not completed after "
            f"{attempts} attempt(s)", "worker_unavailable")

    # -- the raw fan-out path (evaluate / compare) ---------------------------

    async def _forward_once(self, slot: _Slot, raw: bytes) -> bytes:
        slot.inflight += 1
        try:
            return await slot.proc.client.forward(raw)
        finally:
            slot.inflight -= 1

    async def _forward_recorded(self, slot: _Slot, raw: bytes) -> bytes:
        try:
            resp = await self._forward_once(slot, raw)
        except (ConnectionError, OSError):
            slot.breaker.record_failure()
            raise
        slot.breaker.record_success()
        return resp

    async def _hedged_forward(self, slot: _Slot, sibling: Optional[_Slot],
                              raw: bytes, deadline: float) -> bytes:
        """Primary attempt on ``slot``; hedge to ``sibling`` near the
        deadline; first successful response wins, the loser is cancelled.

        Raises ``asyncio.TimeoutError`` when the budget runs out, or the
        last transport error when every launched attempt failed.
        """
        loop = asyncio.get_running_loop()
        tasks: Dict[asyncio.Task, _Slot] = {
            loop.create_task(self._forward_once(slot, raw)): slot}
        hedge_at = loop.time() \
            + (deadline - loop.time()) * self._hedge_fraction
        hedged = False
        last_exc: Optional[BaseException] = None
        try:
            while tasks:
                now = loop.time()
                if now >= deadline:
                    raise asyncio.TimeoutError()
                horizon = deadline if (hedged or sibling is None) \
                    else min(hedge_at, deadline)
                done, _pending = await asyncio.wait(
                    set(tasks), timeout=max(0.0, horizon - now),
                    return_when=asyncio.FIRST_COMPLETED)
                if not done:
                    if hedged or sibling is None:
                        raise asyncio.TimeoutError()  # horizon == deadline
                    hedged = True  # near the deadline: fire the hedge
                    self.counters["hedges"] += 1
                    tasks[loop.create_task(
                        self._forward_once(sibling, raw))] = sibling
                    continue
                for t in done:
                    s = tasks.pop(t)
                    exc = t.exception()
                    if exc is None:
                        s.breaker.record_success()
                        if s is sibling:
                            self.counters["hedge_wins"] += 1
                        return t.result()
                    s.breaker.record_failure()
                    last_exc = exc
            if last_exc is not None:
                raise last_exc
            raise asyncio.TimeoutError()
        finally:
            for t in tasks:
                t.cancel()

    @staticmethod
    def _is_not_found(resp: bytes) -> bool:
        return b'"ok": false' in resp[:48] and _NOT_FOUND_MARK in resp

    async def _heal(self, slot: _Slot, qrel_id: str) -> None:
        """Re-register a journaled collection a replica turned out to miss."""
        entry = self._journal.get(qrel_id)
        if entry is None or slot.proc.client is None:
            return
        client = slot.proc.client
        await client._request("register_qrel", **entry["qrel"])
        for run_payload in entry["runs"].values():
            await client._request("register_run", **run_payload)
        self.counters["healed_replicas"] += 1

    async def _forward(self, qrel_id: str, raw: bytes, rid,
                       deadline: Optional[float] = None):
        """Raw fan-out: p2c replica choice, instant failover, hedging."""
        attempts = self._retries + 1
        failed: Set[str] = set()
        healed: Set[str] = set()  # replicas already re-registered once
        for attempt in range(attempts):
            if self._expired(deadline):
                return self._deadline_error(rid, "evaluate/compare")
            slots = self._replica_slots(qrel_id)
            slot = self._pick_slot(slots, exclude=failed)
            if slot is None:
                # every replica is down or already failed this request:
                # forgive past failures (a restart may be back) and wait
                failed.clear()
                if not await self._wait_any_ready(slots, deadline):
                    if self._expired(deadline):
                        return self._deadline_error(rid,
                                                    "evaluate/compare")
                    break
                continue
            try:
                if deadline is None:
                    resp = await self._forward_recorded(slot, raw)
                else:
                    sibling = self._pick_slot(
                        slots, exclude=failed | {slot.name})
                    resp = await self._hedged_forward(slot, sibling, raw,
                                                      deadline)
            except asyncio.TimeoutError:
                return self._deadline_error(rid, "evaluate/compare")
            except (ConnectionError, OSError):
                self.counters["worker_retries"] += 1
                if any(s.ready.is_set() for s in slots
                       if s.name not in failed and s.name != slot.name):
                    # a sibling replica is live: fail over immediately
                    failed.add(slot.name)
                    self.counters["failovers"] += 1
                else:
                    # no live sibling: keep this replica eligible and give
                    # the supervisor a beat to observe the death (its
                    # `ready` flag may be stale for an instant)
                    await asyncio.sleep(min(0.05 * 2 ** attempt, 1.0))
                continue
            if (slot.name not in healed and self._is_not_found(resp)
                    and qrel_id in self._journal):
                # THIS replica missed a registration (restart raced the
                # journal, or its LRU evicted the collection): heal it
                # and retry instead of relaying a lie — each replica gets
                # healed at most once per request
                healed.add(slot.name)
                with contextlib.suppress(Exception):
                    await self._bounded(self._heal(slot, qrel_id), deadline)
                continue
            self.counters["forwarded"] += 1
            return _rewrite_id(resp, rid)
        return self._unavailable(rid, qrel_id, "evaluate/compare", attempts)

    # -- journaled control ops (register_*) ----------------------------------

    async def _control(self, op: str, qrel_id: str, req: dict,
                       deadline: Optional[float] = None):
        """``register_*``: fan out to every ready replica, journal, ack.

        The ack requires at least one replica to hold the registration;
        replicas that are down (or die mid-request) catch up from the
        journal when their restart replays it.  A *rejected* registration
        (ServerError — bad measures, malformed qrel) is returned verbatim
        and never journaled.
        """
        rid = req.get("id")
        payload = {k: v for k, v in req.items() if k not in ("op", "id")}
        attempts = self._retries + 1
        acked: Set[str] = set()
        result = None
        for attempt in range(attempts):
            if self._expired(deadline):
                return self._deadline_error(rid, op)
            for name in self._replica_names(qrel_id):
                if name in acked:
                    continue
                slot = self._slots.get(name)
                if slot is None or not slot.ready.is_set():
                    continue  # journal replay covers it after restart
                try:
                    result = await self._bounded(
                        slot.proc.client._request(op, **payload), deadline)
                except asyncio.TimeoutError:
                    if acked:
                        break  # already durable on a replica: ack below
                    return self._deadline_error(rid, op)
                except (ConnectionError, OSError):
                    slot.breaker.record_failure()
                    self.counters["worker_retries"] += 1
                    continue
                except ServerError as exc:
                    return _error(rid, exc.args[0], exc.code)
                slot.breaker.record_success()
                acked.add(name)
            if acked:
                # journal BEFORE acking: once the client sees ok, a worker
                # restart, a rebalance, or (durable) a cluster restart
                # must be able to reproduce the registration.
                if op == "register_qrel":
                    self._journal.record_qrel(qrel_id, payload)
                else:
                    self._journal.record_run(qrel_id, str(req["run_id"]),
                                             payload)
                return {"id": rid, "ok": True, "result": result}
            if not await self._wait_any_ready(self._replica_slots(qrel_id),
                                              deadline):
                if self._expired(deadline):
                    return self._deadline_error(rid, op)
                break
        return self._unavailable(rid, qrel_id, op, attempts)

    # -- drop (non-idempotent) -----------------------------------------------

    async def _drop(self, qrel_id: str, req: dict,
                    deadline: Optional[float] = None):
        """``drop_qrel``: fan out to every ready replica, prune the journal.

        Succeeds when ANY replica acknowledges — with R >= 2 a single dead
        replica no longer forces ``worker_unavailable``.  The journal is
        pruned (memory + durable log) the moment one replica answers, so
        neither a dead sibling's restart replay nor a cluster restart can
        resurrect the dropped collection.  Only when NO replica can be
        reached does the caller get ``worker_unavailable`` — the drop is
        never retried behind their back.
        """
        rid = req.get("id")
        slots = self._replica_slots(qrel_id)
        ready = [s for s in slots if s.ready.is_set()]
        if not ready:
            self.counters["worker_unavailable"] += 1
            names = [s.name for s in slots]
            return _error(
                rid, f"all replicas {names!r} of qrel_id {qrel_id!r} are "
                "down; 'drop_qrel' is not retried — re-send once a "
                "replica is back if the drop still matters",
                "worker_unavailable")
        dropped = False
        reached = False
        first_err: Optional[ServerError] = None
        for slot in ready:
            try:
                result = await self._bounded(
                    slot.proc.client._request("drop_qrel",
                                              qrel_id=req["qrel_id"]),
                    deadline)
            except asyncio.TimeoutError:
                # ambiguous (the drop may have landed); surface the
                # deadline, do NOT prune — the caller decides
                return self._deadline_error(rid, "drop_qrel")
            except ServerError as exc:
                reached = True
                if first_err is None:
                    first_err = exc
            except (ConnectionError, OSError):
                slot.breaker.record_failure()
            else:
                slot.breaker.record_success()
                reached = True
                dropped = dropped or bool(result.get("dropped"))
        if not reached:
            self.counters["worker_unavailable"] += 1
            return _error(
                rid, f"every live replica of qrel_id {qrel_id!r} died "
                "during 'drop_qrel'; the drop may or may not have "
                "happened", "worker_unavailable")
        # at least one replica answered: the drop is authoritative — prune
        # so no replay (sibling restart OR durable cluster restart) can
        # resurrect the collection
        self._journal.record_drop(qrel_id)
        if first_err is not None and not dropped:
            return _error(rid, first_err.args[0], first_err.code)
        return {"id": rid, "ok": True, "result": {"dropped": dropped}}

    async def _wait_ready(self, slot: _Slot) -> bool:
        if slot.ready.is_set():
            return True
        try:
            await asyncio.wait_for(slot.ready.wait(), self._ready_timeout)
            return True
        except asyncio.TimeoutError:
            return False

    # -- introspection -------------------------------------------------------

    def health(self) -> dict:
        """Local (no worker round trip) cluster liveness snapshot."""
        workers = [{
            "name": s.name, "ready": s.ready.is_set(),
            "generation": s.proc.generation, "restarts": s.restarts,
            "pid": s.proc.proc.pid if s.proc.proc is not None else None,
            "breaker": s.breaker.state, "inflight": s.inflight,
        } for s in self._slots.values()]
        ready = sum(1 for w in workers if w["ready"])
        return {"status": "ok" if ready == len(workers) else "degraded",
                "workers": workers, "ready": ready,
                "replication": self._replication,
                "collections": len(self._journal)}

    async def stats(self) -> dict:
        """Aggregated worker stats + router counters.

        Top-level ``requests``/``backend_calls`` sum over live workers so
        existing coalescing assertions read the same keys as against a
        single server.
        """
        workers: Dict[str, Optional[dict]] = {}
        for name, slot in self._slots.items():
            if slot.ready.is_set():
                try:
                    workers[name] = await slot.proc.client.stats()
                    continue
                except Exception:
                    pass
            workers[name] = None
        live = [w for w in workers.values() if w is not None]
        return {
            "requests": sum(w.get("requests", 0) for w in live),
            "backend_calls": sum(w.get("backend_calls", 0) for w in live),
            "collections": sorted(
                {c for w in live for c in w.get("collections", ())}),
            "router": {**self.counters, "workers": len(self._slots),
                       "ready": sum(1 for w in workers.values()
                                    if w is not None),
                       "replication": self._replication,
                       "journal_collections": len(self._journal),
                       "journal": self._journal.stats(),
                       "breakers": {n: s.breaker.stats()
                                    for n, s in self._slots.items()}},
            "workers": workers,
        }

    @property
    def worker_names(self) -> Sequence[str]:
        return tuple(self._slots)

    def owner_of(self, qrel_id: str) -> str:
        """The primary replica of ``qrel_id`` (fault-injection aid)."""
        return self._ring.owner(str(qrel_id))

    def replicas_of(self, qrel_id: str) -> List[str]:
        """The full replica set of ``qrel_id``, primary first."""
        return self._replica_names(str(qrel_id))

    # -- drain ---------------------------------------------------------------

    async def quiesce(self, timeout: float = 30.0) -> bool:
        """Wait for router-level in-flight requests to finish."""
        deadline = time.monotonic() + timeout
        while self._inflight and time.monotonic() < deadline:
            await asyncio.sleep(0.002)
        return self._inflight == 0

    async def drain(self, *, timeout: float = 30.0) -> None:
        """Answer what's in flight, then cascade shutdown to the workers.

        The caller must already have closed the listener (new connections
        refused); this waits for in-flight requests, then stops
        supervision and SIGTERMs every worker so each runs its own drain.
        """
        self._closing = True
        await self.quiesce(timeout)
        for slot in list(self._slots.values()):
            await self._retire_slot(slot)
        self._slots.clear()
