"""The cluster router: consistent-hash fan-out over worker processes.

Topology (one router process, N worker processes)::

    clients ──TCP──▶ Router ──┬──▶ worker w0  (repro.serve, own port)
      JSON-lines    (ring)    ├──▶ worker w1
                              └──▶ worker w…

Every request naming a ``qrel_id`` is routed to the worker that owns it on
the :class:`~repro.serve.cluster.ring.HashRing` — so each collection is
interned into exactly one worker's LRU and that worker's micro-batcher
coalesces all traffic aimed at it.  ``evaluate``/``compare`` ride the raw
fan-out path (:meth:`AsyncEvalClient.forward`): the router parses each
request line once for routing, then relays the original bytes with a
spliced internal id and relays the response bytes back with the client's
id restored — no second serialization of multi-megabyte payloads.

Fault model:

* a worker crash fails that worker's in-flight futures immediately; the
  supervisor task restarts the process with exponential backoff and
  *replays the registration journal* (every ``register_qrel`` /
  ``register_run`` the router has accepted for collections the worker
  owns) before marking it ready again;
* **idempotent** ops (``evaluate``, ``compare``, ``register_*``, reads)
  retry transparently against the restarted worker — callers just see a
  slower response;
* **non-idempotent** ``drop_qrel`` is never retried: if the owning worker
  is down (or dies mid-request) the caller gets a machine-readable
  ``worker_unavailable`` error and decides for itself;
* a periodic ``health`` probe per worker catches hung-but-alive processes
  and kills them onto the same restart path.

Membership changes (:meth:`Router.add_worker` / :meth:`Router.remove_worker`)
rebalance the ring with journal replay: moved collections are registered
on their new owner *before* the ring swaps (requests never see a gap) and
best-effort dropped from the old owner after.

:meth:`Router.drain` cascades: wait for router-level in-flight requests,
then stop every worker via SIGTERM → the worker's own
``EvaluationService.drain`` machinery.
"""

from __future__ import annotations

import asyncio
import contextlib
import json
import re
import sys
import time
from typing import Dict, Optional, Sequence

from repro.client.errors import ServerError
from repro.serve.cluster.ring import HashRing
from repro.serve.cluster.worker import WorkerProcess
from repro.serve.frontend import _check_request, _error
from repro.serve.wire import DEFAULT_FRAME_LIMIT, ProtocolError

#: responses from our own front-ends lead with their id (dict insertion
#: order survives json.dumps), so the id can be rewritten by prefix splice
_RESPONSE_ID = re.compile(rb'^\{"id":\s*(?:-?\d+|null)\s*,')

#: ops fanned out as raw bytes (hot path) and retried across restarts
_RAW_OPS = frozenset({"evaluate", "compare"})

#: ops handled with a parsed round trip, journaled, and retried
_CONTROL_OPS = frozenset({"register_qrel", "register_run"})


def _rewrite_id(resp: bytes, rid) -> bytes:
    """Restore the client's request id on a forwarded response frame."""
    rid_b = json.dumps(rid).encode()
    m = _RESPONSE_ID.match(resp)
    if m is not None:
        return b'{"id": ' + rid_b + b"," + resp[m.end():]
    try:  # rare: a response shape we don't recognise — parse and patch
        msg = json.loads(resp)
        msg["id"] = rid
        return json.dumps(msg).encode()
    except ValueError:  # pragma: no cover - garbage from a worker
        return resp


class _Slot:
    """One worker position on the ring (stable name, restartable process)."""

    __slots__ = ("name", "proc", "ready", "restarts", "supervisor",
                 "health_task")

    def __init__(self, name: str, proc: WorkerProcess):
        self.name = name
        self.proc = proc
        self.ready = asyncio.Event()
        self.restarts = 0
        self.supervisor: Optional[asyncio.Task] = None
        self.health_task: Optional[asyncio.Task] = None


class Router:
    """Consistent-hash router over a supervised pool of serve workers.

    ``worker_args`` is appended to every worker's command line (measure
    flags, ``--window-ms``, ``--backend``, ...).  ``retries`` bounds
    transparent re-sends of idempotent requests across worker restarts;
    ``ready_timeout`` bounds how long a request waits for the owning
    worker to come (back) up before giving up with ``worker_unavailable``.
    """

    def __init__(self, n_workers: int = 2, *,
                 worker_args: Sequence[str] = (), replicas: int = 64,
                 retries: int = 3, ready_timeout: float = 15.0,
                 start_timeout: float = 60.0, health_interval: float = 1.0,
                 health_timeout: float = 5.0, backoff: float = 0.25,
                 max_backoff: float = 4.0,
                 frame_limit: int = DEFAULT_FRAME_LIMIT):
        if n_workers < 1:
            raise ValueError(f"need at least one worker, got {n_workers}")
        self._n_initial = int(n_workers)
        self._worker_args = [str(a) for a in worker_args]
        self._retries = int(retries)
        self._ready_timeout = float(ready_timeout)
        self._start_timeout = float(start_timeout)
        self._health_interval = float(health_interval)
        self._health_timeout = float(health_timeout)
        self._backoff = float(backoff)
        self._max_backoff = float(max_backoff)
        self._frame_limit = int(frame_limit)
        self._ring = HashRing(replicas=replicas)
        self._slots: Dict[str, _Slot] = {}
        self._next_slot = 0
        #: qrel_id -> {"qrel": register_qrel payload,
        #:             "runs": {run_id: register_run payload}} — replayed
        #: onto restarted workers and onto new owners at rebalance.  This
        #: is the price of restart transparency: the router holds every
        #: accepted registration in memory.
        self._journal: Dict[str, dict] = {}
        self._inflight = 0
        self._closing = False
        self.counters = {
            "requests": 0, "forwarded": 0, "worker_retries": 0,
            "worker_unavailable": 0, "restarts": 0, "health_failures": 0,
            "replayed_collections": 0, "rebalanced_collections": 0,
        }

    # -- pool lifecycle ------------------------------------------------------

    def _new_slot(self, name: Optional[str] = None) -> _Slot:
        if name is None:
            name = f"w{self._next_slot}"
        self._next_slot += 1
        if name in self._slots:
            raise ValueError(f"worker {name!r} already exists")
        slot = _Slot(name, WorkerProcess(
            name, extra_args=self._worker_args,
            frame_limit=self._frame_limit))
        self._slots[name] = slot
        loop = asyncio.get_running_loop()
        slot.supervisor = loop.create_task(self._supervise(slot))
        slot.health_task = loop.create_task(self._health_loop(slot))
        return slot

    async def start(self) -> None:
        """Spawn the initial pool and wait until every worker is ready."""
        slots = [self._new_slot() for _ in range(self._n_initial)]
        for slot in slots:
            self._ring.add(slot.name)
        try:
            await asyncio.wait_for(
                asyncio.gather(*(s.ready.wait() for s in slots)),
                self._start_timeout)
        except asyncio.TimeoutError:
            stderr = {s.name: list(s.proc.last_stderr)[-3:]
                      for s in slots if not s.ready.is_set()}
            await self.drain()
            raise RuntimeError(
                f"cluster failed to start within {self._start_timeout}s; "
                f"unready workers: {stderr}") from None

    async def _supervise(self, slot: _Slot) -> None:
        """Keep one slot populated: start → ready → wait for death → redo."""
        backoff = self._backoff
        while not self._closing:
            try:
                await slot.proc.start(ready_timeout=self._ready_timeout)
                await self._replay(slot)
            except asyncio.CancelledError:
                raise
            except Exception as exc:  # startup/replay failed: back off
                if self._closing:
                    return
                print(f"[cluster] worker {slot.name} start failed: {exc}; "
                      f"retrying in {backoff:.2f}s", file=sys.stderr,
                      flush=True)
                await asyncio.sleep(backoff)
                backoff = min(backoff * 2, self._max_backoff)
                continue
            backoff = self._backoff
            slot.ready.set()
            await slot.proc.wait()  # blocks for this generation's lifetime
            slot.ready.clear()
            if slot.proc.client is not None:
                # fail the dead generation's pending futures NOW so raw
                # forwards waiting on them retry instead of hanging
                with contextlib.suppress(Exception):
                    await slot.proc.client.aclose()
            if self._closing:
                return
            slot.restarts += 1
            self.counters["restarts"] += 1
            print(f"[cluster] worker {slot.name} exited "
                  f"(rc={slot.proc.proc.returncode}); restarting in "
                  f"{backoff:.2f}s", file=sys.stderr, flush=True)
            await asyncio.sleep(backoff)

    async def _health_loop(self, slot: _Slot) -> None:
        """Probe a ready worker with the cheap ``health`` op on a timer.

        ``proc.wait`` in the supervisor catches crashes instantly; this
        loop catches the *hung-but-alive* worker, which gets SIGKILLed
        onto the same restart-and-replay path.
        """
        while not self._closing:
            await asyncio.sleep(self._health_interval)
            if self._closing or not slot.ready.is_set():
                continue
            client = slot.proc.client
            try:
                await asyncio.wait_for(client.health(),
                                       self._health_timeout)
            except asyncio.CancelledError:
                raise
            except Exception:
                if self._closing or not slot.ready.is_set():
                    continue
                self.counters["health_failures"] += 1
                print(f"[cluster] worker {slot.name} failed its health "
                      "check; killing for restart", file=sys.stderr,
                      flush=True)
                slot.ready.clear()
                slot.proc.kill()

    async def _replay(self, slot: _Slot, ring: Optional[HashRing] = None,
                      only: Optional[Sequence[str]] = None) -> int:
        """Re-register journaled collections owned by ``slot``.

        ``ring`` defaults to the live ring; rebalancing passes the *next*
        ring so moved collections land on their future owner before the
        swap.  ``only`` restricts to the listed qrel ids.
        """
        ring = ring if ring is not None else self._ring
        client = slot.proc.client
        n = 0
        for qrel_id in (list(self._journal) if only is None else only):
            entry = self._journal.get(qrel_id)
            if entry is None or ring.owner(qrel_id) != slot.name:
                continue
            await client._request("register_qrel", **entry["qrel"])
            for run_payload in entry["runs"].values():
                await client._request("register_run", **run_payload)
            n += 1
        if n:
            self.counters["replayed_collections"] += n
        return n

    # -- membership changes --------------------------------------------------

    async def add_worker(self, name: Optional[str] = None) -> str:
        """Grow the pool by one worker; rebalance moved collections.

        The new worker is started and loaded with every collection the
        grown ring assigns to it *before* the ring is swapped, so routing
        never sees an owner without its data; the old owners drop their
        copies afterwards (best effort — a failed drop only wastes cache).
        """
        slot = self._new_slot(name)
        try:
            await asyncio.wait_for(slot.ready.wait(), self._start_timeout)
        except asyncio.TimeoutError:
            await self._retire_slot(slot)
            raise RuntimeError(
                f"new worker {slot.name} failed to become ready; "
                f"stderr: {list(slot.proc.last_stderr)[-3:]}") from None
        new_ring = self._ring.copy()
        new_ring.add(slot.name)
        moved = [q for q in self._journal
                 if new_ring.owner(q) != self._ring.owner(q)]
        await self._replay(slot, ring=new_ring, only=moved)
        old_owner = {q: self._ring.owner(q) for q in moved}
        self._ring = new_ring
        self.counters["rebalanced_collections"] += len(moved)
        for q in moved:
            old = self._slots.get(old_owner[q])
            if old is not None and old.ready.is_set():
                with contextlib.suppress(Exception):
                    await old.proc.client._request("drop_qrel", qrel_id=q)
        return slot.name

    async def remove_worker(self, name: str) -> None:
        """Shrink the pool; its collections move to their new owners."""
        if name not in self._slots:
            raise KeyError(f"no worker named {name!r}")
        if len(self._slots) == 1:
            raise ValueError("cannot remove the last worker")
        slot = self._slots[name]
        new_ring = self._ring.copy()
        new_ring.remove(name)
        moved = [q for q in self._journal if self._ring.owner(q) == name]
        for q in moved:
            heir = self._slots[new_ring.owner(q)]
            if not await self._wait_ready(heir):
                raise RuntimeError(
                    f"cannot rebalance {q!r}: worker {heir.name} is down")
            await self._replay(heir, ring=new_ring, only=[q])
        self._ring = new_ring
        self.counters["rebalanced_collections"] += len(moved)
        del self._slots[name]
        await self._retire_slot(slot)

    async def _retire_slot(self, slot: _Slot) -> None:
        for task in (slot.health_task, slot.supervisor):
            if task is not None:
                task.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await task
        await slot.proc.stop()

    # -- request handling ----------------------------------------------------

    async def handle(self, req: dict, raw: bytes):
        """The :func:`repro.serve.frontend.serve_protocol` handler.

        Returns a response dict, or raw response bytes for the fan-out
        path.  Never raises.
        """
        self.counters["requests"] += 1
        self._inflight += 1
        try:
            return await self._handle(req, raw)
        except Exception as exc:  # noqa: BLE001 — router bug: tell the client
            return _error(req.get("id"),
                          f"router error: {type(exc).__name__}: {exc}",
                          "internal")
        finally:
            self._inflight -= 1

    async def _handle(self, req: dict, raw: bytes):
        rid = req.get("id")
        try:
            op = _check_request(req)
        except ProtocolError as exc:
            return _error(rid, str(exc), exc.code)
        if op == "ping":
            return {"id": rid, "ok": True, "result": "pong"}
        if op == "health":
            return {"id": rid, "ok": True, "result": self.health()}
        if op == "auth":
            # serve_protocol intercepts auth when the router has a token;
            # with no token configured, accept any (same as the worker
            # front-end) so token-configured clients work unchanged
            return {"id": rid, "ok": True,
                    "result": {"authenticated": True}}
        if op == "stats":
            return {"id": rid, "ok": True, "result": await self.stats()}
        qrel_id = str(req["qrel_id"])
        if op == "drop_qrel":
            return await self._drop(qrel_id, req)
        if op in _CONTROL_OPS:
            return await self._control(op, qrel_id, req)
        assert op in _RAW_OPS, op
        return await self._forward(qrel_id, raw, rid)

    async def _wait_ready(self, slot: _Slot) -> bool:
        if slot.ready.is_set():
            return True
        try:
            await asyncio.wait_for(slot.ready.wait(), self._ready_timeout)
            return True
        except asyncio.TimeoutError:
            return False

    def _owner_slot(self, qrel_id: str) -> _Slot:
        # resolved fresh on every retry so rebalances take effect mid-flight
        return self._slots[self._ring.owner(qrel_id)]

    def _unavailable(self, rid, qrel_id: str, op: str, attempts: int):
        self.counters["worker_unavailable"] += 1
        name = self._ring.owner(qrel_id)
        return _error(
            rid, f"worker {name!r} (owner of qrel_id {qrel_id!r}) is "
            f"unavailable; op {op!r} not completed after {attempts} "
            f"attempt(s)", "worker_unavailable")

    async def _forward(self, qrel_id: str, raw: bytes, rid):
        """Raw fan-out with transparent retry for idempotent ops."""
        attempts = self._retries + 1
        for attempt in range(attempts):
            slot = self._owner_slot(qrel_id)
            if not await self._wait_ready(slot):
                break
            try:
                resp = await slot.proc.client.forward(raw)
            except (ConnectionError, OSError):
                self.counters["worker_retries"] += 1
                # the supervisor needs a beat to observe the death and
                # clear `ready`; otherwise retries burn on a stale client
                await asyncio.sleep(min(0.05 * 2 ** attempt, 1.0))
                continue
            self.counters["forwarded"] += 1
            return _rewrite_id(resp, rid)
        return self._unavailable(rid, qrel_id, "evaluate/compare", attempts)

    async def _control(self, op: str, qrel_id: str, req: dict):
        """Parsed round trip for ``register_*``: journaled on success."""
        rid = req.get("id")
        payload = {k: v for k, v in req.items() if k not in ("op", "id")}
        attempts = self._retries + 1
        for attempt in range(attempts):
            slot = self._owner_slot(qrel_id)
            if not await self._wait_ready(slot):
                break
            try:
                result = await slot.proc.client._request(op, **payload)
            except (ConnectionError, OSError):
                self.counters["worker_retries"] += 1
                await asyncio.sleep(min(0.05 * 2 ** attempt, 1.0))
                continue
            except ServerError as exc:
                return _error(rid, exc.args[0], exc.code)
            if op == "register_qrel":
                self._journal[qrel_id] = {"qrel": payload, "runs": {}}
            else:
                entry = self._journal.get(qrel_id)
                if entry is not None:
                    entry["runs"][str(req["run_id"])] = payload
            return {"id": rid, "ok": True, "result": result}
        return self._unavailable(rid, qrel_id, op, attempts)

    async def _drop(self, qrel_id: str, req: dict):
        """``drop_qrel``: single attempt, never retried (non-idempotent)."""
        rid = req.get("id")
        slot = self._owner_slot(qrel_id)
        if not slot.ready.is_set():
            self.counters["worker_unavailable"] += 1
            return _error(
                rid, f"worker {slot.name!r} (owner of qrel_id "
                f"{qrel_id!r}) is down; 'drop_qrel' is not retried — "
                "re-send once the worker is back if the drop still "
                "matters", "worker_unavailable")
        try:
            result = await slot.proc.client._request("drop_qrel",
                                                     qrel_id=req["qrel_id"])
        except ServerError as exc:
            return _error(rid, exc.args[0], exc.code)
        except (ConnectionError, OSError) as exc:
            self.counters["worker_unavailable"] += 1
            return _error(
                rid, f"worker {slot.name!r} died during 'drop_qrel' "
                f"({exc}); the drop may or may not have happened",
                "worker_unavailable")
        self._journal.pop(qrel_id, None)
        return {"id": rid, "ok": True, "result": result}

    # -- introspection -------------------------------------------------------

    def health(self) -> dict:
        """Local (no worker round trip) cluster liveness snapshot."""
        workers = [{
            "name": s.name, "ready": s.ready.is_set(),
            "generation": s.proc.generation, "restarts": s.restarts,
            "pid": s.proc.proc.pid if s.proc.proc is not None else None,
        } for s in self._slots.values()]
        ready = sum(1 for w in workers if w["ready"])
        return {"status": "ok" if ready == len(workers) else "degraded",
                "workers": workers, "ready": ready,
                "collections": len(self._journal)}

    async def stats(self) -> dict:
        """Aggregated worker stats + router counters.

        Top-level ``requests``/``backend_calls`` sum over live workers so
        existing coalescing assertions read the same keys as against a
        single server.
        """
        workers: Dict[str, Optional[dict]] = {}
        for name, slot in self._slots.items():
            if slot.ready.is_set():
                try:
                    workers[name] = await slot.proc.client.stats()
                    continue
                except Exception:
                    pass
            workers[name] = None
        live = [w for w in workers.values() if w is not None]
        return {
            "requests": sum(w.get("requests", 0) for w in live),
            "backend_calls": sum(w.get("backend_calls", 0) for w in live),
            "collections": sorted(
                c for w in live for c in w.get("collections", ())),
            "router": {**self.counters, "workers": len(self._slots),
                       "ready": sum(1 for w in workers.values()
                                    if w is not None),
                       "journal_collections": len(self._journal)},
            "workers": workers,
        }

    @property
    def worker_names(self) -> Sequence[str]:
        return tuple(self._slots)

    def owner_of(self, qrel_id: str) -> str:
        """Which worker owns ``qrel_id`` right now (fault-injection aid)."""
        return self._ring.owner(str(qrel_id))

    # -- drain ---------------------------------------------------------------

    async def quiesce(self, timeout: float = 30.0) -> bool:
        """Wait for router-level in-flight requests to finish."""
        deadline = time.monotonic() + timeout
        while self._inflight and time.monotonic() < deadline:
            await asyncio.sleep(0.002)
        return self._inflight == 0

    async def drain(self, *, timeout: float = 30.0) -> None:
        """Answer what's in flight, then cascade shutdown to the workers.

        The caller must already have closed the listener (new connections
        refused); this waits for in-flight requests, then stops
        supervision and SIGTERMs every worker so each runs its own drain.
        """
        self._closing = True
        await self.quiesce(timeout)
        for slot in list(self._slots.values()):
            await self._retire_slot(slot)
        self._slots.clear()
