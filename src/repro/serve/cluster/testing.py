"""A live cluster (router + worker subprocesses) on a background thread.

The cluster analogue of :class:`repro.serve.testing.ServerThread`, for
synchronous drivers (tests, benchmarks, the ``cluster-smoke`` verify
step): boots a :class:`~repro.serve.cluster.router.Router` and its worker
pool on a private event-loop thread, exposes the router's TCP endpoint,
and tears the whole tree down gracefully on :meth:`close` (listener →
router drain → SIGTERM to every worker).

    >>> from repro.serve.cluster.testing import ClusterThread
    >>> with ClusterThread(n_workers=2) as cluster:   # doctest: +SKIP
    ...     cluster.owner_of("robust04") in cluster.worker_names
    True

(Skipped in doctest runs: booting workers costs ~1 s each; the real
coverage lives in ``tests/test_cluster.py``.)
"""

from __future__ import annotations

import asyncio
import threading
from typing import Optional, Sequence, Tuple

from repro.serve.cluster.router import Router
from repro.serve.frontend import serve_protocol


class ClusterThread:
    """Run a router + worker pool on a private loop thread.

    ``router_kw`` goes to the :class:`Router` constructor (``retries``,
    ``health_interval``, ``worker_args``, ...); remaining keywords in
    ``tcp_kw`` go to :func:`serve_protocol` (``limit``, ``auth_token``,
    ``rate_limit``, ``burst``).  The router listens on ``127.0.0.1`` at an
    ephemeral :attr:`port`.
    """

    def __init__(self, n_workers: int = 2, *,
                 worker_args: Sequence[str] = (),
                 router_kw: Optional[dict] = None, boot_timeout: float = 120,
                 **tcp_kw):
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-cluster-thread")
        self._thread.start()

        async def boot():
            router = Router(n_workers, worker_args=worker_args,
                            **(router_kw or {}))
            await router.start()
            server = await serve_protocol(router.handle, "127.0.0.1", 0,
                                          **tcp_kw)
            return router, server

        self.router, self._server = self.call(boot(), timeout=boot_timeout)
        self.host = "127.0.0.1"
        self.port = self._server.sockets[0].getsockname()[1]

    def _run(self) -> None:
        asyncio.set_event_loop(self._loop)
        self._loop.run_forever()

    # -- sync facade ---------------------------------------------------------

    def call(self, coro, timeout: float = 60):
        """Run a coroutine on the cluster loop; block for its result."""
        return asyncio.run_coroutine_threadsafe(coro, self._loop).result(
            timeout)

    def stats(self) -> dict:
        return self.call(self.router.stats())

    def health(self) -> dict:
        async def _do():
            return self.router.health()
        return self.call(_do())

    @property
    def worker_names(self) -> Tuple[str, ...]:
        return tuple(self.router.worker_names)

    def owner_of(self, qrel_id: str) -> str:
        """Which worker owns ``qrel_id`` (for aiming fault injection)."""
        return self.router.owner_of(qrel_id)

    def replicas_of(self, qrel_id: str) -> Tuple[str, ...]:
        """The full replica set of ``qrel_id``, primary first."""
        return tuple(self.router.replicas_of(qrel_id))

    def kill_worker(self, name: str) -> int:
        """SIGKILL a worker process (fault injection); returns its pid."""
        async def _do():
            proc = self.router._slots[name].proc
            pid = proc.proc.pid
            proc.kill()
            return pid
        return self.call(_do())

    def pause_worker(self, name: str) -> None:
        """SIGSTOP a worker (hung-but-alive fault injection)."""
        async def _do():
            self.router._slots[name].proc.pause()
        self.call(_do())

    def resume_worker(self, name: str) -> None:
        """SIGCONT a paused worker."""
        async def _do():
            self.router._slots[name].proc.resume()
        self.call(_do())

    def add_worker(self, name: Optional[str] = None) -> str:
        return self.call(self.router.add_worker(name), timeout=120)

    def remove_worker(self, name: str) -> None:
        self.call(self.router.remove_worker(name), timeout=120)

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Stop accepting, drain the router, SIGTERM workers, stop loop."""
        if self._thread.is_alive():
            async def _shutdown():
                self._server.close()
                await self._server.wait_closed()
                await self.router.drain()
                others = [t for t in asyncio.all_tasks()
                          if t is not asyncio.current_task()]
                if others:
                    await asyncio.wait(others, timeout=1)
            self.call(_shutdown(), timeout=120)
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(10)
        if not self._loop.is_running():
            self._loop.close()

    def __enter__(self) -> "ClusterThread":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
