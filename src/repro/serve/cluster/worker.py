"""One cluster worker: a ``python -m repro.serve`` subprocess + its client.

:class:`WorkerProcess` owns exactly the *mechanics* of one worker
generation — spawn the subprocess on an ephemeral TCP port, parse the
``serving on host:port`` banner off its stderr, connect an
:class:`~repro.client.aio.AsyncEvalClient` and confirm readiness with the
lightweight ``ping`` op, and later stop it gracefully (SIGTERM → the
worker's own drain machinery finishes in-flight batches → bounded wait →
SIGKILL fallback).  Restart *policy* (backoff, health checks, journal
replay) lives in :class:`~repro.serve.cluster.router.Router`, which calls
:meth:`start` again for each new generation.

Each generation gets a fresh port and a fresh client: the old client's
pending futures fail with ``ConnectionLostError`` the moment the process
dies, which is what unblocks the router's retry path.
"""

from __future__ import annotations

import asyncio
import collections
import contextlib
import os
import signal
import sys
from typing import Deque, List, Optional, Sequence, Tuple

import repro
from repro.client.aio import AsyncEvalClient
from repro.serve.wire import DEFAULT_FRAME_LIMIT

#: directory that makes ``import repro`` work in the child, whatever the
#: parent's cwd is — prepended to the child's PYTHONPATH
_SRC = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))

_BANNER = "serving on "


class WorkerStartupError(ConnectionError):
    """The worker subprocess died or hung before announcing readiness."""


class WorkerProcess:
    """Lifecycle of one worker slot across process generations.

    ``extra_args`` are appended to the ``python -m repro.serve --tcp
    127.0.0.1:0`` command line (measure flags, ``--window-ms``, ...).
    ``frame_limit`` is the *router's* frame limit; the worker's server and
    this side's client both get a little headroom on top of it, because
    forwarded frames carry a spliced-on internal request id.

    ``wrap_endpoint`` is an async hook ``(name, host, port) -> (host,
    port)`` called once per generation, after the banner is parsed and
    before the client connects — the chaos harness uses it to interpose a
    fault-injecting TCP proxy between router and worker.
    """

    def __init__(self, name: str, *, extra_args: Sequence[str] = (),
                 python: str = sys.executable,
                 frame_limit: int = DEFAULT_FRAME_LIMIT,
                 env: Optional[dict] = None, wrap_endpoint=None):
        self.name = name
        self._extra = [str(a) for a in extra_args]
        self._python = python
        self._frame_limit = int(frame_limit) + 4096  # id-splice headroom
        self._env = env
        self._wrap_endpoint = wrap_endpoint
        self.proc: Optional[asyncio.subprocess.Process] = None
        self.client: Optional[AsyncEvalClient] = None
        self.host: Optional[str] = None
        self.port: Optional[int] = None
        self.generation = 0
        #: last stderr lines from the current generation, for diagnostics
        self.last_stderr: Deque[str] = collections.deque(maxlen=40)
        self._stderr_task: Optional[asyncio.Task] = None

    # -- lifecycle -----------------------------------------------------------

    @property
    def alive(self) -> bool:
        return self.proc is not None and self.proc.returncode is None

    def _argv(self) -> List[str]:
        return [self._python, "-m", "repro.serve", "--tcp", "127.0.0.1:0",
                "--max-frame-mb", str(self._frame_limit / 2**20),
                *self._extra]

    def _child_env(self) -> dict:
        env = dict(os.environ)
        if self._env:
            env.update(self._env)
        env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
        return env

    async def start(self, *, ready_timeout: float = 30.0) -> None:
        """Spawn a new generation and block until it answers ``ping``."""
        assert not self.alive, f"worker {self.name} is already running"
        self.generation += 1
        self.last_stderr.clear()
        if self._stderr_task is not None:
            self._stderr_task.cancel()
            self._stderr_task = None
        self.proc = await asyncio.create_subprocess_exec(
            *self._argv(), stdin=asyncio.subprocess.DEVNULL,
            stderr=asyncio.subprocess.PIPE, env=self._child_env())
        try:
            self.host, self.port = await asyncio.wait_for(
                self._await_banner(), ready_timeout)
            if self._wrap_endpoint is not None:
                self.host, self.port = await self._wrap_endpoint(
                    self.name, self.host, self.port)
            # keep stderr flowing so the pipe never fills and the last
            # lines are available when the process dies
            self._stderr_task = asyncio.get_running_loop().create_task(
                self._drain_stderr())
            self.client = await AsyncEvalClient.connect(
                self.host, self.port, retries=0,
                frame_limit=self._frame_limit)
            pong = await asyncio.wait_for(self.client.ping(), ready_timeout)
            assert pong == "pong", pong
        except BaseException as exc:
            self.kill()
            with contextlib.suppress(Exception):
                await self.proc.wait()
            if self.client is not None:
                with contextlib.suppress(Exception):
                    await self.client.aclose()
                self.client = None
            if isinstance(exc, (asyncio.TimeoutError, ConnectionError,
                                OSError)):
                raise WorkerStartupError(
                    f"worker {self.name} failed to become ready: "
                    f"{type(exc).__name__}: {exc}; stderr: "
                    f"{list(self.last_stderr)[-5:]}") from exc
            raise

    async def _await_banner(self) -> Tuple[str, int]:
        while True:
            line = await self.proc.stderr.readline()
            if not line:
                rc = await self.proc.wait()
                raise WorkerStartupError(
                    f"worker {self.name} exited (rc={rc}) before ready; "
                    f"stderr: {list(self.last_stderr)[-5:]}")
            text = line.decode("utf-8", "replace").strip()
            if text:
                self.last_stderr.append(text)
            if text.startswith(_BANNER):
                host, _, port = text[len(_BANNER):].rpartition(":")
                return host, int(port)

    async def _drain_stderr(self) -> None:
        try:
            while True:
                line = await self.proc.stderr.readline()
                if not line:
                    return
                text = line.decode("utf-8", "replace").strip()
                if text:
                    self.last_stderr.append(text)
        except (asyncio.CancelledError, ConnectionError, OSError):
            pass

    async def wait(self) -> int:
        """Block until the current generation's process exits."""
        return await self.proc.wait()

    def kill(self) -> None:
        """SIGKILL the current generation (fault injection / last resort)."""
        if self.alive:
            with contextlib.suppress(ProcessLookupError):
                self.proc.kill()

    def pause(self) -> None:
        """SIGSTOP the current generation: alive but hung (fault
        injection — the router's health probe is what must notice)."""
        if self.alive:
            with contextlib.suppress(ProcessLookupError):
                self.proc.send_signal(signal.SIGSTOP)

    def resume(self) -> None:
        """SIGCONT a paused generation."""
        if self.alive:
            with contextlib.suppress(ProcessLookupError):
                self.proc.send_signal(signal.SIGCONT)

    async def stop(self, *, timeout: float = 15.0) -> None:
        """Graceful shutdown: close the client, SIGTERM, bounded wait.

        SIGTERM lands in the worker's own signal handler, which stops
        accepting, drains in-flight batches (``EvaluationService.drain``)
        and exits — the cascading half of the router's drain.
        """
        if self.client is not None:
            with contextlib.suppress(Exception):
                await self.client.aclose()
            self.client = None
        if self.alive:
            with contextlib.suppress(ProcessLookupError):
                self.proc.send_signal(signal.SIGTERM)
            try:
                await asyncio.wait_for(self.proc.wait(), timeout)
            except asyncio.TimeoutError:
                self.kill()
                await self.proc.wait()
        if self._stderr_task is not None:
            self._stderr_task.cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await self._stderr_task
            self._stderr_task = None
