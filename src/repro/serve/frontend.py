"""JSON-lines front-ends for the evaluation service: stdio and TCP.

One request per line, one JSON object per response line::

    {"op": "register_qrel", "id": 1, "qrel_id": "web",
     "qrel": {"q1": {"d1": 1}}, "measures": ["map"]}
    {"op": "evaluate", "id": 2, "qrel_id": "web",
     "run": {"q1": {"d1": 1.0}}}

Responses echo the request ``id`` (responses may arrive out of order —
requests are handled concurrently so the service can coalesce them)::

    {"id": 1, "ok": true, "result": {...}}
    {"id": 2, "ok": true, "result": {"per_query": {...}, "aggregates": {...}}}
    {"id": 3, "ok": false, "error": "unknown qrel_id 'nope': ..."}

Operations: ``register_qrel``, ``register_run``, ``evaluate``, ``drop_qrel``,
``stats``, ``ping``.  Field names mirror the keyword arguments of
:class:`repro.serve.service.EvaluationService`.

Front-ends::

    python -m repro.serve --qrel tests/fixtures/conformance.qrel -m map
    python -m repro.serve --tcp 127.0.0.1:9090 ...

The default front-end reads stdin and writes stdout (one process per
client); ``--tcp`` serves any number of concurrent connections, and requests
from DIFFERENT connections coalesce into the same backend batches.  The
``-m`` / ``-l`` measure flags are shared with the one-shot CLI
(:func:`repro.cli.add_measure_args`).
"""

from __future__ import annotations

import argparse
import asyncio
import json
import sys
from typing import Optional, Sequence, Tuple

from repro.serve.service import EvaluationService, ServeResult


async def handle_request(service: EvaluationService, req: dict) -> dict:
    """Execute one decoded protocol request; never raises."""
    rid = req.get("id")
    try:
        op = req.get("op")
        if op == "register_qrel":
            result = service.register_qrel(
                req["qrel_id"], req["qrel"], measures=req.get("measures"),
                relevance_level=int(req.get("relevance_level", 1)),
                backend=req.get("backend"))
        elif op == "register_run":
            result = service.register_run(
                req["qrel_id"], req["run_id"], run=req.get("run"),
                tokens=req.get("tokens"))
        elif op == "evaluate":
            res: ServeResult = await service.evaluate(
                req["qrel_id"], run=req.get("run"),
                tokens=req.get("tokens"), run_ref=req.get("run_ref"),
                scores=req.get("scores"))
            result = {"per_query": res.per_query,
                      "aggregates": res.aggregates}
        elif op == "drop_qrel":
            result = {"dropped": service.drop_qrel(req["qrel_id"])}
        elif op == "stats":
            result = service.stats()
        elif op == "ping":
            result = "pong"
        else:
            raise ValueError(f"unknown op {op!r}")
    except Exception as exc:  # noqa: BLE001 — protocol errors go to the client
        return {"id": rid, "ok": False,
                "error": f"{type(exc).__name__}: {exc}"}
    return {"id": rid, "ok": True, "result": result}


async def handle_line(service: EvaluationService, line: str) -> str:
    """One protocol line in, one JSON response line out."""
    try:
        req = json.loads(line)
        if not isinstance(req, dict):
            raise ValueError("request must be a JSON object")
    except ValueError as exc:
        return json.dumps({"id": None, "ok": False,
                           "error": f"bad request line: {exc}"})
    return json.dumps(await handle_request(service, req))


# -- TCP ---------------------------------------------------------------------


async def serve_tcp(service: EvaluationService, host: str = "127.0.0.1",
                    port: int = 0):
    """Start the TCP front-end; returns the ``asyncio`` server object.

    Each connection is a JSON-lines stream.  Every request line becomes its
    own task, so slow evaluations never block the connection's reader — and
    concurrent requests (same or different connections) coalesce in the
    service's micro-batcher.  Pass ``port=0`` for an ephemeral port
    (``server.sockets[0].getsockname()[1]``).
    """

    async def client(reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        wlock = asyncio.Lock()
        tasks = set()

        async def one(raw: bytes) -> None:
            resp = await handle_line(service, raw.decode("utf-8",
                                                         "replace"))
            try:
                async with wlock:
                    writer.write(resp.encode() + b"\n")
                    await writer.drain()
            except (ConnectionError, OSError):
                # client went away before reading its response — the
                # evaluation already happened; nothing useful to raise
                # (an unretrieved task exception would just spam stderr)
                pass

        try:
            while True:
                raw = await reader.readline()
                if not raw:
                    break
                if not raw.strip():
                    continue
                t = asyncio.get_running_loop().create_task(one(raw))
                tasks.add(t)
                t.add_done_callback(tasks.discard)
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    return await asyncio.start_server(client, host, port)


# -- stdio -------------------------------------------------------------------


async def serve_stdio(service: EvaluationService, in_stream=None,
                      out_stream=None) -> None:
    """JSON-lines over stdin/stdout until EOF (one process per client)."""
    loop = asyncio.get_running_loop()
    in_stream = sys.stdin if in_stream is None else in_stream
    out_stream = sys.stdout if out_stream is None else out_stream
    wlock = asyncio.Lock()
    tasks = set()

    async def one(line: str) -> None:
        resp = await handle_line(service, line)
        async with wlock:
            out_stream.write(resp + "\n")
            out_stream.flush()

    while True:
        line = await loop.run_in_executor(None, in_stream.readline)
        if not line:
            break
        if not line.strip():
            continue
        t = loop.create_task(one(line))
        tasks.add(t)
        t.add_done_callback(tasks.discard)
    if tasks:
        await asyncio.gather(*tasks, return_exceptions=True)


# -- entry point -------------------------------------------------------------


def _parse_hostport(spec: str) -> Tuple[str, int]:
    host, _, port = spec.rpartition(":")
    return host or "127.0.0.1", int(port)


def build_service(args) -> EvaluationService:
    """Service + optional default collection from parsed CLI args."""
    from repro import cli
    from repro.core import trec

    service = EvaluationService(
        max_collections=args.max_collections,
        window=args.window_ms / 1e3, max_batch=args.max_batch,
        max_pending=args.max_pending, backend=args.backend)
    if args.qrel:
        info = service.register_qrel(
            args.qrel_id, trec.load_qrel(args.qrel),
            measures=cli.resolve_measures(args.measures),
            relevance_level=args.level)
        print(f"registered qrel {info['qrel_id']!r}: "
              f"{info['n_queries']} queries, backend={info['backend']}",
              file=sys.stderr, flush=True)
    return service


def main(argv: Optional[Sequence[str]] = None) -> int:
    from repro import cli

    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Async evaluation service speaking JSON-lines over "
                    "stdio (default) or TCP.")
    ap.add_argument("--tcp", metavar="HOST:PORT",
                    help="serve TCP instead of stdio (port 0 = ephemeral)")
    ap.add_argument("--qrel", metavar="PATH",
                    help="pre-register this TREC qrel file at startup")
    ap.add_argument("--qrel-id", default="default", metavar="ID",
                    help="collection id for --qrel (default: 'default')")
    cli.add_measure_args(ap)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "single", "sharded"),
                    help="evaluation backend (auto: sharded iff >1 device)")
    ap.add_argument("--window-ms", type=float, default=2.0, metavar="MS",
                    help="coalescing window in milliseconds (default 2)")
    ap.add_argument("--max-batch", type=int, default=64, metavar="N",
                    help="flush a window early at N pending requests")
    ap.add_argument("--max-collections", type=int, default=8, metavar="N",
                    help="LRU capacity for resident collections")
    ap.add_argument("--max-pending", type=int, default=256, metavar="N",
                    help="in-flight request cap (backpressure)")
    args = ap.parse_args(argv)

    async def run() -> None:
        service = build_service(args)
        if args.tcp:
            host, port = _parse_hostport(args.tcp)
            server = await serve_tcp(service, host, port)
            addr = server.sockets[0].getsockname()
            print(f"serving on {addr[0]}:{addr[1]}", file=sys.stderr,
                  flush=True)
            async with server:
                await server.serve_forever()
        else:
            await serve_stdio(service)

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
