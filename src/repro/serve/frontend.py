"""JSON-lines front-ends for the evaluation service: stdio and TCP.

One request per line, one JSON object per response line::

    {"op": "register_qrel", "id": 1, "qrel_id": "web",
     "qrel": {"q1": {"d1": 1}}, "measures": ["map"]}
    {"op": "evaluate", "id": 2, "qrel_id": "web",
     "run": {"q1": {"d1": 1.0}}}

Responses echo the request ``id`` (responses may arrive out of order —
requests are handled concurrently so the service can coalesce them)::

    {"id": 1, "ok": true, "result": {...}}
    {"id": 2, "ok": true, "result": {"per_query": {...}, "aggregates": {...}}}
    {"id": 3, "ok": false, "error": "unknown qrel_id 'nope': ...",
     "code": "not_found"}

Operations: ``register_qrel``, ``register_run``, ``evaluate``,
``compare`` (paired significance tests across K runs — see
:meth:`EvaluationService.compare`), ``drop_qrel``, ``stats``, ``ping``,
``health`` (the cheap liveness probe used by the cluster router's health
checks), ``auth``.  Field names mirror the keyword arguments of
:class:`repro.serve.service.EvaluationService`.

Every failure is a *response*, never a dead socket: unparseable lines,
unknown ops, missing fields, and even request lines longer than the frame
limit (``--max-frame-mb``, default 64 MiB — the asyncio 64 KiB default
rejected any real qrel payload) come back as ``ok: false`` objects with a
machine-readable ``code`` from :data:`repro.serve.wire.ERROR_CODES`, and
the connection keeps serving.

TCP hardening knobs: ``--auth-token`` requires each connection to open
with ``{"op": "auth", "token": ...}`` before other requests (a wrong token
is an error response — the connection may retry); ``--rate-limit`` /
``--burst`` throttle each connection through a token bucket (excess
requests are *delayed*, never dropped).  On SIGINT/SIGTERM the server
stops accepting, finishes in-flight batches
(:meth:`EvaluationService.drain`), and exits cleanly.

Front-ends::

    python -m repro.serve --qrel tests/fixtures/conformance.qrel -m map
    python -m repro.serve --tcp 127.0.0.1:9090 --auth-token s3cret ...

The default front-end reads stdin and writes stdout (one process per
client); ``--tcp`` serves any number of concurrent connections, and requests
from DIFFERENT connections coalesce into the same backend batches.  The
``-m`` / ``-l`` measure flags are shared with the one-shot CLI
(:func:`repro.cli.add_measure_args`).  ``repro.client`` is the library
speaking this protocol (persistent connections, pipelining, retry).
"""

from __future__ import annotations

import argparse
import asyncio
import hmac
import json
import signal
import sys
from typing import Optional, Sequence, Tuple

from repro.serve.service import EvaluationService, ServeResult
from repro.serve.wire import (DEFAULT_FRAME_LIMIT, OversizedFrame,
                              ProtocolError, TokenBucket, iter_frames)

#: required fields per operation, checked before dispatch so the client
#: sees "op 'evaluate' requires field 'qrel_id'" instead of a bare KeyError
REQUIRED_FIELDS = {
    "register_qrel": ("qrel_id", "qrel"),
    "register_run": ("qrel_id", "run_id"),
    "evaluate": ("qrel_id",),
    "compare": ("qrel_id",),
    "drop_qrel": ("qrel_id",),
    "stats": (),
    "ping": (),
    "health": (),
    "auth": ("token",),
}


def _error(rid, message: str, code: str) -> dict:
    return {"id": rid, "ok": False, "error": message, "code": code}


def _exc_message(exc: BaseException) -> str:
    # KeyError('x') stringifies as "'x'" — unwrap single string args
    if len(exc.args) == 1 and isinstance(exc.args[0], str):
        return exc.args[0]
    return str(exc)


def _check_request(req: dict) -> str:
    """Validate op + required fields; returns the op.  Raises ProtocolError."""
    op = req.get("op")
    if op not in REQUIRED_FIELDS:
        raise ProtocolError(
            f"unknown op {op!r} (expected one of "
            f"{'/'.join(sorted(REQUIRED_FIELDS))})", code="unknown_op")
    for field in REQUIRED_FIELDS[op]:
        if field not in req:
            raise ProtocolError(
                f"op {op!r} requires field {field!r}", code="missing_field")
    return op


def _relevance_level(req: dict):
    """The protocol's one typing rule for ``relevance_level``: a number.

    Ints and floats both pass straight through — the single int→float
    conversion lives in :class:`repro.core.RelevanceEvaluator`, exactly as
    for the CLI's ``-l`` flag (no lossy ``int()`` truncation here).
    """
    level = req.get("relevance_level", 1)
    if isinstance(level, bool) or not isinstance(level, (int, float)):
        raise ProtocolError(
            "op 'register_qrel' field 'relevance_level' must be a number "
            f"like the CLI's -l flag, got {type(level).__name__}: {level!r}",
            code="invalid")
    return level


def _deadline_seconds(req: dict) -> Optional[float]:
    """Validate the optional ``deadline_ms`` field → seconds (or None)."""
    ms = req.get("deadline_ms")
    if ms is None:
        return None
    if isinstance(ms, bool) or not isinstance(ms, (int, float)) or ms <= 0:
        raise ProtocolError(
            "field 'deadline_ms' must be a positive number of "
            f"milliseconds, got {ms!r}", code="invalid")
    return float(ms) / 1e3


async def handle_request(service: EvaluationService, req: dict) -> dict:
    """Execute one decoded protocol request; never raises.

    A request may carry ``deadline_ms``: the budget the *caller* is still
    willing to wait.  Past it the op is cancelled and answered with a
    ``deadline_exceeded`` error — each attempt gets the full budget from
    its arrival here (end-to-end enforcement, including queueing and
    retries, is the cluster router's job).
    """
    rid = req.get("id")
    try:
        op = _check_request(req)
        budget = _deadline_seconds(req)
        if budget is None:
            return await _dispatch_request(service, op, req)
        try:
            return await asyncio.wait_for(
                _dispatch_request(service, op, req), budget)
        except asyncio.TimeoutError:
            return _error(
                rid, f"op {op!r} missed its 'deadline_ms' budget "
                f"({req['deadline_ms']} ms) on the server",
                "deadline_exceeded")
    except ProtocolError as exc:
        return _error(rid, str(exc), exc.code)


async def _dispatch_request(service: EvaluationService, op: str,
                            req: dict) -> dict:
    """The per-op dispatch behind :func:`handle_request`; never raises."""
    rid = req.get("id")
    try:
        if op == "register_qrel":
            result = service.register_qrel(
                req["qrel_id"], req["qrel"], measures=req.get("measures"),
                relevance_level=_relevance_level(req),
                backend=req.get("backend"),
                judged_docs_only=bool(req.get("judged_docs_only", False)))
        elif op == "register_run":
            result = service.register_run(
                req["qrel_id"], req["run_id"], run=req.get("run"),
                tokens=req.get("tokens"))
        elif op == "evaluate":
            res: ServeResult = await service.evaluate(
                req["qrel_id"], run=req.get("run"),
                tokens=req.get("tokens"), run_ref=req.get("run_ref"),
                scores=req.get("scores"))
            result = {"per_query": res.per_query,
                      "aggregates": res.aggregates}
        elif op == "compare":
            result = await service.compare(
                req["qrel_id"], runs=req.get("runs"),
                run_refs=req.get("run_refs"),
                measure=req.get("measure", "map"),
                tests=tuple(req.get("tests", ("t",))),
                n_permutations=req.get("n_permutations", 2000),
                seed=req.get("seed", 0), alpha=req.get("alpha", 0.05),
                run_names=req.get("run_names"))
        elif op == "drop_qrel":
            result = {"dropped": service.drop_qrel(req["qrel_id"])}
        elif op == "stats":
            result = service.stats()
        elif op == "health":
            # the cheap liveness/readiness probe (cluster health checks hit
            # this on a timer): counters only, no evaluation machinery
            st = service.stats()
            result = {"status": "ok", "in_flight": st["in_flight"],
                      "collections": st["collections"]}
        elif op == "auth":
            # an unauthenticated front-end accepts any token (no-op), so
            # clients configured with a token work against open servers;
            # the TCP front-end intercepts this op when a token is set
            result = {"authenticated": True}
        else:  # op == "ping"
            result = "pong"
    except asyncio.CancelledError:
        raise  # deadline (or shutdown) cancellation must propagate
    except ProtocolError as exc:
        return _error(rid, str(exc), exc.code)
    except KeyError as exc:  # unknown qrel_id / run_ref from the service
        return _error(rid, _exc_message(exc), "not_found")
    except (TypeError, ValueError) as exc:
        return _error(rid, _exc_message(exc), "invalid")
    except Exception as exc:  # noqa: BLE001 — protocol errors go to the client
        return _error(rid, f"{type(exc).__name__}: {exc}", "internal")
    return {"id": rid, "ok": True, "result": result}


def _decode(line: str) -> Tuple[Optional[dict], Optional[dict]]:
    """Parse one request line → ``(request, None)`` or ``(None, error)``."""
    try:
        req = json.loads(line)
        if not isinstance(req, dict):
            raise ValueError("request must be a JSON object")
    except ValueError as exc:
        return None, _error(None, f"bad request line: {exc}", "bad_request")
    return req, None


async def handle_line(service: EvaluationService, line: str) -> str:
    """One protocol line in, one JSON response line out."""
    req, err = _decode(line)
    if err is not None:
        return json.dumps(err)
    return json.dumps(await handle_request(service, req))


def _oversized_error(frame: OversizedFrame) -> dict:
    return _error(
        None,
        f"request line exceeds the frame limit ({frame.limit} bytes); "
        f"raise --max-frame-mb or split the payload", "frame_too_large")


# -- TCP ---------------------------------------------------------------------


async def serve_protocol(handler, host: str = "127.0.0.1", port: int = 0,
                         *, limit: int = DEFAULT_FRAME_LIMIT,
                         auth_token: Optional[str] = None,
                         rate_limit: Optional[float] = None,
                         burst: Optional[float] = None):
    """TCP JSON-lines listener around an arbitrary async request handler.

    The connection machinery — chunked framing with ``frame_too_large``
    *responses* for oversized lines, per-connection auth interception,
    token-bucket read throttling, one task per request line so slow
    requests never block the reader, write-lock-serialized responses,
    graceful teardown — is identical for the evaluation front-end
    (:func:`serve_tcp`) and the cluster router
    (:mod:`repro.serve.cluster`); only what *handles* a decoded request
    differs.  ``handler(req, raw)`` receives the parsed request object and
    the raw frame bytes, and returns either a response ``dict`` (JSON
    encoded here) or pre-encoded response ``bytes`` — one JSON object, no
    newline — written verbatim (the router's fan-out path returns worker
    response frames untouched to skip a decode/encode round trip).
    ``handler`` must never raise.
    """

    async def client(reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        wlock = asyncio.Lock()
        tasks = set()
        authed = auth_token is None
        bucket = (TokenBucket(rate_limit, burst)
                  if rate_limit is not None else None)

        async def send(payload) -> None:
            body = (payload if isinstance(payload, bytes)
                    else json.dumps(payload).encode())
            try:
                async with wlock:
                    writer.write(body + b"\n")
                    await writer.drain()
            except (ConnectionError, OSError):
                # client went away before reading its response — the
                # evaluation already happened; nothing useful to raise
                # (an unretrieved task exception would just spam stderr)
                pass

        async def one(raw: bytes) -> None:
            nonlocal authed
            req, err = _decode(raw.decode("utf-8", "replace"))
            if err is not None:
                await send(err)
                return
            if auth_token is not None and req.get("op") == "auth":
                if "token" not in req:  # same code as _check_request gives
                    await send(_error(req.get("id"),
                                      "op 'auth' requires field 'token'",
                                      "missing_field"))
                    return
                ok = hmac.compare_digest(str(req["token"]), auth_token)
                # `authed` flips BEFORE this task's first await: requests
                # pipelined right behind a good auth line see it set.
                authed = authed or ok
                await send({"id": req.get("id"), "ok": True,
                            "result": {"authenticated": True}} if ok else
                           _error(req.get("id"), "bad auth token",
                                  "bad_auth"))
                return
            if not authed:
                await send(_error(
                    req.get("id"),
                    "authentication required: send "
                    '{"op": "auth", "token": ...} first', "auth_required"))
                return
            await send(await handler(req, raw))

        try:
            async for raw in iter_frames(reader, limit):
                if isinstance(raw, OversizedFrame):
                    await send(_oversized_error(raw))
                    continue
                if not raw.strip():
                    continue
                if bucket is not None:
                    # throttle by delaying the READ of further requests:
                    # pipelined floods smear out at `rate_limit` req/s
                    await bucket.acquire()
                t = asyncio.get_running_loop().create_task(one(raw))
                tasks.add(t)
                t.add_done_callback(tasks.discard)
        except (ConnectionError, OSError, asyncio.IncompleteReadError):
            pass  # peer vanished mid-line; no one left to tell
        except asyncio.CancelledError:
            raise
        except Exception as exc:  # noqa: BLE001 — reader bug: answer, then close
            await send(_error(None,
                              f"connection error: {type(exc).__name__}: "
                              f"{exc}", "internal"))
        finally:
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    return await asyncio.start_server(client, host, port, limit=limit)


async def serve_tcp(service: EvaluationService, host: str = "127.0.0.1",
                    port: int = 0, *, limit: int = DEFAULT_FRAME_LIMIT,
                    auth_token: Optional[str] = None,
                    rate_limit: Optional[float] = None,
                    burst: Optional[float] = None):
    """Start the TCP front-end; returns the ``asyncio`` server object.

    Each connection is a JSON-lines stream.  Every request line becomes its
    own task, so slow evaluations never block the connection's reader — and
    concurrent requests (same or different connections) coalesce in the
    service's micro-batcher.  Pass ``port=0`` for an ephemeral port
    (``server.sockets[0].getsockname()[1]``).

    ``limit`` bounds the request line length (default 64 MiB; oversized
    lines get a ``frame_too_large`` error response, not a dead socket).
    ``auth_token`` requires each connection to send ``{"op": "auth",
    "token": ...}`` before anything else; ``rate_limit``/``burst`` give
    each connection a token bucket whose exhaustion *delays* reads.
    """

    async def handler(req: dict, raw: bytes) -> dict:
        return await handle_request(service, req)

    return await serve_protocol(handler, host, port, limit=limit,
                                auth_token=auth_token,
                                rate_limit=rate_limit, burst=burst)


# -- stdio -------------------------------------------------------------------


async def serve_stdio(service: EvaluationService, in_stream=None,
                      out_stream=None, *,
                      limit: int = DEFAULT_FRAME_LIMIT) -> None:
    """JSON-lines over stdin/stdout until EOF (one process per client).

    stdio is a trusted local transport: no auth, no rate limit.  ``limit``
    still applies (oversized lines answer ``frame_too_large``) so both
    front-ends enforce the same frame contract.
    """
    loop = asyncio.get_running_loop()
    in_stream = sys.stdin if in_stream is None else in_stream
    out_stream = sys.stdout if out_stream is None else out_stream
    wlock = asyncio.Lock()
    tasks = set()

    async def emit(resp: str) -> None:
        async with wlock:
            out_stream.write(resp + "\n")
            out_stream.flush()

    async def one(line: str) -> None:
        await emit(await handle_line(service, line))

    while True:
        line = await loop.run_in_executor(None, in_stream.readline)
        if not line:
            break
        if not line.strip():
            continue
        body = line[:-1] if line.endswith("\n") else line
        # the limit is in BYTES, matching the TCP framing exactly
        nbytes = len(body) if body.isascii() else len(body.encode("utf-8"))
        if nbytes > limit:
            await emit(json.dumps(_oversized_error(
                OversizedFrame(nbytes, limit))))
            continue
        t = loop.create_task(one(line))
        tasks.add(t)
        t.add_done_callback(tasks.discard)
    if tasks:
        await asyncio.gather(*tasks, return_exceptions=True)
    await service.drain()


# -- entry point -------------------------------------------------------------


def _parse_hostport(spec: str) -> Tuple[str, int]:
    host, _, port = spec.rpartition(":")
    return host or "127.0.0.1", int(port)


def build_service(args) -> EvaluationService:
    """Service + optional default collection from parsed CLI args."""
    from repro import cli
    from repro.core import trec

    service = EvaluationService(
        max_collections=args.max_collections,
        window=args.window_ms / 1e3, max_batch=args.max_batch,
        max_pending=args.max_pending, backend=args.backend)
    if args.qrel:
        info = service.register_qrel(
            args.qrel_id, trec.load_qrel(args.qrel),
            measures=cli.resolve_measures(args.measures),
            relevance_level=args.level,
            judged_docs_only=args.judged_docs_only)
        print(f"registered qrel {info['qrel_id']!r}: "
              f"{info['n_queries']} queries, backend={info['backend']}",
              file=sys.stderr, flush=True)
    return service


def main(argv: Optional[Sequence[str]] = None) -> int:
    from repro import cli

    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Async evaluation service speaking JSON-lines over "
                    "stdio (default) or TCP.")
    ap.add_argument("--tcp", metavar="HOST:PORT",
                    help="serve TCP instead of stdio (port 0 = ephemeral)")
    ap.add_argument("--qrel", metavar="PATH",
                    help="pre-register this TREC qrel file at startup")
    ap.add_argument("--qrel-id", default="default", metavar="ID",
                    help="collection id for --qrel (default: 'default')")
    cli.add_measure_args(ap)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "single", "sharded"),
                    help="evaluation backend (auto: sharded iff >1 device)")
    ap.add_argument("--window-ms", type=float, default=2.0, metavar="MS",
                    help="coalescing window in milliseconds (default 2)")
    ap.add_argument("--max-batch", type=int, default=64, metavar="N",
                    help="flush a window early at N pending requests")
    ap.add_argument("--max-collections", type=int, default=8, metavar="N",
                    help="LRU capacity for resident collections")
    ap.add_argument("--max-pending", type=int, default=256, metavar="N",
                    help="in-flight request cap (backpressure)")
    ap.add_argument("--max-frame-mb", type=float,
                    default=DEFAULT_FRAME_LIMIT / 2**20, metavar="MB",
                    help="request line length limit in MiB (default 64; "
                         "oversized lines get an error response)")
    ap.add_argument("--auth-token", default=None, metavar="TOKEN",
                    help="require TCP connections to authenticate via "
                         "{'op': 'auth', 'token': TOKEN} before anything "
                         "else (stdio is trusted)")
    ap.add_argument("--rate-limit", type=float, default=None, metavar="N",
                    help="per-connection token-bucket budget in requests/s "
                         "(TCP only; excess requests are delayed)")
    ap.add_argument("--burst", type=float, default=None, metavar="N",
                    help="token-bucket burst capacity "
                         "(default: max(1, rate))")
    args = ap.parse_args(argv)
    limit = max(1, int(args.max_frame_mb * 2**20))

    async def run() -> None:
        service = build_service(args)
        if args.tcp:
            host, port = _parse_hostport(args.tcp)
            server = await serve_tcp(
                service, host, port, limit=limit,
                auth_token=args.auth_token, rate_limit=args.rate_limit,
                burst=args.burst)
            addr = server.sockets[0].getsockname()
            print(f"serving on {addr[0]}:{addr[1]}", file=sys.stderr,
                  flush=True)
            stop = asyncio.Event()
            loop = asyncio.get_running_loop()
            for sig in (signal.SIGINT, signal.SIGTERM):
                try:
                    loop.add_signal_handler(sig, stop.set)
                except (NotImplementedError, RuntimeError):
                    pass  # platform without signal handlers (Windows loop)
            try:
                await stop.wait()
            finally:
                # graceful drain: stop accepting, give request lines already
                # read a beat to enter the service, finish in-flight batches
                server.close()
                await server.wait_closed()
                await asyncio.sleep(0.05)
                await service.drain()
                # then let handler tasks finish WRITING those responses
                # (3.10's wait_closed doesn't wait for handlers; bounded,
                # since connected-but-idle clients keep handlers alive)
                others = [t for t in asyncio.all_tasks()
                          if t is not asyncio.current_task()]
                if others:
                    await asyncio.wait(others, timeout=1.0)
                print("drained; exiting", file=sys.stderr, flush=True)
        else:
            await serve_stdio(service, limit=limit)

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
