"""The asyncio evaluation service: collections, coalescing, backpressure.

:class:`EvaluationService` turns the library's session API into serving
infrastructure.  The request lifecycle (documented end to end in
``docs/SERVING.md``):

1. **register** — ``register_qrel`` interns a qrel once into a
   :class:`repro.core.RelevanceEvaluator` held in a bounded LRU cache
   (:mod:`repro.serve.cache`); registering more collections than
   ``max_collections`` evicts the least-recently-used one.
2. **prepare** — each ``evaluate`` request is tokenized against the cached
   vocabulary into a :class:`repro.core.RunBuffer` (dict run, flat token
   payload, or a pre-registered run re-scored via ``run_ref`` + fresh
   ``scores`` — the zero-string-work hot path).
3. **coalesce** — concurrent requests for the same collection are
   micro-batched (:mod:`repro.serve.batcher`): everything arriving within
   ``window`` seconds (or until ``max_batch``) becomes ONE backend
   ``evaluate_buffers`` call on an executor thread.
4. **respond** — per-query rows split back per request; every response
   carries the pytrec_eval-style per-query mapping plus trec_eval's summary
   aggregates (geometric-mean measures exponentiated).

Backpressure: at most ``max_pending`` requests may be in flight; beyond
that, ``evaluate`` awaits a semaphore slot, so socket clients see their
submissions delayed rather than the service growing an unbounded queue.

Backend selection: per collection, ``"single"`` (the in-process evaluator),
``"sharded"`` (:class:`repro.distributed.ShardedEvaluator` over the shared
device mesh), or ``"auto"`` (sharded exactly when >1 device is visible).
Coalescing itself never changes values (either backend returns results
bit-identical to its own per-request calls); between the two backends the
usual fused-kernel caveat applies — exact on integer-representable
cumulative sums, ~1 ulp on arbitrary float DCG sums (see
``docs/ARCHITECTURE.md``).
"""

from __future__ import annotations

import asyncio
from typing import (Dict, List, Mapping, NamedTuple, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core import RelevanceEvaluator, aggregate_results
from repro.core.evaluator import RunBuffer
from repro.core.sweep import common_qids
from repro.serve.batcher import MicroBatcher
from repro.serve.cache import LRUCache


class ServeResult(NamedTuple):
    """One request's evaluation: per-query values + summary aggregates."""

    per_query: Dict[str, Dict[str, float]]
    aggregates: Dict[str, float]


class _Collection:
    """One registered qrel: its evaluator, backend, and named run buffers."""

    __slots__ = ("qrel_id", "evaluator", "backend", "runs", "_sharded")

    def __init__(self, qrel_id: str, evaluator: RelevanceEvaluator,
                 backend: str):
        self.qrel_id = qrel_id
        self.evaluator = evaluator
        self.backend = backend
        self.runs: Dict[str, RunBuffer] = {}
        self._sharded = None

    @property
    def sharded(self):
        if self._sharded is None:
            from repro.distributed.sharded_evaluator import ShardedEvaluator

            self._sharded = ShardedEvaluator(self.evaluator)
        return self._sharded

    def release(self) -> None:
        """Drop everything reachable only through this collection.

        Called by the service's cache ``on_evict`` hook — on LRU eviction
        AND on re-registration of the same ``qrel_id``.  Registered run
        buffers and the lazily built sharded evaluator (which pins a
        compiled dispatch closure plus device-resident qrel slabs) are the
        heavyweight references; clearing them here means a displaced
        collection's memory is reclaimable as soon as in-flight requests
        holding it finish, not whenever the GC finds the cycle.
        """
        self.runs.clear()
        self._sharded = None


class EvaluationService:
    """Async evaluation over cached collections with request coalescing.

    Single-event-loop by design (create it inside the loop that serves).
    Collection registration is synchronous (the string work happens in the
    caller); ``evaluate`` is a coroutine resolving to a
    :class:`ServeResult`.

    >>> import asyncio
    >>> from repro.serve import EvaluationService
    >>> async def demo():
    ...     svc = EvaluationService(window=0.005)
    ...     svc.register_qrel('web', {'q1': {'d1': 1, 'd2': 0}}, ('map',))
    ...     a, b = await asyncio.gather(
    ...         svc.evaluate('web', run={'q1': {'d1': 9.0, 'd2': 1.0}}),
    ...         svc.evaluate('web', run={'q1': {'d1': 0.0, 'd2': 1.0}}))
    ...     return (a.per_query['q1']['map'], b.per_query['q1']['map'],
    ...             svc.stats()['backend_calls'])
    >>> asyncio.run(demo())  # two concurrent requests, ONE backend call
    (1.0, 0.5, 1)
    """

    def __init__(self, *, max_collections: int = 8, window: float = 0.002,
                 max_batch: int = 64, max_pending: int = 256,
                 backend: str = "auto"):
        from repro.distributed.sharded_evaluator import select_backend

        self._select_backend = select_backend
        self.default_backend = backend
        self._collections = LRUCache(max_collections,
                                     on_evict=self._release_collection)
        self._released = 0  # collections displaced (evicted or replaced)
        self._batcher = MicroBatcher(self._flush, window=window,
                                     max_batch=max_batch)
        self.max_pending = int(max_pending)
        self._sem = asyncio.Semaphore(self.max_pending)
        self._active = 0  # evaluate() coroutines between entry and exit
        self._stats = {"requests": 0, "backend_calls": 0, "in_flight": 0,
                       "peak_in_flight": 0}

    # -- registration ---------------------------------------------------------

    def register_qrel(self, qrel_id: str, qrel, measures=None,
                      relevance_level: float = 1,
                      backend: Optional[str] = None,
                      judged_docs_only: bool = False) -> Dict[str, object]:
        """Intern a qrel into a cached evaluator; returns collection info.

        ``measures`` defaults to every supported family and accepts either
        dialect (``"map"``/``"AP"``, ``"ndcg_cut_10"``/``"nDCG@10"``).
        ``relevance_level`` accepts int or float exactly like the CLI's
        ``-l`` flag — the single conversion to float happens inside
        :class:`RelevanceEvaluator`.  ``backend`` overrides the service
        default for this collection (``auto``/``single``/``sharded``);
        ``judged_docs_only`` mirrors trec_eval's ``-J``.  Re-registering a
        ``qrel_id`` replaces the collection (and drops its registered runs).
        """
        from repro.core import supported_measures

        resolved = self._select_backend(backend or self.default_backend)
        ev = RelevanceEvaluator(qrel, measures or supported_measures,
                                relevance_level=relevance_level,
                                judged_docs_only=judged_docs_only)
        self._collections.put(qrel_id, _Collection(qrel_id, ev, resolved))
        return {"qrel_id": qrel_id, "n_queries": len(ev._qrel),
                "vocab_size": int(len(ev.vocab)), "backend": resolved,
                "relevance_level": ev.relevance_level,
                "judged_docs_only": ev.judged_docs_only,
                "measure_keys": list(ev.measure_keys)}

    def register_run(self, qrel_id: str, run_id: str, run=None,
                     tokens=None) -> Dict[str, object]:
        """Tokenize a run once and pin it under ``run_id`` for re-scoring.

        Subsequent ``evaluate(qrel_id, run_ref=run_id, scores=[...])`` calls
        skip ALL string work — the serving analogue of the session API's
        ``RunBuffer`` contract.
        """
        col = self._require(qrel_id)
        buf = self._prepare(col, run=run, tokens=tokens, run_ref=None,
                            scores=None, allow_unscored=True)
        col.runs[run_id] = buf
        return {"qrel_id": qrel_id, "run_id": run_id,
                "n_queries": len(buf), "n_docs": int(buf.qidx.shape[0])}

    def drop_qrel(self, qrel_id: str) -> bool:
        """Explicitly release a collection (True if it was resident)."""
        col = self._collections.pop(qrel_id)
        if col is None:
            return False
        self._release_collection(qrel_id, col)
        return True

    def _release_collection(self, qrel_id: str, col: _Collection) -> None:
        """Cache ``on_evict`` hook: a collection left the resident set.

        Fires for LRU eviction, for replacement via re-registration of the
        same ``qrel_id``, and for explicit ``drop_qrel``.  Without this the
        displaced collection's run buffers and sharded dispatch stayed
        strongly referenced by whatever still pointed at the old object —
        the slow leak this hook exists to close.
        """
        self._released += 1
        col.release()

    # -- evaluation -----------------------------------------------------------

    async def evaluate(self, qrel_id: str, run=None, tokens=None,
                       run_ref: Optional[str] = None,
                       scores=None) -> ServeResult:
        """Evaluate one request; coalesced with concurrent same-qrel calls.

        Exactly one of ``run`` (dict ``{qid: {docno: score}}``), ``tokens``
        (a ``{"qids", "counts", "tokens", "scores"}`` payload for
        ``buffer_from_tokens``), or ``run_ref`` (a ``register_run`` name)
        selects the documents; ``scores`` optionally replaces the scores
        (required with ``run_ref`` unless the registered run carried its
        own).
        """
        col = self._require(qrel_id)
        self._stats["requests"] += 1  # counted at arrival, before any await
        self._active += 1
        try:
            return await self._evaluate(col, qrel_id, run, tokens, run_ref,
                                        scores)
        finally:
            self._active -= 1

    async def _evaluate(self, col: "_Collection", qrel_id: str, run, tokens,
                        run_ref, scores) -> ServeResult:
        if run is not None:
            # Dict-run tokenization (~100ms at Q=1000×D=1000) runs on an
            # executor thread so it never stalls the event loop — other
            # connections keep reading and coalescing window timers keep
            # firing.  Safe: the evaluator is immutable after construction.
            # The tokens/run_ref payloads stay on-loop: their preparation
            # is a bounds check plus at most one float32 copy.
            buf = await asyncio.to_thread(
                self._prepare, col, run=run, tokens=tokens, run_ref=run_ref,
                scores=scores, allow_unscored=False)
        else:
            buf = self._prepare(col, run=run, tokens=tokens, run_ref=run_ref,
                                scores=scores, allow_unscored=False)
        async with self._sem:
            n = self._stats["in_flight"] = self._stats["in_flight"] + 1
            self._stats["peak_in_flight"] = max(
                self._stats["peak_in_flight"], n)
            try:
                return await self._batcher.submit(qrel_id, (col, buf))
            finally:
                self._stats["in_flight"] -= 1

    # -- statistical comparison -----------------------------------------------

    async def compare(self, qrel_id: str, runs=None,
                      run_refs: Optional[Sequence[str]] = None, *,
                      measure: str = "map", tests: Sequence[str] = ("t",),
                      n_permutations: int = 2000, seed: int = 0,
                      alpha: float = 0.05,
                      run_names: Optional[Sequence[str]] = None
                      ) -> Dict[str, object]:
        """Paired significance tests across K >= 2 runs on one collection.

        Exactly one of ``runs`` (a ``{name: run}`` mapping or a sequence of
        dict runs, aligned to their common judged query set) or ``run_refs``
        (names from :meth:`register_run` — the buffers must cover one shared
        qid list and carry scores) selects the systems.  The K per-run
        evaluations go through the SAME micro-batcher as ``evaluate``
        requests, so one ``compare`` typically costs one coalesced backend
        call; the K×K statistics (:mod:`repro.stats`) then run on an
        executor thread.

        Returns a JSON-friendly bundle: ``run_names``, ``measure``,
        ``qids``, per-run ``means``, the ``t`` / ``p`` / ``p_holm`` /
        ``p_bonferroni`` matrices (plus ``p_permutation*`` when
        ``"permutation"`` is in ``tests``), and ``significant`` —
        ``p_holm < alpha`` off the diagonal.
        """
        col = self._require(qrel_id)
        self._stats["requests"] += 1
        self._active += 1
        try:
            return await self._compare(col, qrel_id, runs, run_refs, measure,
                                       tests, n_permutations, seed, alpha,
                                       run_names)
        finally:
            self._active -= 1

    async def _compare(self, col: "_Collection", qrel_id: str, runs,
                       run_refs, measure, tests, n_permutations, seed,
                       alpha, run_names) -> Dict[str, object]:
        from repro.core import registry

        ev = col.evaluator
        measure = str(measure)
        if measure not in ev.measure_keys:
            # either dialect; a malformed string raises MeasureError (a
            # ValueError → wire code "invalid") naming the offending input
            measure = registry.canonical_key(measure)[0]
        if measure not in ev.measure_keys:
            raise ValueError(
                f"measure {registry.both_dialects(measure)} is not computed "
                f"by collection {qrel_id!r} "
                f"(have: {list(ev.measure_keys)})")
        given = [n for n, v in (("runs", runs), ("run_refs", run_refs))
                 if v is not None]
        if len(given) != 1:
            raise ValueError(
                f"need exactly one of runs/run_refs, got {given or 'none'}")
        if runs is not None:
            if isinstance(runs, Mapping):
                if run_names is not None:
                    raise ValueError(
                        "run_names conflicts with a {name: run} mapping")
                run_names = list(runs)
                runs = list(runs.values())
            else:
                runs = list(runs)
            if len(runs) < 2:
                raise ValueError(f"compare needs >= 2 runs, got {len(runs)}")
            if run_names is None:
                run_names = [f"run_{i}" for i in range(len(runs))]
            # dict-run tokenization off-loop, like evaluate's dict path
            bufs = await asyncio.to_thread(self._aligned_buffers, ev, runs)
        else:
            refs = [str(r) for r in run_refs]
            if len(refs) < 2:
                raise ValueError(
                    f"compare needs >= 2 run_refs, got {len(refs)}")
            missing = [r for r in refs if r not in col.runs]
            if missing:
                raise KeyError(
                    f"unknown run_ref {missing[0]!r} for qrel "
                    f"{col.qrel_id!r} (registered: {sorted(col.runs)})")
            bufs = [col.runs[r] for r in refs]
            base = list(bufs[0].qids)
            for r, buf in zip(refs, bufs):
                if buf.scores is None:
                    raise ValueError(
                        f"registered run {r!r} has no scores; re-register "
                        "with scores or pass dict runs")
                if list(buf.qids) != base:
                    raise ValueError(
                        f"run_ref {r!r} covers different queries than "
                        f"{refs[0]!r}; compared runs must share one qid "
                        "list")
            if run_names is None:
                run_names = refs
        run_names = [str(n) for n in run_names]
        if len(run_names) != len(bufs):
            raise ValueError(
                f"{len(run_names)} run_names for {len(bufs)} runs")
        qids = list(bufs[0].qids)
        if len(qids) < 2:
            raise ValueError(
                f"paired tests need >= 2 common judged queries, got "
                f"{len(qids)}")

        # ONE backpressure slot for the whole request: the K coalesced
        # submissions resolve together, and taking K slots could deadlock
        # compare requests against max_pending.
        async with self._sem:
            n = self._stats["in_flight"] = self._stats["in_flight"] + 1
            self._stats["peak_in_flight"] = max(
                self._stats["peak_in_flight"], n)
            try:
                results = await asyncio.gather(
                    *(self._batcher.submit(qrel_id, (col, buf))
                      for buf in bufs))
            finally:
                self._stats["in_flight"] -= 1

        x = np.array([[res.per_query[q][measure] for q in qids]
                      for res in results], dtype=np.float32)
        report = await asyncio.to_thread(self._significance, x, tuple(tests),
                                         int(n_permutations), int(seed))
        out: Dict[str, object] = {
            "run_names": run_names, "measure": measure, "qids": qids,
            "n_queries": len(qids), "alpha": float(alpha),
        }
        out.update({k: np.asarray(v, dtype=float).tolist()
                    for k, v in report.items()})
        k = len(run_names)
        holm = np.asarray(report["p_holm"])
        sig = (holm < float(alpha)) & ~np.eye(k, dtype=bool)
        out["significant"] = sig.tolist()
        return out

    @staticmethod
    def _aligned_buffers(ev: RelevanceEvaluator, runs) -> List[RunBuffer]:
        """Tokenize dict runs on their common judged query set."""
        qids = common_qids(ev._qid_index, runs)
        if not qids:
            raise ValueError("no common judged queries across the runs")
        return [ev.tokenize_run({q: r[q] for q in qids}) for r in runs]

    @staticmethod
    def _significance(x: np.ndarray, tests: Tuple[str, ...],
                      n_permutations: int, seed: int) -> Dict[str, object]:
        from repro import stats

        return stats.significance_report(x, tests=tests,
                                         n_permutations=n_permutations,
                                         seed=seed)

    async def _flush(self, qrel_id: str,
                     items: List[Tuple[_Collection, RunBuffer]]):
        """One coalesced backend call per collection generation."""
        out: List[Optional[ServeResult]] = [None] * len(items)
        groups: Dict[int, List[int]] = {}
        for i, (col, _) in enumerate(items):
            groups.setdefault(id(col), []).append(i)
        for idxs in groups.values():
            col = items[idxs[0]][0]
            bufs = [items[i][1] for i in idxs]
            self._stats["backend_calls"] += 1
            if col.backend == "sharded":
                results = await asyncio.to_thread(
                    col.sharded.evaluate_buffers, bufs)
                packed = [ServeResult(r.per_query, r.aggregates)
                          for r in results]
            else:
                tables = await asyncio.to_thread(
                    col.evaluator.evaluate_buffers, bufs)
                packed = [ServeResult(pq, aggregate_results(pq))
                          for pq in tables]
            for i, res in zip(idxs, packed):
                out[i] = res
        return out

    async def drain(self) -> None:
        """Resolve once every accepted request has been answered.

        "Accepted" spans the whole ``evaluate`` lifecycle — tokenization on
        an executor thread, waiting for a backpressure slot, sitting in a
        coalescing window, and the backend flush itself.  Front-ends call
        this on shutdown so in-flight batches complete before the process
        exits; it does NOT block new submissions, so stop accepting first.
        """
        while self._active or not self._batcher.idle():
            await asyncio.sleep(0.002)

    # -- plumbing -------------------------------------------------------------

    def _require(self, qrel_id: str) -> _Collection:
        col = self._collections.get(qrel_id)
        if col is None:
            raise KeyError(
                f"unknown qrel_id {qrel_id!r}: register_qrel first "
                f"(resident: {sorted(self._collections.keys())})")
        return col

    def _prepare(self, col: _Collection, *, run, tokens, run_ref, scores,
                 allow_unscored: bool) -> RunBuffer:
        given = [name for name, v in
                 (("run", run), ("tokens", tokens), ("run_ref", run_ref))
                 if v is not None]
        if len(given) != 1:
            raise ValueError(
                f"need exactly one of run/tokens/run_ref, got {given or 'none'}")
        ev = col.evaluator
        if run is not None:
            buf = ev.tokenize_run(run)
        elif tokens is not None:
            if not isinstance(tokens, dict):
                raise ValueError("tokens must be a mapping with "
                                 "qids/counts/tokens[/scores]")
            buf = ev.buffer_from_tokens(
                tokens["qids"], tokens["counts"], tokens["tokens"],
                scores=tokens.get("scores"))
        else:
            if run_ref not in col.runs:
                raise KeyError(
                    f"unknown run_ref {run_ref!r} for qrel "
                    f"{col.qrel_id!r} (registered: {sorted(col.runs)})")
            buf = col.runs[run_ref]
        if scores is not None:
            buf = buf.with_scores(scores)
        if buf.scores is None and not allow_unscored:
            raise ValueError("request has no scores: the run/tokens payload "
                             "carried none and no scores= were given")
        return buf

    def stats(self) -> Dict[str, object]:
        """Counters for monitoring and the protocol's ``stats`` op."""
        out = dict(self._stats)
        out["flushes"] = self._batcher.flushes
        out["coalesced"] = self._batcher.submitted - self._batcher.flushes
        out["window"] = self._batcher.window
        out["max_batch"] = self._batcher.max_batch
        out["max_pending"] = self.max_pending
        out["cache"] = self._collections.stats()
        out["released_collections"] = self._released
        out["collections"] = sorted(self._collections.keys())
        return out
