"""A real TCP evaluation server on a background thread, for sync drivers.

Tests, benchmarks, and the ``client-smoke`` verify step all need the same
thing: a live socket endpoint speaking the serve protocol while the driving
code stays synchronous.  :class:`ServerThread` boots an event loop on a
daemon thread, creates the :class:`~repro.serve.service.EvaluationService`
*inside* that loop (the service is single-loop by design), starts the TCP
front-end on an ephemeral port, and tears everything down gracefully —
stop accepting, drain in-flight batches — on :meth:`close`.

    >>> from repro.serve.testing import ServerThread
    >>> with ServerThread() as srv:
    ...     _ = srv.register_qrel("t", {"q1": {"d1": 1}}, ("map",))
    ...     isinstance(srv.port, int)
    True
"""

from __future__ import annotations

import asyncio
import threading
from typing import Optional

from repro.serve.frontend import serve_tcp
from repro.serve.service import EvaluationService


class ServerThread:
    """Run ``EvaluationService`` + ``serve_tcp`` on a private loop thread.

    Keyword arguments split by destination: ``service_kw`` goes to the
    :class:`EvaluationService` constructor, everything else in ``tcp_kw``
    to :func:`serve_tcp` (``limit``, ``auth_token``, ``rate_limit``,
    ``burst``).  The server listens on ``127.0.0.1`` at an ephemeral port
    (:attr:`port`).
    """

    def __init__(self, *, service_kw: Optional[dict] = None, **tcp_kw):
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-serve-thread")
        self._thread.start()

        async def boot():
            service = EvaluationService(**(service_kw or {}))
            server = await serve_tcp(service, "127.0.0.1", 0, **tcp_kw)
            return service, server

        self.service, self._server = self.call(boot(), timeout=30)
        self.host = "127.0.0.1"
        self.port = self._server.sockets[0].getsockname()[1]

    def _run(self) -> None:
        asyncio.set_event_loop(self._loop)
        self._loop.run_forever()

    # -- sync facade ---------------------------------------------------------

    def call(self, coro, timeout: float = 60):
        """Run a coroutine on the server loop; block for its result."""
        return asyncio.run_coroutine_threadsafe(coro, self._loop).result(
            timeout)

    def register_qrel(self, *args, **kw) -> dict:
        async def _do():
            return self.service.register_qrel(*args, **kw)
        return self.call(_do())

    def register_run(self, *args, **kw) -> dict:
        async def _do():
            return self.service.register_run(*args, **kw)
        return self.call(_do())

    def stats(self) -> dict:
        async def _do():
            return self.service.stats()
        return self.call(_do())

    def close(self) -> None:
        """Graceful shutdown: stop accepting, drain, stop the loop."""
        if self._thread.is_alive():
            async def _shutdown():
                self._server.close()
                await self._server.wait_closed()
                await self.service.drain()
                # let connection handlers run their finally blocks before
                # the loop stops (3.10's wait_closed doesn't wait for them)
                others = [t for t in asyncio.all_tasks()
                          if t is not asyncio.current_task()]
                if others:
                    await asyncio.wait(others, timeout=1)
            self.call(_shutdown(), timeout=30)
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(10)
        if not self._loop.is_running():
            self._loop.close()

    def __enter__(self) -> "ServerThread":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
