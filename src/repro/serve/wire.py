"""Wire-protocol plumbing shared by the serve front-ends: framing + limits.

The protocol is JSON-lines: one request object per ``\\n``-terminated line.
Nothing here parses JSON — this module is about the *byte* layer that the
seed implementation got wrong: ``asyncio.StreamReader.readline`` enforces a
64 KiB default limit and raises ``ValueError: Separator is found, but chunk
is longer than limit`` on a practically-sized ``register_qrel`` payload
(the paper's Q=1000×D=1000 grid serializes to tens of megabytes), killing
the connection without a response.

:func:`iter_frames` replaces ``readline`` with an explicit chunked scanner:

* complete lines are yielded as ``bytes`` (without the terminator);
* a line longer than ``limit`` yields ONE :class:`OversizedFrame` marker
  the moment the limit is crossed, then the rest of that line is discarded
  quietly until its terminator — so the caller can send a structured
  ``frame_too_large`` error *response* and keep the connection alive;
* a trailing frame without a final newline is yielded at EOF (pipes).

:class:`TokenBucket` is the per-connection rate limiter used by the TCP
front-end: ``await acquire()`` in the reader loop delays reading the next
request once a connection exceeds its budget, which throttles abusive
clients smoothly (delayed responses, never dropped requests) and composes
with request coalescing.  The clock is injectable so tests are exact.

Error *codes* carried by ``ok: false`` responses live here too
(:data:`ERROR_CODES`); :class:`ProtocolError` is how request handlers raise
a violation with a machine-readable code attached.
"""

from __future__ import annotations

import asyncio
import time
from typing import AsyncIterator, Callable, Optional, Union

#: default maximum request/response line length in bytes (64 MiB).  The
#: asyncio default of 64 KiB (2**16) rejected any real qrel registration;
#: this default admits the paper-scale grids with headroom and is plumbed
#: through ``serve_tcp`` / ``serve_stdio`` / ``--max-frame-mb``.
DEFAULT_FRAME_LIMIT = 64 * 1024 * 1024

#: bytes pulled off the transport per read while scanning for newlines
_CHUNK = 1 << 16

#: machine-readable ``code`` values on ``ok: false`` responses.  Clients
#: switch on these (``repro.client`` maps ``auth_*`` to ``AuthError``);
#: the human-readable ``error`` string is for humans and NOT stable.
ERROR_CODES = (
    "bad_request",      # unparseable line / not a JSON object
    "unknown_op",       # op not in the protocol table
    "missing_field",    # a required field for this op is absent
    "invalid",          # field present but unusable (type/value)
    "not_found",        # unknown qrel_id / run_ref
    "auth_required",    # server has a token, connection not authenticated
    "bad_auth",         # auth attempted with the wrong token
    "frame_too_large",  # request line exceeded the frame limit
    "worker_unavailable",  # cluster router: owning worker down, not retried
    "deadline_exceeded",  # the request's deadline_ms budget ran out first
    "internal",         # anything else — a server-side bug, not the client
)


class ProtocolError(ValueError):
    """A request violated the wire protocol; carries the response code."""

    def __init__(self, message: str, code: str = "invalid"):
        super().__init__(message)
        assert code in ERROR_CODES, code
        self.code = code


class OversizedFrame:
    """Marker yielded by :func:`iter_frames` for a too-long request line.

    ``size`` is the number of bytes seen when the limit was crossed — a
    lower bound on the frame's true length (the rest is still being
    discarded when the marker is yielded).
    """

    __slots__ = ("size", "limit")

    def __init__(self, size: int, limit: int):
        self.size = size
        self.limit = limit

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"OversizedFrame(size={self.size}, limit={self.limit})"


async def iter_frames(reader: asyncio.StreamReader,
                      limit: int = DEFAULT_FRAME_LIMIT,
                      ) -> AsyncIterator[Union[bytes, OversizedFrame]]:
    """Yield newline-delimited frames from ``reader``, bounded by ``limit``.

    Unlike ``reader.readline()`` this never raises on a long line: the
    oversized frame degrades to one :class:`OversizedFrame` marker and the
    stream stays aligned on the next line.  Connection errors propagate.
    """
    buf = bytearray()
    discarding = False  # inside an oversized line, waiting for its newline
    while True:
        chunk = await reader.read(_CHUNK)
        at_eof = not chunk
        buf.extend(chunk)
        while True:
            nl = buf.find(b"\n")
            if nl < 0:
                break
            frame = bytes(buf[:nl])
            del buf[:nl + 1]
            if discarding:
                discarding = False  # tail of the oversized line: drop it
            elif len(frame) > limit:  # whole long line arrived in one read
                yield OversizedFrame(len(frame), limit)
            else:
                yield frame
        if discarding:
            buf.clear()  # still mid-oversized-line: keep discarding
        elif len(buf) > limit:
            yield OversizedFrame(len(buf), limit)
            buf.clear()
            discarding = True
        if at_eof:
            if buf and not discarding:
                yield bytes(buf)  # trailing frame without a newline (pipes)
            return


def split_frames(data: bytes, limit: int = DEFAULT_FRAME_LIMIT):
    """Synchronous sibling of :func:`iter_frames` for durable on-disk logs.

    Yields each complete (newline-terminated) frame as ``bytes``; a frame
    over ``limit`` degrades to one :class:`OversizedFrame` marker exactly
    like the streaming scanner.  Unlike :func:`iter_frames` — whose EOF is
    a *clean* end of a pipe — trailing bytes without a newline mean the
    writer crashed mid-append, so the torn tail is silently dropped and
    replay stops at the last durable record.

    >>> [bytes(f) for f in split_frames(b'{"a":1}\\n{"b":2}\\n{"torn')]
    [b'{"a":1}', b'{"b":2}']
    >>> [f for f in split_frames(b'xxxxx\\nok\\n', limit=3)]
    [OversizedFrame(size=5, limit=3), b'ok']
    """
    start = 0
    while True:
        nl = data.find(b"\n", start)
        if nl < 0:
            return  # torn tail (or clean EOF right after a newline)
        frame = data[start:nl]
        if len(frame) > limit:
            yield OversizedFrame(len(frame), limit)
        else:
            yield frame
        start = nl + 1


class TokenBucket:
    """Classic token-bucket limiter: ``rate`` tokens/s, capacity ``burst``.

    ``acquire()`` reserves one token, sleeping exactly as long as the
    reservation requires; reservations queue FIFO by letting the token
    count go negative, so a burst beyond capacity spreads out at ``rate``
    rather than stampeding when the bucket refills.

    >>> b = TokenBucket(rate=10, burst=2, clock=lambda: 0.0)
    >>> [round(b.reserve(), 2) for _ in range(4)]  # 2 free, then 10/s
    [0.0, 0.0, 0.1, 0.2]
    """

    def __init__(self, rate: float, burst: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        if rate <= 0:
            raise ValueError(f"rate must be > 0 tokens/s, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst) if burst is not None else max(1.0, rate)
        if self.burst < 1.0:
            raise ValueError(f"burst must be >= 1 token, got {self.burst}")
        self._clock = clock or time.monotonic
        self._tokens = self.burst
        self._last = self._clock()

    def reserve(self) -> float:
        """Take one token; return how long the caller must wait for it."""
        now = self._clock()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._last) * self.rate)
        self._last = now
        self._tokens -= 1.0
        if self._tokens >= 0.0:
            return 0.0
        return -self._tokens / self.rate

    async def acquire(self) -> None:
        """Reserve a token and sleep out the wait (possibly zero)."""
        wait = self.reserve()
        if wait > 0:
            await asyncio.sleep(wait)
