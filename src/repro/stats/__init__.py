"""In-JAX statistical comparison of evaluation sweeps.

The experiment-suite workload (ROADMAP item 3) ends in a question no single
measure value answers: *which of these K systems are actually different?*
This package computes the standard IR answers — paired t-tests and paired
(sign-flip) permutation tests over per-query scores — for **all K×K system
pairs at once**, as vectorized JAX reductions.  There is no scipy loop per
pair: one ``[K, Q]`` score matrix in, dense ``[K, K]`` statistic/p-value
matrices out, with Bonferroni and Holm multiple-comparison corrections
applied to the p-value matrix the same way.

Layering: this package is pure array → array statistics.  It imports
nothing from :mod:`repro.core` — the sweep evaluation that *produces* the
``[K, Q]`` matrices lives in :func:`repro.core.sweep.evaluate_sweep`, the
serving surface in :mod:`repro.serve` (the ``compare`` op), and the CLI in
``python -m repro.compare``.

>>> import numpy as np
>>> from repro import stats
>>> x = np.array([[0.6, 0.7, 0.5, 0.8],
...               [0.5, 0.5, 0.4, 0.6],
...               [0.1, 0.2, 0.1, 0.2]], dtype=np.float32)
>>> t, p = stats.paired_t_matrix(x)
>>> t.shape, float(t[0, 0]), bool(p[0, 2] < p[0, 1])  # zero diag; 0 vs 2 clearer
((3, 3), 0.0, True)

Every statistic is pinned to an independent reference in
``tests/test_stats.py``: hand-computed fixtures (closed-form Student-t tail
probabilities at small df), scipy cross-checks, and exact-enumeration
bounds for the Monte Carlo permutation p-values.
"""

from repro.stats.corrections import bonferroni_matrix, holm_matrix
from repro.stats.significance import (EXACT_ENUMERATION_MAX_Q,
                                      paired_diff_means, paired_t_matrix,
                                      paired_permutation_exact,
                                      paired_permutation_matrix,
                                      significance_report)

__all__ = [
    "EXACT_ENUMERATION_MAX_Q",
    "paired_diff_means",
    "paired_t_matrix",
    "paired_permutation_matrix",
    "paired_permutation_exact",
    "significance_report",
    "bonferroni_matrix",
    "holm_matrix",
]
