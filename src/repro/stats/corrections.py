"""Multiple-comparison corrections over symmetric K×K p-value matrices.

A K-system sweep tests ``m = K·(K-1)/2`` hypotheses at once (one per
unordered pair), so the raw per-pair p-values overstate significance.
Both corrections here operate directly on the ``[K, K]`` matrix layout
produced by :mod:`repro.stats.significance`: only the strict upper
triangle is treated as the family of hypotheses, the result is mirrored
back to a symmetric matrix, and the diagonal (self-comparisons, p = 1) is
passed through untouched.

* :func:`bonferroni_matrix` — ``min(p · m, 1)``: simple, strongest
  control, no ordering between hypotheses.
* :func:`holm_matrix` — the step-down refinement: the s-th smallest
  p-value is scaled by ``(m - s)`` and a running max enforces
  monotonicity.  Uniformly at least as powerful as Bonferroni
  (``holm <= bonferroni`` elementwise, a property test in
  ``tests/test_stats.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _as_square(p) -> jnp.ndarray:
    p = jnp.asarray(p, jnp.float32)
    if p.ndim != 2 or p.shape[0] != p.shape[1]:
        raise ValueError(f"expected a square [K, K] p-value matrix, "
                         f"got shape {p.shape}")
    return p


def bonferroni_matrix(p):
    """Bonferroni-correct a symmetric ``[K, K]`` p-value matrix.

    Each off-diagonal entry becomes ``min(p * m, 1)`` with
    ``m = K·(K-1)/2`` tested pairs; the diagonal is returned unchanged.

    >>> import numpy as np
    >>> p = np.array([[1.0, 0.01, 0.4], [0.01, 1.0, 0.5], [0.4, 0.5, 1.0]])
    >>> np.asarray(bonferroni_matrix(p), float).round(2).tolist()
    [[1.0, 0.03, 1.0], [0.03, 1.0, 1.0], [1.0, 1.0, 1.0]]
    """
    p = _as_square(p)
    k = p.shape[0]
    m = k * (k - 1) // 2
    if m == 0:
        return p
    eye = jnp.eye(k, dtype=bool)
    return jnp.where(eye, p, jnp.minimum(p * m, 1.0))


def holm_matrix(p):
    """Holm step-down correction of a symmetric ``[K, K]`` p-value matrix.

    The strict upper triangle is sorted ascending; the s-th smallest raw
    p-value (0-based) is multiplied by ``(m - s)``, a cumulative max makes
    the adjusted sequence non-decreasing, everything is clipped at 1 and
    mirrored back symmetrically.  The diagonal is returned unchanged.

    The classic worked example — raw (0.01, 0.03, 0.04) adjusts to
    (0.03, 0.06, 0.06): the middle value is lifted to keep the sequence
    monotone.

    >>> import numpy as np
    >>> p = np.array([[1.0, 0.01, 0.04], [0.01, 1.0, 0.03], [0.04, 0.03, 1.0]])
    >>> np.asarray(holm_matrix(p), float).round(2).tolist()
    [[1.0, 0.03, 0.06], [0.03, 1.0, 0.06], [0.06, 0.06, 1.0]]
    """
    p = _as_square(p)
    k = p.shape[0]
    if k < 2:
        return p
    iu, ju = np.triu_indices(k, 1)  # static for a given K (jit-safe)
    flat = p[iu, ju]
    m = flat.shape[0]
    order = jnp.argsort(flat)
    scaled = flat[order] * (m - jnp.arange(m, dtype=jnp.float32))
    adjusted = jnp.minimum(jax.lax.cummax(scaled), 1.0)
    # undo the sort, then scatter back into both triangles
    restored = jnp.zeros_like(flat).at[order].set(adjusted)
    out = p.at[iu, ju].set(restored)
    return out.at[ju, iu].set(restored)
