"""Paired significance tests over all K×K system pairs, vectorized in JAX.

Input convention for every function: ``x`` is a ``[K, Q]`` matrix of
per-query scores — row ``i`` is system ``i``'s value of ONE measure on the
same ``Q`` queries (the pairing axis).  Rows must be aligned: column ``q``
is the same query everywhere, which :func:`repro.core.sweep.evaluate_sweep`
guarantees by evaluating every run on a common query list.

All pairwise statistics are computed from the antisymmetric difference
tensor ``d[i, j, q] = x[i, q] - x[j, q]`` with batched reductions — the
K×K loop that a scipy formulation pays per pair collapses into a handful
of XLA ops, which is what makes significance testing over hundreds of
sweep variants a single-digit-millisecond operation
(``benchmarks --only sweep``).

Numerics: inputs are taken as float32 (the measure core's dtype).  The
Student-t tail probability is the regularized incomplete beta function
``I_{df/(df+t²)}(df/2, 1/2)`` via ``jax.scipy.special.betainc`` — within
~2e-7 of scipy's float64 values at fixture scale (``tests/test_stats.py``
pins hand-computed closed forms at df 1 and 3, where the t CDF has exact
arctan expressions).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

#: queries beyond which exact sign-flip enumeration (2^Q patterns) is refused
EXACT_ENUMERATION_MAX_Q = 20

#: relative slack when counting permuted |means| against the observed |mean|
#: — float32 resamples that tie the observed statistic must count as >=
#: (the exact-enumeration tests re-derive the same counts with this rule)
_TIE_RTOL = 1e-6


def _as_kq(x) -> jnp.ndarray:
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"expected a [K, Q] score matrix, got shape {x.shape}")
    if x.shape[1] < 2:
        raise ValueError(
            f"need at least 2 paired queries, got Q={x.shape[1]}")
    return x


def paired_diff_means(x) -> jnp.ndarray:
    """``[K, K]`` matrix of mean per-query differences ``mean_q(x_i - x_j)``.

    Antisymmetric with a zero diagonal; entry ``[i, j] > 0`` means system
    ``i`` beats system ``j`` on average.

    >>> import numpy as np
    >>> m = paired_diff_means(np.array([[1.0, 1.0], [0.0, 0.5]]))
    >>> np.asarray(m).tolist()
    [[0.0, 0.75], [-0.75, 0.0]]
    """
    x = _as_kq(x)
    row = jnp.mean(x, axis=1)
    return row[:, None] - row[None, :]


def _structure(mat, diag, *, anti: bool = False):
    """Enforce exact (anti)symmetry + a fixed diagonal on a [K, K] matrix.

    XLA fusion may evaluate the two broadcast operands of ``a - a.T``-style
    expressions through differently-ordered reductions, leaving ~1e-8 noise
    where the math says exactly 0 — so the structural invariants the tests
    (and corrections) rely on are imposed from the upper triangle.
    """
    upper = jnp.triu(mat, 1)
    eye = jnp.eye(mat.shape[0], dtype=mat.dtype)
    return upper + (-upper.T if anti else upper.T) + diag * eye


@jax.jit
def _t_kernel(x):
    k, q = x.shape
    d = x[:, None, :] - x[None, :, :]  # [K, K, Q] paired differences
    mean = jnp.mean(d, axis=-1)
    var = jnp.sum((d - mean[..., None]) ** 2, axis=-1) / (q - 1)
    se = jnp.sqrt(var / q)
    # Degenerate pairs: se == 0 means every per-query difference is equal.
    # All-zero differences (the diagonal, duplicated systems) get t = 0 /
    # p = 1; a constant non-zero difference is infinitely significant
    # (t = ±inf, p = 0) — matching the scipy.stats.ttest_rel limits.
    t = jnp.where(se > 0, mean / jnp.where(se > 0, se, 1.0),
                  jnp.where(mean == 0, 0.0, jnp.sign(mean) * jnp.inf))
    df = jnp.float32(q - 1)
    tail_x = df / (df + t * t)  # t=0 → 1 → p=1; t=±inf → 0 → p=0
    p = jax.scipy.special.betainc(df / 2.0, 0.5, tail_x)
    return _structure(t, 0.0, anti=True), _structure(p, 1.0)


def paired_t_matrix(x):
    """All-pairs two-sided paired t-test: ``(t, p)``, each ``[K, K]``.

    ``t`` is antisymmetric with a zero diagonal; ``p`` is symmetric with a
    unit diagonal (a system is never significantly different from itself).
    Equivalent to ``scipy.stats.ttest_rel(x[i], x[j])`` for every pair, in
    one batched reduction.

    >>> import numpy as np
    >>> x = np.array([[0.9, 0.8, 0.7, 0.6], [0.1, 0.2, 0.3, 0.4]])
    >>> t, p = paired_t_matrix(x)
    >>> float(t[0, 0]), float(p[0, 0]), bool(abs(t[0, 1]) > 2)
    (0.0, 1.0, True)
    """
    return _t_kernel(_as_kq(x))


@functools.partial(jax.jit, static_argnums=(1,))
def _permutation_kernel(x, n_permutations: int, key):
    k, q = x.shape
    obs = jnp.abs(paired_diff_means(x))  # [K, K]
    signs = jax.random.rademacher(key, (n_permutations, q),
                                  dtype=jnp.float32)
    # Per-pair permuted mean difference = (s·x_i - s·x_j) / Q: computing the
    # [K, P] projections first turns the naive O(K²·P·Q) contraction into
    # O(K·P·Q + K²·P).
    proj = x @ signs.T / q  # [K, P]
    perm = jnp.abs(proj[:, None, :] - proj[None, :, :])  # [K, K, P]
    ge = perm >= obs[..., None] * (1.0 - _TIE_RTOL) - 1e-12
    count = jnp.sum(ge, axis=-1)
    # add-one smoothing: the observed labelling is itself a permutation, so
    # the Monte Carlo p-value is never 0 and never overstates significance
    p = (count + 1.0) / (n_permutations + 1.0)
    return _structure(p, 1.0)


def paired_permutation_matrix(x, n_permutations: int = 2000,
                              key: Optional[jax.Array] = None,
                              seed: int = 0):
    """All-pairs paired (sign-flip) permutation test p-values, ``[K, K]``.

    The null hypothesis for pair ``(i, j)`` is that the per-query
    differences are symmetric around 0; the test statistic is the absolute
    mean difference under ``n_permutations`` random sign flips (one shared
    sign matrix drives every pair, which is what lets the whole K×K grid
    ride a single ``[K, P]`` projection).  Smallest reachable p-value is
    ``1 / (n_permutations + 1)``; the diagonal is exactly 1.

    >>> import numpy as np
    >>> x = np.array([[0.9, 0.8, 0.7, 0.9, 0.8], [0.1, 0.2, 0.3, 0.1, 0.2]])
    >>> p = paired_permutation_matrix(x, n_permutations=500)
    >>> float(p[0, 0]), bool(p[0, 1] < 0.2), bool(p[0, 1] == p[1, 0])
    (1.0, True, True)
    """
    x = _as_kq(x)
    if n_permutations < 1:
        raise ValueError(f"need n_permutations >= 1, got {n_permutations}")
    if key is None:
        key = jax.random.PRNGKey(seed)
    return _permutation_kernel(x, int(n_permutations), key)


@jax.jit
def _exact_permutation_kernel(x):
    k, q = x.shape
    obs = jnp.abs(paired_diff_means(x))
    n = 1 << q
    # all 2^Q sign patterns, bit-decoded: row b is (+1/-1)^Q for bitmask b
    bits = (jnp.arange(n, dtype=jnp.int32)[:, None]
            >> jnp.arange(q, dtype=jnp.int32)[None, :]) & 1
    signs = (bits * 2 - 1).astype(jnp.float32)
    proj = x @ signs.T / q
    perm = jnp.abs(proj[:, None, :] - proj[None, :, :])
    ge = perm >= obs[..., None] * (1.0 - _TIE_RTOL) - 1e-12
    # no smoothing: this IS the full null distribution (the identity
    # pattern is one of the 2^Q, so the count is always >= 1)
    return _structure(jnp.sum(ge, axis=-1) / n, 1.0)


def paired_permutation_exact(x):
    """Exact sign-flip permutation p-values by full 2^Q enumeration.

    Only feasible for tiny query sets (``Q <= 20``); used as the ground
    truth the Monte Carlo :func:`paired_permutation_matrix` is tested
    against.

    >>> import numpy as np
    >>> p = paired_permutation_exact(np.array([[1.0, 2.0], [0.0, 0.0]]))
    >>> np.asarray(p).tolist()  # 4 sign patterns, 2 reach |obs|
    [[1.0, 0.5], [0.5, 1.0]]
    """
    x = _as_kq(x)
    if x.shape[1] > EXACT_ENUMERATION_MAX_Q:
        raise ValueError(
            f"exact enumeration is 2^Q patterns; Q={x.shape[1]} exceeds "
            f"the cap of {EXACT_ENUMERATION_MAX_Q}")
    return _exact_permutation_kernel(x)


def significance_report(x, *, tests: Sequence[str] = ("t",),
                        n_permutations: int = 2000,
                        seed: int = 0) -> Dict[str, np.ndarray]:
    """The full comparison bundle for one ``[K, Q]`` score matrix.

    Returns numpy host arrays (wire- and JSON-friendly):

    * ``means`` — ``[K]`` per-system mean scores;
    * ``diff`` — ``[K, K]`` mean paired differences;
    * ``t``, ``p``, ``p_holm``, ``p_bonferroni`` — the paired t-test and
      its corrected p-value matrices (always present);
    * ``p_permutation``, ``p_permutation_holm``,
      ``p_permutation_bonferroni`` — only when ``"permutation"`` is in
      ``tests``.

    ``tests`` entries must be ``"t"`` or ``"permutation"``; the t-test is
    computed regardless (it is the cheap one that every caller prints).

    >>> import numpy as np
    >>> rep = significance_report(np.array([[1.0, 0.9, 0.8], [0.1, 0.2, 0.3]]))
    >>> sorted(rep)
    ['diff', 'means', 'p', 'p_bonferroni', 'p_holm', 't']
    """
    from repro.stats.corrections import bonferroni_matrix, holm_matrix

    unknown = set(tests) - {"t", "permutation"}
    if unknown:
        raise ValueError(f"unknown significance tests: {sorted(unknown)} "
                         "(expected 't' and/or 'permutation')")
    x = _as_kq(x)
    t, p = paired_t_matrix(x)
    out = {
        "means": np.asarray(jnp.mean(x, axis=1)),
        "diff": np.asarray(paired_diff_means(x)),
        "t": np.asarray(t),
        "p": np.asarray(p),
        "p_holm": np.asarray(holm_matrix(p)),
        "p_bonferroni": np.asarray(bonferroni_matrix(p)),
    }
    if "permutation" in tests:
        pp = paired_permutation_matrix(x, n_permutations=n_permutations,
                                       seed=seed)
        out["p_permutation"] = np.asarray(pp)
        out["p_permutation_holm"] = np.asarray(holm_matrix(pp))
        out["p_permutation_bonferroni"] = np.asarray(bonferroni_matrix(pp))
    return out
