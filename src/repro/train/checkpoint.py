"""Device-count-agnostic checkpointing with atomic commit + async save.

Design for fault tolerance at pod scale:

* **Logical arrays, not device shards.**  Each leaf is saved as its full
  logical value; restore re-shards under *any* mesh (elastic scaling: a job
  restarted on half the chips reloads the same checkpoint).  On a multi-host
  pod the ``device_get`` below becomes a per-host ``all_gather``-free fetch of
  addressable shards + host-0 assembly; on this single-process container it
  is exact.
* **Atomic commit.**  Arrays are written to ``<step>.tmp`` and renamed, with
  a ``.COMMIT`` marker written last — a preempted save can never be mistaken
  for a valid checkpoint.
* **Async.**  ``AsyncCheckpointer`` snapshots to host memory synchronously
  (cheap) and writes to storage on a background thread, so the train loop
  only blocks for the device→host copy.
* **Auto-resume.**  ``latest_step`` scans for the newest committed step.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import numpy as np

import jax


def _flatten(tree) -> Tuple[dict, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = {f"leaf_{i:05d}": np.asarray(jax.device_get(x))
            for i, x in enumerate(leaves)}
    return flat, treedef


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(flat),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # commit marker last: a crash before this line leaves no valid ckpt
    with open(os.path.join(final, ".COMMIT"), "w") as fh:
        fh.write("ok\n")
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, ".COMMIT")):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target) -> Tuple[Any, dict]:
    """Restore into the structure of ``target`` (shapes/dtypes validated).

    ``target`` may hold arrays or ShapeDtypeStructs.  Returns (tree, extra).
    Re-sharding for elastic restarts: pass the restored tree through
    ``jax.device_put(tree, shardings)`` for the new mesh.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, ".COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "manifest.json")) as fh:
        manifest = json.load(fh)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree.flatten(target)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, target expects "
            f"{len(leaves)}")
    out = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i:05d}"]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != target "
                f"{ref.shape}")
        out.append(arr.astype(ref.dtype))
    return jax.tree.unflatten(treedef, out), manifest["extra"]


def garbage_collect(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n[5:]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, n, ".COMMIT")))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))


class AsyncCheckpointer:
    """Snapshot synchronously, persist on a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_committed: Optional[int] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[dict] = None) -> None:
        self.wait()  # one in-flight save at a time
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _persist():
            save(self.ckpt_dir, step, snapshot, extra)
            garbage_collect(self.ckpt_dir, self.keep)
            self.last_committed = step

        self._thread = threading.Thread(target=_persist, daemon=True)
        self._thread.start()
