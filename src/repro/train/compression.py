"""Gradient compression for the DP all-reduce (distributed-optimization trick).

Two schemes, both applied around an explicit ``psum`` in a shard_map'd
data-parallel step (see ``distributed.collectives.compressed_psum``):

* ``bf16``  — cast gradients to bfloat16 before the all-reduce (halves
  collective bytes; the reduction itself still accumulates in fp32 on TPU).
* ``int8``  — per-leaf symmetric int8 quantization with **error feedback**:
  the quantization residual is carried to the next step, so the compressed
  SGD direction is unbiased over time (Karimireddy et al., 2019).

Both compose with the roofline's collective term: bf16 halves it, int8
quarters it, at zero HLO-FLOP cost.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def compress_bf16(g):
    return jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)


def decompress_bf16(g):
    return jax.tree.map(lambda x: x.astype(jnp.float32), g)


def quantize_int8(x, error: Optional[jax.Array] = None,
                  scale: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x (+carried error) → (int8 values, fp scale, new error).

    In a distributed all-reduce the ``scale`` must be agreed on *before*
    quantizing (pmax of the local absmax) — quantizing with local scales and
    dequantizing with a shared one is biased.  Pass the shared scale in.
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_error = xf - deq
    return q, scale, new_error


def local_absmax(x, error: Optional[jax.Array] = None) -> jax.Array:
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    return jnp.max(jnp.abs(xf))


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_grads_int8(grads, error_state):
    """Returns (quantized tree of (q, scale), new error state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = quantize_int8(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, errs))


def decompress_grads_int8(qs, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, qs, scales)
