"""AdamW + schedules from scratch (pytree-based, optax-style interface).

Optimizer state is stored as flat leaf lists aligned with
``jax.tree.leaves(params)`` so per-leaf state layouts can vary:

* ``momentum_dtype`` — storage dtype of m (fp32 math, cast on store).
  bf16 halves the largest optimizer buffer.
* ``factored_v`` — Adafactor-style factored second moment for rank≥2
  params: v ≈ (R ⊗ C) / mean(R) with R/C the row/col EMAs of g².  Cuts v
  from O(params) to O(rows+cols) — the difference between fitting and not
  fitting a 480B model's optimizer state in HBM (EXPERIMENTS.md §Perf A).

Optimizer state shards exactly like its parameters (ZeRO): the partition
specs of (m, v) mirror the param specs (factored leaves drop the trimmed
axis).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    schedule: str = "cosine"  # cosine | linear | constant
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    momentum_dtype: str = "float32"  # float32 | bfloat16
    factored_v: bool = False  # Adafactor-style factored second moment


class OptState(NamedTuple):
    step: jax.Array
    m: list
    v: list


def make_schedule(cfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * t))
        elif cfg.schedule == "linear":
            decay = 1.0 - (1 - cfg.min_lr_ratio) * t
        else:
            decay = jnp.array(1.0)
        return cfg.lr * warm * decay

    return sched


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def _is_factored(p, cfg: OptimizerConfig) -> bool:
    return cfg.factored_v and p.ndim >= 2


def _init_v(p, cfg: OptimizerConfig):
    if _is_factored(p, cfg):
        return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
    return jnp.zeros(p.shape, jnp.float32)


def _update_v(v, g2, cfg: OptimizerConfig):
    """Returns (new_v_state, effective v̂ tensor for the update)."""
    b2 = cfg.b2
    if isinstance(v, dict):
        r = b2 * v["r"] + (1 - b2) * jnp.mean(g2, axis=-1)
        c = b2 * v["c"] + (1 - b2) * jnp.mean(g2, axis=-2)
        denom = jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), 1e-30)
        vhat = (r / denom)[..., None] * c[..., None, :]
        return {"r": r, "c": c}, vhat
    v = b2 * v + (1 - b2) * g2
    return v, v


def adamw(cfg: OptimizerConfig):
    sched = make_schedule(cfg)
    m_dtype = jnp.dtype(cfg.momentum_dtype)

    def init(params) -> OptState:
        leaves = jax.tree.leaves(params)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            m=[jnp.zeros(p.shape, m_dtype) for p in leaves],
            v=[_init_v(p, cfg) for p in leaves])

    def update(grads, state: OptState, params):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        step = state.step + 1
        lr = sched(step)
        b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = jax.tree.leaves(params)
        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(p_leaves, g_leaves, state.m, state.v):
            mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
            v_state, vhat = _update_v(v, jnp.square(g), cfg)
            delta = (mf / b1c) / (jnp.sqrt(vhat / b2c) + cfg.eps)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * delta).astype(p.dtype))
            new_m.append(mf.astype(m_dtype))
            new_v.append(v_state)
        params_out = jax.tree.unflatten(treedef, new_p)
        return params_out, OptState(step=step, m=new_m, v=new_v), {
            "grad_norm": gnorm, "lr": lr}

    return init, update


def opt_state_partition_specs(param_specs, cfg: OptimizerConfig | None = None,
                              params_abs=None) -> OptState:
    """Optimizer-state specs mirror the parameter specs (ZeRO sharding).

    Factored-v leaves drop the trimmed axis from the spec; pass the abstract
    params so leaf ranks are known.
    """
    from jax.sharding import PartitionSpec as P

    spec_leaves = jax.tree.leaves(
        param_specs, is_leaf=lambda x: isinstance(x, P))
    if cfg is None or not cfg.factored_v or params_abs is None:
        v_specs = list(spec_leaves)
    else:
        v_specs = []
        for p, s in zip(jax.tree.leaves(params_abs), spec_leaves):
            if _is_factored(p, cfg):
                # pad the (possibly shorter-than-rank) spec with None first
                full = tuple(s) + (None,) * (p.ndim - len(tuple(s)))
                v_specs.append({"r": P(*full[:-1]),
                                "c": P(*(full[:-2] + (full[-1],)))})
            else:
                v_specs.append(s)
    return OptState(step=P(), m=list(spec_leaves), v=v_specs)
