"""Training loop with fault tolerance: auto-resume, async checkpoints,
preemption handling, straggler detection, in-loop device-resident eval.

The loop is deliberately thin — all heavy lifting is inside the jitted
``train_step`` — because the paper's lesson is precisely that the host-side
Python should only *instruct*, never compute.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np

import jax

from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    # straggler detection: a step slower than `straggler_factor` × the rolling
    # median is flagged (on a real pod this hooks per-host barrier timings).
    straggler_window: int = 20
    straggler_factor: float = 3.0


class StragglerMonitor:
    """Rolling-median step-time outlier detector.

    At pod scale each host runs one of these on its local step times; flagged
    hosts are candidates for replacement before they stall the collective.
    """

    def __init__(self, window: int, factor: float):
        self.window = window
        self.factor = factor
        self.times: list = []
        self.flags: int = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 5:
            med = float(np.median(self.times))
            if dt > self.factor * med:
                self.flags += 1
                return True
        return False


class Trainer:
    def __init__(
        self,
        cfg: TrainConfig,
        train_step: Callable,  # (params, opt_state, batch) -> (p, o, metrics)
        params,
        opt_state,
        data_iter: Iterator,
        eval_fn: Optional[Callable] = None,  # (params) -> dict of scalars
    ):
        self.cfg = cfg
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.data_iter = data_iter
        self.eval_fn = eval_fn
        self.step = 0
        self.history: list = []
        self.monitor = StragglerMonitor(cfg.straggler_window,
                                        cfg.straggler_factor)
        self._preempted = False
        self.checkpointer = (
            ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep_ckpts)
            if cfg.ckpt_dir else None)

    # -- fault tolerance ----------------------------------------------------

    def install_preemption_handler(self) -> None:
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    def maybe_resume(self) -> bool:
        """Auto-resume from the latest committed checkpoint, if any."""
        if not self.cfg.ckpt_dir:
            return False
        latest = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return False
        state = {"params": self.params, "opt_state": self.opt_state}
        restored, extra = ckpt_lib.restore(self.cfg.ckpt_dir, latest, state)
        self.params = restored["params"]
        self.opt_state = restored["opt_state"]
        self.step = latest
        return True

    def _checkpoint(self) -> None:
        if self.checkpointer is None:
            return
        self.checkpointer.save(
            self.step, {"params": self.params, "opt_state": self.opt_state})

    # -- loop ----------------------------------------------------------------

    def run(self, log_fn: Callable[[str], None] = print) -> Dict:
        last_metrics: Dict = {}
        while self.step < self.cfg.total_steps:
            batch = next(self.data_iter)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            self.step += 1
            if self.monitor.record(dt):
                log_fn(f"[straggler] step {self.step} took {dt:.3f}s "
                       f"(>{self.cfg.straggler_factor}x rolling median)")
            if self.step % self.cfg.log_every == 0 or \
                    self.step == self.cfg.total_steps:
                last_metrics = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": self.step, "time_s": dt,
                                     **last_metrics})
                msg = " ".join(f"{k}={v:.4f}" for k, v in last_metrics.items())
                log_fn(f"step {self.step}: {msg} ({dt*1e3:.1f} ms)")
            if self.cfg.ckpt_dir and self.step % self.cfg.ckpt_every == 0:
                self._checkpoint()
            if self._preempted:
                log_fn(f"[preemption] SIGTERM at step {self.step}; "
                       "checkpointing and exiting")
                self._checkpoint()
                break
        if self.checkpointer is not None:
            self._checkpoint()
            self.checkpointer.wait()
        if self.eval_fn is not None:
            last_metrics["eval"] = {
                k: float(v) for k, v in self.eval_fn(self.params).items()}
        return last_metrics
