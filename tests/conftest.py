import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own flag in its
# own process); make sure nothing leaks in.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
