"""Per-architecture smoke tests (deliverable f): every assigned arch runs one
forward/train step on CPU with a reduced config — output shapes + no NaNs.

The FULL configs are exercised only by the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.common import smoke_shape
from repro.launch.api import get_arch, list_archs

RNG = np.random.default_rng(0)


def _tiny_shape(arch, spec):
    o = {}
    if arch.family == "lm":
        o = {"seq_len": 16, "global_batch": 2}
    elif arch.family == "gnn":
        o = {"n_nodes": 64, "n_edges": 128, "d_feat": 8, "n_classes": 5}
        if spec.get("graph_task"):
            o["n_graphs"] = 4
    elif arch.family == "recsys":
        o = {"batch": 4}
        if spec.kind == "retrieval":
            o.update({"n_candidates": 64, "topk": 8})
        if spec.get("slate"):
            o["slate"] = 16
    elif arch.family == "eval":
        o = {"n_queries": 8, "n_docs": 32, "n_judged": 8}
    return smoke_shape(spec, **o)


def _concretize(tree):
    def mk(x):
        if x.dtype == jnp.int32:
            return jnp.asarray(RNG.integers(0, 2, x.shape).astype(np.int32))
        if x.dtype == jnp.bool_:
            return jnp.ones(x.shape, bool)
        # |N(0, .1)|: optimizer second moments must be non-negative
        return jnp.abs(jnp.asarray(
            RNG.standard_normal(x.shape).astype(np.float32) * 0.1))
    return jax.tree.map(mk, tree)


ALL_CELLS = []
for _name in list_archs():
    _arch = get_arch(_name)
    for _sname, _spec in _arch.shapes.items():
        ALL_CELLS.append((_name, _sname))


@pytest.mark.parametrize("arch_name,shape_name", ALL_CELLS)
def test_arch_shape_smoke(arch_name, shape_name):
    arch = get_arch(arch_name)
    spec = arch.shapes[shape_name]
    if spec.skip_reason:
        pytest.skip(spec.skip_reason)
    cfg = arch.make_config(smoke=True)
    bundle = arch.make_step(cfg, _tiny_shape(arch, spec), None)
    args = _concretize(bundle.arg_specs)
    out = jax.jit(bundle.step_fn)(*args)
    # shapes match the abstract spec, floats are finite
    out_abs = jax.eval_shape(bundle.step_fn, *bundle.arg_specs)
    got_leaves = jax.tree.leaves(out)
    want_leaves = jax.tree.leaves(out_abs)
    assert len(got_leaves) == len(want_leaves)
    for g, w in zip(got_leaves, want_leaves):
        assert g.shape == w.shape
        if jnp.issubdtype(g.dtype, jnp.floating):
            assert bool(jnp.isfinite(g).all()), "non-finite output"


def test_registry_has_all_assigned_archs():
    expected = {
        "qwen3-moe-235b-a22b", "arctic-480b", "olmo-1b", "nemotron-4-15b",
        "phi3-medium-14b", "gatedgcn", "sasrec", "xdeepfm", "mind",
        "autoint", "pytrec-eval",
    }
    assert expected <= set(list_archs())


def test_full_configs_match_spec():
    """Config constants pinned to the assignment table."""
    qwen = get_arch("qwen3-moe-235b-a22b").make_config(False)
    assert (qwen.n_layers, qwen.d_model, qwen.n_heads, qwen.n_kv_heads,
            qwen.vocab_size) == (94, 4096, 64, 4, 151936)
    assert (qwen.moe.n_experts, qwen.moe.top_k) == (128, 8)
    # ~235B total / ~22B active
    assert 180e9 < qwen.param_count() < 280e9
    assert 10e9 < qwen.active_param_count() < 30e9

    arctic = get_arch("arctic-480b").make_config(False)
    assert (arctic.n_layers, arctic.d_model, arctic.n_heads,
            arctic.n_kv_heads, arctic.d_ff) == (35, 7168, 56, 8, 4864)
    assert arctic.moe.dense_residual and arctic.moe.top_k == 2
    assert 400e9 < arctic.param_count() < 560e9

    olmo = get_arch("olmo-1b").make_config(False)
    assert olmo.norm == "nonparam" and olmo.tie_embeddings
    assert 0.8e9 < olmo.param_count() < 1.6e9

    nemo = get_arch("nemotron-4-15b").make_config(False)
    assert nemo.ffn == "sq_relu" and nemo.vocab_size == 256_000
    assert 10e9 < nemo.param_count() < 20e9

    phi = get_arch("phi3-medium-14b").make_config(False)
    assert (phi.n_layers, phi.n_kv_heads, phi.d_ff) == (40, 10, 17_920)
    assert 10e9 < phi.param_count() < 18e9

    gg = get_arch("gatedgcn").make_config(False)
    assert (gg.n_layers, gg.d_hidden) == (16, 70)

    xd = get_arch("xdeepfm").make_config(False)
    assert xd.cin_layers == (200, 200, 200) and xd.table.n_fields == 39

    sr = get_arch("sasrec").make_config(False)
    assert (sr.embed_dim, sr.n_blocks, sr.n_heads, sr.seq_len) == (50, 2, 1,
                                                                   50)
    mi = get_arch("mind").make_config(False)
    assert (mi.n_interests, mi.capsule_iters, mi.table.dim) == (4, 3, 64)

    ai = get_arch("autoint").make_config(False)
    assert (ai.n_attn_layers, ai.n_attn_heads, ai.d_attn,
            ai.table.dim) == (3, 2, 32, 16)
