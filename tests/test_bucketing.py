"""Shape bucketing, the block_q autotuner, and the recompile-bound contract.

The tentpole claim of the bucketing layer is operational: however many
distinct raw batch extents a workload produces, the number of compiled jit
signatures stays within the closed set ``padding_classes`` describes.  The
sweep test at the bottom drives the REAL serve path (coalesced waves of 8+
distinct sizes through :class:`EvaluationService`) and asserts the bound on
the trace-time compile counters — the honest count, recorded from inside
the jit'd bodies themselves.
"""

import asyncio
import math
import threading

import numpy as np
import pytest

from repro.kernels import autotune, bucketing


# -- padding classes ---------------------------------------------------------

def test_next_pow2_basics():
    assert [bucketing.next_pow2(n) for n in (1, 2, 3, 4, 5, 9, 1000)] == \
        [1, 2, 4, 4, 8, 16, 1024]
    assert bucketing.next_pow2(3, minimum=8) == 8
    assert bucketing.next_pow2(17, minimum=8) == 32


def test_bucket_queries_pow2_then_multiple():
    assert bucketing.bucket_queries(37) == 64
    assert bucketing.bucket_queries(1) == 1
    assert bucketing.bucket_queries(0) == 1  # degenerate extent still padded
    # shard-aware rounding happens AFTER the pow2 bucket
    assert bucketing.bucket_queries(5, multiple=3) == 9
    assert bucketing.bucket_queries(8, multiple=4) == 8


def test_bucket_docs_floor():
    assert bucketing.bucket_docs(3) == bucketing.MIN_DOC_BUCKET
    assert bucketing.bucket_docs(100) == 128
    assert bucketing.bucket_docs(1000) == 1024


def test_padding_classes_are_closed_and_complete():
    classes = bucketing.padding_classes(64)
    assert classes == (1, 2, 4, 8, 16, 32, 64)
    # completeness: every admissible extent maps INTO the closed set
    for n in range(1, 65):
        assert bucketing.bucket_queries(n) in classes
    assert bucketing.max_signatures(64) == len(classes)


def test_padding_classes_respect_multiple():
    classes = bucketing.padding_classes(16, multiple=4)
    for n in range(1, 17):
        b = bucketing.bucket_queries(n, multiple=4)
        assert b % 4 == 0
        assert b in classes


def test_signature_bound_is_logarithmic():
    # the whole point: 10_000 possible extents, ~log2 signatures
    assert bucketing.max_signatures(10_000) <= math.log2(10_000) + 2


# -- trace counters ----------------------------------------------------------

def test_trace_counters_roundtrip():
    name = "test_counter_roundtrip"
    bucketing.reset_trace_counts([name])
    assert bucketing.compile_count(name) == 0
    bucketing.record_trace(name)
    bucketing.record_trace(name)
    assert bucketing.compile_count(name) == 2
    assert bucketing.trace_counts()[name] == 2
    bucketing.reset_trace_counts([name])
    assert bucketing.compile_count(name) == 0


def test_trace_counters_thread_safe():
    name = "test_counter_threads"
    bucketing.reset_trace_counts([name])
    threads = [threading.Thread(
        target=lambda: [bucketing.record_trace(name) for _ in range(200)])
        for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert bucketing.compile_count(name) == 8 * 200


# -- block_q autotuner -------------------------------------------------------

def test_block_q_bounds_and_pow2():
    for q in (1, 7, 64, 1000, 4096):
        for d in (8, 256, 4096, 1 << 16):
            bq = autotune.block_q_for(q, d)
            assert autotune.MIN_BLOCK_Q <= bq <= autotune.MAX_BLOCK_Q
            assert bq & (bq - 1) == 0  # power of two


def test_block_q_shrinks_with_wider_rows():
    assert autotune.block_q_for(1024, 1 << 16) < \
        autotune.block_q_for(1024, 1 << 10)


def test_block_q_respects_vmem_budget():
    d = 4096
    bq = autotune.block_q_for(1024, d, vmem_bytes=1 << 20)
    assert autotune.LIVE_TILES * bq * d * 4 <= (1 << 20) * \
        autotune.VMEM_HEADROOM or bq == autotune.MIN_BLOCK_Q


def test_block_q_clamps_to_small_batches():
    assert autotune.block_q_for(4, 64) == autotune.MIN_BLOCK_Q


def test_block_q_deterministic():
    assert autotune.block_q_for(512, 512) == autotune.block_q_for(512, 512)


# -- the recompile-bound contract on the real serve path --------------------

def test_serve_wave_sweep_compiles_bounded_signatures():
    """≥8 distinct coalesced wave sizes → at most log2(max_batch)+2 compiles.

    Drives the full request path: concurrent ``evaluate`` calls coalesce
    into waves, each wave concatenates into one RunBuffer whose query axis
    is the wave size, ``batch_from_buffer`` pads it through the bucketing
    module, and the measure core jit-compiles per *padded* signature.  A
    one-off measure tuple keys fresh jit entries, so the counter delta is
    exactly this test's compiles.
    """
    from repro.serve import EvaluationService

    max_batch = 64
    wave_sizes = [1, 2, 3, 5, 9, 17, 33, 64]  # 8 distinct raw sizes
    assert len(set(wave_sizes)) >= 8
    qrel = {"q1": {"d1": 1, "d2": 0, "d3": 1}}
    run = {"q1": {"d1": 0.9, "d2": 0.5, "d3": 0.1}}
    # fresh static jit key: this measure pair is used nowhere else
    measures = ("map_cut_30", "success_5")

    async def sweep():
        svc = EvaluationService(window=0.01, max_batch=max_batch,
                                backend="single")
        svc.register_qrel("sweep", qrel, measures)
        for k in wave_sizes:
            res = await asyncio.gather(
                *(svc.evaluate("sweep", run=run) for _ in range(k)))
            assert len(res) == k
            for r in res:
                assert r.per_query["q1"]["success_5"] == 1.0

    before = bucketing.compile_count("measure_core")
    asyncio.run(sweep())
    compiled = bucketing.compile_count("measure_core") - before
    bound = math.log2(max_batch) + 2
    assert 0 < compiled <= bound, (
        f"{len(wave_sizes)} distinct wave sizes compiled {compiled} "
        f"measure-core signatures; bucketing promises <= {bound}")
    # and the closed set predicted by padding_classes really covers it
    assert compiled <= bucketing.max_signatures(max_batch)


def test_evaluator_padding_uses_shared_buckets():
    """batch_from_buffer's padded axes land exactly on the bucket classes."""
    from repro.core import RelevanceEvaluator

    qrel = {f"q{i}": {f"d{j}": int(j < 2) for j in range(5)}
            for i in range(3)}
    run = {f"q{i}": {f"d{j}": float(10 - j) for j in range(5)}
           for i in range(3)}
    ev = RelevanceEvaluator(qrel, ("map",))
    batch = ev.batch_from_buffer(ev.tokenize_run(run))
    q_pad, d_pad = batch.scores.shape
    assert q_pad == bucketing.bucket_queries(3)
    assert d_pad == bucketing.bucket_docs(5)
    # shard-aware rounding still applies on top of the pow2 class
    batch6 = ev.batch_from_buffer(ev.tokenize_run(run), q_multiple=6)
    assert batch6.scores.shape[0] == bucketing.bucket_queries(3, multiple=6)
