"""Chaos-schedule acceptance tests (ISSUE 10): the cluster under fire.

Seeded :class:`~repro.serve.cluster.chaos.ChaosSchedule`\\ s — kill,
SIGSTOP-hang, response delay, byte truncation, alone and in random
combination — replay against a live replicated cluster while traffic
flows.  Two invariants must hold for EVERY schedule:

* **no garbage, ever**: a response that is delivered is bit-identical to
  the in-process :class:`~repro.core.RelevanceEvaluator`; a request that
  fails fails with a *typed* protocol error
  (:class:`~repro.client.WorkerUnavailableError` /
  :class:`~repro.client.DeadlineExceededError`), never a torn frame or a
  stack trace;
* **no lost acknowledgements**: every registration the router acked —
  including ones acked mid-chaos — evaluates bit-identically once the
  schedule has played out and the cluster has healed.

The cluster is module-scoped (workers cost ~1 s to boot); the wire runs
through :class:`~repro.serve.cluster.chaos.ProxyManager` fault proxies so
delay/truncate events have somewhere to strike.  Health probes are tuned
tight (0.5 s interval, 1 s timeout) so hung workers are SIGKILLed onto
the restart path instead of wedging the pool.
"""

import time

import pytest

from repro.client import (DeadlineExceededError, EvalClient,
                          WorkerUnavailableError)
from repro.core import RelevanceEvaluator
from repro.data.synthetic_ir import synthesize_run
from repro.serve.cluster import ChaosEvent, ChaosSchedule, ProxyManager
from repro.serve.cluster.chaos import inject
from repro.serve.cluster.testing import ClusterThread

MEASURES = ("map", "ndcg", "recip_rank", "P")

#: errors a client may legitimately see WHILE a schedule is running
TOLERATED = (WorkerUnavailableError, DeadlineExceededError)


@pytest.fixture(scope="module")
def chaos_cluster(tmp_path_factory):
    state = str(tmp_path_factory.mktemp("chaos-state"))
    proxies = ProxyManager()
    cluster = ClusterThread(
        2, worker_args=["--backend", "single", "--window-ms", "1",
                        "--max-collections", "64"],
        router_kw=dict(replication=2, retries=4, rng_seed=11,
                       health_interval=0.5, health_timeout=1.0,
                       state_dir=state, wrap_endpoint=proxies.wrap))
    try:
        yield cluster, proxies
    finally:
        try:
            cluster.call(proxies.aclose(), timeout=30)
        finally:
            cluster.close()


def _wait_all_ready(cluster, timeout=90.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cluster.health()["status"] == "ok":
            return
        time.sleep(0.05)
    raise AssertionError(f"cluster not ready: {cluster.health()}")


def _register(client, prefix, n, seed0):
    """Register n collections; return {qrel_id: (run, want)}."""
    registered = {}
    for i in range(n):
        run, qrel = synthesize_run(n_queries=6, n_docs=5, seed=seed0 + i)
        qrel_id = f"{prefix}-{i}"
        client.register_qrel(qrel_id, qrel, MEASURES)
        registered[qrel_id] = (
            run, RelevanceEvaluator(qrel, MEASURES).evaluate(run))
    return registered


def _drive(client, registered, fut, *, deadline=None, register_seed=None):
    """Round-robin evaluates (and optional mid-chaos registrations) until
    the schedule future resolves.  Delivered results must be
    bit-identical; failures must be typed.  Returns {code: count}."""
    errors = {}
    i = 0
    while not fut.done():
        for qrel_id, (run, want) in list(registered.items()):
            try:
                res = client.evaluate(qrel_id, run=run, timeout=deadline)
            except TOLERATED as exc:
                errors[exc.code] = errors.get(exc.code, 0) + 1
            else:
                assert res.per_query == want, qrel_id
        if register_seed is not None and i < 8:  # bounded: LRU headroom
            run, qrel = synthesize_run(n_queries=5, n_docs=4,
                                       seed=register_seed + i)
            qrel_id = f"mid-{register_seed}-{i}"
            try:
                client.register_qrel(qrel_id, qrel, MEASURES)
            except TOLERATED:
                pass  # NOT acked: the router owes us nothing for it
            else:  # acked: it must survive whatever the schedule does
                registered[qrel_id] = (
                    run, RelevanceEvaluator(qrel, MEASURES).evaluate(run))
            i += 1
        time.sleep(0.02)
    fut.result(timeout=60)  # surface injector exceptions


def _assert_converged(cluster, client, registered):
    """Post-schedule: zero lost acks, every answer bit-identical."""
    _wait_all_ready(cluster)
    for qrel_id, (run, want) in registered.items():
        res = client.evaluate(qrel_id, run=run)
        assert res.per_query == want, f"{qrel_id} diverged after chaos"
        assert qrel_id in cluster.router._journal  # ack is still durable


def test_chaos_kills_lose_nothing(chaos_cluster):
    """SIGKILL each worker in turn under live traffic + registrations."""
    cluster, proxies = chaos_cluster
    _wait_all_ready(cluster)
    schedule = ChaosSchedule([
        ChaosEvent(t=0.10, kind="kill", worker="w0"),
        ChaosEvent(t=1.20, kind="kill", worker="w1"),
    ])
    with EvalClient(cluster.host, cluster.port, timeout=120) as client:
        registered = _register(client, "kill", 3, seed0=200)
        injector, fut = inject(cluster, schedule, proxies)
        _drive(client, registered, fut, register_seed=250)
        assert len(injector.applied) == 2 and not injector.skipped
        _assert_converged(cluster, client, registered)
    assert cluster.router.counters["restarts"] >= 2


def test_chaos_hangs_recover_via_health_probe(chaos_cluster):
    """SIGSTOP-hangs: the worker is alive but silent; either the hang
    outlasts the probe timeout (SIGKILL + restart) or it resumes — both
    must be invisible to acknowledged state."""
    cluster, proxies = chaos_cluster
    _wait_all_ready(cluster)
    schedule = ChaosSchedule([
        ChaosEvent(t=0.10, kind="hang", worker="w0", duration=0.35),
        ChaosEvent(t=0.90, kind="hang", worker="w1", duration=0.35),
    ])
    with EvalClient(cluster.host, cluster.port, timeout=120) as client:
        registered = _register(client, "hang", 3, seed0=300)
        injector, fut = inject(cluster, schedule, proxies)
        _drive(client, registered, fut)
        assert len(injector.applied) == 2
        _assert_converged(cluster, client, registered)


def test_chaos_truncation_never_relays_garbage(chaos_cluster):
    """Torn frames on the worker wire: the router's client must treat a
    response cut mid-frame as a connection loss and fail over — the end
    client never sees partial bytes."""
    cluster, proxies = chaos_cluster
    _wait_all_ready(cluster)
    schedule = ChaosSchedule([
        ChaosEvent(t=0.05, kind="truncate", worker="w0"),
        ChaosEvent(t=0.35, kind="truncate", worker="w1"),
        ChaosEvent(t=0.65, kind="truncate", worker="w0"),
    ])
    with EvalClient(cluster.host, cluster.port, timeout=120) as client:
        registered = _register(client, "trunc", 3, seed0=400)
        injector, fut = inject(cluster, schedule, proxies)
        _drive(client, registered, fut)
        assert len(injector.applied) == 3
        # a pending truncate_next fires on the next chunk through the
        # proxy; keep traffic flowing until at least one actually struck
        deadline = time.monotonic() + 15
        while (sum(p.counters["truncated"]
                   for p in proxies.proxies.values()) == 0
               and time.monotonic() < deadline):
            for qrel_id, (run, want) in registered.items():
                try:
                    assert client.evaluate(
                        qrel_id, run=run).per_query == want
                except TOLERATED:
                    pass
        assert sum(p.counters["truncated"]
                   for p in proxies.proxies.values()) >= 1
        _assert_converged(cluster, client, registered)


def test_chaos_delay_with_deadlines_hedges_or_times_out(chaos_cluster):
    """A slow replica (per-chunk delay beyond the hedge point): requests
    carrying deadlines either hedge to the fast sibling or answer
    deadline_exceeded — never a late-garbled result."""
    cluster, proxies = chaos_cluster
    _wait_all_ready(cluster)
    schedule = ChaosSchedule([
        ChaosEvent(t=0.05, kind="delay", worker="w0", duration=0.7),
        ChaosEvent(t=0.40, kind="delay", worker="w1", duration=0.7),
    ])
    with EvalClient(cluster.host, cluster.port, timeout=120) as client:
        registered = _register(client, "slow", 2, seed0=500)
        injector, fut = inject(cluster, schedule, proxies)
        _drive(client, registered, fut, deadline=1.0)
        assert len(injector.applied) == 2
        _assert_converged(cluster, client, registered)
    for proxy in proxies.proxies.values():
        assert proxy.delay == 0.0  # trailing effects undone by run()


@pytest.mark.parametrize("seed", [3, 17])
def test_chaos_random_schedule_converges(chaos_cluster, seed):
    """The headline invariant: a SEEDED random mix of every fault kind,
    with registrations arriving mid-schedule, ends with zero lost acks
    and bit-identical answers."""
    cluster, proxies = chaos_cluster
    _wait_all_ready(cluster)
    schedule = ChaosSchedule.random(seed, cluster.worker_names,
                                    n_events=6, horizon=2.0)
    assert (schedule.events ==
            ChaosSchedule.random(seed, cluster.worker_names,
                                 n_events=6, horizon=2.0).events)
    with EvalClient(cluster.host, cluster.port, timeout=120) as client:
        registered = _register(client, f"rand{seed}", 3, seed0=600 + seed)
        injector, fut = inject(cluster, schedule, proxies)
        _drive(client, registered, fut, register_seed=700 + seed)
        assert len(injector.applied) + len(injector.skipped) == 6
        _assert_converged(cluster, client, registered)
