"""CLI conformance: ``python -m repro`` must speak trec_eval's dialect.

The golden fixture (tests/fixtures/conformance.golden) is byte-compared
against the CLI's output for the hand-verified conformance qrel/run pair, and
independently re-derived from ``test_conformance._trec_eval_reference`` so
the golden itself is anchored to the hand-written trec_eval reimplementation
rather than to the code under test.
"""

import io
import math
import os
import subprocess
import sys

import pytest

from test_conformance import RANKED, _trec_eval_reference

from repro import cli
from repro.core import supported_measures

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
QREL = os.path.join(FIXTURES, "conformance.qrel")
RUN = os.path.join(FIXTURES, "conformance.run")
GOLDEN = os.path.join(FIXTURES, "conformance.golden")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _cli(argv):
    buf = io.StringIO()
    assert cli.main(argv, out=buf) == 0
    return buf.getvalue()


def _golden_text():
    with open(GOLDEN, newline="") as fh:
        return fh.read()


def test_cli_inprocess_byte_matches_golden():
    assert _cli([QREL, RUN]) == _golden_text()


@pytest.mark.slow
def test_python_dash_m_repro_byte_matches_golden():
    """The real ``python -m repro`` entry point, end to end."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-m", "repro", QREL, RUN],
                         capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout == _golden_text()


def test_golden_matches_independent_reference():
    """Every 'all' line re-derived from the hand-written trec_eval reference."""
    per_query = {qid: _trec_eval_reference(s["rels"], s["R"], s["N"],
                                           s["ideal"])
                 for qid, s in RANKED.items()}
    n_q = len(per_query)
    want = {}
    for key in cli.ordered_keys(sorted(supported_measures)):
        total = sum(v[key] for v in per_query.values())
        want[key] = total if key in cli.SUM_MEASURES else total / n_q
        if key in cli.AGGREGATE_ONLY:  # geometric mean: exp of the mean log
            want[key] = math.exp(want[key])
    want["num_q"] = float(n_q)
    want["runid"] = "tag"

    for line in _golden_text().splitlines():
        name, qid, val = line.split("\t")
        name = name.rstrip()
        assert qid == "all"
        assert cli.format_line(name, "all", want[name]) == line, name


def test_cli_per_query_blocks():
    """-q prints query-major blocks (run order) and reference values."""
    lines = _cli(["-q", QREL, RUN]).splitlines()
    all_keys = cli.ordered_keys(sorted(supported_measures))
    # aggregate-only measures (gm_map) print no per-query line
    keys = [k for k in all_keys if k not in cli.AGGREGATE_ONLY]
    # q1 block, q2 block, then runid + num_q + summary (all keys)
    assert len(lines) == 2 * len(keys) + len(all_keys) + 2
    q1 = lines[:len(keys)]
    q2 = lines[len(keys):2 * len(keys)]
    assert all(l.split("\t")[1] == "q1" for l in q1)
    assert all(l.split("\t")[1] == "q2" for l in q2)
    for block, qid in ((q1, "q1"), (q2, "q2")):
        spec = RANKED[qid]
        want = _trec_eval_reference(spec["rels"], spec["R"], spec["N"],
                                    spec["ideal"])
        for line in block:
            name = line.split("\t")[0].rstrip()
            assert cli.format_line(name, qid, want[name]) == line, (qid, name)


def test_cli_measure_selection_and_order():
    out = _cli(["-m", "ndcg", "-m", "map", QREL, RUN]).splitlines()
    names = [l.split("\t")[0].rstrip() for l in out]
    # stable print order regardless of -m order: map before ndcg
    assert names == ["runid", "num_q", "map", "ndcg"]


def test_cli_output_style_measure_key():
    out = _cli(["-m", "P_5", QREL, RUN]).splitlines()
    assert out[-1].split("\t")[0].rstrip() == "P_5"
    assert out[-1].split("\t")[2] == "0.3000"


def test_cli_complete_flag_averages_over_qrel_queries(tmp_path):
    # a run that only answers q1: -c must divide by both qrel queries and
    # count q2's relevant doc in num_rel.
    partial = tmp_path / "partial.run"
    partial.write_text("q1 Q0 APPLE 0 3.0 tag\n")
    base = _cli(["-m", "map", "-m", "num_rel", str(QREL), str(partial)])
    comp = _cli(["-c", "-m", "map", "-m", "num_rel", str(QREL), str(partial)])

    def val(text, name):
        for line in text.splitlines():
            if line.split("\t")[0].rstrip() == name:
                return line.split("\t")[2]
        raise KeyError(name)

    assert val(base, "num_q") == "1" and val(comp, "num_q") == "2"
    assert float(val(comp, "map")) == pytest.approx(
        float(val(base, "map")) / 2, abs=5e-5)
    assert val(base, "num_rel") == "3" and val(comp, "num_rel") == "4"


def test_cli_sharded_flag_byte_identical():
    assert _cli(["--sharded", QREL, RUN]) == _golden_text()


def test_cli_gm_map_is_aggregate_only():
    """-m gm_map: no per-query lines even under -q; geometric-mean summary."""
    out = _cli(["-q", "-m", "gm_map", "-m", "map", QREL, RUN]).splitlines()
    per_query = [l for l in out if l.split("\t")[1] != "all"]
    assert all(l.split("\t")[0].rstrip() == "map" for l in per_query)
    names = {l.split("\t")[0].rstrip(): l.split("\t")[2]
             for l in out if l.split("\t")[1] == "all"}
    # both fixture queries have AP 0.5 → geometric mean 0.5 too
    assert names["gm_map"] == "0.5000" and names["map"] == "0.5000"


def test_cli_rejects_unknown_measure(capsys):
    with pytest.raises(SystemExit):
        cli.main(["-m", "nosuch", QREL, RUN])


def test_cli_merges_repeated_family_selectors():
    """-m P_5 -m P_10 must print BOTH cutoffs (regression: dict() collapse)."""
    out = _cli(["-m", "P_5", "-m", "P_10", QREL, RUN]).splitlines()
    names = [l.split("\t")[0].rstrip() for l in out]
    assert names == ["runid", "num_q", "P_5", "P_10"]
    assert cli.ordered_keys(["ndcg_cut_10", "ndcg_cut_5"]) == \
        ["ndcg_cut_5", "ndcg_cut_10"]


def test_cli_rejects_duplicate_run_rows(tmp_path, capsys):
    """trec_eval errors on duplicate (qid, docno) rows; so must the CLI."""
    dup = tmp_path / "dup.run"
    dup.write_text("q1 Q0 APPLE 0 0.9 t\nq1 Q0 APPLE 1 0.8 t\n")
    with pytest.raises(SystemExit):
        cli.main([QREL, str(dup)])
    assert "duplicate" in capsys.readouterr().err
