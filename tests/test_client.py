"""Client-library acceptance tests (ISSUE 4).

The contract proven here:

* a 100 KB+ ``register_qrel`` + evaluate round-trip over TCP returns
  results bit-identical to ``RelevanceEvaluator.evaluate`` — the payload
  size that crashed the seed's 64 KiB ``readline`` limit;
* N pipelined ``AsyncEvalClient`` requests coalesce into fewer backend
  calls (asserted on the service micro-batcher's ``flushes`` counter);
* an authenticated server answers a wrong-token client with an error
  *response* on a live socket, never a dead connection;
* ``benchmarks/bench_client.py`` runs and reports throughput + p50/p99 at
  >= 2 pipeline depths.

Socket endpoints come from :class:`repro.serve.testing.ServerThread`
(in-process loopback — fast); only the subprocess suite is ``slow``.
"""

import asyncio
import json
import os
import sys

import numpy as np
import pytest

from repro.client import (AsyncEvalClient, AuthError, ClientError,
                          ConnectionLostError, EvalClient, IDEMPOTENT_OPS)
from repro.core import RelevanceEvaluator, aggregate_results
from repro.data.synthetic_ir import synthesize_run
from repro.serve.testing import ServerThread

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
QREL_PATH = os.path.join(FIXTURES, "conformance.qrel")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MEASURES = ("map", "ndcg", "recip_rank", "P")


def _big_collection(n_queries=120, n_docs=32):
    """A qrel/run pair whose JSON serialization tops 100 KB."""
    qrel, run = {}, {}
    rng = np.random.default_rng(11)
    for q in range(n_queries):
        qid = f"query-{q:05d}"
        docs = [f"document-{q:05d}-{d:05d}-padpadpad" for d in range(n_docs)]
        qrel[qid] = {doc: int(rng.integers(0, 3)) for doc in docs}
        run[qid] = {doc: float(rng.normal()) for doc in docs}
    return qrel, run


# -- acceptance: the 64 KiB crash is gone ------------------------------------


def test_large_payload_roundtrip_bit_identical():
    """>100 KB register_qrel + evaluate over TCP == in-process evaluate."""
    qrel, run = _big_collection()
    payload = json.dumps({"op": "register_qrel", "qrel_id": "big",
                          "qrel": qrel}).encode()
    assert len(payload) > 100_000  # the seed crashed beyond 64 KiB (2**16)

    with ServerThread() as srv:
        with EvalClient(srv.host, srv.port) as client:
            info = client.register_qrel("big", qrel, MEASURES)
            assert info["n_queries"] == len(qrel)
            res = client.evaluate("big", run=run)

    want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
    assert res.per_query == want  # bit-identical floats, all queries
    assert res.aggregates == aggregate_results(want)


def test_legacy_limit_now_answers_instead_of_crashing():
    """With the OLD 64 KiB limit configured, an oversized register_qrel
    gets a frame_too_large *response* — not the seed's dead connection."""
    qrel, run = _big_collection()

    async def main(port):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        writer.write(json.dumps({"op": "register_qrel", "id": 1,
                                 "qrel_id": "big", "qrel": qrel}).encode()
                     + b"\n")
        writer.write(b'{"op": "ping", "id": 2}\n')
        await writer.drain()
        first = json.loads(await reader.readline())
        second = json.loads(await reader.readline())
        writer.close()
        await writer.wait_closed()
        return first, second

    with ServerThread(limit=2**16) as srv:
        first, second = asyncio.run(main(srv.port))
    assert not first["ok"] and first["code"] == "frame_too_large"
    assert second["ok"] and second["result"] == "pong"  # connection alive


def test_client_rejects_request_over_frame_limit_locally():
    qrel, _ = _big_collection()
    with ServerThread() as srv:
        with EvalClient(srv.host, srv.port, frame_limit=2**16) as client:
            assert client.ping() == "pong"
            with pytest.raises(ClientError, match="frame limit"):
                client.register_qrel("big", qrel)
            assert client.ping() == "pong"  # stream not poisoned


# -- acceptance: pipelining coalesces ----------------------------------------


def test_pipelined_requests_coalesce_fewer_flushes():
    run, qrel = synthesize_run(n_queries=24, n_docs=16, seed=7)
    ev = RelevanceEvaluator(qrel, ("map", "recip_rank"))
    buf = ev.tokenize_run(run)
    rng = np.random.default_rng(3)
    n = 8
    score_sets = [rng.normal(size=buf.qidx.shape[0]).astype(np.float32)
                  for _ in range(n)]

    with ServerThread(service_kw=dict(window=0.05,
                                      backend="single")) as srv:
        srv.register_qrel("c", qrel, ("map", "recip_rank"))
        srv.register_run("c", "bm25", run=run)
        flushes_before = srv.stats()["flushes"]

        async def main():
            async with await AsyncEvalClient.connect(srv.host,
                                                     srv.port) as client:
                return await client.evaluate_many(
                    "c", run_ref="bm25", scores_list=score_sets)

        results = asyncio.run(main())
        stats = srv.stats()

    flushed = stats["flushes"] - flushes_before
    assert 0 < flushed < n  # N pipelined requests -> fewer batcher flushes
    assert stats["backend_calls"] < n
    for s, res in zip(score_sets, results):
        assert res.per_query == ev.evaluate_buffer(buf, scores=s)


def test_sync_submit_pipelines_too():
    run, qrel = synthesize_run(n_queries=12, n_docs=8, seed=5)
    with ServerThread(service_kw=dict(window=0.05,
                                      backend="single")) as srv:
        srv.register_qrel("c", qrel, ("map",))
        with EvalClient(srv.host, srv.port) as client:
            info = client.register_run("c", "r", run=run)
            scores = np.linspace(0.0, 1.0,
                                 info["n_docs"]).astype(np.float32)
            futures = [client.submit("c", run_ref="r", scores=scores)
                       for _ in range(4)]
            results = [f.result(60) for f in futures]
        stats = srv.stats()
    assert stats["backend_calls"] < 4
    assert all(r.per_query == results[0].per_query for r in results)


# -- acceptance: auth --------------------------------------------------------


def test_wrong_token_gets_error_response_not_dead_socket():
    async def main(port):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)

        async def rpc(obj):
            writer.write(json.dumps(obj).encode() + b"\n")
            await writer.drain()
            return json.loads(await reader.readline())

        tokenless = await rpc({"op": "auth", "id": 0})
        denied = await rpc({"op": "auth", "id": 1, "token": "wrong"})
        unauth = await rpc({"op": "ping", "id": 2})
        granted = await rpc({"op": "auth", "id": 3, "token": "s3cret"})
        pong = await rpc({"op": "ping", "id": 4})
        writer.close()
        await writer.wait_closed()
        return tokenless, denied, unauth, granted, pong

    with ServerThread(auth_token="s3cret") as srv:
        tokenless, denied, unauth, granted, pong = asyncio.run(
            main(srv.port))
    # wrong token: an error RESPONSE on a connection that stays usable
    assert tokenless["code"] == "missing_field"  # same code as open servers
    assert not denied["ok"] and denied["code"] == "bad_auth"
    assert not unauth["ok"] and unauth["code"] == "auth_required"
    assert granted["ok"] and granted["result"]["authenticated"]
    assert pong["ok"] and pong["result"] == "pong"


def test_client_auth_lifecycle():
    qrel = {"q1": {"d1": 1}}
    with ServerThread(auth_token="s3cret") as srv:
        with pytest.raises(AuthError):
            EvalClient(srv.host, srv.port, token="wrong")
        with pytest.raises(AuthError):  # no token at all
            with EvalClient(srv.host, srv.port) as c:
                c.ping()
        with EvalClient(srv.host, srv.port, token="s3cret") as client:
            client.register_qrel("web", qrel, ("map",))
            res = client.evaluate("web", run={"q1": {"d1": 1.0}})
            assert res.per_query["q1"]["map"] == 1.0


# -- reconnect-with-retry ----------------------------------------------------


def test_reconnect_retries_idempotent_requests():
    run, qrel = synthesize_run(n_queries=6, n_docs=4, seed=1)
    want = RelevanceEvaluator(qrel, ("map",)).evaluate(run)

    with ServerThread() as srv:

        async def main():
            client = await AsyncEvalClient.connect(srv.host, srv.port,
                                                   retries=2, backoff=0.01)
            await client.register_qrel("c", qrel, ["map"])
            # sever the transport under the client's feet; the next
            # (idempotent) request must reconnect and retry transparently
            client._writer.close()
            res = await client.evaluate("c", run=run)
            stats = dict(client.transport_stats)
            await client.aclose()
            return res, stats

        res, stats = asyncio.run(main())
    assert res.per_query == want
    assert stats["reconnects"] == 1
    assert "drop_qrel" not in IDEMPOTENT_OPS  # result is not idempotent


def test_connection_refused_surfaces_after_retries():
    async def main():
        client = AsyncEvalClient("127.0.0.1", 1, retries=1, backoff=0.01)
        with pytest.raises((ConnectionLostError, OSError)):
            await client.ping()
        await client.aclose()

    asyncio.run(main())


# -- protocol-level helpers through the client -------------------------------


def test_session_api_mirror_roundtrip():
    run, qrel = synthesize_run(n_queries=8, n_docs=6, seed=2)
    ev = RelevanceEvaluator(qrel, ("map", "ndcg"))
    with ServerThread() as srv:
        with EvalClient(srv.host, srv.port) as client:
            assert client.ping() == "pong"
            info = client.register_qrel("c", qrel, ["map", "ndcg"],
                                        relevance_level=1)
            assert info["relevance_level"] == 1.0
            res = client.evaluate("c", run=run)
            assert res.per_query == ev.evaluate(run)
            stats = client.stats()
            assert stats["requests"] == 1
            assert client.drop_qrel("c") is True
            assert client.drop_qrel("c") is False
            with pytest.raises(Exception, match="unknown qrel_id"):
                client.evaluate("c", run=run)


def test_evaluate_many_validation():
    with ServerThread() as srv:
        with EvalClient(srv.host, srv.port) as client:
            with pytest.raises(ValueError, match="exactly one"):
                client.evaluate_many("c")


# -- acceptance: the client benchmark runs -----------------------------------

def test_bench_client_reports_two_pipeline_depths():
    from benchmarks import bench_client

    rows = bench_client.run(full=False)
    client_rows = [r for r in rows if r["mode"] == "client"]
    assert len({r["depth"] for r in client_rows}) >= 2
    for row in rows:
        assert row["runs_per_s"] > 0
        assert 0 <= row["p50_ms"] <= row["p99_ms"]
    assert any(r["mode"] == "raw_socket" for r in rows)


# -- stdio transport (subprocess: slow) --------------------------------------


@pytest.mark.slow
def test_spawn_stdio_subprocess_with_large_payload():
    qrel, run = _big_collection(n_queries=48, n_docs=24)
    orig = os.environ.get("PYTHONPATH")
    # the spawned subprocess must be able to import repro
    os.environ["PYTHONPATH"] = SRC + ((os.pathsep + orig) if orig else "")
    try:
        with EvalClient.spawn_stdio(
                [sys.executable, "-m", "repro.serve", "--qrel", QREL_PATH,
                 "-m", "map", "--window-ms", "1"]) as client:
            assert client.ping() == "pong"
            # the pre-registered default collection from --qrel works
            res = client.evaluate("default",
                                  run={"q1": {"APPLE": 2.0, "BANANA": 1.0}})
            assert res.per_query["q1"]["map"] > 0
            # and a fresh >64 KiB registration round-trips bit-identically
            client.register_qrel("big", qrel, ("map",))
            res = client.evaluate("big", run=run)
        want = RelevanceEvaluator(qrel, ("map",)).evaluate(run)
        assert res.per_query == want
    finally:
        if orig is None:
            os.environ.pop("PYTHONPATH", None)
        else:
            os.environ["PYTHONPATH"] = orig
