"""Cluster acceptance tests (ISSUE 8): router, ring, and fault injection.

The contract proven here:

* a 2-worker cluster answers every op **bit-identically** to a
  single-process server / the in-process evaluator — including the CLI
  conformance golden reproduced byte-for-byte through ``EvalClient``;
* killing a worker **mid-request** is invisible to idempotent callers:
  the supervisor restarts the process, replays the registration journal,
  and the router retries the forwarded request transparently;
* non-idempotent ``drop_qrel`` against a down worker surfaces a
  machine-readable ``worker_unavailable`` error
  (:class:`~repro.client.errors.WorkerUnavailableError`) instead of
  retrying behind the caller's back;
* router drain answers in-flight requests and refuses new connections;
* membership changes (:meth:`Router.add_worker` / ``remove_worker``)
  move only the collections the ring reassigns, with no gap in service.

Worker processes cost ~1 s each to boot, so clusters are module-scoped:
``cluster`` (fast window, identity tests) and ``fault_cluster`` (wide
coalescing window so requests are reliably in flight when we kill the
worker under them).
"""

import asyncio
import json
import os
import time

import numpy as np
import pytest

from repro import cli
from repro.client import (DeadlineExceededError, EvalClient, ServerError,
                          WorkerUnavailableError)
from repro.core import RelevanceEvaluator, aggregate_results, trec
from repro.core import supported_measures
from repro.data.synthetic_ir import synthesize_run
from repro.serve import EvaluationService
from repro.serve.cluster import (CircuitBreaker, HashRing,
                                 RegistrationJournal, Router)
from repro.serve.cluster.journal import JOURNAL_FILE
from repro.serve.cluster.testing import ClusterThread
from repro.serve.frontend import serve_protocol

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
QREL = os.path.join(FIXTURES, "conformance.qrel")
RUN = os.path.join(FIXTURES, "conformance.run")
GOLDEN = os.path.join(FIXTURES, "conformance.golden")

MEASURES = ("map", "ndcg", "recip_rank", "P")


# -- the hash ring (pure, no processes) ---------------------------------------


def test_ring_deterministic_across_instances():
    a = HashRing(["w0", "w1", "w2"])
    b = HashRing(["w2", "w0", "w1"])  # construction order must not matter
    keys = [f"col{i}" for i in range(200)]
    assert [a.owner(k) for k in keys] == [b.owner(k) for k in keys]


def test_ring_balance():
    ring = HashRing([f"w{i}" for i in range(4)])
    keys = [f"collection-{i}" for i in range(2000)]
    counts = {}
    for k in keys:
        counts[ring.owner(k)] = counts.get(ring.owner(k), 0) + 1
    assert set(counts) == {"w0", "w1", "w2", "w3"}
    for n in counts.values():  # 64 virtual nodes: no worker is starved
        assert 0.10 * len(keys) < n < 0.45 * len(keys)


def test_ring_minimal_remap_on_membership_change():
    ring = HashRing(["w0", "w1", "w2"])
    keys = [f"doc{i}" for i in range(1000)]
    before = {k: ring.owner(k) for k in keys}
    grown = ring.copy()
    grown.add("w3")
    moved = [k for k in keys if grown.owner(k) != before[k]]
    # every moved key lands on the newcomer, and only ~1/4 of keys move
    assert moved and all(grown.owner(k) == "w3" for k in moved)
    assert len(moved) < 0.45 * len(keys)
    grown.remove("w3")  # removal restores the previous assignment exactly
    assert {k: grown.owner(k) for k in keys} == before


def test_ring_owners_replica_sets_distinct_and_deterministic():
    ring = HashRing(["w0", "w1", "w2", "w3"])
    other = HashRing(["w3", "w1", "w0", "w2"])  # construction order agnostic
    for i in range(300):
        key = f"col{i}"
        owners = ring.owners(key, 2)
        assert len(owners) == 2 and len(set(owners)) == 2
        assert owners[0] == ring.owner(key)  # primary == the R=1 owner
        assert owners == other.owners(key, 2)
        # asking for more replicas than workers degrades to "everybody"
        assert sorted(ring.owners(key, 99)) == ["w0", "w1", "w2", "w3"]
    with pytest.raises(ValueError):
        ring.owners("x", 0)


def test_ring_owners_minimal_disturbance_on_membership_change():
    ring = HashRing(["w0", "w1", "w2"])
    keys = [f"doc{i}" for i in range(800)]
    before = {k: ring.owners(k, 2) for k in keys}
    grown = ring.copy()
    grown.add("w3")
    changed = 0
    for k in keys:
        after = set(grown.owners(k, 2))
        # the successor walk only gains stops: a set can change only by
        # the newcomer displacing ONE previous member, never by reshuffle
        assert after <= set(before[k]) | {"w3"}
        assert len(after & set(before[k])) >= 1
        if after != set(before[k]):
            assert "w3" in after
            changed += 1
    assert 0 < changed < 0.8 * len(keys)
    grown.remove("w3")  # removal restores every replica set exactly
    assert {k: grown.owners(k, 2) for k in keys} == before


# -- the circuit breaker (pure, no processes) ---------------------------------


def test_breaker_trips_probes_and_recovers():
    now = [0.0]
    b = CircuitBreaker(failures=3, cooldown=2.0, clock=lambda: now[0])
    assert b.state == "closed" and b.would_allow() and b.allow()
    b.record_failure()
    b.record_failure()
    b.record_success()   # any success resets the consecutive count
    b.record_failure()
    b.record_failure()
    assert b.state == "closed"
    b.record_failure()   # third CONSECUTIVE failure trips it
    assert b.state == "open" and b.trips == 1
    assert not b.would_allow() and not b.allow()
    now[0] = 1.0         # still cooling
    assert not b.would_allow()
    now[0] = 2.5         # cooled: exactly one half-open probe
    assert b.would_allow()       # pure check does not consume the probe
    assert b.would_allow()
    assert b.allow()             # the consuming check takes the slot
    assert b.state == "half_open"
    assert not b.would_allow() and not b.allow()  # single probe in flight
    b.record_failure()   # probe failed: straight back to open
    assert b.state == "open" and b.trips == 2
    now[0] = 5.0
    assert b.allow()
    b.record_success()   # probe succeeded: closed again
    assert b.state == "closed" and b.would_allow()
    assert b.stats() == {"state": "closed", "trips": 2,
                         "consecutive_failures": 0}


# -- the registration journal (durable, no processes) -------------------------


def test_journal_durable_roundtrip_and_drop_prune(tmp_path):
    """The prune-on-drop regression: a dropped collection must leave the
    durable log too, or replay after a restart resurrects it."""
    d = str(tmp_path)
    j = RegistrationJournal(d)
    j.record_qrel("web", {"qrel_id": "web", "qrel": {"q1": {"d1": 1}}})
    j.record_run("web", "bm25", {"qrel_id": "web", "run_id": "bm25"})
    j.record_qrel("news", {"qrel_id": "news", "qrel": {"q2": {"d2": 2}}})

    j2 = RegistrationJournal(d)  # a restarted router recovers both
    assert sorted(j2) == ["news", "web"]
    assert list(j2.get("web")["runs"]) == ["bm25"]
    assert j2.counters["recovered_collections"] == 2

    assert j2.record_drop("web") is True
    assert j2.record_drop("web") is False  # already gone
    assert "web" not in j2 and len(j2) == 1

    j3 = RegistrationJournal(d)  # ...and the drop is durable: no zombie
    assert sorted(j3) == ["news"]
    assert j3.get("web") is None


def test_journal_compaction_folds_dead_records(tmp_path):
    d = str(tmp_path)
    j = RegistrationJournal(d, compact_min_dead=4, fsync=False)
    for i in range(6):  # re-registrations supersede: dead records pile up
        j.record_qrel("col", {"qrel_id": "col", "n": i})
    assert j.counters["compactions"] >= 1
    path = os.path.join(d, JOURNAL_FILE)
    with open(path, "rb") as fh:
        lines = fh.read().splitlines()
    assert len(lines) <= 2  # snapshot: only the live entry survives
    j2 = RegistrationJournal(d)
    assert j2.get("col")["qrel"]["n"] == 5


def test_journal_tolerates_torn_tail_and_corrupt_lines(tmp_path):
    d = str(tmp_path)
    j = RegistrationJournal(d)
    j.record_qrel("ok", {"qrel_id": "ok"})
    path = os.path.join(d, JOURNAL_FILE)
    with open(path, "ab") as fh:
        fh.write(b"this is not json\n")                    # corrupt record
        fh.write(b'{"kind": "qrel", "qrel_id": "torn"')    # crash mid-append
    j2 = RegistrationJournal(d)
    assert sorted(j2) == ["ok"]  # torn tail + garbage skipped, not fatal
    assert j2.stats()["skipped_records"] == 1  # the torn line never framed


def test_journal_memory_only_mode(tmp_path):
    j = RegistrationJournal(None)
    j.record_qrel("a", {"qrel_id": "a"})
    assert "a" in j and j.stats()["durable"] is False
    assert j.record_drop("a") is True and len(j) == 0
    assert not os.listdir(tmp_path)  # nothing written anywhere


# -- live clusters ------------------------------------------------------------


@pytest.fixture(scope="module")
def cluster():
    with ClusterThread(
            2, worker_args=["--backend", "single", "--window-ms", "1"],
            router_kw=dict(health_interval=5.0)) as c:
        yield c


@pytest.fixture(scope="module")
def fault_cluster():
    # a wide coalescing window so an evaluate is reliably *in flight* at
    # the worker when the test kills it; health checks pushed out of the
    # way so restarts are driven by the supervisor's proc.wait alone
    with ClusterThread(
            2, worker_args=["--backend", "single", "--window-ms", "250"],
            router_kw=dict(retries=4, health_interval=30.0)) as c:
        yield c


def _distinct_owner_ids(cluster, n=2):
    """qrel_ids owned by n different workers (deterministic: SHA-1 ring)."""
    picked, owners = [], set()
    for i in range(200):
        qid = f"col{i}"
        owner = cluster.owner_of(qid)
        if owner not in owners:
            owners.add(owner)
            picked.append(qid)
            if len(picked) == n:
                return picked
    raise AssertionError(f"ring maps 200 candidate ids onto < {n} workers")


def _wait_all_ready(cluster, timeout=90.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cluster.health()["status"] == "ok":
            return
        time.sleep(0.05)
    raise AssertionError(f"cluster not ready: {cluster.health()}")


# -- bit-identity vs the in-process evaluator ---------------------------------


def test_cluster_ping_health_and_worker_spread(cluster):
    with EvalClient(cluster.host, cluster.port) as client:
        assert client.ping() == "pong"
        health = client.health()
    assert health["status"] == "ok" and health["ready"] == 2
    assert {w["name"] for w in health["workers"]} == {"w0", "w1"}
    ids = _distinct_owner_ids(cluster, n=2)  # both workers take traffic
    assert cluster.owner_of(ids[0]) != cluster.owner_of(ids[1])


def test_cluster_evaluate_bit_identical_across_workers(cluster):
    """One collection per worker; both answer == RelevanceEvaluator."""
    ids = _distinct_owner_ids(cluster, n=2)
    with EvalClient(cluster.host, cluster.port) as client:
        for seed, qrel_id in enumerate(ids):
            run, qrel = synthesize_run(n_queries=12, n_docs=10, seed=seed)
            info = client.register_qrel(qrel_id, qrel, MEASURES)
            assert info["n_queries"] == len(qrel)
            res = client.evaluate(qrel_id, run=run)
            want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
            assert res.per_query == want  # bit-identical floats
            assert res.aggregates == aggregate_results(want)
        # each collection is resident on exactly ONE worker
        stats = client.stats()
    residence = {name: set(w["collections"]) if w else set()
                 for name, w in stats["workers"].items()}
    for qrel_id in ids:
        holders = [n for n, cols in residence.items() if qrel_id in cols]
        assert holders == [cluster.owner_of(qrel_id)], (qrel_id, residence)


def test_cluster_rescoring_run_ref_bit_identical(cluster):
    run, qrel = synthesize_run(n_queries=10, n_docs=8, seed=41)
    ev = RelevanceEvaluator(qrel, ("map", "recip_rank"))
    buf = ev.tokenize_run(run)
    rng = np.random.default_rng(8)
    score_sets = [rng.normal(size=buf.qidx.shape[0]).astype(np.float32)
                  for _ in range(4)]
    with EvalClient(cluster.host, cluster.port) as client:
        client.register_qrel("rescore", qrel, ("map", "recip_rank"))
        client.register_run("rescore", "bm25", run=run)
        results = client.evaluate_many("rescore", run_ref="bm25",
                                       scores_list=score_sets)
    for scores, res in zip(score_sets, results):
        assert res.per_query == ev.evaluate_buffer(buf, scores=scores)


def test_cluster_compare_matches_single_process(cluster):
    run_a, qrel = synthesize_run(n_queries=9, n_docs=7, seed=3)
    run_b, _ = synthesize_run(n_queries=9, n_docs=7, seed=4)
    runs = {"a": run_a, "b": run_b}
    with EvalClient(cluster.host, cluster.port) as client:
        client.register_qrel("cmp", qrel, ("map",))
        got = client.compare("cmp", runs=runs, measure="map",
                             tests=["t", "permutation"],
                             n_permutations=200, seed=7)

    async def direct():
        svc = EvaluationService(backend="single")
        svc.register_qrel("cmp", qrel, ("map",))
        return await svc.compare("cmp", runs=runs, measure="map",
                                 tests=("t", "permutation"),
                                 n_permutations=200, seed=7)

    want = asyncio.run(direct())
    # json round-trip on both sides: NaN-safe bit-exact comparison
    assert json.dumps(got, sort_keys=True) == json.dumps(want,
                                                         sort_keys=True)


def test_cluster_conformance_golden_byte_match(cluster):
    """The CLI golden, reproduced through a 2-worker cluster."""
    selected = sorted(supported_measures)
    keys = cli.ordered_keys(selected)
    qrel = trec.load_qrel(QREL)
    run = trec.load_run(RUN)
    with EvalClient(cluster.host, cluster.port) as client:
        client.register_qrel("conformance", qrel, selected,
                             relevance_level=1)
        res = client.evaluate("conformance", run=run)
    summary = cli._summarize(res.per_query, keys, qrel, complete=False,
                             relevance_level=1)
    lines = [cli.format_line("runid", "all", trec.run_id(RUN)),
             cli.format_line("num_q", "all", summary["num_q"])]
    lines.extend(cli.format_line(k, "all", summary[k]) for k in keys)
    with open(GOLDEN, newline="") as fh:
        assert "\n".join(lines) + "\n" == fh.read()


def test_cluster_large_payload_roundtrip(cluster):
    """>64 KiB register_qrel + evaluate through the router, bit-identical
    (the forwarded frame also carries the spliced router id — headroom)."""
    qrel, run = {}, {}
    rng = np.random.default_rng(17)
    for q in range(80):
        qid = f"query-{q:05d}"
        docs = [f"document-{q:05d}-{d:05d}-padpadpad" for d in range(24)]
        qrel[qid] = {doc: int(rng.integers(0, 3)) for doc in docs}
        run[qid] = {doc: float(rng.normal()) for doc in docs}
    payload = json.dumps({"op": "evaluate", "qrel_id": "big",
                          "run": run}).encode()
    assert len(payload) > 64 * 1024
    with EvalClient(cluster.host, cluster.port) as client:
        client.register_qrel("big", qrel, MEASURES)
        res = client.evaluate("big", run=run)
    want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
    assert res.per_query == want


# -- membership changes -------------------------------------------------------


def test_cluster_add_then_remove_worker_rebalances(cluster):
    # pick a collection the grown ring reassigns to the newcomer, using a
    # local replica of the router's (deterministic) ring
    local = HashRing(["w0", "w1"])
    grown = local.copy()
    grown.add("wx")
    moving = next(f"move{i}" for i in range(500)
                  if grown.owner(f"move{i}") == "wx")
    staying = next(f"move{i}" for i in range(500)
                   if grown.owner(f"move{i}") != "wx")

    run, qrel = synthesize_run(n_queries=8, n_docs=6, seed=9)
    want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
    with EvalClient(cluster.host, cluster.port) as client:
        for qrel_id in (moving, staying):
            client.register_qrel(qrel_id, qrel, MEASURES)
        before = cluster.owner_of(moving)

        assert cluster.add_worker("wx") == "wx"
        assert cluster.owner_of(moving) == "wx" != before
        assert cluster.owner_of(staying) != "wx"
        # no gap in service: the moved collection answers bit-identically
        assert client.evaluate(moving, run=run).per_query == want
        rebalanced = cluster.stats()["router"]["rebalanced_collections"]
        assert rebalanced >= 1

        cluster.remove_worker("wx")
        assert cluster.owner_of(moving) == before
        assert "wx" not in cluster.worker_names
        for qrel_id in (moving, staying):  # moved back, still identical
            assert client.evaluate(qrel_id, run=run).per_query == want
            client.drop_qrel(qrel_id)


# -- fault injection ----------------------------------------------------------


def _wait_worker_inflight(cluster, worker, timeout=20.0):
    """Block until ``worker`` reports an in-flight service request."""

    async def poll():
        slot = cluster.router._slots[worker]
        loop = asyncio.get_running_loop()
        deadline = loop.time() + timeout
        while loop.time() < deadline:
            health = await asyncio.wait_for(slot.proc.client.health(), 5)
            if health["in_flight"] > 0:
                return True
            await asyncio.sleep(0.002)
        return False

    assert cluster.call(poll(), timeout=timeout + 10)


def test_worker_kill_midrequest_retries_transparently(fault_cluster):
    """SIGKILL the owner while an evaluate sits in its coalescing window:
    the caller sees nothing but a slower, still bit-identical response."""
    _wait_all_ready(fault_cluster)
    qrel_id = _distinct_owner_ids(fault_cluster, n=1)[0]
    owner = fault_cluster.owner_of(qrel_id)
    run, qrel = synthesize_run(n_queries=10, n_docs=8, seed=21)
    want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)

    restarts_before = fault_cluster.router.counters["restarts"]
    with EvalClient(fault_cluster.host, fault_cluster.port,
                    timeout=180) as client:
        client.register_qrel(qrel_id, qrel, MEASURES)
        future = client.submit(qrel_id, run=run)
        _wait_worker_inflight(fault_cluster, owner)  # inside the window
        fault_cluster.kill_worker(owner)
        res = future.result(180)  # transparent retry after restart+replay
    assert res.per_query == want
    counters = fault_cluster.router.counters
    assert counters["restarts"] > restarts_before
    assert counters["worker_retries"] >= 1
    assert counters["replayed_collections"] >= 1


def test_drop_qrel_on_down_worker_is_worker_unavailable(fault_cluster):
    """Non-idempotent drop_qrel is never retried: a down owner surfaces a
    machine-readable error, and the journal keeps the collection so the
    restarted worker still has it."""
    _wait_all_ready(fault_cluster)
    qrel_id = _distinct_owner_ids(fault_cluster, n=1)[0] + "-drop"
    owner = fault_cluster.owner_of(qrel_id)
    run, qrel = synthesize_run(n_queries=6, n_docs=5, seed=33)
    with EvalClient(fault_cluster.host, fault_cluster.port,
                    timeout=180) as client:
        client.register_qrel(qrel_id, qrel, MEASURES)
        fault_cluster.kill_worker(owner)
        with pytest.raises(WorkerUnavailableError) as exc_info:
            client.drop_qrel(qrel_id)
        assert exc_info.value.code == "worker_unavailable"
        assert fault_cluster.router.counters["worker_unavailable"] >= 1
        # after the restart the journal was replayed: the collection is
        # back, evaluates identically, and NOW the drop goes through
        _wait_all_ready(fault_cluster)
        res = client.evaluate(qrel_id, run=run)
        want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
        assert res.per_query == want
        assert client.drop_qrel(qrel_id) is True


def test_router_drain_answers_inflight_and_refuses_new():
    """Drain contract: the listener closes first, in-flight requests are
    answered through the cascade, new connections are refused."""

    async def main():
        router = Router(1, worker_args=["--backend", "single",
                                        "--window-ms", "300"],
                        health_interval=30.0)
        await router.start()
        server = await serve_protocol(router.handle, "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)

        async def rpc(obj):
            writer.write(json.dumps(obj).encode() + b"\n")
            await writer.drain()
            return json.loads(await reader.readline())

        reg = await rpc({"op": "register_qrel", "id": 1, "qrel_id": "c",
                         "qrel": {"q1": {"d1": 1, "d2": 0}},
                         "measures": ["map"]})
        assert reg["ok"], reg
        # the evaluate sits in the worker's 300 ms coalescing window;
        # wait until the router has it in flight, then start the drain
        writer.write(json.dumps({"op": "evaluate", "id": 2, "qrel_id": "c",
                                 "run": {"q1": {"d1": 1.0}}}).encode()
                     + b"\n")
        await writer.drain()
        while router._inflight == 0:
            await asyncio.sleep(0.001)
        server.close()
        await server.wait_closed()
        drain = asyncio.get_running_loop().create_task(router.drain())
        answered = json.loads(await reader.readline())
        await drain
        writer.close()
        await writer.wait_closed()
        refused = None
        try:
            r2, w2 = await asyncio.open_connection("127.0.0.1", port)
            w2.close()
        except OSError as exc:
            refused = exc
        return answered, refused

    answered, refused = asyncio.run(main())
    assert answered["ok"] and answered["id"] == 2
    assert answered["result"]["per_query"]["q1"]["map"] == 1.0
    assert isinstance(refused, OSError)  # listener gone


# -- replication (R=2): fan-out, failover, durable drops ----------------------


@pytest.fixture(scope="module")
def replicated_cluster(tmp_path_factory):
    # R=2 over 2 workers: every collection lives on BOTH, reads balance
    # with power-of-two-choices, and the journal is durable on disk.
    # Health probes pushed out of the way: the hedging test SIGSTOPs a
    # worker and must not race the prober's kill-on-hang path.
    state = str(tmp_path_factory.mktemp("cluster-state"))
    with ClusterThread(
            2, worker_args=["--backend", "single", "--window-ms", "1"],
            router_kw=dict(replication=2, retries=4, health_interval=30.0,
                           rng_seed=0, state_dir=state)) as c:
        yield c


def test_replicated_register_fans_out_to_all_replicas(replicated_cluster):
    _wait_all_ready(replicated_cluster)
    run, qrel = synthesize_run(n_queries=10, n_docs=8, seed=51)
    want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
    assert sorted(replicated_cluster.replicas_of("fanout")) == ["w0", "w1"]
    with EvalClient(replicated_cluster.host, replicated_cluster.port) as c:
        c.register_qrel("fanout", qrel, MEASURES)
        assert c.evaluate("fanout", run=run).per_query == want
        stats = c.stats()
    # acked register == resident on EVERY replica, not just the primary
    for name, w in stats["workers"].items():
        assert "fanout" in w["collections"], (name, stats["workers"])
    assert stats["router"]["replication"] == 2
    assert stats["router"]["journal"]["durable"] is True


def test_replicated_kill_one_replica_is_invisible(replicated_cluster):
    """Evaluate keeps answering bit-identically the instant a replica
    dies: the sibling already holds the collection, no restart needed."""
    _wait_all_ready(replicated_cluster)
    run, qrel = synthesize_run(n_queries=12, n_docs=9, seed=52)
    want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
    with EvalClient(replicated_cluster.host, replicated_cluster.port,
                    timeout=120) as c:
        c.register_qrel("failover", qrel, MEASURES)
        victim = replicated_cluster.replicas_of("failover")[0]
        replicated_cluster.kill_worker(victim)
        for _ in range(6):  # p2c will aim some of these at the corpse
            assert c.evaluate("failover", run=run).per_query == want
    _wait_all_ready(replicated_cluster)


def test_replicated_drop_succeeds_with_one_replica_down(replicated_cluster):
    """R=2 drop with a dead replica: acks (any live replica suffices),
    prunes the journal, and the restarted sibling does NOT resurrect it."""
    _wait_all_ready(replicated_cluster)
    run, qrel = synthesize_run(n_queries=6, n_docs=5, seed=53)
    with EvalClient(replicated_cluster.host, replicated_cluster.port,
                    timeout=120) as c:
        c.register_qrel("durable-drop", qrel, MEASURES)
        victim = replicated_cluster.replicas_of("durable-drop")[0]
        replicated_cluster.kill_worker(victim)
        assert c.drop_qrel("durable-drop") is True  # no WorkerUnavailable
        assert "durable-drop" not in replicated_cluster.router._journal
        # the dead replica restarts and replays the journal: the dropped
        # collection must stay dropped everywhere (the resurrection bug)
        _wait_all_ready(replicated_cluster)
        with pytest.raises(ServerError) as exc_info:
            c.evaluate("durable-drop", run=run)
        assert exc_info.value.code == "not_found"


def test_replicated_hedged_request_wins_past_hung_replica(replicated_cluster):
    """SIGSTOP one replica: deadline-carrying evaluates that land on it
    are hedged to the sibling at half the budget and still answer
    bit-identically, well before the deadline expires."""
    _wait_all_ready(replicated_cluster)
    run, qrel = synthesize_run(n_queries=8, n_docs=6, seed=54)
    want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
    counters = replicated_cluster.router.counters
    with EvalClient(replicated_cluster.host, replicated_cluster.port,
                    timeout=120) as c:
        c.register_qrel("hedged", qrel, MEASURES)
        victim = replicated_cluster.replicas_of("hedged")[0]
        hedges_before = counters["hedges"]
        replicated_cluster.pause_worker(victim)
        try:
            for _ in range(16):  # stop as soon as one request hedged
                res = c.evaluate("hedged", run=run, timeout=1.0)
                assert res.per_query == want
                if counters["hedges"] > hedges_before:
                    break
        finally:
            replicated_cluster.resume_worker(victim)
    assert counters["hedges"] > hedges_before
    assert counters["hedge_wins"] > 0
    _wait_all_ready(replicated_cluster)


# -- deadlines ----------------------------------------------------------------


def test_deadline_exceeded_is_a_typed_error(fault_cluster):
    """A deadline shorter than the worker's 250 ms coalescing window
    surfaces as DeadlineExceededError with the machine-readable code —
    and a generous deadline changes nothing about the bytes."""
    _wait_all_ready(fault_cluster)
    run, qrel = synthesize_run(n_queries=6, n_docs=5, seed=55)
    want = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
    counters = fault_cluster.router.counters
    before = counters["deadline_exceeded"]
    with EvalClient(fault_cluster.host, fault_cluster.port,
                    timeout=120) as c:
        c.register_qrel("deadline", qrel, MEASURES)
        with pytest.raises(DeadlineExceededError) as exc_info:
            c.evaluate("deadline", run=run, timeout=0.05)
        assert exc_info.value.code == "deadline_exceeded"
        assert counters["deadline_exceeded"] > before
        # generous deadline: same bytes as no deadline at all
        assert c.evaluate("deadline", run=run, timeout=60).per_query == want
        assert c.drop_qrel("deadline") is True
    with pytest.raises(ValueError):  # local validation, never sent
        with EvalClient(fault_cluster.host, fault_cluster.port) as c:
            c.evaluate("deadline", run=run, timeout=-1)


# -- whole-cluster restart from --state-dir -----------------------------------


def test_cluster_restart_from_state_dir_byte_matches_golden(tmp_path):
    """Kill the WHOLE cluster; boot a fresh one against the same
    --state-dir; the conformance golden reproduces byte-for-byte without
    re-registering anything (acceptance criterion for durability)."""
    state = str(tmp_path / "state")
    selected = sorted(supported_measures)
    keys = cli.ordered_keys(selected)
    qrel = trec.load_qrel(QREL)
    run = trec.load_run(RUN)
    kw = dict(worker_args=["--backend", "single", "--window-ms", "1"],
              router_kw=dict(replication=2, health_interval=30.0,
                             state_dir=state))
    with ClusterThread(2, **kw) as first:
        with EvalClient(first.host, first.port) as c:
            c.register_qrel("conformance", qrel, selected,
                            relevance_level=1)

    with ClusterThread(2, **kw) as reborn:  # same state dir, cold start
        stats = reborn.stats()
        assert stats["router"]["journal"]["recovered_collections"] == 1
        with EvalClient(reborn.host, reborn.port) as c:
            res = c.evaluate("conformance", run=run)  # NO re-registration
    summary = cli._summarize(res.per_query, keys, qrel, complete=False,
                             relevance_level=1)
    lines = [cli.format_line("runid", "all", trec.run_id(RUN)),
             cli.format_line("num_q", "all", summary["num_q"])]
    lines.extend(cli.format_line(k, "all", summary[k]) for k in keys)
    with open(GOLDEN, newline="") as fh:
        assert "\n".join(lines) + "\n" == fh.read()
