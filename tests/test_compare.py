"""The ``compare`` surface: serve op, wire protocol, clients, and the CLI.

One batched sweep + in-JAX significance tests, reachable three ways —
``EvaluationService.compare`` (and its JSON-lines ``compare`` op),
``EvalClient.compare`` over a real socket, and ``python -m repro.compare``
— all of which must agree with :func:`repro.core.sweep.evaluate_sweep` +
:mod:`repro.stats` computed directly.  The CLI output is golden
byte-matched (``tests/fixtures/compare.golden``); the wire tests mirror the
serve layer's standing regressions (>64 KiB frames, cancellation under
``wait_for``) for the new op.
"""

import asyncio
import io
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import compare as compare_cli
from repro import stats
from repro.core import RelevanceEvaluator, evaluate_sweep, trec
from repro.data.synthetic_ir import synthesize_run
from repro.serve import EvaluationService, MicroBatcher, handle_line

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
QREL = os.path.join(FIXTURES, "conformance.qrel")
RUNS = [os.path.join(FIXTURES, f"{name}.run")
        for name in ("conformance", "sweep_b", "sweep_c")]
GOLDEN = os.path.join(FIXTURES, "compare.golden")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def collection():
    run, qrel = synthesize_run(n_queries=12, n_docs=10, seed=3)
    rng = np.random.default_rng(1)
    runs = [{qid: {d: float(s + rng.normal()) for d, s in docs.items()}
             for qid, docs in run.items()} for _ in range(4)]
    return qrel, runs


# -- service op ---------------------------------------------------------------


def test_service_compare_matches_direct_sweep(collection):
    qrel, runs = collection

    async def main():
        svc = EvaluationService(window=0.05, backend="single")
        svc.register_qrel("c", qrel, ("map", "ndcg"))
        resp = await svc.compare("c", runs={"a": runs[0], "b": runs[1],
                                            "c": runs[2]}, measure="ndcg")
        return resp, svc.stats()

    resp, served_stats = asyncio.run(main())
    assert resp["run_names"] == ["a", "b", "c"]
    assert resp["measure"] == "ndcg"
    # the K per-run evaluations coalesced into ONE backend call
    assert served_stats["backend_calls"] == 1
    assert served_stats["in_flight"] == 0

    result = evaluate_sweep(qrel, runs[:3], measures=("map", "ndcg"))
    rep = stats.significance_report(
        np.ascontiguousarray(result.measure("ndcg")))
    assert resp["qids"] == list(result.qids)
    for key in ("t", "p", "p_holm", "p_bonferroni", "diff", "means"):
        assert np.asarray(resp[key]).tolist() == \
            np.asarray(rep[key], dtype=float).tolist(), key
    sig = np.asarray(resp["significant"])
    holm = np.asarray(resp["p_holm"])
    off = ~np.eye(3, dtype=bool)
    assert np.array_equal(sig[off], holm[off] < resp["alpha"])
    assert not sig.diagonal().any()


def test_service_compare_run_refs_path(collection):
    qrel, runs = collection

    async def main():
        svc = EvaluationService(window=0.01, backend="single")
        svc.register_qrel("c", qrel, ("map",))
        for i, r in enumerate(runs[:2]):
            svc.register_run("c", f"sys{i}", run=r)
        resp = await svc.compare("c", run_refs=["sys0", "sys1"])
        with pytest.raises(KeyError, match="unknown run_ref"):
            await svc.compare("c", run_refs=["sys0", "nope"])
        return resp

    resp = asyncio.run(main())
    assert resp["run_names"] == ["sys0", "sys1"]
    result = evaluate_sweep(qrel, runs[:2], measures=("map",))
    rep = result.compare("map")
    assert np.asarray(resp["p"]).tolist() == \
        np.asarray(rep["p"], dtype=float).tolist()


def test_service_compare_validation(collection):
    qrel, runs = collection

    async def main():
        svc = EvaluationService(backend="single")
        svc.register_qrel("c", qrel, ("map",))
        with pytest.raises(ValueError, match="exactly one"):
            await svc.compare("c")
        with pytest.raises(ValueError, match="exactly one"):
            await svc.compare("c", runs=runs[:2], run_refs=["a", "b"])
        with pytest.raises(ValueError, match=">= 2 runs"):
            await svc.compare("c", runs=runs[:1])
        with pytest.raises(ValueError, match="not computed"):
            await svc.compare("c", runs=runs[:2], measure="ndcg")
        with pytest.raises(KeyError, match="unknown qrel_id"):
            await svc.compare("zzz", runs=runs[:2])
        with pytest.raises(ValueError, match="no common judged"):
            await svc.compare("c", runs=[runs[0], {"zz": {"d": 1.0}}])
        with pytest.raises(ValueError, match="run_names for"):
            await svc.compare("c", runs=runs[:3], run_names=["a"])

    asyncio.run(main())


def test_service_compare_cancelled_flush_does_not_hang(collection):
    """PR 6 regression, mirrored for compare: a cancelled micro-batch flush
    must propagate to the K gathered waiters instead of stranding the
    request (and must release the single backpressure slot it held)."""
    qrel, runs = collection

    async def main():
        svc = EvaluationService(window=0.005, backend="single")
        svc.register_qrel("c", qrel, ("map",))

        async def cancelled_flush(key, items):
            raise asyncio.CancelledError()

        svc._batcher = MicroBatcher(cancelled_flush, window=0.005)
        with pytest.raises(asyncio.CancelledError):
            await asyncio.wait_for(svc.compare("c", runs=runs[:3]),
                                   timeout=5.0)
        assert svc.stats()["in_flight"] == 0
        # the slot came back: a healthy compare on a fresh batcher succeeds
        svc._batcher = MicroBatcher(svc._flush, window=0.005)
        resp = await asyncio.wait_for(svc.compare("c", runs=runs[:2]),
                                      timeout=30.0)
        assert resp["run_names"] == ["run_0", "run_1"]

    asyncio.run(main())


# -- wire protocol ------------------------------------------------------------


def test_wire_compare_roundtrip_and_error_codes(collection):
    qrel, runs = collection

    async def main():
        svc = EvaluationService(window=0.01, backend="single")
        out = {}
        out["no_qrel_id"] = json.loads(await handle_line(
            svc, json.dumps({"op": "compare", "id": 1})))
        out["not_found"] = json.loads(await handle_line(svc, json.dumps(
            {"op": "compare", "id": 2, "qrel_id": "zzz",
             "runs": runs[:2]})))
        svc.register_qrel("c", qrel, ("map",))
        out["both"] = json.loads(await handle_line(svc, json.dumps(
            {"op": "compare", "id": 3, "qrel_id": "c", "runs": runs[:2],
             "run_refs": ["a", "b"]})))
        out["bad_measure"] = json.loads(await handle_line(svc, json.dumps(
            {"op": "compare", "id": 4, "qrel_id": "c", "runs": runs[:2],
             "measure": "ndcg"})))
        out["ok"] = json.loads(await handle_line(svc, json.dumps(
            {"op": "compare", "id": 5, "qrel_id": "c",
             "runs": {"a": runs[0], "b": runs[1]}})))
        return out

    out = asyncio.run(main())
    assert not out["no_qrel_id"]["ok"]
    assert out["no_qrel_id"]["code"] == "missing_field"
    assert not out["not_found"]["ok"]
    assert out["not_found"]["code"] == "not_found"
    assert not out["both"]["ok"] and out["both"]["code"] == "invalid"
    assert not out["bad_measure"]["ok"]
    assert out["bad_measure"]["code"] == "invalid"
    ok = out["ok"]
    assert ok["ok"] and ok["id"] == 5
    assert ok["result"]["run_names"] == ["a", "b"]
    assert len(ok["result"]["p"]) == 2


def test_wire_compare_measure_dialects(collection):
    """The compare op's ``measure`` field takes either dialect; errors for
    uncomputed measures name both spellings, malformed ones the input."""
    qrel, runs = collection

    async def main():
        svc = EvaluationService(window=0.01, backend="single")
        svc.register_qrel("c", qrel, ("ndcg_cut", "map"))
        out = {}
        for key, measure in (("ir", "nDCG@10"), ("trec", "ndcg_cut_10"),
                             ("missing", "RBP(p=0.8)"),
                             ("malformed", "Bogus@5")):
            out[key] = json.loads(await handle_line(svc, json.dumps(
                {"op": "compare", "id": 1, "qrel_id": "c",
                 "runs": {"a": runs[0], "b": runs[1]},
                 "measure": measure})))
        return out

    out = asyncio.run(main())
    assert out["ir"]["ok"] and out["trec"]["ok"]
    assert out["ir"]["result"]["measure"] == "ndcg_cut_10"
    assert out["ir"]["result"]["t"] == out["trec"]["result"]["t"]
    miss = out["missing"]
    assert not miss["ok"] and miss["code"] == "invalid"
    assert "rbp_0.80" in miss["error"] and "RBP(p=0.8)" in miss["error"]
    mal = out["malformed"]
    assert not mal["ok"] and mal["code"] == "invalid"
    assert "Bogus@5" in mal["error"]


def test_wire_compare_serializes_infinite_t():
    """A dominated pair has t = ±inf; the JSON-lines reply must carry it
    (Python json emits the non-strict ``Infinity`` literal) and parse back
    to the same float."""
    qrel = trec.load_qrel(QREL)
    run_a = trec.load_run(RUNS[0])
    run_c = trec.load_run(RUNS[2])  # sweep_c dominates on every query

    async def main():
        svc = EvaluationService(window=0.01, backend="single")
        svc.register_qrel("c", qrel, ("map",))
        return json.loads(await handle_line(svc, json.dumps(
            {"op": "compare", "id": 1, "qrel_id": "c",
             "runs": {"a": run_a, "c": run_c}})))

    resp = asyncio.run(main())
    assert resp["ok"], resp
    t = resp["result"]["t"]
    assert t[0][1] == -float("inf") and t[1][0] == float("inf")
    assert resp["result"]["p"][0][1] == 0.0
    assert resp["result"]["significant"][0][1] is True


# -- clients over a real socket (slow) ---------------------------------------


@pytest.mark.slow
def test_client_compare_large_frame_roundtrip(collection):
    """EvalClient.compare with a >64 KiB request line (PR 4 regression,
    extended to the new op) against direct sweep+stats values."""
    from repro.client import EvalClient
    from repro.serve.testing import ServerThread

    big_qrel = {"Q%04d-%s" % (i, "x" * 120):
                {"D%03d-%s" % (d, "y" * 120): int((i + d) % 2)
                 for d in range(12)} for i in range(24)}
    rng = np.random.default_rng(5)
    big_runs = {f"sys{j}": {q: {d: float(s) for d, s in
                                zip(docs, rng.random(len(docs)))}
                            for q, docs in big_qrel.items()}
                for j in range(2)}
    line = json.dumps({"op": "compare", "qrel_id": "big",
                       "runs": big_runs})
    assert len(line) > (1 << 16)

    with ServerThread(service_kw=dict(window=0.02)) as srv:
        with EvalClient(srv.host, srv.port) as client:
            client.register_qrel("big", big_qrel, ("map",))
            resp = client.compare("big", runs=big_runs)
        served = srv.stats()
    assert resp["run_names"] == ["sys0", "sys1"]
    result = evaluate_sweep(big_qrel, list(big_runs.values()),
                            measures=("map",))
    rep = stats.significance_report(np.ascontiguousarray(
        result.measure("map")))
    assert np.asarray(resp["p"]).tolist() == \
        np.asarray(rep["p"], dtype=float).tolist()
    assert served["backend_calls"] <= served["requests"]


# -- CLI ----------------------------------------------------------------------


def _cli(argv):
    buf = io.StringIO()
    assert compare_cli.main(argv, out=buf) == 0
    return buf.getvalue()


def _golden_text():
    with open(GOLDEN, newline="") as fh:
        return fh.read()


def test_compare_cli_byte_matches_golden():
    assert _cli([QREL] + RUNS) == _golden_text()


@pytest.mark.slow
def test_python_dash_m_repro_compare_byte_matches_golden():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.compare", QREL] + RUNS,
        capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout == _golden_text()


def test_compare_cli_golden_matches_direct_stats():
    """Every pair line in the golden re-derived from sweep + stats."""
    qrel = trec.load_qrel(QREL)
    runs = [trec.load_run(p) for p in RUNS]
    result = evaluate_sweep(qrel, runs, measures=("map",),
                            run_names=["conformance", "sweep_b", "sweep_c"])
    rep = result.compare("map")
    pair_lines = [l for l in _golden_text().splitlines()
                  if l.startswith("pair\t")]
    idx = {name: i for i, name in enumerate(result.run_names)}
    assert len(pair_lines) == 3
    for line in pair_lines:
        cells = line.split("\t")
        a, b = cells[1].split(":")
        i, j = idx[a], idx[b]
        assert cells[2] == f"diff={float(rep['diff'][i, j]):+.4f}"
        assert cells[3] == f"t={float(rep['t'][i, j]):+.4f}"
        assert cells[4] == f"p={float(rep['p'][i, j]):.4f}"
        assert cells[5] == f"p_holm={float(rep['p_holm'][i, j]):.4f}"
        starred = cells[-1] == "*"
        assert starred == (float(rep["p_holm"][i, j]) < 0.05), line


def test_compare_cli_repeated_measures_and_permutation():
    out = _cli(["-m", "map", "-m", "ndcg", "--test", "both",
                "--permutations", "200", QREL] + RUNS)
    blocks = [l for l in out.splitlines() if l.startswith("measure\t")]
    assert blocks == ["measure\tall\tmap", "measure\tall\tndcg"]
    pair_lines = [l for l in out.splitlines() if l.startswith("pair\t")]
    assert len(pair_lines) == 6  # 3 pairs x 2 measures
    assert all("p_perm=" in l and "p_perm_holm=" in l for l in pair_lines)


def test_compare_cli_errors():
    with pytest.raises(SystemExit):
        compare_cli.main([QREL, RUNS[0]])  # one run is not a comparison
    with pytest.raises(SystemExit):
        compare_cli.main(["-m", "nosuch", QREL] + RUNS)
