"""Conformance golden tests: TREC-format fixtures → RelevanceEvaluator must
reproduce hand-verified trec_eval values for every SUPPORTED_MEASURES family
(including ``iprec_at_recall`` and ``success``, which the unit tests in
``test_measures.py`` do not cover).

The fixture (tests/fixtures/conformance.{qrel,run}) is small enough to rank
by hand.  trec_eval orders by score descending, ties broken by docno
descending, so:

* q1 run = APPLE:3, CHERRY:2, MANGO:2, BANANA:1 with qrels
  APPLE=2, BANANA=1, CHERRY=0, DATE=1 (DATE unretrieved, MANGO unjudged).
  The 2.0 tie puts MANGO before CHERRY ('M' > 'C').
  Ranking: APPLE(2), MANGO(unjudged), CHERRY(0), BANANA(1); R=3.
* q2 run = EGG:2, APPLE:1 with qrels APPLE=1, EGG=0.
  Ranking: EGG(0), APPLE(1); R=1.

``EXPECTED`` below holds explicit hand-computed goldens for the interesting
keys; the remaining cutoffs of each family are derived from the hand-written
rank/judgment sequences by ``_trec_eval_reference`` — a ~50-line
reimplementation of trec_eval's definitions that is independent of both
``repro.core`` and ``repro.baselines``.
"""

import math
import os

import pytest

from repro.core import (RelevanceEvaluator, measure_keys, supported_measures,
                        trec)
from repro.core.measures import (DEFAULT_CUTOFFS, IPREC_LEVELS,
                                 SUCCESS_CUTOFFS)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")

#: judgments in trec_eval rank order (None = unjudged), hand-derived above
RANKED = {
    "q1": {"rels": [2, None, 0, 1], "R": 3, "N": 1, "ideal": [2, 1, 1, 0]},
    "q2": {"rels": [0, 1], "R": 1, "N": 1, "ideal": [1, 0]},
}

LOG2_3 = math.log2(3)
LOG2_5 = math.log2(5)

#: explicit golden values (trec_eval semantics, computed by hand)
EXPECTED = {
    "q1": {
        "map": 0.5,  # (1/1 + 2/4) / 3
        "gm_map": math.log(0.5),  # log contribution; aggregate = exp(mean)
        "recip_rank": 1.0,
        "Rprec": 1 / 3,  # 1 relevant in the top R=3
        "bpref": 1 / 3,  # APPLE clean, BANANA below 1 nonrel (bound 1)
        "ndcg": (2 + 1 / LOG2_5) / (2 + 1 / LOG2_3 + 0.5),
        "P_5": 0.4,
        "recall_5": 2 / 3,
        "success_1": 1.0,
        "num_ret": 4.0,
        "num_rel": 3.0,
        "num_rel_ret": 2.0,
        "map_cut_5": 0.5,
        "ndcg_cut_5": (2 + 1 / LOG2_5) / (2 + 1 / LOG2_3 + 0.5),
        # 11-pt interpolated precision: recall 1/3 at rank 1 (prec 1.0),
        # recall 2/3 at rank 4 (prec 0.5), recall 1.0 never reached.
        "iprec_at_recall_0.00": 1.0,
        "iprec_at_recall_0.30": 1.0,
        "iprec_at_recall_0.40": 0.5,
        "iprec_at_recall_0.60": 0.5,
        "iprec_at_recall_0.70": 0.0,
        "iprec_at_recall_1.00": 0.0,
        # 3 of the top 5 are judged (APPLE, CHERRY, BANANA; MANGO is not)
        "judged_5": 3 / 5,
        "judged_10": 3 / 10,
        # RBP(p=0.8): relevant at ranks 1 and 4 → 0.2·(0.8^0 + 0.8^3)
        "rbp_0.80": 0.2 * (1.0 + 0.8 ** 3),
        # ERR: max grade 2 → stop = (2^g - 1)/4: [3/4, 0, 0, 1/4];
        # ERR@5 = 3/4·1/1 + (1 - 3/4)·1/4·1/4 = 49/64
        "err_5": 49 / 64,
        "err_10": 49 / 64,
    },
    "q2": {
        "map": 0.5,
        "gm_map": math.log(0.5),
        "recip_rank": 0.5,
        "Rprec": 0.0,  # rank-1 doc (EGG) is non-relevant
        "bpref": 0.0,  # the one relevant doc sits below the one nonrel
        "ndcg": 1 / LOG2_3,
        "P_5": 0.2,
        "recall_5": 1.0,
        "success_1": 0.0,
        "success_5": 1.0,
        "num_ret": 2.0,
        "num_rel": 1.0,
        "num_rel_ret": 1.0,
        # all recall levels are reached at rank 2 with prec 0.5
        "iprec_at_recall_0.00": 0.5,
        "iprec_at_recall_0.50": 0.5,
        "iprec_at_recall_1.00": 0.5,
        "judged_5": 2 / 5,  # both retrieved docs are judged
        "rbp_0.80": 0.2 * 0.8,  # relevant at rank 2 only
        # ERR: max grade 1 → stops [0, 1/2]; ERR@5 = 1/2 · 1/2
        "err_5": 0.25,
    },
}


def _trec_eval_reference(rels, R, N, ideal):
    """All supported measure keys from a hand-written ranked judgment list."""
    level = 1
    binrel = [r is not None and r >= level for r in rels]
    cum = []
    c = 0
    for b in binrel:
        c += b
        cum.append(c)
    n_ret = len(rels)
    prec = [cum[i] / (i + 1) for i in range(n_ret)]
    out = {
        "num_ret": float(n_ret),
        "num_rel": float(R),
        "num_rel_ret": float(cum[-1]) if cum else 0.0,
        "map": sum(p for p, b in zip(prec, binrel) if b) / R if R else 0.0,
        "recip_rank": next((1.0 / (i + 1) for i, b in enumerate(binrel) if b),
                           0.0),
        "Rprec": (cum[min(R, n_ret) - 1] / R) if R and n_ret else 0.0,
    }
    # bpref
    bp, nonrel_above = 0.0, 0
    for r, b in zip(rels, binrel):
        if b:
            bp += (1.0 - min(nonrel_above, R) / min(R, N)
                   if nonrel_above else 1.0)
        elif r is not None:
            nonrel_above += 1
    out["bpref"] = bp / R if R else 0.0
    # gm_map per-query contribution: log of the clipped AP (trec_eval
    # accumulates exactly this; the summary row is exp of the mean).
    out["gm_map"] = math.log(max(out["map"], 1e-5))
    # ndcg family (linear gain)
    dcg = [0.0]
    for i, r in enumerate(rels):
        dcg.append(dcg[-1] + ((r or 0) / math.log2(i + 2) if r and r > 0
                              else 0.0))
    idcg = [0.0]
    for i, r in enumerate(ideal):
        idcg.append(idcg[-1] + (r / math.log2(i + 2) if r > 0 else 0.0))
    out["ndcg"] = dcg[-1] / idcg[-1] if idcg[-1] > 0 else 0.0
    for k in DEFAULT_CUTOFFS:
        ck, ick = dcg[min(k, n_ret)], idcg[min(k, len(ideal))]
        out[f"ndcg_cut_{k}"] = ck / ick if ick > 0 else 0.0
        out[f"P_{k}"] = (cum[min(k, n_ret) - 1] if n_ret else 0) / k
        out[f"recall_{k}"] = ((cum[min(k, n_ret) - 1] / R)
                              if R and n_ret else 0.0)
        ap_k = sum(p for i, (p, b) in enumerate(zip(prec, binrel))
                   if b and i < k)
        out[f"map_cut_{k}"] = ap_k / R if R else 0.0
    for k in SUCCESS_CUTOFFS:
        out[f"success_{k}"] = float(n_ret and cum[min(k, n_ret) - 1] > 0)
    for lv in IPREC_LEVELS:
        target = math.ceil(lv * R)
        best = 0.0
        for i in range(n_ret):
            if cum[i] >= target:
                best = max(prec[i:])
                break
        out[f"iprec_at_recall_{lv:.2f}"] = best if R else 0.0
    # judged@k: fraction of the top k that carries a judgment (÷k, like P@k)
    for k in DEFAULT_CUTOFFS:
        out[f"judged_{k}"] = sum(r is not None for r in rels[:k]) / k
    # RBP at the default persistence: sum of (1-p)·p^(rank-1) over relevant
    p = 0.8
    out["rbp_0.80"] = sum((1 - p) * p ** i for i, b in enumerate(binrel) if b)
    # ERR (cascade model): stop probability (2^g - 1) / 2^G with the
    # per-query max grade G taken from the ideal (sorted-desc) judgments
    G = max(ideal[0] if ideal else 1, 1)
    stops = [(2.0 ** max(r or 0, 0) - 1.0) / 2.0 ** G for r in rels]
    for k in DEFAULT_CUTOFFS:
        err, prior = 0.0, 1.0
        for i, stop in enumerate(stops[:k]):
            err += prior * stop / (i + 1)
            prior *= 1.0 - stop
        out[f"err_{k}"] = err
    return out


@pytest.fixture(scope="module")
def fixture_results():
    qrel = trec.load_qrel(os.path.join(FIXTURES, "conformance.qrel"))
    run = trec.load_run(os.path.join(FIXTURES, "conformance.run"))
    ev = RelevanceEvaluator(qrel, supported_measures)
    return ev.evaluate(run)


def test_fixture_parses_as_expected():
    qrel = trec.load_qrel(os.path.join(FIXTURES, "conformance.qrel"))
    run = trec.load_run(os.path.join(FIXTURES, "conformance.run"))
    assert qrel == {"q1": {"APPLE": 2, "BANANA": 1, "CHERRY": 0, "DATE": 1},
                    "q2": {"APPLE": 1, "EGG": 0}}
    assert run["q1"]["MANGO"] == 2.0 and len(run["q2"]) == 2


def test_hand_verified_goldens(fixture_results):
    for qid, expected in EXPECTED.items():
        for key, val in expected.items():
            assert fixture_results[qid][key] == pytest.approx(val, abs=1e-5), \
                (qid, key)


def test_all_supported_measures_conform(fixture_results):
    keys = measure_keys(supported_measures)
    for qid, spec in RANKED.items():
        want = _trec_eval_reference(spec["rels"], spec["R"], spec["N"],
                                    spec["ideal"])
        got = fixture_results[qid]
        assert set(keys) <= set(got)
        for key in keys:
            assert got[key] == pytest.approx(want[key], abs=1e-5), (qid, key)


def test_reference_densifier_conforms_too():
    qrel = trec.load_qrel(os.path.join(FIXTURES, "conformance.qrel"))
    run = trec.load_run(os.path.join(FIXTURES, "conformance.run"))
    ev = RelevanceEvaluator(qrel, supported_measures, densify="reference")
    res = ev.evaluate(run)
    for qid, expected in EXPECTED.items():
        for key, val in expected.items():
            assert res[qid][key] == pytest.approx(val, abs=1e-5), (qid, key)


def test_array_parse_path_conforms(fixture_results):
    """parse_run_arrays → buffer_from_arrays is the tokenized ingest path."""
    qrel = trec.load_qrel(os.path.join(FIXTURES, "conformance.qrel"))
    with open(os.path.join(FIXTURES, "conformance.run")) as fh:
        qids, docnos, scores = trec.parse_run_arrays(fh)
    assert len(qids) == 6
    ev = RelevanceEvaluator(qrel, supported_measures)
    res = ev.evaluate_buffer(ev.buffer_from_arrays(qids, docnos, scores))
    for qid in fixture_results:
        for key in fixture_results[qid]:
            assert res[qid][key] == pytest.approx(
                fixture_results[qid][key], abs=1e-6), (qid, key)


def test_unjudged_queries_skipped_trec_eval_style(fixture_results):
    """Queries in the run but absent from the qrels are SKIPPED, exactly as
    trec_eval does — and the judged queries' values are untouched by the
    extra traffic, bit-identically across the dict path, the RunBuffer
    path, and the reference densifier."""
    qrel = trec.load_qrel(os.path.join(FIXTURES, "conformance.qrel"))
    run = trec.load_run(os.path.join(FIXTURES, "conformance.run"))
    run["q_unjudged"] = {"APPLE": 3.0, "ZEBRA": 1.0}
    run["q_also_unjudged"] = {"BANANA": 0.5}

    ev = RelevanceEvaluator(qrel, supported_measures)
    res_dict = ev.evaluate(run)
    assert set(res_dict) == {"q1", "q2"}  # intersection semantics

    buf = ev.tokenize_run(run)
    assert len(buf) == 2  # unjudged queries never enter the buffer
    res_buf = ev.evaluate_buffer(buf)
    assert res_buf == res_dict  # bit-identical floats

    ref = RelevanceEvaluator(qrel, supported_measures,
                             densify="reference").evaluate(run)
    assert ref == res_dict

    # and the judged queries are exactly the clean-run values
    assert res_dict == fixture_results


def test_gm_map_hand_computed_reference():
    """Geometric-mean MAP against values computed entirely by hand.

    q1: relevant d1 ranked first → AP = 1.  q2: the only relevant doc (d2)
    is not retrieved → AP = 0, clipped to GM_MIN = 1e-5.  Geometric mean =
    exp((ln 1 + ln 1e-5) / 2) = sqrt(1e-5); the arithmetic MAP is 0.5.
    """
    from repro.core import GM_MIN, aggregate_results

    qrel = {"q1": {"d1": 1}, "q2": {"d2": 1}}
    run = {"q1": {"d1": 2.0, "dx": 1.0}, "q2": {"dy": 1.0}}
    ev = RelevanceEvaluator(qrel, {"map", "gm_map"})
    res = ev.evaluate(run)
    # per-query gm_map is the log contribution
    assert res["q1"]["gm_map"] == pytest.approx(math.log(1.0), abs=1e-6)
    assert res["q2"]["gm_map"] == pytest.approx(math.log(GM_MIN), rel=1e-6)
    agg = aggregate_results(res)
    assert agg["map"] == pytest.approx(0.5, abs=1e-6)
    assert agg["gm_map"] == pytest.approx(math.sqrt(1e-5), rel=1e-4)


def test_gm_map_sharded_aggregate_matches():
    """The sharded path must exp the gm_map aggregate too."""
    from repro.core import aggregate_results
    from repro.distributed import ShardedEvaluator

    qrel = trec.load_qrel(os.path.join(FIXTURES, "conformance.qrel"))
    run = trec.load_run(os.path.join(FIXTURES, "conformance.run"))
    ev = RelevanceEvaluator(qrel, {"map", "gm_map"})
    res = ShardedEvaluator(ev).evaluate(run)
    want = aggregate_results(ev.evaluate(run))
    assert res.aggregates["gm_map"] == pytest.approx(want["gm_map"], rel=1e-6)
    assert res.aggregates["gm_map"] == pytest.approx(0.5, abs=1e-5)


def test_judged_docs_only_hand_computed():
    """trec_eval -J on the fixture, ranked by hand.

    q1 drops unjudged MANGO → ranking APPLE(2), CHERRY(0), BANANA(1):
    AP = (1/1 + 2/3) / 3 = 5/9, P_5 = 2/5, num_ret = 3.  q2 has no
    unjudged docs, so every value matches the plain run exactly.
    """
    qrel = trec.load_qrel(os.path.join(FIXTURES, "conformance.qrel"))
    run = trec.load_run(os.path.join(FIXTURES, "conformance.run"))
    ev = RelevanceEvaluator(qrel, {"map", "P", "num_ret", "judged"},
                            judged_docs_only=True)
    res = ev.evaluate(run)
    assert res["q1"]["map"] == pytest.approx(5 / 9, abs=1e-6)
    assert res["q1"]["P_5"] == pytest.approx(2 / 5, abs=1e-6)
    assert res["q1"]["num_ret"] == 3.0
    assert res["q1"]["judged_5"] == pytest.approx(3 / 5, abs=1e-6)
    assert res["q2"]["map"] == pytest.approx(0.5, abs=1e-6)
    assert res["q2"]["num_ret"] == 2.0

    # upstream pytrec_eval spells the flag judged_docs_only_flag
    alias = RelevanceEvaluator(qrel, {"map"}, judged_docs_only_flag=True)
    assert alias.evaluate(run)["q1"]["map"] == res["q1"]["map"]

    # the flag off reproduces the plain ranking (MANGO counted, AP = 0.5)
    plain = RelevanceEvaluator(qrel, {"map"}).evaluate(run)
    assert plain["q1"]["map"] == pytest.approx(0.5, abs=1e-6)


def test_new_measures_ir_dialect_and_parameters():
    """RBP/ERR/Judged requested via the ir-measures dialect, hand-checked.

    RBP(p=0.5) on q1 (relevant at ranks 1, 4): 0.5·(1 + 0.5^3) = 0.5625.
    """
    qrel = trec.load_qrel(os.path.join(FIXTURES, "conformance.qrel"))
    run = trec.load_run(os.path.join(FIXTURES, "conformance.run"))
    ev = RelevanceEvaluator(
        qrel, ["RBP(p=0.5)", "ERR@5", "Judged@10"])
    res = ev.evaluate(run)
    assert res["q1"]["rbp_0.50"] == pytest.approx(0.5625, abs=1e-6)
    assert res["q1"]["err_5"] == pytest.approx(49 / 64, abs=1e-6)
    assert res["q1"]["judged_10"] == pytest.approx(0.3, abs=1e-6)
    assert res["q2"]["err_5"] == pytest.approx(0.25, abs=1e-6)


def test_qrel_array_parse_roundtrip():
    with open(os.path.join(FIXTURES, "conformance.qrel")) as fh:
        qids, docnos, rels = trec.parse_qrel_arrays(fh)
    rebuilt = {}
    for q, d, r in zip(qids.tolist(), docnos.tolist(), rels.tolist()):
        rebuilt.setdefault(q, {})[d] = int(r)
    assert rebuilt == trec.load_qrel(
        os.path.join(FIXTURES, "conformance.qrel"))
