"""Cross-path consistency: serve vs train logits, padding invariance,
neighbor-sampler validity, synthetic IR pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import graph_data, synthetic_ir as sir
from repro.models import gnn
from repro.models.moe import MoEConfig
from repro.models.transformer import (TransformerConfig, decode_step,
                                      init_transformer, logits_train,
                                      prefill)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                            n_kv_heads=2, d_ff=64, vocab_size=101)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 101)
    return cfg, params, toks


def test_prefill_decode_match_train(tiny_lm):
    cfg, params, toks = tiny_lm
    full = logits_train(params, toks, cfg)
    last, cache = prefill(params, toks[:, :6], cfg, max_seq=12)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, 5]),
                               atol=2e-4)
    for pos in range(6, 9):
        lg, cache = decode_step(params, cache, toks[:, pos], pos, cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, pos]),
                                   atol=2e-4)


def test_moe_prefill_decode_match_train_no_drops():
    cfg = TransformerConfig(
        name="m", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=101,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                      dense_residual=True, capacity_factor=64.0))
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 101)
    full = logits_train(params, toks, cfg)
    last, cache = prefill(params, toks[:, :5], cfg, max_seq=10)
    lg, cache = decode_step(params, cache, toks[:, 5], 5, cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 5]),
                               atol=2e-4)


def test_gnn_padding_invariance():
    cfg = gnn.GatedGCNConfig(name="g", n_layers=2, d_hidden=8, d_in=4,
                             d_edge_in=4, n_classes=3)
    params = gnn.init_gatedgcn(jax.random.PRNGKey(0), cfg)
    g = graph_data.random_graph(graph_data.GraphConfig(
        n_nodes=12, n_edges=30, d_feat=4, d_edge_feat=4, n_classes=3))
    batch = {k: jnp.asarray(v) for k, v in g.items()}
    out = gnn.gatedgcn_forward(params, batch, cfg)
    # pad with 5 fake nodes and 7 fake edges → real-node outputs unchanged
    padded = {
        "node_feat": jnp.pad(batch["node_feat"], ((0, 5), (0, 0))),
        "edge_feat": jnp.pad(batch["edge_feat"], ((0, 7), (0, 0))),
        "src": jnp.pad(batch["src"], (0, 7)),
        "dst": jnp.pad(batch["dst"], (0, 7)),
        "node_mask": jnp.pad(batch["node_mask"], (0, 5)),
        "edge_mask": jnp.pad(batch["edge_mask"], (0, 7)),
        "labels": jnp.pad(batch["labels"], (0, 5)),
    }
    out_p = gnn.gatedgcn_forward(params, padded, cfg)
    np.testing.assert_allclose(np.asarray(out_p[:12]), np.asarray(out),
                               atol=1e-4)


def test_neighbor_sampler_subgraph_validity():
    g = graph_data.random_graph(graph_data.GraphConfig(
        n_nodes=500, n_edges=4000, d_feat=6))
    ns = graph_data.NeighborSampler(g, (4, 3), 32, seed=1)
    sub = ns.sample()
    n_valid = int(sub["node_mask"].sum())
    e_valid = int(sub["edge_mask"].sum())
    assert 32 <= n_valid <= ns.max_nodes
    assert e_valid <= ns.max_edges
    # all edges reference valid local node ids
    assert (sub["src"][sub["edge_mask"]] < n_valid).all()
    assert (sub["dst"][sub["edge_mask"]] < n_valid).all()
    # every sampled edge exists in the source graph
    real = set(zip(g["src"].tolist(), g["dst"].tolist()))
    nodes = np.flatnonzero(sub["node_mask"])
    # reconstruct original ids: position i ↔ original node
    # (sampler stores features; check via feature equality on a few edges)
    for i in np.flatnonzero(sub["edge_mask"])[:10]:
        s_feat = sub["node_feat"][sub["src"][i]]
        assert np.isfinite(s_feat).all()


def test_synthetic_ir_qrels_are_rankable():
    coll = sir.build_collection(sir.CollectionConfig(
        vocab_size=200, n_docs=30, n_queries=20, avg_doc_len=60, seed=1))
    assert coll.doc_term.sum() > 0
    # query terms should make their relevant docs rank above average
    from repro.core import RelevanceEvaluator, aggregate_results

    ev = RelevanceEvaluator(coll.qrels, {"ndcg"})
    run = {}
    for qid in list(coll.qrels)[:20]:
        run[qid] = {f"d{d:06d}": float(s) for d, s in enumerate(
            sir.ql_scores(coll, coll.query_terms[qid]))}
    agg = aggregate_results(ev.evaluate(run))
    # random ranking over 30 docs with 5 relevant would give ndcg ≈ 0.4;
    # QL retrieval on the synthetic collection must do clearly better
    assert agg["ndcg"] > 0.55


def test_qlearning_learns_on_tiny_collection():
    from repro.rl.environment import EnvConfig, QueryExpansionEnv
    from repro.rl.qlearning import QLearningAgent, QLearningConfig

    coll = sir.build_collection(sir.CollectionConfig(
        vocab_size=60, n_docs=15, n_queries=8, avg_doc_len=40,
        avg_query_len=2, seed=2))
    env = QueryExpansionEnv(coll, EnvConfig(depth=10, max_actions=3))
    agent = QLearningAgent(env, QLearningConfig(n_candidate_actions=16,
                                                seed=0))
    qids = list(coll.qrels)[:4]
    rewards = agent.train(qids, episodes=60)
    assert len(rewards) == 60
    assert np.isfinite(rewards).all()
    # Q-table populated and exploitation path runs
    obs = env.reset(qids[0])
    assert 0 <= agent.act(obs) < len(agent.actions)
