"""Densification equivalence: the vectorized run→EvalBatch pipeline must be
*bit-identical* to the retained per-query reference densifier, and both must
agree with the independent pure-Python trec_eval engine.

Stress surface: duplicate scores (tie-breaks), unjudged (out-of-vocabulary)
docs, empty-qrel queries, non-ASCII docnos, uneven ranking depths, and both
join/rank regimes (dense table vs searchsorted, counting rank vs argsort).
"""

import random

import numpy as np
import pytest

from repro.baselines import pure_eval
from repro.core import RelevanceEvaluator, RunBuffer

MEASURES = ("map", "ndcg", "ndcg_cut", "P", "recall", "recip_rank", "Rprec",
            "bpref", "success", "map_cut", "num_ret", "num_rel",
            "num_rel_ret")


def _random_case(rng, with_oov=True, with_nonascii=True, with_ties=True,
                 with_empty_qrel=True, max_docs=60):
    run, qrel = {}, {}
    nq = rng.randint(1, 8)
    for qi in range(nq):
        qid = f"q{qi}"
        docs = [f"d{j:03d}" for j in range(rng.randint(1, max_docs))]
        if with_nonascii:
            docs += ["δοκίμιο", "文档-甲", "ß-umlaut"]
        if with_oov:
            docs += [f"oov{j}" for j in range(rng.randint(1, 4))]
        rng.shuffle(docs)
        score_pool = ([0.0, 0.5, 1.0, 2.0] if with_ties
                      else [rng.random() for _ in docs])
        run[qid] = {d: rng.choice(score_pool) + (0 if with_ties
                                                 else rng.random())
                    for d in docs}
        judged = [d for d in docs if not d.startswith("oov")]
        judged = rng.sample(judged, k=rng.randint(0, len(judged)))
        qrel[qid] = {d: rng.randint(0, 3) for d in judged}
        # judged-but-unretrieved docs (affect R and the ideal ranking)
        for j in range(rng.randint(0, 4)):
            qrel[qid][f"extra{j}"] = rng.randint(0, 2)
        if not qrel[qid]:
            qrel[qid]["extra0"] = 1
    if with_empty_qrel:
        qrel["q_empty"] = {}
        run["q_empty"] = {"dX": 1.0, "dY": 1.0}
    return run, qrel


def _assert_bit_identical(run, qrel, measures=("map", "ndcg"), **ev_kw):
    ev_vec = RelevanceEvaluator(qrel, measures, **ev_kw)
    ev_ref = RelevanceEvaluator(qrel, measures, densify="reference", **ev_kw)
    qids = [q for q in run if q in qrel]
    batch_vec, _ = ev_vec._densify(run, qids)
    batch_ref, _ = ev_ref._densify(run, qids)
    for field in batch_vec._fields:
        a = np.asarray(getattr(batch_vec, field))
        b = np.asarray(getattr(batch_ref, field))
        assert a.dtype == b.dtype, field
        assert a.shape == b.shape, field
        assert np.array_equal(a, b), (
            field, np.argwhere(a != b)[:5].tolist())
    return ev_vec


def test_bit_identical_randomized():
    rng = random.Random(1234)
    for _ in range(12):
        run, qrel = _random_case(rng)
        _assert_bit_identical(run, qrel)


def test_bit_identical_fully_judged_token_fast_path():
    # No OOV docs → the integer counting-sort path; must still be identical.
    rng = random.Random(7)
    for _ in range(6):
        run, qrel = _random_case(rng, with_oov=False, with_empty_qrel=False)
        for qid in run:  # judge every retrieved doc
            for d in run[qid]:
                qrel[qid].setdefault(d, rng.randint(0, 2))
        _assert_bit_identical(run, qrel)


def test_bit_identical_searchsorted_regimes():
    # Force the sparse join + argsort rank fallbacks via the caps.
    rng = random.Random(99)
    run, qrel = _random_case(rng)

    class SmallCaps(RelevanceEvaluator):
        _DENSE_JOIN_CAP = 0
        _COUNTING_RANK_CAP = 0

    ev_vec = SmallCaps(qrel, ("map", "ndcg"))
    assert ev_vec._rel_table is None
    ev_ref = RelevanceEvaluator(qrel, ("map", "ndcg"), densify="reference")
    qids = [q for q in run if q in qrel]
    bv, _ = ev_vec._densify(run, qids)
    br, _ = ev_ref._densify(run, qids)
    for field in bv._fields:
        assert np.array_equal(np.asarray(getattr(bv, field)),
                              np.asarray(getattr(br, field))), field


def test_bit_identical_relevance_level_2():
    rng = random.Random(5)
    run, qrel = _random_case(rng)
    _assert_bit_identical(run, qrel, relevance_level=2)


def test_duplicate_scores_tie_break_exact():
    # every score identical → ranking decided purely by docno desc-lex
    docs = ["a", "B", "ähnlich", "Z9", "z1", "中文"]
    qrel = {"q": {d: i % 2 for i, d in enumerate(docs)}}
    run = {"q": {d: 1.0 for d in docs}}
    ev = _assert_bit_identical(run, qrel, measures=MEASURES)
    ours = ev.evaluate(run)["q"]
    ref = pure_eval.evaluate(run, qrel, MEASURES)["q"]
    for k, v in ref.items():
        assert ours[k] == pytest.approx(v, abs=2e-4), k


def test_matches_pure_python_engine_randomized():
    rng = random.Random(31)
    for _ in range(8):
        run, qrel = _random_case(rng)
        ev = RelevanceEvaluator(qrel, MEASURES)
        ours = ev.evaluate(run)
        ref = pure_eval.evaluate(
            {q: d for q, d in run.items() if qrel.get(q)},
            qrel, MEASURES)
        for qid in ref:
            for key, val in ref[qid].items():
                assert ours[qid][key] == pytest.approx(val, abs=2e-4), \
                    (qid, key)


def test_empty_qrel_query_all_zero():
    qrel = {"q": {}}
    run = {"q": {"d1": 2.0, "d2": 1.0}}
    ev = _assert_bit_identical(run, qrel, measures=("map", "ndcg", "num_ret"))
    res = ev.evaluate(run)["q"]
    assert res["map"] == 0.0 and res["ndcg"] == 0.0
    assert res["num_ret"] == 2.0


def test_evaluate_many_sequence_and_mapping():
    qrel = {"q": {"d1": 1, "d2": 0}}
    ev = RelevanceEvaluator(qrel, ("map",))
    run_a = {"q": {"d1": 2.0, "d2": 1.0}}
    run_b = {"q": {"d1": 1.0, "d2": 2.0}}
    seq = ev.evaluate_many([run_a, run_b])
    assert seq[0]["q"]["map"] == pytest.approx(1.0)
    assert seq[1]["q"]["map"] == pytest.approx(0.5)
    named = ev.evaluate_many({"a": run_a, "b": run_b})
    assert named["a"] == seq[0] and named["b"] == seq[1]


def test_run_buffer_matches_evaluate():
    rng = random.Random(77)
    run, qrel = _random_case(rng)
    ev = RelevanceEvaluator(qrel, ("map", "ndcg", "recip_rank"))
    want = ev.evaluate(run)
    buf = ev.tokenize_run(run)
    assert isinstance(buf, RunBuffer)
    got = ev.evaluate_buffer(buf)
    assert got.keys() == want.keys()
    for qid in want:
        for k in want[qid]:
            assert got[qid][k] == pytest.approx(want[qid][k], abs=1e-7), \
                (qid, k)


def test_run_buffer_fresh_scores_no_string_work():
    qrel = {"q1": {"d1": 1, "d2": 0, "d3": 2}, "q2": {"d1": 1}}
    run = {"q1": {"d1": 1.0, "d2": 3.0, "d3": 2.0}, "q2": {"d1": 0.5}}
    ev = RelevanceEvaluator(qrel, ("map", "ndcg"))
    buf = ev.tokenize_run(run)
    # flip q1's ordering via fresh flat scores (buffer's query order)
    new_scores = np.array([3.0, 1.0, 2.0, 0.5], dtype=np.float32)
    got = ev.evaluate_buffer(buf, new_scores)
    want = ev.evaluate({"q1": {"d1": 3.0, "d2": 1.0, "d3": 2.0},
                        "q2": {"d1": 0.5}})
    for qid in want:
        for k in want[qid]:
            assert got[qid][k] == pytest.approx(want[qid][k]), (qid, k)


def test_buffer_from_tokens_pretokenized():
    qrel = {"q": {"a": 1, "b": 0, "c": 2}}
    ev = RelevanceEvaluator(qrel, ("map", "ndcg", "recip_rank"))
    vocab = ev.vocab.tolist()
    docs = ["c", "a", "b"]
    tokens = np.array([vocab.index(d) for d in docs], dtype=np.int64)
    scores = np.array([1.0, 3.0, 2.0], dtype=np.float32)
    buf = ev.buffer_from_tokens(["q"], [3], tokens, scores)
    got = ev.evaluate_buffer(buf)["q"]
    want = ev.evaluate({"q": dict(zip(docs, scores.tolist()))})["q"]
    for k in want:
        assert got[k] == pytest.approx(want[k]), k


def test_buffer_from_tokens_oov_and_validation():
    qrel = {"q": {"a": 1}}
    ev = RelevanceEvaluator(qrel, ("map", "num_ret"))
    # OOV doc (-1) is unjudged but still counts as retrieved
    buf = ev.buffer_from_tokens(["q"], [2], np.array([0, -1]),
                                np.array([1.0, 2.0], np.float32))
    res = ev.evaluate_buffer(buf)["q"]
    assert res["num_ret"] == 2.0
    assert res["map"] == pytest.approx(0.5)  # "a" ranked second
    with pytest.raises(KeyError):
        ev.buffer_from_tokens(["nope"], [1], np.array([0]))
    with pytest.raises(ValueError):
        ev.buffer_from_tokens(["q"], [2], np.array([0]))


def test_buffer_from_arrays_matches_dict_path():
    qrel = {"q1": {"d1": 1, "d2": 0}, "q2": {"d9": 2}}
    run = {"q1": {"d1": 0.3, "d2": 0.9}, "q2": {"d9": 1.0, "dx": 2.0}}
    ev = RelevanceEvaluator(qrel, ("map", "ndcg"))
    qids, docnos, scores = [], [], []
    for q, docs in run.items():
        for d, s in docs.items():
            qids.append(q), docnos.append(d), scores.append(s)
    # extra row for an unjudged query must be dropped
    qids.append("q_unknown"), docnos.append("d1"), scores.append(9.0)
    buf = ev.buffer_from_arrays(np.array(qids), np.array(docnos),
                                np.array(scores, np.float32))
    got = ev.evaluate_buffer(buf)
    want = ev.evaluate(run)
    assert got.keys() == want.keys()
    for qid in want:
        for k in want[qid]:
            assert got[qid][k] == pytest.approx(want[qid][k]), (qid, k)


def test_streaming_metric_update_run():
    from repro.core import measures as M
    from repro.core import streaming

    qrel = {"q1": {"d1": 1, "d2": 0}, "q2": {"d3": 1}}
    run = {"q1": {"d1": 2.0, "d2": 1.0}, "q2": {"d3": 1.0, "d4": 2.0}}
    ev = RelevanceEvaluator(qrel, ("recip_rank",))
    buf = ev.tokenize_run(run)
    state = streaming.metric_init(("recip_rank",))
    state = streaming.metric_update_run(state, ev, buf, buf.scores,
                                        ("recip_rank",))
    means = streaming.metric_finalize(state)
    assert float(means["recip_rank"]) == pytest.approx((1.0 + 0.5) / 2)
