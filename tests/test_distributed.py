"""Distribution machinery on multiple fake devices (subprocess-isolated:
the device count must be set before jax initializes)."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_evaluate_matches_local():
    """The shard_map evaluator must equal single-device evaluation."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import measures as M
        from repro.distributed.collectives import sharded_evaluate

        rng = np.random.default_rng(0)
        q, d = 16, 40
        scores = jnp.asarray(rng.standard_normal((q, d)).astype(np.float32))
        rel = jnp.asarray(rng.integers(0, 2, (q, d)).astype(np.float32))
        batch = M.batch_from_dense(scores, rel)
        mesh = jax.make_mesh((8,), ("data",))
        with mesh:
            out = jax.jit(lambda b: sharded_evaluate(
                b, ("ndcg", "recip_rank"), mesh))(batch)
        parsed = M.parse_measures(("ndcg", "recip_rank"))
        per_q = M.compute_measures(batch, parsed)
        want = M.aggregate(per_q, batch.query_mask)
        for k in out:
            np.testing.assert_allclose(float(out[k]), float(want[k]),
                                       atol=1e-5)
        print("OK")
    """)


def test_compressed_psum_dp_equivalence():
    """bf16/int8-compressed DP all-reduce approximates the exact mean."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import shard_map
        from repro.distributed.collectives import compressed_psum
        from repro.train import compression

        mesh = jax.make_mesh((8,), ("data",))
        g = jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 64.0

        def dp_mean(method):
            def f(gl):
                grads = {"w": gl}
                err = compression.init_error_state(grads)
                out, _ = compressed_psum(grads, "data", method, err)
                return out["w"]
            return jax.jit(shard_map(
                f, mesh=mesh, in_specs=P("data", None),
                out_specs=P("data", None), check_vma=False))(g)

        exact = dp_mean("none")
        want = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
        np.testing.assert_allclose(np.asarray(exact)[:1],
                                   np.asarray(want)[:1], atol=1e-6)
        for method, tol in (("bf16", 1e-2), ("int8", 2e-2)):
            approx = dp_mean(method)
            err = float(jnp.abs(approx - exact).max())
            assert err < tol, (method, err)
        print("OK")
    """)


def test_mini_dryrun_lm_and_retrieval():
    """End-to-end: lower+compile smoke cells on 2×2 and 2×2×2 meshes."""
    out = _run("""
        import jax
        import repro.launch.dryrun as dr
        from repro.launch.api import get_arch
        from repro.configs.common import smoke_shape

        def mini(name, devices_per_pod=4):
            if name == "multi":
                return jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                                     devices=jax.devices()[:8])
            return jax.make_mesh((2, 2), ("data", "model"),
                                 devices=jax.devices()[:4])
        dr._mesh_for = mini

        for arch_name, sname, o in (
            ("qwen3-moe-235b-a22b", "train_4k",
             {"seq_len": 16, "global_batch": 8}),
            ("sasrec", "retrieval_cand",
             {"n_candidates": 64, "topk": 8}),
            ("gatedgcn", "molecule", {"n_nodes": 64, "n_edges": 128,
             "d_feat": 8, "n_classes": 4, "n_graphs": 8}),
        ):
            arch = get_arch(arch_name)
            arch.shapes = dict(arch.shapes)
            arch.shapes[sname] = smoke_shape(arch.shapes[sname], **o)
            for mesh_name in ("single", "multi"):
                rec = dr.run_cell(arch_name, sname, mesh_name, smoke=True)
                assert rec["status"] == "ok", rec.get("error")
                assert rec["collectives"]["total"] > 0, "no collectives?"
        print("OK")
    """)
    assert "OK" in out


def test_elastic_restore_under_new_topology():
    """A checkpoint saved under one mesh restores under another (elastic)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as C

        mesh8 = jax.make_mesh((8,), ("data",))
        x = jax.device_put(jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
                           NamedSharding(mesh8, P("data", None)))
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 1, {"x": x})
            # "job restarted on half the chips"
            mesh4 = jax.make_mesh((4,), ("data",),
                                  devices=jax.devices()[:4])
            restored, _ = C.restore(
                d, 1, {"x": jax.ShapeDtypeStruct((8, 4), jnp.float32)})
            y = jax.device_put(restored["x"],
                               NamedSharding(mesh4, P("data", None)))
            np.testing.assert_allclose(np.asarray(y), np.asarray(x))
        print("OK")
    """)
