"""Documentation must execute: every ```python block in README.md and
docs/ARCHITECTURE.md runs as-is (blocks within one file share a namespace,
so later snippets may build on earlier ones), and the public-API docstring
examples run under doctest."""

import doctest
import os
import re

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

_BLOCK = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _python_blocks(path):
    with open(os.path.join(ROOT, path)) as fh:
        return _BLOCK.findall(fh.read())


@pytest.mark.parametrize("path", ["README.md", "docs/ARCHITECTURE.md",
                                  "docs/SERVING.md", "docs/CONFORMANCE.md"])
def test_doc_code_blocks_run(path):
    blocks = _python_blocks(path)
    assert blocks, f"{path} has no python blocks?"
    ns = {}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"{path}:block{i}", "exec"), ns)
        except Exception as e:  # pragma: no cover - the assertion message
            raise AssertionError(
                f"{path} block {i} failed: {e}\n---\n{block}") from e


@pytest.mark.parametrize("module_name", [
    "repro.core.evaluator",
    "repro.core.trec",
    "repro.serve",
    "repro.serve.service",
    "repro.serve.cache",
    "repro.serve.batcher",
    "repro.serve.wire",
    "repro.serve.testing",
    "repro.client",
    "repro.client.aio",
    "repro.client.sync",
])
def test_docstring_examples(module_name):
    import importlib

    module = importlib.import_module(module_name)
    results = doctest.testmod(module, verbose=False, report=True)
    assert results.attempted > 0, f"{module_name}: no doctests collected"
    assert results.failed == 0, f"{module_name}: {results.failed} doctest(s) failed"


def test_readme_documents_required_sections():
    with open(os.path.join(ROOT, "README.md")) as fh:
        readme = fh.read()
    for needle in ("python -m repro", "make verify", "Module map",
                   "tokenize_run", "ShardedEvaluator", "repro.serve",
                   "EvaluationService"):
        assert needle in readme, needle
