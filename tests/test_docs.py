"""Documentation must execute: every ```python block in README.md and
docs/ARCHITECTURE.md runs as-is (blocks within one file share a namespace,
so later snippets may build on earlier ones), and the public-API docstring
examples run under doctest."""

import doctest
import os
import re

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

_BLOCK = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _python_blocks(path):
    with open(os.path.join(ROOT, path)) as fh:
        return _BLOCK.findall(fh.read())


@pytest.mark.parametrize("path", ["README.md", "docs/ARCHITECTURE.md",
                                  "docs/SERVING.md", "docs/CONFORMANCE.md",
                                  "docs/EXPERIMENTS.md", "docs/MEASURES.md"])
def test_doc_code_blocks_run(path):
    blocks = _python_blocks(path)
    assert blocks, f"{path} has no python blocks?"
    ns = {}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"{path}:block{i}", "exec"), ns)
        except Exception as e:  # pragma: no cover - the assertion message
            raise AssertionError(
                f"{path} block {i} failed: {e}\n---\n{block}") from e


@pytest.mark.parametrize("module_name", [
    "repro.core.evaluator",
    "repro.core.registry",
    "repro.core.trec",
    "repro.serve",
    "repro.serve.service",
    "repro.serve.cache",
    "repro.serve.batcher",
    "repro.serve.wire",
    "repro.serve.testing",
    "repro.serve.cluster.ring",
    "repro.serve.cluster.breaker",
    "repro.serve.cluster.journal",
    "repro.serve.cluster.chaos",
    "repro.client",
    "repro.client.aio",
    "repro.client.sync",
    "repro.kernels.ops",
    "repro.kernels.bucketing",
    "repro.kernels.autotune",
    "repro.stats",
    "repro.stats.significance",
    "repro.stats.corrections",
    "repro.core.sweep",
])
def test_docstring_examples(module_name):
    import importlib

    module = importlib.import_module(module_name)
    results = doctest.testmod(module, verbose=False, report=True)
    assert results.attempted > 0, f"{module_name}: no doctests collected"
    assert results.failed == 0, f"{module_name}: {results.failed} doctest(s) failed"


def test_readme_documents_required_sections():
    with open(os.path.join(ROOT, "README.md")) as fh:
        readme = fh.read()
    for needle in ("python -m repro", "make verify", "Module map",
                   "tokenize_run", "ShardedEvaluator", "repro.serve",
                   "EvaluationService", "REPRO_INTERPRET",
                   "kernels/bucketing.py"):
        assert needle in readme, needle


def test_benchmark_segment_names_match_docs():
    """`benchmarks.run --list` and the docs must name the same segments.

    The registry (``benchmarks.run.SEGMENTS``) is the single source of
    truth; the run.py module docstring and the README's segment list must
    mention every name, so ``--only`` help, docs, and CI never drift.
    """
    import sys

    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import SEGMENTS
    finally:
        sys.path.pop(0)
    names = list(SEGMENTS)
    assert len(names) == len(set(names))

    import benchmarks.run as run_mod

    for name in names:
        assert f"``{name}``" in run_mod.__doc__, (
            f"segment {name!r} missing from benchmarks/run.py docstring")
    with open(os.path.join(ROOT, "README.md")) as fh:
        readme = fh.read()
    m = re.search(r"Full segment list: (.*?)\.\n", readme, re.DOTALL)
    assert m, "README.md lost its 'Full segment list:' line"
    readme_names = re.findall(r"`([a-z0-9_]+)`", m.group(1))
    assert readme_names == names, (
        f"README segment list {readme_names} != registry {names}")
