"""pytrec_eval API parity, TREC formats, CLI + serialize-invoke-parse."""

import io
import os
import subprocess
import sys
import tempfile

import pytest

from repro.baselines import workflow
from repro.core import RelevanceEvaluator, measure_keys, trec


def test_paper_code_snippet():
    """The minimal example from the paper's Code snippet 1."""
    qrel = {"q1": {"d1": 0, "d2": 1}, "q2": {"d1": 1}}
    evaluator = RelevanceEvaluator(qrel, {"map", "ndcg"})
    run = {"q1": {"d1": 1.0, "d2": 0.0}, "q2": {"d1": 1.5, "d2": 0.2}}
    results = evaluator.evaluate(run)
    assert set(results) == {"q1", "q2"}
    for qid in results:
        assert set(results[qid]) == {"map", "ndcg"}
    # q2: d1 relevant ranked first (d2 unjudged → non-relevant)
    assert results["q2"]["map"] == 1.0
    # q1: the only relevant doc (d2) is ranked second
    assert results["q1"]["map"] == pytest.approx(0.5)


def test_measure_keys_cutoff_families():
    keys = measure_keys(("ndcg_cut", "P.5,10", "map"))
    assert "ndcg_cut_5" in keys and "ndcg_cut_1000" in keys
    assert "P_5" in keys and "P_10" in keys and "P_15" not in keys
    assert "map" in keys


def test_unsupported_measure_raises():
    with pytest.raises(ValueError):
        RelevanceEvaluator({"q": {"d": 1}}, {"not_a_measure"})


def test_trec_roundtrip():
    run = {"q1": {"d1": 1.5, "d2": -0.25}, "q2": {"d9": 3.0}}
    qrel = {"q1": {"d1": 2, "d2": 0}, "q2": {"d9": 1}}
    buf = io.StringIO()
    trec.write_run(buf, run)
    assert trec.parse_run(io.StringIO(buf.getvalue())) == run
    buf = io.StringIO()
    trec.write_qrel(buf, qrel)
    assert trec.parse_qrel(io.StringIO(buf.getvalue())) == qrel


def test_malformed_lines_raise():
    with pytest.raises(ValueError):
        trec.parse_run(io.StringIO("q1 Q0 d1 0 1.0\n"))  # 5 fields
    with pytest.raises(ValueError):
        trec.parse_qrel(io.StringIO("q1 0 d1\n"))


def test_cli_output_format(tmp_path):
    run = {"q1": {"d1": 2.0, "d2": 1.0}}
    qrel = {"q1": {"d1": 1, "d2": 0}}
    trec.save_run(str(tmp_path / "r.run"), run)
    trec.save_qrel(str(tmp_path / "r.qrel"), qrel)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.baselines.trec_eval_cli", "-q",
         "-m", "map", str(tmp_path / "r.qrel"), str(tmp_path / "r.run")],
        capture_output=True, text=True, check=True,
        env={**os.environ, "PYTHONPATH": src})
    lines = out.stdout.strip().splitlines()
    assert lines[0].split("\t") == ["map", "q1", "1.0000"]
    assert lines[-1].split("\t") == ["map", "all", "1.0000"]


def test_serialize_invoke_parse_matches_in_process(tmp_path):
    """RQ1's two workflows must agree on the measure values."""
    run = {"q1": {"d1": 0.3, "d2": 0.9, "d3": 0.1}}
    qrel = {"q1": {"d1": 1, "d3": 2}}
    stdout = workflow.serialize_invoke_parse(run, qrel, str(tmp_path),
                                             measures=("map", "ndcg"))
    parsed = {}
    for line in stdout.splitlines():
        meas, qid, val = line.split("\t")
        parsed[(meas, qid)] = float(val)
    res = RelevanceEvaluator(qrel, ("map", "ndcg")).evaluate(run)["q1"]
    assert parsed[("map", "q1")] == pytest.approx(res["map"], abs=1e-4)
    assert parsed[("ndcg", "q1")] == pytest.approx(res["ndcg"], abs=1e-4)
