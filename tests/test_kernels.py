"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import measures as M
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("q,d,k", [
    (1, 257, 10), (3, 1000, 100), (2, 4096, 1000), (5, 64, 64), (1, 10000, 13),
])
def test_topk_matches_lax(q, d, k):
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    v, i = ops.topk(scores, k)
    rv, ri = ref.topk_ref(scores, k)
    kk = min(k, d)
    np.testing.assert_allclose(np.asarray(v)[:, :kk], np.asarray(rv)[:, :kk])
    np.testing.assert_array_equal(np.asarray(i)[:, :kk],
                                  np.asarray(ri)[:, :kk])


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_topk_ties_break_by_index(dtype):
    # heavy ties: the kernel must match lax.top_k's lower-index-first rule
    scores = jnp.asarray(
        RNG.choice(np.array([0.0, 1.0, 2.0], np.float32), size=(4, 2000)))
    v, i = ops.topk(scores, 50)
    rv, ri = jax.lax.top_k(scores, 50)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_topk_handles_short_rows():
    scores = jnp.asarray(RNG.standard_normal((2, 5)).astype(np.float32))
    v, i = ops.topk(scores, 8)
    rv, ri = ref.topk_ref(scores, 8)
    np.testing.assert_allclose(np.asarray(v)[:, :5], np.asarray(rv)[:, :5])


@pytest.mark.parametrize("q,d", [(3, 64), (8, 200), (13, 1024), (1, 4096)])
def test_fused_measures_matches_ref(q, d):
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    rel = jnp.asarray(RNG.integers(0, 4, (q, d)).astype(np.float32))
    judged = jnp.asarray(RNG.random((q, d)) < 0.6)
    batch = M.batch_from_dense(scores, rel, judged=judged)
    s = M.sort_batch(batch)
    scal = ops.make_scalars(batch.n_rel, batch.n_judged_nonrel,
                            batch.ideal_rel)
    got = ops.fused_measures_cols(s.rel, s.judged, scal)
    want = ref.fused_measures_ref(s.rel, s.judged, scal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_fused_evaluate_matches_measure_core():
    q, d = 9, 300
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    rel = jnp.asarray(RNG.integers(0, 3, (q, d)).astype(np.float32))
    batch = M.batch_from_dense(scores, rel)
    fused = ops.evaluate_fused(batch)
    parsed = M.parse_measures(("map", "ndcg", "ndcg_cut", "P", "recall",
                               "recip_rank", "Rprec", "bpref", "success"))
    want = M.compute_measures(batch, parsed)
    for k, v in want.items():
        np.testing.assert_allclose(np.asarray(fused[k]), np.asarray(v),
                                   atol=2e-4, rtol=2e-4, err_msg=k)


@pytest.mark.parametrize("v,e,b,l", [(30, 8, 4, 20), (100, 32, 10, 64),
                                     (11, 16, 3, 7)])
def test_embedding_bag_matches_ref(v, e, b, l):
    table = jnp.asarray(RNG.standard_normal((v, e)).astype(np.float32))
    seg = jnp.asarray(np.sort(RNG.integers(0, b, l)).astype(np.int32))
    idx = jnp.asarray(RNG.integers(0, v, l).astype(np.int32))
    w = jnp.asarray(RNG.random(l).astype(np.float32))
    got = ops.embedding_bag(table, idx, seg, b, w)
    want = ref.embedding_bag_ref(table, idx, seg, b, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_embedding_bag_empty_bags_zero():
    table = jnp.ones((5, 4), jnp.float32)
    idx = jnp.asarray([1, 2], jnp.int32)
    seg = jnp.asarray([2, 2], jnp.int32)
    out = ops.embedding_bag(table, idx, seg, 4)
    np.testing.assert_allclose(np.asarray(out[0]), 0.0)
    np.testing.assert_allclose(np.asarray(out[2]), 2.0)
    np.testing.assert_allclose(np.asarray(out[3]), 0.0)


def test_embedding_module_kernel_path_matches_reference_path():
    from repro.models import embedding as E

    table = jnp.asarray(RNG.standard_normal((50, 8)).astype(np.float32))
    idx = jnp.asarray(np.sort(RNG.integers(0, 50, 30)).astype(np.int32))
    seg = jnp.asarray(np.sort(RNG.integers(0, 6, 30)).astype(np.int32))
    a = E.embedding_bag(table, idx, seg, 6, use_kernel=False)
    b = E.embedding_bag(table, idx, seg, 6, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# -- execution-mode resolution (REPRO_INTERPRET / backend) -------------------

def test_resolve_interpret_env_parsing():
    for flag in ("1", "true", "YES", " on ", "interpret"):
        assert ops.resolve_interpret(env=flag) is True
    for flag in ("0", "false", "No", " off ", "compiled"):
        assert ops.resolve_interpret(env=flag) is False
    with pytest.raises(ValueError, match="REPRO_INTERPRET"):
        ops.resolve_interpret(env="maybe")


def test_resolve_interpret_backend_fallback():
    # empty/blank env falls through to the backend rule
    assert ops.resolve_interpret(env="", backend="tpu") is False
    assert ops.resolve_interpret(env="  ", backend="cpu") is True
    assert ops.resolve_interpret(env="", backend="gpu") is True
    # env wins over backend when set
    assert ops.resolve_interpret(env="1", backend="tpu") is True
    assert ops.resolve_interpret(env="0", backend="cpu") is False


def test_module_default_matches_this_host():
    assert ops.INTERPRET == ops.resolve_interpret(
        env=None) or "REPRO_INTERPRET" not in __import__("os").environ
    # on this host the resolved default must be valid: interpret anywhere,
    # compiled only on TPU
    if jax.default_backend() != "tpu":
        assert ops.resolve_interpret(env="") is True


# -- toggle semantics: global read at CALL time, static jit argument ---------

def test_wrappers_read_global_at_call_time(monkeypatch):
    """Flipping ops.INTERPRET takes effect on the very next wrapper call."""
    seen = []

    def fake_topk(scores, k, block_d=None, interpret=None):
        seen.append(interpret)
        return scores[:, :k], jnp.zeros((scores.shape[0], k), jnp.int32)

    monkeypatch.setattr(ops._topk, "topk", fake_topk)
    x = jnp.zeros((2, 16), jnp.float32)
    monkeypatch.setattr(ops, "INTERPRET", False)
    ops.topk(x, 4)
    monkeypatch.setattr(ops, "INTERPRET", True)
    ops.topk(x, 4)
    ops.topk(x, 4, interpret=False)  # per-call arg outranks the global
    assert seen == [False, True, False]


def test_fused_wrapper_reads_global_at_call_time(monkeypatch):
    seen = []

    def fake_fused(rel, judged, scal, block_q=None, relevance_level=1.0,
                   interpret=None):
        seen.append(interpret)
        return jnp.zeros((rel.shape[0], 64), jnp.float32)

    monkeypatch.setattr(ops._fm, "fused_measures", fake_fused)
    rel = jnp.zeros((2, 8), jnp.float32)
    scal = jnp.zeros((2, 16), jnp.float32)
    monkeypatch.setattr(ops, "INTERPRET", True)
    ops.fused_measures_cols(rel, rel, scal)
    monkeypatch.setattr(ops, "INTERPRET", False)
    ops.fused_measures_cols(rel, rel, scal)
    ops.fused_measures_cols(rel, rel, scal, interpret=True)
    assert seen == [True, False, True]


def test_sharded_evaluator_snapshots_interpret(monkeypatch):
    """ShardedEvaluator captures the mode at construction — documented caveat."""
    from repro.core import RelevanceEvaluator
    from repro.distributed.sharded_evaluator import ShardedEvaluator

    ev = RelevanceEvaluator({"q1": {"d1": 1}}, ("map",))
    live = ops.INTERPRET
    se = ShardedEvaluator(ev)
    assert se.interpret == live
    # flipping the global does NOT change an existing instance...
    monkeypatch.setattr(ops, "INTERPRET", not live)
    assert se.interpret == live
    # ...but a rebuilt one (or an explicit arg) picks the new mode up
    assert ShardedEvaluator(ev).interpret == (not live)
    assert ShardedEvaluator(ev, interpret=live).interpret == live


# -- compiled-vs-interpret conformance gate ----------------------------------
#
# On a TPU host the resolved default is the COMPILED path and this gate
# compares real Mosaic executables against the interpreter (documented
# tolerance: ~1 ulp on float accumulations).  On CPU/GPU hosts both modes
# resolve to the interpreter, so the gate degenerates to a bit-identity
# check through the same call path — the resolution plumbing itself is
# exercised either way.

def _assert_mode_parity(got, want, what):
    got, want = np.asarray(got), np.asarray(want)
    if ops.INTERPRET:
        np.testing.assert_array_equal(got, want, err_msg=what)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6,
                                   err_msg=what)


def test_parity_fused_measures_default_vs_interpret():
    q, d = 7, 200
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    rel = jnp.asarray(RNG.integers(0, 3, (q, d)).astype(np.float32))
    batch = M.batch_from_dense(scores, rel)
    s = M.sort_batch(batch)
    scal = ops.make_scalars(batch.n_rel, batch.n_judged_nonrel,
                            batch.ideal_rel)
    got = ops.fused_measures_cols(s.rel, s.judged, scal)  # resolved default
    want = ops.fused_measures_cols(s.rel, s.judged, scal, interpret=True)
    _assert_mode_parity(got, want, "fused_measures default vs interpret")


def test_parity_topk_default_vs_interpret():
    scores = jnp.asarray(RNG.standard_normal((3, 1000)).astype(np.float32))
    v, i = ops.topk(scores, 50)
    vi, ii = ops.topk(scores, 50, interpret=True)
    _assert_mode_parity(v, vi, "topk values default vs interpret")
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ii))


def test_parity_embedding_bag_default_vs_interpret():
    table = jnp.asarray(RNG.standard_normal((40, 16)).astype(np.float32))
    seg = jnp.asarray(np.sort(RNG.integers(0, 6, 30)).astype(np.int32))
    idx = jnp.asarray(RNG.integers(0, 40, 30).astype(np.int32))
    got = ops.embedding_bag(table, idx, seg, 6)
    want = ops.embedding_bag(table, idx, seg, 6, interpret=True)
    _assert_mode_parity(got, want, "embedding_bag default vs interpret")


def test_explicit_block_q_matches_autotuned():
    """block_q only tiles the VMEM walk; results are block-size invariant."""
    q, d = 13, 128
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    rel = jnp.asarray(RNG.integers(0, 2, (q, d)).astype(np.float32))
    batch = M.batch_from_dense(scores, rel)
    s = M.sort_batch(batch)
    scal = ops.make_scalars(batch.n_rel, batch.n_judged_nonrel,
                            batch.ideal_rel)
    auto = ops.fused_measures_cols(s.rel, s.judged, scal)
    for bq in (8, 16, 128):
        manual = ops.fused_measures_cols(s.rel, s.judged, scal, block_q=bq)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(manual),
                                      err_msg=f"block_q={bq}")
