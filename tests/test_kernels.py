"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import measures as M
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("q,d,k", [
    (1, 257, 10), (3, 1000, 100), (2, 4096, 1000), (5, 64, 64), (1, 10000, 13),
])
def test_topk_matches_lax(q, d, k):
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    v, i = ops.topk(scores, k)
    rv, ri = ref.topk_ref(scores, k)
    kk = min(k, d)
    np.testing.assert_allclose(np.asarray(v)[:, :kk], np.asarray(rv)[:, :kk])
    np.testing.assert_array_equal(np.asarray(i)[:, :kk],
                                  np.asarray(ri)[:, :kk])


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_topk_ties_break_by_index(dtype):
    # heavy ties: the kernel must match lax.top_k's lower-index-first rule
    scores = jnp.asarray(
        RNG.choice(np.array([0.0, 1.0, 2.0], np.float32), size=(4, 2000)))
    v, i = ops.topk(scores, 50)
    rv, ri = jax.lax.top_k(scores, 50)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_topk_handles_short_rows():
    scores = jnp.asarray(RNG.standard_normal((2, 5)).astype(np.float32))
    v, i = ops.topk(scores, 8)
    rv, ri = ref.topk_ref(scores, 8)
    np.testing.assert_allclose(np.asarray(v)[:, :5], np.asarray(rv)[:, :5])


@pytest.mark.parametrize("q,d", [(3, 64), (8, 200), (13, 1024), (1, 4096)])
def test_fused_measures_matches_ref(q, d):
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    rel = jnp.asarray(RNG.integers(0, 4, (q, d)).astype(np.float32))
    judged = jnp.asarray(RNG.random((q, d)) < 0.6)
    batch = M.batch_from_dense(scores, rel, judged=judged)
    s = M.sort_batch(batch)
    scal = ops.make_scalars(batch.n_rel, batch.n_judged_nonrel,
                            batch.ideal_rel)
    got = ops.fused_measures_cols(s.rel, s.judged, scal)
    want = ref.fused_measures_ref(s.rel, s.judged, scal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_fused_evaluate_matches_measure_core():
    q, d = 9, 300
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    rel = jnp.asarray(RNG.integers(0, 3, (q, d)).astype(np.float32))
    batch = M.batch_from_dense(scores, rel)
    fused = ops.evaluate_fused(batch)
    parsed = M.parse_measures(("map", "ndcg", "ndcg_cut", "P", "recall",
                               "recip_rank", "Rprec", "bpref", "success"))
    want = M.compute_measures(batch, parsed)
    for k, v in want.items():
        np.testing.assert_allclose(np.asarray(fused[k]), np.asarray(v),
                                   atol=2e-4, rtol=2e-4, err_msg=k)


@pytest.mark.parametrize("v,e,b,l", [(30, 8, 4, 20), (100, 32, 10, 64),
                                     (11, 16, 3, 7)])
def test_embedding_bag_matches_ref(v, e, b, l):
    table = jnp.asarray(RNG.standard_normal((v, e)).astype(np.float32))
    seg = jnp.asarray(np.sort(RNG.integers(0, b, l)).astype(np.int32))
    idx = jnp.asarray(RNG.integers(0, v, l).astype(np.int32))
    w = jnp.asarray(RNG.random(l).astype(np.float32))
    got = ops.embedding_bag(table, idx, seg, b, w)
    want = ref.embedding_bag_ref(table, idx, seg, b, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_embedding_bag_empty_bags_zero():
    table = jnp.ones((5, 4), jnp.float32)
    idx = jnp.asarray([1, 2], jnp.int32)
    seg = jnp.asarray([2, 2], jnp.int32)
    out = ops.embedding_bag(table, idx, seg, 4)
    np.testing.assert_allclose(np.asarray(out[0]), 0.0)
    np.testing.assert_allclose(np.asarray(out[2]), 2.0)
    np.testing.assert_allclose(np.asarray(out[3]), 0.0)


def test_embedding_module_kernel_path_matches_reference_path():
    from repro.models import embedding as E

    table = jnp.asarray(RNG.standard_normal((50, 8)).astype(np.float32))
    idx = jnp.asarray(np.sort(RNG.integers(0, 50, 30)).astype(np.int32))
    seg = jnp.asarray(np.sort(RNG.integers(0, 6, 30)).astype(np.int32))
    a = E.embedding_bag(table, idx, seg, 6, use_kernel=False)
    b = E.embedding_bag(table, idx, seg, 6, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
