"""Measure semantics: hand-computed cases + cross-validation vs the
independent pure-Python engine (which mirrors trec_eval's C loop)."""

import math
import random

import numpy as np
import pytest

from repro.baselines import native_ndcg, pure_eval
from repro.core import RelevanceEvaluator, aggregate_results

MEASURES = ("map", "ndcg", "ndcg_cut", "P", "recall", "recip_rank", "Rprec",
            "bpref", "success", "map_cut", "num_ret", "num_rel",
            "num_rel_ret")


@pytest.fixture
def simple_case():
    qrel = {"q1": {"d1": 1, "d2": 0, "d3": 2, "d4": 1}}
    run = {"q1": {"d1": 1.0, "d2": 0.5, "d3": 2.0}}
    return run, qrel


def test_hand_computed_values(simple_case):
    run, qrel = simple_case
    ev = RelevanceEvaluator(qrel, MEASURES)
    res = ev.evaluate(run)["q1"]
    idcg = 2 + 1 / math.log2(3) + 0.5
    dcg = 2 + 1 / math.log2(3)
    expected = {
        "map": 2 / 3, "P_5": 0.4, "recall_5": 2 / 3, "recip_rank": 1.0,
        "Rprec": 2 / 3, "bpref": 2 / 3, "num_rel_ret": 2.0, "num_ret": 3.0,
        "num_rel": 3.0, "ndcg": dcg / idcg, "ndcg_cut_10": dcg / idcg,
        "success_1": 1.0, "map_cut_5": 2 / 3,
    }
    for k, v in expected.items():
        assert res[k] == pytest.approx(v, abs=1e-5), k


def test_tie_break_larger_docno_wins():
    # equal scores: trec_eval ranks the lexicographically larger docno first
    ev = RelevanceEvaluator({"q": {"dB": 1}}, {"recip_rank"})
    res = ev.evaluate({"q": {"dA": 1.0, "dB": 1.0}})
    assert res["q"]["recip_rank"] == 1.0
    ev2 = RelevanceEvaluator({"q": {"dA": 1}}, {"recip_rank"})
    res2 = ev2.evaluate({"q": {"dA": 1.0, "dB": 1.0}})
    assert res2["q"]["recip_rank"] == 0.5


def test_run_qrel_intersection():
    ev = RelevanceEvaluator({"q1": {"d1": 1}}, {"map"})
    res = ev.evaluate({"q1": {"d1": 1.0}, "q_unjudged": {"d1": 1.0}})
    assert set(res) == {"q1"}
    assert ev.evaluate({}) == {}


def test_no_relevant_docs_query():
    # R=0: trec_eval yields 0 for R-normalized measures (no div-by-zero)
    ev = RelevanceEvaluator({"q": {"d1": 0}}, MEASURES)
    res = ev.evaluate({"q": {"d1": 1.0, "d2": 2.0}})
    assert res["q"]["map"] == 0.0
    assert res["q"]["ndcg"] == 0.0
    assert res["q"]["num_ret"] == 2.0


def test_unjudged_documents_are_nonrelevant():
    ev = RelevanceEvaluator({"q": {"d1": 1}}, {"P", "map"})
    res = ev.evaluate({"q": {"d_unjudged": 5.0, "d1": 1.0}})
    assert res["q"]["P_5"] == pytest.approx(1 / 5)
    assert res["q"]["map"] == pytest.approx(1 / 2)


def test_graded_relevance_levels():
    # relevance_level=2: only rel>=2 counts as relevant for binary measures
    qrel = {"q": {"d1": 1, "d2": 2}}
    run = {"q": {"d1": 2.0, "d2": 1.0}}
    res = RelevanceEvaluator(qrel, {"map"}, relevance_level=2).evaluate(run)
    assert res["q"]["map"] == pytest.approx(1 / 2)


def test_matches_pure_python_engine_randomized():
    random.seed(42)
    for _ in range(8):
        nq = random.randint(1, 6)
        run, qrel = {}, {}
        for qi in range(nq):
            qid = f"q{qi}"
            docs = [f"d{j}" for j in range(random.randint(1, 60))]
            run[qid] = {d: random.choice([0.0, 0.5, 1.0, 2.0,
                                          random.random()]) for d in docs}
            judged = random.sample(docs, k=random.randint(0, len(docs)))
            qrel[qid] = {d: random.randint(0, 3) for d in judged}
            for j in range(random.randint(0, 4)):
                qrel[qid][f"extra{j}"] = random.randint(0, 2)
            if not qrel[qid]:
                qrel[qid]["extra0"] = 1
        ours = RelevanceEvaluator(qrel, MEASURES).evaluate(run)
        ref = pure_eval.evaluate(run, qrel, MEASURES)
        for qid in ref:
            for key, val in ref[qid].items():
                assert ours[qid][key] == pytest.approx(val, abs=2e-4), \
                    (qid, key)


def test_native_ndcg_matches_engines():
    run = {"q": {f"d{i}": float(i % 7) for i in range(30)}}
    qrel = {"q": {f"d{i}": i % 3 for i in range(25)}}
    ref = pure_eval.evaluate(run, qrel, ("ndcg",))["q"]["ndcg"]
    assert native_ndcg.ndcg(run["q"], qrel["q"]) == pytest.approx(ref)


def test_aggregate_results():
    ev = RelevanceEvaluator(
        {"q1": {"d1": 1}, "q2": {"d1": 1}}, {"recip_rank"})
    res = ev.evaluate({"q1": {"d1": 1.0}, "q2": {"d1": 1.0, "d2": 2.0}})
    agg = aggregate_results(res)
    assert agg["recip_rank"] == pytest.approx((1.0 + 0.5) / 2)


def test_supported_measures_property():
    from repro.core import supported_measures

    assert "ndcg" in supported_measures
    assert "map" in supported_measures
    ev = RelevanceEvaluator({"q": {"d": 1}}, supported_measures)
    res = ev.evaluate({"q": {"d": 1.0}})
    assert res["q"]["ndcg"] == 1.0


# -- top-k kernel routing ----------------------------------------------------

# Depth-bounded request (mixed dialects on purpose): max depth 20, so a
# batch padded past max(2*next_pow2(20, 128), 512) = 512 docs routes to
# the top-k kernel instead of the full multi-key sort.
BOUNDED = ("P@5", "P_10", "recall_10", "nDCG@10", "map_cut_10",
           "success_10", "Judged@10", "ERR@20", "num_ret", "num_rel")


def _wide_case(nd=600, nq=3, seed=7):
    rng = random.Random(seed)
    run, qrel = {}, {}
    for qi in range(nq):
        qid = f"q{qi}"
        run[qid] = {f"d{j:04d}": rng.random() for j in range(nd)}
        qrel[qid] = {f"d{j:04d}": rng.randint(0, 2)
                     for j in rng.sample(range(nd), 40)}
    return run, qrel


@pytest.mark.parametrize("judged_only", [False, True])
def test_topk_route_taken_and_bit_identical(monkeypatch, judged_only):
    from repro.core import measures as M

    run, qrel = _wide_case()
    ev = RelevanceEvaluator(qrel, BOUNDED, judged_docs_only=judged_only)
    calls = []
    real = M.compute_measures_topk_jit

    def spy(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(M, "compute_measures_topk_jit", spy)
    routed = ev.evaluate(run)
    assert calls, "wide depth-bounded batch must take the top-k path"

    ev_full = RelevanceEvaluator(qrel, BOUNDED, judged_docs_only=judged_only)
    monkeypatch.setattr(type(ev_full), "_route_topk",
                        lambda self, buf: False)
    full = ev_full.evaluate(run)
    assert routed.keys() == full.keys()
    for qid in routed:
        assert routed[qid].keys() == full[qid].keys()
        for key in routed[qid]:
            assert routed[qid][key] == full[qid][key], (qid, key)


def test_full_depth_measure_disables_topk_route(monkeypatch):
    from repro.core import measures as M

    run, qrel = _wide_case(nq=1)
    ev = RelevanceEvaluator(qrel, ("map", "P_10"))  # map needs the full sort
    monkeypatch.setattr(
        M, "compute_measures_topk_jit",
        lambda *a, **k: pytest.fail("top-k path taken for full-depth map"))
    ev.evaluate(run)

    # narrow batches stay on the full sort too (top-k gains nothing there)
    ev2 = RelevanceEvaluator({"q": {"d1": 1}}, ("P_10",))
    assert not ev2._route_topk(ev2.tokenize_run({"q": {"d1": 1.0}}))


def test_topk_path_preserves_trec_tie_rule(monkeypatch):
    # equal scores: the tiebreak-column layout makes the kernel's
    # smaller-index-wins rule equal trec_eval's larger-docno-wins rule
    ev = RelevanceEvaluator({"q": {"dB": 1}}, ("P_5", "success_1"))
    monkeypatch.setattr(type(ev), "_route_topk", lambda self, buf: True)
    res = ev.evaluate({"q": {"dA": 1.0, "dB": 1.0}})
    assert res["q"]["success_1"] == 1.0
    assert res["q"]["P_5"] == pytest.approx(1 / 5)
