"""Property-based tests (hypothesis) of the measure core's invariants."""

import math

import pytest

pytest.importorskip("hypothesis")

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.baselines import pure_eval
from repro.core import RelevanceEvaluator

MEASURES = ("map", "ndcg", "P", "recall", "recip_rank", "Rprec", "bpref",
            "success", "ndcg_cut", "map_cut")
BOUNDED = [m for m in
           ("map", "ndcg", "P_5", "recall_10", "recip_rank", "Rprec",
            "bpref", "success_1", "ndcg_cut_10", "map_cut_10")]


@st.composite
def run_and_qrel(draw, max_docs=40):
    n_docs = draw(st.integers(1, max_docs))
    docs = [f"d{i}" for i in range(n_docs)]
    scores = draw(st.lists(
        # subnormals excluded: XLA flushes them to zero (score ties would
        # then resolve differently than in pure Python — float32 semantics
        # boundary, documented in DESIGN.md)
        st.floats(-100, 100, allow_nan=False, allow_subnormal=False,
                  width=32),
        min_size=n_docs, max_size=n_docs))
    rels = draw(st.lists(st.integers(-1, 3) | st.none(),
                         min_size=n_docs, max_size=n_docs))
    qrel = {d: r for d, r in zip(docs, rels) if r is not None}
    if not any(r is not None and r > 0 for r in rels):
        qrel["d_unret"] = 1  # ensure R>0 (trec_eval skips R=0 queries)
    return {"q": dict(zip(docs, scores))}, {"q": qrel}


@given(run_and_qrel())
@settings(max_examples=60, deadline=None)
def test_measures_bounded_01(data):
    run, qrel = data
    res = RelevanceEvaluator(qrel, MEASURES).evaluate(run)["q"]
    for key in BOUNDED:
        assert -1e-6 <= res[key] <= 1 + 1e-6, (key, res[key])


@given(run_and_qrel(), st.randoms())
@settings(max_examples=40, deadline=None)
def test_insertion_order_invariance(data, rnd):
    """trec_eval ignores the order documents appear in the run."""
    run, qrel = data
    docs = list(run["q"].items())
    rnd.shuffle(docs)
    shuffled = {"q": dict(docs)}
    ev = RelevanceEvaluator(qrel, MEASURES)
    a = ev.evaluate(run)["q"]
    b = ev.evaluate(shuffled)["q"]
    for k in a:
        assert a[k] == b[k], k


@given(run_and_qrel())
@settings(max_examples=40, deadline=None)
def test_jax_core_equals_pure_python(data):
    run, qrel = data
    ours = RelevanceEvaluator(qrel, MEASURES).evaluate(run)["q"]
    ref = pure_eval.evaluate(run, qrel, MEASURES)["q"]
    for k, v in ref.items():
        assert math.isclose(ours[k], v, rel_tol=1e-4, abs_tol=2e-4), \
            (k, ours[k], v)


@given(st.integers(1, 30), st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_ideal_ranking_is_perfect(n_docs, extra_levels):
    """Scoring documents by their own relevance yields NDCG=1, AP=1 (when
    every relevant doc is retrieved)."""
    qrel = {"q": {f"d{i}": (i % (extra_levels + 2)) for i in range(n_docs)}}
    if not any(v > 0 for v in qrel["q"].values()):
        qrel["q"]["d0"] = 1
    run = {"q": {d: float(r) for d, r in qrel["q"].items()}}
    res = RelevanceEvaluator(qrel, ("ndcg", "map")).evaluate(run)["q"]
    # fusion may change the dcg/idcg reduction-tree order → last-ulp drift
    assert abs(res["ndcg"] - 1.0) < 1e-6
    assert abs(res["map"] - 1.0) < 1e-6


@given(run_and_qrel())
@settings(max_examples=30, deadline=None)
def test_promoting_relevant_doc_never_hurts_ap(data):
    """Moving a relevant doc to the top of the ranking cannot decrease AP."""
    run, qrel = data
    rel_docs = [d for d, r in qrel["q"].items() if r >= 1 and d in run["q"]]
    if not rel_docs:
        return
    ev = RelevanceEvaluator(qrel, ("map",))
    before = ev.evaluate(run)["q"]["map"]
    boosted = dict(run["q"])
    boosted[rel_docs[0]] = max(boosted.values()) + 1.0
    after = ev.evaluate({"q": boosted})["q"]["map"]
    assert after >= before - 1e-6


@given(st.lists(st.floats(0, 1, allow_nan=False, width=32), min_size=2,
                max_size=64))
@settings(max_examples=30, deadline=None)
def test_precision_recall_consistency(scores):
    """recall_k * R == P_k * k == #relevant in top k (counting identity)."""
    docs = {f"d{i}": float(s) for i, s in enumerate(scores)}
    qrel = {"q": {f"d{i}": int(i % 2 == 0) for i in range(len(scores))}}
    if not any(qrel["q"].values()):
        qrel["q"]["d0"] = 1
    r = sum(qrel["q"].values())
    res = RelevanceEvaluator(qrel, ("P", "recall")).evaluate({"q": docs})["q"]
    for k in (5, 10, 100):
        assert res[f"recall_{k}"] * r == pytest.approx(res[f"P_{k}"] * k,
                                                       abs=1e-4)


import pytest  # noqa: E402  (used in the last property)
