"""Rank-reduction engine (core.ranked) must agree exactly with the sorted
engine (core.measures) — including ties, unjudged docs, padding, and graded
relevance."""

import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import measures as M
from repro.core import ranked as R

MEASURES = M.parse_measures(
    ("map", "ndcg", "ndcg_cut", "P", "recall", "recip_rank", "Rprec",
     "bpref", "success", "map_cut", "iprec_at_recall", "num_ret", "num_rel",
     "num_rel_ret", "judged", "rbp", "err"))

RNG = np.random.default_rng(11)


def _rand_batch(q, d, tie_levels=None, judged_p=0.5):
    if tie_levels:
        scores = RNG.choice(np.linspace(0, 1, tie_levels), size=(q, d))
    else:
        scores = RNG.standard_normal((q, d))
    rel = RNG.integers(0, 4, (q, d)).astype(np.float32)
    judged = RNG.random((q, d)) < judged_p
    mask = np.ones((q, d), bool)
    mask[:, int(d * 0.9):] = RNG.random((q, d - int(d * 0.9))) < 0.5
    return M.batch_from_dense(
        jnp.asarray(scores.astype(np.float32)), jnp.asarray(rel),
        mask=jnp.asarray(mask), judged=jnp.asarray(judged & mask))


@pytest.mark.parametrize("q,d,ties", [(5, 64, None), (3, 200, 4),
                                      (8, 100, 2), (1, 32, None)])
def test_ranked_equals_sorted_engine(q, d, ties):
    batch = _rand_batch(q, d, tie_levels=ties)
    want = M.compute_measures(batch, MEASURES)
    rb = R.from_eval_batch(batch)
    got = R.compute_measures_ranked(rb, MEASURES)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=2e-4, rtol=2e-4, err_msg=k)


def test_ranked_handles_unretrieved_judged_docs():
    # relevant doc exists in qrels but not in the run → recall < 1, idcg full
    batch = M.EvalBatch(
        scores=jnp.asarray([[3.0, 2.0]]),
        tiebreak=jnp.asarray([[0, 1]], jnp.int32),
        rel=jnp.asarray([[1.0, 0.0]]),
        judged=jnp.asarray([[True, True]]),
        mask=jnp.asarray([[True, True]]),
        ideal_rel=jnp.asarray([[2.0, 1.0]]),  # an unretrieved rel=2 doc
        n_rel=jnp.asarray([2.0]),
        n_judged_nonrel=jnp.asarray([1.0]),
        query_mask=jnp.asarray([True]))
    want = M.compute_measures(batch, MEASURES)
    got = R.compute_measures_ranked(R.from_eval_batch(batch), MEASURES)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=2e-4, err_msg=k)
    assert float(got["recall_5"][0]) == pytest.approx(0.5)


def test_judged_ranks_tie_semantics():
    batch = M.batch_from_dense(
        jnp.asarray([[1.0, 2.0, 2.0, 0.5]]),
        jnp.asarray([[1.0, 0.0, 1.0, 1.0]]))
    rb = R.from_eval_batch(batch)
    ranks = R.judged_ranks(rb)
    # scores 2.0(idx1), 2.0(idx2), 1.0(idx0), 0.5(idx3); idx1 wins the tie
    order = {int(i): float(r) for i, r in zip(
        np.asarray(rb.judged_tiebreak[0]), np.asarray(ranks[0]))}
    assert order[1] == 1.0 and order[2] == 2.0
    assert order[0] == 3.0 and order[3] == 4.0


@given(st.integers(1, 6), st.integers(2, 40), st.integers(2, 5))
@settings(max_examples=25, deadline=None)
def test_ranked_property_equivalence(q, d, levels):
    batch = _rand_batch(q, d, tie_levels=levels, judged_p=0.7)
    want = M.compute_measures(batch, MEASURES)
    got = R.compute_measures_ranked(R.from_eval_batch(batch), MEASURES)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=3e-4, rtol=3e-4, err_msg=k)
