"""The declarative measure registry: dialects, rendering, derivations.

Covers the registry contract every consumer leans on:

* both dialects canonicalize to identical parsed selectors and keys;
* ``rel=`` resolution (agreement, conflicts, the weak-default-1 rule);
* unknown/malformed measures raise :class:`MeasureError` naming the input;
* trec↔ir round-trip rendering (property-tested when hypothesis exists);
* the CLI's derived print order / int / sum / aggregate-only sets;
* depth bounds for the top-k routing decision;
* the ``docs/MEASURES.md`` drift-gate machinery.
"""

import pytest

from repro.core import registry
from repro.core.registry import MeasureError


# -- dialect equivalence -----------------------------------------------------


@pytest.mark.parametrize("trec_m,ir_m", [
    ("map", "AP"),
    ("map", "MAP"),
    ("gm_map", "GMAP"),
    ("recip_rank", "RR"),
    ("recip_rank", "MRR"),
    ("Rprec", "Rprec"),
    ("bpref", "Bpref"),
    ("ndcg", "nDCG"),
    ("P_5", "P@5"),
    ("recall_10", "R@10"),
    ("recall_10", "Recall@10"),
    ("ndcg_cut_10", "nDCG@10"),
    ("map_cut_20", "AP@20"),
    ("success_1", "Success@1"),
    ("judged_10", "Judged@10"),
    ("err_20", "ERR@20"),
    ("rbp_0.80", "RBP(p=0.8)"),
    ("iprec_at_recall_0.10", "IPrec@0.10"),
    ("num_ret", "NumRet"),
    ("num_rel", "NumRel"),
    ("num_rel_ret", "NumRelRet"),
])
def test_both_dialects_same_canonical_form(trec_m, ir_m):
    assert registry.canonicalize([trec_m]) == registry.canonicalize([ir_m])
    assert registry.canonical_key(ir_m)[0] == trec_m


def test_ir_dialect_case_insensitive_names():
    for spelling in ("ap", "Ap", "AP", "ndcg@10", "NDCG@10", "judged@5"):
        registry.canonical_key(spelling)  # must not raise


def test_family_selectors_merge_across_dialects():
    parsed, level = registry.canonicalize(("P@5", "P_10", "P.15,20"))
    assert parsed == (("P", (5.0, 10.0, 15.0, 20.0)),)
    assert level == 1.0


def test_whole_family_expands_to_default_grid():
    assert registry.measure_keys(["P"]) == tuple(
        f"P_{k}" for k in registry.DEFAULT_CUTOFFS)
    assert registry.measure_keys(["success"]) == tuple(
        f"success_{k}" for k in registry.SUCCESS_CUTOFFS)
    assert registry.measure_keys(["iprec_at_recall"]) == tuple(
        f"iprec_at_recall_{v:.2f}" for v in registry.IPREC_LEVELS)


# -- rel= resolution ---------------------------------------------------------


def test_rel_annotation_sets_level():
    parsed, level = registry.canonicalize(["AP(rel=2)"])
    assert parsed == (("map", ()),) and level == 2.0


def test_rel_annotations_must_agree():
    with pytest.raises(MeasureError, match="conflicting rel="):
        registry.canonicalize(["AP(rel=2)", "P(rel=3)@5"])
    # agreement is fine, and merges with un-annotated measures
    parsed, level = registry.canonicalize(["AP(rel=2)", "P(rel=2)@5", "ndcg"])
    assert level == 2.0 and len(parsed) == 3


def test_rel_conflicts_with_explicit_level():
    with pytest.raises(MeasureError, match="conflicts with relevance_level"):
        registry.canonicalize(["AP(rel=2)"], relevance_level=3)
    # ...but the weak default 1 does NOT conflict (serve's default -l 1)
    assert registry.canonicalize(["AP(rel=2)"], relevance_level=1)[1] == 2.0


def test_parse_measures_rejects_nondefault_rel():
    with pytest.raises(MeasureError, match="relevance_level-aware"):
        registry.parse_measures(["AP(rel=2)"])


# -- errors ------------------------------------------------------------------


@pytest.mark.parametrize("bad", [
    "bogus", "Bogus@5", "P_5.5", "P@0", "ndcg_cut_0", "RBP(p=1.5)",
    "RBP(p=0.875)", "AP(frobnicate=1)", "AP(rel=x)", "RR@5",
    "iprec_at_recall_1.50", "",
])
def test_malformed_measures_raise_measure_error(bad):
    with pytest.raises(MeasureError):
        registry.canonicalize([bad])


def test_error_names_the_offending_measure():
    with pytest.raises(MeasureError, match="Bogus@5"):
        registry.canonicalize(["map", "Bogus@5"])


def test_measure_error_is_a_value_error():
    # the serve front-end maps ValueError → wire code "invalid"
    assert issubclass(MeasureError, ValueError)


def test_canonical_key_rejects_whole_parameterized_family():
    with pytest.raises(MeasureError, match="whole family"):
        registry.canonical_key("P")


# -- rendering ---------------------------------------------------------------


def test_render_ir_spellings():
    assert registry.render_ir("map") == "AP"
    assert registry.render_ir("gm_map") == "GMAP"
    assert registry.render_ir("recip_rank") == "RR"
    assert registry.render_ir("ndcg_cut_10") == "nDCG@10"
    assert registry.render_ir("rbp_0.80") == "RBP(p=0.8)"
    assert registry.render_ir("judged_10") == "Judged@10"
    assert registry.render_ir("err_20") == "ERR@20"
    assert registry.render_ir("iprec_at_recall_0.10") == "IPrec@0.10"


def test_render_round_trip_every_default_key():
    """trec key → ir spelling → same trec key, for the full default grid."""
    for spec in registry.REGISTRY:
        for key in registry.family_keys(spec.family, spec.default_params):
            ir = registry.render_ir(key)
            assert registry.render_trec(ir) == key, (key, ir)


def test_both_dialects_error_helper():
    assert "nDCG@10" in registry.both_dialects("ndcg_cut_10")
    assert registry.both_dialects("garbage!") == "'garbage!'"


# -- derived consumer tables -------------------------------------------------


def test_cli_tables_are_registry_derived():
    from repro import cli

    assert cli.FAMILY_ORDER == registry.family_order()
    assert cli.INT_MEASURES == frozenset({"num_q"}) | registry.integer_keys()
    assert cli.SUM_MEASURES == registry.sum_families()
    assert cli.AGGREGATE_ONLY == registry.aggregate_only_families()
    # declaration order starts with the counters, like trec_eval
    assert cli.FAMILY_ORDER[:3] == ("num_ret", "num_rel", "num_rel_ret")
    assert set(("judged", "rbp", "err")) <= set(cli.FAMILY_ORDER)


def test_supported_measures_matches_registry():
    from repro.core import supported_measures

    assert supported_measures == registry.supported_families()
    assert len(registry.REGISTRY) == len(supported_measures)


def test_missing_contributions():
    assert registry.missing_contribution("num_rel") == "n_rel"
    assert registry.missing_contribution("gm_map") == "log_gm_min"
    assert registry.missing_contribution("map") == "zero"
    assert registry.missing_contribution("ndcg_cut_10") == "zero"


# -- depth bounds ------------------------------------------------------------


def test_topk_depth_bounded_sets():
    parsed, _ = registry.canonicalize(["P@5", "nDCG@100", "Judged@10"])
    assert registry.topk_depth(parsed) == 100
    parsed, _ = registry.canonicalize(["P@5", "num_ret", "num_rel"])
    assert registry.topk_depth(parsed) == 5


@pytest.mark.parametrize("full_m", ["map", "ndcg", "bpref", "recip_rank",
                                    "Rprec", "rbp_0.80", "gm_map",
                                    "iprec_at_recall", "num_rel_ret"])
def test_topk_depth_none_for_full_depth_measures(full_m):
    parsed, _ = registry.canonicalize([full_m, "P@5"])
    assert registry.topk_depth(parsed) is None


# -- documentation table / drift gate ----------------------------------------


def test_markdown_table_lists_every_family():
    table = registry.markdown_table()
    for spec in registry.REGISTRY:
        assert f"| `{spec.family}` |" in table


def test_check_docs_accepts_current_measures_md():
    import os

    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "docs", "MEASURES.md")
    registry.check_docs(path)  # raises SystemExit on drift


def test_check_docs_rejects_stale_table(tmp_path):
    stale = tmp_path / "MEASURES.md"
    stale.write_text("# measures\n\nnothing here\n")
    with pytest.raises(SystemExit):
        registry.check_docs(str(stale))


def test_registry_cli_check_and_print(capsys):
    assert registry.main(["--print"]) == 0
    out = capsys.readouterr().out
    assert out.strip() == registry.markdown_table()


# -- property-based round trips (hypothesis, optional) -----------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment without hypothesis
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    _cutoff_fams = sorted(
        s.family for s in registry.REGISTRY if s.param_kind == "cutoff")

    @st.composite
    def measure_strings(draw):
        spec = registry.SPECS[draw(st.sampled_from(_cutoff_fams))]
        k = draw(st.integers(1, 5000))
        dialect = draw(st.booleans())
        if dialect:
            return f"{spec.ir_name}@{k}", f"{spec.family}_{k}"
        return f"{spec.family}_{k}", f"{spec.family}_{k}"

    @settings(max_examples=200, deadline=None)
    @given(measure_strings())
    def test_parse_render_parse_round_trip(case):
        spelling, canonical = case
        key = registry.render_trec(spelling)
        assert key == canonical
        # render to the OTHER dialect and parse again: same canonical key
        assert registry.render_trec(registry.render_ir(key)) == key
        # and canonicalization agrees with the direct spelling
        assert registry.canonicalize([spelling]) == \
            registry.canonicalize([key])

    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 99), st.integers(1, 99))
    def test_rbp_p_round_trip(a, b):
        p = round(a / 100 + b / 10000, 2)  # any 2-decimal p in (0, 1)
        if not 0.0 < p < 1.0:
            return
        key = registry.render_trec(f"RBP(p={p:g})")
        assert key == f"rbp_{p:.2f}"
        assert registry.render_trec(registry.render_ir(key)) == key
