"""Serve-layer tests: coalescing, bit-identity, cache eviction, backpressure,
and the JSON-lines front-ends.

The acceptance contract (ISSUE 3): N concurrent requests for the same qrel
must be coalesced into FEWER backend ``evaluate_*`` calls than N, with
per-query results bit-identical to direct ``RelevanceEvaluator.evaluate``.
Socket-spinning suites (TCP, stdio subprocess) are marked ``slow``.
"""

import asyncio
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import RelevanceEvaluator, concat_run_buffers
from repro.data.synthetic_ir import synthesize_run
from repro.serve import (EvaluationService, LRUCache, MicroBatcher,
                         handle_line)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
QREL_PATH = os.path.join(FIXTURES, "conformance.qrel")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MEASURES = ("map", "ndcg", "recip_rank", "P", "bpref")


@pytest.fixture(scope="module")
def collection():
    run, qrel = synthesize_run(n_queries=24, n_docs=16, seed=7)
    return run, qrel


def _runs_with_perturbed_scores(run, n, seed=0):
    """n runs over the same documents with different scores."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        out.append({qid: {d: float(s + rng.normal())
                          for d, s in docs.items()}
                    for qid, docs in run.items()})
    return out


# -- evaluator coalescing hook (the backend primitive) -----------------------


def test_evaluate_buffers_bit_identical_to_evaluate(collection):
    run, qrel = collection
    ev = RelevanceEvaluator(qrel, MEASURES)
    runs = _runs_with_perturbed_scores(run, 5)
    bufs = [ev.tokenize_run(r) for r in runs]
    coalesced = ev.evaluate_buffers(bufs)
    for r, got in zip(runs, coalesced):
        want = ev.evaluate(r)
        assert got == want  # bit-identical: same floats, not approx


def test_evaluate_buffers_scores_list(collection):
    run, qrel = collection
    ev = RelevanceEvaluator(qrel, ("map",))
    buf = ev.tokenize_run(run)
    flip = -np.asarray(buf.scores)
    a, b = ev.evaluate_buffers([buf, buf], scores_list=[None, flip])
    assert a == ev.evaluate_buffer(buf)
    assert b == ev.evaluate_buffer(buf, scores=flip)


def test_evaluate_buffers_empty_and_mixed(collection):
    run, qrel = collection
    ev = RelevanceEvaluator(qrel, ("map",))
    empty = ev.tokenize_run({})
    buf = ev.tokenize_run(run)
    out = ev.evaluate_buffers([empty, buf, empty])
    assert out[0] == {} and out[2] == {}
    assert out[1] == ev.evaluate_buffer(buf)
    assert ev.evaluate_buffers([]) == []


def test_concat_run_buffers_validation(collection):
    run, qrel = collection
    ev = RelevanceEvaluator(qrel, ("map",))
    with pytest.raises(ValueError):
        concat_run_buffers([])
    unscored = ev.buffer_from_tokens(
        [list(qrel)[0]], counts=[1], tokens=[0])
    with pytest.raises(ValueError):
        concat_run_buffers([unscored, unscored])


def test_sharded_evaluate_buffers_matches_single(collection):
    run, qrel = collection
    from repro.distributed import ShardedEvaluator

    ev = RelevanceEvaluator(qrel, MEASURES)
    sev = ShardedEvaluator(ev)
    runs = _runs_with_perturbed_scores(run, 3)
    bufs = [ev.tokenize_run(r) for r in runs]
    results = sev.evaluate_buffers(bufs)
    singles = [sev.evaluate_buffer(b) for b in bufs]
    for got, want in zip(results, singles):
        assert got.per_query == want.per_query
        for k, v in want.aggregates.items():
            assert got.aggregates[k] == pytest.approx(v, rel=1e-6), k


# -- the service: coalescing acceptance test ---------------------------------


def test_service_coalesces_concurrent_requests(collection, monkeypatch):
    """N concurrent same-qrel requests → fewer backend calls than N, with
    per-query results bit-identical to direct RelevanceEvaluator.evaluate."""
    run, qrel = collection
    n = 8
    runs = _runs_with_perturbed_scores(run, n)
    direct = RelevanceEvaluator(qrel, MEASURES)
    want = [direct.evaluate(r) for r in runs]

    backend_calls = []
    real = RelevanceEvaluator.evaluate_buffers

    def counting(self, bufs, scores_list=None):
        backend_calls.append(len(bufs))
        return real(self, bufs, scores_list)

    monkeypatch.setattr(RelevanceEvaluator, "evaluate_buffers", counting)

    async def main():
        svc = EvaluationService(window=0.02, backend="single")
        svc.register_qrel("c", qrel, MEASURES)
        return await asyncio.gather(
            *(svc.evaluate("c", run=r) for r in runs)), svc

    results, svc = asyncio.run(main())
    assert len(backend_calls) < n  # coalesced: fewer evaluate_* calls than N
    assert sum(backend_calls) == n  # ... but every request was evaluated
    assert svc.stats()["backend_calls"] == len(backend_calls)
    for res, w in zip(results, want):
        assert res.per_query == w  # bit-identical floats


def test_service_max_batch_bounds_coalescing(collection):
    run, qrel = collection
    runs = _runs_with_perturbed_scores(run, 4)

    async def main():
        svc = EvaluationService(window=0.05, max_batch=2, backend="single")
        svc.register_qrel("c", qrel, ("map",))
        await asyncio.gather(*(svc.evaluate("c", run=r) for r in runs))
        return svc.stats()

    stats = asyncio.run(main())
    assert stats["backend_calls"] == 2  # 4 requests, size cap 2


def test_service_run_ref_rescoring_hot_path(collection):
    """register_run once, then score-only requests (zero string work)."""
    run, qrel = collection
    ev = RelevanceEvaluator(qrel, ("map", "recip_rank"))
    buf = ev.tokenize_run(run)
    rng = np.random.default_rng(3)
    score_sets = [rng.normal(size=buf.scores.shape[0]).astype(np.float32)
                  for _ in range(4)]

    async def main():
        svc = EvaluationService(window=0.02, backend="single")
        svc.register_qrel("c", qrel, ("map", "recip_rank"))
        info = svc.register_run("c", "bm25", run=run)
        assert info["n_queries"] == len(buf)
        res = await asyncio.gather(
            *(svc.evaluate("c", run_ref="bm25", scores=s)
              for s in score_sets))
        return res, svc.stats()

    results, stats = asyncio.run(main())
    assert stats["backend_calls"] < len(score_sets)
    for s, res in zip(score_sets, results):
        assert res.per_query == ev.evaluate_buffer(buf, scores=s)


def test_service_tokens_payload(collection):
    _, qrel = collection
    qid = sorted(qrel)[0]

    async def main():
        svc = EvaluationService(backend="single")
        svc.register_qrel("c", qrel, ("recip_rank",))
        return await svc.evaluate("c", tokens={
            "qids": [qid], "counts": [2], "tokens": [0, 1],
            "scores": [0.1, 0.9]})

    res = asyncio.run(main())
    ev = RelevanceEvaluator(qrel, ("recip_rank",))
    buf = ev.buffer_from_tokens([qid], [2], [0, 1], scores=[0.1, 0.9])
    assert res.per_query == ev.evaluate_buffer(buf)


def test_service_sharded_backend_matches_single(collection):
    run, qrel = collection
    from repro.distributed import ShardedEvaluator

    async def main():
        svc = EvaluationService(backend="sharded")
        svc.register_qrel("c", qrel, MEASURES)
        return await svc.evaluate("c", run=run)

    res = asyncio.run(main())
    ev = RelevanceEvaluator(qrel, MEASURES)
    # bit-identical to the direct sharded pipeline (same engine) ...
    assert res.per_query == ShardedEvaluator(ev).evaluate(run).per_query
    # ... and within the fused kernel's documented ~1-ulp of the single
    # evaluator (the log-step VMEM scan may associate float DCG sums
    # differently from jnp.cumsum; see distributed/sharded_evaluator.py).
    want = ev.evaluate(run)
    for qid in want:
        for k, v in want[qid].items():
            assert res.per_query[qid][k] == pytest.approx(v, rel=1e-6), \
                (qid, k)


def test_service_cache_eviction_lru(collection):
    _, qrel = collection

    async def main():
        svc = EvaluationService(max_collections=2, backend="single")
        svc.register_qrel("a", qrel, ("map",))
        svc.register_qrel("b", qrel, ("map",))
        await svc.evaluate("a", run={})  # refresh 'a' → 'b' becomes LRU
        svc.register_qrel("c", qrel, ("map",))  # evicts 'b'
        stats = svc.stats()
        assert stats["collections"] == ["a", "c"]
        assert stats["cache"]["evictions"] == 1
        with pytest.raises(KeyError, match="unknown qrel_id 'b'"):
            await svc.evaluate("b", run={})
        # re-registration brings it back
        svc.register_qrel("b", qrel, ("map",))
        return await svc.evaluate("b", run={})

    res = asyncio.run(main())
    assert res.per_query == {}


def test_service_backpressure_caps_in_flight(collection):
    run, qrel = collection
    runs = _runs_with_perturbed_scores(run, 6)

    async def main():
        svc = EvaluationService(window=0.01, max_pending=2,
                                backend="single")
        svc.register_qrel("c", qrel, ("map",))
        await asyncio.gather(*(svc.evaluate("c", run=r) for r in runs))
        return svc.stats()

    stats = asyncio.run(main())
    assert stats["peak_in_flight"] <= 2
    assert stats["requests"] == 6 and stats["in_flight"] == 0


def test_service_request_validation(collection):
    run, qrel = collection

    async def main():
        svc = EvaluationService(backend="single")
        svc.register_qrel("c", qrel, ("map",))
        with pytest.raises(ValueError, match="exactly one"):
            await svc.evaluate("c")
        with pytest.raises(ValueError, match="exactly one"):
            await svc.evaluate("c", run=run, run_ref="x")
        with pytest.raises(KeyError, match="unknown run_ref"):
            await svc.evaluate("c", run_ref="nope", scores=[1.0])
        with pytest.raises(KeyError, match="unknown qrel_id"):
            await svc.evaluate("zzz", run=run)
        unscored = {"qids": [sorted(qrel)[0]], "counts": [1], "tokens": [0]}
        with pytest.raises(ValueError, match="no scores"):
            await svc.evaluate("c", tokens=unscored)

    asyncio.run(main())


# -- protocol (no sockets) ---------------------------------------------------


def test_protocol_handle_line_roundtrip(collection):
    run, qrel = collection

    async def main():
        svc = EvaluationService(backend="single")
        reg = json.loads(await handle_line(svc, json.dumps(
            {"op": "register_qrel", "id": 1, "qrel_id": "c",
             "qrel": qrel, "measures": ["map"]})))
        assert reg["ok"] and reg["id"] == 1
        assert reg["result"]["backend"] == "single"
        ev_resp = json.loads(await handle_line(svc, json.dumps(
            {"op": "evaluate", "id": 2, "qrel_id": "c", "run": run})))
        assert ev_resp["ok"]
        stats = json.loads(await handle_line(svc, json.dumps(
            {"op": "stats", "id": 3})))
        assert stats["result"]["requests"] == 1
        pong = json.loads(await handle_line(svc, '{"op": "ping", "id": 4}'))
        assert pong["result"] == "pong"
        dropped = json.loads(await handle_line(svc, json.dumps(
            {"op": "drop_qrel", "id": 5, "qrel_id": "c"})))
        assert dropped["result"] == {"dropped": True}
        bad_op = json.loads(await handle_line(svc, '{"op": "frobnicate"}'))
        assert not bad_op["ok"] and "unknown op" in bad_op["error"]
        bad_line = json.loads(await handle_line(svc, "{not json"))
        assert not bad_line["ok"] and "bad request line" in bad_line["error"]
        return ev_resp

    resp = json.loads(json.dumps(asyncio.run(main())))
    want = RelevanceEvaluator(collection[1], ("map",)).evaluate(collection[0])
    got = resp["result"]["per_query"]
    for qid in want:
        assert got[qid]["map"] == pytest.approx(want[qid]["map"], abs=1e-9)


def test_protocol_deadline_ms_enforced_server_side(collection):
    """A worker enforces ``deadline_ms`` on its own: an op that cannot
    finish inside the budget answers ``deadline_exceeded`` instead of
    holding the connection, and an ample budget changes nothing."""
    run, qrel = collection

    async def main():
        svc = EvaluationService(backend="single", window=0.25)
        reg = json.loads(await handle_line(svc, json.dumps(
            {"op": "register_qrel", "id": 1, "qrel_id": "c",
             "qrel": qrel, "measures": ["map"], "deadline_ms": 60000})))
        assert reg["ok"], reg
        # the evaluate sits in the 250 ms coalescing window: a 30 ms
        # budget cannot be met, and the worker says so machine-readably
        late = json.loads(await handle_line(svc, json.dumps(
            {"op": "evaluate", "id": 2, "qrel_id": "c", "run": run,
             "deadline_ms": 30})))
        assert not late["ok"] and late["code"] == "deadline_exceeded"
        assert "deadline_ms" in late["error"]
        ample = json.loads(await handle_line(svc, json.dumps(
            {"op": "evaluate", "id": 3, "qrel_id": "c", "run": run,
             "deadline_ms": 60000})))
        plain = json.loads(await handle_line(svc, json.dumps(
            {"op": "evaluate", "id": 4, "qrel_id": "c", "run": run})))
        assert ample["ok"] and plain["ok"]
        assert ample["result"] == plain["result"]  # budget leaves no trace
        for bad in (0, -5, True, "soon"):
            resp = json.loads(await handle_line(svc, json.dumps(
                {"op": "ping", "id": 9, "deadline_ms": bad})))
            assert not resp["ok"] and resp["code"] == "invalid", resp
        return True

    assert asyncio.run(main())


def test_unjudged_queries_skipped_across_serve_roundtrip(collection):
    """Run-only queries are skipped trec_eval-style, bit-identically across
    the dict path, the RunBuffer path, and a serve round-trip."""
    run, qrel = collection
    noisy = {**run, "zz_unjudged": {"dA": 2.0, "dB": 1.0},
             "zz_also": {"dC": 0.5}}
    ev = RelevanceEvaluator(qrel, MEASURES)
    want = ev.evaluate(noisy)
    assert set(want) == set(qrel) & set(noisy)
    assert "zz_unjudged" not in want and "zz_also" not in want
    # dict path == RunBuffer path, bit-identical
    assert ev.evaluate_buffer(ev.tokenize_run(noisy)) == want

    async def main():
        svc = EvaluationService(backend="single")
        reg = json.loads(await handle_line(svc, json.dumps(
            {"op": "register_qrel", "id": 1, "qrel_id": "c", "qrel": qrel,
             "measures": list(MEASURES)})))
        assert reg["ok"], reg
        return json.loads(await handle_line(svc, json.dumps(
            {"op": "evaluate", "id": 2, "qrel_id": "c", "run": noisy})))

    resp = asyncio.run(main())
    assert resp["ok"], resp
    # JSON round-trips floats exactly: the serve path is bit-identical too
    assert resp["result"]["per_query"] == want


# -- unit: cache + batcher ---------------------------------------------------


def test_lru_cache_eviction_order_and_hook():
    evicted = []
    c = LRUCache(2, on_evict=lambda k, v: evicted.append(k))
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1
    c.put("c", 3)
    assert evicted == ["b"] and sorted(c.keys()) == ["a", "c"]
    assert c.get("b") is None
    assert c.stats()["evictions"] == 1 and c.stats()["misses"] == 1
    with pytest.raises(ValueError):
        LRUCache(0)


def test_batcher_error_fans_out_to_all_waiters():
    async def main():
        async def flush(key, items):
            raise RuntimeError("backend down")

        mb = MicroBatcher(flush, window=0.005)
        results = await asyncio.gather(
            *(mb.submit("k", i) for i in range(3)), return_exceptions=True)
        assert all(isinstance(r, RuntimeError) for r in results)
        assert mb.flushes == 1

    asyncio.run(main())


def test_lru_cache_replacement_fires_hook_and_counter():
    """put() on a resident key must release the displaced value (the leak)."""
    gone = []
    c = LRUCache(4, on_evict=lambda k, v: gone.append((k, v)))
    c.put("a", "old")
    c.put("a", "new")                       # replacement, same key
    assert gone == [("a", "old")]
    assert c.get("a") == "new"
    assert c.stats()["replacements"] == 1
    assert c.stats()["evictions"] == 0      # replacement is not an eviction
    # re-putting the SAME object is a recency refresh, not a displacement
    c.put("a", "new")
    assert gone == [("a", "old")]
    assert c.stats()["replacements"] == 1
    # a stored None is still a real entry: replacing it fires too
    c.put("n", None)
    c.put("n", 0)
    assert gone[-1] == ("n", None)


def test_lru_cache_replacement_and_eviction_compose():
    gone = []
    c = LRUCache(2, on_evict=lambda k, v: gone.append((k, v)))
    c.put("a", 1)
    c.put("b", 2)
    c.put("a", 10)      # replace refreshes recency: 'b' is now LRU
    c.put("c", 3)       # capacity eviction drops 'b'
    assert gone == [("a", 1), ("b", 2)]
    assert sorted(c.keys()) == ["a", "c"]
    s = c.stats()
    assert s["replacements"] == 1 and s["evictions"] == 1


def test_batcher_cancelled_flush_cancels_all_waiters():
    """CancelledError from flush_fn must not strand coalesced waiters.

    It is a BaseException, so the generic error fan-out never sees it; the
    regression was three submit() coroutines awaiting futures nobody would
    ever resolve.  wait_for puts a hard bound on the hang.
    """
    async def main():
        async def flush(key, items):
            raise asyncio.CancelledError()

        mb = MicroBatcher(flush, window=0.005)
        results = await asyncio.wait_for(
            asyncio.gather(*(mb.submit("k", i) for i in range(3)),
                           return_exceptions=True),
            timeout=2.0)
        assert all(isinstance(r, asyncio.CancelledError) for r in results)
        assert mb.flushes == 1          # the flush still counts
        assert mb.idle()                # nothing left pending or in flight

    asyncio.run(main())


def test_batcher_timer_cancellation_rejects_pending_waiters():
    """Cancelling a window timer (teardown) cancels the waiters it covered."""
    async def main():
        async def flush(key, items):
            return items

        mb = MicroBatcher(flush, window=30.0)   # far beyond the test
        waiter = asyncio.ensure_future(mb.submit("k", 1))

        async def timer_sleeping():
            # deterministic, load-immune sync: wait for the timer task to
            # exist and then to be SUSPENDED at an await (its window
            # sleep), so cancel() lands inside _timed_flush — not before
            # the coroutine's first step, where cleanup could never run
            while not mb._timers:
                await asyncio.sleep(0)
            (t,) = mb._timers.values()
            while t.get_coro().cr_await is None:
                await asyncio.sleep(0)
            return t

        timer = await asyncio.wait_for(timer_sleeping(), timeout=2.0)
        timer.cancel()
        with pytest.raises(asyncio.CancelledError):
            await asyncio.wait_for(waiter, timeout=2.0)
        assert mb.idle()                        # no orphaned pending state

    asyncio.run(main())


def test_batcher_size_cap_flush_survives_timer_cancel_race():
    """_flush_now's own timer cancel must not touch the claimed batch."""
    async def main():
        async def flush(key, items):
            return [x * 10 for x in items]

        mb = MicroBatcher(flush, window=30.0, max_batch=2)
        # first submit opens the window; second hits the size cap, which
        # cancels the timer and flushes both immediately
        results = await asyncio.wait_for(
            asyncio.gather(mb.submit("k", 1), mb.submit("k", 2)),
            timeout=2.0)
        assert results == [10, 20]

        async def spin_idle():
            # the cancelled timer and the flush task's finally block are
            # plain ready-queue callbacks: yielding (no wall-clock sleep)
            # until idle() is deterministic under any load
            while not mb.idle():
                await asyncio.sleep(0)

        await asyncio.wait_for(spin_idle(), timeout=2.0)
        assert mb.idle()

    asyncio.run(main())


def test_service_reregister_releases_old_collection():
    """Re-registering a qrel_id must release the displaced collection."""
    qrel = {"q1": {"d1": 1, "d2": 0}}
    run = {"q1": {"d1": 2.0, "d2": 1.0}}

    async def main():
        svc = EvaluationService(backend="single")
        svc.register_qrel("c", qrel, ("map",))
        svc.register_run("c", "r", run=run)
        old = svc._collections.get("c")
        assert old.runs                  # the state that used to leak
        svc.register_qrel("c", qrel, ("map",))
        assert not old.runs              # displaced collection was released
        assert old._sharded is None
        s = svc.stats()
        assert s["cache"]["replacements"] == 1
        assert s["released_collections"] == 1
        # the fresh collection starts clean and still serves
        with pytest.raises(KeyError):
            await svc.evaluate("c", run_ref="r", scores=[1.0, 2.0])
        res = await svc.evaluate("c", run=run)
        assert res.per_query["q1"]["map"] == 1.0

    asyncio.run(main())


def test_service_drop_qrel_releases_state():
    qrel = {"q1": {"d1": 1}}

    async def main():
        svc = EvaluationService(backend="single")
        svc.register_qrel("c", qrel, ("map",))
        svc.register_run("c", "r", run={"q1": {"d1": 1.0}})
        col = svc._collections.get("c")
        assert svc.drop_qrel("c") is True
        assert not col.runs
        assert svc.stats()["released_collections"] == 1
        assert svc.drop_qrel("c") is False

    asyncio.run(main())


def test_batcher_separate_keys_flush_separately():
    async def main():
        calls = []

        async def flush(key, items):
            calls.append((key, len(items)))
            return items

        mb = MicroBatcher(flush, window=0.005)
        await asyncio.gather(mb.submit("a", 1), mb.submit("b", 2),
                             mb.submit("a", 3))
        return sorted(calls)

    assert asyncio.run(main()) == [("a", 2), ("b", 1)]


# -- front-ends (sockets / subprocess: slow) ---------------------------------


async def _tcp_request(host, port, lines):
    reader, writer = await asyncio.open_connection(host, port)
    for line in lines:
        writer.write((json.dumps(line) + "\n").encode())
    await writer.drain()
    out = []
    for _ in lines:
        out.append(json.loads(await reader.readline()))
    writer.close()
    await writer.wait_closed()
    return out


@pytest.mark.slow
def test_tcp_frontend_coalesces_across_connections(collection):
    """Concurrent requests from DIFFERENT TCP clients share backend calls."""
    from repro.serve import serve_tcp

    run, qrel = collection
    n = 6
    runs = _runs_with_perturbed_scores(run, n)
    want = [RelevanceEvaluator(qrel, ("map",)).evaluate(r) for r in runs]

    async def main():
        svc = EvaluationService(window=0.05, backend="single")
        svc.register_qrel("c", qrel, ("map",))
        server = await serve_tcp(svc, "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        try:
            replies = await asyncio.gather(*(
                _tcp_request("127.0.0.1", port,
                             [{"op": "evaluate", "id": i, "qrel_id": "c",
                               "run": runs[i]}])
                for i in range(n)))
        finally:
            server.close()
            await server.wait_closed()
        return replies, svc.stats()

    replies, stats = asyncio.run(main())
    assert stats["backend_calls"] < n
    for i, (reply,) in enumerate(replies):
        assert reply["ok"], reply
        got = reply["result"]["per_query"]
        for qid in want[i]:
            assert got[qid]["map"] == pytest.approx(want[i][qid]["map"],
                                                    abs=1e-9)


@pytest.mark.slow
def test_tcp_large_qrel_regression(collection):
    """ISSUE 4 repro: a >64 KiB register_qrel line used to raise
    ``ValueError: Separator is found, but chunk is longer than limit`` in
    the reader loop and kill the connection with an empty response.  At the
    server's DEFAULT limit it must round-trip bit-identically."""
    from repro.serve import serve_tcp

    run, qrel = collection
    # pad ids so the qrel line clears 64 KiB by a wide margin
    big_qrel = {f"{qid}-{'x' * 220}": {f"{d}-{'y' * 220}": r
                                      for d, r in docs.items()}
                for qid, docs in qrel.items()}
    big_run = {f"{qid}-{'x' * 220}": {f"{d}-{'y' * 220}": s
                                     for d, s in docs.items()}
               for qid, docs in run.items()}
    line = json.dumps({"op": "register_qrel", "id": 1, "qrel_id": "big",
                       "qrel": big_qrel, "measures": ["map", "ndcg"]})
    assert len(line) > (1 << 16)

    async def main():
        svc = EvaluationService(backend="single")
        server = await serve_tcp(svc, "127.0.0.1", 0)  # default limit
        port = server.sockets[0].getsockname()[1]
        try:
            reg, res = await _tcp_request("127.0.0.1", port, [
                json.loads(line),
                {"op": "evaluate", "id": 2, "qrel_id": "big",
                 "run": big_run}])
        finally:
            server.close()
            await server.wait_closed()
        return reg, res

    reg, res = asyncio.run(main())
    assert reg["ok"], reg
    assert res["ok"], res
    want = RelevanceEvaluator(big_qrel, ("map", "ndcg")).evaluate(big_run)
    assert res["result"]["per_query"] == want  # bit-identical


@pytest.mark.slow
def test_stdio_frontend_subprocess():
    """python -m repro.serve end to end over stdin/stdout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    requests = "\n".join([
        json.dumps({"op": "ping", "id": 0}),
        json.dumps({"op": "evaluate", "id": 1, "qrel_id": "default",
                    "run": {"q1": {"APPLE": 2.0, "BANANA": 1.0}}}),
        json.dumps({"op": "stats", "id": 2}),
    ]) + "\n"
    out = subprocess.run(
        [sys.executable, "-m", "repro.serve", "--qrel", QREL_PATH,
         "-m", "map", "--window-ms", "1"],
        input=requests, capture_output=True, text=True, env=env,
        timeout=180)
    assert out.returncode == 0, out.stderr[-2000:]
    replies = {r["id"]: r for r in map(json.loads,
                                       out.stdout.strip().splitlines())}
    assert replies[0]["result"] == "pong"
    assert replies[1]["ok"], replies[1]
    assert replies[1]["result"]["per_query"]["q1"]["map"] > 0
    assert replies[2]["result"]["requests"] == 1
    assert "registered qrel 'default'" in out.stderr
