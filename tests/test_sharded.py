"""Sharded evaluation pipeline: bit-identity with the single-device evaluator.

Acceptance for the sharded path: ``ShardedEvaluator`` must produce per-query
results **bit-identical** to ``RelevanceEvaluator.evaluate`` on the
conformance fixtures for mesh sizes 1, 2, and 4.  Mesh size 1 runs
in-process; 2 and 4 need ``--xla_force_host_platform_device_count`` set
before jax initializes, hence subprocesses.  These are tier-1 tests (not
marked slow): they guard the acceptance criterion of the sharded pipeline.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_BIT_IDENTITY_CODE = """
    import numpy as np
    from repro.core import RelevanceEvaluator, aggregate_results, \\
        supported_measures, trec
    from repro.distributed import ShardedEvaluator

    qrel = trec.load_qrel({qrel!r})
    run = trec.load_run({run!r})
    ev = RelevanceEvaluator(qrel, supported_measures)
    want = ev.evaluate(run)
    sev = ShardedEvaluator(ev)
    assert sev.n_shards == {devices}, sev.n_shards
    res = sev.evaluate(run)
    assert set(res.per_query) == set(want)
    for qid in want:
        for key, val in want[qid].items():
            got = res.per_query[qid][key]
            assert got == val, (qid, key, got, val)  # bit-identical
    agg = aggregate_results(want)
    for key, val in agg.items():
        np.testing.assert_allclose(res.aggregates[key], val, atol=1e-6,
                                   err_msg=key)
    print("BIT_IDENTICAL")
"""


def _fixture_paths():
    return (os.path.join(FIXTURES, "conformance.qrel"),
            os.path.join(FIXTURES, "conformance.run"))


def _run_subprocess(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_bit_identical_mesh1():
    qrel_path, run_path = _fixture_paths()
    code = _BIT_IDENTITY_CODE.format(qrel=qrel_path, run=run_path, devices=1)
    env_devices = 1
    # in-process: the tier-1 session runs on exactly one device (conftest)
    ns = {}
    exec(textwrap.dedent(code), ns)  # raises on mismatch


@pytest.mark.parametrize("devices", [2, 4])
def test_sharded_bit_identical_multi_device(devices):
    qrel_path, run_path = _fixture_paths()
    out = _run_subprocess(
        _BIT_IDENTITY_CODE.format(qrel=qrel_path, run=run_path,
                                  devices=devices), devices)
    assert "BIT_IDENTICAL" in out


_MESH_INVARIANCE_CODE = """
    import json
    from repro.core import RelevanceEvaluator, supported_measures
    from repro.data.synthetic_ir import synthesize_run
    from repro.distributed import ShardedEvaluator

    run, qrel = synthesize_run(12, 30)
    ev = RelevanceEvaluator(qrel, supported_measures)
    res = ShardedEvaluator(ev).evaluate(run)
    print(json.dumps(res.per_query, sort_keys=True))
"""


def test_sharded_results_invariant_across_mesh_sizes():
    """Measures are row-independent: sharding must not change ANY bit, even
    on synthetic float data where the kernel and the reference engine may
    legitimately differ by an ulp."""
    out2 = _run_subprocess(_MESH_INVARIANCE_CODE, devices=2)
    out4 = _run_subprocess(_MESH_INVARIANCE_CODE, devices=4)
    assert out2 == out4
    import json

    per_query = json.loads(out2)
    assert len(per_query) == 12

    # and vs the reference engine: exact for reference-computed measures,
    # <= ~1 ulp for fused-kernel columns (float association, documented)
    from repro.core import RelevanceEvaluator, supported_measures
    from repro.data.synthetic_ir import synthesize_run

    run, qrel = synthesize_run(12, 30)
    want = RelevanceEvaluator(qrel, supported_measures).evaluate(run)
    for qid in want:
        for key, val in want[qid].items():
            assert per_query[qid][key] == pytest.approx(val, abs=1e-6), \
                (qid, key)


def test_sharded_buffer_rescore_matches_evaluate_buffer():
    """Session fast path under sharding: fresh scores, zero string work."""
    from repro.core import RelevanceEvaluator, supported_measures, trec
    from repro.distributed import ShardedEvaluator

    qrel_path, run_path = _fixture_paths()
    ev = RelevanceEvaluator(trec.load_qrel(qrel_path), supported_measures)
    buf = ev.buffer_from_arrays(*trec.load_run_arrays(run_path))
    sev = ShardedEvaluator(ev)
    fresh = np.linspace(1.0, 0.1, buf.qidx.shape[0]).astype(np.float32)
    want = ev.evaluate_buffer(buf, scores=fresh)
    got = sev.evaluate_buffer(buf, scores=fresh).per_query
    for qid in want:
        for key, val in want[qid].items():
            assert got[qid][key] == val, (qid, key)


def test_sharded_from_files_and_uneven_padding():
    """from_files ingest + a query count that does not divide the mesh."""
    from repro.distributed import ShardedEvaluator

    qrel_path, run_path = _fixture_paths()
    sev, buf = ShardedEvaluator.from_files(qrel_path, run_path,
                                           measures=("map", "ndcg"))
    res = sev.evaluate_buffer(buf)
    want = sev.evaluator.evaluate_buffer(buf)
    assert set(res.per_query) == set(want)
    for qid in want:
        for key, val in want[qid].items():
            assert res.per_query[qid][key] == val
    # aggregates equal the mean over real queries only (padding masked out)
    for key in ("map", "ndcg"):
        vals = [want[q][key] for q in want]
        np.testing.assert_allclose(res.aggregates[key], np.mean(vals),
                                   atol=1e-6)


def test_sharded_empty_run():
    from repro.core import RelevanceEvaluator
    from repro.distributed import ShardedEvaluator

    ev = RelevanceEvaluator({"q1": {"d1": 1}}, ("map",))
    res = ShardedEvaluator(ev).evaluate({})
    assert res.per_query == {} and res.aggregates == {}


def test_evaluator_convenience_method():
    from repro.core import RelevanceEvaluator

    ev = RelevanceEvaluator({"q1": {"d1": 1, "d2": 0}}, ("map",))
    res = ev.evaluate_sharded({"q1": {"d1": 0.2, "d2": 0.9}})
    assert res.per_query["q1"]["map"] == 0.5
