"""Conformance tests for :mod:`repro.stats` — the in-JAX significance stack.

Three layers of evidence, so a numerical regression cannot hide:

1. **Hand-computed fixtures** at degrees of freedom where the t
   distribution has a closed form (df=1 is Cauchy, df=3 is elementary),
   checked to 1e-6.
2. **scipy cross-checks** on random data (skipped when scipy is absent);
   float32 ``betainc`` drifts with df, so random-data tolerances are
   looser than the fixture tolerances.
3. **Structural properties** that hold for every input: antisymmetric
   zero-diagonal t, symmetric unit-diagonal p, Holm <= Bonferroni <= 1,
   Monte-Carlo permutation p within a CI-style bound of the exact
   enumeration.
"""

import math

import numpy as np
import pytest

from repro import stats

# two runs over two queries whose per-query difference is d = [0.1, 0.3]:
# mean 0.2, sd 0.1*sqrt(2), t = 2 at df = 1 (Cauchy), so the two-sided
# p-value has the closed form 1 - (2/pi) * atan(|t|).
X_DF1 = np.array([[0.4, 0.6], [0.3, 0.3]], dtype=np.float32)
T_DF1 = 2.0
P_DF1 = 1.0 - (2.0 / math.pi) * math.atan(2.0)  # 0.29516723...

# d = [0.1, 0.2, 0.3, 0.4]: mean 0.25, t = sqrt(15) at df = 3, where
# P(|T| > t) = 1 - 2/pi * (atan(u) + u/(1+u^2)) with u = t/sqrt(3).
X_DF3 = np.array([[0.2, 0.4, 0.6, 0.8], [0.1, 0.2, 0.3, 0.4]],
                 dtype=np.float32)
T_DF3 = math.sqrt(15.0)
_u = T_DF3 / math.sqrt(3.0)
P_DF3 = 1.0 - (2.0 / math.pi) * (math.atan(_u) + _u / (1.0 + _u * _u))


def _rand(k, q, seed=0):
    return np.random.default_rng(seed).random((k, q)).astype(np.float32)


# -- hand-computed fixtures ---------------------------------------------------


def test_t_matrix_df1_closed_form():
    t, p = (np.asarray(a) for a in stats.paired_t_matrix(X_DF1))
    assert abs(float(t[0, 1]) - T_DF1) < 1e-6
    assert abs(float(p[0, 1]) - P_DF1) < 1e-6
    assert float(t[1, 0]) == -float(t[0, 1])
    assert float(p[1, 0]) == float(p[0, 1])


def test_t_matrix_df3_closed_form():
    t, p = (np.asarray(a) for a in stats.paired_t_matrix(X_DF3))
    assert abs(float(t[0, 1]) - T_DF3) < 1e-5
    assert abs(float(p[0, 1]) - P_DF3) < 1e-6


def test_diff_means_fixture():
    d = np.asarray(stats.paired_diff_means(X_DF1))
    assert d[0, 1] == pytest.approx(0.2, abs=1e-7)
    assert d[1, 0] == pytest.approx(-0.2, abs=1e-7)
    assert d[0, 0] == d[1, 1] == 0.0


def test_exact_permutation_df1():
    # Q=2 -> 4 sign patterns; |mean| of [.1,.3] flips: {.2,.1,.1,.2} so
    # every pattern ties-or-beats the observed |.2| except the two at .1:
    # p = 2/4.
    p = np.asarray(stats.paired_permutation_exact(X_DF1))
    assert float(p[0, 1]) == pytest.approx(0.5, abs=1e-7)


def test_holm_and_bonferroni_hand_example():
    # classic three-hypothesis example: raw (0.01, 0.04, 0.03)
    p = np.ones((3, 3), dtype=np.float32)
    p[0, 1] = p[1, 0] = 0.01
    p[0, 2] = p[2, 0] = 0.04
    p[1, 2] = p[2, 1] = 0.03
    holm = np.asarray(stats.holm_matrix(p))
    bonf = np.asarray(stats.bonferroni_matrix(p))
    assert holm[0, 1] == pytest.approx(0.03, abs=1e-7)   # 0.01 * 3
    assert holm[1, 2] == pytest.approx(0.06, abs=1e-7)   # 0.03 * 2
    assert holm[0, 2] == pytest.approx(0.06, abs=1e-7)   # monotone step-down
    assert bonf[0, 1] == pytest.approx(0.03, abs=1e-7)
    assert bonf[0, 2] == pytest.approx(0.12, abs=1e-7)
    assert bonf[1, 2] == pytest.approx(0.09, abs=1e-7)
    for m in (holm, bonf):
        assert np.array_equal(np.diag(m), np.ones(3))
        assert np.array_equal(m, m.T)


# -- degenerate inputs --------------------------------------------------------


def test_identical_runs_give_t_zero_p_one():
    x = np.tile(_rand(1, 8), (3, 1))
    t, p = (np.asarray(a) for a in stats.paired_t_matrix(x))
    assert np.array_equal(t, np.zeros((3, 3)))
    assert np.array_equal(p, np.ones((3, 3)))


def test_constant_nonzero_diff_gives_infinite_t():
    # values exactly representable in float32 so the per-query difference
    # is EXACTLY constant (se = 0) rather than constant-up-to-rounding
    base = np.array([0.25, 0.5, 0.75, 0.0, 0.25, 0.5], dtype=np.float32)
    x = np.stack([base, base + 0.5]).astype(np.float32)
    t, p = (np.asarray(a) for a in stats.paired_t_matrix(x))
    assert t[0, 1] == -np.inf and t[1, 0] == np.inf
    assert p[0, 1] == 0.0 and p[1, 0] == 0.0


def test_input_validation():
    with pytest.raises(ValueError):
        stats.paired_t_matrix(np.zeros(4, np.float32))  # 1-D
    with pytest.raises(ValueError):
        stats.paired_t_matrix(np.zeros((3, 1), np.float32))  # Q < 2
    with pytest.raises(ValueError):
        stats.paired_permutation_exact(
            np.zeros((2, stats.EXACT_ENUMERATION_MAX_Q + 1), np.float32))
    with pytest.raises(ValueError):
        stats.significance_report(X_DF1, tests=("wilcoxon",))


# -- structural properties on random data ------------------------------------


@pytest.mark.parametrize("k,q,seed", [(3, 5, 0), (6, 12, 1), (9, 40, 2)])
def test_t_and_p_matrix_structure(k, q, seed):
    x = _rand(k, q, seed)
    t, p = (np.asarray(a) for a in stats.paired_t_matrix(x))
    assert np.array_equal(t, -t.T)
    assert np.array_equal(np.diag(t), np.zeros(k))
    assert np.array_equal(p, p.T)
    assert np.array_equal(np.diag(p), np.ones(k))
    assert ((p >= 0) & (p <= 1)).all()


@pytest.mark.parametrize("k,q,seed", [(4, 8, 3), (7, 25, 4)])
def test_holm_between_raw_and_bonferroni(k, q, seed):
    _, p = stats.paired_t_matrix(_rand(k, q, seed))
    p = np.asarray(p)
    holm = np.asarray(stats.holm_matrix(p))
    bonf = np.asarray(stats.bonferroni_matrix(p))
    off = ~np.eye(k, dtype=bool)
    assert (holm[off] >= p[off] - 1e-7).all()
    assert (holm[off] <= bonf[off] + 1e-7).all()
    assert (holm <= 1.0).all() and (bonf <= 1.0).all()
    assert np.array_equal(holm, holm.T)


def test_permutation_matrix_structure():
    p = np.asarray(stats.paired_permutation_matrix(_rand(5, 10, 7),
                                                   n_permutations=500))
    assert np.array_equal(p, p.T)
    assert np.array_equal(np.diag(p), np.ones(5))
    assert ((p > 0) & (p <= 1)).all()  # add-one MC estimate is never 0


def test_permutation_seed_determinism():
    x = _rand(4, 9, 8)
    a = np.asarray(stats.paired_permutation_matrix(x, seed=3))
    b = np.asarray(stats.paired_permutation_matrix(x, seed=3))
    c = np.asarray(stats.paired_permutation_matrix(x, seed=4))
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_mc_permutation_within_ci_of_exact():
    """(count+1)/(P+1) must land within a binomial CI of the exact p."""
    x = _rand(4, 10, seed=11)
    n_perm = 4000
    exact = np.asarray(stats.paired_permutation_exact(x))
    mc = np.asarray(stats.paired_permutation_matrix(
        x, n_permutations=n_perm, seed=5))
    for i in range(4):
        for j in range(i + 1, 4):
            pe = float(exact[i, j])
            bound = 3.5 * math.sqrt(pe * (1 - pe) / n_perm) + 2 / (n_perm + 1)
            assert abs(float(mc[i, j]) - pe) <= bound, (i, j, pe, mc[i, j])


# -- significance_report ------------------------------------------------------


def test_significance_report_keys_and_consistency():
    x = _rand(3, 7, seed=6)
    rep = stats.significance_report(x, tests=("t", "permutation"),
                                    n_permutations=300, seed=1)
    for key in ("means", "diff", "t", "p", "p_holm", "p_bonferroni",
                "p_permutation", "p_permutation_holm",
                "p_permutation_bonferroni"):
        assert key in rep, key
        assert isinstance(rep[key], np.ndarray)
    assert rep["means"].shape == (3,)
    assert np.allclose(rep["means"], x.mean(axis=1), atol=1e-6)
    t, p = (np.asarray(a) for a in stats.paired_t_matrix(x))
    assert np.array_equal(rep["t"], t)
    assert np.array_equal(rep["p"], p)
    assert np.array_equal(rep["p_holm"], np.asarray(stats.holm_matrix(p)))
    rep_t = stats.significance_report(x)
    assert "p_permutation" not in rep_t


# -- scipy cross-checks (skipped when scipy is not installed) ----------------


def test_t_matrix_matches_scipy():
    sps = pytest.importorskip("scipy.stats")
    x = _rand(8, 40, seed=9)
    t, p = (np.asarray(a) for a in stats.paired_t_matrix(x))
    for i in range(8):
        for j in range(i + 1, 8):
            ref = sps.ttest_rel(x[i], x[j])
            assert abs(float(t[i, j]) - ref.statistic) < 1e-4
            # float32 betainc error grows with df; 2.4e-5 observed at df=39
            assert abs(float(p[i, j]) - ref.pvalue) < 1e-4


def test_fixtures_match_scipy_to_1e6():
    sps = pytest.importorskip("scipy.stats")
    for x in (X_DF1, X_DF3):
        _, p = (np.asarray(a) for a in stats.paired_t_matrix(x))
        ref = sps.ttest_rel(x[0], x[1])
        assert abs(float(p[0, 1]) - ref.pvalue) < 1e-6


def test_holm_matches_scipy_false_discovery_control():
    sps = pytest.importorskip("scipy.stats")
    if not hasattr(sps, "false_discovery_control"):
        pytest.skip("scipy too old for false_discovery_control")
    # scipy has no paired Holm-over-matrix helper; cross-check our Holm
    # against statsmodels-style manual step-down on the flat vector.
    _, p = stats.paired_t_matrix(_rand(6, 15, seed=10))
    p = np.asarray(p)
    iu = np.triu_indices(6, 1)
    flat = p[iu]
    order = np.argsort(flat)
    m = len(flat)
    ref = np.empty_like(flat)
    ref[order] = np.minimum(
        np.maximum.accumulate(flat[order] * (m - np.arange(m))), 1.0)
    holm = np.asarray(stats.holm_matrix(p))
    assert np.allclose(holm[iu], ref, atol=1e-7)
