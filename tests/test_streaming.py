"""Streaming (in-loop) evaluator vs one-shot batch evaluation."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batch_from_dense, compute_measures, parse_measures
from repro.core import streaming

RNG = np.random.default_rng(3)
NAMES = ("ndcg", "recip_rank", "P")


def _rand_batch(q, d):
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    rel = jnp.asarray(RNG.integers(0, 2, (q, d)).astype(np.float32))
    return batch_from_dense(scores, rel)


def test_streaming_equals_batch():
    batches = [_rand_batch(4, 50) for _ in range(3)]
    state = streaming.metric_init(NAMES)
    for b in batches:
        state = streaming.metric_update(state, b, NAMES)
    stream = streaming.metric_finalize(state)

    parsed = parse_measures(NAMES)
    sums = {k: 0.0 for k in stream}
    n = 0
    for b in batches:
        per_q = compute_measures(b, parsed)
        for k in sums:
            sums[k] += float(jnp.sum(per_q[k]))
        n += b.scores.shape[0]
    for k in sums:
        assert float(stream[k]) == pytest.approx(sums[k] / n, abs=1e-5), k


def test_streaming_respects_query_mask():
    b = _rand_batch(4, 20)
    masked = b._replace(query_mask=jnp.asarray([True, True, False, False]))
    state = streaming.metric_update(streaming.metric_init(NAMES), masked,
                                    NAMES)
    assert float(state["__count"]) == 2.0


def test_rank_metrics_single_relevant_equivalence():
    """rank_metrics == full measures when exactly one doc is relevant."""
    q, d = 6, 40
    scores = jnp.asarray(RNG.standard_normal((q, d)).astype(np.float32))
    gold = jnp.asarray(RNG.integers(0, d, (q,)).astype(np.int32))
    rel = jnp.zeros((q, d)).at[jnp.arange(q), gold].set(1.0)
    batch = batch_from_dense(scores, rel)
    parsed = parse_measures(("ndcg", "recip_rank", "success"))
    full = compute_measures(batch, parsed)

    from repro.core.sorting import gold_rank

    ranks = gold_rank(scores, gold)
    quick = streaming.rank_metrics(ranks, ks=(1, 5, 10))
    assert float(quick["recip_rank"]) == pytest.approx(
        float(jnp.mean(full["recip_rank"])), abs=1e-5)
    assert float(quick["ndcg"]) == pytest.approx(
        float(jnp.mean(full["ndcg"])), abs=1e-5)
    assert float(quick["success_10"]) == pytest.approx(
        float(jnp.mean(full["success_10"])), abs=1e-5)


def test_gold_rank_tie_semantics():
    from repro.core.sorting import gold_rank

    scores = jnp.asarray([[1.0, 2.0, 2.0, 0.5]])
    # ranking: idx1 (2.0, wins tie by lower index), idx2 (2.0), idx0, idx3
    assert int(gold_rank(scores, jnp.asarray([1]))[0]) == 1
    assert int(gold_rank(scores, jnp.asarray([2]))[0]) == 2
    assert int(gold_rank(scores, jnp.asarray([0]))[0]) == 3
    assert int(gold_rank(scores, jnp.asarray([3]))[0]) == 4
