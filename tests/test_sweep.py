"""``evaluate_sweep`` must be bit-identical to K independent evaluations.

The sweep's whole value proposition is "same numbers, one dispatch", so
these tests assert EXACT float equality between the ``[K, Q, M]`` sweep
table and per-run :meth:`RelevanceEvaluator.evaluate_buffer` calls —
including ragged per-query document counts, chunked dispatch groups, and
randomized shapes (hypothesis when installed, a seeded sweep otherwise).
The sharded backend is held to the same standard against its own
``evaluate_buffer`` (the fused kernel's float-gain reductions drift ~1 ulp
from the single-device core, so cross-backend comparison is 1e-6).
"""

import numpy as np
import pytest

from repro.core import RelevanceEvaluator, evaluate_sweep, trec
from repro.core.evaluator import RunBuffer
from repro.core.sweep import common_qids

MEASURES = ("map", "ndcg", "P_5", "recip_rank", "gm_map")


def _make_runs(k, n_queries, n_docs, seed=0, ragged=False):
    """K random runs + a qrel over the same corpus; ragged varies depth."""
    rng = np.random.default_rng(seed)
    qrel = {}
    base_docs = {}
    for qi in range(n_queries):
        qid = f"q{qi}"
        nd = int(rng.integers(1, n_docs + 1)) if ragged else n_docs
        docs = [f"d{j}" for j in range(nd)]
        base_docs[qid] = docs
        qrel[qid] = {d: int(rng.integers(0, 3)) for d in docs}
        if not any(qrel[qid].values()):
            qrel[qid][docs[0]] = 1  # every query judges something relevant
    runs = []
    for _ in range(k):
        runs.append({qid: {d: float(s) for d, s in
                           zip(docs, rng.random(len(docs)))}
                     for qid, docs in base_docs.items()})
    return qrel, runs


def _assert_table_matches_per_run(result, ev, runs):
    for ki, run in enumerate(runs):
        want = ev.evaluate_buffer(
            run if isinstance(run, RunBuffer)
            else ev.tokenize_run({q: run[q] for q in result.qids}))
        for qi, qid in enumerate(result.qids):
            for mi, key in enumerate(result.measure_keys):
                assert result.table[ki, qi, mi] == \
                    want[qid][key], (ki, qid, key)


def test_k8_bit_identical_to_independent_evaluations():
    qrel, runs = _make_runs(8, 12, 9, seed=1)
    ev = RelevanceEvaluator(qrel, MEASURES)
    result = evaluate_sweep(ev, runs)
    assert result.table.shape == (8, 12, len(ev.measure_keys))
    assert result.run_names == tuple(f"run_{i}" for i in range(8))
    _assert_table_matches_per_run(result, ev, runs)


def test_ragged_document_counts_stay_bit_identical():
    qrel, runs = _make_runs(5, 10, 17, seed=2, ragged=True)
    ev = RelevanceEvaluator(qrel, MEASURES)
    result = evaluate_sweep(ev, runs)
    _assert_table_matches_per_run(result, ev, runs)


def test_chunked_dispatch_is_identical_to_one_shot():
    qrel, runs = _make_runs(7, 6, 5, seed=3)
    one = evaluate_sweep(RelevanceEvaluator(qrel, MEASURES), runs)
    ev = RelevanceEvaluator(qrel, MEASURES)
    ev.chunk_queries = 13  # groups of 2 runs (13 // 6), then a remainder
    chunked = evaluate_sweep(ev, runs)
    assert np.array_equal(one.table, chunked.table)


def test_buffer_input_path_identical_to_dict_path():
    qrel, runs = _make_runs(4, 8, 6, seed=4)
    ev = RelevanceEvaluator(qrel, MEASURES)
    via_dicts = evaluate_sweep(ev, runs)
    bufs = [ev.tokenize_run({q: r[q] for q in via_dicts.qids}) for r in runs]
    via_bufs = evaluate_sweep(ev, bufs)
    assert via_dicts.qids == via_bufs.qids
    assert np.array_equal(via_dicts.table, via_bufs.table)
    _assert_table_matches_per_run(via_bufs, ev, bufs)


def test_sharded_backend_matches_sharded_evaluate_buffer():
    from repro.distributed.sharded_evaluator import ShardedEvaluator

    qrel, runs = _make_runs(4, 9, 7, seed=5)
    ev = RelevanceEvaluator(qrel, MEASURES)
    result = evaluate_sweep(ev, runs, backend="sharded")
    sev = ShardedEvaluator(ev)
    # exact vs the SAME backend's per-run path...
    for ki, run in enumerate(runs):
        res = sev.evaluate(
            {q: run[q] for q in result.qids})
        for qi, qid in enumerate(result.qids):
            for mi, key in enumerate(result.measure_keys):
                assert result.table[ki, qi, mi] == \
                    res.per_query[qid][key], (ki, qid, key)
    # ...and within float32 noise of the single-device sweep (the fused
    # kernel's gain reductions associate differently: ~1 ulp on ndcg)
    single = evaluate_sweep(ev, runs)
    assert np.allclose(result.table, single.table, atol=1e-6)


# -- randomized shapes: hypothesis when available, seeded sweep always -------


def _roundtrip(k, n_queries, n_docs, seed, ragged):
    qrel, runs = _make_runs(k, n_queries, n_docs, seed=seed, ragged=ragged)
    ev = RelevanceEvaluator(qrel, ("map", "ndcg", "P_5"))
    _assert_table_matches_per_run(evaluate_sweep(ev, runs), ev, runs)


def test_random_shapes_bit_identical_seeded():
    rng = np.random.default_rng(123)
    for trial in range(6):
        _roundtrip(int(rng.integers(1, 7)), int(rng.integers(1, 11)),
                   int(rng.integers(1, 14)), seed=100 + trial,
                   ragged=bool(trial % 2))


def test_random_shapes_bit_identical_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=15, deadline=None)
    @hyp.given(k=st.integers(1, 6), n_queries=st.integers(1, 10),
               n_docs=st.integers(1, 12), seed=st.integers(0, 2**16),
               ragged=st.booleans())
    def inner(k, n_queries, n_docs, seed, ragged):
        _roundtrip(k, n_queries, n_docs, seed, ragged)

    inner()


# -- SweepResult helpers ------------------------------------------------------


def test_sweep_result_views_agree_with_table():
    qrel, runs = _make_runs(3, 5, 4, seed=6)
    result = evaluate_sweep(qrel, dict(zip("abc", runs)),
                            measures=("map", "ndcg"))
    assert result.run_names == ("a", "b", "c")
    pq = result.per_query()
    for ki, name in enumerate(result.run_names):
        for qi, qid in enumerate(result.qids):
            assert pq[name][qid]["map"] == result.table[ki, qi, 0]
    sl = result.measure("ndcg")
    assert sl.shape == (3, 5)
    assert np.array_equal(sl, result.table[:, :, 1])
    with pytest.raises(KeyError):
        result.measure("P_5")
    aggs = result.aggregates()
    assert aggs["a"]["map"] == pytest.approx(
        float(result.table[0, :, 0].mean(dtype=np.float64)))


def test_gm_map_aggregate_is_geometric():
    qrel, runs = _make_runs(2, 4, 5, seed=7)
    result = evaluate_sweep(qrel, runs, measures=("map", "gm_map"))
    want = RelevanceEvaluator(qrel, ("map", "gm_map")).evaluate(
        {q: runs[0][q] for q in result.qids})
    got = result.aggregates()["run_0"]["gm_map"]
    ref = np.exp(np.mean([want[q]["gm_map"] for q in result.qids]))
    assert got == pytest.approx(float(ref), rel=1e-6)


def test_compare_returns_significance_bundle():
    qrel, runs = _make_runs(3, 8, 6, seed=8)
    result = evaluate_sweep(qrel, runs, measures=("map",))
    rep = result.compare("map")
    assert rep["run_names"] == result.run_names
    assert rep["measure"] == "map"
    assert rep["t"].shape == (3, 3)
    assert np.array_equal(rep["p"], rep["p"].T)
    # an identical pair of runs must come out utterly non-significant
    twin = evaluate_sweep(qrel, [runs[0], dict(runs[0])], measures=("map",))
    rep2 = twin.compare("map")
    assert float(rep2["t"][0, 1]) == 0.0 and float(rep2["p"][0, 1]) == 1.0


# -- alignment and error paths -----------------------------------------------


def test_common_qids_intersection_in_first_run_order():
    qrel_qids = {"q1": 0, "q2": 1, "q3": 2}
    runs = [{"q3": {}, "q1": {}, "q2": {}, "qX": {}},
            {"q1": {}, "q3": {}}]
    assert common_qids(qrel_qids, runs) == ["q3", "q1"]


def test_dict_runs_align_on_common_judged_queries():
    qrel = {"q1": {"d1": 1}, "q2": {"d1": 1}, "q3": {"d1": 1}}
    runs = [{"q1": {"d1": 1.0}, "q2": {"d1": 1.0}, "q3": {"d1": 1.0}},
            {"q2": {"d1": 2.0}, "q3": {"d1": 2.0}}]
    result = evaluate_sweep(qrel, runs, measures=("map",))
    assert result.qids == ("q2", "q3")


def test_error_paths():
    qrel, runs = _make_runs(2, 3, 3, seed=9)
    ev = RelevanceEvaluator(qrel, ("map",))
    with pytest.raises(ValueError, match="evaluator already owns"):
        evaluate_sweep(ev, runs, measures=("map",))
    with pytest.raises(ValueError, match="no runs"):
        evaluate_sweep(ev, [])
    with pytest.raises(ValueError, match="names for"):
        evaluate_sweep(ev, runs, run_names=["only_one"])
    with pytest.raises(ValueError, match="run_names conflicts"):
        evaluate_sweep(ev, {"a": runs[0], "b": runs[1]},
                       run_names=["a", "b"])
    with pytest.raises(TypeError, match="mix"):
        evaluate_sweep(ev, [runs[0], ev.tokenize_run(runs[1])])
    with pytest.raises(TypeError, match="mix"):
        evaluate_sweep(ev, [ev.tokenize_run(runs[0]), runs[1]])
    with pytest.raises(ValueError, match="no common judged"):
        evaluate_sweep(ev, [runs[0], {"zzz": {"d1": 1.0}}])
    b0 = ev.tokenize_run(runs[0])
    b1 = ev.tokenize_run({"q0": runs[1]["q0"]})
    with pytest.raises(ValueError, match="different queries"):
        evaluate_sweep(ev, [b0, b1])
    scoreless = RunBuffer(b0.qids, b0.gidx, b0.qidx, b0.col, b0.counts,
                          b0.rel, b0.judged, b0.tiebreak, None)
    with pytest.raises(ValueError, match="no scores"):
        evaluate_sweep(ev, [scoreless, b0])


def test_conformance_fixture_sweep_matches_single_run_cli_values():
    """The golden fixtures run through the sweep give the known map values."""
    qrel = trec.load_qrel("tests/fixtures/conformance.qrel")
    runs = {name: trec.load_run(f"tests/fixtures/{name}.run")
            for name in ("conformance", "sweep_b", "sweep_c")}
    result = evaluate_sweep(qrel, runs, measures=("map",))
    aggs = result.aggregates()
    assert aggs["conformance"]["map"] == pytest.approx(0.5, abs=1e-6)
    assert aggs["sweep_c"]["map"] == pytest.approx(1.0, abs=1e-6)
    rep = result.compare("map")
    # sweep_c beats conformance on every query -> constant-sign diff,
    # infinite t, p = 0 (the CLI golden renders this pair with a '*')
    i, j = 0, 2
    assert float(rep["t"][i, j]) == -np.inf
    assert float(rep["p"][i, j]) == 0.0
