"""End-to-end behaviour: the paper's full workflow on this framework.

Train a small model with the device-resident evaluator fused into the loop,
checkpoint it, restart it, and verify the in-loop metrics move — the
pytrec_eval promise (evaluation cheap enough to run every step) end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.common import smoke_shape
from repro.data import lm_data, recsys_data
from repro.launch.api import get_arch
from repro.train import checkpoint as C
from repro.train.trainer import TrainConfig, Trainer

pytestmark = pytest.mark.slow


def _init_from_bundle(bundle, rng=np.random.default_rng(0)):
    """Concrete init for smoke training: real init fns via the step specs."""
    def mk(x):
        if x.dtype == jnp.int32:
            return jnp.zeros(x.shape, jnp.int32)
        if x.dtype == jnp.bool_:
            return jnp.ones(x.shape, bool)
        return jnp.asarray(
            rng.standard_normal(x.shape).astype(np.float32) * 0.05)
    return jax.tree.map(mk, bundle.arg_specs)


def test_lm_train_loss_falls_with_inloop_eval(tmp_path):
    from repro.launch.steps import lm_step_bundle
    from repro.models.transformer import TransformerConfig, init_transformer
    from repro.train import optimizer as O

    arch = get_arch("olmo-1b")
    cfg = TransformerConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=4, d_ff=128, vocab_size=128,
                            tie_embeddings=True, norm="nonparam", remat=False)
    shape = smoke_shape(arch.shapes["train_4k"], seq_len=32, global_batch=16)
    ocfg = O.OptimizerConfig(lr=3e-3, warmup_steps=5, decay_steps=10_000)
    bundle = lm_step_bundle(cfg, shape, None, opt_cfg=ocfg)

    params = init_transformer(jax.random.PRNGKey(0), cfg)
    init_opt, _ = O.adamw(ocfg)
    opt = init_opt(params)

    data_cfg = lm_data.LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=16, n_successors=8)
    gen = lm_data.MarkovLM(data_cfg)
    step_fn = jax.jit(bundle.step_fn)

    def data_iter():
        for b in gen.iterator():
            yield (jnp.asarray(b["tokens"]), jnp.asarray(b["labels"]))

    it = data_iter()
    losses, mrrs = [], []
    for _ in range(60):
        tokens, labels = next(it)
        params, opt, metrics = step_fn(params, opt, tokens, labels)
        losses.append(float(metrics["loss"]))
        mrrs.append(float(metrics["recip_rank"]))
    # loss falls, device-resident MRR of the gold token rises
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3
    assert np.mean(mrrs[-5:]) > np.mean(mrrs[:5]) + 0.05

    # checkpoint → restart → resume (fault-tolerance path, real model)
    d = str(tmp_path / "ck")
    C.save(d, 30, {"params": params, "opt": opt})
    restored, _ = C.restore(d, 30, jax.eval_shape(
        lambda: {"params": params, "opt": opt}))
    p2, o2 = restored["params"], restored["opt"]
    tokens, labels = next(it)
    _, _, m1 = step_fn(params, opt, tokens, labels)
    _, _, m2 = step_fn(p2, o2, tokens, labels)
    assert float(m1["loss"]) == float(m2["loss"])


def test_recsys_serving_with_inloop_metrics():
    """Batched serving requests, NDCG computed on device (paper pattern)."""
    arch = get_arch("sasrec")
    cfg = arch.make_config(smoke=True)
    shape = smoke_shape(arch.shapes["serve_p99"], batch=16, slate=32)
    bundle = arch.make_step(cfg, shape, None)

    from repro.models.recsys import sasrec_init

    params = sasrec_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    batch = {
        "items": jnp.asarray(rng.integers(0, cfg.n_items, (16, cfg.seq_len)),
                             jnp.int32),
        "pos": jnp.asarray(rng.integers(0, cfg.n_items, (16, cfg.seq_len)),
                           jnp.int32),
        "neg": jnp.asarray(rng.integers(0, cfg.n_items, (16, cfg.seq_len)),
                           jnp.int32),
        "mask": jnp.ones((16, cfg.seq_len), bool),
    }
    cand = jnp.asarray(rng.integers(0, cfg.n_items, (16, 32)), jnp.int32)
    rel = jnp.zeros((16, 32), jnp.int32).at[:, 0].set(1)
    scores, metrics = jax.jit(bundle.step_fn)(params, batch, cand, rel)
    assert scores.shape == (16, 32)
    for k in ("ndcg_cut_10", "recip_rank", "success_10"):
        assert 0.0 <= float(metrics[k]) <= 1.0


def test_trainer_with_gnn_end_to_end(tmp_path):
    from repro.data import graph_data
    from repro.models import gnn as gnn_lib
    from repro.train import optimizer as O

    cfg = gnn_lib.GatedGCNConfig(name="t", n_layers=2, d_hidden=16, d_in=6,
                                 d_edge_in=8, n_classes=4)
    g = graph_data.random_graph(graph_data.GraphConfig(
        n_nodes=120, n_edges=600, d_feat=6, n_classes=4, seed=3))
    params = gnn_lib.init_gatedgcn(jax.random.PRNGKey(0), cfg)
    init_opt, update = O.adamw(O.OptimizerConfig(lr=3e-3))
    opt = init_opt(params)

    @jax.jit
    def step(params, opt_state, batch):
        def loss_fn(p):
            loss, _ = gnn_lib.gatedgcn_loss(p, batch, cfg)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, info = update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    def data():
        while True:
            yield {k: jnp.asarray(v) for k, v in g.items()}

    trainer = Trainer(TrainConfig(total_steps=25, log_every=100,
                                  ckpt_every=10,
                                  ckpt_dir=str(tmp_path / "gnn")),
                      step, params, opt, data())
    trainer.run(log_fn=lambda *_: None)
    trainer.checkpointer.wait()
    first = trainer.history[0]["loss"] if trainer.history else None
    assert C.latest_step(str(tmp_path / "gnn")) == 25
