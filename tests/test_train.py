"""Training substrate: optimizer, checkpoint/restart, fault tolerance,
gradient compression."""

import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C
from repro.train import compression as Z
from repro.train import optimizer as O
from repro.train.trainer import StragglerMonitor, TrainConfig, Trainer

pytestmark = pytest.mark.slow


def test_adamw_converges_on_quadratic():
    init, update = O.adamw(O.OptimizerConfig(
        lr=0.1, warmup_steps=0, decay_steps=1000, weight_decay=0.0,
        schedule="constant"))
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = init(params)
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum((p["x"] - target) ** 2))(params)
        params, state, _ = update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clipping_and_schedule():
    cfg = O.OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                            clip_norm=1.0)
    sched = O.make_schedule(cfg)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(cfg.min_lr_ratio)
    clipped, norm = O.clip_by_global_norm({"g": jnp.full((4,), 100.0)}, 1.0)
    assert float(O.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.asarray([1, 2], jnp.int32)}}
    C.save(d, 3, tree, extra={"note": "x"})
    assert C.latest_step(d) == 3
    restored, extra = C.restore(d, 3, jax.eval_shape(lambda: tree))
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert extra == {"note": "x"}
    # a checkpoint without .COMMIT is invisible (atomicity)
    os.remove(os.path.join(d, "step_00000003", ".COMMIT"))
    assert C.latest_step(d) is None


def test_checkpoint_shape_validation(tmp_path):
    d = str(tmp_path)
    C.save(d, 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        C.restore(d, 1, {"a": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


def test_checkpoint_gc(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        C.save(d, s, {"a": jnp.zeros(1)})
    C.garbage_collect(d, keep=2)
    assert C.latest_step(d) == 4
    assert not os.path.exists(os.path.join(d, "step_00000001"))


def _make_trainer(tmp_path, steps=12):
    init, update = O.adamw(O.OptimizerConfig(lr=0.05, warmup_steps=0,
                                             schedule="constant"))
    params = {"x": jnp.zeros(2)}
    opt = init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.sum((p["x"] - batch) ** 2))(params)
        params, opt_state, info = update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    def data():
        while True:
            yield jnp.asarray([1.0, -1.0])

    cfg = TrainConfig(total_steps=steps, log_every=50, ckpt_every=5,
                      ckpt_dir=str(tmp_path / "ckpt"))
    return Trainer(cfg, step, params, opt, data())


def test_trainer_runs_and_checkpoints(tmp_path):
    t = _make_trainer(tmp_path)
    t.run(log_fn=lambda *_: None)
    t.checkpointer.wait()
    assert C.latest_step(str(tmp_path / "ckpt")) == 12


def test_trainer_auto_resume(tmp_path):
    t = _make_trainer(tmp_path)
    t.run(log_fn=lambda *_: None)
    t.checkpointer.wait()
    # a "restarted job": fresh trainer, same ckpt dir → resumes at step 12
    t2 = _make_trainer(tmp_path, steps=15)
    assert t2.maybe_resume()
    assert t2.step == 12
    t2.run(log_fn=lambda *_: None)
    t2.checkpointer.wait()
    assert C.latest_step(str(tmp_path / "ckpt")) == 15


def test_trainer_preemption(tmp_path):
    t = _make_trainer(tmp_path, steps=10_000)
    msgs = []
    orig_record = t.monitor.record

    def record_and_preempt(dt):
        if t.step == 7:
            t._preempted = True  # simulate SIGTERM mid-run
        return orig_record(dt)

    t.monitor.record = record_and_preempt
    t.run(log_fn=msgs.append)
    t.checkpointer.wait()
    assert t.step == 7
    assert C.latest_step(str(tmp_path / "ckpt")) == 7
    assert any("preemption" in m for m in msgs)


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(window=10, factor=3.0)
    flagged = [m.record(0.1) for _ in range(8)]
    assert not any(flagged)
    assert m.record(1.0) is True
    assert m.flags == 1


def test_int8_error_feedback_is_unbiased_over_time():
    """With error feedback, the accumulated quantized sum tracks the true
    gradient sum (residuals are carried, not dropped)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256).astype(np.float32) * 1e-3)
    err = jnp.zeros(256)
    acc = jnp.zeros(256)
    for _ in range(50):
        q, scale, err = Z.quantize_int8(g_true, err)
        acc = acc + q.astype(jnp.float32) * scale
    rel = float(jnp.linalg.norm(acc / 50 - g_true) / jnp.linalg.norm(g_true))
    assert rel < 0.02
    # without error feedback the same signal can vanish entirely
    q0, s0, _ = Z.quantize_int8(g_true * 1e-6)
    assert float(jnp.abs(q0).max()) <= 127


def test_bf16_compression_roundtrip():
    g = {"w": jnp.asarray([1.0, 2.0, 3.0])}
    out = Z.decompress_bf16(Z.compress_bf16(g))
    np.testing.assert_allclose(np.asarray(out["w"]), [1, 2, 3], rtol=1e-2)
