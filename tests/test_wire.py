"""Wire-protocol hardening tests: framing, limits, error codes, rate/auth.

The seed bug this guards against: ``asyncio``'s 64 KiB default line limit
made ``reader.readline()`` raise ``ValueError: Separator is found, but
chunk is longer than limit`` on any realistic ``register_qrel`` payload,
killing the connection with no response.  Everything here asserts the
replacement contract — every failure is an ``ok: false`` *response* with a
machine-readable ``code``, and the connection keeps serving.
"""

import asyncio
import json

import pytest

from repro.serve import EvaluationService, handle_line, handle_request
from repro.serve.wire import (ERROR_CODES, OversizedFrame, ProtocolError,
                              TokenBucket, iter_frames)


# -- framing ------------------------------------------------------------------


def _frames(chunks, limit):
    """Feed byte chunks through iter_frames; return the yielded items."""

    async def main():
        reader = asyncio.StreamReader()
        for chunk in chunks:
            reader.feed_data(chunk)
        reader.feed_eof()
        return [f async for f in iter_frames(reader, limit)]

    return asyncio.run(main())


def test_iter_frames_basic_lines():
    out = _frames([b"one\ntwo\n", b"thr", b"ee\n"], limit=1024)
    assert out == [b"one", b"two", b"three"]


def test_iter_frames_trailing_frame_without_newline():
    assert _frames([b"a\nb"], limit=1024) == [b"a", b"b"]


def test_iter_frames_oversized_yields_marker_and_stays_aligned():
    big = b"x" * 5000
    out = _frames([b"ok1\n", big + b"\n", b"ok2\n"], limit=100)
    assert out[0] == b"ok1"
    assert isinstance(out[1], OversizedFrame)
    assert out[1].limit == 100 and out[1].size > 100
    assert out[2] == b"ok2"  # the stream recovered on the next line


def test_iter_frames_oversized_split_across_many_chunks():
    # the oversized line arrives in dribbles, newline in a later chunk
    chunks = [b"y" * 64 for _ in range(10)] + [b"\nafter\n"]
    out = _frames(chunks, limit=100)
    markers = [f for f in out if isinstance(f, OversizedFrame)]
    assert len(markers) == 1  # ONE error per oversized frame, not per chunk
    assert out[-1] == b"after"


def test_iter_frames_exact_limit_is_not_oversized():
    out = _frames([b"z" * 100 + b"\n"], limit=100)
    assert out == [b"z" * 100]


# -- framing fuzz: random splits, limit straddles, garbage interleave ---------
#
# The invariant under fuzz: however the byte stream is cut into read
# chunks, iter_frames yields exactly one item per input line, in order —
# the payload bytes for lines within the limit, ONE OversizedFrame marker
# for lines beyond it — and realigns on the next newline every time.
# Property-based via hypothesis when installed; a seeded random sweep
# covers the same ground always.


def _fuzz_lines(rng, limit):
    """Random line payloads: blanks, garbage, limit straddles, big blobs."""
    lines = []
    for _ in range(rng.randint(1, 12)):
        roll = rng.random()
        if roll < 0.15:
            lines.append(b"")  # blank line: still one (empty) frame
        elif roll < 0.35:  # garbage that is not JSON — framing doesn't care
            lines.append(bytes(rng.choice(b'{<garbage>:,"\\')
                               for _ in range(rng.randint(1, 30))))
        elif roll < 0.55:  # straddle the limit exactly: -1, exact, +1
            lines.append(b"s" * (limit + rng.choice((-1, 0, 1))))
        else:
            lines.append(b"x" * rng.randint(1, 2 * limit))
    return lines


def _random_chunks(rng, stream, max_cuts=8):
    """Cut a byte stream at random positions (coalescing + splitting)."""
    cuts = sorted(rng.randrange(len(stream) + 1)
                  for _ in range(rng.randint(0, max_cuts)))
    bounds = [0] + cuts + [len(stream)]
    return [stream[a:b] for a, b in zip(bounds, bounds[1:]) if b > a]


def _assert_aligned(lines, chunks, limit):
    got = _frames(chunks, limit)
    assert len(got) == len(lines), (len(got), len(lines))
    for item, line in zip(got, lines):
        if len(line) > limit:
            assert isinstance(item, OversizedFrame)
            assert item.limit == limit and item.size > limit
        else:
            assert item == line


@pytest.mark.parametrize("seed", range(30))
def test_iter_frames_fuzz_seeded(seed):
    import random

    rng = random.Random(seed)
    limit = rng.choice((16, 64, 100, 257))
    lines = _fuzz_lines(rng, limit)
    stream = b"".join(line + b"\n" for line in lines)
    _assert_aligned(lines, _random_chunks(rng, stream), limit)


def test_iter_frames_fuzz_one_byte_chunks():
    """The pathological dribble: every chunk is a single byte."""
    import random

    rng = random.Random(99)
    limit = 32
    lines = _fuzz_lines(rng, limit)
    stream = b"".join(line + b"\n" for line in lines)
    _assert_aligned(lines, [stream[i:i + 1] for i in range(len(stream))],
                    limit)


def test_iter_frames_limit_straddle_at_chunk_boundary():
    """Frames of limit-1/limit/limit+1 bytes, each split AT the limit."""
    limit = 50
    for size in (limit - 1, limit, limit + 1):
        line = b"b" * size
        for cut in (limit - 1, limit, min(size, limit)):
            stream = line + b"\nafter\n"
            chunks = [stream[:cut], stream[cut:]]
            _assert_aligned([line, b"after"], chunks, limit)


def test_iter_frames_fuzz_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(data=st.data(), limit=st.integers(8, 300))
    def inner(data, limit):
        lines = data.draw(st.lists(
            st.one_of(
                st.binary(max_size=3 * limit).filter(
                    lambda b: b"\n" not in b),
                st.integers(-1, 1).map(
                    lambda d: b"s" * max(0, limit + d))),
            min_size=1, max_size=10))
        stream = b"".join(line + b"\n" for line in lines)
        n_cuts = data.draw(st.integers(0, 8))
        cuts = sorted(data.draw(st.integers(0, len(stream)))
                      for _ in range(n_cuts))
        bounds = [0] + cuts + [len(stream)]
        chunks = [stream[a:b] for a, b in zip(bounds, bounds[1:]) if b > a]
        _assert_aligned(lines, chunks, limit)

    inner()


def test_tcp_every_line_gets_exactly_one_response():
    """Interleave pings, garbage, and oversized lines on one connection:
    N lines in → N responses out, ids aligned, connection never dies."""
    from repro.serve import EvaluationService, serve_tcp

    async def main():
        svc = EvaluationService(backend="single")
        server = await serve_tcp(svc, "127.0.0.1", 0, limit=256)
        port = server.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        import random

        rng = random.Random(5)
        sent = []  # expected (kind, id) per line, in order
        payload = b""
        for i in range(40):
            roll = rng.random()
            if roll < 0.4:
                payload += json.dumps({"op": "ping", "id": i}).encode() \
                    + b"\n"
                sent.append(("pong", i))
            elif roll < 0.7:
                payload += b"}{ not json at all %d\n" % i
                sent.append(("bad_request", None))
            else:
                pad = b"x" * rng.randint(256, 600)
                payload += b'{"op": "ping", "id": %d, "pad": "%s"}\n' \
                    % (i, pad)
                sent.append(("frame_too_large", None))
        # dribble the whole payload in random chunks
        for chunk in _random_chunks(rng, payload, max_cuts=25):
            writer.write(chunk)
            await writer.drain()
        replies = [json.loads(await reader.readline()) for _ in sent]
        writer.close()
        await writer.wait_closed()
        server.close()
        await server.wait_closed()
        return sent, replies

    sent, replies = asyncio.run(main())
    assert len(replies) == len(sent)  # exactly one response per line
    # responses may arrive out of order (per-line tasks): match pings by
    # echoed id and error lines by code count — nothing lost, nothing dup
    want_pongs = {rid for kind, rid in sent if kind == "pong"}
    got_pongs = {r["id"] for r in replies if r.get("ok")}
    assert got_pongs == want_pongs
    assert all(r["result"] == "pong" for r in replies if r.get("ok"))
    for code in ("bad_request", "frame_too_large"):
        want = sum(1 for kind, _ in sent if kind == code)
        got = sum(1 for r in replies
                  if not r.get("ok") and r["code"] == code)
        assert got == want, code


# -- token bucket -------------------------------------------------------------


def test_token_bucket_burst_then_spacing():
    bucket = TokenBucket(rate=10, burst=3, clock=lambda: 0.0)
    waits = [bucket.reserve() for _ in range(5)]
    assert waits[:3] == [0.0, 0.0, 0.0]
    assert waits[3] == pytest.approx(0.1)
    assert waits[4] == pytest.approx(0.2)  # FIFO reservations queue up


def test_token_bucket_refills_with_time():
    now = [0.0]
    bucket = TokenBucket(rate=10, burst=1, clock=lambda: now[0])
    assert bucket.reserve() == 0.0
    assert bucket.reserve() == pytest.approx(0.1)
    now[0] = 1.0  # plenty of time passes; capacity caps the refill
    assert bucket.reserve() == 0.0
    assert bucket.reserve() == pytest.approx(0.1)


def test_token_bucket_validation():
    with pytest.raises(ValueError):
        TokenBucket(rate=0)
    with pytest.raises(ValueError):
        TokenBucket(rate=5, burst=0.25)


# -- protocol error codes -----------------------------------------------------


def _roundtrip(service, req):
    return asyncio.run(handle_request(service, req))


@pytest.fixture()
def service():
    svc = EvaluationService(backend="single")
    svc.register_qrel("web", {"q1": {"d1": 1, "d2": 0}}, ("map",))
    return svc


def test_unknown_op_code(service):
    resp = _roundtrip(service, {"op": "frobnicate", "id": 1})
    assert not resp["ok"] and resp["code"] == "unknown_op"
    assert "unknown op" in resp["error"]


def test_missing_field_is_named(service):
    resp = _roundtrip(service, {"op": "register_qrel", "id": 2,
                                "qrel": {"q1": {"d1": 1}}})
    assert not resp["ok"] and resp["code"] == "missing_field"
    assert "'register_qrel'" in resp["error"]
    assert "'qrel_id'" in resp["error"]  # names the op AND the field
    resp = _roundtrip(service, {"op": "evaluate", "id": 3})
    assert resp["code"] == "missing_field" and "'qrel_id'" in resp["error"]


def test_unknown_qrel_is_not_found(service):
    resp = _roundtrip(service, {"op": "evaluate", "id": 4,
                                "qrel_id": "nope", "run": {}})
    assert not resp["ok"] and resp["code"] == "not_found"
    assert "unknown qrel_id 'nope'" in resp["error"]


def test_exactly_one_of_violation_is_invalid(service):
    resp = _roundtrip(service, {"op": "evaluate", "id": 5, "qrel_id": "web",
                                "run": {}, "run_ref": "r"})
    assert not resp["ok"] and resp["code"] == "invalid"


def test_bad_request_line_code(service):
    resp = json.loads(asyncio.run(handle_line(service, "{not json")))
    assert not resp["ok"] and resp["code"] == "bad_request"
    resp = json.loads(asyncio.run(handle_line(service, '["array"]')))
    assert resp["code"] == "bad_request"


def test_all_emitted_codes_are_registered(service):
    for req in ({"op": "zzz"}, {"op": "evaluate"},
                {"op": "evaluate", "qrel_id": "zzz", "run": {}}):
        resp = _roundtrip(service, req)
        assert resp["code"] in ERROR_CODES
    with pytest.raises(AssertionError):
        ProtocolError("x", code="not-a-real-code")


# -- relevance_level: one conversion, aligned with the CLI -------------------


def test_relevance_level_int_and_float_agree(service):
    qrel = {"q1": {"d1": 2, "d2": 1}}
    run = {"q1": {"d1": 1.0, "d2": 2.0}}
    results = []
    for rid, level in (("i", 2), ("f", 2.0)):
        reg = _roundtrip(service, {"op": "register_qrel", "qrel_id": rid,
                                   "qrel": qrel, "measures": ["map"],
                                   "relevance_level": level})
        assert reg["ok"], reg
        # the single int→float conversion happens in the evaluator core
        assert reg["result"]["relevance_level"] == 2.0
        resp = _roundtrip(service, {"op": "evaluate", "qrel_id": rid,
                                    "run": run})
        results.append(resp["result"]["per_query"])
    assert results[0] == results[1]  # bit-identical
    # only d1 is relevant at level 2 and it ranks second
    assert results[0]["q1"]["map"] == 0.5


def test_relevance_level_rejects_non_numbers(service):
    for bad in ("2", None, True, [2]):
        resp = _roundtrip(service, {"op": "register_qrel", "qrel_id": "x",
                                    "qrel": {"q1": {"d1": 1}},
                                    "relevance_level": bad})
        assert not resp["ok"] and resp["code"] == "invalid", bad
        assert "relevance_level" in resp["error"]


# -- measure dialects over the wire -------------------------------------------


def test_register_qrel_accepts_either_measure_dialect(service):
    qrel = {"q1": {"d1": 1, "d2": 0}}
    run = {"q1": {"d1": 2.0, "d2": 1.0}}
    per_query = []
    for rid, measures in (("trec", ["ndcg_cut_10", "map", "judged_5"]),
                          ("ir", ["nDCG@10", "AP", "Judged@5"])):
        reg = _roundtrip(service, {"op": "register_qrel", "qrel_id": rid,
                                   "qrel": qrel, "measures": measures})
        assert reg["ok"], reg
        # canonical trec_eval keys come back whatever the request dialect
        assert set(reg["result"]["measure_keys"]) == \
            {"ndcg_cut_10", "map", "judged_5"}
        resp = _roundtrip(service, {"op": "evaluate", "qrel_id": rid,
                                    "run": run})
        assert resp["ok"], resp
        per_query.append(resp["result"]["per_query"])
    assert per_query[0] == per_query[1]  # bit-identical through the wire


def test_unknown_measure_is_invalid_and_names_it(service):
    for bad in ("Bogus@5", "bogus", "RBP(p=1.5)", "P@0"):
        resp = _roundtrip(service, {"op": "register_qrel", "qrel_id": "x",
                                    "qrel": {"q1": {"d1": 1}},
                                    "measures": [bad]})
        assert not resp["ok"] and resp["code"] == "invalid", bad
        assert bad in resp["error"], resp["error"]
    # the connection survives: the original collection still answers
    resp = _roundtrip(service, {"op": "evaluate", "qrel_id": "web",
                                "run": {"q1": {"d1": 1.0}}})
    assert resp["ok"]


def test_judged_docs_only_over_the_wire(service):
    qrel = {"q1": {"d1": 1, "d2": 0}}
    run = {"q1": {"dx": 3.0, "d1": 2.0, "d2": 1.0}}  # dx is unjudged
    reg = _roundtrip(service, {"op": "register_qrel", "qrel_id": "j",
                               "qrel": qrel, "measures": ["map", "num_ret"],
                               "judged_docs_only": True})
    assert reg["ok"] and reg["result"]["judged_docs_only"] is True
    resp = _roundtrip(service, {"op": "evaluate", "qrel_id": "j",
                                "run": run})
    q1 = resp["result"]["per_query"]["q1"]
    assert q1["num_ret"] == 2.0  # dx dropped before scoring
    assert q1["map"] == 1.0      # d1 ranks first among the judged docs


# -- TCP integration: oversized frames, rate limiting, drain ------------------


@pytest.fixture()
def qrel():
    return {"q1": {"d1": 1, "d2": 0}}


def test_tcp_oversized_frame_gets_error_response_then_recovers(qrel):
    from repro.serve import serve_tcp

    async def main():
        svc = EvaluationService(backend="single")
        svc.register_qrel("web", qrel, ("map",))
        server = await serve_tcp(svc, "127.0.0.1", 0, limit=1024)
        port = server.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        # a >limit request line: must produce an error RESPONSE, and the
        # same connection must keep working afterwards
        writer.write(b'{"op": "ping", "pad": "' + b"x" * 4096 + b'"}\n')
        writer.write(json.dumps(
            {"op": "evaluate", "id": 7, "qrel_id": "web",
             "run": {"q1": {"d1": 1.0}}}).encode() + b"\n")
        await writer.drain()
        first = json.loads(await reader.readline())
        second = json.loads(await reader.readline())
        writer.close()
        await writer.wait_closed()
        server.close()
        await server.wait_closed()
        return first, second

    first, second = asyncio.run(main())
    assert not first["ok"] and first["code"] == "frame_too_large"
    assert "frame limit" in first["error"]
    assert second["ok"] and second["id"] == 7
    assert second["result"]["per_query"]["q1"]["map"] == 1.0


def test_tcp_rate_limit_delays_but_never_drops(qrel):
    from repro.serve import serve_tcp

    async def main():
        svc = EvaluationService(backend="single")
        server = await serve_tcp(svc, "127.0.0.1", 0,
                                 rate_limit=100, burst=1)
        port = server.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        n = 8
        loop = asyncio.get_running_loop()
        t0 = loop.time()
        for i in range(n):
            writer.write(json.dumps({"op": "ping", "id": i}).encode()
                         + b"\n")
        await writer.drain()
        replies = [json.loads(await reader.readline()) for _ in range(n)]
        elapsed = loop.time() - t0
        writer.close()
        await writer.wait_closed()
        server.close()
        await server.wait_closed()
        return replies, elapsed

    replies, elapsed = asyncio.run(main())
    assert all(r["ok"] and r["result"] == "pong" for r in replies)
    # 8 requests at 100/s with burst 1 → >= 70ms of enforced spacing;
    # assert half of it to stay robust under CI jitter
    assert elapsed > 0.035


def test_service_drain_waits_for_inflight_batches(qrel):
    async def main():
        svc = EvaluationService(window=0.05, backend="single")
        svc.register_qrel("web", qrel, ("map",))
        task = asyncio.get_running_loop().create_task(
            svc.evaluate("web", run={"q1": {"d1": 1.0}}))
        await asyncio.sleep(0)  # the request enters its coalescing window
        await svc.drain()
        assert task.done()  # drain resolved only after the batch flushed
        return (await task).per_query["q1"]["map"]

    assert asyncio.run(main()) == 1.0
